"""Step-time regression gate for the benchmark smoke tier.

Compares fresh ``--smoke`` step-times against the committed baseline in
``results/BENCH_large_graph.json`` (its ``smoke_baseline`` section) and
exits nonzero when any swept engine configuration drifted by more than
``--tolerance`` — so a change that quietly wrecks a layout's step-time
fails CI even though every correctness test still passes.

The comparison is **relative, not absolute**: each configuration's
steps/sec is first normalized by the *same run's* ``sparse`` number for
the same graph family, and the gate compares those ratios between the
fresh run and the baseline.  Host speed cancels out — a CI runner 3x
slower than the baseline machine shifts every configuration equally and
passes, while a single layout falling off its fast path (or the sparse
reference itself rotting, which shows as every other ratio rising) trips
the gate on any machine.

Usage (what CI and tests/test_bench_smoke.py run):

    PYTHONPATH=src python -m benchmarks.run --smoke --json smoke.json
    PYTHONPATH=src python benchmarks/check_regression.py --fresh smoke.json

Without ``--fresh`` the smoke tier is executed in-process.  The default
tolerance (2.5x) is deliberately generous: smoke sizes are tiny and even
same-machine ratios jitter, so this gate catches order-of-magnitude rot
(a layout losing its kernel path, an accidental O(W·n) gather), not
percent-level drift — the full sweep in ``docs/benchmarks.md`` is the
precision instrument.  *New* configurations in the fresh run are ignored
until ``--update`` adopts them into the baseline, but a configuration the
baseline knows that the fresh run no longer sweeps — a layout silently
dropped from the sweep, exactly the rot this gate exists for — is a loud
failure with the missing key named, never a silent skip (and never a bare
``KeyError``).  Refresh the committed baseline with ``--update`` after
intentional perf or sweep changes (it is force-committed past the
``results/`` scratch ignore, see .gitignore).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:  # allow `python benchmarks/check_regression.py`
    sys.path.insert(0, REPO)

BASELINE_PATH = os.path.join(REPO, "results", "BENCH_large_graph.json")
METRIC_SUFFIX = "_steps_per_sec"
REFERENCE_LABEL = "sparse"
# Presence-gated keys: the law sweep's `{family}_{law}_herfindahl`
# telemetry, the dynamic-graph sweep's `{family}_churn_speedup`, and the
# serving sweep's `ba_{law}_p99_ticks` / `ba_{law}_requests_per_sec`.
# These values are statistical (walk occupancy), wall-clock ratios or
# latency percentiles on a tiny smoke batch, not step-times, so their
# magnitude is not compared — each key is pinned to ratio 1.0 and only its
# EXISTENCE is gated: a chain law, the churn sweep, or a serving routing
# law silently dropped from the run is a loud missing-key failure, a noisy
# value is not.
PRESENCE_SUFFIXES = (
    "_herfindahl", "_churn_speedup", "_p99_ticks", "_requests_per_sec",
    "_rescue", "_fault_free",
)
# Fleet rows (`fleet_w{W}_aggregate_walk_steps_per_sec`) have no sparse
# sibling: they normalize against the same sweep's smallest-W row, so the
# gate watches the W-scaling shape — and a fleet configuration vanishing
# from the sweep still fails loudly via the usual missing-key path.
AGGREGATE_SUFFIX = "_aggregate_walk" + METRIC_SUFFIX
_AGGREGATE_RE = re.compile(
    r"^(?P<prefix>.+)_w(?P<w>\d+)" + re.escape(AGGREGATE_SUFFIX) + r"$"
)


def aggregate_ratios(derived: dict) -> dict:
    """Fleet aggregate-throughput keys normalized by the smallest-W row of
    the same ``{prefix}_w{W}`` group (which is omitted, trivially 1)."""
    groups: dict = {}
    for key, val in derived.items():
        m = _AGGREGATE_RE.match(key)
        if m and val:
            groups.setdefault(m["prefix"], []).append((int(m["w"]), key, val))
    out = {}
    for rows in groups.values():
        rows.sort()
        ref = rows[0][2]
        for _, key, val in rows[1:]:
            out[key] = val / ref
    return out


def fresh_smoke_derived() -> dict:
    """Run the smoke tiers in-process; returns {module: derived}."""
    from benchmarks import (
        fault_sweep,
        fig5_sparse_graphs,
        large_graph_walk,
        law_sweep,
        serve_throughput,
    )

    return {
        mod.NAME: mod.run_smoke().get("derived", {})
        for mod in (
            fig5_sparse_graphs, large_graph_walk, law_sweep,
            serve_throughput, fault_sweep,
        )
    }


def normalized_ratios(derived: dict) -> dict:
    """steps/sec keys divided by their family's ``sparse`` number from the
    SAME run: ``{tag}_{label}_steps_per_sec`` -> value / value of
    ``{tag}_sparse_steps_per_sec``.  Machine speed cancels in the ratio.
    The sparse keys themselves (trivially 1) and keys without a sparse
    sibling are omitted.  Fleet aggregate keys normalize within their own
    W-sweep instead (:func:`aggregate_ratios`); presence-gated keys
    (``PRESENCE_SUFFIXES``) are pinned to ratio 1.0 so only their existence
    is compared."""
    ref_suffix = f"_{REFERENCE_LABEL}{METRIC_SUFFIX}"
    tags = [k[: -len(ref_suffix)] for k in derived if k.endswith(ref_suffix)]
    out = aggregate_ratios(derived)
    for key in derived:
        if key.endswith(PRESENCE_SUFFIXES):
            out[key] = 1.0  # presence-only gate (see PRESENCE_SUFFIXES)
    for key, val in derived.items():
        if not key.endswith(METRIC_SUFFIX) or not val:
            continue
        if _AGGREGATE_RE.match(key):  # handled by aggregate_ratios above
            continue
        fam = key[: -len(METRIC_SUFFIX)]
        tag = next(
            (
                t
                for t in sorted(tags, key=len, reverse=True)
                if fam.startswith(f"{t}_")
            ),
            None,
        )
        if tag is None or fam == f"{tag}_{REFERENCE_LABEL}":
            continue
        ref = derived.get(f"{tag}{ref_suffix}")
        if ref:
            out[key] = val / ref
    return out


def compare(baseline: dict, fresh: dict, tolerance: float) -> list[str]:
    """Drift messages for every baseline normalized ratio: moved by more
    than ``tolerance`` in either direction, or missing from the fresh run
    entirely (a configuration the baseline knows was silently dropped from
    the sweep — the exact rot this gate guards).  Empty list == gate
    passes; new fresh-only configurations are ignored until ``--update``."""
    problems = []
    for module, base_derived in baseline.items():
        base_norm = normalized_ratios(base_derived)
        fresh_norm = normalized_ratios(fresh.get(module, {}))
        for key, base_ratio in base_norm.items():
            fresh_ratio = fresh_norm.get(key)
            if fresh_ratio is None:
                problems.append(
                    f"{module}:{key}: configuration in the committed "
                    "baseline but absent from the fresh smoke run — a "
                    "swept layout was dropped; if intentional, refresh "
                    "with --update"
                )
                continue
            drift = max(base_ratio / fresh_ratio, fresh_ratio / base_ratio)
            if drift > tolerance:
                problems.append(
                    f"{module}:{key}: {drift:.2f}x relative-to-{REFERENCE_LABEL} "
                    f"drift (baseline ratio {base_ratio:.3f}, fresh "
                    f"{fresh_ratio:.3f}, tolerance {tolerance}x) — if "
                    "intentional, refresh with --update"
                )
    return problems


def shared_key_count(baseline: dict, fresh: dict) -> int:
    return sum(
        1
        for module, d in baseline.items()
        for k in normalized_ratios(d)
        if k in normalized_ratios(fresh.get(module, {}))
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--fresh", default=None, metavar="PATH",
        help="JSON from `benchmarks.run --smoke --json PATH`; omitted = "
        "run the smoke tier in-process",
    )
    ap.add_argument(
        "--baseline", default=BASELINE_PATH, metavar="PATH",
        help="committed benchmark JSON holding the smoke_baseline section",
    )
    ap.add_argument(
        "--tolerance", type=float, default=2.5,
        help="max allowed relative drift factor (default 2.5, noise-safe)",
    )
    ap.add_argument(
        "--update", action="store_true",
        help="write the fresh numbers into the baseline's smoke_baseline "
        "section instead of comparing",
    )
    args = ap.parse_args()

    if args.fresh:
        with open(args.fresh) as f:
            fresh = json.load(f)
    else:
        fresh = fresh_smoke_derived()

    if args.update:
        doc = {}
        if os.path.exists(args.baseline):
            with open(args.baseline) as f:
                doc = json.load(f)
        doc["smoke_baseline"] = fresh
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=2, default=float)
        print(f"smoke_baseline updated in {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; nothing to compare",
              file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        doc = json.load(f)
    baseline = doc.get("smoke_baseline")
    if not baseline:
        print(
            f"{args.baseline} has no smoke_baseline section; run "
            "`python benchmarks/check_regression.py --update` and commit",
            file=sys.stderr,
        )
        return 2

    problems = compare(baseline, fresh, args.tolerance)
    if problems:
        print(f"step-time regressions ({len(problems)}):")
        for p in problems:
            print(f"  {p}")
        return 1
    print(
        f"no step-time regressions across "
        f"{shared_key_count(baseline, fresh)} configurations "
        f"(relative drift tolerance {args.tolerance}x)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
