"""Shared benchmark utilities: timing, result records, milestone metrics."""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def milestones(mse: np.ndarray, ts=(1000, 5000, 10000, 20000, -1)) -> dict:
    out = {}
    for t in ts:
        idx = len(mse) - 1 if t == -1 else min(t, len(mse) - 1)
        lo, hi = max(0, idx - 250), min(len(mse), idx + 250)
        out[f"mse@{'end' if t == -1 else t}"] = float(np.median(mse[lo:hi]))
    return out


def time_call(fn, *args, **kwargs):
    t0 = time.time()
    out = fn(*args, **kwargs)
    return out, time.time() - t0


def dump(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"bench_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def row(name: str, seconds: float, derived: dict) -> str:
    kv = " ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                  for k, v in derived.items())
    return f"{name},{seconds * 1e6:.0f},{kv}"
