"""Chaos sweep: convergence and serving under node failures, rescue on/off.

Training leg — the fleet scan under a Markov node-fault process
(``repro.core.faults.FaultModel``: per-tick crash probability, slow
recovery) on the two fault-sensitive families: the **dumbbell** (one
bridge — a single death disconnects the cliques) and
**Barabasi-Albert** (hub deaths take out the routing shortcuts).  Per
failure rate the sweep runs the same seeded scan three ways —
fault-free, faults with the Lévy-jump rescue, faults with the rescue
disabled — and reports the *convergence excess*: tail-window
fleet-averaged MSE minus the exact least-squares optimum.  Rescue-off
walkers park on dead nodes for the full outage (their compute is down,
they are excluded from the masked averaging, and their stale models
drag the fleet mean), so their excess stalls; rescue-on walkers
teleport to the live set after ``patience`` blocked steps and keep
training.

The data is *homogeneous* regression deliberately: the forced rescue
jump is uniform over the live set, which perturbs the chain's
stationary visit distribution — under heterogeneous data the
importance-weighted laws would fold that perturbation into their
L_bar/L_v correction and the measurement would conflate rescue bias
with fault stalls (docs/faults.md, "rescue bias").  Homogeneous data
keeps the mhlj weights ≈ 1, so the sweep isolates the fault dynamics.

Serving leg — one fault-free ``ServeSimulator`` run records its arrival
trace, then every (failure rate × rescue) leg replays the *identical*
workload (``arrival_trace=``) under faults, so p99 latency and the shed
rate (queue-full + deadline + node_down, over offered) isolate the
policy: any difference between legs is degradation handling, not load
noise.

The full sweep lands in ``results/BENCH_faults.json``.  The smoke tier
runs one failure rate at toy sizes; its ``*_with_rescue`` /
``*_no_rescue`` derived keys are presence-gated by
``benchmarks/check_regression.py`` (values are statistical, so only
their existence is compared) — a rescue leg silently dropped from the
sweep is a loud missing-key CI failure on both ``REPRO_BACKEND`` legs.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR
from repro.configs import get_arch, reduced
from repro.core.faults import FaultModel
from repro.core.graphs import barabasi_albert, dumbbell
from repro.data.synthetic import make_homogeneous_regression
from repro.launch.serve import ServeEngine, ServeSimulator
from repro.models import regression as reg
from repro.walk_sgd.fleet import WalkFleet, run_fleet

NAME = "fault_sweep"
PAPER_CLAIM = (
    "Node failures re-create the entrapment problem at runtime: a walker "
    "blocked by dead nodes stops mixing exactly like a trapped one.  The "
    "Lévy-jump rescue (forced jump to the live set after `patience` "
    "blocked steps) restores convergence to within ~2x of the fault-free "
    "run at a 5% per-tick failure rate, while the rescue-off fleet "
    "stalls; on the serving side the same faults show up as p99/shed-rate "
    "degradation that trace-replayed legs make directly comparable."
)

RATES = {"smoke": (0.05,), "quick": (0.05,), "full": (0.01, 0.05, 0.10)}

SCALES = {
    "smoke": dict(
        dumbbell=(10, 1), ba=(96, 2), dim=4, steps=240, walks=6,
        avg_every=20, recovery=0.05, patience=2,
        serve=dict(
            n=96, m=2, walkers=8, ticks=60, drain=30, rate=1.0, pickup=2,
            batch=2, cache_len=64, max_queue=16, deadline=50,
            prompt_len=(3, 6), max_new=4, relocate_after=2,
        ),
    ),
    "quick": dict(
        dumbbell=(30, 2), ba=(500, 3), dim=8, steps=800, walks=8,
        avg_every=25, recovery=0.05, patience=2,
        serve=dict(
            n=500, m=3, walkers=24, ticks=200, drain=80, rate=1.2, pickup=4,
            batch=4, cache_len=96, max_queue=32, deadline=150,
            prompt_len=(4, 10), max_new=6, relocate_after=3,
        ),
    ),
    "full": dict(
        dumbbell=(60, 2), ba=(2000, 3), dim=10, steps=600, walks=16,
        avg_every=25, recovery=0.02, patience=2,
        serve=dict(
            n=2000, m=3, walkers=64, ticks=500, drain=200, rate=1.5,
            pickup=4, batch=8, cache_len=128, max_queue=64, deadline=350,
            prompt_len=(4, 16), max_new=8, relocate_after=3,
        ),
    ),
}


def _graphs(p):
    """(family, graph, data) for the two fault-sensitive families.

    Homogeneous data on purpose — see the module docstring: the uniform
    rescue jump perturbs the visit distribution, and a flat Lipschitz
    field keeps the mhlj importance weights ≈ 1 so that perturbation
    cannot masquerade as (or hide) the fault-stall signal.
    """
    c, plen = p["dumbbell"]
    g_dumb = dumbbell(c, path_len=plen)
    d_dumb = make_homogeneous_regression(g_dumb.n, dim=p["dim"], seed=0)
    n, m = p["ba"]
    g_ba = barabasi_albert(n, m, seed=0, layout="ragged")
    d_ba = make_homogeneous_regression(n, dim=p["dim"], seed=1)
    return (("dumbbell", g_dumb, d_dumb), ("ba", g_ba, d_ba))


def _mse_opt(data) -> float:
    """Exact least-squares optimum of the paper's reported MSE metric."""
    F = np.asarray(data.features, np.float64)
    y = np.asarray(data.targets, np.float64)
    x_opt, *_ = np.linalg.lstsq(F, y, rcond=None)
    return float(np.mean((y - F @ x_opt) ** 2))


def _train_leg(graph, data, p, *, seed=0, fault_model=None) -> dict:
    """One fleet run (mhlj law) → final averaged MSE + fault telemetry."""
    from repro.walk_sgd import trainer as trainer_mod

    steps, walks = p["steps"], p["walks"]
    row_probs, weights, p_j_sched, p_d, r, use_weights = (
        trainer_mod._setup_method(
            "mhlj", graph, data, None, None, steps, None
        )
    )
    engine = trainer_mod._build_engine(graph, p_d, r, row_probs, None, "auto")
    fleet = WalkFleet.create(
        engine, walks, seed=seed, avg_every=p["avg_every"]
    )
    lips = np.asarray(data.lipschitz, np.float64)
    gamma = 0.3 / float(lips.mean())
    x0s = jnp.zeros((walks, data.dim), jnp.float32)
    _xs, _mses, avg_mses, _nodes, _hops, final = run_fleet(
        jax.random.PRNGKey(seed),
        x0s,
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights,
        fleet,
        steps,
        gamma,
        p_j_sched,
        use_weights,
        reg.linear_grad,
        faults=fault_model,
    )
    # tail-window mean: the plateau level, not one noisy last sample
    tail = max(1, steps // 10)
    out = {"final_avg_mse": float(np.asarray(avg_mses)[-tail:].mean())}
    if fault_model is not None:
        out["rescues"] = int(np.asarray(final["rescued"]).sum())
        out["blocked_steps"] = int(np.asarray(final["blocked"]).sum())
    return out


def _serve_leg(graph, sp, engine, *, fault_model=None, trace=None) -> dict:
    sim = ServeSimulator(
        graph,
        engine.reset(),
        method="mhlj",
        num_walkers=sp["walkers"],
        rate=sp["rate"],
        pickup=sp["pickup"],
        deadline_ticks=sp["deadline"],
        prompt_len=sp["prompt_len"],
        max_new_tokens=sp["max_new"],
        seed=0,
        fault_model=fault_model,
        relocate_after=sp["relocate_after"],
        arrival_trace=trace,
    )
    m = sim.run(sp["ticks"], drain_ticks=sp["drain"])
    shed = m["shed_queue_full"] + m["shed_deadline"] + m["shed_node_down"]
    m["shed_rate"] = shed / max(1, m["offered"])
    m["arrival_log"] = sim.arrival_log
    return m


def run(quick: bool = False, scale: str | None = None) -> dict:
    scale = scale or ("quick" if quick else "full")
    p = SCALES[scale]
    rates = RATES[scale]
    out = {
        "scale": scale,
        "claim": PAPER_CLAIM,
        "rates": list(rates),
        "recovery_rate": p["recovery"],
        "patience": p["patience"],
        "train": {},
        "serve": {},
    }
    derived: dict = {}

    # -- training leg: convergence excess vs failure rate ------------------
    for fam, graph, data in _graphs(p):
        opt = _mse_opt(data)
        free = _train_leg(graph, data, p)
        free_excess = max(free["final_avg_mse"] - opt, 1e-12)
        fam_out = {
            "mse_opt": opt,
            "fault_free": {**free, "excess": free_excess},
        }
        derived[f"{fam}_excess_fault_free"] = free_excess
        for rate in rates:
            pct = int(round(rate * 100))
            for tag, rescue in (("with_rescue", True), ("no_rescue", False)):
                leg = _train_leg(
                    graph, data, p,
                    fault_model=FaultModel(
                        crash_rate=rate,
                        recovery_rate=p["recovery"],
                        patience=p["patience"],
                        rescue=rescue,
                    ),
                )
                excess = max(leg["final_avg_mse"] - opt, 1e-12)
                leg["excess"] = excess
                leg["excess_vs_fault_free"] = excess / free_excess
                fam_out[f"f{pct}_{tag}"] = leg
                derived[f"{fam}_excess_f{pct}_{tag}"] = excess
        out["train"][fam] = fam_out

    # -- serving leg: identical trace replayed across rescue legs ----------
    sp = p["serve"]
    graph = barabasi_albert(sp["n"], sp["m"], seed=0, layout="ragged")
    cfg = reduced(get_arch("mamba2-370m"))
    engine = ServeEngine(
        cfg, sp["batch"], sp["cache_len"], seed=0, max_queue=sp["max_queue"]
    )
    base = _serve_leg(graph, sp, engine)
    trace = np.asarray(base.pop("arrival_log"), np.int64)
    out["serve"]["fault_free"] = base
    derived["serve_p99_fault_free"] = base["p99_ticks"]
    derived["serve_shed_rate_fault_free"] = base["shed_rate"]
    for rate in rates:
        pct = int(round(rate * 100))
        for tag, rescue in (("with_rescue", True), ("no_rescue", False)):
            m = _serve_leg(
                graph, sp, engine,
                fault_model=FaultModel(
                    crash_rate=rate,
                    recovery_rate=p["recovery"],
                    patience=p["patience"],
                    rescue=rescue,
                ),
                trace=trace,
            )
            m.pop("arrival_log")
            out["serve"][f"f{pct}_{tag}"] = m
            derived[f"serve_p99_f{pct}_{tag}"] = m["p99_ticks"]
            derived[f"serve_shed_rate_f{pct}_{tag}"] = m["shed_rate"]

    # the acceptance record: at the 5% failure rate on the dumbbell the
    # rescued fleet must sit within ~2x of the fault-free excess while the
    # rescue-off fleet stalls well beyond it
    if 0.05 in rates:
        d = out["train"]["dumbbell"]
        out["criterion"] = {
            "dumbbell_f5_with_rescue_vs_fault_free":
                d["f5_with_rescue"]["excess_vs_fault_free"],
            "dumbbell_f5_no_rescue_vs_fault_free":
                d["f5_no_rescue"]["excess_vs_fault_free"],
        }
    out["derived"] = derived

    if scale == "full":
        # only the full sweep may write the committed results file
        # (docs/faults.md cites its numbers); the smoke-tier regression
        # baseline lives in BENCH_large_graph.json's smoke_baseline
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, "BENCH_faults.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=2, default=float)
    return out


def run_smoke() -> dict:
    """Tiny tier for the tier-1 bench-smoke test: both families train
    through all three fault legs and the serving trace replays across
    rescue-on/off, so the fault path cannot rot silently."""
    return run(scale="smoke")


if __name__ == "__main__":
    res = run(scale="full")
    for k, v in sorted(res["derived"].items()):
        print(f"{k}: {v:.4g}" if isinstance(v, float) else f"{k}: {v}")
    if "criterion" in res:
        print("\ncriterion:", json.dumps(res["criterion"], indent=2))
    print(f"\nwrote {os.path.join(RESULTS_DIR, 'BENCH_faults.json')}")
