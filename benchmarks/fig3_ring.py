"""Paper Fig. 3: ring(1000), heterogeneous data, uniform vs IS vs MHLJ.

Exact paper setting: A_v ~ N(0, sigma^2 I_10) with sigma^2 = 100 w.p. 0.002
(else 1), y = A^T x* + eps, (p_J, p_d, r) = (0.1, 0.5, 3), MSE metric
sum_v (y_v - A_v x)^2 / |V|.  Entrapment makes MH-IS slower than uniform on
the ring; MHLJ restores fast convergence with a small error gap.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import milestones
from repro.core import MHLJParams, ring
from repro.core.entrapment import occupancy_concentration
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import run_rw_sgd

NAME = "fig3_ring"
PAPER_CLAIM = (
    "C3: on a sparse ring with heterogeneous data, MH-IS suffers entrapment "
    "(high top-node occupancy, slowed mid-phase convergence); MHLJ escapes "
    "and converges fastest, with a bounded error gap (Remark 1 overhead <=1.1)."
)


def run(quick: bool = False) -> dict:
    n = 256 if quick else 1000
    T = 20_000 if quick else 40_000
    graph = ring(n)
    data = make_heterogeneous_regression(
        n, dim=10, sigma_high_sq=100.0, p_high=0.002, seed=0,
        force_min_high=2, x_star_scale=10.0,
    )
    gamma_u = 0.5 / data.lipschitz.max()
    gamma = 0.5 / data.lipschitz.mean()
    params = MHLJParams(0.1, 0.5, 3)

    out = {"n": n, "T": T, "num_high": int(data.high_variance_mask.sum()),
           "claim": PAPER_CLAIM, "methods": {}}
    for method, g in (("uniform", gamma_u), ("importance", gamma), ("mhlj", gamma)):
        res = run_rw_sgd(
            method, graph, data, g, T,
            mhlj_params=params if method == "mhlj" else None,
            seed=1, v0=int(np.argmax(data.lipschitz)),
        )
        occ = occupancy_concentration(res.update_nodes, n)
        out["methods"][method] = {
            **milestones(res.mse),
            "top_node_occupancy": occ["topk_share"],
            "transitions_per_update": res.transitions_per_update,
        }
    m = out["methods"]
    out["derived"] = {
        # occupancy: IS concentrates on ONE node of n (x n = ratio-to-uniform)
        "is_entrapped_occupancy": m["importance"]["top_node_occupancy"],
        "mhlj_occupancy": m["mhlj"]["top_node_occupancy"],
        # early-phase speed (paper Fig 3's x-axis story): MSE after 1k updates
        "mhlj_vs_is_early_ratio": m["mhlj"]["mse@1000"] / m["importance"]["mse@1000"],
        "mhlj_vs_uniform_early_ratio": m["mhlj"]["mse@1000"] / m["uniform"]["mse@1000"],
        # late phase: IS oscillates at the trap while uniform passes it
        "is_vs_uniform_late_ratio": m["importance"]["mse@20000"] / m["uniform"]["mse@20000"],
        "mhlj_comm_overhead": m["mhlj"]["transitions_per_update"],
    }
    return out
