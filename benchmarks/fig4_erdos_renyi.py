"""Paper Fig. 4: Erdos-Renyi(1000, 0.1) — well-connected control.

(a) homogeneous data: uniform-MH and IS-MH converge at similar rates.
(b) heterogeneous data: IS-MH beats uniform-MH (the Needell centralized
    speedup survives decentralization when the graph is well-connected —
    no entrapment).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import milestones
from repro.core.graphs import erdos_renyi
from repro.data import make_heterogeneous_regression, make_homogeneous_regression
from repro.walk_sgd import run_rw_sgd

NAME = "fig4_erdos_renyi"
PAPER_CLAIM = (
    "C1/C2: on ER(1000,0.1), homogeneous data -> uniform ~= IS; "
    "heterogeneous data -> IS faster than uniform."
)


def _auc_log(mse, lo, hi):
    return float(np.log(np.maximum(mse[lo:hi], 1e-12)).mean())


def run(quick: bool = False) -> dict:
    n = 256 if quick else 1000
    T = 10_000 if quick else 20_000
    graph = erdos_renyi(n, 0.1, seed=0)
    out = {"n": n, "T": T, "claim": PAPER_CLAIM}

    homo = make_homogeneous_regression(n, dim=10, seed=0, x_star_scale=10.0)
    het = make_heterogeneous_regression(
        n, dim=10, sigma_high_sq=100.0, p_high=0.005, seed=1,
        force_min_high=3, x_star_scale=10.0,
    )
    for tag, data in (("homogeneous", homo), ("heterogeneous", het)):
        gamma_u = 0.5 / data.lipschitz.max()
        gamma = 0.5 / data.lipschitz.mean()
        res_u = run_rw_sgd("uniform", graph, data, gamma_u, T, seed=2)
        res_i = run_rw_sgd("importance", graph, data, gamma, T, seed=2)
        out[tag] = {
            "uniform": milestones(res_u.mse),
            "importance": milestones(res_i.mse),
            "auc_log_uniform": _auc_log(res_u.mse, 200, T // 2),
            "auc_log_importance": _auc_log(res_i.mse, 200, T // 2),
        }
    out["derived"] = {
        "homo_auc_gap": out["homogeneous"]["auc_log_importance"]
        - out["homogeneous"]["auc_log_uniform"],
        "hetero_is_advantage": out["heterogeneous"]["auc_log_uniform"]
        - out["heterogeneous"]["auc_log_importance"],
    }
    return out
