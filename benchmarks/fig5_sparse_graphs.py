"""Paper Fig. 5 + trap-prone extensions: entrapment and the MHLJ fix on
sparse topologies.

Same protocol as Fig 3 on the paper's other sparse topologies —
(a) 2-d grid (25x40 = 1000 nodes), (b) Watts-Strogatz(1000, 4, 0.1) — plus
the graph families the entrapment literature actually studies: hub-heavy
Barabasi-Albert, bottlenecked stochastic block models, and the dumbbell
worst case.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import milestones
from repro.core import MHLJParams
from repro.core.entrapment import occupancy_concentration
from repro.core.graphs import barabasi_albert, dumbbell, grid2d, sbm, watts_strogatz

NAME = "fig5_sparse_graphs"
PAPER_CLAIM = (
    "C4: the entrapment problem and the MHLJ fix replicate on 2-d grid and "
    "Watts-Strogatz sparse networks (not ring-specific), and extend to the "
    "trap-prone families (Barabasi-Albert hubs, SBM bottlenecks, dumbbell)."
)


def _graphs(scale: str) -> dict:
    if scale == "smoke":
        return {
            "grid2d": grid2d(8, 8),
            "sbm": sbm([32, 32], 0.3, 0.02, seed=0),
        }
    if scale == "quick":
        return {
            "grid2d": grid2d(16, 16),
            "watts_strogatz": watts_strogatz(256, 4, 0.1, 0),
            "barabasi_albert": barabasi_albert(256, 3, seed=0),
            "sbm": sbm([64] * 4, 0.2, 0.01, seed=0),
            "dumbbell": dumbbell(32, 16),
        }
    return {
        "grid2d": grid2d(25, 40),
        "watts_strogatz": watts_strogatz(1000, 4, 0.1, 0),
        "barabasi_albert": barabasi_albert(1000, 3, seed=0),
        "sbm": sbm([250] * 4, 0.1, 0.004, seed=0),
        "dumbbell": dumbbell(64, 128),
    }


def run(quick: bool = False, scale: str | None = None) -> dict:
    from repro.data import make_heterogeneous_regression
    from repro.walk_sgd import run_rw_sgd

    scale = scale or ("quick" if quick else "full")
    T = {"smoke": 800, "quick": 20_000, "full": 40_000}[scale]
    graphs = _graphs(scale)
    params = MHLJParams(0.1, 0.5, 3)
    out = {"T": T, "claim": PAPER_CLAIM}
    for tag, graph in graphs.items():
        n = graph.n
        data = make_heterogeneous_regression(
            n, dim=10, sigma_high_sq=100.0, p_high=0.002, seed=3,
            force_min_high=2, x_star_scale=10.0,
        )
        gamma_u = 0.5 / data.lipschitz.max()
        gamma = 0.5 / data.lipschitz.mean()
        v0 = int(np.argmax(data.lipschitz))
        sub = {}
        for method, g in (("uniform", gamma_u), ("importance", gamma), ("mhlj", gamma)):
            res = run_rw_sgd(
                method, graph, data, g, T,
                mhlj_params=params if method == "mhlj" else None, seed=4, v0=v0,
            )
            sub[method] = {
                **milestones(res.mse),
                "top_node_occupancy":
                    occupancy_concentration(res.update_nodes, n)["topk_share"],
            }
        out[tag] = sub
    out["derived"] = {
        f"{tag}_is_occ": out[tag]["importance"]["top_node_occupancy"]
        for tag in graphs
    } | {
        f"{tag}_mhlj_occ": out[tag]["mhlj"]["top_node_occupancy"] for tag in graphs
    }
    return out


def run_smoke() -> dict:
    """Tiny tier exercised by the tier-1 bench-smoke test."""
    return run(scale="smoke")
