"""Paper Fig. 5: entrapment + MHLJ fix on 2-d grid and Watts-Strogatz.

Same protocol as Fig 3 on the paper's other sparse topologies:
(a) 2-d grid (25x40 = 1000 nodes), (b) Watts-Strogatz(1000, 4, 0.1).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import milestones
from repro.core import MHLJParams
from repro.core.entrapment import occupancy_concentration
from repro.core.graphs import grid2d, watts_strogatz
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import run_rw_sgd

NAME = "fig5_sparse_graphs"
PAPER_CLAIM = (
    "C4: the entrapment problem and the MHLJ fix replicate on 2-d grid and "
    "Watts-Strogatz sparse networks (not ring-specific)."
)


def run(quick: bool = False) -> dict:
    T = 20_000 if quick else 40_000
    if quick:
        graphs = {"grid2d": grid2d(16, 16), "watts_strogatz": watts_strogatz(256, 4, 0.1, 0)}
    else:
        graphs = {"grid2d": grid2d(25, 40), "watts_strogatz": watts_strogatz(1000, 4, 0.1, 0)}
    params = MHLJParams(0.1, 0.5, 3)
    out = {"T": T, "claim": PAPER_CLAIM}
    for tag, graph in graphs.items():
        n = graph.n
        data = make_heterogeneous_regression(
            n, dim=10, sigma_high_sq=100.0, p_high=0.002, seed=3,
            force_min_high=2, x_star_scale=10.0,
        )
        gamma_u = 0.5 / data.lipschitz.max()
        gamma = 0.5 / data.lipschitz.mean()
        v0 = int(np.argmax(data.lipschitz))
        sub = {}
        for method, g in (("uniform", gamma_u), ("importance", gamma), ("mhlj", gamma)):
            res = run_rw_sgd(
                method, graph, data, g, T,
                mhlj_params=params if method == "mhlj" else None, seed=4, v0=v0,
            )
            sub[method] = {
                **milestones(res.mse),
                "top_node_occupancy":
                    occupancy_concentration(res.update_nodes, n)["topk_share"],
            }
        out[tag] = sub
    out["derived"] = {
        f"{tag}_is_occ": out[tag]["importance"]["top_node_occupancy"]
        for tag in graphs
    } | {
        f"{tag}_mhlj_occ": out[tag]["mhlj"]["top_node_occupancy"] for tag in graphs
    }
    return out
