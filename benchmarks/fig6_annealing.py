"""Paper Fig. 6: annealing p_J -> 0 eliminates the error gap.

Two measurements: (1) the exact asymptotic bias ||x~(p_J) - x_LS||^2 in
closed form (slope -> 2 on log-log: Theorem 1's O(p_J^2) term); (2) a
seed-averaged simulation comparing constant vs annealed p_J tails — all
replicas run as one batched fleet through the unified walk engine
(``run_rw_sgd_multi`` with a scheduled p_J), so the annealing schedule
exercises the engine's traced-p_J path directly.
"""
from __future__ import annotations

import numpy as np

from repro.core import MHLJParams, ring, schedules
from repro.core.theory import error_gap_exact
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import run_rw_sgd_multi

NAME = "fig6_annealing"
PAPER_CLAIM = (
    "C5: the MHLJ error gap scales O(p_J^2) and annealing p_J -> 0 removes "
    "it without losing convergence speed."
)


def run(quick: bool = False) -> dict:
    n = 64
    graph = ring(n)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(n, 6)) * np.where(rng.random(n) < 0.1, 2.0, 1.0)[:, None]
    targs = feats @ (3 * rng.normal(size=6)) + rng.normal(size=n)
    lips = 2 * (feats**2).sum(1)
    pjs = [0.2, 0.1, 0.05, 0.025, 0.0125]
    gaps = [
        error_gap_exact(graph, feats, targs, lips, MHLJParams(pj, 0.5, 3))
        for pj in pjs
    ]
    slopes = [
        float(np.log(gaps[i] / gaps[i - 1]) / np.log(pjs[i] / pjs[i - 1]))
        for i in range(1, len(gaps))
    ]

    T = 20_000 if quick else 40_000
    n_replicas = 3 if quick else 6
    data = make_heterogeneous_regression(
        n, dim=6, sigma_high_sq=100.0, p_high=0.05, seed=5, x_star_scale=3.0
    )
    gamma = 0.3 / data.lipschitz.mean()

    def tails(schedule):
        # one batched engine run services all replicas (independent models,
        # no averaging); tail = per-replica median, averaged over replicas
        res = run_rw_sgd_multi(
            "mhlj", graph, data, gamma, T, n_replicas,
            mhlj_params=MHLJParams(0.3, 0.5, 3),
            p_j_schedule=schedule, v0s=np.zeros(n_replicas, np.int32), seed=0,
        )
        return float(np.mean(np.median(res.mse[:, -4000:], axis=1)))

    const_tail = tails(None)
    ann_tail = tails(schedules.polynomial_decay(0.3, T, power=1.0, t0=2000))
    return {
        "claim": PAPER_CLAIM,
        "p_j_sweep": dict(zip(map(str, pjs), gaps)),
        "loglog_slopes": slopes,
        "const_pj_tail_mse": const_tail,
        "annealed_tail_mse": ann_tail,
        "derived": {
            "final_slope": slopes[-1],
            "gap_shrink": gaps[-1] / gaps[0],
            "annealed_vs_const": ann_tail / const_tail,
        },
    }
