"""Large-graph MHLJ walk sweep — the scale axis of the ROADMAP north star.

Sweeps batched MHLJ walks over trap-prone CSR topologies up to ~100k nodes
and records steps/sec.  Everything on this path is O(E): graphs are built as
edge lists (``layout="csr"``, no N×N adjacency ever exists), P_IS rows are
the padded ``(n, max_deg)`` Eq.-7 table computed from local information
only, and the engine's sparse layout gathers just the W active rows per
step.  The JSON result lands in ``results/BENCH_large_graph.json`` (plus
the harness's usual ``bench_large_graph_walk.json``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR
from repro.core import MHLJParams, WalkEngine, p_is_rows
from repro.core.graphs import barabasi_albert, dumbbell, grid2d, ring, sbm

NAME = "large_graph_walk"
PAPER_CLAIM = (
    "Scale (beyond-paper): the sparse CSR engine sweeps MHLJ walks over "
    "trap-prone graphs up to ~100k nodes in O(E) memory — no dense N×N "
    "transition table is ever materialized."
)

PARAMS = MHLJParams(p_j=0.1, p_d=0.5, r=3)


def _families(scale: str):
    """(tag, builder) pairs per scale tier; every builder returns a CSRGraph."""
    if scale == "smoke":
        return [
            ("ring", lambda: ring(1_500, layout="csr")),
            ("sbm", lambda: sbm([400] * 3, 0.02, 0.002, seed=0, layout="csr")),
        ]
    if scale == "quick":
        return [
            ("ring", lambda: ring(8_000, layout="csr")),
            ("grid2d", lambda: grid2d(64, 64, layout="csr")),
            ("sbm", lambda: sbm([2_000] * 4, 0.005, 0.0002, seed=0, layout="csr")),
            ("barabasi_albert", lambda: barabasi_albert(8_000, 3, seed=0, layout="csr")),
            ("dumbbell", lambda: dumbbell(128, 4_000, layout="csr")),
        ]
    return [
        ("ring", lambda: ring(100_000, layout="csr")),
        ("grid2d", lambda: grid2d(316, 316, layout="csr")),
        ("sbm", lambda: sbm([25_000] * 4, 0.0008, 0.00002, seed=0, layout="csr")),
        ("barabasi_albert", lambda: barabasi_albert(30_000, 3, seed=0, layout="csr")),
        ("dumbbell", lambda: dumbbell(256, 99_488, layout="csr")),
    ]


def _sweep_one(graph, num_walks: int, num_steps: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    lips = jnp.asarray(
        np.exp(rng.normal(0.0, 1.0, graph.n)), jnp.float32
    )  # heavy-tailed Lipschitz spread: realistic trap pressure
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    rows = p_is_rows(neighbors, degrees, lips)  # (n, max_deg): O(E) table
    engine = WalkEngine(
        neighbors=neighbors,
        degrees=degrees,
        p_j=PARAMS.p_j,
        p_d=PARAMS.p_d,
        r=PARAMS.r,
        row_probs=rows,
        backend="auto",  # pallas sparse tiles on TPU, scan elsewhere
        layout="sparse",
    )
    v0s = jnp.asarray(rng.integers(0, graph.n, num_walks), jnp.int32)
    key = jax.random.PRNGKey(seed)

    nodes, hops = engine.run(key, v0s, num_steps)  # compile + warm
    nodes.block_until_ready()
    t0 = time.perf_counter()
    nodes, hops = engine.run(jax.random.PRNGKey(seed + 1), v0s, num_steps)
    nodes.block_until_ready()
    dt = time.perf_counter() - t0

    hops_np = np.asarray(hops, np.float64)
    return {
        "n": graph.n,
        "nnz": graph.num_edges,
        "max_degree": graph.max_degree,
        "num_walks": num_walks,
        "num_steps": num_steps,
        "walk_steps_per_sec": float(num_walks * num_steps / dt),
        "transitions_per_update": float(hops_np.mean()),
        "csr_bytes": int(
            graph.indptr.nbytes + graph.indices.nbytes
            + graph.neighbors.nbytes + graph.degrees.nbytes
        ),
        "dense_table_bytes_avoided": int(graph.n) ** 2 * 8,
    }


def run(quick: bool = False, scale: str | None = None) -> dict:
    scale = scale or ("quick" if quick else "full")
    num_walks = {"smoke": 128, "quick": 1024, "full": 2048}[scale]
    num_steps = {"smoke": 30, "quick": 100, "full": 200}[scale]
    out = {"claim": PAPER_CLAIM, "scale": scale, "params": vars(PARAMS) | {}}
    derived = {}
    for tag, build in _families(scale):
        t0 = time.perf_counter()
        graph = build()
        build_s = time.perf_counter() - t0
        res = _sweep_one(graph, num_walks, num_steps, seed=7)
        res["construction_sec"] = build_s
        out[tag] = res
        derived[f"{tag}_steps_per_sec"] = res["walk_steps_per_sec"]
        derived[f"{tag}_n"] = res["n"]
    out["derived"] = derived

    if scale != "smoke":  # don't clobber real sweeps from the anti-rot tier
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, "BENCH_large_graph.json"), "w") as f:
            json.dump(out, f, indent=2, default=float)
    return out


def run_smoke() -> dict:
    """Tiny tier exercised by the tier-1 bench-smoke test."""
    return run(scale="smoke")
