"""Large-graph MHLJ walk sweep — the scale axis of the ROADMAP north star.

Sweeps batched MHLJ walks over trap-prone CSR topologies up to ~100k nodes
and records steps/sec **per engine layout**: the padded-CSR sparse layout
(rows padded to the global ``max_deg``) against the degree-bucketed ragged
layout (rows padded per power-of-two bucket, Lévy hops gathered from the
flat CSR).  On hub-heavy families (Barabási–Albert) the padded layout's
resident tables cost O(n·max_deg) — one degree-~10³ hub inflates every
row — while the bucketed layout stays O(E + Σ_b n_b·width_b); the per-run
``resident_table_bytes`` field records exactly that footprint, and the
per-family ``bucketed_table_shrink`` / ``bucketed_step_speedup`` deriveds
summarize the win (docs/benchmarks.md tells the story).

Everything on this path is O(E): graphs are built as edge lists
(``layout="csr"``, no N×N adjacency ever exists) and P_IS rows are the
Eq.-7 law computed from local information only.  The smoke tier sweeps
**every registered engine layout** (``repro.core.engine.LAYOUTS``,
including the dense parity layout) so a rotted layout fails tier-1, not
just the default.  The JSON result lands in
``results/BENCH_large_graph.json`` (plus the harness's usual
``bench_large_graph_walk.json``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR
from repro.core import LAYOUTS, MHLJParams, WalkEngine
from repro.core.graphs import barabasi_albert, dumbbell, grid2d, ring, sbm

NAME = "large_graph_walk"
PAPER_CLAIM = (
    "Scale (beyond-paper): the sparse CSR engine sweeps MHLJ walks over "
    "trap-prone graphs up to ~100k nodes in O(E) memory, and the "
    "degree-bucketed layout removes the O(n·max_deg) padded-table wall on "
    "hub-heavy topologies — no dense N×N transition table is ever "
    "materialized."
)

PARAMS = MHLJParams(p_j=0.1, p_d=0.5, r=3)


def _families(scale: str):
    """(tag, builder) pairs per scale tier; every builder returns a CSRGraph."""
    if scale == "smoke":
        return [
            ("ring", lambda: ring(1_500, layout="csr")),
            ("sbm", lambda: sbm([400] * 3, 0.02, 0.002, seed=0, layout="csr")),
        ]
    if scale == "quick":
        return [
            ("ring", lambda: ring(8_000, layout="csr")),
            ("grid2d", lambda: grid2d(64, 64, layout="csr")),
            ("sbm", lambda: sbm([2_000] * 4, 0.005, 0.0002, seed=0, layout="csr")),
            ("barabasi_albert", lambda: barabasi_albert(8_000, 3, seed=0, layout="csr")),
            ("dumbbell", lambda: dumbbell(128, 4_000, layout="csr")),
        ]
    return [
        ("ring", lambda: ring(100_000, layout="csr")),
        ("grid2d", lambda: grid2d(316, 316, layout="csr")),
        ("sbm", lambda: sbm([25_000] * 4, 0.0008, 0.00002, seed=0, layout="csr")),
        ("barabasi_albert", lambda: barabasi_albert(100_000, 3, seed=0, layout="csr")),
        ("dumbbell", lambda: dumbbell(256, 99_488, layout="csr")),
    ]


def _resident_table_bytes(engine: WalkEngine) -> int:
    """Bytes of per-layout resident row/neighbor state (the thing the
    bucketed layout shrinks); degrees/uniform plumbing are common to all."""
    total = int(engine.degrees.nbytes)
    for field in (engine.neighbors, engine.row_probs, engine.indptr,
                  engine.indices, engine.node_bucket, engine.node_slot):
        if field is not None:
            total += int(field.nbytes)
    for group in (engine.bucket_neighbors, engine.bucket_rows):
        if group is not None:
            total += sum(int(a.nbytes) for a in group)
    return total


def _sweep_one(
    graph, num_walks: int, num_steps: int, seed: int, layout: str,
    backend: str = "auto",
) -> dict:
    rng = np.random.default_rng(seed)
    lips = jnp.asarray(
        np.exp(rng.normal(0.0, 1.0, graph.n)), jnp.float32
    )  # heavy-tailed Lipschitz spread: realistic trap pressure
    g = graph.to_bucketed() if layout == "bucketed" else graph
    engine = WalkEngine.from_graph(
        g, PARAMS, lipschitz=lips, backend=backend, layout=layout
    )
    v0s = jnp.asarray(rng.integers(0, graph.n, num_walks), jnp.int32)
    key = jax.random.PRNGKey(seed)

    nodes, hops = engine.run(key, v0s, num_steps)  # compile + warm
    nodes.block_until_ready()
    t0 = time.perf_counter()
    nodes, hops = engine.run(jax.random.PRNGKey(seed + 1), v0s, num_steps)
    nodes.block_until_ready()
    dt = time.perf_counter() - t0

    hops_np = np.asarray(hops, np.float64)
    return {
        "layout": layout,
        "n": graph.n,
        "nnz": graph.num_edges,
        "max_degree": graph.max_degree,
        "bucket_widths": list(g.bucket_widths) if layout == "bucketed" else None,
        "num_walks": num_walks,
        "num_steps": num_steps,
        "walk_steps_per_sec": float(num_walks * num_steps / dt),
        "transitions_per_update": float(hops_np.mean()),
        "resident_table_bytes": _resident_table_bytes(engine),
        "csr_bytes": int(graph.indptr.nbytes + graph.indices.nbytes),
        "dense_table_bytes_avoided": int(graph.n) ** 2 * 8,
    }


def run(quick: bool = False, scale: str | None = None) -> dict:
    scale = scale or ("quick" if quick else "full")
    num_walks = {"smoke": 128, "quick": 1024, "full": 2048}[scale]
    num_steps = {"smoke": 30, "quick": 100, "full": 200}[scale]
    # smoke exercises EVERY registered layout (anti-rot); the real sweeps
    # compare the two production layouts (dense is a small-n parity layout).
    # Smoke must force backend="pallas": under "auto" an off-TPU run
    # resolves to scan and the layouts' kernels would never execute, so a
    # rotted kernel could pass CI.  Off-TPU the pallas backend runs in
    # interpret mode — slow, hence the tiny smoke sizes.
    layouts = LAYOUTS if scale == "smoke" else ("sparse", "bucketed")
    backend = "pallas" if scale == "smoke" else "auto"
    out = {"claim": PAPER_CLAIM, "scale": scale, "params": vars(PARAMS) | {}}
    derived = {}
    for tag, build in _families(scale):
        t0 = time.perf_counter()
        graph = build()
        build_s = time.perf_counter() - t0
        fam: dict = {"construction_sec": build_s}
        for layout in layouts:
            fam[layout] = _sweep_one(
                graph, num_walks, num_steps, seed=7, layout=layout,
                backend=backend,
            )
            derived[f"{tag}_{layout}_steps_per_sec"] = (
                fam[layout]["walk_steps_per_sec"]
            )
        if "sparse" in fam and "bucketed" in fam:
            fam["bucketed_step_speedup"] = (
                fam["bucketed"]["walk_steps_per_sec"]
                / fam["sparse"]["walk_steps_per_sec"]
            )
            fam["bucketed_table_shrink"] = (
                fam["sparse"]["resident_table_bytes"]
                / fam["bucketed"]["resident_table_bytes"]
            )
            derived[f"{tag}_bucketed_table_shrink"] = fam["bucketed_table_shrink"]
        out[tag] = fam
    out["derived"] = derived

    if scale != "smoke":  # don't clobber real sweeps from the anti-rot tier
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, "BENCH_large_graph.json"), "w") as f:
            json.dump(out, f, indent=2, default=float)
    return out


def run_smoke() -> dict:
    """Tiny tier exercised by the tier-1 bench-smoke test: every registered
    engine layout takes real steps here, so a broken layout fails CI."""
    return run(scale="smoke")
