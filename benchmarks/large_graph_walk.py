"""Large-graph MHLJ walk sweep — the scale axis of the ROADMAP north star.

Sweeps batched MHLJ walks over trap-prone CSR topologies up to 1M nodes
and records steps/sec **per engine configuration**: the padded-CSR sparse
layout (rows padded to the global ``max_deg``), the degree-bucketed
layout — both *uncompacted* (every per-bucket pass runs all W walks) and
*compacted* (walks sorted by bucket id per step, each bucket's tile pass
running at its static capacity — the ``engine.bucket_capacities`` rule) —
and the **ragged true-degree layout** (``layout="ragged"``: one flat
per-edge CDF, binary-search MH inversion, no ladder and no compaction
machinery at all).  On hub-heavy families (Barabási–Albert) the padded
layout's resident tables cost O(n·max_deg) — one degree-~10³ hub inflates
every row — the bucketed layout stays O(E + Σ_b n_b·width_b), and the
ragged layout is exactly O(E); compaction removes the bucketed layout's
step-time penalty (per-step MH work drops from W·Σ_b width_b to
Σ_b cap_b·width_b), and the ragged layout drops per-walk row work to
O(log max_deg) outright.  The per-run ``resident_table_bytes`` field
records the memory footprint, ``compact_overflow_rate`` audits the static
capacity rule (fraction of steps whose compacted dispatch overflowed and
fell back — the ``engine.WalkEngine.step`` aux telemetry), and the
per-family ``bucketed_table_shrink`` / ``compaction_step_speedup`` /
``compact_vs_sparse`` / ``ragged_vs_sparse`` / ``ragged_vs_compact``
deriveds summarize the wins (docs/benchmarks.md tells the story).

The full tier additionally runs the ROADMAP's **1M-node Barabási–Albert
sweep in bounded-memory mode**: the graph is built with
``layout="bucketed"`` (the padded ``(n, max_deg)`` table — ~GBs at this
scale — is never materialized, see ``graphs.from_edges``) and only the
bucketed + ragged engine configurations run, so the whole sweep fits a
single host.  The BA family also sweeps the ``bucket_factor`` ladder knob
(factor 4 = coarser ladder, fewer per-bucket passes, more padding).

Everything on this path is O(E): graphs are built as edge lists
(``layout="csr"`` / ``layout="bucketed"``, no N×N adjacency ever exists)
and P_IS rows are the Eq.-7 law computed from local information only.
Graph construction time is recorded per family (``construction_sec``,
also surfaced in ``derived``) so build-path regressions — e.g. the
vectorized Batagelj-style ``barabasi_albert`` sampler rotting back to a
per-node loop — are visible in the JSON.  The smoke tier sweeps **every
registered engine layout** (``repro.core.engine.LAYOUTS``, including the
dense parity layout) plus the compacted bucketed configuration so a
rotted path fails tier-1, not just the default; its derived steps/sec
also feed the CI regression gate (``benchmarks/check_regression.py``).
The JSON result lands in ``results/BENCH_large_graph.json`` (plus the
harness's usual ``bench_large_graph_walk.json``).

The **fleet sweep** (every tier, the ``fleet`` section of the JSON)
measures the mesh-sharded W-walker path of ``repro.walk_sgd.fleet``: the
walker batch is sharded over the ``walker`` logical axis of
``repro.sharding.rules`` (``repro.launch.mesh.make_walker_mesh`` — on
CPU, multi-device only under
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and the ragged
engine's ``run`` is timed end to end, recording ``num_walkers`` and
**aggregate** walk-steps/s per fleet size (the ROADMAP's 10M+ aggregate
target is this number), plus a convergence-vs-num-walkers training sweep
through ``repro.walk_sgd.run_rw_sgd_multi`` with periodic averaging —
the arXiv:2604.12260 multi-walker claim (variance term ~1/W, bias floor
unchanged) measured in the same JSON the regression gate watches.  The
fleet rows run on the scan backend: off-TPU the pallas interpret path
would time the interpreter, not the sharded engine, and the gate
normalizes fleet rows against their own smallest-W row
(``benchmarks/check_regression.py``), so the two backends never mix in
one ratio.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR
from repro.core import LAYOUTS, MHLJParams, WalkEngine
from repro.core.graphs import barabasi_albert, dumbbell, grid2d, ring, sbm

NAME = "large_graph_walk"
PAPER_CLAIM = (
    "Scale (beyond-paper): the sparse CSR engine sweeps MHLJ walks over "
    "trap-prone graphs up to 1M nodes in O(E) memory, the degree-bucketed "
    "layout removes the O(n·max_deg) padded-table wall on hub-heavy "
    "topologies, per-step walk compaction removes the bucketed layout's "
    "step-time penalty, and the ragged true-degree layout drops the "
    "bucket ladder entirely (flat per-edge CDF, O(log max_deg) MH "
    "inversion, exactly-O(E) resident state) — no dense N×N transition "
    "table is ever materialized."
)

PARAMS = MHLJParams(p_j=0.1, p_d=0.5, r=3)

# Engine configurations swept per family: label -> from_graph overrides.
# "bucketed" is the uncompacted dispatch (compact=False) so the sweep
# isolates what compaction buys on top of bucketing.
CONFIGS = {
    "sparse": dict(layout="sparse"),
    "dense": dict(layout="dense"),
    "bucketed": dict(layout="bucketed", compact=False),
    "bucketed_compact": dict(layout="bucketed", compact=True),
    "bucketed_compact_f4": dict(layout="bucketed", compact=True,
                                bucket_factor=4),
    "ragged": dict(layout="ragged"),
}


def _families(scale: str):
    """(tag, builder, labels) triples per scale tier.

    ``labels`` picks the engine configurations swept for the family; the
    1M BA entry is bucketed-only (bounded-memory mode: its builder
    returns a ``BucketedCSRGraph`` and the padded table never exists).
    """
    base = ("sparse", "bucketed", "bucketed_compact", "ragged")
    ba = base + ("bucketed_compact_f4",)
    bounded = ("bucketed", "bucketed_compact", "ragged")
    if scale == "smoke":
        # every registered layout + the compacted bucketed path (anti-rot)
        labels = tuple(LAYOUTS) + ("bucketed_compact",)
        return [
            ("ring", lambda: ring(1_500, layout="csr"), labels),
            ("sbm", lambda: sbm([400] * 3, 0.02, 0.002, seed=0, layout="csr"),
             labels),
        ]
    if scale == "quick":
        return [
            ("ring", lambda: ring(8_000, layout="csr"), base),
            ("grid2d", lambda: grid2d(64, 64, layout="csr"), base),
            ("sbm", lambda: sbm([2_000] * 4, 0.005, 0.0002, seed=0,
                                layout="csr"), base),
            ("barabasi_albert", lambda: barabasi_albert(8_000, 3, seed=0,
                                                        layout="csr"), ba),
            ("dumbbell", lambda: dumbbell(128, 4_000, layout="csr"), base),
        ]
    return [
        ("ring", lambda: ring(100_000, layout="csr"), base),
        ("grid2d", lambda: grid2d(316, 316, layout="csr"), base),
        ("sbm", lambda: sbm([25_000] * 4, 0.0008, 0.00002, seed=0,
                            layout="csr"), base),
        ("barabasi_albert", lambda: barabasi_albert(100_000, 3, seed=0,
                                                    layout="csr"), ba),
        ("dumbbell", lambda: dumbbell(256, 99_488, layout="csr"), base),
        # ROADMAP item: the 1M-node hub-heavy sweep.  Bounded-memory mode —
        # built straight into the bucketed layout, padded tables (~8 GB at
        # this max_deg) never exist, only bucketed configs run.
        ("barabasi_albert_1m",
         lambda: barabasi_albert(1_000_000, 3, seed=0, layout="bucketed"),
         bounded),
    ]


def _resident_table_bytes(engine: WalkEngine) -> int:
    """Bytes of per-layout resident row/neighbor state (the thing the
    bucketed layout shrinks); degrees/uniform plumbing are common to all."""
    total = int(engine.degrees.nbytes)
    for field in (engine.neighbors, engine.row_probs, engine.indptr,
                  engine.indices, engine.node_bucket, engine.node_slot,
                  engine.edge_cdf):
        if field is not None:
            total += int(field.nbytes)
    for group in (engine.bucket_neighbors, engine.bucket_rows):
        if group is not None:
            total += sum(int(a.nbytes) for a in group)
    return total


def _sweep_one(
    graph, num_walks: int, num_steps: int, seed: int, label: str,
    backend: str = "auto",
) -> dict:
    cfg = dict(CONFIGS[label])
    layout = cfg.pop("layout")
    rng = np.random.default_rng(seed)
    lips = jnp.asarray(
        np.exp(rng.normal(0.0, 1.0, graph.n)), jnp.float32
    )  # heavy-tailed Lipschitz spread: realistic trap pressure
    engine = WalkEngine.from_graph(
        graph, PARAMS, lipschitz=lips, backend=backend, layout=layout, **cfg
    )
    v0s = jnp.asarray(rng.integers(0, graph.n, num_walks), jnp.int32)
    key = jax.random.PRNGKey(seed)

    # jit the whole trajectory, exactly like the production consumers
    # (walk_sgd.trainer scans the engine inside one jitted loop) — timing
    # the unjitted path would measure per-call retrace/dispatch overhead,
    # not the engine.  with_aux threads out the per-step compaction
    # telemetry (overflow flags) at no extra cost on the other layouts.
    run = jax.jit(lambda k, v: engine.run(k, v, num_steps, with_aux=True))
    nodes, hops, aux = run(key, v0s)  # compile + warm
    nodes.block_until_ready()
    t0 = time.perf_counter()
    nodes, hops, aux = run(jax.random.PRNGKey(seed + 1), v0s)
    nodes.block_until_ready()
    dt = time.perf_counter() - t0

    hops_np = np.asarray(hops, np.float64)
    bucketed = layout == "bucketed"
    compacted = bucketed and bool(engine.compact)
    return {
        "label": label,
        "layout": layout,
        "compact": bool(engine.compact) if bucketed else None,
        "n": graph.n,
        "nnz": graph.num_edges,
        "max_degree": graph.max_degree,
        "bucket_widths": (
            [nb.shape[1] for nb in engine.bucket_neighbors] if bucketed
            else None
        ),
        "num_walks": num_walks,
        "num_steps": num_steps,
        "walk_steps_per_sec": float(num_walks * num_steps / dt),
        "transitions_per_update": float(hops_np.mean()),
        # fraction of steps whose compacted dispatch overflowed a static
        # bucket capacity and lax.cond fell back to the full-W dispatch —
        # the audit trail of the engine.bucket_capacities rule
        "compact_overflow_rate": (
            float(np.asarray(aux["compact_overflow"], np.float64).mean())
            if compacted else None
        ),
        "resident_table_bytes": _resident_table_bytes(engine),
        "csr_bytes": int(graph.indptr.nbytes + graph.indices.nbytes),
        "dense_table_bytes_avoided": int(graph.n) ** 2 * 8,
    }


def _fleet_sweep(scale: str) -> tuple[dict, dict]:
    """Mesh-sharded fleet throughput + convergence-vs-num-walkers sweep.

    Returns ``(fleet_section, derived)``.  Throughput rows time the ragged
    engine's batched ``run`` with the walker batch sharded over the
    ``walker`` logical axis (replication fallback when W doesn't divide
    the mesh) and record **aggregate** walk-steps/s; the convergence rows
    train W walks with periodic averaging through ``run_rw_sgd_multi``
    on the multi-walk benchmark's regression setting and record the
    final averaged-model excess over the least-squares floor — the
    arXiv:2604.12260 ~1/W variance claim, next to the throughput it buys.
    """
    from repro.data import make_heterogeneous_regression
    from repro.launch.mesh import make_walker_mesh
    from repro.sharding.rules import resolve_walker_axis
    from repro.walk_sgd import run_rw_sgd_multi

    mesh = make_walker_mesh()
    n_dev = int(mesh.devices.size)
    fleet_sizes = {
        "smoke": (64, 128), "quick": (1024, 4096), "full": (2048, 8192),
    }[scale]
    num_steps = {"smoke": 30, "quick": 100, "full": 200}[scale]
    if scale == "smoke":
        graph = ring(1_500, layout="csr")
    else:
        graph_n = {"quick": 8_000, "full": 100_000}[scale]
        graph = barabasi_albert(graph_n, 3, seed=0, layout="csr")
    rng = np.random.default_rng(11)
    lips = jnp.asarray(np.exp(rng.normal(0.0, 1.0, graph.n)), jnp.float32)
    # ragged layout on the scan backend: off-TPU the pallas interpret path
    # would time the interpreter, not the sharded engine (module docstring)
    engine = WalkEngine.from_graph(
        graph, PARAMS, lipschitz=lips, backend="scan", layout="ragged"
    )

    fleet: dict = {"mesh_devices": n_dev, "graph_n": graph.n,
                   "layout": "ragged", "backend": "scan"}
    derived: dict = {"fleet_mesh_devices": n_dev}
    for w in fleet_sizes:
        sharding = resolve_walker_axis(w, mesh)
        eng_w = (
            engine.with_walker_sharding(sharding)
            if sharding is not None else engine
        )
        v0s = jnp.asarray(rng.integers(0, graph.n, w), jnp.int32)
        if sharding is not None:
            v0s = jax.device_put(v0s, sharding)
        run_fn = jax.jit(
            lambda k, v, e=eng_w: e.run(k, v, num_steps)
        )
        nodes, _ = run_fn(jax.random.PRNGKey(3), v0s)  # compile + warm
        nodes.block_until_ready()
        t0 = time.perf_counter()
        nodes, _ = run_fn(jax.random.PRNGKey(4), v0s)
        nodes.block_until_ready()
        dt = time.perf_counter() - t0
        agg = float(w * num_steps / dt)
        fleet[f"w{w}"] = {
            "num_walkers": w,
            "sharded": sharding is not None,
            "aggregate_walk_steps_per_sec": agg,
        }
        derived[f"fleet_w{w}_num_walkers"] = w
        derived[f"fleet_w{w}_aggregate_walk_steps_per_sec"] = agg

    # convergence-vs-num-walkers: same recipe as benchmarks/multi_walk.py,
    # but through the mesh-sharded fleet path with *periodic* averaging
    conv_n = 128
    conv_graph = ring(conv_n)
    data = make_heterogeneous_regression(
        conv_n, dim=6, sigma_high_sq=100.0, p_high=0.03, seed=7,
        x_star_scale=3.0,
    )
    gamma = float(0.3 / data.lipschitz.mean())
    conv_T = {"smoke": 2_000, "quick": 10_000, "full": 20_000}[scale]
    conv_ws = (1, 8) if scale == "smoke" else (1, 2, 4, 8)
    avg_every = 50
    floor = float(data.mse(data.optimum()))
    conv: dict = {}
    for w in conv_ws:
        res = run_rw_sgd_multi(
            "mhlj", conv_graph, data, gamma, conv_T, w,
            mhlj_params=PARAMS, seed=0, avg_every=avg_every, mesh=mesh,
        )
        final = float(res.avg_mse[-1])
        conv[f"w{w}"] = {
            "num_walkers": w,
            "avg_every": avg_every,
            "final_avg_mse": final,
            "excess_over_floor": final - floor,
            "transitions_per_update": res.transitions_per_update,
        }
        derived[f"fleet_conv_w{w}_excess"] = final - floor
    fleet["ls_floor_mse"] = floor
    fleet["convergence_vs_num_walkers"] = conv
    return fleet, derived


def _churn_sweep(scale: str) -> tuple[dict, dict]:
    """Incremental edge churn vs full rebuild on a hub-heavy BA graph.

    One batched churn of 0.1% of the undirected edges (half deletes —
    both endpoints keep degree >= 3, halve-and-retry on disconnect —
    half inserts) is applied two ways.  The batch fraction is the
    scaling knob that decides whether incremental can win at all: an MH
    row reads its neighbors' degrees, so the recompute set is the 1-hop
    closure of the churn endpoints, and on a BA graph edge-uniform
    deletes are hub-biased — the closure amplifies the batch ~25-30x
    (measured at n=100k: 0.1% of edges -> 8% of rows, 1% -> 46%).  By
    ~1% of edges the incremental path is recomputing half the graph and
    necessarily converges to rebuild cost; at 0.1% the O(closure·width)
    patch beats the O(n·width) rebuild by the pinned margin.  The batch
    is applied two ways: (a) the incremental path,
    ``graphs.apply_edge_churn`` + ``WalkEngine.apply_churn`` patching only
    the touched CDF segments, and (b) the from-scratch path,
    ``from_edges(layout="ragged")`` over the churned edge list +
    ``WalkEngine.from_graph``.  Both are warmed once and the second run is
    timed.  The incremental CDF must come out **bitwise identical** to an
    untimed from-scratch oracle built at the engine's recorded
    ``cdf_width`` (``RuntimeError`` otherwise — a fast wrong answer is
    not a speedup).  The width matters: on a BA graph the hub is an
    endpoint of some delete in almost every 1% batch, so the max degree
    drops and a rebuild at the *new* natural width lands on different
    XLA reduction lane splits — 1-ulp CDF diffs on rows the churn never
    touched.  The sticky-width contract (``engine.cdf_width``) is exactly
    what makes the incremental patch sound, and the oracle checks it at
    that width.  ``ba_churn_speedup = rebuild_sec / incremental_sec``
    lands in
    ``derived`` under the presence gate of
    ``benchmarks/check_regression.py`` (wall-clock ratios on the tiny
    smoke batch are too noisy to magnitude-gate).
    """
    from repro.core.graphs import apply_edge_churn, from_edges

    n, m = {
        "smoke": (2_000, 3), "quick": (20_000, 3), "full": (100_000, 3),
    }[scale]
    graph = barabasi_albert(n, m, seed=0, layout="ragged")
    rng = np.random.default_rng(5)
    lips = jnp.asarray(np.exp(rng.normal(0.0, 1.0, n)), jnp.float32)
    engine = WalkEngine.from_graph(
        graph, PARAMS, lipschitz=lips, backend="scan", layout="ragged"
    )

    deg = np.asarray(graph.degrees, np.int64)
    src = np.repeat(
        np.arange(n, dtype=np.int64),
        np.diff(np.asarray(graph.indptr, np.int64)),
    )
    dst = np.asarray(graph.indices, np.int64)
    keep = src < dst
    pairs = np.stack([src[keep], dst[keep]], axis=1)
    budget = max(2, int(0.001 * pairs.shape[0]))
    cand = pairs[(deg[pairs[:, 0]] >= 4) & (deg[pairs[:, 1]] >= 4)]
    k_del = min(budget // 2, cand.shape[0])
    dele = None
    while k_del:
        sel = rng.choice(cand.shape[0], size=k_del, replace=False)
        try:
            apply_edge_churn(
                graph, delete=cand[sel], check_connectivity=True
            )
        except ValueError:
            k_del //= 2
            continue
        dele = cand[sel]
        break
    num_del = 0 if dele is None else dele.shape[0]
    codes = set((pairs[:, 0] * n + pairs[:, 1]).tolist())
    ins = []
    while len(ins) < budget - num_del:
        a, b = (int(x) for x in rng.integers(0, n, size=2))
        if a == b:
            continue
        lo, hi = min(a, b), max(a, b)
        if lo * n + hi in codes:
            continue
        codes.add(lo * n + hi)
        ins.append((lo, hi))
    ins = np.asarray(ins, np.int64)

    def incremental():
        g2, churn = apply_edge_churn(graph, insert=ins, delete=dele)
        eng2 = engine.apply_churn(g2, churn, lipschitz=lips)
        eng2.edge_cdf.block_until_ready()
        return g2, churn, eng2

    # the rebuild path starts from the same churned edge list (extraction
    # is shared state in a real system, so it is timed in neither path)
    g2_warm, churn, eng_inc = incremental()  # warm the block-op jits
    src2 = np.repeat(
        np.arange(n, dtype=np.int64),
        np.diff(np.asarray(g2_warm.indptr, np.int64)),
    )
    dst2 = np.asarray(g2_warm.indices, np.int64)
    keep2 = src2 < dst2

    def rebuild():
        g3 = from_edges(n, src2[keep2], dst2[keep2], layout="ragged")
        eng3 = WalkEngine.from_graph(
            g3, PARAMS, lipschitz=lips, backend="scan", layout="ragged"
        )
        eng3.edge_cdf.block_until_ready()
        return g3, eng3

    rebuild()  # warm
    t0 = time.perf_counter()
    _, _, eng_inc = incremental()
    incremental_sec = time.perf_counter() - t0
    t0 = time.perf_counter()
    g3, eng_reb = rebuild()
    rebuild_sec = time.perf_counter() - t0

    # untimed differential oracle: a from-scratch build at the engine's
    # sticky cdf_width (the timed rebuild above built at the churned
    # graph's own max degree, whose bits legitimately differ when the
    # churn moved the max — see the docstring)
    from repro.core.engine import ragged_edge_cdf

    oracle = ragged_edge_cdf(
        g3.indptr, g3.indices, g3.degrees,
        lipschitz=lips, width=eng_inc.cdf_width,
    )
    same = (
        np.array_equal(np.asarray(g2_warm.indptr), np.asarray(g3.indptr))
        and np.array_equal(
            np.asarray(g2_warm.indices), np.asarray(g3.indices)
        )
        and np.array_equal(
            np.asarray(eng_inc.edge_cdf).view(np.int32),
            np.asarray(oracle).view(np.int32),
        )
    )
    if not same:
        raise RuntimeError(
            "incremental churn diverged bitwise from the from-scratch "
            "same-width oracle — the differential contract is broken, "
            "the timing is meaningless"
        )
    del eng_reb
    speedup = rebuild_sec / incremental_sec
    section = {
        "graph_n": n,
        "num_undirected_edges": int(pairs.shape[0]),
        "batch_inserts": int(ins.shape[0]),
        "batch_deletes": int(num_del),
        "touched_rows": int(churn.touched_rows.size),
        "incremental_sec": incremental_sec,
        "rebuild_sec": rebuild_sec,
        "speedup": speedup,
        "bitwise_equal": True,
    }
    return section, {"ba_churn_speedup": speedup}


def run(quick: bool = False, scale: str | None = None) -> dict:
    scale = scale or ("quick" if quick else "full")
    num_walks = {"smoke": 128, "quick": 1024, "full": 2048}[scale]
    num_steps = {"smoke": 30, "quick": 100, "full": 200}[scale]
    # Smoke must force backend="pallas": under "auto" an off-TPU run
    # resolves to scan and the layouts' kernels would never execute, so a
    # rotted kernel could pass CI.  Off-TPU the pallas backend runs in
    # interpret mode — slow, hence the tiny smoke sizes.
    backend = "pallas" if scale == "smoke" else "auto"
    out = {"claim": PAPER_CLAIM, "scale": scale, "params": vars(PARAMS) | {}}
    derived = {}
    for tag, build, labels in _families(scale):
        t0 = time.perf_counter()
        graph = build()
        build_s = time.perf_counter() - t0
        fam: dict = {"construction_sec": build_s}
        # surfaced in derived too, so a build-path regression (e.g. the
        # vectorized BA sampler rotting back to a per-node loop) is visible
        # where the smoke/regression tooling looks
        derived[f"{tag}_construction_sec"] = build_s
        for label in labels:
            fam[label] = _sweep_one(
                graph, num_walks, num_steps, seed=7, label=label,
                backend=backend,
            )
            derived[f"{tag}_{label}_steps_per_sec"] = (
                fam[label]["walk_steps_per_sec"]
            )
            rate = fam[label].get("compact_overflow_rate")
            if rate is not None:
                derived[f"{tag}_{label}_overflow_rate"] = rate
        if "sparse" in fam and "bucketed" in fam:
            fam["bucketed_step_speedup"] = (
                fam["bucketed"]["walk_steps_per_sec"]
                / fam["sparse"]["walk_steps_per_sec"]
            )
            fam["bucketed_table_shrink"] = (
                fam["sparse"]["resident_table_bytes"]
                / fam["bucketed"]["resident_table_bytes"]
            )
            derived[f"{tag}_bucketed_table_shrink"] = fam["bucketed_table_shrink"]
        if "bucketed" in fam and "bucketed_compact" in fam:
            fam["compaction_step_speedup"] = (
                fam["bucketed_compact"]["walk_steps_per_sec"]
                / fam["bucketed"]["walk_steps_per_sec"]
            )
            derived[f"{tag}_compaction_step_speedup"] = (
                fam["compaction_step_speedup"]
            )
        if "sparse" in fam and "bucketed_compact" in fam:
            fam["compact_vs_sparse"] = (
                fam["bucketed_compact"]["walk_steps_per_sec"]
                / fam["sparse"]["walk_steps_per_sec"]
            )
        if "sparse" in fam and "ragged" in fam:
            fam["ragged_vs_sparse"] = (
                fam["ragged"]["walk_steps_per_sec"]
                / fam["sparse"]["walk_steps_per_sec"]
            )
            fam["ragged_table_shrink"] = (
                fam["sparse"]["resident_table_bytes"]
                / fam["ragged"]["resident_table_bytes"]
            )
        if "bucketed_compact" in fam and "ragged" in fam:
            fam["ragged_vs_compact"] = (
                fam["ragged"]["walk_steps_per_sec"]
                / fam["bucketed_compact"]["walk_steps_per_sec"]
            )
        out[tag] = fam
    fleet, fleet_derived = _fleet_sweep(scale)
    out["fleet"] = fleet
    derived.update(fleet_derived)
    churn, churn_derived = _churn_sweep(scale)
    out["churn"] = churn
    derived.update(churn_derived)
    out["derived"] = derived

    if scale != "smoke":  # don't clobber real sweeps from the anti-rot tier
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, "BENCH_large_graph.json")
        # keep the committed smoke-tier regression baseline
        # (benchmarks/check_regression.py --update writes it) across
        # full-sweep refreshes
        if os.path.exists(path):
            with open(path) as f:
                prior = json.load(f)
            if "smoke_baseline" in prior:
                out["smoke_baseline"] = prior["smoke_baseline"]
        with open(path, "w") as f:
            json.dump(out, f, indent=2, default=float)
    return out


def run_smoke() -> dict:
    """Tiny tier exercised by the tier-1 bench-smoke test: every registered
    engine layout (plus the compacted bucketed dispatch) takes real steps
    here, so a broken path fails CI."""
    return run(scale="smoke")
