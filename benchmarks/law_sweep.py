"""Convergence-vs-chain-law sweep with entrapment telemetry.

One protocol, every transition law the repo implements: simple RW,
MH-uniform, P_IS (Eq. 7), MHLJ (Algorithm 1), the heterogeneity-aware law
(MH targeting the dissimilarity-optimized pi of arXiv:2204.06477) and the
private weighted walk (arXiv:2009.01790) at several privacy levels gamma —
each swept over the trap-prone graph families (hub-heavy Barabasi-Albert,
the dumbbell bottleneck, the lollipop hitting-time stressor).

Per (family, law) cell the sweep records the MSE milestones AND the
entrapment telemetry of the update-node sequence (Herfindahl index, top-k
visit share) — so the convergence/entrapment trade-off each law makes is
one JSON apart from the others, including how the private law's gamma knob
buys privacy with stationary drift and how the heterogeneity law shifts
visit mass onto the high-dissimilarity nodes.

The full sweep lands in ``results/BENCH_law_sweep.json``.  The smoke tier
runs every law at toy sizes and its ``{family}_{law}_herfindahl`` derived
keys are presence-gated by ``benchmarks/check_regression.py`` against the
committed ``smoke_baseline`` (in ``results/BENCH_large_graph.json``, next
to the other modules') — so a law that stops building, or silently drops
out of the sweep, fails tier 1 via the gate's missing-key path, on both
``REPRO_BACKEND`` legs.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import RESULTS_DIR, milestones
from repro.core import MHLJParams
from repro.core.entrapment import occupancy_concentration
from repro.core.graphs import barabasi_albert, dumbbell, lollipop

NAME = "law_sweep"
PAPER_CLAIM = (
    "C7: the chain law is an open design axis — simple RW, MH-uniform, "
    "P_IS, MHLJ, heterogeneity-aware and private weighted walks run the "
    "same trap-prone protocol, and the entrapment telemetry (Herfindahl, "
    "top-k share) separates the laws the convergence curves alone blur."
)

# (label, trainer method, law_kwargs) — the private law is swept at several
# gammas so the privacy/convergence trade-off is a column, not a footnote
LAWS = (
    ("simple", "simple", None),
    ("uniform", "uniform", None),
    ("importance", "importance", None),
    ("mhlj", "mhlj", None),
    ("heterogeneity", "heterogeneity", None),
    ("private_g0.1", "private", {"gamma": 0.1}),
    ("private_g1.0", "private", {"gamma": 1.0}),
)


def _graphs(scale: str) -> dict:
    if scale == "smoke":
        return {
            "ba": barabasi_albert(48, 3, seed=0),
            "dumbbell": dumbbell(12, 6),
            "lollipop": lollipop(16, 9),
        }
    if scale == "quick":
        return {
            "ba": barabasi_albert(256, 3, seed=0),
            "dumbbell": dumbbell(48, 32),
            "lollipop": lollipop(96, 64),
        }
    return {
        "ba": barabasi_albert(1000, 3, seed=0),
        "dumbbell": dumbbell(128, 64),
        "lollipop": lollipop(256, 128),
    }


def run(quick: bool = False, scale: str | None = None) -> dict:
    from repro.data import make_heterogeneous_regression
    from repro.walk_sgd import run_rw_sgd

    scale = scale or ("quick" if quick else "full")
    T = {"smoke": 600, "quick": 15_000, "full": 40_000}[scale]
    graphs = _graphs(scale)
    params = MHLJParams(0.1, 0.5, 3)
    out = {"T": T, "claim": PAPER_CLAIM, "laws": [l[0] for l in LAWS]}
    derived: dict = {}
    for tag, graph in graphs.items():
        n = graph.n
        data = make_heterogeneous_regression(
            n, dim=10, sigma_high_sq=100.0, p_high=0.002, seed=3,
            force_min_high=2, x_star_scale=10.0,
        )
        gamma_max = 0.5 / data.lipschitz.max()
        gamma_mean = 0.5 / data.lipschitz.mean()
        v0 = int(np.argmax(data.lipschitz))  # start inside the trap
        sub = {}
        for label, method, law_kwargs in LAWS:
            # per-law stable step sizes: laws whose gradient weights cancel
            # the per-node smoothness (P_IS/MHLJ, and the private walk up
            # to its (1+gamma) weight inflation from the Gamma mean shift)
            # take the mean-L rate; laws that don't (simple, uniform, the
            # heterogeneity target — its pi tracks dissimilarity, not L)
            # need the worst-case max-L rate
            if method in ("importance", "mhlj"):
                lr = gamma_mean
            elif method == "private":
                lr = gamma_mean / (1.0 + law_kwargs["gamma"])
            else:
                lr = gamma_max
            res = run_rw_sgd(
                method, graph, data, lr, T,
                mhlj_params=params if method == "mhlj" else None,
                law_kwargs=law_kwargs, seed=4, v0=v0,
            )
            conc = occupancy_concentration(res.update_nodes, n, topk=3)
            sub[label] = {
                **milestones(res.mse),
                "herfindahl": conc["herfindahl"],
                "topk_share": conc["topk_share"],
            }
            # the gate key: presence says the law is still swept (a law
            # vanishing from the sweep is a loud missing-key CI failure)
            derived[f"{tag}_{label}_herfindahl"] = conc["herfindahl"]
        out[tag] = sub
    out["derived"] = derived

    if scale != "smoke":  # don't clobber real sweeps from the anti-rot tier
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, "BENCH_law_sweep.json")
        # (the smoke-tier regression baseline lives with the other modules'
        # in BENCH_large_graph.json's smoke_baseline section)
        with open(path, "w") as f:
            json.dump(out, f, indent=2, default=float)
    return out


def run_smoke() -> dict:
    """Tiny tier exercised by the tier-1 bench-smoke test: every law in
    ``LAWS`` trains on every trap family, so a law that stops building
    fails CI instead of rotting."""
    return run(scale="smoke")
