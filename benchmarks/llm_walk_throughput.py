"""System benchmark: walk-orchestrated LLM training + serving throughput
(CPU smoke scale; the production-mesh path is costed by the roofline bench).

Measures steps/s of the jitted walk train step (reduced qwen config) per
routing method, and decode tokens/s of the serving engine — the numbers a
deployment would track.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_arch, reduced
from repro.launch.serve import Request, ServeEngine
from repro.launch.train import run_training

NAME = "llm_walk_throughput"
PAPER_CLAIM = (
    "System: walk-orchestrated training sustains the same step rate as "
    "static routing (the transition adds O(1) device work, Remark 1 bounds "
    "the extra hops); serving sustains continuous batching."
)


def run(quick: bool = False) -> dict:
    cfg = reduced(get_arch("qwen2.5-32b"))
    steps = 20 if quick else 60
    out = {"claim": PAPER_CLAIM, "train": {}}
    for method in ("uniform", "mhlj"):
        res = run_training(
            cfg, graph_kind="ring", n_silos=8, method=method, steps=steps,
            batch_size=2, seq_len=64, log_every=0, seed=0,
        )
        out["train"][method] = {
            "steps_per_sec": res["steps_per_sec"],
            "loss_drop": float(res["losses"][:5].mean() - res["losses"][-5:].mean()),
            "hops_per_update": res["transitions_per_update"],
        }

    engine = ServeEngine(cfg, batch_size=4, cache_len=128)
    rng = np.random.default_rng(0)
    for rid in range(8):
        engine.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 8))
    t0 = time.time()
    stats = engine.run()
    out["serve"] = {**{k: v for k, v in stats.items()}, "wall_s": time.time() - t0}
    out["derived"] = {
        "mhlj_vs_uniform_step_rate": out["train"]["mhlj"]["steps_per_sec"]
        / out["train"]["uniform"]["steps_per_sec"],
        "serve_tokens_per_sec": stats["tokens_per_sec"],
        "slot_utilization": stats["slot_utilization"],
    }
    return out
