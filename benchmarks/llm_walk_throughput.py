"""System benchmark: walk-orchestrated LLM training + serving throughput
(CPU smoke scale; the production-mesh path is costed by the roofline bench).

Measures steps/s of the jitted walk train step (reduced qwen config) per
routing method, decode tokens/s of the serving engine, and the raw sampler
throughput of the unified walk engine (transitions/s for a W-walk fleet,
per backend) — the numbers a deployment would track.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import MHLJParams, WalkEngine, watts_strogatz
from repro.launch.serve import Request, ServeEngine
from repro.launch.train import run_training

NAME = "llm_walk_throughput"
PAPER_CLAIM = (
    "System: walk-orchestrated training sustains the same step rate as "
    "static routing (the transition adds O(1) device work, Remark 1 bounds "
    "the extra hops); serving sustains continuous batching."
)


def _sampler_throughput(backend: str, walks: int, steps: int, iters: int) -> dict:
    """Transitions/s of one batched engine fleet on an orchestration graph."""
    n = 512
    g = watts_strogatz(n, 8, 0.1, seed=0)
    rng = np.random.default_rng(0)
    lips = jnp.asarray(np.exp(rng.normal(size=n)), jnp.float32)
    eng = WalkEngine.from_graph(
        g, MHLJParams(0.2, 0.5, 3), lipschitz=lips, backend=backend
    )
    v0s = jnp.arange(walks, dtype=jnp.int32) % n
    run_fn = jax.jit(lambda key: eng.run(key, v0s, steps))
    nodes, hops = run_fn(jax.random.PRNGKey(0))  # warm-up / compile
    nodes.block_until_ready()
    t0 = time.time()
    for i in range(iters):
        nodes, hops = run_fn(jax.random.PRNGKey(i + 1))
    nodes.block_until_ready()
    dt = time.time() - t0
    return {
        "walks": walks,
        "steps": steps,
        "transitions_per_sec": walks * steps * iters / dt,
        "mean_hops_per_update": float(np.asarray(hops, np.float64).mean()),
    }


def run(quick: bool = False) -> dict:
    cfg = reduced(get_arch("qwen2.5-32b"))
    steps = 20 if quick else 60
    out = {"claim": PAPER_CLAIM, "train": {}}
    for method in ("uniform", "mhlj"):
        res = run_training(
            cfg, graph_kind="ring", n_silos=8, method=method, steps=steps,
            batch_size=2, seq_len=64, log_every=0, seed=0,
        )
        out["train"][method] = {
            "steps_per_sec": res["steps_per_sec"],
            "loss_drop": float(res["losses"][:5].mean() - res["losses"][-5:].mean()),
            "hops_per_update": res["transitions_per_update"],
        }

    # raw walk-engine sampler throughput (the orchestration hot path).  The
    # scan backend at fleet scale; the Pallas backend small off-TPU (interpret
    # mode is an emulator — its numbers only prove the path runs end to end).
    on_tpu = jax.default_backend() == "tpu"
    out["sampler"] = {
        "scan": _sampler_throughput(
            "scan", walks=1024 if quick else 4096, steps=8, iters=2 if quick else 5
        ),
        "pallas": _sampler_throughput(
            "pallas",
            walks=4096 if on_tpu else 256,
            steps=8 if on_tpu else 2,
            iters=5 if on_tpu else 1,
        ),
    }

    engine = ServeEngine(cfg, batch_size=4, cache_len=128)
    rng = np.random.default_rng(0)
    for rid in range(8):
        engine.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 8))
    t0 = time.time()
    stats = engine.run()
    out["serve"] = {**{k: v for k, v in stats.items()}, "wall_s": time.time() - t0}
    out["derived"] = {
        "mhlj_vs_uniform_step_rate": out["train"]["mhlj"]["steps_per_sec"]
        / out["train"]["uniform"]["steps_per_sec"],
        "serve_tokens_per_sec": stats["tokens_per_sec"],
        "slot_utilization": stats["slot_utilization"],
        "sampler_transitions_per_sec": out["sampler"]["scan"][
            "transitions_per_sec"
        ],
    }
    return out
