"""Beyond-paper: W parallel MHLJ walks + parameter averaging.

The paper runs ONE walk.  On a multi-pod mesh we can run one walk per pod
and average (``repro.walk_sgd.fleet``).  Theorem 1's variance term scales
like 1/W under averaging while the O(p_J^2) bias term does not — so
averaging should cut the noisy component of the error, not the floor.

This benchmark measures exactly that on the paper's regression setting,
through the unified fleet scan: each repetition trains all W walks in ONE
``run_rw_sgd_multi`` call (a single batched ``WalkEngine.step`` services
every walk per iteration, the walker batch sharded over the ``walker``
mesh axis of ``repro.launch.mesh.make_walker_mesh``), models averaged at
the end (one-shot local-SGD averaging), vs the single-walk baseline.
Each W row also records ``num_walkers`` and the fleet's **aggregate**
update throughput (W x T / wall-clock, min over repetitions so compile
time drops out); the periodic-averaging variant and the sharded
steps/s-vs-W scaling live in the fleet section of
``benchmarks/large_graph_walk.py``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import MHLJParams, ring
from repro.data import make_heterogeneous_regression
from repro.launch.mesh import make_walker_mesh
from repro.walk_sgd import run_rw_sgd_multi

NAME = "multi_walk"
PAPER_CLAIM = (
    "Beyond-paper: averaging W parallel MHLJ walks reduces the variance "
    "component of the error (~1/W) without touching the O(p_J^2) bias floor."
)


def run(quick: bool = False) -> dict:
    n = 128
    graph = ring(n)
    data = make_heterogeneous_regression(
        n, dim=6, sigma_high_sq=100.0, p_high=0.03, seed=7, x_star_scale=3.0
    )
    gamma = 0.3 / data.lipschitz.mean()
    T = 10_000 if quick else 20_000
    params = MHLJParams(0.1, 0.5, 3)
    reps = 3 if quick else 5
    mesh = make_walker_mesh()

    rng = np.random.default_rng(0)
    out_w = {}
    for w in (1, 2, 4, 8):
        final_mses = []
        hops_per_update = []
        rep_secs = []
        for rep in range(reps):
            t0 = time.perf_counter()
            res = run_rw_sgd_multi(
                "mhlj", graph, data, gamma, T, w, mhlj_params=params,
                seed=1000 * rep, v0s=rng.integers(0, n, size=w), mesh=mesh,
            )
            rep_secs.append(time.perf_counter() - t0)
            final_mses.append(data.mse(res.x_avg))
            hops_per_update.append(res.transitions_per_update)
        out_w[w] = {
            "num_walkers": w,
            "mean_final_mse": float(np.mean(final_mses)),
            "std_final_mse": float(np.std(final_mses)),
            "hops_per_update": float(np.mean(hops_per_update)),
            # min over reps: rep 0 pays jit compile, the rest are steady-state
            "aggregate_walk_steps_per_sec": float(w * T / min(rep_secs)),
        }

    floor = data.mse(data.optimum())
    excess = {w: out_w[w]["mean_final_mse"] - floor for w in out_w}
    return {
        "claim": PAPER_CLAIM,
        "mesh_devices": int(mesh.devices.size),
        "walks": out_w,
        "ls_floor_mse": floor,
        "excess_over_floor": {str(w): float(e) for w, e in excess.items()},
        "derived": {
            "excess_w1": excess[1],
            "excess_w8": excess[8],
            "variance_reduction_w8": excess[1] / max(excess[8], 1e-12),
            "aggregate_walk_steps_per_sec_w8": (
                out_w[8]["aggregate_walk_steps_per_sec"]
            ),
        },
    }
