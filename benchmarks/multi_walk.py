"""Beyond-paper: W parallel MHLJ walks + parameter averaging.

The paper runs ONE walk.  On a multi-pod mesh we can run one walk per pod
and average (walk_sgd/multi_walk.py).  Theorem 1's variance term scales
like 1/W under averaging while the O(p_J^2) bias term does not — so
averaging should cut the noisy component of the error, not the floor.

This benchmark measures exactly that on the paper's regression setting,
through the unified walk engine: each repetition trains all W walks in ONE
``run_rw_sgd_multi`` scan (a single batched ``WalkEngine.step`` services
every walk per iteration), models averaged at the end (one-shot local-SGD
averaging), vs the single-walk baseline.
"""
from __future__ import annotations

import numpy as np

from repro.core import MHLJParams, ring
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import run_rw_sgd_multi

NAME = "multi_walk"
PAPER_CLAIM = (
    "Beyond-paper: averaging W parallel MHLJ walks reduces the variance "
    "component of the error (~1/W) without touching the O(p_J^2) bias floor."
)


def run(quick: bool = False) -> dict:
    n = 128
    graph = ring(n)
    data = make_heterogeneous_regression(
        n, dim=6, sigma_high_sq=100.0, p_high=0.03, seed=7, x_star_scale=3.0
    )
    gamma = 0.3 / data.lipschitz.mean()
    T = 10_000 if quick else 20_000
    params = MHLJParams(0.1, 0.5, 3)
    reps = 3 if quick else 5

    rng = np.random.default_rng(0)
    out_w = {}
    for w in (1, 2, 4, 8):
        final_mses = []
        hops_per_update = []
        for rep in range(reps):
            res = run_rw_sgd_multi(
                "mhlj", graph, data, gamma, T, w, mhlj_params=params,
                seed=1000 * rep, v0s=rng.integers(0, n, size=w),
            )
            final_mses.append(data.mse(res.x_avg))
            hops_per_update.append(res.transitions_per_update)
        out_w[w] = {
            "mean_final_mse": float(np.mean(final_mses)),
            "std_final_mse": float(np.std(final_mses)),
            "hops_per_update": float(np.mean(hops_per_update)),
        }

    floor = data.mse(data.optimum())
    excess = {w: out_w[w]["mean_final_mse"] - floor for w in out_w}
    return {
        "claim": PAPER_CLAIM,
        "walks": out_w,
        "ls_floor_mse": floor,
        "excess_over_floor": {str(w): float(e) for w, e in excess.items()},
        "derived": {
            "excess_w1": excess[1],
            "excess_w8": excess[8],
            "variance_reduction_w8": excess[1] / max(excess[8], 1e-12),
        },
    }
