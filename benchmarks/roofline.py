"""Roofline analysis (deliverable g): three terms per (arch x shape) from
the UNROLLED dry-run capture (results/roofline.jsonl).

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip: cost_analysis
  memory term     = HLO_bytes / HBM_bw                reports the post-SPMD
  collective term = collective_bytes / ICI link bw    per-device program)

plus MODEL_FLOPS = 6 * N(_active) * D and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs (catches remat / redundancy waste).

Collective bytes are ring-cost weighted (hlo_parse.collective_summary):
all-reduce ~ 2x operand, all-gather/reduce-scatter ~ (k-1)/k, permute 1x.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR
from repro.configs import SHAPES_BY_NAME, get_arch
from repro.launch.mesh import HW

NAME = "roofline"
PAPER_CLAIM = (
    "System benchmark (beyond-paper): measured per-device throughput of the "
    "model configs vs the analytic HBM/MXU roofline."
)

CAPTURE = os.path.join(RESULTS_DIR, "roofline.jsonl")
CAPTURE_OPT = os.path.join(RESULTS_DIR, "roofline_opt.jsonl")


def model_flops_per_device(rec: dict) -> float:
    """6*N_active*D analytic model FLOPs for this case, per chip."""
    shape = SHAPES_BY_NAME[rec["shape"]]
    cfg = get_arch(rec["arch"])
    n_active = rec.get("params_active") or cfg.active_param_count()
    chips = 512 if rec["multi_pod"] else 256
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6  # fwd + bwd
    elif rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2
    return mult * n_active * tokens / chips


def analyze_record(rec: dict) -> dict:
    coll = rec["collectives"]
    flops = rec["flops"]
    t_comp = flops / HW.PEAK_FLOPS_BF16
    t_mem = rec["bytes_accessed"] / HW.HBM_BW
    t_coll = coll.get("total_ring_cost_bytes", coll["total_bytes"]) / HW.ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "kind": rec["kind"],
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops > 0 else float("nan"),
        "hbm_gb": rec["memory"].get("temp_size_in_bytes", 0) / 1e9,
    }


def load_capture(path: str = CAPTURE) -> list:
    recs = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") == "ok":
                recs[(r["arch"], r["shape"], r["multi_pod"])] = r
    return [analyze_record(r) for r in recs.values()]


def run(quick: bool = False) -> dict:
    rows = load_capture()
    if not rows:
        return {"error": f"no capture at {CAPTURE}; run "
                "`python -m repro.launch.roofline_capture --out results/roofline.jsonl`"}
    by_dom = {}
    for r in rows:
        by_dom.setdefault(r["dominant"], []).append(f"{r['arch']}x{r['shape']}")
    worst = sorted(rows, key=lambda r: -r["bound_s"])[:3]
    out = {
        "rows": rows,
        "dominant_counts": {k: len(v) for k, v in by_dom.items()},
        "worst_cases": [f"{r['arch']} x {r['shape']} ({r['dominant']}, {r['bound_s']:.3f}s)"
                        for r in worst],
        "derived": {
            "cases": len(rows),
            "compute_bound": len(by_dom.get("compute", [])),
            "memory_bound": len(by_dom.get("memory", [])),
            "collective_bound": len(by_dom.get("collective", [])),
        },
    }
    opt = load_capture(CAPTURE_OPT)
    if opt:
        base_by = {(r["arch"], r["shape"]): r for r in rows}
        speedups = []
        for r in opt:
            b = base_by.get((r["arch"], r["shape"]))
            if b and r["bound_s"] > 0:
                speedups.append(b["bound_s"] / r["bound_s"])
        out["opt_rows"] = opt
        out["derived"]["opt_cases"] = len(opt)
        out["derived"]["median_bound_speedup"] = float(
            sorted(speedups)[len(speedups) // 2]
        ) if speedups else 0.0
        out["derived"]["max_bound_speedup"] = max(speedups) if speedups else 0.0
    return out


def format_table(rows: list) -> str:
    hdr = (f"{'arch':<22}{'shape':<13}{'mesh':<9}{'compute_s':>11}{'memory_s':>11}"
           f"{'collect_s':>11}{'dominant':>11}{'useful':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"{r['arch']:<22}{r['shape']:<13}{r['mesh']:<9}"
            f"{r['compute_s']:>11.4g}{r['memory_s']:>11.4g}{r['collective_s']:>11.4g}"
            f"{r['dominant']:>11}{r['useful_ratio']:>8.2f}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    res = run()
    if "rows" in res:
        print(format_table(res["rows"]))
        print("\ndominant:", res["dominant_counts"])
    else:
        print(res["error"])
