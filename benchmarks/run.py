"""Benchmark harness (deliverable d): one module per paper figure/claim plus
the roofline and system benchmarks.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--smoke] [--only fig3_ring,...]

Each module exposes ``run(quick) -> dict`` (with a ``derived`` summary) and
``PAPER_CLAIM``; results land in results/bench_<name>.json and a CSV line
``name,us_per_call,derived...`` is printed per benchmark (us_per_call =
wall time of the benchmark body).

``--smoke`` is the anti-rot tier exercised by the tier-1 test suite
(tests/test_bench_smoke.py): it verifies every module's harness contract
(NAME / PAPER_CLAIM / run) and *executes* the modules that define a
``run_smoke()`` tier at toy sizes — so a benchmark that stops importing or
crashes on its first step fails CI instead of rotting silently.  The
large-graph smoke tier additionally takes real walk steps through every
registered engine layout (``repro.core.engine.LAYOUTS`` — sparse, dense,
bucketed), so a layout cannot rot while the default one keeps passing.
Smoke results are not dumped to results/.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks import (
    fault_sweep,
    fig3_ring,
    fig4_erdos_renyi,
    fig5_sparse_graphs,
    fig6_annealing,
    large_graph_walk,
    law_sweep,
    llm_walk_throughput,
    multi_walk,
    roofline,
    serve_throughput,
    theorem1_remark1,
)
from benchmarks.common import dump, row, time_call

MODULES = [
    fig3_ring,
    fig4_erdos_renyi,
    fig5_sparse_graphs,
    fig6_annealing,
    theorem1_remark1,
    multi_walk,
    llm_walk_throughput,
    large_graph_walk,
    law_sweep,
    serve_throughput,
    fault_sweep,
    roofline,
]


def smoke(json_path: str | None = None) -> int:
    """Contract-check every module; execute the ones with a smoke tier.

    ``json_path`` additionally dumps ``{module: derived}`` for the executed
    smoke tiers — the input of ``benchmarks/check_regression.py``, which
    compares these steps/sec against the committed baseline.
    """
    failures = 0
    derived_by_module: dict = {}
    print("name,us_per_call,derived")
    for mod in MODULES:
        if not (
            isinstance(getattr(mod, "NAME", None), str)
            and isinstance(getattr(mod, "PAPER_CLAIM", None), str)
            and callable(getattr(mod, "run", None))
        ):
            failures += 1
            print(f"{getattr(mod, '__name__', mod)},0,FAILED: harness contract")
            continue
        if not callable(getattr(mod, "run_smoke", None)):
            print(f"{mod.NAME},0,import-ok")
            continue
        try:
            result, seconds = time_call(mod.run_smoke)
            derived_by_module[mod.NAME] = result.get("derived", {})
            print(row(f"{mod.NAME}[smoke]", seconds, result.get("derived", {})))
        except Exception as e:
            failures += 1
            print(f"{mod.NAME},0,FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(derived_by_module, f, indent=2, default=float)
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes/iters")
    ap.add_argument(
        "--smoke", action="store_true",
        help="anti-rot tier: contract-check all modules, run toy sizes",
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="with --smoke: dump per-module derived metrics to PATH "
        "(consumed by benchmarks/check_regression.py)",
    )
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()

    if args.smoke:
        return smoke(json_path=args.json)

    selected = MODULES
    if args.only:
        names = set(args.only.split(","))
        selected = [m for m in MODULES if m.NAME in names]
        if not selected:
            print(f"no benchmarks match {args.only!r}", file=sys.stderr)
            return 2

    print("name,us_per_call,derived")
    failures = 0
    for mod in selected:
        try:
            result, seconds = time_call(mod.run, args.quick)
            derived = result.get("derived", {})
            if "error" in result:
                print(f"{mod.NAME},0,SKIPPED: {result['error']}")
                continue
            dump(mod.NAME, result)
            print(row(mod.NAME, seconds, derived))
            if mod is roofline and "rows" in result:
                print()
                print(roofline.format_table(result["rows"]))
                print()
        except Exception as e:
            failures += 1
            print(f"{mod.NAME},0,FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
