"""Walk-routed serving throughput: where requests/s meets walk-steps/s.

One ``repro.launch.serve.ServeSimulator`` workload per routing law: requests
arrive at nodes of a ragged-layout Barabasi-Albert graph (traffic skewed
∝ degree, so demand concentrates on the hubs), a W-walker fleet routes them
via batched ``WalkEngine`` transitions — law selected through the trainer
METHODS seam — and a slot-based ``ServeEngine`` with a bounded admission
queue and per-request deadlines decodes them through the cached decode path.

Per law the sweep records requests/s, p50/p95/p99 latency (in engine ticks,
machine-independent), queue depth, slot occupancy, the shed counters
(queue-full backpressure + deadline expiry) and the per-node visit
Herfindahl/top-k share (``repro.core.entrapment.occupancy_concentration``,
the exact entrapment telemetry ``law_sweep.py`` attaches to training
walks) — so "which chain law serves skewed traffic best, and what
entrapment does it pay" is one JSON apart per law.

The full sweep (100k-node ragged BA, 512 walkers) lands in
``results/BENCH_serve.json``.  The smoke tier runs every law at toy sizes;
its ``ba_{law}_herfindahl`` / ``ba_{law}_p99_ticks`` /
``ba_{law}_requests_per_sec`` derived keys are presence-gated by
``benchmarks/check_regression.py`` (values are wall-clock / statistical, so
only their existence is compared) — a law silently dropped from the serving
sweep is a loud missing-key CI failure on both ``REPRO_BACKEND`` legs.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR
from repro.configs import get_arch, reduced
from repro.core.graphs import barabasi_albert
from repro.launch.serve import ServeEngine, ServeSimulator

NAME = "serve_throughput"
PAPER_CLAIM = (
    "Serving closes the loop: requests pinned to nodes of a hub-heavy "
    "graph are routed by walker fleets, and the chain law's entrapment "
    "trade-off (Herfindahl) becomes a requests/s + p99-latency trade-off."
)

# (label, trainer method, law_kwargs) — heterogeneity's pi defaults to the
# load vector inside ServeSimulator (visit mass ∝ demand), so no O(n²)
# dissimilarity measurement runs at serving scale
LAWS = (
    ("simple", "simple", None),
    ("uniform", "uniform", None),
    ("importance", "importance", None),
    ("mhlj", "mhlj", None),
    ("heterogeneity", "heterogeneity", None),
    ("private_g0.5", "private", {"gamma": 0.5}),
)

# one scenario per scale: graph size, fleet size, traffic and decode budget
SCALES = {
    "smoke": dict(
        n=384, m=3, walkers=24, ticks=90, drain=30, rate=1.0, pickup=4,
        batch=4, cache_len=64, max_queue=32, deadline=80,
        prompt_len=(4, 10), max_new=6,
    ),
    "quick": dict(
        n=20_000, m=3, walkers=128, ticks=400, drain=150, rate=1.5, pickup=4,
        batch=8, cache_len=128, max_queue=64, deadline=300,
        prompt_len=(4, 16), max_new=8,
    ),
    "full": dict(
        n=100_000, m=3, walkers=512, ticks=1500, drain=500, rate=2.0,
        pickup=4, batch=8, cache_len=192, max_queue=128, deadline=1000,
        prompt_len=(4, 24), max_new=12,
    ),
}


def run(quick: bool = False, scale: str | None = None) -> dict:
    scale = scale or ("quick" if quick else "full")
    p = SCALES[scale]
    graph = barabasi_albert(p["n"], p["m"], seed=0, layout="ragged")
    cfg = reduced(get_arch("mamba2-370m"))
    # ONE model build + decode compile for the whole law sweep: each law
    # reuses the slot engine via reset()
    engine = ServeEngine(
        cfg, p["batch"], p["cache_len"], seed=0, max_queue=p["max_queue"]
    )
    out = {
        "scale": scale,
        "graph": graph.name,
        "n": graph.n,
        "walkers": p["walkers"],
        "ticks": p["ticks"] + p["drain"],
        "claim": PAPER_CLAIM,
        "laws": [l[0] for l in LAWS],
    }
    derived: dict = {}
    for label, method, law_kwargs in LAWS:
        sim = ServeSimulator(
            graph,
            engine.reset(),
            method=method,
            num_walkers=p["walkers"],
            rate=p["rate"],
            pickup=p["pickup"],
            deadline_ticks=p["deadline"],
            prompt_len=p["prompt_len"],
            max_new_tokens=p["max_new"],
            law_kwargs=law_kwargs,
            seed=0,
        )
        metrics = sim.run(p["ticks"], drain_ticks=p["drain"])
        out[label] = metrics
        # the gate keys: presence says the law still serves (a law dropped
        # from the sweep is a loud missing-key CI failure); values are
        # wall-clock/statistical, so magnitude is deliberately not gated
        derived[f"ba_{label}_herfindahl"] = metrics["herfindahl"]
        derived[f"ba_{label}_p99_ticks"] = metrics["p99_ticks"]
        derived[f"ba_{label}_requests_per_sec"] = metrics["requests_per_sec"]
    out["derived"] = derived

    if scale == "full":
        # only the full 100k-node sweep may write the committed results
        # file — docs/benchmarks.md cites its numbers, so a --quick or
        # smoke run must not clobber it (benchmarks.run already drops
        # every tier's output in its own results/bench_<name>.json)
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, "BENCH_serve.json")
        # (the smoke-tier regression baseline lives with the other modules'
        # in BENCH_large_graph.json's smoke_baseline section)
        with open(path, "w") as f:
            json.dump(out, f, indent=2, default=float)
    return out


def run_smoke() -> dict:
    """Tiny tier exercised by the tier-1 bench-smoke test: every routing
    law serves a toy workload end to end (arrivals → fleet pickup → slot
    decode → shed accounting), so the serving path cannot rot silently."""
    return run(scale="smoke")


if __name__ == "__main__":
    res = run(scale="full")
    for k, v in sorted(res["derived"].items()):
        print(f"{k}: {v:.4g}" if isinstance(v, float) else f"{k}: {v}")
    print(f"\nwrote {os.path.join(RESULTS_DIR, 'BENCH_serve.json')}")
