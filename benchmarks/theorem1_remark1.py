"""Theorem 1 + Remark 1 quantitative checks (paper §V-VI).

* tau_mix / spectral gap of MHLJ vs MH-IS on trap graphs (jumps improve
  conductance — 'tau_mix is smaller than its MH counterpart').
* Remark 1: measured transitions/update vs 1 + p_J(1/p_d - 1) bound.
* Needell centralized reference rates for the same L distribution.
"""
from __future__ import annotations

import numpy as np

from repro.core import MHLJParams, ring
from repro.core.graphs import watts_strogatz
from repro.core.theory import needell_rates, theorem1_terms
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import comm_report, run_rw_sgd

NAME = "theorem1_remark1"
PAPER_CLAIM = (
    "C6/C7: tau_mix(MHLJ) < tau_mix(MH-IS) on trap graphs; measured "
    "transitions/update within Remark 1's bound."
)


def run(quick: bool = False) -> dict:
    n = 64 if quick else 128
    params = MHLJParams(0.1, 0.5, 3)
    out = {"claim": PAPER_CLAIM}

    for tag, graph in (("ring", ring(n)), ("ws", watts_strogatz(n, 4, 0.1, 0))):
        lips = np.ones(n)
        lips[n // 2] = 60.0
        t1 = theorem1_terms(graph, lips, params, num_iters=10_000)
        out[tag] = {
            "tau_mix_mhlj": t1.tau_mix,
            "tau_mix_mh_is": t1.tau_mix_mh,
            "spectral_gap_mhlj": t1.spectral_gap,
            "spectral_gap_mh_is": t1.spectral_gap_mh,
            "perturbation_l1": t1.perturbation_l1,
            "rate_term": t1.rate_term,
            "gap_term": t1.gap_term,
        }

    data = make_heterogeneous_regression(32, dim=4, seed=0)
    res = run_rw_sgd(
        "mhlj", ring(32), data, 1e-3, 5_000 if quick else 20_000,
        mhlj_params=params, seed=0,
    )
    out["remark1"] = comm_report(res.transitions, params.p_j, params.p_d, params.r)
    out["needell_rates"] = needell_rates(data.lipschitz, 10_000)
    out["derived"] = {
        "ring_tau_ratio": out["ring"]["tau_mix_mh_is"] / max(1, out["ring"]["tau_mix_mhlj"]),
        "ws_tau_ratio": out["ws"]["tau_mix_mh_is"] / max(1, out["ws"]["tau_mix_mhlj"]),
        "remark1_within": out["remark1"]["within_bound"],
        "hops_per_update": out["remark1"]["transitions_per_update_measured"],
    }
    return out
