"""Paper Fig. 6 + Theorem 1's error gap, measured exactly.

MHLJ's jumps perturb the stationary distribution away from pi_IS, so
weighted RW-SGD converges to a biased fixed point; Theorem 1 bounds the
squared bias by O(p_J^2 ||P_IS - P_Levy||_1^2).  Simulated endpoints are
noisy (SGD variance), so this demo computes the bias IN CLOSED FORM from
the weighted normal equations (core.theory.error_gap_exact):

  part 1  log-log sweep of p_J -> slope approaches 2 (the O(p_J^2) law)
  part 2  Fig-6 simulation: annealing p_J -> 0 tracks the unbiased optimum
          while keeping the early-phase escape speed (seed-averaged)

Run:  PYTHONPATH=src python examples/annealing_error_gap.py
"""
import numpy as np

from repro.core import MHLJParams, ring, schedules
from repro.core.theory import error_gap_exact
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import run_rw_sgd

N, T = 64, 40_000


def main():
    graph = ring(N)

    # --- part 1: exact O(p_J^2) error gap --------------------------------
    # moderate heterogeneity keeps the chain in Theorem 1's linear-response
    # regime (p_J below the trap-exit scale L_min/L_max)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(N, 6)) * np.where(rng.random(N) < 0.1, 2.0, 1.0)[:, None]
    targs = feats @ (3 * rng.normal(size=6)) + rng.normal(size=N)
    lips = 2 * (feats**2).sum(1)
    print("exact asymptotic error gap ||x~(p_J) - x_LS||^2  "
          f"(ring {N}, L_max/L_min = {lips.max() / lips.min():.0f})")
    pjs = [0.2, 0.1, 0.05, 0.025, 0.0125]
    gaps = [
        error_gap_exact(graph, feats, targs, lips, MHLJParams(pj, 0.5, 3))
        for pj in pjs
    ]
    print(f"{'p_J':>9}{'gap':>12}{'log-log slope':>15}")
    for i, (pj, gap) in enumerate(zip(pjs, gaps)):
        slope = (
            "" if i == 0
            else f"{np.log(gaps[i] / gaps[i-1]) / np.log(pjs[i] / pjs[i-1]):>15.2f}"
        )
        print(f"{pj:>9.4f}{gap:>12.3e}{slope}")
    print("  -> slope approaches 2: the paper's O(p_J^2) gap term.\n")

    # --- part 2: Fig-6 annealing simulation ------------------------------
    data = make_heterogeneous_regression(
        N, dim=6, sigma_high_sq=100.0, p_high=0.05, seed=5, x_star_scale=3.0
    )
    gamma = 0.3 / data.lipschitz.mean()
    seeds = range(6)

    def run(schedule):
        tails, mids = [], []
        for s in seeds:
            res = run_rw_sgd(
                "mhlj", graph, data, gamma, T,
                mhlj_params=MHLJParams(0.3, 0.5, 3),
                p_j_schedule=schedule, seed=s,
            )
            mids.append(np.median(res.mse[2000:10000]))
            tails.append(np.median(res.mse[-4000:]))
        return float(np.mean(mids)), float(np.mean(tails))

    const_mid, const_tail = run(None)
    ann_mid, ann_tail = run(schedules.polynomial_decay(0.3, T, power=1.0, t0=2000))
    print(f"Fig-6 simulation (mean over {len(list(seeds))} seeds):")
    print(f"{'variant':<22}{'mid MSE':>12}{'tail MSE':>12}")
    print(f"{'constant p_J=0.3':<22}{const_mid:>12.4g}{const_tail:>12.4g}")
    print(f"{'annealed 0.3->0':<22}{ann_mid:>12.4g}{ann_tail:>12.4g}")
    print("\nannealing keeps the early speed and lowers the asymptotic floor.")


if __name__ == "__main__":
    main()
