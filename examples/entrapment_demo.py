"""Entrapment anatomy (paper Section IV + Theorem 1 quantities).

For each sparse topology the paper studies (ring, 2-d grid, Watts-Strogatz)
this demo computes — exactly, from the transition matrices —

  * the trap escape probability / expected dwell time at the L-spike node,
  * spectral gaps + mixing-time bounds of MH-IS vs the MHLJ chain
    (Theorem 1: tau_mix of the perturbed chain is smaller),
  * the error-gap driver ||P_IS - P_Levy||_1 and the predicted O(p_J^2) gap,

and then confirms the walk-level picture by simulation (occupancy).

Run:  PYTHONPATH=src python examples/entrapment_demo.py
"""
import jax
import numpy as np

from repro.core import transition as trans
from repro.core.entrapment import expected_dwell_time, occupancy_concentration
from repro.core.graphs import grid2d, ring, watts_strogatz
from repro.core.mixing import mixing_time_bounds, spectral_gap
from repro.core.theory import perturbation_l1
from repro.core.transition import MHLJParams
from repro.core.walk import graph_tensors, walk_markov, walk_mhlj

PARAMS = MHLJParams(p_j=0.1, p_d=0.5, r=3)
T_SIM = 40_000


def analyze(graph, spike=50.0):
    n = graph.n
    lips = np.ones(n)
    spike_node = n // 2
    lips[spike_node] = spike

    p_is = trans.mh_importance(graph, lips)
    p_mhlj = trans.mhlj(graph, lips, PARAMS)

    dwell_is = expected_dwell_time(p_is)[spike_node]
    dwell_mhlj = expected_dwell_time(p_mhlj)[spike_node]
    gap_is, gap_mhlj = spectral_gap(p_is), spectral_gap(p_mhlj)
    tmix_is = mixing_time_bounds(p_is)
    tmix_mhlj = mixing_time_bounds(p_mhlj)
    pert = perturbation_l1(graph, lips, PARAMS)

    # simulate the actual walks
    rp_is = trans.row_probs_padded(p_is, graph)
    nbrs, degs = graph_tensors(graph)
    traj_is = np.asarray(
        walk_markov(jax.random.PRNGKey(0), rp_is, nbrs, spike_node, T_SIM)
    )
    nodes_mhlj, _ = walk_mhlj(
        jax.random.PRNGKey(0), rp_is, nbrs, degs, spike_node, T_SIM,
        PARAMS.p_j, PARAMS.p_d, PARAMS.r,
    )
    occ_is = occupancy_concentration(traj_is, n)["topk_share"]
    occ_mhlj = occupancy_concentration(np.asarray(nodes_mhlj), n)["topk_share"]

    print(f"\n== {graph.name}  (n={n}, L spike x{spike:.0f} at node {spike_node})")
    print(f"   escape: E[dwell at spike]     MH-IS {dwell_is:10.1f}   "
          f"MHLJ {dwell_mhlj:10.1f}   ({dwell_is / dwell_mhlj:.1f}x shorter)")
    print(f"   mixing: spectral gap          MH-IS {gap_is:10.2e}   MHLJ {gap_mhlj:10.2e}")
    print(f"   mixing: t_mix upper bound     MH-IS {tmix_is['upper']:10.1f}   "
          f"MHLJ {tmix_mhlj['upper']:10.1f}")
    print(f"   occupancy of top node (sim)   MH-IS {occ_is:10.2%}   MHLJ {occ_mhlj:10.2%}")
    print(f"   error-gap driver ||P_IS - P_Levy||_1 = {pert:.3f}  "
          f"-> predicted gap O(p_J^2 ||.||^2) = {PARAMS.p_j**2 * pert**2:.3f}")


def main():
    analyze(ring(100))
    analyze(grid2d(10, 10))
    analyze(watts_strogatz(100, 4, 0.1, seed=0))
    print(
        "\nTakeaway: on every sparse topology the MH-IS chain's dwell time at"
        "\nthe important node explodes with the L ratio (detailed balance,"
        "\nEq. 8) while MHLJ caps it near 1/p_J; the spectral gap improves by"
        "\norders of magnitude, at the price of a bounded O(p_J^2) error gap."
    )


if __name__ == "__main__":
    main()
