"""End-to-end driver: decentralized LLM pre-training with MHLJ routing.

A ~35M-parameter llama-family model (qwen2.5 config family, custom dims)
is trained for a few hundred steps over a 16-silo Watts-Strogatz network
with heterogeneous per-silo token shards.  The walk decides which silo's
data produces every batch; silo importance (L_v) is estimated ONLINE from
gradient-norm secants (the paper's L_v has no closed form for LLM losses
— DESIGN.md §2 adaptation).  Compares MHLJ against MH-uniform routing.

Run (CPU, ~30-60 min):
  PYTHONPATH=src python examples/llm_decentralized.py
Faster sanity pass:
  PYTHONPATH=src python examples/llm_decentralized.py --steps 60 --small
A ~110M configuration (slower, same code path):
  PYTHONPATH=src python examples/llm_decentralized.py --big
On a real pod slice the same step lowers under the production mesh — see
src/repro/launch/dryrun.py (train_4k shape).
"""
import argparse
import dataclasses


from repro.configs import get_arch, reduced
from repro.launch.train import run_training


def model_cfg(scale: str):
    base = reduced(get_arch("qwen2.5-32b"))
    dims = {
        "small": dict(num_layers=2, d_model=256, num_heads=4, d_ff=1024, vocab_size=2048),
        "default": dict(num_layers=8, d_model=512, num_heads=8, d_ff=2048, vocab_size=8192),
        "big": dict(num_layers=12, d_model=768, num_heads=12, d_ff=3072, vocab_size=16384),
    }[scale]
    return dataclasses.replace(
        base,
        name=f"qwen-family-{scale}",
        num_kv_heads=dims["num_heads"] // 2,
        head_dim=dims["d_model"] // dims["num_heads"],
        loss_chunks=1,
        **dims,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = model_cfg("big" if args.big else ("small" if args.small else "default"))
    print(f"model: {cfg.name}  ~{cfg.param_count() / 1e6:.1f}M params")

    results = {}
    for method in ("uniform", "mhlj"):
        print(f"\n=== routing method: {method} ===")
        res = run_training(
            cfg,
            graph_kind="watts_strogatz",
            n_silos=16,
            method=method,
            steps=args.steps,
            batch_size=args.batch,
            seq_len=args.seq,
            lr=1e-3,
            online_lipschitz=method == "mhlj",
            log_every=max(1, args.steps // 10),
            seed=0,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=max(1, args.steps // 2) if args.checkpoint_dir else 0,
        )
        results[method] = res

    print("\n=== summary ===")
    for method, res in results.items():
        lo = res["losses"]
        print(
            f"{method:<8} loss {lo[:10].mean():.3f} -> {lo[-10:].mean():.3f}   "
            f"hops/update {res['transitions_per_update']:.3f}   "
            f"{res['steps_per_sec']:.2f} steps/s"
        )
    if "mhlj" in results:
        lips = results["mhlj"]["final_lipschitz"]
        print(f"online L_v estimates: min {lips.min():.3g}  mean {lips.mean():.3g}  "
              f"max {lips.max():.3g}  (hard silos get larger L_v -> more visits)")


if __name__ == "__main__":
    main()
