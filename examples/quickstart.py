"""Quickstart: the paper's experiment in miniature (~30 s on CPU).

Trains a decentralized least-squares model over a ring of 64 nodes with
heterogeneous data, comparing the three transition designs the paper
studies (Section I) plus the proposed MHLJ (Algorithm 1):

  uniform     MH targeting the uniform distribution
  importance  MH targeting pi_IS(v) ~ L_v  (entrapment-prone on the ring)
  mhlj        importance + Levy jumps  (the paper's fix)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import MHLJParams, ring
from repro.core.entrapment import occupancy_concentration
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import comm_report, run_rw_sgd

N, T = 64, 20_000
PARAMS = MHLJParams(p_j=0.1, p_d=0.5, r=3)


CHECKPOINTS = (500, 2_000, 5_000, 10_000, 19_500)


def main():
    graph = ring(N)
    data = make_heterogeneous_regression(
        N, dim=6, sigma_high_sq=1e3, high_nodes=np.array([0]), seed=3,
        x_star_scale=3.0,
    )
    print(f"graph={graph.name}  nodes={N}  L_max/L_bar="
          f"{data.lipschitz.max() / data.lipschitz.mean():.1f}\n")

    # paper's step-size protocol: uniform takes the largest stable step
    # (1/L_max); importance-weighted methods step with 1/L_bar
    gamma = 0.3 / data.lipschitz.mean()
    gamma_u = 0.3 / data.lipschitz.max()

    print("median MSE around iteration t   (walk starts AT the L-spike node)")
    print(f"{'method':<12}" + "".join(f"t={t:>7}  " for t in CHECKPOINTS)
          + f"{'occupancy(v0)':>14}{'hops/upd':>10}")
    for method, g in (("uniform", gamma_u), ("importance", gamma), ("mhlj", gamma)):
        res = run_rw_sgd(
            method, graph, data, g, T,
            mhlj_params=PARAMS if method == "mhlj" else None,
            seed=1, v0=0,
        )
        occ = occupancy_concentration(res.update_nodes, N, topk=1)
        meds = [float(np.median(res.mse[max(0, t - 500):t + 500])) for t in CHECKPOINTS]
        print(f"{method:<12}" + "".join(f"{m:>9.4g}  " for m in meds)
              + f"{occ['topk_share']:>14.2%}{res.transitions_per_update:>10.3f}")

    rep = comm_report(
        run_rw_sgd("mhlj", graph, data, gamma, 5_000, mhlj_params=PARAMS, seed=2).transitions,
        PARAMS.p_j, PARAMS.p_d, PARAMS.r,
    )
    print("\nRemark 1: measured transitions/update = "
          f"{rep['transitions_per_update_measured']:.3f} "
          f"<= bound {rep['transitions_per_update_bound']:.3f}  "
          f"(within_bound={rep['within_bound']})")
    print("\nEntrapment: 'importance' freezes at the L-spike node (occupancy ~1);"
          "\nMHLJ's jumps break detailed balance and restore convergence.")


if __name__ == "__main__":
    main()
