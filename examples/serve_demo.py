"""Serving demo: walk-routed requests on a hub-heavy graph, per routing law.

Spins up ONE ServeEngine (reduced mamba2-370m — SSM decode, O(1) state)
and, for each routing law in the trainer METHODS seam, a ServeSimulator on
a ragged-layout Barabasi-Albert graph: requests arrive at nodes with
degree-proportional skew (demand concentrates on the hubs), a small walker
fleet picks them up and feeds the slot scheduler, and the table shows the
serving numbers next to the entrapment telemetry — requests/s, p50/p99
latency in ticks, shed counters (backpressure + deadlines) and the
per-node visit Herfindahl.  The full architecture sweep (`docs/serving.md`)
and the 100k-node numbers live in `benchmarks/serve_throughput.py`.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
from repro.configs import get_arch, reduced
from repro.core.graphs import barabasi_albert
from repro.launch.serve import ServeEngine, ServeSimulator

LAWS = (
    ("simple", "simple", None),
    ("uniform", "uniform", None),
    ("mhlj", "mhlj", None),
    ("private_g0.5", "private", {"gamma": 0.5}),
)


def main():
    graph = barabasi_albert(512, 3, seed=0, layout="ragged")
    cfg = reduced(get_arch("mamba2-370m"))
    # one model build + decode compile; each law resets the serving state
    engine = ServeEngine(cfg, batch_size=4, cache_len=64, max_queue=32)
    print(f"graph: {graph.name} (n={graph.n}), walkers: 32, "
          f"arch: {cfg.name} (reduced)")
    print(f"{'law':<14} {'served':>9} {'req/s':>7} {'p50':>5} {'p99':>6} "
          f"{'shed(q/ddl)':>11} {'herfindahl':>10}")
    for label, method, law_kwargs in LAWS:
        sim = ServeSimulator(
            graph, engine.reset(), method=method, num_walkers=32,
            rate=1.5, pickup=4, deadline_ticks=120,
            prompt_len=(4, 12), max_new_tokens=6,
            law_kwargs=law_kwargs, seed=0,
        )
        m = sim.run(150, drain_ticks=50)
        print(f"{label:<14} {m['completed']:>4}/{m['offered']:<4} "
              f"{m['requests_per_sec']:>7.1f} {m['p50_ticks']:>5.0f} "
              f"{m['p99_ticks']:>6.1f} "
              f"{m['shed_queue_full']:>5}/{m['shed_deadline']:<5} "
              f"{m['herfindahl']:>10.4f}")
    print("\n(toy scale on CPU; the 100k-node ragged-graph sweep writes "
          "results/BENCH_serve.json)")


if __name__ == "__main__":
    main()
