"""Serving demo: batched greedy decoding with continuous batching.

Spins up the ServeEngine on the reduced mamba2-370m (SSM: O(1) decode
state) and the reduced qwen2.5 (KV cache) backbones, submits a bursty
queue of requests with mixed prompt lengths, and reports throughput +
slot utilization.  The production decode path for all 10 assigned
architectures is exercised by the decode_32k / long_500k dry-run shapes.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import numpy as np

from repro.configs import get_arch, reduced
from repro.launch.serve import Request, ServeEngine


def demo(arch: str, n_requests: int = 12, batch: int = 4):
    cfg = reduced(get_arch(arch))
    engine = ServeEngine(cfg, batch_size=batch, cache_len=256)
    rng = np.random.default_rng(0)
    for rid in range(n_requests):
        engine.submit(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 32))).astype(np.int32),
                max_new_tokens=int(rng.integers(8, 24)),
            )
        )
    stats = engine.run()
    print(f"{arch:<24} completed {stats['completed']:>3}/{n_requests}   "
          f"tokens {stats['generated_tokens']:>4}   "
          f"slot-util {stats['slot_utilization']:.1%}   "
          f"{stats['tokens_per_sec']:.1f} tok/s")


def main():
    print(f"{'arch':<24} {'results'}")
    for arch in ("mamba2-370m", "qwen2.5-32b", "olmoe-1b-7b"):
        demo(arch)
    print("\n(reduced configs on CPU; decode_32k/long_500k dry-run shapes prove"
          "\n the full configs lower on the production mesh)")


if __name__ == "__main__":
    main()
