"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig, INPUT_SHAPES, SHAPES_BY_NAME, reduced
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from repro.configs.deepseek_7b import CONFIG as DEEPSEEK_7B
from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA_1_5_LARGE_398B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.qwen2_5_32b import CONFIG as QWEN2_5_32B

ARCHITECTURES = {
    c.name: c
    for c in (
        PALIGEMMA_3B,
        DEEPSEEK_MOE_16B,
        DEEPSEEK_7B,
        MINITRON_8B,
        JAMBA_1_5_LARGE_398B,
        DEEPSEEK_67B,
        MAMBA2_370M,
        OLMOE_1B_7B,
        WHISPER_TINY,
        QWEN2_5_32B,
    )
}

# sliding-window used for the long_500k adaptation of full-attention archs
LONG_CONTEXT_WINDOW = 8192


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def arch_for_shape(cfg: ArchConfig, shape: ShapeConfig) -> ArchConfig:
    """Shape-specific adaptation: long_500k forces sliding-window attention
    on attention-bearing archs (DESIGN.md §4); SSM needs nothing."""
    if shape.name == "long_500k" and cfg.family != "ssm" and cfg.num_heads:
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "INPUT_SHAPES",
    "ARCHITECTURES",
    "LONG_CONTEXT_WINDOW",
    "get_arch",
    "get_shape",
    "arch_for_shape",
    "reduced",
]
