"""Architecture config schema + input-shape suite.

Every assigned architecture gets one ``<id>.py`` in this package exporting
``CONFIG`` (exact assigned numbers, source cited) and the framework builds the
model from it.  ``reduced()`` derives the CPU smoke-test variant (<=2 layers,
d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["ArchConfig", "ShapeConfig", "INPUT_SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (assigned d_ff for moe archs)
    moe_every: int = 1  # MoE FFN every k-th layer (jamba: 2)
    first_dense_layers: int = 0  # deepseek-moe: layer 0 is dense
    dense_d_ff: int = 0  # FFN dim of the dense layers in a MoE stack
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4
    ssd_chunk: int = 256
    attn_period: int = 0  # hybrid: one attention layer per `attn_period` layers
    attn_offset: int = 0  # position of the attn layer within the period
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    gqa_repeat_kv: bool = False  # §Perf: Megatron-style kv repeat (attention.py)
    use_kernels: bool = False  # Pallas kernels (flash attention / SSD) in layers
    sliding_window: int = 0  # 0 = full attention; >0 = window (long_500k variant)
    # --- enc-dec (audio) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_len: int = 0  # whisper: 1500 frames
    # --- vlm ---
    is_prefix_lm: bool = False
    num_prefix_tokens: int = 0  # paligemma: 256 image tokens
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "swiglu"  # swiglu | gelu
    use_rope: bool = True
    optimizer: str = "adamw"  # adamw | adafactor (jamba-398b: memory)
    remat: str = "full"  # full | dots | none  (activation checkpoint policy)
    loss_chunks: int = 8

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, v = self.d_model, self.vocab_size
        n_emb = v * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads * 2 + self.num_kv_heads * 2)
        dense_ffn = 3 * d * self.d_ff if self.act == "swiglu" else 2 * d * self.d_ff
        moe_ffn = (
            self.num_experts * 3 * d * self.moe_d_ff
            + self.num_shared_experts * 3 * d * self.moe_d_ff
            + d * self.num_experts
        )
        mamba = (
            d * (self.d_inner * 2 + 2 * self.ssm_groups * self.ssm_state + self.ssm_heads)
            + self.d_inner * d
        )
        total = n_emb
        for layer in range(self.num_layers):
            if self.family in ("ssm",):
                total += mamba
                continue
            is_attn = True
            if self.attn_period:
                is_attn = layer % self.attn_period == self.attn_offset
            total += attn if is_attn else (mamba if self.family == "hybrid" else 0)
            if self.num_experts and layer >= self.first_dense_layers and (
                (layer - self.first_dense_layers) % self.moe_every == 0 or self.moe_every == 1
            ):
                total += moe_ffn
            elif self.family != "ssm":
                total += dense_ffn if not self.num_experts else 3 * d * (self.dense_d_ff or self.d_ff)
        if self.is_encoder_decoder:
            enc = self.num_encoder_layers * (attn + dense_ffn)
            total += enc + self.num_layers * attn  # cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        inactive_per_moe_layer = (
            (self.num_experts - self.experts_per_token) * 3 * d * self.moe_d_ff
        )
        n_moe_layers = sum(
            1
            for layer in range(self.num_layers)
            if layer >= self.first_dense_layers
            and ((layer - self.first_dense_layers) % self.moe_every == 0 or self.moe_every == 1)
        )
        return int(full - n_moe_layers * inactive_per_moe_layer)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts, small vocab."""
    d_model = min(cfg.d_model, 256)
    num_heads = min(cfg.num_heads, 4)
    num_kv = max(1, min(cfg.num_kv_heads, num_heads, 2))
    num_layers = min(cfg.num_layers, 2 if not cfg.attn_period else cfg.attn_period)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        dense_d_ff=min(cfg.dense_d_ff, 512) if cfg.dense_d_ff else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_len=min(cfg.encoder_len, 64) if cfg.encoder_len else 0,
        num_prefix_tokens=min(cfg.num_prefix_tokens, 16) if cfg.num_prefix_tokens else 0,
        ssd_chunk=32,
        loss_chunks=1,
        attn_period=min(cfg.attn_period, num_layers) if cfg.attn_period else 0,
        attn_offset=min(cfg.attn_offset, num_layers - 1) if cfg.attn_period else 0,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
    )
