"""DeepSeek-7B [arXiv:2401.02954] — llama architecture.

30L, d_model 4096, 32 heads (kv=32), d_ff 11008, vocab 102400.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    source="arXiv:2401.02954",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
)
