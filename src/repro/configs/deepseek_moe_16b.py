"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained experts.

28L, d_model 2048, 16 heads (MHA kv=16), expert d_ff 1408, vocab 102400,
64 routed experts top-6 + 2 shared experts, first layer dense (d_ff 10944).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    dense_d_ff=10944,
    vocab_size=102400,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    first_dense_layers=1,
)
