"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887].

72L, d_model 8192, 64 heads (GQA kv=8), d_ff 24576, vocab 65536;
Mamba:attention 7:1 (one attn layer per period of 8, at offset 4);
MoE every 2 layers: 16 experts top-2.  No RoPE (mamba provides position).
Adafactor optimizer (Adam state would exceed per-chip HBM — DESIGN.md §5).

NOTE: mixer SSM implemented as mamba2-style SSD (d_state 128, head_dim 64);
Jamba ships mamba1 (d_state 16) — recorded as a TPU-native adaptation.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    moe_d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_period=8,
    attn_offset=4,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=8,
    use_rope=False,
    optimizer="adafactor",
)
