"""Mamba2-370M [arXiv:2405.21060] — SSD, attention-free.

48L, d_model 1024, vocab 50280, d_state 128, head_dim 64, expand 2
(d_inner 2048 -> 32 SSD heads), conv kernel 4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    use_rope=False,
)
