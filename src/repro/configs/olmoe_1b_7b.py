"""OLMoE-1B-7B [arXiv:2409.02060].

16L, d_model 2048, 16 heads (kv=16), expert d_ff 1024, vocab 50304,
64 experts top-8, no shared experts, all layers MoE.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    moe_d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
)
