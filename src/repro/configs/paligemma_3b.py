"""PaliGemma-3B language backbone [arXiv:2407.07726] — SigLIP tower stubbed.

Gemma-2B decoder: 18L, d_model 2048, 8 heads with MQA (kv=1), head_dim 256,
d_ff 16384, vocab 257216; prefix-LM over 256 image tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    is_prefix_lm=True,
    num_prefix_tokens=256,
    tie_embeddings=True,
)
