"""Whisper-tiny [arXiv:2212.04356] — enc-dec; conv frontend stubbed.

4L encoder + 4L decoder, d_model 384, 6 heads (kv=6), d_ff 1536,
vocab 51865, encoder length 1500 frames (stub supplies frame embeddings).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    num_encoder_layers=4,
    encoder_len=1500,
    use_rope=False,
    act="gelu",
)
