"""Core contribution of the paper: random-walk transition design + MHLJ."""
from repro.core.graphs import (
    Graph,
    CSRGraph,
    BucketedCSRGraph,
    DegreeBucket,
    ring,
    grid2d,
    watts_strogatz,
    erdos_renyi,
    star,
    complete,
    expander,
    barabasi_albert,
    sbm,
    dumbbell,
    lollipop,
    from_adjacency,
    from_edges,
)
from repro.core.transition import (
    MHLJParams,
    simple_rw,
    mh,
    mh_uniform,
    mh_importance,
    mhlj,
    row_probs_padded,
    simple_rw_rows,
    mh_uniform_rows,
    mh_importance_rows,
    simple_rw_rows_bucketed,
    mh_uniform_rows_bucketed,
    mh_importance_rows_bucketed,
)
from repro.core.levy import (
    trunc_geom_pmf,
    levy_matrix,
    levy_matrix_chained,
    expected_transitions_per_update,
    remark1_bound,
)
from repro.core.importance import (
    linear_regression_lipschitz,
    logistic_regression_lipschitz,
    importance_distribution,
    importance_weights,
)
from repro.core.engine import (
    LAYOUTS,
    BACKEND_ENV_VAR,
    WalkEngine,
    p_is_rows,
    p_is_rows_block,
    mh_cdf_invert,
    levy_jump_batched,
    bucket_capacities,
    compact_plan,
    scatter_compacted,
)
from repro.core.walk import (
    graph_tensors,
    walk_markov,
    walk_mhlj,
    walk_markov_batched,
    walk_mhlj_batched,
)
from repro.core import mixing, entrapment, theory, schedules

__all__ = [
    "Graph", "CSRGraph", "BucketedCSRGraph", "DegreeBucket", "ring",
    "grid2d", "watts_strogatz", "erdos_renyi",
    "star", "complete", "expander", "barabasi_albert", "sbm", "dumbbell",
    "lollipop", "from_adjacency", "from_edges",
    "MHLJParams", "simple_rw", "mh", "mh_uniform", "mh_importance", "mhlj",
    "row_probs_padded", "simple_rw_rows", "mh_uniform_rows",
    "mh_importance_rows", "simple_rw_rows_bucketed",
    "mh_uniform_rows_bucketed", "mh_importance_rows_bucketed",
    "trunc_geom_pmf", "levy_matrix", "levy_matrix_chained",
    "expected_transitions_per_update", "remark1_bound",
    "linear_regression_lipschitz", "logistic_regression_lipschitz",
    "importance_distribution", "importance_weights",
    "LAYOUTS", "BACKEND_ENV_VAR", "WalkEngine", "p_is_rows",
    "p_is_rows_block", "mh_cdf_invert", "levy_jump_batched",
    "bucket_capacities", "compact_plan", "scatter_compacted",
    "graph_tensors", "walk_markov", "walk_mhlj", "walk_markov_batched",
    "walk_mhlj_batched",
    "mixing", "entrapment", "theory", "schedules",
]
