"""Batched MHLJ walk engine — THE single implementation of Algorithm 1.

Every consumer of the paper's MHLJ transition (the §II.C simulators in
``core.walk``, the regression trainer ``walk_sgd.trainer``, the pjit LLM
orchestrator ``walk_sgd.llm_trainer.WalkContext``, the multi-walk runner
``walk_sgd.multi_walk`` and the ``benchmarks/`` entry points) routes through
this module, so the chain law that Theorem 1 attaches to is sampled by
exactly one piece of code.

Design
------
A transition for W parallel walks consumes a pre-drawn uniform block of
shape ``(W, 3 + r)`` with slot layout::

    [jump_flag, mh, distance, hop_1 .. hop_r]
     U_JUMP     U_MH  U_DIST   U_HOP0 ..

Each stochastic decision owns its own slot (the seed implementations shared
one key/uniform between the MH draw and the jump machinery — benign for the
marginal law because the branches are exclusive, but wrong as documented and
a trap for anything consuming both branches).  The Bernoulli(p_J) jump
decision is resolved *outside* the backends — slot ``U_JUMP`` arrives as a
{0.0, 1.0} flag — which is what lets ``p_j`` be a traced scalar (Fig 6
annealing schedules) while the Pallas kernel keeps only truly-static
compile-time parameters.

Backends (identical law, bitwise-identical outputs given the same key):

* ``"scan"``   — pure JAX ``vmap`` over walks; also the oracle for kernel
  tests.  Gathers only the W active P_IS rows, so it stays cheap for
  single-walk training loops.
* ``"pallas"`` — the ``kernels/walk_transition`` TPU kernels; falls back to
  ``interpret=True`` off-TPU.  Row handling is governed by ``layout``:
  ``"sparse"`` (default) gathers only the W active ``[block_w, max_deg]``
  neighbor tiles and runs the MH CDF inversion in
  ``walk_transition_sparse`` with the Lévy hop chain as O(W) XLA gathers —
  working set O(W·max_deg + E), so 100k-node graphs fit; ``"dense"`` keeps
  the original full-table-in-VMEM kernel for parity testing at
  orchestration scale (n <= a few thousand).
* ``"auto"``   — pallas on TPU, scan elsewhere.

P_IS rows (Eq. 7) come either precomputed (``row_probs`` from
``transition.row_probs_padded``) or on the fly from a live Lipschitz vector
(the online-estimator path of ``llm_trainer``) via :func:`p_is_rows`, which
needs only local information (deg(v), deg(u), L_v, L_u).

Remark-1 accounting: every step returns the physical hop count taken per
walk (1 for an MH move, d for a Lévy jump).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.levy import trunc_geom_icdf

__all__ = [
    "U_JUMP",
    "U_MH",
    "U_DIST",
    "U_HOP0",
    "num_uniforms",
    "p_is_rows",
    "mhlj_transition_math",
    "combine_mh_jump",
    "levy_jump_batched",
    "WalkEngine",
]

# Uniform-block slot layout (shared with the Pallas kernel).
U_JUMP, U_MH, U_DIST, U_HOP0 = 0, 1, 2, 3


def num_uniforms(r: int) -> int:
    """Columns of the pre-drawn uniform block for jump range ``r``."""
    return U_HOP0 + r


def p_is_rows(
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    lipschitz: jnp.ndarray,
    nodes: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """P_IS rows of Eq. (7) over padded neighbor lists, from local info only.

    P(v,u) = min{1/deg(v), L_u / (deg(u) L_v)} for true neighbors u != v;
    leftover mass goes to staying (spread over the self/pad slots, which all
    alias node v, so the sampled law is exact).

    ``nodes=None`` returns the full (n, max_deg) table (Pallas backend /
    precomputation); ``nodes=(W,)`` returns only those W rows (scan backend).
    """
    if nodes is None:
        nodes = jnp.arange(neighbors.shape[0], dtype=jnp.int32)
    nbrs = neighbors[nodes]  # (W, max_deg)
    deg_v = degrees[nodes].astype(jnp.float32)[:, None]
    deg_u = degrees[nbrs].astype(jnp.float32)
    l_v = lipschitz[nodes][:, None]
    l_u = lipschitz[nbrs]
    move = jnp.minimum(1.0 / deg_v, l_u / (deg_u * l_v))
    is_self = nbrs == nodes[:, None]
    move = jnp.where(is_self, 0.0, move)
    p_stay = 1.0 - move.sum(axis=-1, keepdims=True)
    n_self = jnp.maximum(is_self.sum(axis=-1, keepdims=True), 1)
    probs = jnp.where(is_self, p_stay / n_self, move)
    return jnp.maximum(probs, 0.0)


def mhlj_transition_math(
    nodes: jnp.ndarray,  # (W,) int32 current node per walk
    rows: jnp.ndarray,  # (W, max_deg) P_IS row per walk (padded)
    neighbors: jnp.ndarray,  # (n, max_deg) int32, pads = self id
    degrees: jnp.ndarray,  # (n,) int32
    uniforms: jnp.ndarray,  # (W, 3 + r); slot U_JUMP is a {0,1} flag
    p_d: float,
    r: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One Algorithm-1 transition for W walks — the canonical math.

    The MH-IS move is a per-walk CDF inversion (vmapped); the Lévy branch
    is :func:`levy_jump_batched`, shared verbatim with the sparse Pallas
    path so the jump law exists exactly once in pure JAX.  The Pallas
    kernels mirror this arithmetic (same CDF inversion, same
    :func:`trunc_geom_icdf`, same hop-index formula), and the parity tests
    assert bitwise-equal outputs given the same uniforms.

    Returns ``(next_nodes, hops)``, both ``(W,)`` int32; ``hops`` is the
    Remark-1 physical transition count (1 for MH, d for a jump).
    """
    max_deg = neighbors.shape[1]

    def one_walk_mh(v, prow, u):
        # MH-IS move: CDF inversion over the padded P_IS row.
        cdf = jnp.cumsum(prow)
        idx = jnp.sum((cdf < u[U_MH] * cdf[-1]).astype(jnp.int32))
        idx = jnp.minimum(idx, max_deg - 1)
        return neighbors[v, idx]

    v_mh = jax.vmap(one_walk_mh)(nodes, rows, uniforms)
    v_jump, d = levy_jump_batched(nodes, uniforms, neighbors, degrees, p_d, r)
    return combine_mh_jump(v_mh, v_jump, d, uniforms)


def combine_mh_jump(
    v_mh: jnp.ndarray, v_jump: jnp.ndarray, d: jnp.ndarray, uniforms: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Resolve the J~Ber(p_J) branch per walk — THE jump/MH combine.

    Selects the jump or MH destination from the ``U_JUMP`` flag and
    produces the Remark-1 hop count (1 for MH, d for a jump).  Shared by
    every pure-JAX path (scan and sparse Pallas) so the branch convention
    exists exactly once; the dense Pallas kernel mirrors it per walk.
    """
    do_jump = uniforms[:, U_JUMP] > 0.5
    v_next = jnp.where(do_jump, v_jump, v_mh)
    hops = jnp.where(do_jump, d, jnp.int32(1))
    return v_next, hops


def levy_jump_batched(
    nodes: jnp.ndarray,  # (W,) int32
    uniforms: jnp.ndarray,  # (W, 3 + r)
    neighbors: jnp.ndarray,  # (n, max_deg) int32
    degrees: jnp.ndarray,  # (n,) int32
    p_d: float,
    r: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The Lévy branch of Algorithm 1 for W walks — THE jump implementation.

    d ~ TruncGeom(p_d, r) then d uniform hops, expressed as W-wide XLA
    gathers (no dense table, no per-walk scan).  Consumed by both the scan
    backend (via :func:`mhlj_transition_math`) and the sparse Pallas path;
    the dense Pallas kernel mirrors this arithmetic per walk.  Returns
    ``(v_jump, d)``.
    """
    d = trunc_geom_icdf(uniforms[:, U_DIST], p_d, r)

    def hop(i, v_cur):
        deg = degrees[v_cur]
        hop_idx = jnp.minimum(
            (uniforms[:, U_HOP0 + i] * deg.astype(jnp.float32)).astype(jnp.int32),
            deg - 1,
        )
        v_new = neighbors[v_cur, hop_idx]
        return jnp.where(i < d, v_new, v_cur)

    v_jump = jax.lax.fori_loop(0, r, hop, nodes)
    return v_jump, d


@dataclasses.dataclass(frozen=True, eq=False)
class WalkEngine:
    """Batched MHLJ sampler for W parallel walks with pluggable backends.

    Construct once (``from_graph``) and call :meth:`step` inside jitted
    training loops or :meth:`run` for whole trajectories.  All fields are
    device arrays or static python scalars, so instances may also be built
    inside a trace (the regression trainer does).
    """

    neighbors: jnp.ndarray  # (n, max_deg) int32, pads = self id
    degrees: jnp.ndarray  # (n,) int32
    p_j: Union[float, jnp.ndarray] = 0.1  # default jump prob (overridable per call)
    p_d: float = 0.5
    r: int = 3
    row_probs: Optional[jnp.ndarray] = None  # (n, max_deg) precomputed P_IS
    backend: str = "auto"  # "auto" | "scan" | "pallas"
    layout: str = "sparse"  # "sparse" | "dense" — pallas-backend row handling
    block_w: int = 256
    interpret: Optional[bool] = None  # None = auto (interpret off-TPU)

    @classmethod
    def from_graph(
        cls,
        graph,
        params,
        *,
        row_probs: Optional[jnp.ndarray] = None,
        lipschitz: Optional[jnp.ndarray] = None,
        backend: str = "auto",
        layout: str = "sparse",
        block_w: int = 256,
        interpret: Optional[bool] = None,
    ) -> "WalkEngine":
        """Engine from a ``core.graphs.Graph`` or ``CSRGraph`` + ``MHLJParams``.

        Both graph classes expose the same padded ``neighbors``/``degrees``
        tensors, so large CSR graphs plug in with no dense adjacency ever
        materialized.  Row source precedence: explicit ``row_probs`` table,
        else a table precomputed from a *static* ``lipschitz`` vector, else
        live rows from the ``lipschitz=`` argument of :meth:`step` /
        :meth:`run`.
        """
        neighbors = jnp.asarray(graph.neighbors)
        degrees = jnp.asarray(graph.degrees)
        if row_probs is None and lipschitz is not None:
            row_probs = p_is_rows(
                neighbors, degrees, jnp.asarray(lipschitz, jnp.float32)
            )
        return cls(
            neighbors=neighbors,
            degrees=degrees,
            p_j=params.p_j,
            p_d=params.p_d,
            r=params.r,
            row_probs=None if row_probs is None else jnp.asarray(row_probs),
            backend=backend,
            layout=layout,
            block_w=block_w,
            interpret=interpret,
        )

    def __post_init__(self):
        if self.backend not in ("auto", "scan", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.layout not in ("sparse", "dense"):
            raise ValueError(f"unknown layout {self.layout!r}")

    # -- backend resolution -------------------------------------------------

    @property
    def resolved_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        return "pallas" if jax.default_backend() == "tpu" else "scan"

    @property
    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    # -- P_IS row plumbing --------------------------------------------------

    def rows_table(self, lipschitz: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Full (n, max_deg) P_IS table (precomputed or live Eq.-7).

        Only the dense layout consumes this; the sparse layout touches
        :meth:`rows_for` exclusively, so an engine with live rows never
        builds the whole table.
        """
        if self.row_probs is not None:
            return self.row_probs
        if lipschitz is None:
            raise ValueError(
                "engine has no precomputed row_probs; pass lipschitz= for "
                "live Eq. (7) rows"
            )
        return p_is_rows(self.neighbors, self.degrees, lipschitz)

    def rows_for(
        self, nodes: jnp.ndarray, lipschitz: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        """P_IS rows for the W active walk positions only."""
        if self.row_probs is not None:
            return self.row_probs[nodes]
        if lipschitz is None:
            raise ValueError(
                "engine has no precomputed row_probs; pass lipschitz= for "
                "live Eq. (7) rows"
            )
        return p_is_rows(self.neighbors, self.degrees, lipschitz, nodes=nodes)

    # -- the transition -----------------------------------------------------

    def step(
        self,
        key: jax.Array,
        nodes: jnp.ndarray,
        *,
        p_j: Optional[Union[float, jnp.ndarray]] = None,
        lipschitz: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """One batched MHLJ transition.

        Args:
          key: PRNG key (consumed wholly by this step).
          nodes: (W,) int32 current positions, or a scalar for one walk.
          p_j: jump probability override (python float or traced scalar);
            defaults to the engine's ``p_j``.
          lipschitz: (n,) live Lipschitz vector when the engine has no
            precomputed rows.

        Returns:
          (next_nodes, hops) matching the shape of ``nodes``.
        """
        nodes = jnp.asarray(nodes, jnp.int32)
        squeeze = nodes.ndim == 0
        if squeeze:
            nodes = nodes[None]
        p_j_t = self.p_j if p_j is None else p_j
        u = jax.random.uniform(
            key, (nodes.shape[0], num_uniforms(self.r)), jnp.float32
        )
        flag = (u[:, U_JUMP] < p_j_t).astype(jnp.float32)
        u = u.at[:, U_JUMP].set(flag)

        if self.resolved_backend == "pallas" and self.layout == "dense":
            # local import: kernels package imports back into this module
            from repro.kernels.walk_transition.kernel import walk_transition

            nxt, hops = walk_transition(
                nodes,
                self.rows_table(lipschitz),
                self.neighbors,
                self.degrees,
                u,
                p_d=self.p_d,
                r=self.r,
                block_w=self.block_w,
                interpret=self.resolved_interpret,
            )
        elif self.resolved_backend == "pallas":
            # sparse layout: gather only the W active rows/neighbor tiles —
            # O(W·max_deg) working set, never the (n, max_deg) table
            from repro.kernels.walk_transition.kernel import (
                walk_transition_sparse,
            )

            v_mh = walk_transition_sparse(
                self.rows_for(nodes, lipschitz),
                self.neighbors[nodes],
                u[:, U_MH],
                block_w=self.block_w,
                interpret=self.resolved_interpret,
            )
            v_jump, d = levy_jump_batched(
                nodes, u, self.neighbors, self.degrees, self.p_d, self.r
            )
            nxt, hops = combine_mh_jump(v_mh, v_jump, d, u)
        else:
            nxt, hops = mhlj_transition_math(
                nodes,
                self.rows_for(nodes, lipschitz),
                self.neighbors,
                self.degrees,
                u,
                self.p_d,
                self.r,
            )
        if squeeze:
            return nxt[0], hops[0]
        return nxt, hops

    def run(
        self,
        key: jax.Array,
        v0s: jnp.ndarray,
        num_steps: int,
        *,
        p_j: Optional[Union[float, jnp.ndarray]] = None,
        lipschitz: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Whole trajectories for W walks (Algorithm 1's update sequence).

        ``p_j`` may be a scalar or a (num_steps,) schedule (Fig 6 annealing).

        Returns:
          update_nodes: (W, num_steps) int32 — element t is the node holding
            the model when update t runs (the first update runs at v0).
          hops: (W, num_steps) int32 — Remark-1 physical transitions taken
            after update t.
          Scalar ``v0s`` drops the leading walk axis.
        """
        v0s = jnp.asarray(v0s, jnp.int32)
        squeeze = v0s.ndim == 0
        if squeeze:
            v0s = v0s[None]
        p_j_base = self.p_j if p_j is None else p_j
        p_j_sched = jnp.broadcast_to(
            jnp.asarray(p_j_base, jnp.float32), (num_steps,)
        )
        keys = jax.random.split(key, num_steps)

        def body(v, xs):
            k, pj = xs
            v_next, hops = self.step(k, v, p_j=pj, lipschitz=lipschitz)
            return v_next, (v, hops)

        _, (update_nodes, hops) = jax.lax.scan(body, v0s, (keys, p_j_sched))
        update_nodes = update_nodes.T  # (T, W) -> (W, T)
        hops = hops.T
        if squeeze:
            return update_nodes[0], hops[0]
        return update_nodes, hops
