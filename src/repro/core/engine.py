"""Batched MHLJ walk engine — THE single implementation of Algorithm 1.

Every consumer of the paper's MHLJ transition (the §II.C simulators in
``core.walk``, the regression trainer ``walk_sgd.trainer``, the pjit LLM
orchestrator ``walk_sgd.llm_trainer.WalkContext``, the multi-walk runner
``walk_sgd.multi_walk`` and the ``benchmarks/`` entry points) routes through
this module, so the chain law that Theorem 1 attaches to is sampled by
exactly one piece of code.

Design
------
A transition for W parallel walks consumes a pre-drawn uniform block of
shape ``(W, 3 + r)`` with slot layout::

    [jump_flag, mh, distance, hop_1 .. hop_r]
     U_JUMP     U_MH  U_DIST   U_HOP0 ..

Each stochastic decision owns its own slot (the seed implementations shared
one key/uniform between the MH draw and the jump machinery — benign for the
marginal law because the branches are exclusive, but wrong as documented and
a trap for anything consuming both branches).  The Bernoulli(p_J) jump
decision is resolved *outside* the backends — slot ``U_JUMP`` arrives as a
{0.0, 1.0} flag — which is what lets ``p_j`` be a traced scalar (Fig 6
annealing schedules) while the Pallas kernel keeps only truly-static
compile-time parameters.

Backends (identical law, bitwise-identical outputs given the same key):

* ``"scan"``   — pure JAX ``vmap`` over walks; also the oracle for kernel
  tests.  Gathers only the W active P_IS rows, so it stays cheap for
  single-walk training loops.
* ``"pallas"`` — the ``kernels/walk_transition`` TPU kernels; falls back to
  ``interpret=True`` off-TPU.  Row handling is governed by ``layout``:
  ``"sparse"`` (default) gathers only the W active ``[block_w, max_deg]``
  neighbor tiles and runs the MH CDF inversion in
  ``walk_transition_sparse`` with the Lévy hop chain as O(W) XLA gathers —
  working set O(W·max_deg + E), so 100k-node graphs fit; ``"bucketed"``
  dispatches the same tile kernel per degree bucket of a
  ``graphs.BucketedCSRGraph`` (geometric width ladder, ``bucket_factor``
  2 or 4) with the Lévy hops gathered straight from the CSR arrays,
  dropping the resident tables from O(n·max_deg) to
  O(E + Σ_b n_b·width_b) — the hub-heavy-graph path.  By default the
  bucketed dispatch is *compacted* per step: a stable sort groups the W
  walk indices by bucket id, each bucket's tile pass runs at a static
  capacity (:func:`bucket_capacities`) instead of all W lanes, and
  results scatter back to walk order (:func:`scatter_compacted`) — so
  per-step MH work is Σ_b cap_b·width_b rather than W·Σ_b width_b, with
  a ``lax.cond`` fallback to the full dispatch on capacity overflow;
  ``"ragged"`` is the true-degree layout — resident row state is one flat
  per-edge CDF buffer aligned with the CSR ``indices`` (exactly O(E), no
  padded and no per-bucket table), the MH inversion is a binary search of
  each walk's own CDF segment (:func:`ragged_mh_invert`, O(W·log max_deg)
  per step instead of O(W·max_deg)), and the pallas path is one fused
  scalar-prefetch kernel per walk tile
  (``kernels.walk_transition.walk_transition_ragged``) that performs the
  inversion, the r-hop Lévy gather and the jump/MH combine in a single
  pass — no bucket ladder, no compaction argsort/scatter, no overflow
  ``lax.cond``, and none of the O(W) XLA gather round-trips the other
  sparse layouts leave between kernel and engine; ``"dense"`` keeps the
  original full-table-in-VMEM kernel for parity testing at orchestration
  scale (n <= a few thousand).  The registered layouts live in
  :data:`LAYOUTS`.
* ``"auto"``   — pallas on TPU, scan elsewhere; overridable via the
  ``REPRO_BACKEND`` environment variable (:data:`BACKEND_ENV_VAR`), which
  is how the CI matrix forces each backend.  The scan backend also
  services the bucketed layout (pure-jnp per-bucket dispatch, compacted
  the same way), so the bucketed path runs everywhere the engine runs.

P_IS rows (Eq. 7) come either precomputed (``row_probs`` from
``transition.row_probs_padded`` / ``transition.mh_importance_rows``, or a
per-bucket tuple from ``transition.mh_importance_rows_bucketed``) or on
the fly from a live Lipschitz vector (the online-estimator path of
``llm_trainer``) via :func:`p_is_rows`, which needs only local information
(deg(v), deg(u), L_v, L_u).  Rows follow the padded-row convention of
``core.transition``: every true neighbor slot (including the single self
slot) carries its probability, leftover MH mass lands on the self slot,
pads carry exactly 0.  Because pads are exact zeros, a row truncated to
its degree bucket's width has the same CDF prefix bit for bit — the
property that makes ``layout="bucketed"`` agree with the other layouts
per key (see docs/layouts.md).

Remark-1 accounting: every step returns the physical hop count taken per
walk (1 for an MH move, d for a Lévy jump).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core.levy import trunc_geom_icdf

__all__ = [
    "U_JUMP",
    "U_MH",
    "U_DIST",
    "U_HOP0",
    "LAYOUTS",
    "BACKEND_ENV_VAR",
    "num_uniforms",
    "p_is_rows",
    "p_is_rows_block",
    "mh_cdf_invert",
    "ragged_edge_cdf",
    "ragged_edge_cdf_update",
    "ragged_mh_invert",
    "combine_bucketed",
    "bucket_capacities",
    "compact_plan",
    "scatter_compacted",
    "mhlj_transition_math",
    "combine_mh_jump",
    "levy_jump_batched",
    "WalkEngine",
]

# Uniform-block slot layout (shared with the Pallas kernel).
U_JUMP, U_MH, U_DIST, U_HOP0 = 0, 1, 2, 3

# Registered row layouts of the pallas backend.  Anything listed here is
# exercised by the benchmark anti-rot tier (benchmarks/run.py --smoke), so a
# new layout cannot silently rot out of tier-1 coverage.
LAYOUTS = ("sparse", "dense", "bucketed", "ragged")

# Environment override for backend="auto": set REPRO_BACKEND=scan|pallas to
# pin the resolved backend (off-TPU the pallas backend runs interpret mode).
# This is what the CI matrix flips to run tier-1 under both backends.
BACKEND_ENV_VAR = "REPRO_BACKEND"


def num_uniforms(r: int) -> int:
    """Columns of the pre-drawn uniform block for jump range ``r``."""
    return U_HOP0 + r


def p_is_rows(
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    lipschitz: jnp.ndarray,
    nodes: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """P_IS rows of Eq. (7) over padded neighbor lists, from local info only.

    P(v,u) = min{1/deg(v), L_u / (deg(u) L_v)} for true neighbors u != v;
    leftover mass goes to the single true self slot, pads carry exactly 0
    (the shared padded-row convention of ``core.transition``, which keeps
    bucket-width row truncations bitwise-exact).

    ``nodes=None`` returns the full (n, max_deg) table (Pallas backend /
    precomputation); ``nodes=(W,)`` returns only those W rows (scan backend).
    """
    if nodes is None:
        nodes = jnp.arange(neighbors.shape[0], dtype=jnp.int32)
    return p_is_rows_block(
        neighbors[nodes], nodes, degrees[nodes], degrees, lipschitz
    )


def p_is_rows_block(
    nbrs: jnp.ndarray,  # (W, width) padded neighbor block
    self_ids: jnp.ndarray,  # (W,) owning node id per row
    deg_v: jnp.ndarray,  # (W,) true degree per row
    degrees: jnp.ndarray,  # (n,) full degree vector (neighbor lookups)
    lipschitz: jnp.ndarray,  # (n,)
) -> jnp.ndarray:
    """Eq.-7 rows on an arbitrary padded neighbor block — THE live-row math.

    Shared by the full-width path (:func:`p_is_rows`) and the per-bucket
    dispatch of ``layout="bucketed"``; ``width`` may be anything ≥ the
    rows' true degrees.  Pads carry exactly 0 and leftover mass lands on
    the self slot, mirroring ``transition._mh_rows_block``.
    """
    deg_vf = deg_v.astype(jnp.float32)[:, None]
    deg_u = degrees[nbrs].astype(jnp.float32)
    l_v = lipschitz[self_ids][:, None]
    l_u = lipschitz[nbrs]
    move = jnp.minimum(1.0 / deg_vf, l_u / (deg_u * l_v))
    is_pad = (
        jax.lax.broadcasted_iota(jnp.int32, nbrs.shape, 1)
        >= deg_v.astype(jnp.int32)[:, None]
    )
    is_self = (nbrs == self_ids[:, None]) & ~is_pad
    move = jnp.where(is_self | is_pad, 0.0, move)
    p_stay = 1.0 - move.sum(axis=-1, keepdims=True)
    probs = jnp.where(is_self, p_stay, move)
    return jnp.maximum(probs, 0.0)


def mh_cdf_invert(
    rows: jnp.ndarray,  # (W, width) padded probability rows
    neigh_rows: jnp.ndarray,  # (W, width) matching padded neighbor rows
    u_mh: jnp.ndarray,  # (W,) the U_MH uniform per walk
) -> jnp.ndarray:
    """THE MH-move CDF inversion over padded rows; returns ``v_mh`` (W,).

    Vectorized over any row width (``max_deg`` for the sparse/scan paths, a
    bucket width for the bucketed dispatch).  The Pallas tile kernel
    (``walk_transition_sparse``) and the dense kernel's per-walk body
    mirror this arithmetic statement for statement, and the parity tests
    assert bitwise-equal outputs.
    """
    width = rows.shape[1]
    cdf = jnp.cumsum(rows, axis=1)
    idx = jnp.sum(
        (cdf < u_mh[:, None] * cdf[:, -1:]).astype(jnp.int32), axis=1
    )
    idx = jnp.minimum(idx, width - 1)
    return jnp.take_along_axis(neigh_rows, idx[:, None], axis=1)[:, 0]


def ragged_edge_cdf(
    indptr,
    indices,
    degrees,
    *,
    row_probs=None,
    lipschitz=None,
    chunk_rows: Optional[int] = None,
    width: Optional[int] = None,
) -> jnp.ndarray:
    """THE flat per-edge CDF builder of the ragged layout — (nnz,) float32.

    Entry ``indptr[v] + k`` holds the inclusive CDF prefix of row v at
    slot k, bit-for-bit equal to ``jnp.cumsum(padded_row)[k]`` — the value
    :func:`mh_cdf_invert` compares against on the padded layouts.  That
    exactness is free, not assumed: rows are materialized in bounded-size
    chunks at the **full** ``max_deg`` width (the identical
    :func:`p_is_rows_block` / cumsum ops the other layouts run) and the
    pad columns — exact zeros that never move a CDF prefix — are then
    dropped by ``graphs.flat_edge_values``.  No O(n·max_deg) array ever
    exists; transient memory is O(chunk·max_deg) and the resident result
    is exactly O(E).

    Row source: ``row_probs`` as an (n, max_deg) padded table, a flat
    (nnz,) probability buffer (``transition.mh_importance_rows_ragged``
    et al.), or live Eq.-7 rows from a ``lipschitz`` vector.  Host-side
    only (chunking is a python loop) — the engine builds this once at
    construction, never per step.

    ``width`` pins the padded materialization width (default: the
    graph's ``max_deg``).  The bits of a row's CDF prefix **depend on
    that width**: XLA's CPU reductions lane-split by row length, so the
    same probabilities summed at width 29 vs 600 differ in the last
    ulp.  Incremental churn therefore rebuilds touched rows at the
    *engine's recorded build width* (``WalkEngine.cdf_width``), not the
    churned graph's possibly-different max degree — the only way copied
    untouched segments and freshly patched rows can share one bitwise
    story.  A ``width`` below the actual max degree raises.
    """
    from repro.core.graphs import (
        _pad_neighbor_lists,
        _ragged_row_chunks,
        flat_edge_values,
    )

    indptr_np = np.asarray(indptr, dtype=np.int64)
    indices_np = np.asarray(indices)
    deg_np = np.asarray(degrees, dtype=np.int64)
    n, nnz, max_deg = deg_np.size, indices_np.shape[0], int(deg_np.max())
    if width is None:
        width = max_deg
    elif width < max_deg:
        raise ValueError(
            f"width={width} cannot cover max degree {max_deg}; CDF rows "
            "must materialize at least as wide as the longest row"
        )
    flat_probs = None
    if row_probs is not None:
        rp = np.asarray(row_probs)
        if rp.ndim == 1:
            if rp.shape[0] != nnz:
                raise ValueError(
                    f"flat row_probs must have nnz={nnz} entries, got "
                    f"{rp.shape[0]}"
                )
            flat_probs = rp.astype(np.float32)
        elif rp.shape != (n, max_deg):
            raise ValueError(
                f"row_probs must be (n, max_deg)=({n}, {max_deg}) or flat "
                f"(nnz,), got {rp.shape}"
            )
    elif lipschitz is None:
        raise ValueError(
            "ragged_edge_cdf needs a row source: row_probs (padded table "
            "or flat buffer) or lipschitz"
        )
    if lipschitz is not None and row_probs is None:
        lips = jnp.asarray(lipschitz, jnp.float32)
        deg_j = jnp.asarray(deg_np, jnp.int32)
    out = np.empty(nnz, dtype=np.float32)
    cols = np.arange(width)
    for ids in _ragged_row_chunks(n, width, chunk_rows):
        if flat_probs is not None:
            rows = np.zeros((ids.size, width), dtype=np.float32)
            mask = cols[None, :] < deg_np[ids][:, None]
            rows[mask] = flat_probs[
                indptr_np[ids[0]] : indptr_np[ids[-1] + 1]
            ]
            rows = jnp.asarray(rows)
        elif row_probs is not None:
            block = rp[ids]
            if block.shape[1] < width:
                block = np.pad(
                    block, ((0, 0), (0, width - block.shape[1]))
                )
            rows = jnp.asarray(block)
        else:
            nbrs = _pad_neighbor_lists(
                indptr_np, indices_np, deg_np, node_ids=ids, width=width
            )
            rows = p_is_rows_block(
                jnp.asarray(nbrs),
                jnp.asarray(ids, jnp.int32),
                deg_j[ids],
                deg_j,
                lips,
            )
        cdf = np.asarray(jnp.cumsum(rows, axis=1))
        out[indptr_np[ids[0]] : indptr_np[ids[-1] + 1]] = flat_edge_values(
            indptr_np, deg_np, cdf, node_ids=ids
        )
    return jnp.asarray(out)


def ragged_edge_cdf_update(
    old_indptr,
    old_degrees,
    old_edge_cdf,
    new_indptr,
    new_indices,
    new_degrees,
    touched_rows,
    *,
    touched_probs=None,
    lipschitz=None,
    width: Optional[int] = None,
) -> jnp.ndarray:
    """Incremental flat per-edge CDF after a batched edge churn — (nnz',).

    The segment-local counterpart of :func:`ragged_edge_cdf`: every row
    *not* in ``touched_rows`` keeps its old CDF segment **verbatim** (the
    per-row cumsum makes each segment bitwise-independent of every other
    row), and only the touched rows — ``graphs.EdgeChurn.touched_rows``:
    churn endpoints plus new-graph neighbors of degree-changed nodes — are
    recomputed, through the **identical** :func:`p_is_rows_block` /
    ``jnp.cumsum`` / ``flat_edge_values`` ops the from-scratch builder
    runs, at the **same materialization width**.  That last clause is
    load-bearing: XLA's CPU reductions lane-split by row width, so the
    same probabilities padded to a different width differ in the last
    ulp — a row's bits are a function of (values, width), not values
    alone.  Pass ``width`` = the width the *old* CDF was built at
    (``WalkEngine.cdf_width``); the result is then bitwise-identical to
    ``ragged_edge_cdf(new_graph, width=width)`` (the differential tests
    in ``tests/test_dynamic_graphs.py`` pin this on every layout) while
    the work is O(E) copies + O(touched·width) recompute instead of a
    full O(E log E) rebuild.  Default width: the new graph's max degree
    — only safe when churn did not change it.  A width below the new
    max degree raises: the caller must escalate to a full
    :func:`ragged_edge_cdf` rebuild at the wider width instead
    (``WalkEngine.apply_churn`` does).

    Row source for the touched rows: ``touched_probs`` — a flat float32
    buffer of length ``sum(new_degrees[touched_rows])`` in ascending-row
    CSR edge order, e.g. any ``transition.*_rows_ragged`` builder called
    with ``node_ids=touched_rows`` — or live Eq.-7 rows from a full-length
    ``lipschitz`` vector.  Exactly one must be given.

    Validation is strict: the node count must be unchanged (churn moves
    edges, never nodes), ``touched_rows`` must be unique ascending in
    range, and any row outside it whose degree changed raises — an
    incomplete touched set would silently corrupt the walk law otherwise.
    """
    from repro.core.graphs import (
        _concat_ranges,
        _pad_neighbor_lists,
        flat_edge_values,
    )

    old_indptr_np = np.asarray(old_indptr, dtype=np.int64)
    deg_old = np.asarray(old_degrees, dtype=np.int64)
    old_cdf = np.asarray(old_edge_cdf, dtype=np.float32)
    new_indptr_np = np.asarray(new_indptr, dtype=np.int64)
    indices_np = np.asarray(new_indices)
    deg_new = np.asarray(new_degrees, dtype=np.int64)
    touched = np.asarray(touched_rows, dtype=np.int64)
    n = deg_new.size
    if deg_old.size != n:
        raise ValueError(
            "node count changed across the churn; apply_edge_churn moves "
            "edges, never nodes"
        )
    if touched.size and (
        np.any(np.diff(touched) <= 0) or touched[0] < 0 or touched[-1] >= n
    ):
        raise ValueError(
            "touched_rows must be unique ascending node ids in range "
            "(EdgeChurn.touched_rows is)"
        )
    if (touched_probs is None) == (lipschitz is None):
        raise ValueError(
            "pass exactly one row source: touched_probs (flat buffer over "
            "the touched rows) or lipschitz (full vector, live Eq.-7 rows)"
        )
    keep = np.ones(n, dtype=bool)
    keep[touched] = False
    keep_ids = np.nonzero(keep)[0]
    if not np.array_equal(deg_old[keep_ids], deg_new[keep_ids]):
        raise ValueError(
            "a row outside touched_rows changed degree; touched_rows must "
            "cover every changed row (use EdgeChurn.touched_rows)"
        )
    out = np.empty(int(new_indptr_np[-1]), dtype=np.float32)
    out[_concat_ranges(new_indptr_np[keep_ids], deg_new[keep_ids])] = old_cdf[
        _concat_ranges(old_indptr_np[keep_ids], deg_old[keep_ids])
    ]
    max_deg = int(deg_new.max())
    if width is None:
        width = max_deg
    elif width < max_deg:
        raise ValueError(
            f"width={width} cannot cover the new max degree {max_deg}; "
            "the churn outgrew the old build width — escalate to a full "
            "ragged_edge_cdf rebuild at the wider width"
        )
    if touched.size == 0:
        return jnp.asarray(out)
    deg_t = deg_new[touched]
    if lipschitz is not None:
        deg_j = jnp.asarray(deg_new, jnp.int32)
        lips_j = jnp.asarray(lipschitz, jnp.float32)
        tp = tp_off = None
    else:
        tp = np.asarray(touched_probs, dtype=np.float32)
        expect = int(deg_t.sum())
        if tp.ndim != 1 or tp.shape[0] != expect:
            raise ValueError(
                f"touched_probs must be a flat ({expect},) buffer covering "
                f"the touched rows in CSR edge order, got {tp.shape}"
            )
        tp_off = np.concatenate([[0], np.cumsum(deg_t)])
    # bounded-memory recompute: the same ~32 MB transient-block rule as
    # the from-scratch builder (graphs._ragged_row_chunks), applied to
    # slices of the touched list — a hub-heavy closure at a large width
    # would otherwise materialize one (touched, width) block of hundreds
    # of MB and fall off the builder's cell throughput
    chunk = max(256, (32 << 20) // max(1, 4 * width))
    cols = np.arange(width)
    for a in range(0, touched.size, chunk):
        ids = touched[a : a + chunk]
        dt = deg_t[a : a + chunk]
        if lipschitz is not None:
            nbrs = _pad_neighbor_lists(
                new_indptr_np, indices_np, deg_new, node_ids=ids,
                width=width,
            )
            rows = p_is_rows_block(
                jnp.asarray(nbrs),
                jnp.asarray(ids, jnp.int32),
                deg_j[ids],
                deg_j,
                lips_j,
            )
        else:
            rows_np = np.zeros((ids.size, width), dtype=np.float32)
            rows_np[cols[None, :] < dt[:, None]] = tp[
                tp_off[a] : tp_off[a + ids.size]
            ]
            rows = jnp.asarray(rows_np)
        cdf = np.asarray(jnp.cumsum(rows, axis=1))
        out[_concat_ranges(new_indptr_np[ids], dt)] = flat_edge_values(
            new_indptr_np, deg_new, cdf, node_ids=ids
        )
    return jnp.asarray(out)


def ragged_mh_invert(
    indptr: jnp.ndarray,  # (n+1,) int32 CSR row pointers
    degrees: jnp.ndarray,  # (n,) int32
    indices: jnp.ndarray,  # (nnz,) int32 CSR neighbor ids
    edge_cdf: jnp.ndarray,  # (nnz,) float32 flat per-edge CDF
    nodes: jnp.ndarray,  # (W,) int32 current node per walk
    u_mh: jnp.ndarray,  # (W,) the U_MH uniform per walk
    *,
    max_degree: int,
) -> jnp.ndarray:
    """THE ragged MH-move inversion: binary-search each walk's own CDF
    segment at its true degree; returns ``v_mh`` (W,).

    The padded layouts count ``cdf < u · cdf[-1]`` across the full row
    width; over a non-decreasing CDF that count is a lower bound, so the
    same index falls out of a binary search of the row's true-degree
    segment ``edge_cdf[indptr[v] : indptr[v] + deg(v)]`` — pad slots
    (trailing exact-total entries on the padded row, ``u < 1`` strictly)
    never counted anyway.  ceil(log2(max_degree + 1)) rounds of W-wide
    gathers replace the O(W·max_deg) row materialization; given the flat
    CDF of :func:`ragged_edge_cdf` the returned neighbor is bitwise-equal
    to :func:`mh_cdf_invert` on the padded row per key.  This is both the
    scan backend's ragged MH move and the oracle the fused scalar-prefetch
    kernel (``kernels.walk_transition.walk_transition_ragged``) mirrors
    per walk.
    """
    start = indptr[nodes]
    deg = degrees[nodes]
    total = edge_cdf[start + deg - 1]
    t = u_mh * total
    lo = jnp.zeros_like(deg)
    hi = deg
    for _ in range(max(1, math.ceil(math.log2(max_degree + 1)))):
        active = lo < hi
        mid = (lo + hi) // 2
        c = edge_cdf[start + jnp.minimum(mid, deg - 1)]
        pred = active & (c < t)
        lo = jnp.where(pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
    idx = jnp.minimum(lo, deg - 1)
    return indices[start + idx]


def combine_bucketed(
    bucket_ids: jnp.ndarray, results_by_bucket
) -> jnp.ndarray:
    """THE bucket-merge rule: walk w keeps result of bucket ``bucket_ids[w]``.

    Every per-bucket dispatcher (the engine's scan fallback, the Pallas
    ``walk_transition_bucketed`` and the ``ref`` oracle) routes through
    this, so the keep-own-bucket convention exists exactly once.
    """
    merged = None
    for b, vm in enumerate(results_by_bucket):
        merged = vm if merged is None else jnp.where(bucket_ids == b, vm, merged)
    return merged


def bucket_capacities(
    num_walks: int,
    shares: Tuple[float, ...],
    capacity_factor: float,
    *,
    min_cap: int = 32,
    lane: int = 8,
) -> Tuple[int, ...]:
    """Static per-bucket walk capacities for the compacted dispatch.

    THE capacity rule, documented once: bucket b gets
    ``min(W, round_up(max(min_cap, ceil(capacity_factor · W · share_b)),
    lane))`` lanes.  ``share_b`` is the bucket's expected walk share —
    the engine uses ``max(node share n_b/n, degree share E_b/E)``,
    because walk occupancy tracks node share under the MH-IS stationary
    law but is *degree*-biased through the Lévy branch (uniform hops land
    on a node with probability ∝ its degree) and the simple-RW MH
    proposal, so hub buckets hold far more walks than their node count
    suggests.  ``capacity_factor`` > 1 leaves headroom for per-step
    fluctuation, ``min_cap`` keeps near-empty hub buckets from
    overflowing on bursts, and ``lane`` rounding keeps tile shapes
    friendly.  Everything here is a python number known at trace time
    (shapes + graph construction constants), so the capacities are
    jit-compile-time constants.  A step whose per-bucket walk counts
    exceed these capacities falls back to the uncompacted full-W dispatch
    (see :meth:`WalkEngine.step`) — same law, same bits, just slower.
    """
    caps = []
    for share in shares:
        c = math.ceil(capacity_factor * num_walks * share)
        c = max(c, min_cap)
        c = -(-c // lane) * lane
        caps.append(min(c, num_walks))
    return tuple(caps)


def compact_plan(
    bucket_ids: jnp.ndarray, num_buckets: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort the W walks by bucket id — THE compaction pass.

    Returns ``(order, starts, counts)``: ``order`` is the stable argsort of
    ``bucket_ids`` (walks of bucket b occupy positions
    ``starts[b] : starts[b] + counts[b]`` of ``order``, in original walk
    order within the bucket), ``counts[b]`` the number of walks currently
    in bucket b.  All shapes are static; only the values are traced.
    """
    counts = jnp.zeros(num_buckets, jnp.int32).at[bucket_ids].add(1)
    order = jnp.argsort(bucket_ids, stable=True).astype(jnp.int32)
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]]
    )
    return order, starts, counts


def scatter_compacted(
    num_walks: int,
    walk_idx_by_bucket,
    valid_by_bucket,
    results_by_bucket,
) -> jnp.ndarray:
    """THE compacted merge rule: scatter per-bucket results back to walk
    order.

    Bucket b's pass produced ``results_by_bucket[b][lane]`` for walk
    ``walk_idx_by_bucket[b][lane]``; lanes beyond the bucket's walk count
    (``valid_by_bucket[b][lane] == False``) are capacity slop whose results
    are dropped — their scatter index is pushed out of bounds and JAX's
    ``mode="drop"`` discards them.  Valid lanes partition the walk set
    (each walk is in exactly one bucket), so the scatters never collide.
    Shared by the engine's scan path, the Pallas compacted dispatch
    (``kernels.walk_transition.walk_transition_bucketed_compacted``) and
    the ``ref`` oracle, so the merge convention exists exactly once.
    """
    out = jnp.zeros(num_walks, dtype=results_by_bucket[0].dtype)
    for widx, valid, res in zip(
        walk_idx_by_bucket, valid_by_bucket, results_by_bucket
    ):
        idx = jnp.where(valid, widx, num_walks)  # invalid -> out of bounds
        out = out.at[idx].set(res, mode="drop")
    return out


def mhlj_transition_math(
    nodes: jnp.ndarray,  # (W,) int32 current node per walk
    rows: jnp.ndarray,  # (W, max_deg) P_IS row per walk (padded)
    neighbors: jnp.ndarray,  # (n, max_deg) int32, pads = self id
    degrees: jnp.ndarray,  # (n,) int32
    uniforms: jnp.ndarray,  # (W, 3 + r); slot U_JUMP is a {0,1} flag
    p_d: float,
    r: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One Algorithm-1 transition for W walks — the canonical math.

    The MH-IS move is a per-walk CDF inversion (vmapped); the Lévy branch
    is :func:`levy_jump_batched`, shared verbatim with the sparse Pallas
    path so the jump law exists exactly once in pure JAX.  The Pallas
    kernels mirror this arithmetic (same CDF inversion, same
    :func:`trunc_geom_icdf`, same hop-index formula), and the parity tests
    assert bitwise-equal outputs given the same uniforms.

    Returns ``(next_nodes, hops)``, both ``(W,)`` int32; ``hops`` is the
    Remark-1 physical transition count (1 for MH, d for a jump).
    """
    v_mh = mh_cdf_invert(rows, neighbors[nodes], uniforms[:, U_MH])
    v_jump, d = levy_jump_batched(nodes, uniforms, neighbors, degrees, p_d, r)
    return combine_mh_jump(v_mh, v_jump, d, uniforms)


def combine_mh_jump(
    v_mh: jnp.ndarray, v_jump: jnp.ndarray, d: jnp.ndarray, uniforms: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Resolve the J~Ber(p_J) branch per walk — THE jump/MH combine.

    Selects the jump or MH destination from the ``U_JUMP`` flag and
    produces the Remark-1 hop count (1 for MH, d for a jump).  Shared by
    every pure-JAX path (scan and sparse Pallas) so the branch convention
    exists exactly once; the dense Pallas kernel mirrors it per walk.
    """
    do_jump = uniforms[:, U_JUMP] > 0.5
    v_next = jnp.where(do_jump, v_jump, v_mh)
    hops = jnp.where(do_jump, d, jnp.int32(1))
    return v_next, hops


def levy_jump_batched(
    nodes: jnp.ndarray,  # (W,) int32
    uniforms: jnp.ndarray,  # (W, 3 + r)
    neighbors: Optional[jnp.ndarray],  # (n, max_deg) int32, or None with csr=
    degrees: jnp.ndarray,  # (n,) int32
    p_d: float,
    r: int,
    *,
    csr: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The Lévy branch of Algorithm 1 for W walks — THE jump implementation.

    d ~ TruncGeom(p_d, r) then d uniform hops, expressed as W-wide XLA
    gathers (no dense table, no per-walk scan).  Consumed by the scan
    backend (via :func:`mhlj_transition_math`), the sparse Pallas path and
    the bucketed path; the dense Pallas kernel mirrors this arithmetic per
    walk.  Returns ``(v_jump, d)``.

    The k-th neighbor of ``v`` comes from the padded table
    (``neighbors[v, k]``) or, when ``csr=(indptr, indices)`` is given, from
    the flat CSR arrays (``indices[indptr[v] + k]``).  Hop indices always
    satisfy ``k < deg(v)``, where both sources hold the identical value —
    so the bucketed layout (which never materializes the padded table)
    samples the same jump bit for bit.
    """
    d = trunc_geom_icdf(uniforms[:, U_DIST], p_d, r)

    def hop(i, v_cur):
        deg = degrees[v_cur]
        hop_idx = jnp.minimum(
            (uniforms[:, U_HOP0 + i] * deg.astype(jnp.float32)).astype(jnp.int32),
            deg - 1,
        )
        if csr is None:
            v_new = neighbors[v_cur, hop_idx]
        else:
            indptr, indices = csr
            v_new = indices[indptr[v_cur] + hop_idx]
        return jnp.where(i < d, v_new, v_cur)

    v_jump = jax.lax.fori_loop(0, r, hop, nodes)
    return v_jump, d


@dataclasses.dataclass(frozen=True, eq=False)
class WalkEngine:
    """Batched MHLJ sampler for W parallel walks with pluggable backends.

    Construct once (``from_graph``) and call :meth:`step` inside jitted
    training loops or :meth:`run` for whole trajectories.  All fields are
    device arrays or static python scalars, so instances may also be built
    inside a trace (the regression trainer does).  Engines are registered
    as pytrees (array fields are leaves; backend/layout/shape statics are
    aux data), so an engine may also be passed *through* a ``jax.jit``
    boundary as an argument — the trainer does exactly that, which is what
    lets every layout (padded or bucketed) ride the same jitted loop.
    """

    neighbors: Optional[jnp.ndarray]  # (n, max_deg) int32, pads = self id;
    #   None on the bucketed layout, which never materializes the table
    degrees: jnp.ndarray  # (n,) int32
    p_j: Union[float, jnp.ndarray] = 0.1  # default jump prob (overridable per call)
    p_d: float = 0.5
    r: int = 3
    row_probs: Optional[jnp.ndarray] = None  # (n, max_deg) precomputed P_IS
    backend: str = "auto"  # "auto" | "scan" | "pallas"
    layout: str = "sparse"  # engine.LAYOUTS — pallas-backend row handling
    block_w: int = 256
    interpret: Optional[bool] = None  # None = auto (interpret off-TPU)
    # -- bucketed-layout compaction knobs (static) --------------------------
    compact: bool = True  # sort walks by bucket, run tiles at capacity
    capacity_factor: float = 1.25  # headroom of the bucket_capacities rule
    bucket_share: Optional[Tuple[float, ...]] = None  # per-bucket expected
    #   walk share, max(node share, degree share); None = node share only
    # -- bucketed/ragged-layout state (None on the padded layouts) ----------
    indptr: Optional[jnp.ndarray] = None  # (n+1,) int32 CSR row pointers
    indices: Optional[jnp.ndarray] = None  # (nnz,) int32 CSR neighbor ids
    node_bucket: Optional[jnp.ndarray] = None  # (n,) int32 bucket id per node
    node_slot: Optional[jnp.ndarray] = None  # (n,) int32 row within bucket
    bucket_neighbors: Optional[Tuple[jnp.ndarray, ...]] = None  # (n_b, w_b)
    bucket_rows: Optional[Tuple[jnp.ndarray, ...]] = None  # (n_b, w_b) P_IS
    # -- ragged-layout state (the O(E) true-degree path) --------------------
    edge_cdf: Optional[jnp.ndarray] = None  # (nnz,) float32 flat per-edge CDF
    max_degree: Optional[int] = None  # static bound for the binary search
    cdf_width: Optional[int] = None  # width edge_cdf was materialized at —
    #   XLA reduction bits depend on the padded row width, so incremental
    #   churn must keep patching at THIS width (>= max_degree) to stay
    #   bitwise vs a same-width rebuild; apply_churn escalates to a full
    #   recompute only when an insert pushes max degree past it
    # -- fleet sharding (static; see repro.walk_sgd.fleet) -------------------
    walker_sharding: Optional[object] = None  # jax NamedSharding for the W
    #   walker axis; None = single-device (no constraints emitted).  When
    #   set, step/run pin the per-walk uniform block and outputs to the
    #   walker mesh axis so GSPMD keeps the whole transition
    #   walker-parallel (graph state stays replicated per
    #   repro.sharding.rules.fleet_specs).
    # -- dynamic graphs (static; see docs/dynamic_graphs.md) -----------------
    graph_version: int = 0  # bumped by apply_churn — static aux, so jitted
    #   consumers retrace across graph versions (an nnz change forces a
    #   retrace anyway; the counter makes equal-nnz churns explicit too,
    #   and walk-continuity bookkeeping keys off it)

    @classmethod
    def from_graph(
        cls,
        graph,
        params,
        *,
        row_probs=None,
        lipschitz: Optional[jnp.ndarray] = None,
        backend: str = "auto",
        layout: Optional[str] = None,
        block_w: int = 256,
        interpret: Optional[bool] = None,
        bucket_factor: Optional[int] = None,
        compact: bool = True,
        capacity_factor: float = 1.25,
    ) -> "WalkEngine":
        """Engine from any ``core.graphs`` class + ``MHLJParams``.

        ``Graph`` and ``CSRGraph`` expose the same padded
        ``neighbors``/``degrees`` tensors, so large CSR graphs plug in with
        no dense adjacency ever materialized; a ``BucketedCSRGraph``
        selects ``layout="bucketed"`` automatically and a
        ``RaggedCSRGraph`` selects ``layout="ragged"`` (and any graph is
        converted when either layout is requested explicitly, with
        ``bucket_factor`` picking the bucketed width ladder).  ``compact``
        / ``capacity_factor`` tune the bucketed layout's per-step walk
        compaction (see :meth:`step`); they are inert on the other
        layouts.  Row source precedence: explicit ``row_probs`` (an
        (n, max_deg) table, a per-bucket tuple for the bucketed layout —
        a full table is column-truncated per bucket, which is
        bitwise-exact — or a flat (nnz,) buffer for the ragged layout,
        e.g. ``transition.mh_importance_rows_ragged``), else rows
        precomputed from a *static* ``lipschitz`` vector, else live rows
        from the ``lipschitz=`` argument of :meth:`step` / :meth:`run`
        (the ragged layout, whose row state is the flat CDF built once at
        construction, requires one of the first two).
        """
        is_bucketed = hasattr(graph, "buckets")
        is_bare_csr = hasattr(graph, "indptr") and not (
            is_bucketed or hasattr(graph, "neighbors")
        )
        if layout is None:
            layout = (
                "bucketed" if is_bucketed
                else "ragged" if is_bare_csr
                else "sparse"
            )
        if layout == "ragged":
            # true-degree layout: resident row state is the flat per-edge
            # CDF (exactly O(E)); no padded or bucketed table is built
            core = graph if hasattr(graph, "indptr") else graph.to_csr()
            degrees = jnp.asarray(core.degrees, jnp.int32)
            if row_probs is None and lipschitz is None:
                raise ValueError(
                    "layout='ragged' precomputes its flat per-edge CDF at "
                    "construction; pass row_probs (padded table or flat "
                    "buffer) or lipschitz to from_graph"
                )
            edge_cdf = ragged_edge_cdf(
                core.indptr, core.indices, core.degrees,
                row_probs=row_probs, lipschitz=lipschitz,
            )
            return cls(
                neighbors=None,
                degrees=degrees,
                p_j=params.p_j,
                p_d=params.p_d,
                r=params.r,
                row_probs=None,
                backend=backend,
                layout="ragged",
                block_w=block_w,
                interpret=interpret,
                compact=compact,
                capacity_factor=capacity_factor,
                indptr=jnp.asarray(core.indptr, jnp.int32),
                indices=jnp.asarray(core.indices, jnp.int32),
                edge_cdf=edge_cdf,
                max_degree=int(np.asarray(core.degrees).max()),
                cdf_width=int(np.asarray(core.degrees).max()),
            )
        if layout == "bucketed":
            # bucket_factor=None keeps an already-bucketed graph's ladder
            # as-is; an explicit value re-buckets on mismatch.  Every
            # sparse class buckets straight off its CSR core, so a bare
            # RaggedCSRGraph never materializes the padded table here.
            if is_bucketed and bucket_factor is None:
                bg = graph
            else:
                base = (
                    graph if hasattr(graph, "to_bucketed") else graph.to_csr()
                )
                bg = base.to_bucketed(bucket_factor=bucket_factor or 2)
            degrees = jnp.asarray(bg.degrees)
            bucket_neighbors = tuple(
                jnp.asarray(b.neighbors) for b in bg.buckets
            )
            if row_probs is not None:
                if isinstance(row_probs, (tuple, list)):
                    bucket_rows = tuple(jnp.asarray(x) for x in row_probs)
                else:  # (n, max_deg) table: exact per-bucket truncation
                    table = jnp.asarray(row_probs)
                    bucket_rows = tuple(
                        table[jnp.asarray(b.node_ids)][:, : b.width]
                        for b in bg.buckets
                    )
            elif lipschitz is not None:
                lips = jnp.asarray(lipschitz, jnp.float32)
                bucket_rows = tuple(
                    p_is_rows_block(
                        jnp.asarray(b.neighbors),
                        jnp.asarray(b.node_ids),
                        degrees[jnp.asarray(b.node_ids)],
                        degrees,
                        lips,
                    )
                    for b in bg.buckets
                )
            else:
                bucket_rows = None
            # expected walk share per bucket (static): max of node share
            # (MH-IS stationary occupancy) and degree share (Lévy-jump /
            # simple-RW-proposal occupancy) — see bucket_capacities
            total_deg = int(bg.degrees.sum())
            bucket_share = tuple(
                max(
                    int(b.node_ids.size) / bg.n,
                    int(bg.degrees[b.node_ids].sum()) / total_deg,
                )
                for b in bg.buckets
            )
            return cls(
                neighbors=None,
                degrees=degrees,
                p_j=params.p_j,
                p_d=params.p_d,
                r=params.r,
                row_probs=None,
                backend=backend,
                layout="bucketed",
                block_w=block_w,
                interpret=interpret,
                compact=compact,
                capacity_factor=capacity_factor,
                bucket_share=bucket_share,
                indptr=jnp.asarray(bg.indptr, jnp.int32),
                indices=jnp.asarray(bg.indices, jnp.int32),
                node_bucket=jnp.asarray(bg.node_bucket),
                node_slot=jnp.asarray(bg.node_slot),
                bucket_neighbors=bucket_neighbors,
                bucket_rows=bucket_rows,
            )
        if is_bucketed or is_bare_csr:
            graph = graph.to_csr()  # padded layouts need the full tensors
        neighbors = jnp.asarray(graph.neighbors)
        degrees = jnp.asarray(graph.degrees)
        if row_probs is None and lipschitz is not None:
            row_probs = p_is_rows(
                neighbors, degrees, jnp.asarray(lipschitz, jnp.float32)
            )
        return cls(
            neighbors=neighbors,
            degrees=degrees,
            p_j=params.p_j,
            p_d=params.p_d,
            r=params.r,
            row_probs=None if row_probs is None else jnp.asarray(row_probs),
            backend=backend,
            layout=layout,
            block_w=block_w,
            interpret=interpret,
            compact=compact,
            capacity_factor=capacity_factor,
        )

    def __post_init__(self):
        if self.backend not in ("auto", "scan", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.layout not in LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}")

    def apply_churn(
        self,
        graph,
        churn,
        *,
        lipschitz=None,
        touched_probs=None,
    ) -> "WalkEngine":
        """New engine over a churned graph, recomputing only touched rows.

        ``graph`` is the **post-churn** sparse graph and ``churn`` the
        :class:`repro.core.graphs.EdgeChurn` receipt, both straight from
        ``apply_edge_churn``.  Row state is refreshed by
        :func:`ragged_edge_cdf_update` (untouched CDF segments copied
        verbatim, ``churn.touched_rows`` recomputed from ``lipschitz`` or
        ``touched_probs`` — exactly one) **at the engine's recorded
        ``cdf_width``**, so the patched buffer stays bitwise-identical to
        a same-width from-scratch rebuild even when the churn *lowered*
        the max degree (XLA reduction bits depend on the padded row
        width — see :func:`ragged_edge_cdf`).  Only when an insert pushes
        the max degree **past** ``cdf_width`` does the update escalate to
        a full :func:`ragged_edge_cdf` recompute at the new width — rare
        under random churn (an insert must land on the current hub), and
        the escalation needs a *full* row source: ``lipschitz`` works as
        is, while a ``touched_probs`` buffer restricted to the touched
        rows cannot rebuild untouched rows and must be passed full-length
        (nnz,) instead.  ``graph_version`` is bumped by one and every
        other engine knob carries over.  Walk positions are *not*
        migrated here — that is the fleet's continuity rule
        (:func:`repro.walk_sgd.fleet.migrate_walk_nodes`), which keys off
        the new degree vector.

        Ragged layout only: the other layouts' row state (padded tables /
        per-bucket tiles) has no segment-local structure worth patching —
        rebuild those engines via :meth:`from_graph`.
        """
        if self.layout != "ragged":
            raise ValueError(
                "incremental churn updates exist on layout='ragged' only "
                "(the flat per-edge CDF is segment-local); rebuild other "
                "layouts via WalkEngine.from_graph"
            )
        if not hasattr(graph, "indptr"):
            raise TypeError(
                "apply_churn needs the post-churn CSRGraph/RaggedCSRGraph "
                f"(got {type(graph).__name__})"
            )
        new_max = int(np.asarray(graph.degrees).max())
        old_width = self.cdf_width if self.cdf_width is not None else (
            self.max_degree
        )
        if new_max <= old_width:
            new_cdf = ragged_edge_cdf_update(
                np.asarray(self.indptr, dtype=np.int64),
                np.asarray(self.degrees),
                self.edge_cdf,
                graph.indptr,
                graph.indices,
                graph.degrees,
                churn.touched_rows,
                touched_probs=touched_probs,
                lipschitz=lipschitz,
                width=old_width,
            )
            new_width = old_width
        else:
            # escalation: the churn outgrew the recorded build width, so
            # EVERY row's bits change (width-dependent reductions) — a
            # segment patch cannot help; rebuild the whole flat CDF once
            # at the new width and record it
            if (touched_probs is None) == (lipschitz is None):
                raise ValueError(
                    "pass exactly one row source: touched_probs or "
                    "lipschitz"
                )
            nnz = int(np.asarray(graph.indices).shape[0])
            if touched_probs is not None:
                tp = np.asarray(touched_probs, dtype=np.float32)
                if tp.ndim != 1 or tp.shape[0] != nnz:
                    raise ValueError(
                        f"churn raised the max degree past the engine's "
                        f"cdf_width ({old_width} -> {new_max}); the "
                        "escalated full rebuild needs a full-length "
                        f"({nnz},) row-probability buffer, not one "
                        "restricted to the touched rows — recompute "
                        f"without node_ids (got {tp.shape})"
                    )
                new_cdf = ragged_edge_cdf(
                    graph.indptr, graph.indices, graph.degrees,
                    row_probs=tp, width=new_max,
                )
            else:
                new_cdf = ragged_edge_cdf(
                    graph.indptr, graph.indices, graph.degrees,
                    lipschitz=lipschitz, width=new_max,
                )
            new_width = new_max
        return dataclasses.replace(
            self,
            degrees=jnp.asarray(graph.degrees, jnp.int32),
            indptr=jnp.asarray(graph.indptr, jnp.int32),
            indices=jnp.asarray(graph.indices, jnp.int32),
            edge_cdf=new_cdf,
            max_degree=new_max,
            cdf_width=new_width,
            graph_version=self.graph_version + 1,
        )

    # -- backend resolution -------------------------------------------------

    @property
    def resolved_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        env = os.environ.get(BACKEND_ENV_VAR, "").strip().lower()
        if env in ("scan", "pallas"):
            return env
        return "pallas" if jax.default_backend() == "tpu" else "scan"

    @property
    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    # -- P_IS row plumbing --------------------------------------------------

    def rows_table(self, lipschitz: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Full (n, max_deg) P_IS table (precomputed or live Eq.-7).

        Only the dense layout consumes this; the sparse layout touches
        :meth:`rows_for` exclusively, so an engine with live rows never
        builds the whole table.
        """
        if self.layout == "bucketed":
            raise ValueError(
                "the bucketed layout has no full-width row table; rows live "
                "per degree bucket (bucket_rows)"
            )
        if self.layout == "ragged":
            raise ValueError(
                "the ragged layout has no full-width row table; row state "
                "is the flat per-edge CDF (edge_cdf)"
            )
        if self.row_probs is not None:
            return self.row_probs
        if lipschitz is None:
            raise ValueError(
                "engine has no precomputed row_probs; pass lipschitz= for "
                "live Eq. (7) rows"
            )
        return p_is_rows(self.neighbors, self.degrees, lipschitz)

    def rows_for(
        self, nodes: jnp.ndarray, lipschitz: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        """P_IS rows for the W active walk positions only."""
        if self.layout == "bucketed":
            raise ValueError(
                "the bucketed layout has no full-width rows; per-bucket "
                "tiles come from _bucket_tiles (bucket_rows / live Eq. 7)"
            )
        if self.layout == "ragged":
            raise ValueError(
                "the ragged layout has no full-width rows; the MH move "
                "binary-searches the flat per-edge CDF (ragged_mh_invert)"
            )
        if self.row_probs is not None:
            return self.row_probs[nodes]
        if lipschitz is None:
            raise ValueError(
                "engine has no precomputed row_probs; pass lipschitz= for "
                "live Eq. (7) rows"
            )
        return p_is_rows(self.neighbors, self.degrees, lipschitz, nodes=nodes)

    def _bucket_tiles(
        self, nodes: jnp.ndarray, lipschitz: Optional[jnp.ndarray] = None
    ):
        """Per-bucket (P_IS rows, neighbor tiles) for the W active walks.

        For each degree bucket b the W walks gather a ``(W, width_b)`` tile
        from the bucket's storage; a walk outside bucket b is pointed at
        the bucket's row 0 — a harmless dummy whose result the caller
        discards via the per-walk bucket mask.  Returns
        ``(bucket_id, rows_by_bucket, tiles_by_bucket)``.
        """
        if self.bucket_rows is None and lipschitz is None:
            raise ValueError(
                "engine has no precomputed bucket rows; pass lipschitz= for "
                "live Eq. (7) rows"
            )
        bid = self.node_bucket[nodes]
        slot = self.node_slot[nodes]
        deg_v = self.degrees[nodes]
        rows_by_bucket, tiles_by_bucket = [], []
        for b, nbrs_b in enumerate(self.bucket_neighbors):
            local = jnp.where(bid == b, slot, 0)
            tiles = nbrs_b[local]  # (W, width_b)
            if self.bucket_rows is not None:
                rows = self.bucket_rows[b][local]
            else:
                # live Eq.-7 rows at bucket width; out-of-bucket lanes mix a
                # dummy tile with their own degree — finite garbage, masked
                # away by the caller
                rows = p_is_rows_block(
                    tiles, nodes, deg_v, self.degrees, lipschitz
                )
            rows_by_bucket.append(rows)
            tiles_by_bucket.append(tiles)
        return bid, tuple(rows_by_bucket), tuple(tiles_by_bucket)

    def _bucketed_mh_full(
        self,
        nodes: jnp.ndarray,
        u_mh: jnp.ndarray,
        lipschitz: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Uncompacted bucketed MH move: every bucket pass runs all W walks.

        The pre-compaction dispatch, kept as (a) the ``compact=False``
        path and (b) the jit-able fallback a capacity overflow selects via
        ``lax.cond`` — so an adversarial walk distribution degrades to the
        old per-step cost, never to a wrong answer.
        """
        bid, rows_by_bucket, tiles_by_bucket = self._bucket_tiles(
            nodes, lipschitz
        )
        if self.resolved_backend == "pallas":
            from repro.kernels.walk_transition.kernel import (
                walk_transition_bucketed,
            )

            return walk_transition_bucketed(
                bid,
                rows_by_bucket,
                tiles_by_bucket,
                u_mh,
                block_w=self.block_w,
                interpret=self.resolved_interpret,
            )
        # scan fallback: same per-bucket math, pure jnp
        return combine_bucketed(
            bid,
            [
                mh_cdf_invert(rows, tiles, u_mh)
                for rows, tiles in zip(rows_by_bucket, tiles_by_bucket)
            ],
        )

    def compacted_bucket_inputs(
        self,
        nodes: jnp.ndarray,
        u_mh: jnp.ndarray,
        caps: Tuple[int, ...],
        order: jnp.ndarray,
        starts: jnp.ndarray,
        counts: jnp.ndarray,
        lipschitz: Optional[jnp.ndarray] = None,
    ):
        """THE compacted gather convention: per-bucket ``[cap_b, …]`` inputs
        from a :func:`compact_plan`.

        For each bucket b, slices ``cap_b`` walk indices out of the sorted
        order (the order vector is padded so no ``dynamic_slice`` ever
        clamps — lane j is exactly sorted position ``starts[b] + j``),
        marks lanes beyond ``counts[b]`` invalid, and gathers the bucket's
        neighbor/P_IS tiles with capacity-slop lanes pointed at the
        bucket's row 0 (a harmless dummy :func:`scatter_compacted` drops).
        Returns ``(walk_idx, valid, rows, tiles, u_mh)`` — each a tuple
        with one entry per bucket.  Shared by :meth:`step`'s compacted
        branch and the kernel-vs-oracle parity tests, so the gather
        convention exists exactly once.
        """
        order_p = jnp.concatenate(
            [order, jnp.zeros((max(caps),), order.dtype)]
        )
        widx_by, valid_by, rows_by, tiles_by, u_by = [], [], [], [], []
        for b, cap in enumerate(caps):
            widx = jax.lax.dynamic_slice(order_p, (starts[b],), (cap,))
            valid = jnp.arange(cap, dtype=counts.dtype) < counts[b]
            nodes_b = nodes[widx]
            slot = jnp.where(valid, self.node_slot[nodes_b], 0)
            tiles = self.bucket_neighbors[b][slot]
            if self.bucket_rows is not None:
                rows = self.bucket_rows[b][slot]
            else:
                rows = p_is_rows_block(
                    tiles, nodes_b, self.degrees[nodes_b],
                    self.degrees, lipschitz,
                )
            widx_by.append(widx)
            valid_by.append(valid)
            rows_by.append(rows)
            tiles_by.append(tiles)
            u_by.append(u_mh[widx])
        return (
            tuple(widx_by), tuple(valid_by), tuple(rows_by),
            tuple(tiles_by), tuple(u_by),
        )

    def _bucketed_mh_compacted(
        self,
        nodes: jnp.ndarray,
        u_mh: jnp.ndarray,
        lipschitz: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Compacted bucketed MH move: each bucket pays only its own walks.

        One :func:`compact_plan` stable sort groups the W walk indices by
        bucket id; bucket b's pass then gathers a
        ``[cap_b, width_b]`` tile (``cap_b`` from the static
        :func:`bucket_capacities` rule) instead of ``[W, width_b]``, and
        :func:`scatter_compacted` puts results back in walk order.  Per-
        walk arithmetic is identical to the full dispatch — same tile row,
        same uniform, same CDF inversion — so outputs are bitwise-equal
        per key.  If any bucket's walk count exceeds its capacity this
        step, ``lax.cond`` selects :meth:`_bucketed_mh_full` instead (both
        branches have static shapes, so the whole step stays jit-able).

        Returns ``(v_mh, overflow)`` — the traced overflow flag is the
        compaction telemetry :meth:`step` surfaces through its aux output,
        so the static :func:`bucket_capacities` rule can be *audited*
        (observed overflow rate) instead of guessed.
        """
        if self.bucket_rows is None and lipschitz is None:
            raise ValueError(
                "engine has no precomputed bucket rows; pass lipschitz= for "
                "live Eq. (7) rows"
            )
        num_walks = nodes.shape[0]
        if self.bucket_share is not None:
            shares = self.bucket_share
        else:  # engines built without from_graph: node share only
            n = int(self.degrees.shape[0])
            shares = tuple(
                int(nb.shape[0]) / n for nb in self.bucket_neighbors
            )
        caps = bucket_capacities(num_walks, shares, self.capacity_factor)
        bid = self.node_bucket[nodes]
        order, starts, counts = compact_plan(bid, len(caps))
        overflow = jnp.any(counts > jnp.asarray(caps, counts.dtype))

        def compacted(_):
            widx_by, valid_by, rows_by, tiles_by, u_by = (
                self.compacted_bucket_inputs(
                    nodes, u_mh, caps, order, starts, counts, lipschitz
                )
            )
            if self.resolved_backend == "pallas":
                from repro.kernels.walk_transition.kernel import (
                    walk_transition_bucketed_compacted,
                )

                return walk_transition_bucketed_compacted(
                    rows_by, tiles_by, u_by, widx_by, valid_by, num_walks,
                    block_w=self.block_w,
                    interpret=self.resolved_interpret,
                )
            return scatter_compacted(
                num_walks, widx_by, valid_by,
                [
                    mh_cdf_invert(rows, tiles, u_b)
                    for rows, tiles, u_b in zip(rows_by, tiles_by, u_by)
                ],
            )

        def fallback(_):
            return self._bucketed_mh_full(nodes, u_mh, lipschitz)

        return jax.lax.cond(overflow, fallback, compacted, None), overflow

    # -- fleet sharding ------------------------------------------------------

    def with_walker_sharding(self, sharding) -> "WalkEngine":
        """Shard-aware engine: pin walker-axis intermediates/outputs of
        :meth:`step`/:meth:`run` to ``sharding`` (a ``NamedSharding`` for a
        1-D ``(W,)`` walker batch, e.g. from
        ``repro.sharding.rules.resolve_walker_axis``).  The constraint is
        value-preserving — sharded results stay bitwise-identical per key
        to the single-device engine (``tests/test_fleet.py``)."""
        return dataclasses.replace(self, walker_sharding=sharding)

    def _constrain_walkers(self, x: jnp.ndarray) -> jnp.ndarray:
        """Pin dim 0 of ``x`` to the walker mesh axis (no-op when unset)."""
        s = self.walker_sharding
        if s is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        spec = PartitionSpec(
            *(tuple(s.spec) + (None,) * x.ndim)[: x.ndim]
        )
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(s.mesh, spec)
        )

    # -- the transition -----------------------------------------------------

    def step(
        self,
        key: jax.Array,
        nodes: jnp.ndarray,
        *,
        p_j: Optional[Union[float, jnp.ndarray]] = None,
        lipschitz: Optional[jnp.ndarray] = None,
        with_aux: bool = False,
        faults: Optional[tuple] = None,
    ):
        """One batched MHLJ transition.

        Args:
          key: PRNG key (consumed wholly by this step).
          nodes: (W,) int32 current positions, or a scalar for one walk.
          p_j: jump probability override (python float or traced scalar);
            defaults to the engine's ``p_j``.
          lipschitz: (n,) live Lipschitz vector when the engine has no
            precomputed rows.
          with_aux: also return step telemetry — currently
            ``{"compact_overflow": bool scalar}``, True when this step's
            compacted bucketed dispatch overflowed a static capacity and
            ``lax.cond`` took the full-W fallback (always False on the
            other layouts / with compaction off).  This is how the static
            :func:`bucket_capacities` rule is audited in production
            sweeps instead of guessed.
          faults: optional ``(FaultModel, FaultState)`` pair — the
            liveness-masked transition path (docs/faults.md).  The
            backend proposal is computed exactly as without faults (scan
            and Pallas stay bitwise-identical per key), then
            :func:`repro.core.faults.apply_liveness` rejects handoffs
            onto dead nodes/edges like MH rejections and force-jumps
            walkers blocked past the model's ``patience`` to a uniform
            live node.  Requires ``with_aux=True``; the aux dict gains
            ``blocked_steps`` (the updated (W,) consecutive counter — the
            caller's next ``FaultState.blocked``), plus ``fault_blocked``
            and ``rescued`` (W,) masks.  ``faults=None`` consumes the key
            identically to the pre-fault engine (bitwise).

        Returns:
          (next_nodes, hops) matching the shape of ``nodes``; with
          ``with_aux``, (next_nodes, hops, aux).
        """
        if faults is not None and not with_aux:
            raise ValueError(
                "the liveness-masked path returns its blocked counter "
                "through aux; call step(..., faults=..., with_aux=True)"
            )
        if faults is not None:
            # split BEFORE the uniform draw so the rescue stream is
            # independent of the transition stream; the faults=None path
            # consumes the caller's key untouched (bitwise).
            key, rescue_key = jax.random.split(key)
        nodes = jnp.asarray(nodes, jnp.int32)
        squeeze = nodes.ndim == 0
        if squeeze:
            nodes = nodes[None]
        p_j_t = self.p_j if p_j is None else p_j
        u = jax.random.uniform(
            key, (nodes.shape[0], num_uniforms(self.r)), jnp.float32
        )
        flag = (u[:, U_JUMP] < p_j_t).astype(jnp.float32)
        u = u.at[:, U_JUMP].set(flag)
        if self.walker_sharding is not None and not squeeze:
            u = self._constrain_walkers(u)
        overflow = jnp.asarray(False)

        if self.layout == "ragged":
            # true-degree path: the MH move binary-searches the flat
            # per-edge CDF; resident row state is exactly O(E).  No bucket
            # ladder, no compaction sort/scatter, no overflow cond.
            if self.edge_cdf is None:
                raise ValueError(
                    "ragged engine has no flat per-edge CDF; build it via "
                    "from_graph (row_probs or lipschitz)"
                )
            if self.resolved_backend == "pallas":
                # one fused scalar-prefetch kernel pass per walk tile:
                # inversion + r-hop Lévy gather + jump/MH combine, no
                # engine-side XLA gather round-trips
                from repro.kernels.walk_transition.kernel import (
                    walk_transition_ragged,
                )

                nxt, hops = walk_transition_ragged(
                    nodes,
                    self.indptr,
                    self.degrees,
                    self.indices,
                    self.edge_cdf,
                    u,
                    p_d=self.p_d,
                    r=self.r,
                    max_degree=self.max_degree,
                    block_w=self.block_w,
                    interpret=self.resolved_interpret,
                )
            else:
                v_mh = ragged_mh_invert(
                    self.indptr, self.degrees, self.indices, self.edge_cdf,
                    nodes, u[:, U_MH], max_degree=self.max_degree,
                )
                v_jump, d = levy_jump_batched(
                    nodes, u, None, self.degrees, self.p_d, self.r,
                    csr=(self.indptr, self.indices),
                )
                nxt, hops = combine_mh_jump(v_mh, v_jump, d, u)
        elif self.layout == "bucketed":
            # per-bucket MH dispatch + CSR-gathered Lévy hops: resident
            # state is O(E + Σ_b n_b·width_b); no (n, max_deg) table exists.
            # With compaction on (and >1 bucket to dispatch), walks are
            # sorted by bucket id and each bucket's tile pass runs at its
            # static capacity instead of all W lanes; a capacity overflow
            # falls back to the full-W dispatch for that step.
            if self.compact and len(self.bucket_neighbors) > 1:
                v_mh, overflow = self._bucketed_mh_compacted(
                    nodes, u[:, U_MH], lipschitz
                )
            else:
                v_mh = self._bucketed_mh_full(nodes, u[:, U_MH], lipschitz)
            v_jump, d = levy_jump_batched(
                nodes, u, None, self.degrees, self.p_d, self.r,
                csr=(self.indptr, self.indices),
            )
            nxt, hops = combine_mh_jump(v_mh, v_jump, d, u)
        elif self.resolved_backend == "pallas" and self.layout == "dense":
            # local import: kernels package imports back into this module
            from repro.kernels.walk_transition.kernel import walk_transition

            nxt, hops = walk_transition(
                nodes,
                self.rows_table(lipschitz),
                self.neighbors,
                self.degrees,
                u,
                p_d=self.p_d,
                r=self.r,
                block_w=self.block_w,
                interpret=self.resolved_interpret,
            )
        elif self.resolved_backend == "pallas":
            # sparse layout: gather only the W active rows/neighbor tiles —
            # O(W·max_deg) working set, never the (n, max_deg) table
            from repro.kernels.walk_transition.kernel import (
                walk_transition_sparse,
            )

            v_mh = walk_transition_sparse(
                self.rows_for(nodes, lipschitz),
                self.neighbors[nodes],
                u[:, U_MH],
                block_w=self.block_w,
                interpret=self.resolved_interpret,
            )
            v_jump, d = levy_jump_batched(
                nodes, u, self.neighbors, self.degrees, self.p_d, self.r
            )
            nxt, hops = combine_mh_jump(v_mh, v_jump, d, u)
        else:
            nxt, hops = mhlj_transition_math(
                nodes,
                self.rows_for(nodes, lipschitz),
                self.neighbors,
                self.degrees,
                u,
                self.p_d,
                self.r,
            )
        aux = {"compact_overflow": overflow}
        if faults is not None:
            # liveness masking applies AFTER the backend dispatch, on the
            # proposed endpoints — every backend/layout pair shares this
            # exact rejection + rescue arithmetic (see docs/faults.md)
            fmodel, fstate = faults
            nxt, hops, blocked, was_blocked, rescued = faults_mod.apply_liveness(
                rescue_key,
                nodes,
                nxt,
                hops,
                jnp.atleast_1d(fstate.blocked),
                fmodel.live_mask(fstate),
                patience=fmodel.patience,
                rescue=fmodel.rescue,
                rescue_hops=self.r,
                edge_live=fmodel.edge_live_mask(fstate),
                indptr=self.indptr,
                indices=self.indices,
                max_degree=self.max_degree,
            )
            aux["blocked_steps"] = blocked[0] if squeeze else blocked
            aux["fault_blocked"] = was_blocked[0] if squeeze else was_blocked
            aux["rescued"] = rescued[0] if squeeze else rescued
        if self.walker_sharding is not None and not squeeze:
            nxt = self._constrain_walkers(nxt)
            hops = self._constrain_walkers(hops)
        if squeeze:
            nxt, hops = nxt[0], hops[0]
        if with_aux:
            return nxt, hops, aux
        return nxt, hops

    def run(
        self,
        key: jax.Array,
        v0s: jnp.ndarray,
        num_steps: int,
        *,
        p_j: Optional[Union[float, jnp.ndarray]] = None,
        lipschitz: Optional[jnp.ndarray] = None,
        with_aux: bool = False,
    ):
        """Whole trajectories for W walks (Algorithm 1's update sequence).

        ``p_j`` may be a scalar or a (num_steps,) schedule (Fig 6 annealing).

        Returns:
          update_nodes: (W, num_steps) int32 — element t is the node holding
            the model when update t runs (the first update runs at v0).
          hops: (W, num_steps) int32 — Remark-1 physical transitions taken
            after update t.
          Scalar ``v0s`` drops the leading walk axis.  With ``with_aux``, a
          third element carries per-step telemetry:
          ``{"compact_overflow": (num_steps,) bool}`` — which steps of the
          compacted bucketed dispatch overflowed their static capacities
          (``benchmarks/large_graph_walk.py`` records the rate so the
          ``capacity_factor`` rule is audited, not guessed).
        """
        v0s = jnp.asarray(v0s, jnp.int32)
        squeeze = v0s.ndim == 0
        if squeeze:
            v0s = v0s[None]
        p_j_base = self.p_j if p_j is None else p_j
        p_j_sched = jnp.broadcast_to(
            jnp.asarray(p_j_base, jnp.float32), (num_steps,)
        )
        keys = jax.random.split(key, num_steps)

        def body(v, xs):
            k, pj = xs
            v_next, hops, aux = self.step(
                k, v, p_j=pj, lipschitz=lipschitz, with_aux=True
            )
            return v_next, (v, hops, aux["compact_overflow"])

        _, (update_nodes, hops, overflow) = jax.lax.scan(
            body, v0s, (keys, p_j_sched)
        )
        update_nodes = update_nodes.T  # (T, W) -> (W, T)
        hops = hops.T
        if squeeze:
            update_nodes, hops = update_nodes[0], hops[0]
        if with_aux:
            return update_nodes, hops, {"compact_overflow": overflow}
        return update_nodes, hops


# -- pytree registration ----------------------------------------------------
#
# Array state (any layout's tensors, plus the possibly-traced p_j) flattens
# to leaves; compile-time knobs ride as hashable aux data.  This lets an
# engine cross a jit boundary as a plain argument — walk_sgd.trainer passes
# one engine object into its scanned training loop, so padded and bucketed
# layouts share the identical jitted code path.

_ENGINE_DATA_FIELDS = (
    "neighbors", "degrees", "p_j", "row_probs",
    "indptr", "indices", "node_bucket", "node_slot",
    "bucket_neighbors", "bucket_rows", "edge_cdf",
)
_ENGINE_META_FIELDS = (
    "p_d", "r", "backend", "layout", "block_w", "interpret",
    "compact", "capacity_factor", "bucket_share", "max_degree",
    "cdf_width",
    "walker_sharding",  # NamedSharding is hashable -> valid static aux
    "graph_version",
)


def _engine_flatten(e: WalkEngine):
    children = tuple(getattr(e, f) for f in _ENGINE_DATA_FIELDS)
    aux = tuple(getattr(e, f) for f in _ENGINE_META_FIELDS)
    return children, aux


def _engine_unflatten(aux, children) -> WalkEngine:
    return WalkEngine(
        **dict(zip(_ENGINE_DATA_FIELDS, children)),
        **dict(zip(_ENGINE_META_FIELDS, aux)),
    )


jax.tree_util.register_pytree_node(
    WalkEngine, _engine_flatten, _engine_unflatten
)
