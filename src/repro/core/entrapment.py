"""Entrapment diagnostics (paper §IV).

The entrapment problem: under P_IS on a sparse graph with heterogeneous L_v,
detailed balance (Eq. 8) makes the exit probability from high-L nodes tiny, so
the walk dwells there and the model overfits local data.  These diagnostics
quantify it:

* ``escape_probability`` — 1 - P(v, v): per-node one-step exit mass.
* ``expected_dwell_time`` — geometric dwell 1 / (1 - P(v,v)).
* ``occupancy_concentration`` — from a trajectory: max/topk node visit share
  vs its stationary share.
* ``trap_score`` — analytic: pi(v) * dwell(v) ranking; the paper's Fig 2
  5-node ring example has node 1 dominating.
* ``expected_return_time`` — 1/pi(v), for cross-checks.
"""
from __future__ import annotations

import numpy as np

from repro.core.mixing import stationary_distribution

__all__ = [
    "escape_probability",
    "expected_dwell_time",
    "trap_score",
    "occupancy_concentration",
    "visit_fractions",
]


def escape_probability(p: np.ndarray) -> np.ndarray:
    """One-step probability of leaving each node: 1 - diag(P)."""
    return 1.0 - np.diag(p)


def expected_dwell_time(p: np.ndarray) -> np.ndarray:
    """Expected consecutive steps spent at v once entered: 1 / (1 - P(v,v))."""
    esc = escape_probability(p)
    return 1.0 / np.maximum(esc, 1e-300)


def trap_score(p: np.ndarray) -> np.ndarray:
    """pi(v) * dwell(v): long-run update mass concentrated per visit-run."""
    pi = stationary_distribution(p)
    return pi * expected_dwell_time(p)


def visit_fractions(trajectory: np.ndarray, n: int) -> np.ndarray:
    """Empirical node-visit distribution of a trajectory of node ids.

    Ids must lie in ``[0, n)``: an out-of-range id means the trajectory and
    the graph disagree (wrong n, stale trajectory, transposed axes) and every
    downstream concentration statistic would be silently wrong — ``bincount``
    would happily grow past ``n`` and the returned vector would have the
    wrong length.
    """
    traj = np.asarray(trajectory).ravel()
    if traj.size == 0:
        raise ValueError("empty trajectory has no visit distribution")
    lo, hi = int(traj.min()), int(traj.max())
    if lo < 0 or hi >= n:
        raise ValueError(
            f"trajectory node ids must lie in [0, {n}): found range "
            f"[{lo}, {hi}] — trajectory and graph size disagree"
        )
    counts = np.bincount(traj, minlength=n).astype(np.float64)
    return counts / counts.sum()


def occupancy_concentration(trajectory: np.ndarray, n: int, topk: int = 1) -> dict:
    """Concentration stats of a walk trajectory.

    Returns top-k visit share, the empirical/uniform ratio for the most
    visited node, and the Herfindahl index (sum of squared shares) — a scalar
    entrapment severity measure (1/n = perfectly even, 1 = fully trapped).
    """
    frac = visit_fractions(trajectory, n)
    order = np.argsort(frac)[::-1]
    top = frac[order[:topk]].sum()
    return {
        "topk_share": float(top),
        "max_over_uniform": float(frac.max() * n),
        "herfindahl": float((frac**2).sum()),
        "argmax": int(order[0]),
    }
