"""Node/link fault injection for the walk stack — THE liveness layer.

The paper's entrapment problem has an adversarial sibling: a crashed hub
or a partitioned cut traps the chain *absolutely*, not just
probabilistically.  This module supplies the seeded, jit-compatible fault
process every layer threads through (``WalkEngine.step`` →
``walk_sgd.fleet`` → ``launch.serve``; see docs/faults.md):

* :class:`FaultModel` — the static fault *law*: a per-node two-state
  Markov up/down process (``crash_rate`` up→down, ``recovery_rate``
  down→up, both per tick), deterministic scripted windows (node ``v`` is
  down while ``down_at[v] <= t < up_at[v]``) and, on CSR-bearing layouts,
  per-edge drop windows over the flat ``(nnz,)`` slot axis.  Registered
  as a pytree (scripted arrays are leaves; rates and the rescue policy
  ride as static aux), so a model crosses ``jax.jit``/``lax.scan``
  boundaries exactly like the engine does.
* :class:`FaultState` — the per-tick carry: the Markov liveness vector,
  the per-walk consecutive ``blocked`` counter and the tick index.  One
  small pytree, scanned alongside the walk state.
* :func:`apply_liveness` — the rejection rule: a transition whose
  endpoint is dead (or whose traversed edge is dropped) is rejected like
  an MH rejection — the walker stays put and its ``blocked`` counter
  increments; ``blocked >= patience`` triggers the **jump rescue**, a
  forced Levy jump restricted to the live node set
  (:func:`live_uniform_choice` — the max-range limit of the truncated
  Levy law of arXiv:2604.12260, the same escape primitive the paper uses
  against probabilistic entrapment).

Semantics (documented in docs/faults.md, pinned by tests/test_faults.py):
a transition is a model handoff ``v -> v'``, so liveness is checked at
the endpoint — a multi-hop Levy jump is one handoff whose intermediate
hops are virtual routing.  A blocked handoff still pays its attempted
hop cost (the transmission was tried); a rescue jump pays the engine's
``r`` hops (the max-range jump).  A walker standing on a node that dies
under it is blocked every step until recovery or rescue — the
stalled-worker regime of Markov-chain SGD (arXiv:1909.10238) that
``benchmarks/fault_sweep.py`` prices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "NEVER",
    "FaultModel",
    "FaultState",
    "apply_liveness",
    "live_uniform_choice",
    "edge_slot_lookup",
    "kill_top_hubs",
    "partition_groups",
    "dumbbell_bridge_mask",
]

# scripted-window sentinel: a node/edge with down_at == NEVER never faults
NEVER = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True, eq=False)
class FaultState:
    """Per-tick fault carry: Markov liveness + per-walk blocked counters.

    ``live`` is the *Markov* component only — the effective mask is
    :meth:`FaultModel.live_mask`, which also applies the scripted windows
    at tick ``t`` (so a pure-scripted model never mutates ``live``).
    """

    live: jnp.ndarray  # (n,) bool Markov up/down component
    blocked: jnp.ndarray  # (W,) int32 consecutive fault-blocked steps
    t: jnp.ndarray  # () int32 tick index


def _state_flatten(s: FaultState):
    return (s.live, s.blocked, s.t), None


def _state_unflatten(_, children) -> FaultState:
    return FaultState(*children)


jax.tree_util.register_pytree_node(
    FaultState, _state_flatten, _state_unflatten
)


@dataclasses.dataclass(frozen=True, eq=False)
class FaultModel:
    """Seeded fault law: Markov node churn + scripted node/edge windows.

    ``crash_rate``/``recovery_rate`` are per-tick probabilities of the
    two-state Markov process (steady-state down fraction
    ``crash / (crash + recovery)``, mean downtime ``1 / recovery``
    ticks).  ``down_at``/``up_at`` script node ``v`` down during
    ``[down_at[v], up_at[v])``; ``edge_down_at``/``edge_up_at`` do the
    same per CSR edge slot (requires an engine with flat ``indptr`` /
    ``indices`` state, i.e. the ragged layout).  ``patience`` and
    ``rescue`` are the jump-rescue policy: a walker blocked ``patience``
    consecutive steps is force-jumped to a uniform live node;
    ``rescue=False`` (the ablation leg of ``benchmarks/fault_sweep.py``)
    leaves it parked.
    """

    crash_rate: float = 0.0
    recovery_rate: float = 0.0
    down_at: Optional[jnp.ndarray] = None  # (n,) int32, NEVER = no fault
    up_at: Optional[jnp.ndarray] = None  # (n,) int32 scripted recovery tick
    edge_down_at: Optional[jnp.ndarray] = None  # (nnz,) int32 per CSR slot
    edge_up_at: Optional[jnp.ndarray] = None  # (nnz,) int32
    patience: int = 3  # static: blocked steps before the forced jump
    rescue: bool = True  # static: enable the jump-rescue policy

    def __post_init__(self):
        if (self.down_at is None) != (self.up_at is None):
            raise ValueError("down_at and up_at must be given together")
        if (self.edge_down_at is None) != (self.edge_up_at is None):
            raise ValueError(
                "edge_down_at and edge_up_at must be given together"
            )
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1, got {self.patience}")

    # -- state lifecycle ----------------------------------------------------
    def init_state(self, num_nodes: int, num_walks: int) -> FaultState:
        """All-live state at tick 0 with zeroed blocked counters."""
        return FaultState(
            live=jnp.ones((num_nodes,), bool),
            blocked=jnp.zeros((num_walks,), jnp.int32),
            t=jnp.int32(0),
        )

    def advance(self, key: jax.Array, state: FaultState) -> FaultState:
        """One tick of the Markov up/down process (scripted windows are
        evaluated lazily by :meth:`live_mask`, so they cost nothing here).

        ``blocked`` is carried through untouched — it is the *step's*
        output (:func:`apply_liveness`), not the fault process's.
        """
        live = state.live
        if self.crash_rate > 0.0 or self.recovery_rate > 0.0:
            u = jax.random.uniform(key, live.shape, jnp.float32)
            crash = u < jnp.float32(self.crash_rate)
            recover = u < jnp.float32(self.recovery_rate)
            live = jnp.where(live, ~crash, recover)
        return FaultState(live=live, blocked=state.blocked, t=state.t + 1)

    # -- masks --------------------------------------------------------------
    def live_mask(self, state: FaultState) -> jnp.ndarray:
        """(n,) bool effective node liveness: Markov AND scripted windows."""
        live = state.live
        if self.down_at is not None:
            scripted_down = (self.down_at <= state.t) & (state.t < self.up_at)
            live = live & ~scripted_down
        return live

    def edge_live_mask(self, state: FaultState) -> Optional[jnp.ndarray]:
        """(nnz,) bool per-CSR-slot edge liveness, or None without edge
        faults (the common case pays nothing)."""
        if self.edge_down_at is None:
            return None
        return ~(
            (self.edge_down_at <= state.t) & (state.t < self.edge_up_at)
        )


def _model_flatten(m: FaultModel):
    children = (m.down_at, m.up_at, m.edge_down_at, m.edge_up_at)
    aux = (m.crash_rate, m.recovery_rate, m.patience, m.rescue)
    return children, aux


def _model_unflatten(aux, children) -> FaultModel:
    crash_rate, recovery_rate, patience, rescue = aux
    down_at, up_at, edge_down_at, edge_up_at = children
    return FaultModel(
        crash_rate=crash_rate,
        recovery_rate=recovery_rate,
        down_at=down_at,
        up_at=up_at,
        edge_down_at=edge_down_at,
        edge_up_at=edge_up_at,
        patience=patience,
        rescue=rescue,
    )


jax.tree_util.register_pytree_node(
    FaultModel, _model_flatten, _model_unflatten
)


# ---------------------------------------------------------------------------
# the rejection + rescue math (pure functions; the engine calls these AFTER
# its backend dispatch, so scan and Pallas stay bitwise-identical per key)
# ---------------------------------------------------------------------------


def live_uniform_choice(u: jnp.ndarray, live: jnp.ndarray) -> jnp.ndarray:
    """Uniform draw over the live node set — THE rescue destination law.

    Inverse-CDF over the 0/1 liveness weights: ``cumsum`` puts a unit
    step at every live node, so ``searchsorted(cdf, u * n_live)`` lands
    uniformly on live nodes (the max-range limit of the truncated Levy
    jump).  With **no** live node the draw is meaningless — callers must
    gate on ``live.sum() > 0`` (:func:`apply_liveness` does).
    """
    w = live.astype(jnp.float32)
    cdf = jnp.cumsum(w)
    tgt = u * cdf[-1]
    idx = jnp.searchsorted(cdf, tgt, side="right")
    return jnp.clip(idx, 0, live.shape[0] - 1).astype(jnp.int32)


def edge_slot_lookup(
    indptr: jnp.ndarray,
    indices: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    max_degree: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Flat CSR slot of edge ``src -> dst`` per walk: ``(slot, found)``.

    Scans each source row's ``max_degree``-wide window (the ragged
    layout's static bound) for ``dst``; ``found=False`` marks pairs with
    no such edge (e.g. a multi-hop jump endpoint), whose ``slot`` is
    meaningless and must be masked by the caller.
    """
    start = indptr[src]
    deg = (indptr[src + 1] - start).astype(jnp.int32)
    offs = jnp.arange(max_degree, dtype=start.dtype)
    gather = jnp.clip(start[:, None] + offs[None, :], 0, indices.shape[0] - 1)
    cand = indices[gather]
    hit = (cand == dst[:, None]) & (
        offs[None, :].astype(jnp.int32) < deg[:, None]
    )
    found = hit.any(axis=1)
    slot = start + jnp.argmax(hit, axis=1).astype(start.dtype)
    return slot, found


def apply_liveness(
    key: jax.Array,
    nodes: jnp.ndarray,  # (W,) int32 positions before the step
    nxt: jnp.ndarray,  # (W,) int32 proposed positions (backend output)
    hops: jnp.ndarray,  # (W,) int32 attempted hop cost
    blocked: jnp.ndarray,  # (W,) int32 consecutive blocked counter
    live: jnp.ndarray,  # (n,) bool effective node liveness
    *,
    patience: int,
    rescue: bool,
    rescue_hops: int = 1,  # hop cost of a rescue jump (engines pass r)
    edge_live: Optional[jnp.ndarray] = None,  # (nnz,) bool CSR slot mask
    indptr: Optional[jnp.ndarray] = None,
    indices: Optional[jnp.ndarray] = None,
    max_degree: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Liveness-masked acceptance of one batched transition.

    The rejection rule (see module docstring): a handoff is blocked when
    the walker's own node is down, the endpoint is down, or (edge faults)
    the traversed single-hop edge is dropped.  Blocked walkers stay put,
    pay the attempted ``hops`` and increment ``blocked``; a walker
    reaching ``patience`` is force-jumped to a uniform live node when
    ``rescue`` is on and any live node exists.

    Returns ``(next_nodes, hops, blocked, was_blocked, rescued)``; the
    first three replace the step's outputs/carry, the last two are
    telemetry masks.
    """
    self_dead = ~live[nodes]
    moved = nxt != nodes
    dst_dead = moved & ~live[nxt]
    fault_blocked = self_dead | dst_dead
    if edge_live is not None:
        if indptr is None or indices is None or max_degree is None:
            raise ValueError(
                "edge faults need flat CSR state (indptr/indices/"
                "max_degree) — only CSR-bearing engine layouts (ragged) "
                "support per-edge drop masks"
            )
        slot, found = edge_slot_lookup(indptr, indices, nodes, nxt, max_degree)
        fault_blocked = fault_blocked | (moved & found & ~edge_live[slot])
    nxt_out = jnp.where(fault_blocked, nodes, nxt)
    blocked_out = jnp.where(fault_blocked, blocked + 1, jnp.int32(0))
    rescued = jnp.zeros_like(fault_blocked)
    if rescue:
        # the rescue uniform is drawn unconditionally (fixed key
        # consumption given faults are active), applied only past patience
        u = jax.random.uniform(key, nodes.shape, jnp.float32)
        v_rescue = live_uniform_choice(u, live)
        rescued = (
            fault_blocked
            & (blocked_out >= jnp.int32(patience))
            & (live.sum() > 0)
        )
        nxt_out = jnp.where(rescued, v_rescue, nxt_out)
        hops = jnp.where(rescued, jnp.int32(rescue_hops), hops)
        blocked_out = jnp.where(rescued, jnp.int32(0), blocked_out)
    return nxt_out, hops, blocked_out, fault_blocked, rescued


# ---------------------------------------------------------------------------
# scripted scenarios
# ---------------------------------------------------------------------------


def kill_top_hubs(
    degrees,
    k: int,
    *,
    at: int,
    duration: Optional[int] = None,
    **model_kwargs,
) -> FaultModel:
    """Scripted scenario: the ``k`` highest-degree nodes crash at tick
    ``at`` (ties broken by node id) and recover after ``duration`` ticks
    (``None`` = never) — the adversarial version of hub entrapment.
    Extra kwargs (Markov rates, patience, rescue) pass through."""
    deg = np.asarray(degrees)
    n = deg.shape[0]
    if not 0 < k <= n:
        raise ValueError(f"k must be in [1, {n}], got {k}")
    top = np.argsort(-deg, kind="stable")[:k]
    down_at = np.full(n, NEVER, np.int32)
    up_at = np.full(n, NEVER, np.int32)
    down_at[top] = at
    if duration is not None:
        up_at[top] = at + duration
    return FaultModel(
        down_at=jnp.asarray(down_at), up_at=jnp.asarray(up_at), **model_kwargs
    )


def partition_groups(
    indptr,
    indices,
    side: np.ndarray,
    *,
    at: int,
    duration: Optional[int] = None,
    **model_kwargs,
) -> FaultModel:
    """Scripted scenario: drop every edge crossing the ``side`` cut (both
    CSR directions) during ``[at, at + duration)`` — the graph partition.

    ``side`` is an (n,) bool group assignment; with
    :func:`dumbbell_bridge_mask` this is "partition the dumbbell bridge".
    """
    indptr_np = np.asarray(indptr)
    indices_np = np.asarray(indices)
    side = np.asarray(side, bool)
    n = indptr_np.shape[0] - 1
    if side.shape != (n,):
        raise ValueError(f"side must be an ({n},) bool mask, got {side.shape}")
    src = np.repeat(np.arange(n), np.diff(indptr_np))
    crossing = side[src] != side[indices_np]
    if not crossing.any():
        raise ValueError("side mask cuts no edge; nothing to partition")
    edge_down = np.full(indices_np.shape[0], NEVER, np.int32)
    edge_up = np.full(indices_np.shape[0], NEVER, np.int32)
    edge_down[crossing] = at
    if duration is not None:
        edge_up[crossing] = at + duration
    return FaultModel(
        edge_down_at=jnp.asarray(edge_down),
        edge_up_at=jnp.asarray(edge_up),
        **model_kwargs,
    )


def dumbbell_bridge_mask(
    n: int, clique_n: int, path_len: int = 1
) -> np.ndarray:
    """Side assignment splitting ``graphs.dumbbell(clique_n, path_len)``
    at the middle of its bridge (clique A + the first half of the chain
    vs the rest), for :func:`partition_groups`."""
    if n != 2 * clique_n + path_len:
        raise ValueError(
            f"n={n} is not a dumbbell({clique_n},{path_len}) node count "
            f"({2 * clique_n + path_len})"
        )
    side = np.zeros(n, bool)
    side[clique_n + (path_len + 1) // 2:] = True
    return side
