"""Graph substrate for random-walk decentralized learning.

The paper studies sparse communication graphs (ring, 2-D grid, Watts-Strogatz,
Erdos-Renyi); the entrapment literature adds hub/bottleneck topologies
(Barabasi-Albert, stochastic block models, dumbbell, lollipop).  Every node
has a self-loop (paper §II.A).  We keep three representations:

* a dense adjacency matrix (numpy, ``float64``) used to *construct* transition
  matrices and compute spectral quantities offline — only materialized for
  :class:`Graph`, i.e. analysis-scale topologies;
* a CSR pair ``(indptr, indices)`` — the O(E) ground truth of
  :class:`CSRGraph`, the large-graph representation (no N×N array ever
  exists on this path);
* a padded neighbor-list tensor (``int32`` of shape ``(n, max_deg)`` plus a
  degree vector) used *inside* jitted walk steps and the Pallas transition
  kernels, where ragged structures are not representable.  Both graph
  classes carry it, with identical ordering (ascending node id per row,
  pads repeating the row's own id), so a walk sampled on ``g`` and on
  ``g.to_csr()`` is bitwise identical; and
* a degree-bucketed ragged form, :class:`BucketedCSRGraph`
  (``CSRGraph.to_bucketed()``): rows grouped into power-of-two degree
  buckets, each bucket padded only to its own width — so a degree-1000 hub
  inflates its bucket, not all n rows.  Bucket rows are column-truncations
  of the shared padded rows (same ordering, same pad convention), which is
  what makes walks on the bucketed layout bitwise-identical to the padded
  layouts (see ``docs/layouts.md``); and
* the bare CSR core, :class:`RaggedCSRGraph` (``to_ragged()`` on either
  sparse class, or ``from_edges(layout="ragged")``): exactly
  ``indptr``/``indices``/``degrees`` and nothing else — no padded tensor
  and no per-bucket tables ever exist.  This is the substrate of the
  engine's ``layout="ragged"`` true-degree path, whose resident row state
  is a flat per-edge CDF buffer aligned with ``indices`` (O(E) exactly).

Construction is deterministic given a seed.  Builders that admit an O(E)
edge-list construction (``ring``, ``grid2d`` and the trap-prone families)
take ``layout="dense" | "csr"``; the dense layout routes through
``from_adjacency`` exactly as before, the csr layout never touches an N×N
array.  Every construction path ends in a ``validate()`` call, so
disconnected or asymmetric graphs fail loudly at build time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "Graph",
    "CSRGraph",
    "DegreeBucket",
    "BucketedCSRGraph",
    "RaggedCSRGraph",
    "EdgeChurn",
    "apply_edge_churn",
    "flat_edge_values",
    "ring",
    "grid2d",
    "watts_strogatz",
    "erdos_renyi",
    "star",
    "complete",
    "expander",
    "barabasi_albert",
    "sbm",
    "dumbbell",
    "lollipop",
    "from_adjacency",
    "from_edges",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected graph with self-loops, in both dense and padded forms.

    Attributes:
      adj: (n, n) float64 {0,1} adjacency, symmetric, unit diagonal.
      neighbors: (n, max_deg) int32 padded neighbor lists.  Row v holds the
        neighbor ids of v (including v itself, for the self-loop) in
        ascending order followed by padding that repeats v (so sampling a
        pad index is a harmless self-hop and probability masks make pads
        unreachable anyway).
      degrees: (n,) int32 true degrees (including the self-loop).
      name: human-readable description.
    """

    adj: np.ndarray
    neighbors: np.ndarray
    degrees: np.ndarray
    name: str = "graph"

    @property
    def n(self) -> int:
        return int(self.adj.shape[0])

    @property
    def max_degree(self) -> int:
        return int(self.neighbors.shape[1])

    @property
    def num_edges(self) -> int:
        """Directed edge count incl. self-loops (nnz of the adjacency)."""
        return int(self.degrees.astype(np.int64).sum())

    def validate(self) -> None:
        a = self.adj
        if a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if not np.allclose(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if not np.all(np.diag(a) == 1):
            raise ValueError("every node needs a self-loop (paper §II.A)")
        if not np.all((a == 0) | (a == 1)):
            raise ValueError("adjacency entries must be 0/1")
        if not _is_connected(a):
            raise ValueError("graph must be connected")
        deg = a.sum(axis=1).astype(np.int64)
        if not np.array_equal(deg, self.degrees.astype(np.int64)):
            raise ValueError("degree vector inconsistent with adjacency")

    def to_csr(self) -> "CSRGraph":
        """O(E) CSR view of this graph (shared padded-neighbor ordering)."""
        rows, cols = np.nonzero(self.adj)  # row-major => sorted per row
        counts = np.bincount(rows, minlength=self.n).astype(np.int64)
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        g = CSRGraph(
            indptr=indptr,
            indices=cols.astype(np.int32),
            degrees=self.degrees.copy(),
            neighbors=self.neighbors.copy(),
            name=self.name,
        )
        g.validate()
        return g


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """An undirected graph with self-loops in O(E) sparse form.

    The large-graph counterpart of :class:`Graph`: no dense N×N array is
    ever materialized.  Carries the same padded neighbor tensor (identical
    ordering), so :class:`repro.core.engine.WalkEngine` consumes either
    class interchangeably.

    Attributes:
      indptr: (n+1,) int64 CSR row pointers.
      indices: (nnz,) int32 neighbor ids, ascending within each row,
        including the self-loop.
      degrees: (n,) int32 true degrees (== diff(indptr)).
      neighbors: (n, max_deg) int32 padded neighbor lists (pads = row id).
      name: human-readable description.
    """

    indptr: np.ndarray
    indices: np.ndarray
    degrees: np.ndarray
    neighbors: np.ndarray
    name: str = "csr-graph"

    @property
    def n(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def max_degree(self) -> int:
        return int(self.neighbors.shape[1])

    @property
    def num_edges(self) -> int:
        """Directed edge count incl. self-loops (nnz of the CSR)."""
        return int(self.indices.shape[0])

    def row(self, v: int) -> np.ndarray:
        """True (unpadded) neighbor ids of node v."""
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def validate(self) -> None:
        _validate_csr_core(self.indptr, self.indices, self.degrees)
        expect = _pad_neighbor_lists(self.indptr, self.indices, self.degrees)
        if not np.array_equal(expect, self.neighbors):
            raise ValueError("padded neighbor tensor inconsistent with CSR")

    def to_csr(self) -> "CSRGraph":
        """Identity — lets callers normalize either graph class to CSR."""
        return self

    def apply_edge_churn(
        self, insert=None, delete=None, *, check_connectivity: bool = False
    ):
        """Batched incremental edge insert/delete — see
        :func:`apply_edge_churn`.  Returns ``(new_graph, EdgeChurn)``."""
        return apply_edge_churn(
            self, insert, delete, check_connectivity=check_connectivity
        )

    def to_bucketed(
        self, min_width: int = 8, bucket_factor: int = 2
    ) -> "BucketedCSRGraph":
        """Degree-bucketed ragged view with a geometric width ladder.

        Bucket widths are ``min_width, min_width·f, min_width·f², …``
        (clamped to ``max_degree``) with ``f = bucket_factor`` — ``f = 2``
        is the fine ladder (least padding per row, most buckets to
        dispatch), ``f = 4`` a coarser one (fewer per-bucket passes, more
        padding waste).  Each bucket's neighbor rows are padded only to its
        own width, so hub rows stop inflating the whole graph: storage
        drops from O(n·max_deg) to O(Σ_b n_b·width_b).  Bucket rows are
        column-truncations of this graph's padded rows, so walks on the
        bucketed layout stay bitwise-identical per key.
        """
        return _bucketed_from_csr_arrays(
            self.indptr.copy(), self.indices.copy(), self.degrees.copy(),
            min_width=min_width, bucket_factor=bucket_factor,
            name=self.name,
        )

    def to_ragged(self) -> "RaggedCSRGraph":
        """Bare-CSR-core view (drops the padded tensor; O(E) resident)."""
        g = RaggedCSRGraph(
            indptr=self.indptr.copy(),
            indices=self.indices.copy(),
            degrees=self.degrees.copy(),
            name=self.name,
        )
        g.validate()
        return g

    def to_dense(self) -> Graph:
        """Materialize the dense :class:`Graph` (analysis-scale only)."""
        n = self.n
        adj = np.zeros((n, n), dtype=np.float64)
        src = np.repeat(np.arange(n, dtype=np.int64), np.diff(self.indptr))
        adj[src, self.indices.astype(np.int64)] = 1.0
        g = Graph(
            adj=adj,
            neighbors=self.neighbors.copy(),
            degrees=self.degrees.copy(),
            name=self.name,
        )
        g.validate()
        return g


@dataclasses.dataclass(frozen=True)
class DegreeBucket:
    """One degree bucket of a :class:`BucketedCSRGraph`.

    Attributes:
      width: padded row width of this bucket (every member's degree ≤ width).
      node_ids: (n_b,) int32 member node ids, ascending.
      neighbors: (n_b, width) int32 padded neighbor rows — each row is the
        column-truncation of the member's full padded row (same ordering,
        pads repeat the node's own id).
    """

    width: int
    node_ids: np.ndarray
    neighbors: np.ndarray


@dataclasses.dataclass(frozen=True)
class BucketedCSRGraph:
    """Degree-bucketed ragged layout for hub-heavy graphs.

    The padded-neighbor contract costs O(n·max_deg): one degree-1000 hub in
    a 100k-node Barabási–Albert graph inflates all 100k rows.  Here rows
    are grouped into power-of-two degree buckets and padded per bucket, so
    total storage is O(E + Σ_b n_b·width_b) while each bucket row stays a
    column-truncation of the shared padded row — the property that keeps
    ``layout="bucketed"`` walks bitwise-identical to the other layouts.
    Built via :meth:`CSRGraph.to_bucketed`; ``to_csr()`` round-trips.

    Attributes:
      indptr/indices/degrees: the O(E) CSR core, identical to the source
        :class:`CSRGraph`.
      node_bucket: (n,) int32 bucket id per node.
      node_slot: (n,) int32 row index of the node inside its bucket.
      buckets: tuple of :class:`DegreeBucket`, widths strictly increasing.
      name: human-readable description.
    """

    indptr: np.ndarray
    indices: np.ndarray
    degrees: np.ndarray
    node_bucket: np.ndarray
    node_slot: np.ndarray
    buckets: tuple
    name: str = "bucketed-csr-graph"
    min_width: int = 8
    bucket_factor: int = 2

    @property
    def n(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def num_edges(self) -> int:
        """Directed edge count incl. self-loops (nnz of the CSR)."""
        return int(self.indices.shape[0])

    @property
    def bucket_widths(self) -> tuple:
        return tuple(b.width for b in self.buckets)

    def row(self, v: int) -> np.ndarray:
        """True (unpadded) neighbor ids of node v."""
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def validate(self) -> None:
        _validate_csr_core(self.indptr, self.indices, self.degrees)
        widths = np.asarray(self.bucket_widths, dtype=np.int64)
        if widths.size == 0 or np.any(np.diff(widths) <= 0):
            raise ValueError("bucket widths must be non-empty and increasing")
        deg = self.degrees.astype(np.int64)
        seen = np.zeros(self.n, dtype=np.int64)
        for b, bk in enumerate(self.buckets):
            ids = bk.node_ids.astype(np.int64)
            if np.any(np.diff(ids) <= 0):
                raise ValueError("bucket node_ids must be ascending")
            seen[ids] += 1
            if not np.array_equal(self.node_bucket[ids], np.full(ids.size, b)):
                raise ValueError("node_bucket inconsistent with bucket members")
            if not np.array_equal(
                self.node_slot[ids], np.arange(ids.size, dtype=np.int64)
            ):
                raise ValueError("node_slot inconsistent with bucket order")
            if np.any(deg[ids] > bk.width):
                raise ValueError("bucket member degree exceeds bucket width")
            if b > 0 and np.any(deg[ids] <= self.buckets[b - 1].width):
                raise ValueError(
                    "bucket member would fit in a smaller bucket"
                )
            expect = _pad_neighbor_lists(
                self.indptr, self.indices, self.degrees,
                node_ids=ids, width=bk.width,
            )
            if not np.array_equal(expect, bk.neighbors):
                raise ValueError("bucket neighbor rows inconsistent with CSR")
        if not np.all(seen == 1):
            raise ValueError("buckets must partition the node set")

    def to_csr(self) -> CSRGraph:
        """Round-trip back to the padded CSR layout (exact inverse of
        :meth:`CSRGraph.to_bucketed`)."""
        g = CSRGraph(
            indptr=self.indptr.copy(),
            indices=self.indices.copy(),
            degrees=self.degrees.copy(),
            neighbors=_pad_neighbor_lists(
                self.indptr, self.indices, self.degrees
            ),
            name=self.name,
        )
        g.validate()
        return g

    def to_bucketed(
        self, min_width: int = 8, bucket_factor: int = 2
    ) -> "BucketedCSRGraph":
        """Identity when the requested ladder matches this graph's; otherwise
        re-buckets straight from the CSR core (no padded table is built)."""
        if (min_width, bucket_factor) == (self.min_width, self.bucket_factor):
            return self
        return _bucketed_from_csr_arrays(
            self.indptr.copy(), self.indices.copy(), self.degrees.copy(),
            min_width=min_width, bucket_factor=bucket_factor,
            name=self.name,
        )

    def to_ragged(self) -> "RaggedCSRGraph":
        """Bare-CSR-core view (drops the per-bucket tables; O(E) resident)."""
        g = RaggedCSRGraph(
            indptr=self.indptr.copy(),
            indices=self.indices.copy(),
            degrees=self.degrees.copy(),
            name=self.name,
        )
        g.validate()
        return g

    def to_dense(self) -> Graph:
        """Materialize the dense :class:`Graph` (analysis-scale only)."""
        return self.to_csr().to_dense()


@dataclasses.dataclass(frozen=True)
class RaggedCSRGraph:
    """The bare CSR core — the zero-padding graph representation.

    Exactly ``indptr``/``indices``/``degrees``: no padded neighbor tensor,
    no per-bucket tables, nothing whose size depends on ``max_degree``.
    This is the substrate of the engine's ``layout="ragged"`` path, which
    reads every row from the flat arrays at its *true* degree — resident
    state is O(E) with no width factor at all, so one degree-10³ hub costs
    its own degree and nothing else.  Built via ``to_ragged()`` on
    :class:`CSRGraph` / :class:`BucketedCSRGraph` or directly with
    ``from_edges(layout="ragged")`` (the padded table is never
    materialized on that path); ``to_csr()`` round-trips exactly.

    Attributes:
      indptr: (n+1,) int64 CSR row pointers.
      indices: (nnz,) int32 neighbor ids, ascending within each row,
        including the self-loop.
      degrees: (n,) int32 true degrees (== diff(indptr)).
      name: human-readable description.
    """

    indptr: np.ndarray
    indices: np.ndarray
    degrees: np.ndarray
    name: str = "ragged-csr-graph"

    @property
    def n(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def num_edges(self) -> int:
        """Directed edge count incl. self-loops (nnz of the CSR)."""
        return int(self.indices.shape[0])

    def row(self, v: int) -> np.ndarray:
        """True (unpadded) neighbor ids of node v."""
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def validate(self) -> None:
        _validate_csr_core(self.indptr, self.indices, self.degrees)

    def apply_edge_churn(
        self, insert=None, delete=None, *, check_connectivity: bool = False
    ):
        """Batched incremental edge insert/delete — see
        :func:`apply_edge_churn`.  Returns ``(new_graph, EdgeChurn)``."""
        return apply_edge_churn(
            self, insert, delete, check_connectivity=check_connectivity
        )

    def to_ragged(self) -> "RaggedCSRGraph":
        """Identity — lets callers normalize any sparse class to the core."""
        return self

    def to_csr(self) -> CSRGraph:
        """Materialize the padded-tensor :class:`CSRGraph` (exact inverse
        of ``to_ragged()``)."""
        g = CSRGraph(
            indptr=self.indptr.copy(),
            indices=self.indices.copy(),
            degrees=self.degrees.copy(),
            neighbors=_pad_neighbor_lists(
                self.indptr, self.indices, self.degrees
            ),
            name=self.name,
        )
        g.validate()
        return g

    def to_bucketed(
        self, min_width: int = 8, bucket_factor: int = 2
    ) -> BucketedCSRGraph:
        """Degree-bucketed view straight from the core (no padded table)."""
        return _bucketed_from_csr_arrays(
            self.indptr.copy(), self.indices.copy(), self.degrees.copy(),
            min_width=min_width, bucket_factor=bucket_factor,
            name=self.name,
        )

    def to_dense(self) -> Graph:
        """Materialize the dense :class:`Graph` (analysis-scale only)."""
        return self.to_csr().to_dense()


def flat_edge_values(
    indptr: np.ndarray,
    degrees: np.ndarray,
    table: np.ndarray,
    node_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Flatten per-row padded values into the flat per-edge buffer.

    Given any ``(rows, width)`` array aligned with the padded neighbor
    rows (probabilities, CDFs, …), returns the ``(nnz,)`` buffer holding
    each row's first ``deg(v)`` entries at positions
    ``indptr[v] : indptr[v] + deg(v)`` — i.e. CSR edge order, aligned
    with ``indices``.  This is how the ragged layout stores row state
    with **no padding at all**: the padded table's pad columns carry
    exactly 0 and are simply dropped.  With ``node_ids`` the table covers
    only those rows (the chunked O(E) builders use this so the full
    padded table never has to exist at once).
    """
    if node_ids is None:
        node_ids = np.arange(indptr.shape[0] - 1, dtype=np.int64)
    deg = np.asarray(degrees, dtype=np.int64)[node_ids]
    if table.shape[0] != node_ids.shape[0] or table.shape[1] < int(
        deg.max(initial=0)
    ):
        raise ValueError("table shape inconsistent with the requested rows")
    mask = np.arange(table.shape[1])[None, :] < deg[:, None]
    return np.asarray(table)[mask]


def _ragged_row_chunks(n: int, max_deg: int, chunk_rows: Optional[int] = None):
    """Contiguous row-id chunks for the O(E) flat-buffer builders.

    THE chunking rule, shared by ``transition._rows_ragged`` and
    ``engine.ragged_edge_cdf`` so the two builders cannot drift: chunk
    size bounds the transient ``(chunk, max_deg)`` padded block at
    ~32 MB (floored at 256 rows), and each yielded ``ids`` array is a
    contiguous ascending range — so a chunk's flat output occupies
    exactly ``indptr[ids[0]] : indptr[ids[-1] + 1]``.
    """
    if chunk_rows is None:
        chunk_rows = max(256, min(n, (32 << 20) // max(1, 4 * max_deg)))
    for a in range(0, n, chunk_rows):
        yield np.arange(a, min(a + chunk_rows, n), dtype=np.int64)


# ---------------------------------------------------------------------------
# Construction machinery (dense + O(E) edge-list paths)
# ---------------------------------------------------------------------------


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        v = stack.pop()
        for u in np.nonzero(adj[v])[0]:
            if not seen[u]:
                seen[u] = True
                stack.append(int(u))
    return bool(seen.all())


def _concat_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Vectorized concatenation of ``[arange(s, s+c) for s, c in zip(...)]``."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out = np.ones(total, dtype=np.int64)
    cum = np.cumsum(counts)
    out[0] = starts[0]
    out[cum[:-1]] = starts[1:] - (starts[:-1] + counts[:-1] - 1)
    return np.cumsum(out)


def _csr_is_connected(indptr: np.ndarray, indices: np.ndarray) -> bool:
    """BFS over the CSR structure — O(E) total, no dense matrix."""
    n = indptr.shape[0] - 1
    seen = np.zeros(n, dtype=bool)
    seen[0] = True
    frontier = np.array([0], dtype=np.int64)
    while frontier.size:
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        nbrs = indices[_concat_ranges(starts, counts)]
        new = np.unique(nbrs[~seen[nbrs]])
        seen[new] = True
        frontier = new
    return bool(seen.all())


def _validate_csr_core(
    indptr: np.ndarray, indices: np.ndarray, degrees: np.ndarray
) -> None:
    """Structural CSR checks shared by :class:`CSRGraph` and
    :class:`BucketedCSRGraph`: degree consistency, sortedness, symmetry,
    self-loops, connectivity.  Raises ``ValueError`` on the first failure."""
    n = indptr.shape[0] - 1
    deg = np.diff(indptr)
    if not np.array_equal(deg, degrees.astype(np.int64)):
        raise ValueError("degree vector inconsistent with indptr")
    if int(deg.min(initial=1)) < 1:
        raise ValueError("every node needs a self-loop (paper §II.A)")
    if indices.shape[0] != int(indptr[-1]):
        raise ValueError("indices length inconsistent with indptr")
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    dst = indices.astype(np.int64)
    if np.any(dst < 0) or np.any(dst >= n):
        raise ValueError("neighbor ids out of range")
    codes = src * n + dst
    if np.any(np.diff(codes) <= 0):
        raise ValueError("CSR rows must be sorted and duplicate-free")
    if not np.array_equal(np.sort(dst * n + src), codes):
        raise ValueError("edge set must be symmetric (undirected graph)")
    self_codes = np.arange(n, dtype=np.int64) * (n + 1)
    pos = np.searchsorted(codes, self_codes)
    if np.any(pos >= codes.shape[0]) or np.any(codes[pos] != self_codes):
        raise ValueError("every node needs a self-loop (paper §II.A)")
    if not _csr_is_connected(indptr, indices):
        raise ValueError("graph must be connected")


def _edges_to_csr(n: int, src: np.ndarray, dst: np.ndarray):
    """Symmetrize + add self-loops + dedupe an edge list into sorted CSR.

    Endpoints are assumed range-checked by ``from_edges``, the only caller.
    """
    keep = src != dst  # self-loops are added uniformly below
    src, dst = src[keep], dst[keep]
    loops = np.arange(n, dtype=np.int64)
    a = np.concatenate([src, dst, loops])
    b = np.concatenate([dst, src, loops])
    codes = np.unique(a * n + b)  # sorted row-major == sorted CSR
    rows = codes // n
    indices = (codes % n).astype(np.int32)
    degrees = np.bincount(rows, minlength=n).astype(np.int32)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(degrees, out=indptr[1:])
    return indptr, indices, degrees


def _pad_neighbor_lists(
    indptr: np.ndarray,
    indices: np.ndarray,
    degrees: np.ndarray,
    node_ids: Optional[np.ndarray] = None,
    width: Optional[int] = None,
) -> np.ndarray:
    """Padded neighbor rows from CSR; pads repeat the row's own id.

    Default: the full ``(n, max_deg)`` tensor.  With ``node_ids``/``width``
    only those rows are materialized at the requested width — the bucketed
    layout uses this to pad each degree bucket to its own width instead of
    the global ``max_deg``.  Row contents are identical either way (a
    bucket row is a column-truncation of the full padded row), which is
    the ordering contract every walk layout shares.
    """
    if node_ids is None:
        node_ids = np.arange(indptr.shape[0] - 1, dtype=np.int64)
    deg = np.asarray(degrees, dtype=np.int64)[node_ids]
    width = int(deg.max()) if width is None else int(width)
    out = np.repeat(node_ids.astype(np.int32)[:, None], width, axis=1)
    mask = np.arange(width)[None, :] < deg[:, None]
    out[mask] = indices[_concat_ranges(indptr[node_ids], deg)]
    return out


def _bucket_widths_ladder(
    max_deg: int, min_width: int, bucket_factor: int
) -> np.ndarray:
    """The geometric bucket-width ladder: min_width · bucket_factor^k,
    clamped to ``max_deg``.  The last rung is always exactly ``max_deg`` so
    no degree overflows its bucket."""
    if min_width < 1:
        raise ValueError("min_width must be >= 1")
    if bucket_factor < 2:
        raise ValueError("bucket_factor must be >= 2")
    ladder = [min_width]
    while ladder[-1] < max_deg:
        ladder.append(ladder[-1] * bucket_factor)
    return np.minimum(np.asarray(ladder, dtype=np.int64), max_deg)


def _bucketed_from_csr_arrays(
    indptr: np.ndarray,
    indices: np.ndarray,
    degrees: np.ndarray,
    *,
    min_width: int,
    bucket_factor: int,
    name: str,
) -> "BucketedCSRGraph":
    """Degree-bucketed graph straight from a validated CSR core.

    This is the bounded-memory construction path: only the per-bucket
    padded rows are ever materialized — never the full ``(n, max_deg)``
    tensor — so a 1M-node hub-heavy graph buckets in O(E + Σ_b n_b·width_b)
    instead of the multi-GB padded table.  No full ``validate()`` here: the
    CSR core is validated by every caller, and the bucket invariants
    (partition, ascending ids, slot order, width bounds, truncation) hold
    by construction; ``validate()`` remains the from-scratch audit for
    hand-built instances/tests.
    """
    deg = np.asarray(degrees, dtype=np.int64)
    max_deg = int(deg.max())
    ladder = _bucket_widths_ladder(max_deg, min_width, bucket_factor)
    width_of = ladder[np.searchsorted(ladder, deg, side="left")]
    widths = np.unique(width_of)
    node_bucket = np.searchsorted(widths, width_of).astype(np.int32)
    node_slot = np.empty(deg.size, dtype=np.int32)
    buckets = []
    for b, w in enumerate(widths):
        ids = np.nonzero(node_bucket == b)[0]  # ascending node ids
        node_slot[ids] = np.arange(ids.size, dtype=np.int32)
        buckets.append(
            DegreeBucket(
                width=int(w),
                node_ids=ids.astype(np.int32),
                neighbors=_pad_neighbor_lists(
                    indptr, indices, degrees, node_ids=ids, width=int(w)
                ),
            )
        )
    return BucketedCSRGraph(
        indptr=indptr,
        indices=indices,
        degrees=degrees,
        node_bucket=node_bucket,
        node_slot=node_slot,
        buckets=tuple(buckets),
        name=name,
        min_width=min_width,
        bucket_factor=bucket_factor,
    )


def from_adjacency(adj: np.ndarray, name: str = "graph") -> Graph:
    """Build a :class:`Graph` from a 0/1 adjacency; adds self-loops if absent."""
    adj = np.asarray(adj, dtype=np.float64).copy()
    np.fill_diagonal(adj, 1.0)
    adj = np.maximum(adj, adj.T)  # symmetrize
    degrees = adj.sum(axis=1).astype(np.int32)
    max_deg = int(degrees.max())
    n = adj.shape[0]
    neighbors = np.empty((n, max_deg), dtype=np.int32)
    for v in range(n):
        nbrs = np.nonzero(adj[v])[0].astype(np.int32)
        pad = np.full(max_deg - len(nbrs), v, dtype=np.int32)
        neighbors[v] = np.concatenate([nbrs, pad])
    g = Graph(adj=adj, neighbors=neighbors, degrees=degrees, name=name)
    g.validate()
    return g


def from_edges(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    *,
    name: str = "graph",
    layout: str = "csr",
    bucket_factor: int = 2,
):
    """Build a graph from an undirected edge list (self-loops added).

    ``layout="csr"`` is the O(E) path — no N×N array is ever created;
    ``layout="bucketed"`` builds the degree-bucketed ragged layout
    *directly from the CSR core* (bounded-memory: the full ``(n, max_deg)``
    padded table is never materialized, which is what lets 1M-node
    hub-heavy graphs construct on a single host); ``layout="ragged"``
    keeps only the bare CSR core (:class:`RaggedCSRGraph` — neither the
    padded nor any per-bucket table ever exists, the strictest
    bounded-memory path and the substrate of the engine's true-degree
    layout); and ``layout="dense"`` routes through :func:`from_adjacency`
    for the analysis stack.  ``bucket_factor`` picks the bucket-width
    ladder of the bucketed layout (see :meth:`CSRGraph.to_bucketed`).
    All validate on construction (connectivity included), so an invalid
    edge set fails loudly here rather than corrupting a walk.
    """
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    if src.shape != dst.shape:
        raise ValueError("src/dst edge arrays must have the same length")
    if src.size and (
        min(src.min(), dst.min()) < 0 or max(src.max(), dst.max()) >= n
    ):
        raise ValueError("edge endpoints out of range")
    if layout == "dense":
        adj = np.zeros((n, n), dtype=np.float64)
        adj[src, dst] = 1.0
        return from_adjacency(adj, name=name)
    if layout not in ("csr", "bucketed", "ragged"):
        raise ValueError(
            f"layout must be 'dense', 'csr', 'bucketed' or 'ragged', "
            f"got {layout!r}"
        )
    indptr, indices, degrees = _edges_to_csr(n, src, dst)
    return _csr_graph_from_arrays(
        indptr, indices, degrees, name, layout, bucket_factor=bucket_factor
    )


def _csr_graph_from_arrays(
    indptr: np.ndarray,
    indices: np.ndarray,
    degrees: np.ndarray,
    name: str,
    layout: str,
    bucket_factor: int = 2,
):
    """Validated graph from already-built CSR arrays (no recomputation)."""
    if layout not in ("dense", "csr", "bucketed", "ragged"):
        raise ValueError(
            f"layout must be 'dense', 'csr', 'bucketed' or 'ragged', "
            f"got {layout!r}"
        )
    if layout == "bucketed":
        # bounded-memory path: validate the CSR core, then bucket directly —
        # the (n, max_deg) padded tensor is never built
        _validate_csr_core(indptr, indices, degrees)
        return _bucketed_from_csr_arrays(
            indptr, indices, degrees,
            min_width=8, bucket_factor=bucket_factor, name=name,
        )
    if layout == "ragged":
        # strictest bounded-memory path: the CSR core IS the graph — no
        # padded tensor, no bucket tables, nothing sized by max_degree
        _validate_csr_core(indptr, indices, degrees)
        return RaggedCSRGraph(
            indptr=indptr, indices=indices, degrees=degrees, name=name
        )
    g = CSRGraph(
        indptr=indptr,
        indices=indices,
        degrees=degrees,
        neighbors=_pad_neighbor_lists(indptr, indices, degrees),
        name=name,
    )
    g.validate()
    return g.to_dense() if layout == "dense" else g


# ---------------------------------------------------------------------------
# Dynamic graphs: batched incremental edge churn
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EdgeChurn:
    """Receipt of one batched insert/delete applied by :func:`apply_edge_churn`.

    Everything downstream of a churn keys off this receipt: the engine's
    incremental CDF update recomputes exactly ``touched_rows``, the fleet's
    continuity rule re-seeds exactly the walks standing on departed nodes.

    Attributes:
      inserted: (k_i, 2) int64 undirected pairs inserted, canonical
        ``(min, max)`` orientation, sorted by pair code.
      deleted: (k_d, 2) int64 undirected pairs deleted, same form.
      endpoints: unique ascending int64 node ids incident to any churned
        edge — the rows whose neighbor lists changed.
      degree_changed: the subset of ``endpoints`` whose degree actually
        changed (a node that gained and lost equally many edges keeps its
        degree but still appears in ``endpoints``).
      touched_rows: unique ascending int64 node ids whose flat per-edge
        row state (probabilities / CDF segments) must be recomputed:
        ``endpoints`` plus every *new-graph* neighbor of a node in
        ``degree_changed`` — MH acceptance (Eq. 7) reads *neighbor*
        degrees, so a degree change at u invalidates every row containing
        u, not just u's own row.
      num_edges_before/num_edges_after: directed nnz incl. self-loops.
    """

    inserted: np.ndarray
    deleted: np.ndarray
    endpoints: np.ndarray
    degree_changed: np.ndarray
    touched_rows: np.ndarray
    num_edges_before: int
    num_edges_after: int


def _canonical_pairs(pairs, n: int, tag: str) -> np.ndarray:
    """Validate an undirected pair batch into canonical sorted (k, 2) form.

    Strict contract (misuse fails loudly, never silently repairs): pairs
    must be (k, 2) node ids in range, no self-pairs (self-loops are
    structural, paper §II.A) and no duplicate undirected pairs.  Output
    rows are ``(min, max)`` sorted ascending by pair code ``lo*n + hi``.
    """
    if pairs is None:
        return np.empty((0, 2), dtype=np.int64)
    arr = np.asarray(pairs, dtype=np.int64)
    if arr.size == 0:
        return np.empty((0, 2), dtype=np.int64)
    if arr.ndim != 2 or arr.shape[1] != 2:
        raise ValueError(f"{tag} must be a (k, 2) array of node pairs")
    if arr.min() < 0 or arr.max() >= n:
        raise ValueError(f"{tag} endpoints out of range for n={n}")
    if np.any(arr[:, 0] == arr[:, 1]):
        raise ValueError(
            f"{tag} contains a self-loop; self-loops are structural "
            "(paper §II.A) and cannot be churned"
        )
    lo = np.minimum(arr[:, 0], arr[:, 1])
    hi = np.maximum(arr[:, 0], arr[:, 1])
    order = np.argsort(lo * n + hi, kind="stable")
    lo, hi = lo[order], hi[order]
    if np.any((np.diff(lo) == 0) & (np.diff(hi) == 0)):
        raise ValueError(f"{tag} contains duplicate undirected pairs")
    return np.stack([lo, hi], axis=1)


def _directed_codes(pairs: np.ndarray, n: int) -> np.ndarray:
    """Sorted int64 ``src*n + dst`` codes for both orientations of each
    undirected pair — the CSR edge-code space of :func:`_validate_csr_core`."""
    a = np.concatenate([pairs[:, 0], pairs[:, 1]])
    b = np.concatenate([pairs[:, 1], pairs[:, 0]])
    return np.sort(a * n + b)


def apply_edge_churn(
    graph,
    insert=None,
    delete=None,
    *,
    check_connectivity: bool = False,
):
    """Apply a batched undirected edge insert/delete to a sparse graph.

    Returns ``(new_graph, churn)`` where ``new_graph`` is the same class as
    ``graph`` (:class:`CSRGraph` or :class:`RaggedCSRGraph`) over the
    churned edge set and ``churn`` is the :class:`EdgeChurn` receipt that
    drives the engine's incremental CDF update
    (:func:`repro.core.engine.ragged_edge_cdf_update`) and the fleet's
    walk-continuity rule (:func:`repro.walk_sgd.fleet.migrate_walk_nodes`).

    The whole update is O(E + k) linear passes over the sorted edge-code
    array — no re-sort of the full edge list, which is what makes the
    incremental path beat a :func:`from_edges` rebuild (O(E log E) through
    ``np.unique``) by the benchmarked margin.  The new CSR core is sorted,
    symmetric and self-looped **by construction** (deletes mask both
    orientations out of a sorted array, inserts merge both orientations
    in at their searchsorted positions, self-loop codes are untouchable),
    so — like :func:`_bucketed_from_csr_arrays` — no full ``validate()``
    runs here; ``validate()`` on the result remains the from-scratch
    audit and the differential tests pin it.

    Strict batch contract, enforced before anything is modified: deleting
    an absent edge, inserting a present one, self-pairs, duplicate pairs,
    out-of-range ids, or an insert∩delete overlap all raise ``ValueError``.
    Deleting a node's last non-loop edge is allowed — the node "departs"
    (degree 1, self-loop only) but stays a valid row; by default the
    connectivity invariant is deferred to the caller (a departed node
    makes the graph technically disconnected for the walk), pass
    ``check_connectivity=True`` to fail loudly instead.
    """
    if not isinstance(graph, (CSRGraph, RaggedCSRGraph)):
        raise TypeError(
            "apply_edge_churn needs a CSRGraph or RaggedCSRGraph, got "
            f"{type(graph).__name__}; convert dense/bucketed graphs via "
            "to_csr()/to_ragged() first"
        )
    n = graph.n
    ins = _canonical_pairs(insert, n, "insert")
    dele = _canonical_pairs(delete, n, "delete")
    if ins.shape[0] and dele.shape[0]:
        overlap = np.intersect1d(
            ins[:, 0] * n + ins[:, 1], dele[:, 0] * n + dele[:, 1]
        )
        if overlap.size:
            raise ValueError(
                "insert and delete batches overlap on "
                f"{overlap.size} pair(s); resolve the net churn first"
            )

    indptr = np.asarray(graph.indptr, dtype=np.int64)
    deg_old = np.diff(indptr)
    nnz_old = int(graph.indices.shape[0])
    codes_old = (
        np.repeat(np.arange(n, dtype=np.int64), deg_old) * n
        + graph.indices.astype(np.int64)
    )  # sorted by the CSR invariant

    kept = codes_old
    if dele.shape[0]:
        del_codes = _directed_codes(dele, n)
        pos = np.searchsorted(codes_old, del_codes)
        if np.any(pos >= nnz_old) or np.any(codes_old[pos] != del_codes):
            raise ValueError(
                "delete batch contains an edge not present in the graph"
            )
        mask = np.ones(nnz_old, dtype=bool)
        mask[pos] = False
        kept = codes_old[mask]
    if ins.shape[0]:
        ins_codes = _directed_codes(ins, n)
        pos = np.searchsorted(kept, ins_codes)
        clamped = np.minimum(pos, kept.shape[0] - 1)
        if kept.size and np.any(kept[clamped] == ins_codes):
            raise ValueError(
                "insert batch contains an edge already present in the graph"
            )
        new_codes = np.insert(kept, pos, ins_codes)
    else:
        new_codes = kept

    new_rows = new_codes // n
    new_indices = (new_codes % n).astype(np.int32)
    new_degrees = np.bincount(new_rows, minlength=n).astype(np.int32)
    new_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(new_degrees, out=new_indptr[1:])
    if check_connectivity and not _csr_is_connected(new_indptr, new_indices):
        raise ValueError("churn disconnects the graph")

    if ins.shape[0] or dele.shape[0]:
        endpoints = np.unique(np.concatenate([ins.ravel(), dele.ravel()]))
    else:
        endpoints = np.empty(0, dtype=np.int64)
    deg_new64 = new_degrees.astype(np.int64)
    degree_changed = endpoints[deg_new64[endpoints] != deg_old[endpoints]]
    if degree_changed.size:
        nbrs = new_indices[
            _concat_ranges(new_indptr[degree_changed], deg_new64[degree_changed])
        ].astype(np.int64)
        touched_rows = np.unique(np.concatenate([endpoints, nbrs]))
    else:
        touched_rows = endpoints

    churn = EdgeChurn(
        inserted=ins,
        deleted=dele,
        endpoints=endpoints,
        degree_changed=degree_changed,
        touched_rows=touched_rows,
        num_edges_before=nnz_old,
        num_edges_after=int(new_codes.shape[0]),
    )

    if isinstance(graph, RaggedCSRGraph):
        new_graph = RaggedCSRGraph(
            indptr=new_indptr,
            indices=new_indices,
            degrees=new_degrees,
            name=graph.name,
        )
        return new_graph, churn

    # CSRGraph: patch the padded tensor in place when the width survives —
    # only endpoint rows changed (pads repeat the row's own id, so a row
    # with an unchanged neighbor list is bitwise-identical at fixed width)
    old_width = int(graph.neighbors.shape[1])
    new_width = int(deg_new64.max())
    if new_width == old_width:
        neighbors = graph.neighbors.copy()
        if endpoints.size:
            neighbors[endpoints] = _pad_neighbor_lists(
                new_indptr, new_indices, new_degrees,
                node_ids=endpoints, width=old_width,
            )
    else:
        neighbors = _pad_neighbor_lists(new_indptr, new_indices, new_degrees)
    new_graph = CSRGraph(
        indptr=new_indptr,
        indices=new_indices,
        degrees=new_degrees,
        neighbors=neighbors,
        name=graph.name,
    )
    return new_graph, churn


# ---------------------------------------------------------------------------
# Paper topologies
# ---------------------------------------------------------------------------


def ring(n: int, layout: str = "dense", bucket_factor: int = 2):
    """Ring of n nodes — the paper's canonical entrapment topology (Fig 2a)."""
    if n < 3:
        raise ValueError("ring needs n >= 3")
    idx = np.arange(n, dtype=np.int64)
    return from_edges(
        n, idx, (idx + 1) % n, name=f"ring({n})", layout=layout,
        bucket_factor=bucket_factor,
    )


def grid2d(
    rows: int,
    cols: Optional[int] = None,
    layout: str = "dense",
    bucket_factor: int = 2,
):
    """2-D grid (paper Fig 5a uses ~1000 nodes)."""
    cols = cols or rows
    n = rows * cols
    ids = np.arange(n, dtype=np.int64).reshape(rows, cols)
    src = np.concatenate([ids[:, :-1].ravel(), ids[:-1, :].ravel()])
    dst = np.concatenate([ids[:, 1:].ravel(), ids[1:, :].ravel()])
    return from_edges(
        n, src, dst, name=f"grid2d({rows}x{cols})", layout=layout,
        bucket_factor=bucket_factor,
    )


def watts_strogatz(n: int, k: int, p: float, seed: int = 0) -> Graph:
    """Watts-Strogatz small world (paper Fig 5b: WS(1000, 4, 0.1)).

    Standard construction: ring lattice with k nearest neighbors (k even),
    each "forward" edge rewired with probability p (no self/multi edges).
    Connectivity is checked *before* handing the adjacency to the validating
    constructor, so an unlucky rewiring retries with the next seed instead
    of raising out of ``from_adjacency``.
    """
    if k % 2 != 0 or k >= n:
        raise ValueError("watts_strogatz requires even k < n")
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n))
    for v in range(n):
        for j in range(1, k // 2 + 1):
            adj[v, (v + j) % n] = 1
            adj[(v + j) % n, v] = 1
    for v in range(n):
        for j in range(1, k // 2 + 1):
            if rng.random() < p:
                u = (v + j) % n
                # rewire edge (v, u) -> (v, w)
                candidates = np.nonzero((adj[v] == 0))[0]
                candidates = candidates[candidates != v]
                if len(candidates) == 0:
                    continue
                w = int(rng.choice(candidates))
                adj[v, u] = adj[u, v] = 0
                adj[v, w] = adj[w, v] = 1
    if not _is_connected(np.maximum(adj, np.eye(n))):  # unlikely; retry
        return watts_strogatz(n, k, p, seed=seed + 1)
    return from_adjacency(adj, name=f"ws({n},{k},{p})")  # validates


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """Erdos-Renyi G(n, p) (paper Fig 4 uses ER(1000, 0.1)); resamples until connected."""
    rng = np.random.default_rng(seed)
    for attempt in range(64):
        upper = rng.random((n, n)) < p
        adj = np.triu(upper, k=1).astype(np.float64)
        adj = adj + adj.T
        if _is_connected(np.maximum(adj, np.eye(n))):
            return from_adjacency(adj, name=f"er({n},{p})")  # validates
    raise RuntimeError(f"could not sample a connected ER({n},{p}) in 64 tries")


def star(n: int) -> Graph:
    """Star graph — worst-case hub topology, useful in entrapment tests."""
    adj = np.zeros((n, n))
    adj[0, 1:] = 1
    adj[1:, 0] = 1
    return from_adjacency(adj, name=f"star({n})")


def complete(n: int) -> Graph:
    """Complete graph — the centralized-equivalent reference topology."""
    adj = np.ones((n, n))
    return from_adjacency(adj, name=f"complete({n})")


def expander(n: int, d: int = 6, seed: int = 0) -> Graph:
    """Random d-regular-ish expander via union of d/2 random perfect matchings.

    Good conductance — a control topology where entrapment should NOT occur.
    """
    if n % 2 != 0:
        raise ValueError("expander builder needs even n")
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n))
    for _ in range(max(1, d // 2)):
        perm = rng.permutation(n)
        for i in range(0, n, 2):
            a, b = perm[i], perm[i + 1]
            adj[a, b] = adj[b, a] = 1
    # also add a ring to guarantee connectivity
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = 1
    adj[(idx + 1) % n, idx] = 1
    return from_adjacency(adj, name=f"expander({n},{d})")  # validates


# ---------------------------------------------------------------------------
# Trap-prone families from the entrapment literature (O(E) constructions)
# ---------------------------------------------------------------------------


def barabasi_albert(
    n: int, m: int, seed: int = 0, layout: str = "dense",
    bucket_factor: int = 2,
):
    """Barabasi-Albert preferential attachment: hubs = degree-bias traps.

    Batagelj–Brandes repeated-nodes construction, fully vectorized: edge
    ``e`` of new node ``v`` picks a uniform position of the repeated
    endpoint list built by all *earlier* nodes' edges (each endpoint
    appears once per incident edge, so the pick is degree-proportional),
    and the position→endpoint indirection is resolved by vectorized
    pointer chasing instead of a per-node Python loop.  Draws landing on
    an odd position point at an earlier edge's *target*, whose own draw
    strictly precedes it, so chains shrink monotonically and resolve in
    O(log) numpy passes — the whole build is O(n m) array work (a 1M-node
    graph builds in ~1 s vs ~22 s for the former per-node loop; the
    benchmark JSON's ``construction_sec`` field tracks this).  Duplicate
    picks within a node collapse (every node still attaches to ≥ 1
    earlier node, so the graph stays connected by construction); node
    ``m`` seeds the process by attaching to all of ``0..m-1``.
    """
    if not (1 <= m < n):
        raise ValueError("barabasi_albert requires 1 <= m < n")
    rng = np.random.default_rng(seed)
    num_edges = m * (n - m)
    # source of edge e is node m + e//m; the first m edges (node m's) are
    # the deterministic seed attachments to 0..m-1
    src = m + np.arange(num_edges, dtype=np.int64) // m
    # edge e of node v draws a repeated-list position in [0, 2m(v-m)) —
    # the list state before node v's own edges, so no self-attachment.
    # Position 2e' is edge e''s source, position 2e'+1 its target.
    bound = 2 * m * (src - m)
    pos = np.zeros(num_edges, dtype=np.int64)
    if num_edges > m:
        pos[m:] = rng.integers(0, bound[m:])
    # resolve the indirection: odd positions point at target(e') for
    # e' = (pos-1)//2, whose own pos strictly precedes — chase until every
    # pointer lands on an even position (a known source) or a seed edge
    # (target e' < m is the literal node e').  Chains shrink by at least
    # half the position each hop, so this loop runs O(log) times.
    while True:
        e_prev = (pos - 1) // 2
        unresolved = (pos % 2 == 1) & (e_prev >= m)
        if not unresolved.any():
            break
        pos[unresolved] = pos[e_prev[unresolved]]
    dst = np.where(pos % 2 == 0, m + (pos // 2) // m, (pos - 1) // 2)
    dst[:m] = np.arange(m)  # the seed attachments
    return from_edges(
        n,
        src,
        dst,
        name=f"ba({n},{m})",
        layout=layout,
        bucket_factor=bucket_factor,
    )


def _tri_decode(codes: np.ndarray, s: int):
    """Decode c in [0, s(s-1)/2) to the c-th pair (i, j), i < j, row-major."""
    c = codes.astype(np.float64)
    i = np.floor((2 * s - 1 - np.sqrt((2 * s - 1) ** 2 - 8 * c)) / 2).astype(
        np.int64
    )

    def rowstart(k):
        return k * s - k * (k + 1) // 2

    i[codes < rowstart(i)] -= 1  # fix sqrt rounding either way
    i[codes >= rowstart(i + 1)] += 1
    j = codes - rowstart(i) + i + 1
    return i, j


def _sample_distinct_codes(rng, pairs: int, count: int) -> np.ndarray:
    """``count`` distinct uniform draws from [0, pairs) without ever
    allocating O(pairs) (the permutation path of ``choice(replace=False)``):
    draw with replacement and top up the deficit until all are distinct."""
    codes = np.unique(rng.integers(0, pairs, size=count))
    while codes.size < count:
        extra = rng.integers(0, pairs, size=count - codes.size)
        codes = np.unique(np.concatenate([codes, extra]))
    return codes


def sbm(
    block_sizes: Sequence[int],
    p_in: float,
    p_out: float,
    seed: int = 0,
    layout: str = "dense",
    bucket_factor: int = 2,
):
    """Stochastic block model with tunable inter-cluster bottlenecks.

    Dense intra-block connectivity (``p_in``) with a thin ``p_out`` cut
    between blocks — the canonical conductance-bottleneck topology where
    a random walk gets trapped inside a cluster.  Edges are sampled
    sparsely per block pair — a Binomial(pairs, p) count, then that many
    *distinct* uniform pair codes — so each pair is present i.i.d. with
    the exact requested probability while construction stays O(E), never
    O(N^2); resamples until connected.
    """
    sizes = np.asarray(block_sizes, dtype=np.int64)
    if sizes.ndim != 1 or sizes.size < 1 or np.any(sizes < 1):
        raise ValueError("block_sizes must be a non-empty list of positive ints")
    for q, tag in ((p_in, "p_in"), (p_out, "p_out")):
        if not (0.0 <= q <= 1.0):
            raise ValueError(f"{tag} must be in [0,1], got {q}")
    n = int(sizes.sum())
    offs = np.zeros(sizes.size, dtype=np.int64)
    np.cumsum(sizes[:-1], out=offs[1:])
    name = f"sbm({list(map(int, sizes))},{p_in},{p_out})"
    for attempt in range(64):
        rng = np.random.default_rng(seed + 9973 * attempt)
        src_parts, dst_parts = [], []
        for a in range(sizes.size):
            s_a = int(sizes[a])
            pairs = s_a * (s_a - 1) // 2
            if pairs and p_in > 0:
                count = rng.binomial(pairs, p_in)
                if count:
                    codes = _sample_distinct_codes(rng, pairs, count)
                    i, j = _tri_decode(codes, s_a)
                    src_parts.append(i + offs[a])
                    dst_parts.append(j + offs[a])
            for b in range(a + 1, sizes.size):
                s_b = int(sizes[b])
                count = rng.binomial(s_a * s_b, p_out)
                if count:
                    codes = _sample_distinct_codes(rng, s_a * s_b, count)
                    src_parts.append(codes // s_b + offs[a])
                    dst_parts.append(codes % s_b + offs[b])
        src = np.concatenate(src_parts) if src_parts else np.empty(0, np.int64)
        dst = np.concatenate(dst_parts) if dst_parts else np.empty(0, np.int64)
        # pre-check connectivity on the O(E) CSR structure so a disconnected
        # draw resamples instead of raising out of the validating
        # constructor; the arrays are then reused, not recomputed
        indptr, indices, degrees = _edges_to_csr(n, src, dst)
        if _csr_is_connected(indptr, indices):
            return _csr_graph_from_arrays(
                indptr, indices, degrees, name, layout,
                bucket_factor=bucket_factor,
            )
    raise RuntimeError(f"could not sample a connected {name} in 64 tries")


def dumbbell(
    clique_n: int, path_len: int = 1, layout: str = "dense",
    bucket_factor: int = 2,
):
    """Two ``clique_n``-cliques joined by a ``path_len``-node path.

    The textbook worst case for random-walk escape times: the bridge is a
    single-edge bottleneck, so a walk entering one bell is trapped for
    Omega(clique_n^2) expected steps.  ``path_len=0`` joins the cliques by
    a direct edge.
    """
    if clique_n < 3:
        raise ValueError("dumbbell needs clique_n >= 3")
    if path_len < 0:
        raise ValueError("dumbbell needs path_len >= 0")
    n = 2 * clique_n + path_len
    iu, ju = np.triu_indices(clique_n, k=1)
    off_b = clique_n + path_len
    chain = np.concatenate(
        [[clique_n - 1], np.arange(clique_n, off_b), [off_b]]
    )
    src = np.concatenate([iu, iu + off_b, chain[:-1]])
    dst = np.concatenate([ju, ju + off_b, chain[1:]])
    return from_edges(
        n, src, dst, name=f"dumbbell({clique_n},{path_len})", layout=layout,
        bucket_factor=bucket_factor,
    )


def lollipop(
    clique_n: int, path_len: int, layout: str = "dense",
    bucket_factor: int = 2,
):
    """A ``clique_n``-clique with a ``path_len``-node path hanging off it.

    Maximizes hitting time clique -> path tip (the classical Theta(n^3)
    lollipop bound) — the sharpest single-walk entrapment stressor.
    """
    if clique_n < 3:
        raise ValueError("lollipop needs clique_n >= 3")
    if path_len < 1:
        raise ValueError("lollipop needs path_len >= 1")
    n = clique_n + path_len
    iu, ju = np.triu_indices(clique_n, k=1)
    chain = np.concatenate([[clique_n - 1], np.arange(clique_n, n)])
    src = np.concatenate([iu, chain[:-1]])
    dst = np.concatenate([ju, chain[1:]])
    return from_edges(
        n, src, dst, name=f"lollipop({clique_n},{path_len})", layout=layout,
        bucket_factor=bucket_factor,
    )
