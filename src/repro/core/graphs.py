"""Graph substrate for random-walk decentralized learning.

The paper studies sparse communication graphs (ring, 2-D grid, Watts-Strogatz,
Erdos-Renyi).  Every node has a self-loop (paper §II.A).  We keep two
representations:

* a dense adjacency matrix (numpy, ``float64``) used to *construct* transition
  matrices and compute spectral quantities offline, and
* a padded neighbor-list tensor (``jnp.int32`` of shape ``(n, max_deg)`` plus a
  degree vector) used *inside* jitted walk steps and the Pallas transition
  kernel, where ragged structures are not representable.

Construction is deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "ring",
    "grid2d",
    "watts_strogatz",
    "erdos_renyi",
    "star",
    "complete",
    "expander",
    "from_adjacency",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected graph with self-loops, in both dense and padded forms.

    Attributes:
      adj: (n, n) float64 {0,1} adjacency, symmetric, unit diagonal.
      neighbors: (n, max_deg) int32 padded neighbor lists.  Row v holds the
        neighbor ids of v (including v itself, for the self-loop) followed by
        padding that repeats v (so sampling a pad index is a harmless self-hop
        and probability masks make pads unreachable anyway).
      degrees: (n,) int32 true degrees (including the self-loop).
      name: human-readable description.
    """

    adj: np.ndarray
    neighbors: np.ndarray
    degrees: np.ndarray
    name: str = "graph"

    @property
    def n(self) -> int:
        return int(self.adj.shape[0])

    @property
    def max_degree(self) -> int:
        return int(self.neighbors.shape[1])

    def validate(self) -> None:
        a = self.adj
        if a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if not np.allclose(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if not np.all(np.diag(a) == 1):
            raise ValueError("every node needs a self-loop (paper §II.A)")
        if not np.all((a == 0) | (a == 1)):
            raise ValueError("adjacency entries must be 0/1")
        if not _is_connected(a):
            raise ValueError("graph must be connected")
        deg = a.sum(axis=1).astype(np.int64)
        if not np.array_equal(deg, self.degrees.astype(np.int64)):
            raise ValueError("degree vector inconsistent with adjacency")


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        v = stack.pop()
        for u in np.nonzero(adj[v])[0]:
            if not seen[u]:
                seen[u] = True
                stack.append(int(u))
    return bool(seen.all())


def from_adjacency(adj: np.ndarray, name: str = "graph") -> Graph:
    """Build a :class:`Graph` from a 0/1 adjacency; adds self-loops if absent."""
    adj = np.asarray(adj, dtype=np.float64).copy()
    np.fill_diagonal(adj, 1.0)
    adj = np.maximum(adj, adj.T)  # symmetrize
    degrees = adj.sum(axis=1).astype(np.int32)
    max_deg = int(degrees.max())
    n = adj.shape[0]
    neighbors = np.empty((n, max_deg), dtype=np.int32)
    for v in range(n):
        nbrs = np.nonzero(adj[v])[0].astype(np.int32)
        pad = np.full(max_deg - len(nbrs), v, dtype=np.int32)
        neighbors[v] = np.concatenate([nbrs, pad])
    g = Graph(adj=adj, neighbors=neighbors, degrees=degrees, name=name)
    g.validate()
    return g


def ring(n: int) -> Graph:
    """Ring of n nodes — the paper's canonical entrapment topology (Fig 2a)."""
    if n < 3:
        raise ValueError("ring needs n >= 3")
    adj = np.zeros((n, n))
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = 1
    adj[(idx + 1) % n, idx] = 1
    return from_adjacency(adj, name=f"ring({n})")


def grid2d(rows: int, cols: Optional[int] = None) -> Graph:
    """2-D grid (paper Fig 5a uses ~1000 nodes)."""
    cols = cols or rows
    n = rows * cols
    adj = np.zeros((n, n))

    def nid(r, c):
        return r * cols + c

    for r in range(rows):
        for c in range(cols):
            if r + 1 < rows:
                adj[nid(r, c), nid(r + 1, c)] = 1
            if c + 1 < cols:
                adj[nid(r, c), nid(r, c + 1)] = 1
    return from_adjacency(adj, name=f"grid2d({rows}x{cols})")


def watts_strogatz(n: int, k: int, p: float, seed: int = 0) -> Graph:
    """Watts-Strogatz small world (paper Fig 5b: WS(1000, 4, 0.1)).

    Standard construction: ring lattice with k nearest neighbors (k even),
    each "forward" edge rewired with probability p (no self/multi edges).
    """
    if k % 2 != 0 or k >= n:
        raise ValueError("watts_strogatz requires even k < n")
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n))
    for v in range(n):
        for j in range(1, k // 2 + 1):
            adj[v, (v + j) % n] = 1
            adj[(v + j) % n, v] = 1
    for v in range(n):
        for j in range(1, k // 2 + 1):
            if rng.random() < p:
                u = (v + j) % n
                # rewire edge (v, u) -> (v, w)
                candidates = np.nonzero((adj[v] == 0))[0]
                candidates = candidates[candidates != v]
                if len(candidates) == 0:
                    continue
                w = int(rng.choice(candidates))
                adj[v, u] = adj[u, v] = 0
                adj[v, w] = adj[w, v] = 1
    g = from_adjacency(adj, name=f"ws({n},{k},{p})")
    if not _is_connected(g.adj):  # extremely unlikely for paper params; retry
        return watts_strogatz(n, k, p, seed=seed + 1)
    return g


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """Erdos-Renyi G(n, p) (paper Fig 4 uses ER(1000, 0.1)); resamples until connected."""
    rng = np.random.default_rng(seed)
    for attempt in range(64):
        upper = rng.random((n, n)) < p
        adj = np.triu(upper, k=1).astype(np.float64)
        adj = adj + adj.T
        if _is_connected(np.maximum(adj, np.eye(n))):
            return from_adjacency(adj, name=f"er({n},{p})")
    raise RuntimeError(f"could not sample a connected ER({n},{p}) in 64 tries")


def star(n: int) -> Graph:
    """Star graph — worst-case hub topology, useful in entrapment tests."""
    adj = np.zeros((n, n))
    adj[0, 1:] = 1
    adj[1:, 0] = 1
    return from_adjacency(adj, name=f"star({n})")


def complete(n: int) -> Graph:
    """Complete graph — the centralized-equivalent reference topology."""
    adj = np.ones((n, n))
    return from_adjacency(adj, name=f"complete({n})")


def expander(n: int, d: int = 6, seed: int = 0) -> Graph:
    """Random d-regular-ish expander via union of d/2 random perfect matchings.

    Good conductance — a control topology where entrapment should NOT occur.
    """
    if n % 2 != 0:
        raise ValueError("expander builder needs even n")
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n))
    for _ in range(max(1, d // 2)):
        perm = rng.permutation(n)
        for i in range(0, n, 2):
            a, b = perm[i], perm[i + 1]
            adj[a, b] = adj[b, a] = 1
    # also add a ring to guarantee connectivity
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = 1
    adj[(idx + 1) % n, idx] = 1
    return from_adjacency(adj, name=f"expander({n},{d})")
