"""Heterogeneity-aware transition targets (Dandi et al., arXiv:2204.06477).

The paper's P_IS targets pi ∝ L_v — smoothness-aware importance sampling.
Dandi et al. argue the *data heterogeneity* between nodes, not just their
smoothness, should shape the communication topology: nodes whose local
gradients disagree most with the rest of the network carry the most
information and deserve more visit mass.  This module implements that
pipeline for the repo's chain-law stack:

1. **Measure** — :func:`measure_dissimilarity` evaluates each node's local
   gradient at a small set of probe parameter points and returns the pairwise
   gradient-dissimilarity matrix ``H[u, v] = mean_probes ||g_u - g_v||^2``
   (the discrete analogue of the zeta^2 heterogeneity bound in
   arXiv:2204.06477).

2. **Optimize** — :func:`optimize_pi` minimizes the sampling-variance
   surrogate

       J(pi) = sum_v  h_bar(v) / pi_v,      h_bar(v) = mean_u H[v, u]

   over the probability simplex by projected gradient descent, with an
   entrywise floor ``pi_v >= floor / n`` that keeps the optimized chain
   irreducible and the importance weights 1/(n pi_v) bounded (the same role
   the weight clip plays for the online L_v estimator).  With the floor
   inactive the minimizer is the closed form ``pi ∝ sqrt(h_bar)``
   (:func:`optimal_pi_closed_form`) — the test oracle for the descent.

3. **Walk** — the optimized pi feeds ``transition.heterogeneity_rows*``:
   Metropolis–Hastings rows targeting pi through the identical
   ``_mh_rows_block`` math as every other law, so all four engine layouts
   sample it bitwise-identically.

Everything here is offline numpy precompute (the analysis stack), like the
dense transition builders: the output is one (n,) target handed to the row
builders once per training run.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "pairwise_gradient_dissimilarity",
    "measure_dissimilarity",
    "mean_dissimilarity",
    "project_to_simplex",
    "optimal_pi_closed_form",
    "optimize_pi",
    "heterogeneity_pi",
]


def pairwise_gradient_dissimilarity(grads: np.ndarray) -> np.ndarray:
    """``H[u, v] = mean_p ||g_u - g_v||^2`` from per-probe node gradients.

    ``grads`` is ``(num_probes, n, d)`` (or ``(n, d)`` for a single probe).
    Computed via the Gram expansion ||g_u||^2 + ||g_v||^2 - 2 g_u.g_v, one
    (n, n) matmul per probe — O(p n^2 d) flops, O(n^2) memory.
    """
    grads = np.asarray(grads, dtype=np.float64)
    if grads.ndim == 2:
        grads = grads[None]
    if grads.ndim != 3:
        raise ValueError(
            f"grads must be (num_probes, n, d) or (n, d), got {grads.shape}"
        )
    p, n, _ = grads.shape
    h = np.zeros((n, n), dtype=np.float64)
    for g in grads:
        sq = (g**2).sum(axis=1)
        h += sq[:, None] + sq[None, :] - 2.0 * (g @ g.T)
    h = np.maximum(h / p, 0.0)  # float error can push diagonals below 0
    np.fill_diagonal(h, 0.0)
    return 0.5 * (h + h.T)  # exact symmetry for downstream consumers


def measure_dissimilarity(
    data,
    num_probes: int = 8,
    probe_scale: float = 1.0,
    seed: int = 0,
) -> np.ndarray:
    """Pairwise gradient-dissimilarity matrix of a regression instance.

    Evaluates each node's local least-squares gradient
    ``g_v(x) = -2 (y_v - A_v.x) A_v`` at ``num_probes`` parameter probes
    (the origin plus fixed-seed Gaussian draws of scale ``probe_scale``) and
    averages the pairwise squared gradient gaps — a plug-in estimate of the
    heterogeneity matrix of arXiv:2204.06477 measured where training
    actually starts, not at the (unknown) optimum.
    """
    if num_probes < 1:
        raise ValueError(f"num_probes must be >= 1, got {num_probes}")
    features = np.asarray(data.features, dtype=np.float64)
    targets = np.asarray(data.targets, dtype=np.float64)
    n, d = features.shape
    rng = np.random.default_rng(seed)
    probes = [np.zeros(d)]
    probes += [
        probe_scale * rng.standard_normal(d) for _ in range(num_probes - 1)
    ]
    grads = np.stack(
        [
            -2.0 * (targets - features @ x)[:, None] * features
            for x in probes
        ]
    )
    return pairwise_gradient_dissimilarity(grads)


def mean_dissimilarity(h: np.ndarray) -> np.ndarray:
    """Per-node mean dissimilarity ``h_bar(v) = mean_u H[v, u]``."""
    h = np.asarray(h, dtype=np.float64)
    if h.ndim != 2 or h.shape[0] != h.shape[1]:
        raise ValueError(f"H must be square (n, n), got {h.shape}")
    if np.any(h < 0):
        raise ValueError("dissimilarity entries must be nonnegative")
    return h.mean(axis=1)


def project_to_simplex(v: np.ndarray, floor: float = 0.0) -> np.ndarray:
    """Euclidean projection onto ``{pi : sum pi = 1, pi_i >= floor/n}``.

    The floored simplex is the plain simplex shifted by ``floor/n`` per
    coordinate: project ``v - floor/n`` onto the simplex of total mass
    ``1 - floor`` (the standard sort-based algorithm) and shift back.
    """
    v = np.asarray(v, dtype=np.float64)
    n = v.size
    if not (0.0 <= floor < 1.0):
        raise ValueError(f"floor must be in [0, 1), got {floor}")
    z = v - floor / n
    mass = 1.0 - floor
    u = np.sort(z)[::-1]
    css = np.cumsum(u) - mass
    idx = np.arange(1, n + 1)
    rho = idx[u - css / idx > 0][-1]
    theta = css[rho - 1] / rho
    return np.maximum(z - theta, 0.0) + floor / n


def optimal_pi_closed_form(h: np.ndarray) -> np.ndarray:
    """Unconstrained simplex minimizer of J(pi): ``pi ∝ sqrt(h_bar)``.

    From the KKT conditions h_bar(v) / pi_v^2 = const.  Exact only while
    every entry clears the floor — the projected-descent optimizer handles
    the constrained case; this is its oracle (and its warm start).
    """
    hbar = mean_dissimilarity(h)
    if hbar.max() <= 0.0:
        return np.full(hbar.size, 1.0 / hbar.size)
    root = np.sqrt(hbar)
    return root / root.sum()


def optimize_pi(
    h: np.ndarray,
    floor: float = 0.25,
    steps: int = 400,
    step_size: float = 0.1,
    init: np.ndarray | None = None,
) -> np.ndarray:
    """Projected-descent minimizer of ``J(pi) = sum_v h_bar(v)/pi_v``.

    Normalized projected gradient descent with a 1/sqrt(t) step decay on the
    floored simplex (``pi_v >= floor/n``).  ``floor`` keeps the MH chain
    targeting pi irreducible on any connected graph and bounds the
    importance weights; ``floor=0`` recovers the unconstrained optimum
    ``pi ∝ sqrt(h_bar)`` up to descent tolerance.  A fully homogeneous
    network (H = 0) returns the uniform distribution — heterogeneity-aware
    sampling degenerates to MH-uniform, as it should.
    """
    hbar = mean_dissimilarity(h)
    n = hbar.size
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    if hbar.max() <= 0.0:
        return np.full(n, 1.0 / n)
    hbar = hbar / hbar.max()  # argmin is scale-invariant; tame the gradients
    if init is None:
        pi = project_to_simplex(optimal_pi_closed_form(h), floor)
    else:
        pi = project_to_simplex(np.asarray(init, dtype=np.float64), floor)
    best, best_obj = pi, float(np.sum(hbar / pi))
    for t in range(steps):
        grad = -hbar / pi**2
        lr = step_size / (np.abs(grad).max() * np.sqrt(t + 1.0))
        pi = project_to_simplex(pi - lr * grad, floor)
        obj = float(np.sum(hbar / pi))
        if obj < best_obj:
            best, best_obj = pi, obj
    return best


def heterogeneity_pi(
    data,
    floor: float = 0.25,
    num_probes: int = 8,
    probe_scale: float = 1.0,
    seed: int = 0,
    steps: int = 400,
) -> np.ndarray:
    """Measure-then-optimize convenience: the (n,) walk target in one call.

    This is what ``walk_sgd.trainer`` invokes for ``method="heterogeneity"``
    when no precomputed pi is supplied.
    """
    h = measure_dissimilarity(
        data, num_probes=num_probes, probe_scale=probe_scale, seed=seed
    )
    return optimize_pi(h, floor=floor, steps=steps)
