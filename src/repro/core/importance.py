"""Importance measures: gradient-Lipschitz constants L_v and pi_IS (paper §III).

Closed forms (paper §II.B, §Appendix D):
* linear regression   f_v(x) = (y_v - x^T A_v)^2        ->  L_v = 2 ||A_v||^2
  (the paper's Def-1 example with the 1/2 factor gives ||A_v||^2; Appendix D
  drops the 1/2 and uses L_v = 2 A_v^T A_v — we follow the experiment section)
* logistic regression f_v(x) = y_v x^T A_v - log(1+e^{x^T A_v}) -> L_v = ||A_v||^2 / 4

For non-convex losses (the LLM architectures) no closed form exists; we provide
an online EMA estimator of the local curvature proxy

    L_v ~= ||g_v(x_t) - g_v(x_{t'})|| / ||x_t - x_{t'}||

maintained per node from consecutive visits (secant estimate of the gradient
Lipschitz constant along the trajectory), with clipping to keep weights
L_bar / L_v bounded.  This is the standard surrogate (cf. adaptive IS
literature) and is documented as a hardware/model adaptation in DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "linear_regression_lipschitz",
    "logistic_regression_lipschitz",
    "importance_distribution",
    "importance_weights",
    "OnlineLipschitzState",
    "online_lipschitz_init",
    "online_lipschitz_update",
]


def linear_regression_lipschitz(features: np.ndarray) -> np.ndarray:
    """L_v = 2 ||A_v||^2 for f_v(x) = (y_v - x^T A_v)^2 (paper Appendix D)."""
    features = np.asarray(features)
    return 2.0 * (features**2).sum(axis=-1)


def logistic_regression_lipschitz(features: np.ndarray) -> np.ndarray:
    """L_v = ||A_v||^2 / 4 (paper §II.B)."""
    features = np.asarray(features)
    return 0.25 * (features**2).sum(axis=-1)


def importance_distribution(lipschitz: np.ndarray) -> np.ndarray:
    """pi_IS(v) = L_v / sum_u L_u (paper Eq. 5)."""
    lipschitz = np.asarray(lipschitz, dtype=np.float64)
    if np.any(lipschitz <= 0):
        raise ValueError("Lipschitz constants must be positive")
    return lipschitz / lipschitz.sum()


def importance_weights(lipschitz: jnp.ndarray | np.ndarray) -> jnp.ndarray:
    """Per-node update weights w(v) = L_bar / L_v used in Eq. (12)."""
    lipschitz = jnp.asarray(lipschitz)
    return jnp.mean(lipschitz) / lipschitz


# ---------------------------------------------------------------------------
# Online L_v estimation for losses without closed forms (LLM adaptation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OnlineLipschitzState:
    """Per-node secant-based curvature estimates, JAX pytree-compatible."""

    lipschitz: jnp.ndarray  # (n,) current estimates
    last_grad_norm: jnp.ndarray  # (n,) ||g_v|| at last visit
    last_param_fingerprint: jnp.ndarray  # (n,) ||x|| fingerprint at last visit
    visited: jnp.ndarray  # (n,) bool

    def tree_flatten(self):
        return (
            (self.lipschitz, self.last_grad_norm, self.last_param_fingerprint, self.visited),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    OnlineLipschitzState,
    OnlineLipschitzState.tree_flatten,
    lambda aux, children: OnlineLipschitzState.tree_unflatten(aux, children),
)


def online_lipschitz_init(n: int, init: float = 1.0) -> OnlineLipschitzState:
    return OnlineLipschitzState(
        lipschitz=jnp.full((n,), init, dtype=jnp.float32),
        last_grad_norm=jnp.zeros((n,), dtype=jnp.float32),
        last_param_fingerprint=jnp.zeros((n,), dtype=jnp.float32),
        visited=jnp.zeros((n,), dtype=bool),
    )


def online_lipschitz_update(
    state: OnlineLipschitzState,
    node: jnp.ndarray,
    grad_norm: jnp.ndarray,
    param_fingerprint: jnp.ndarray,
    *,
    ema: float = 0.9,
    clip_min: float = 1e-3,
    clip_max: float = 1e3,
) -> OnlineLipschitzState:
    """Secant update of L_node from consecutive visits.

    L_new = |grad_norm - last_grad_norm| / |fingerprint - last_fingerprint|
    blended into an EMA; first visit keeps the prior.  All ops are gather/
    scatter on index ``node`` so the update jits inside lax.scan.
    """
    node = jnp.asarray(node, dtype=jnp.int32)
    prev_g = state.last_grad_norm[node]
    prev_f = state.last_param_fingerprint[node]
    seen = state.visited[node]
    dx = jnp.abs(param_fingerprint - prev_f)
    secant = jnp.abs(grad_norm - prev_g) / jnp.maximum(dx, 1e-8)
    secant = jnp.clip(secant, clip_min, clip_max)
    old = state.lipschitz[node]
    blended = jnp.where(seen, ema * old + (1.0 - ema) * secant, old)
    return OnlineLipschitzState(
        lipschitz=state.lipschitz.at[node].set(blended),
        last_grad_norm=state.last_grad_norm.at[node].set(grad_norm),
        last_param_fingerprint=state.last_param_fingerprint.at[node].set(param_fingerprint),
        visited=state.visited.at[node].set(True),
    )
