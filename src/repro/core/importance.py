"""Importance measures: gradient-Lipschitz constants L_v and pi_IS (paper §III).

Closed forms (paper §II.B, §Appendix D):
* linear regression   f_v(x) = (y_v - x^T A_v)^2        ->  L_v = 2 ||A_v||^2
  (the paper's Def-1 example with the 1/2 factor gives ||A_v||^2; Appendix D
  drops the 1/2 and uses L_v = 2 A_v^T A_v — we follow the experiment section)
* logistic regression f_v(x) = y_v x^T A_v - log(1+e^{x^T A_v}) -> L_v = ||A_v||^2 / 4

For non-convex losses (the LLM architectures) no closed form exists; we provide
an online EMA estimator of the local curvature proxy

    L_v ~= ||g_v(x_t) - g_v(x_{t'})|| / ||x_t - x_{t'}||

maintained per node from consecutive visits (secant estimate of the gradient
Lipschitz constant along the trajectory), with clipping to keep weights
L_bar / L_v bounded.  This is the standard surrogate (cf. adaptive IS
literature) and is documented as a hardware/model adaptation in DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "linear_regression_lipschitz",
    "logistic_regression_lipschitz",
    "importance_distribution",
    "importance_weights",
    "FINGERPRINT_SEED",
    "param_fingerprint",
    "OnlineLipschitzState",
    "online_lipschitz_init",
    "online_lipschitz_update",
]


def linear_regression_lipschitz(features: np.ndarray) -> np.ndarray:
    """L_v = 2 ||A_v||^2 for f_v(x) = (y_v - x^T A_v)^2 (paper Appendix D)."""
    features = np.asarray(features)
    return 2.0 * (features**2).sum(axis=-1)


def logistic_regression_lipschitz(features: np.ndarray) -> np.ndarray:
    """L_v = ||A_v||^2 / 4 (paper §II.B)."""
    features = np.asarray(features)
    return 0.25 * (features**2).sum(axis=-1)


def importance_distribution(lipschitz: np.ndarray) -> np.ndarray:
    """pi_IS(v) = L_v / sum_u L_u (paper Eq. 5)."""
    lipschitz = np.asarray(lipschitz, dtype=np.float64)
    if np.any(lipschitz <= 0):
        raise ValueError("Lipschitz constants must be positive")
    return lipschitz / lipschitz.sum()


def importance_weights(lipschitz: jnp.ndarray | np.ndarray) -> jnp.ndarray:
    """Per-node update weights w(v) = L_bar / L_v used in Eq. (12)."""
    lipschitz = jnp.asarray(lipschitz)
    return jnp.mean(lipschitz) / lipschitz


# ---------------------------------------------------------------------------
# Online L_v estimation for losses without closed forms (LLM adaptation)
# ---------------------------------------------------------------------------

# Fixed seed of the random-projection fingerprint.  The fingerprint must be
# the SAME deterministic functional of the parameters at every visit of every
# node (otherwise the secant denominator compares apples to oranges), so the
# projection direction is frozen once per state and recorded in it.
FINGERPRINT_SEED = 0


def param_fingerprint(params, seed: int = FINGERPRINT_SEED) -> jnp.ndarray:
    """Deterministic random-projection fingerprint <r, vec(x)> / sqrt(D).

    The secant estimator needs a scalar summary f(x) whose difference
    |f(x_t) - f(x_{t'})| tracks ||x_t - x_{t'}||.  The norm ||x|| is NOT such
    a summary: two far-apart parameter vectors of equal norm give df = 0 and
    the secant blows up to its clip ceiling.  A fixed random projection
    r ~ N(0, I/D) collides only on the measure-zero hyperplane orthogonal to
    r, and E[(r·(x-x'))^2] = ||x - x'||^2 / D, so differences are calibrated
    to parameter distance.  ``r`` is regenerated from ``seed`` on each call
    (pure function of the fixed seed — jit folds it into the compiled step).
    """
    leaves = jax.tree_util.tree_leaves(params)
    dim = sum(int(np.prod(leaf.shape)) for leaf in leaves) or 1
    base = jax.random.PRNGKey(seed)
    total = jnp.float32(0.0)
    for i, leaf in enumerate(leaves):
        r = jax.random.normal(
            jax.random.fold_in(base, i), leaf.shape, dtype=jnp.float32
        )
        total = total + jnp.vdot(r, jnp.asarray(leaf, jnp.float32))
    return total / np.sqrt(dim)


@dataclasses.dataclass
class OnlineLipschitzState:
    """Per-node secant-based curvature estimates, JAX pytree-compatible.

    ``proj_seed`` (static aux data) records the fixed seed of the
    random-projection fingerprint the stored ``last_param_fingerprint``
    values were computed with — callers must feed
    ``param_fingerprint(params, seed=state.proj_seed)`` so consecutive
    visits are fingerprinted identically.
    """

    lipschitz: jnp.ndarray  # (n,) current estimates
    last_grad_norm: jnp.ndarray  # (n,) ||g_v|| at last visit
    last_param_fingerprint: jnp.ndarray  # (n,) projection fingerprint at last visit
    visited: jnp.ndarray  # (n,) bool
    proj_seed: int = FINGERPRINT_SEED  # static: fingerprint projection seed

    def tree_flatten(self):
        return (
            (self.lipschitz, self.last_grad_norm, self.last_param_fingerprint, self.visited),
            self.proj_seed,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, proj_seed=aux)


jax.tree_util.register_pytree_node(
    OnlineLipschitzState,
    OnlineLipschitzState.tree_flatten,
    lambda aux, children: OnlineLipschitzState.tree_unflatten(aux, children),
)


def online_lipschitz_init(
    n: int, init: float = 1.0, proj_seed: int = FINGERPRINT_SEED
) -> OnlineLipschitzState:
    return OnlineLipschitzState(
        lipschitz=jnp.full((n,), init, dtype=jnp.float32),
        last_grad_norm=jnp.zeros((n,), dtype=jnp.float32),
        last_param_fingerprint=jnp.zeros((n,), dtype=jnp.float32),
        visited=jnp.zeros((n,), dtype=bool),
        proj_seed=proj_seed,
    )


def online_lipschitz_update(
    state: OnlineLipschitzState,
    node: jnp.ndarray,
    grad_norm: jnp.ndarray,
    param_fingerprint: jnp.ndarray,
    *,
    ema: float = 0.9,
    clip_min: float = 1e-3,
    clip_max: float = 1e3,
) -> OnlineLipschitzState:
    """Secant update of L_node from consecutive visits.

    L_new = |grad_norm - last_grad_norm| / |fingerprint - last_fingerprint|
    blended into an EMA; first visit keeps the prior.  All ops are gather/
    scatter on index ``node`` so the update jits inside lax.scan.

    ``param_fingerprint`` must come from :func:`param_fingerprint` with
    ``seed=state.proj_seed`` (a fixed random projection of the parameters).
    The former ``||x||`` fingerprint collided for distinct params of equal
    norm, driving the secant denominator to ~0 and the estimate to
    ``clip_max`` — wrecking the IS weights w = L_bar / L_v.
    """
    node = jnp.asarray(node, dtype=jnp.int32)
    prev_g = state.last_grad_norm[node]
    prev_f = state.last_param_fingerprint[node]
    seen = state.visited[node]
    dx = jnp.abs(param_fingerprint - prev_f)
    secant = jnp.abs(grad_norm - prev_g) / jnp.maximum(dx, 1e-8)
    secant = jnp.clip(secant, clip_min, clip_max)
    old = state.lipschitz[node]
    blended = jnp.where(seen, ema * old + (1.0 - ema) * secant, old)
    return OnlineLipschitzState(
        lipschitz=state.lipschitz.at[node].set(blended),
        last_grad_norm=state.last_grad_norm.at[node].set(grad_norm),
        last_param_fingerprint=state.last_param_fingerprint.at[node].set(param_fingerprint),
        visited=state.visited.at[node].set(True),
        proj_seed=state.proj_seed,
    )
