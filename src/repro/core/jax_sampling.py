"""``jax.random`` ports of the trap-prone graph samplers.

The numpy builders in ``core.graphs`` (``barabasi_albert``, ``sbm``) are
host-side: a ``np.random.Generator`` stream, python retry loops, O(E)
array passes.  That is the right tool for one-shot construction, but the
dynamic-graph loop (docs/dynamic_graphs.md) re-samples graphs *between*
jitted training epochs, and a resample that lives inside a jitted region
needs fixed shapes and a ``jax.random`` key.  This module provides that:

* :func:`barabasi_albert_edges` — the Batagelj–Brandes repeated-nodes
  construction of ``graphs.barabasi_albert``, ported op for op to
  ``jnp`` (the position→endpoint pointer chase becomes a
  ``lax.while_loop``).  Fully jit-compatible: static ``(n, m)``, fixed
  ``(m·(n-m),)`` output shapes, one key in.
* :func:`sbm_pair_mask` — the jit-compatible core of the SBM sampler: a
  fixed-shape Bernoulli mask over all ``n(n-1)/2`` unordered pairs with
  the block-dependent edge probability.  Extracting the variable-length
  edge list is inherently shape-dynamic, so that stays host-side.
* :func:`barabasi_albert_jax` / :func:`sbm_jax` — host wrappers that turn
  the device samples into validated ``core.graphs`` classes via the
  usual ``from_edges`` machinery (any layout).

Parity contract (pinned by ``tests/test_graphs.py``): **family-level,
not stream-level**.  A ``jax.random`` key and a numpy ``Generator``
produce different streams by design, so the ports match the numpy
samplers in family properties — degree-sequence shape for BA (power-law
hubs, min degree, edge count bounds), block densities for SBM — and in
every structural invariant (``validate()`` passes), not edge for edge.
The SBM mask is O(n²) pairs where the numpy sampler is O(E); that is the
price of fixed shapes, and it bounds this port to the analysis/Dada
scales (n ≲ a few thousand) — the numpy sampler remains THE large-graph
constructor.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graphs import (
    _csr_graph_from_arrays,
    _csr_is_connected,
    _edges_to_csr,
    from_edges,
)

__all__ = [
    "barabasi_albert_edges",
    "barabasi_albert_jax",
    "sbm_pair_mask",
    "sbm_jax",
]


def barabasi_albert_edges(
    n: int, m: int, key: jax.Array
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Barabási–Albert attachment edges on device — jit-compatible.

    The exact Batagelj–Brandes scheme of ``graphs.barabasi_albert``: edge
    ``e`` of node ``v = m + e//m`` picks a uniform position of the
    repeated endpoint list built by earlier nodes' edges (so the pick is
    degree-proportional), and odd positions — pointers at an earlier
    edge's *target* — are resolved by a ``lax.while_loop`` pointer chase
    that strictly shrinks per round (O(log) iterations).  ``n``/``m`` are
    static (they fix the output shapes); returns ``(src, dst)`` int32
    arrays of ``m·(n-m)`` undirected attachment edges with ``dst < src``,
    connected by construction once deduped (node ``m`` seeds by attaching
    to all of ``0..m-1``).  Feed through :func:`barabasi_albert_jax` (or
    ``graphs.from_edges``) to get a validated graph class.
    """
    if not (1 <= m < n):
        raise ValueError("barabasi_albert requires 1 <= m < n")
    num_edges = m * (n - m)
    eidx = jnp.arange(num_edges, dtype=jnp.int32)
    src = m + eidx // m
    # position draw in [0, 2m(v-m)) — the repeated-list state before node
    # v's own edges; the first m (seed) edges have bound 0 and are
    # overwritten below
    bound = 2 * m * (src - m)
    u = jax.random.uniform(key, (num_edges,))
    pos = jnp.minimum(
        (u * bound.astype(jnp.float32)).astype(jnp.int32),
        jnp.maximum(bound - 1, 0),
    )

    def unresolved(p):
        e_prev = (p - 1) // 2
        return (p % 2 == 1) & (e_prev >= m)

    def body(p):
        e_prev = (p - 1) // 2
        # clip keeps the gather in range on already-resolved lanes (their
        # looked-up value is discarded by the where)
        looked = p[jnp.clip(e_prev, 0, num_edges - 1)]
        return jnp.where(unresolved(p), looked, p)

    pos = lax.while_loop(lambda p: jnp.any(unresolved(p)), body, pos)
    dst = jnp.where(pos % 2 == 0, m + (pos // 2) // m, (pos - 1) // 2)
    dst = jnp.where(eidx < m, eidx, dst)  # node m's seed attachments
    return src, dst


def barabasi_albert_jax(
    n: int,
    m: int,
    key: jax.Array,
    *,
    layout: str = "csr",
    bucket_factor: int = 2,
):
    """Validated BA graph from a ``jax.random`` key (host wrapper).

    Samples :func:`barabasi_albert_edges` on device, then builds the
    requested ``core.graphs`` layout through ``from_edges`` (dedupe,
    self-loops, full validation) exactly like the numpy builder.
    """
    src, dst = barabasi_albert_edges(n, m, key)
    return from_edges(
        n,
        np.asarray(src, dtype=np.int64),
        np.asarray(dst, dtype=np.int64),
        name=f"ba_jax({n},{m})",
        layout=layout,
        bucket_factor=bucket_factor,
    )


def _sbm_pair_meta(block_sizes: Sequence[int]):
    sizes = np.asarray(block_sizes, dtype=np.int64)
    if sizes.ndim != 1 or sizes.size < 1 or np.any(sizes < 1):
        raise ValueError("block_sizes must be a non-empty list of positive ints")
    n = int(sizes.sum())
    block_ids = np.repeat(np.arange(sizes.size), sizes)
    i, j = np.triu_indices(n, k=1)
    return n, i, j, block_ids[i] == block_ids[j]


def sbm_pair_mask(
    block_sizes: Sequence[int], p_in: float, p_out: float, key: jax.Array
) -> jnp.ndarray:
    """Bernoulli mask over all unordered node pairs — jit-compatible.

    Entry ``k`` decides pair ``(i_k, j_k)`` of ``np.triu_indices(n, 1)``
    row-major order: present with probability ``p_in`` inside a block,
    ``p_out`` across.  ``block_sizes`` is static (it fixes the
    ``(n(n-1)/2,)`` shape); ``p_in``/``p_out`` may be traced.  This is
    the whole device-side randomness of the SBM port — edge-list
    extraction (variable length) happens in :func:`sbm_jax` host-side.
    """
    _, _, _, same_block = _sbm_pair_meta(block_sizes)
    p_pair = jnp.where(
        jnp.asarray(same_block),
        jnp.asarray(p_in, jnp.float32),
        jnp.asarray(p_out, jnp.float32),
    )
    return jax.random.uniform(key, p_pair.shape) < p_pair


def sbm_jax(
    block_sizes: Sequence[int],
    p_in: float,
    p_out: float,
    key: jax.Array,
    *,
    layout: str = "csr",
    bucket_factor: int = 2,
    max_retries: int = 64,
):
    """Validated SBM graph from a ``jax.random`` key (host wrapper).

    Mirrors ``graphs.sbm``'s retry-until-connected loop with
    ``jax.random.fold_in(key, attempt)`` as the per-attempt key (attempt
    0 uses ``key`` itself, so one connected draw consumes exactly the
    caller's key).  Probabilities are validated here — the mask core
    accepts traced values and cannot.
    """
    for q, tag in ((p_in, "p_in"), (p_out, "p_out")):
        if not (0.0 <= float(q) <= 1.0):
            raise ValueError(f"{tag} must be in [0,1], got {q}")
    n, i, j, _ = _sbm_pair_meta(block_sizes)
    sizes = [int(s) for s in np.asarray(block_sizes, dtype=np.int64)]
    name = f"sbm_jax({sizes},{p_in},{p_out})"
    for attempt in range(max_retries):
        k = key if attempt == 0 else jax.random.fold_in(key, attempt)
        mask = np.asarray(sbm_pair_mask(block_sizes, p_in, p_out, k))
        indptr, indices, degrees = _edges_to_csr(n, i[mask], j[mask])
        if _csr_is_connected(indptr, indices):
            return _csr_graph_from_arrays(
                indptr, indices, degrees, name, layout,
                bucket_factor=bucket_factor,
            )
    raise RuntimeError(
        f"could not sample a connected {name} in {max_retries} tries"
    )
