"""Lévy jump machinery (paper §V).

The jump distance is drawn from a truncated geometric distribution

    P(D = d) = p_d (1 - p_d)^{d-1} / (1 - (1 - p_d)^r),   1 <= d <= r,

and the jump itself performs ``d`` consecutive *uniform* simple-random-walk
hops with no model updates.  The induced one-shot transition matrix has the
closed form (paper Eq. in §V / Appendix A):

    P_Lévy = sum_{i=1..r} w_i * diag(A^i 1)^{-1} A^i,
    w_i = p_d (1 - p_d)^{i-1} / (1 - (1 - p_d)^r).

NOTE on the closed form: the paper composes *adjacency powers* (A^i row-
normalized), which counts i-hop *paths*; the simulated jump chains i uniform
single hops, i.e. D^i where D = diag(A 1)^{-1} A.  On regular graphs the two
coincide; on irregular graphs they differ slightly.  We implement BOTH
(``levy_matrix`` = paper closed form, ``levy_matrix_chained`` = exact law of
Algorithm 1's jump loop) and use the chained form for simulation-faithful
analysis, the paper form for reproducing Theorem-1 constants.  The discrepancy
is surfaced in tests and EXPERIMENTS.md.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.graphs import Graph

__all__ = [
    "trunc_geom_pmf",
    "trunc_geom_mean",
    "trunc_geom_icdf",
    "levy_weights",
    "levy_matrix",
    "levy_matrix_chained",
    "expected_transitions_per_update",
]


def trunc_geom_pmf(p_d: float, r: int) -> np.ndarray:
    """PMF of TruncGeom(p_d, r) over support {1, ..., r}."""
    if not (0.0 < p_d < 1.0):
        raise ValueError(f"p_d must be in (0,1), got {p_d}")
    if r < 1:
        raise ValueError(f"r must be >= 1, got {r}")
    d = np.arange(1, r + 1, dtype=np.float64)
    pmf = p_d * (1.0 - p_d) ** (d - 1.0)
    pmf /= 1.0 - (1.0 - p_d) ** r
    return pmf


def trunc_geom_icdf(u, p_d: float, r: int):
    """Inverse CDF of TruncGeom(p_d, r): maps U(0,1) draws to d in {1..r}.

    F(d) = (1 - (1-p_d)^d) / (1 - (1-p_d)^r), so
    d = ceil(log1p(-u * Z) / log(1 - p_d)) with Z = 1 - (1-p_d)^r.

    Pure ``jnp`` on scalars or arrays — this is the single distance-sampling
    formula shared by every backend of :mod:`repro.core.engine` (including
    the Pallas walk-transition kernel, where it traces into kernel code).
    """
    z = 1.0 - (1.0 - p_d) ** r
    d = jnp.ceil(jnp.log1p(-u * z) / jnp.log(1.0 - p_d)).astype(jnp.int32)
    return jnp.clip(d, 1, r)


def trunc_geom_mean(p_d: float, r: int) -> float:
    """E[D] for D ~ TruncGeom(p_d, r)."""
    pmf = trunc_geom_pmf(p_d, r)
    return float(np.dot(np.arange(1, r + 1), pmf))


def levy_weights(p_d: float, r: int) -> np.ndarray:
    """Alias for the mixture weights w_i (identical to the pmf)."""
    return trunc_geom_pmf(p_d, r)


def levy_matrix(graph: Graph, p_d: float, r: int) -> np.ndarray:
    """Paper closed form: sum_i w_i diag(A^i 1)^{-1} A^i."""
    a = graph.adj
    w = levy_weights(p_d, r)
    out = np.zeros_like(a)
    a_pow = np.eye(graph.n)
    for i in range(1, r + 1):
        a_pow = a_pow @ a
        row_sums = a_pow.sum(axis=1, keepdims=True)
        out += w[i - 1] * (a_pow / row_sums)
    return out


def levy_matrix_chained(graph: Graph, p_d: float, r: int) -> np.ndarray:
    """Exact law of Algorithm 1's jump loop: sum_i w_i D^i, D = deg^{-1} A."""
    a = graph.adj
    d_mat = a / a.sum(axis=1, keepdims=True)
    w = levy_weights(p_d, r)
    out = np.zeros_like(a)
    d_pow = np.eye(graph.n)
    for i in range(1, r + 1):
        d_pow = d_pow @ d_mat
        out += w[i - 1] * d_pow
    return out


def expected_transitions_per_update(p_j: float, p_d: float, r: int) -> float:
    """Remark 1: exact expected node visits per SGD update, and its bound.

    Returns the exact value (1-p_J)*1 + p_J*E[D]; the paper's bound is
    1 + p_J(1/p_d - 1) and is asserted >= exact in tests.
    """
    return (1.0 - p_j) * 1.0 + p_j * trunc_geom_mean(p_d, r)


def remark1_bound(p_j: float, p_d: float, r: int) -> float:
    """Paper Remark 1 upper bound: 1 + p_J (1/p_d - 1)."""
    del r
    return 1.0 + p_j * (1.0 / p_d - 1.0)
