"""Markov-chain mixing analysis (paper §VI uses tau_mix in Theorem 1).

Quantities:

* stationary distribution (left eigenvector / power iteration),
* absolute spectral gap and the standard mixing-time bounds
    t_mix(eps) <= log(1/(eps pi_min)) / gap        (reversible upper bound)
    t_mix(eps) >= (1/gap - 1) log(1/(2 eps))       (lower bound)
* empirical mixing time: smallest t with max_v ||P^t(v,.) - pi||_TV <= eps,
* conductance (bottleneck ratio) via sweep cuts — explains WHY entrapment
  slows mixing on sparse graphs.

MHLJ's chain is non-reversible (jumps break detailed balance), so eigenvalue
bounds use the absolute second-largest modulus; the empirical TV mixing time
is exact regardless and is what EXPERIMENTS.md reports.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "stationary_distribution",
    "spectral_gap",
    "mixing_time_tv",
    "mixing_time_bounds",
    "tv_distance",
    "conductance",
    "is_reversible",
    "NotMixedError",
]


class NotMixedError(RuntimeError):
    """Raised when a chain has not reached the TV threshold by ``max_t``.

    Carries the horizon and the worst-case TV distance still standing there,
    so callers can distinguish "does not mix" (disconnected, periodic,
    absorbing) from "mixes slowly — raise max_t" without parsing strings.
    """

    def __init__(self, max_t: int, worst_tv: float, eps: float):
        self.max_t = int(max_t)
        self.worst_tv = float(worst_tv)
        self.eps = float(eps)
        super().__init__(
            f"chain has not mixed by t={max_t}: worst-case TV distance "
            f"{worst_tv:.4g} > eps={eps} — the chain may be reducible or "
            "periodic; if it merely mixes slowly, raise max_t"
        )


def stationary_distribution(p: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """Left Perron vector of a row-stochastic matrix via eig + power polish."""
    vals, vecs = np.linalg.eig(p.T)
    idx = int(np.argmin(np.abs(vals - 1.0)))
    pi = np.real(vecs[:, idx])
    pi = np.abs(pi)
    pi = pi / pi.sum()
    # power-iteration polish for numerical hygiene
    for _ in range(1000):
        nxt = pi @ p
        if np.abs(nxt - pi).max() < tol:
            pi = nxt
            break
        pi = nxt
    return pi / pi.sum()


def is_reversible(p: np.ndarray, pi: np.ndarray | None = None, atol: float = 1e-8) -> bool:
    """Detailed balance check: pi_i P_ij == pi_j P_ji."""
    pi = stationary_distribution(p) if pi is None else pi
    flow = pi[:, None] * p
    return bool(np.allclose(flow, flow.T, atol=atol))


def spectral_gap(p: np.ndarray) -> float:
    """Absolute spectral gap 1 - max_{i>=2} |lambda_i|."""
    vals = np.linalg.eigvals(p)
    mags = np.sort(np.abs(vals))[::-1]
    # the top eigenvalue is 1 (row stochastic); guard numerical noise
    slem = mags[1] if len(mags) > 1 else 0.0
    return float(max(0.0, 1.0 - slem))


def tv_distance(mu: np.ndarray, nu: np.ndarray) -> float:
    return float(0.5 * np.abs(mu - nu).sum())


def mixing_time_tv(
    p: np.ndarray,
    eps: float = 0.25,
    max_t: int = 1_000_000,
) -> int:
    """Exact empirical mixing time: min t s.t. max_v ||P^t(v,.) - pi||_TV <= eps.

    Uses repeated squaring of P to reach large t in O(log t) matmuls, then
    refines by bisection over the doubling bracket.  Worst-case distance is
    monotone non-increasing in t, which makes bisection valid.

    Raises :class:`NotMixedError` when the chain is still above ``eps`` at
    ``max_t`` — a reducible/periodic chain never mixes, and returning
    ``max_t`` for it (as this function once did) is indistinguishable from
    "mixed at exactly max_t", silently corrupting every tau_mix consumer
    (Theorem-1 terms, entrapment comparisons).
    """
    pi = stationary_distribution(p)

    def worst_tv(pt: np.ndarray) -> float:
        return float(0.5 * np.abs(pt - pi[None, :]).sum(axis=1).max())

    # bracket by doubling
    powers = [p]  # powers[k] = P^(2^k)
    t = 1
    pt = p
    while worst_tv(pt) > eps:
        if t >= max_t:
            raise NotMixedError(max_t, worst_tv(pt), eps)
        pt = pt @ pt
        powers.append(pt)
        t *= 2
    if t == 1:
        return 1
    # bisect in (t/2, t]: build P^m from binary expansion using cached squares
    lo, hi = t // 2, t

    def p_pow(m: int) -> np.ndarray:
        out = None
        k = 0
        while m:
            if m & 1:
                out = powers[k] if out is None else out @ powers[k]
            m >>= 1
            k += 1
        return out

    while hi - lo > 1:
        mid = (lo + hi) // 2
        if worst_tv(p_pow(mid)) <= eps:
            hi = mid
        else:
            lo = mid
    return hi


def mixing_time_bounds(p: np.ndarray, eps: float = 0.25) -> dict:
    """Spectral upper/lower bounds on t_mix(eps) (Levin-Peres Thm 12.4/12.5)."""
    gap = spectral_gap(p)
    pi = stationary_distribution(p)
    pi_min = float(pi.min())
    if gap <= 0:
        return {"gap": gap, "upper": float("inf"), "lower": float("inf"), "pi_min": pi_min}
    t_rel = 1.0 / gap
    upper = t_rel * np.log(1.0 / (eps * pi_min))
    lower = (t_rel - 1.0) * np.log(1.0 / (2.0 * eps))
    return {"gap": gap, "upper": float(upper), "lower": float(max(lower, 0.0)), "pi_min": pi_min}


def conductance(p: np.ndarray, pi: np.ndarray | None = None) -> float:
    """Bottleneck ratio Phi = min_S Q(S, S^c) / pi(S) over sweep cuts.

    Exact conductance is NP-hard; we use the standard spectral sweep-cut
    heuristic (order nodes by the second eigenvector, evaluate all prefix
    cuts), which upper-bounds the true conductance and is tight enough to
    explain ring/grid entrapment.
    """
    pi = stationary_distribution(p) if pi is None else pi
    # second eigenvector of the additive reversibilization for ordering
    q = pi[:, None] * p
    sym = 0.5 * (q + q.T)
    lap = sym / np.sqrt(np.outer(pi, pi))
    vals, vecs = np.linalg.eigh(lap)
    order = np.argsort(vecs[:, -2])
    best = np.inf
    s_mask = np.zeros(len(pi), dtype=bool)
    for v in order[:-1]:
        s_mask[v] = True
        pi_s = pi[s_mask].sum()
        denom = min(pi_s, 1.0 - pi_s)
        if denom <= 0:
            continue
        flow = q[s_mask][:, ~s_mask].sum()
        best = min(best, flow / denom)
    return float(best)
