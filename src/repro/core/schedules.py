"""Jump-probability schedules p_J(t) (paper Fig 6: shrink p_J -> 0 to kill the
error gap without losing speed).

Each schedule is a factory returning a (T,) float32 numpy array consumable by
``walk.walk_mhlj`` and the trainers.  Every factory validates its arguments
the way ``MHLJParams.validate`` does — p_J values are probabilities, so an
out-of-range ``p_j0`` would feed the engine a Bernoulli parameter outside
[0, 1] and silently clamp (or worse, wrap) inside the sampler.
"""
from __future__ import annotations

import numpy as np

__all__ = ["constant", "polynomial_decay", "step_decay", "linear_to_zero"]


def _validate(p_j0: float, num_steps: int) -> None:
    """Mirror of ``MHLJParams.validate`` for the schedule factories."""
    if not (0.0 <= p_j0 <= 1.0):
        raise ValueError(f"p_j0 must be in [0,1], got {p_j0}")
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")


def constant(p_j: float, num_steps: int) -> np.ndarray:
    _validate(p_j, num_steps)
    return np.full(num_steps, p_j, dtype=np.float32)


def polynomial_decay(p_j0: float, num_steps: int, power: float = 1.0, t0: int = 1) -> np.ndarray:
    """p_J(t) = p_j0 * (t0 / (t0 + t))^power — the Fig-6 style annealing."""
    _validate(p_j0, num_steps)
    if t0 < 1:
        raise ValueError(f"t0 must be >= 1, got {t0}")
    if power < 0:
        raise ValueError(f"power must be >= 0, got {power}")
    t = np.arange(num_steps, dtype=np.float64)
    return (p_j0 * (t0 / (t0 + t)) ** power).astype(np.float32)


def step_decay(p_j0: float, num_steps: int, drop_every: int, factor: float = 0.5) -> np.ndarray:
    """p_J(t) = p_j0 * factor^(t // drop_every) — staircase annealing."""
    _validate(p_j0, num_steps)
    if drop_every <= 0:
        raise ValueError(
            f"drop_every must be a positive step count, got {drop_every}"
        )
    if not (0.0 < factor <= 1.0):
        raise ValueError(f"factor must be in (0,1], got {factor}")
    t = np.arange(num_steps)
    return (p_j0 * factor ** (t // drop_every)).astype(np.float32)


def linear_to_zero(p_j0: float, num_steps: int, zero_at: float = 0.8) -> np.ndarray:
    """Linear ramp from p_j0 to 0 reaching zero at fraction ``zero_at`` of T."""
    _validate(p_j0, num_steps)
    if not (0.0 < zero_at <= 1.0):
        raise ValueError(f"zero_at must be in (0,1], got {zero_at}")
    t = np.arange(num_steps, dtype=np.float64)
    horizon = max(1.0, zero_at * num_steps)
    return np.maximum(0.0, p_j0 * (1.0 - t / horizon)).astype(np.float32)
