"""Jump-probability schedules p_J(t) (paper Fig 6: shrink p_J -> 0 to kill the
error gap without losing speed).

Each schedule is a factory returning a (T,) float32 numpy array consumable by
``walk.walk_mhlj`` and the trainers.
"""
from __future__ import annotations

import numpy as np

__all__ = ["constant", "polynomial_decay", "step_decay", "linear_to_zero"]


def constant(p_j: float, num_steps: int) -> np.ndarray:
    return np.full(num_steps, p_j, dtype=np.float32)


def polynomial_decay(p_j0: float, num_steps: int, power: float = 1.0, t0: int = 1) -> np.ndarray:
    """p_J(t) = p_j0 * (t0 / (t0 + t))^power — the Fig-6 style annealing."""
    t = np.arange(num_steps, dtype=np.float64)
    return (p_j0 * (t0 / (t0 + t)) ** power).astype(np.float32)


def step_decay(p_j0: float, num_steps: int, drop_every: int, factor: float = 0.5) -> np.ndarray:
    t = np.arange(num_steps)
    return (p_j0 * factor ** (t // drop_every)).astype(np.float32)


def linear_to_zero(p_j0: float, num_steps: int, zero_at: float = 0.8) -> np.ndarray:
    """Linear ramp from p_j0 to 0 reaching zero at fraction ``zero_at`` of T."""
    t = np.arange(num_steps, dtype=np.float64)
    horizon = max(1.0, zero_at * num_steps)
    return np.maximum(0.0, p_j0 * (1.0 - t / horizon)).astype(np.float32)
