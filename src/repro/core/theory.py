"""Theorem-1 machinery: convergence-bound and error-gap evaluation (paper §VI).

E||x^T - x*||^2 <= O~( L_bar^2 tau_mix sigma*^2 / (L_min T) )
                 + O( p_J^2 ||P_IS - P_Levy||_1^2 )

We evaluate both terms with explicit constants-free scaling so EXPERIMENTS.md
can check the *predicted scalings* (1/T rate; p_J^2 gap slope; tau_mix
reduction from jumps) against measured curves, which is what the paper itself
validates (Figs 3, 6).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import levy as levy_mod
from repro.core import mixing
from repro.core import transition as trans_mod
from repro.core.graphs import Graph

__all__ = [
    "matrix_l1_norm",
    "perturbation_l1",
    "theorem1_terms",
    "needell_rates",
    "regression_fixed_point",
    "error_gap_exact",
]


def matrix_l1_norm(m: np.ndarray) -> float:
    """Induced L1 norm (max absolute column sum) — the paper's ||.||_1."""
    return float(np.abs(m).sum(axis=0).max())


def perturbation_l1(graph: Graph, lipschitz: np.ndarray, params: trans_mod.MHLJParams) -> float:
    """||P_IS - P_Levy||_1 — the error-gap driver in Theorem 1 (bounded by n^2)."""
    p_is = trans_mod.mh_importance(graph, lipschitz)
    p_levy = levy_mod.levy_matrix_chained(graph, params.p_d, params.r)
    return matrix_l1_norm(p_is - p_levy)


@dataclasses.dataclass(frozen=True)
class Theorem1Terms:
    """Evaluated scaling terms of Eq. (9) plus the chain statistics behind them."""

    rate_term: float  # L_bar^2 tau_mix sigma*^2 / (L_min T)
    gap_term: float  # p_J^2 ||P_IS - P_Levy||_1^2
    tau_mix: int
    tau_mix_mh: int  # mixing time of the unperturbed P_IS chain, for comparison
    spectral_gap: float
    spectral_gap_mh: float
    perturbation_l1: float
    l_bar: float
    l_min: float
    l_max: float


def theorem1_terms(
    graph: Graph,
    lipschitz: np.ndarray,
    params: trans_mod.MHLJParams,
    *,
    sigma_star_sq: float = 1.0,
    num_iters: int = 1,
    eps: float = 0.25,
    max_t: int = 1 << 22,
) -> Theorem1Terms:
    """Evaluate both Theorem-1 terms for a concrete (graph, L, params) instance."""
    lipschitz = np.asarray(lipschitz, dtype=np.float64)
    p_is = trans_mod.mh_importance(graph, lipschitz)
    p = trans_mod.mhlj(graph, lipschitz, params)
    tau = mixing.mixing_time_tv(p, eps=eps, max_t=max_t)
    tau_mh = mixing.mixing_time_tv(p_is, eps=eps, max_t=max_t)
    pert = perturbation_l1(graph, lipschitz, params)
    l_bar = float(lipschitz.mean())
    l_min = float(lipschitz.min())
    rate = (l_bar**2) * tau * sigma_star_sq / (l_min * num_iters)
    gap = (params.p_j**2) * (pert**2)
    return Theorem1Terms(
        rate_term=float(rate),
        gap_term=float(gap),
        tau_mix=int(tau),
        tau_mix_mh=int(tau_mh),
        spectral_gap=mixing.spectral_gap(p),
        spectral_gap_mh=mixing.spectral_gap(p_is),
        perturbation_l1=pert,
        l_bar=l_bar,
        l_min=l_min,
        l_max=float(lipschitz.max()),
    )


def regression_fixed_point(
    features: np.ndarray,  # (n, d) A_v
    targets: np.ndarray,  # (n,) y_v
    nu: np.ndarray,  # (n,) sampling distribution of the walk
    weights: np.ndarray,  # (n,) importance weights w(v) = L_bar / L_v
) -> np.ndarray:
    """Exact expected fixed point of weighted RW-SGD for least squares.

    SGD with sampling distribution nu and gradient weights w converges (in
    expectation, for small gamma) to the solution of
        sum_v nu_v w_v A_v (A_v^T x - y_v) = 0,
    i.e. weighted normal equations.  When nu = pi_IS and w = L_bar/L_v the
    weights cancel the bias exactly (nu_v w_v = const) and x~ equals the true
    least-squares optimum; MHLJ's perturbed nu leaves an O(p_J) residual in
    nu_v w_v and hence an O(p_J^2) squared error gap — Theorem 1's second
    term, computable in closed form here."""
    c = nu * weights  # (n,)
    gram = (features * c[:, None]).T @ features
    rhs = (features * c[:, None]).T @ targets
    return np.linalg.solve(gram, rhs)


def error_gap_exact(
    graph: Graph,
    features: np.ndarray,
    targets: np.ndarray,
    lipschitz: np.ndarray,
    params: trans_mod.MHLJParams,
) -> float:
    """||x~(p_J) - x_LS||^2: the exact asymptotic error gap of MHLJ on a
    least-squares instance (zero when p_J = 0)."""
    p = trans_mod.mhlj(graph, lipschitz, params)
    nu = mixing.stationary_distribution(p)
    w = lipschitz.mean() / lipschitz
    x_tilde = regression_fixed_point(features, targets, nu, w)
    x_ls = np.linalg.pinv(features) @ targets
    return float(((x_tilde - x_ls) ** 2).sum())


def needell_rates(lipschitz: np.ndarray, num_iters: int) -> dict:
    """Centralized reference rates (paper §III.A, Needell et al. Thm 2.1).

    uniform:    O~(L_max / T)
    importance: O~(L_bar^2 / (L_min T))
    """
    lipschitz = np.asarray(lipschitz, dtype=np.float64)
    l_bar = lipschitz.mean()
    return {
        "uniform": float(lipschitz.max() / num_iters),
        "importance": float(l_bar**2 / (lipschitz.min() * num_iters)),
        "speedup_predicted": float(
            lipschitz.max() * lipschitz.min() / (l_bar**2)
        ),
    }
