"""Transition-matrix designs for random-walk decentralized SGD (paper §I, §III, §V).

All builders return dense row-stochastic numpy ``(n, n)`` matrices supported on
the graph (plus self-loops).  Padded per-row probability tensors for jitted
sampling are produced by :func:`row_probs_padded`.

Designs implemented:

1. ``simple_rw``        P(v,u) = 1/deg(v)                      (stationary ∝ deg)
2. ``mh(pi)``           general Metropolis–Hastings, Eq. (6)
3. ``mh_uniform``       MH targeting uniform π                  (Eq. choice 2)
4. ``mh_importance``    P_IS of Eq. (7): MH targeting π_IS ∝ L_v
5. ``mhlj``             P = (1-p_J) P_IS + p_J P_Lévy           (paper §V)
6. ``heterogeneity_mh``   MH targeting the heterogeneity-optimized π of
   ``repro.core.heterogeneity`` (Dandi et al., arXiv:2204.06477)
7. ``private_weighted_mh``  MH targeting Gamma-noised weights ŵ = w + G —
   the private weighted walk of Ayache & El Rouayheb (arXiv:2009.01790)

Every MH-family law (3, 4, 6, 7) is "MH targeting a weight vector" and all
its padded / bucketed / ragged row builders route through the ONE shared
block ``_mh_rows_block`` — a new law inherits the four-layout bitwise
parity contract by construction instead of re-proving it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.graphs import (
    Graph,
    _pad_neighbor_lists,
    _ragged_row_chunks,
    flat_edge_values,
)
from repro.core import levy as levy_mod

__all__ = [
    "simple_rw",
    "mh",
    "mh_uniform",
    "mh_importance",
    "mhlj",
    "MHLJParams",
    "row_probs_padded",
    "simple_rw_rows",
    "mh_uniform_rows",
    "mh_importance_rows",
    "simple_rw_rows_bucketed",
    "mh_uniform_rows_bucketed",
    "mh_importance_rows_bucketed",
    "simple_rw_rows_ragged",
    "mh_uniform_rows_ragged",
    "mh_importance_rows_ragged",
    "heterogeneity_mh",
    "heterogeneity_rows",
    "heterogeneity_rows_bucketed",
    "heterogeneity_rows_ragged",
    "private_weights",
    "private_weighted_mh",
    "private_weighted_rows",
    "private_weighted_rows_bucketed",
    "private_weighted_rows_ragged",
    "is_row_stochastic",
    "supported_on_graph",
]


@dataclasses.dataclass(frozen=True)
class MHLJParams:
    """Lévy jump hyper-parameters (paper uses (0.1, 0.5, 3) in Fig 3)."""

    p_j: float = 0.1
    p_d: float = 0.5
    r: int = 3

    def validate(self) -> None:
        if not (0.0 <= self.p_j <= 1.0):
            raise ValueError(f"p_j must be in [0,1], got {self.p_j}")
        if not (0.0 < self.p_d < 1.0):
            raise ValueError(f"p_d must be in (0,1), got {self.p_d}")
        if self.r < 1:
            raise ValueError(f"r must be >= 1, got {self.r}")


def simple_rw(graph: Graph) -> np.ndarray:
    """Uniform neighbor choice: P(v,u) = 1/deg(v) on edges (incl. self-loop)."""
    a = graph.adj
    return a / a.sum(axis=1, keepdims=True)


def mh(graph: Graph, pi: np.ndarray, q: Optional[np.ndarray] = None) -> np.ndarray:
    """General Metropolis–Hastings transition, paper Eq. (6).

    P(i,j) = Q(i,j) min{1, pi_j Q(j,i) / (pi_i Q(i,j))} for i != j on edges,
    diagonal = leftover mass.  Q defaults to the simple random walk.

    A custom proposal ``q`` must be a valid chain for the MH construction to
    return the MH chain *of that proposal*: row-stochastic and supported on
    the graph (plus self-loops).  An invalid ``q`` raises — masking off-graph
    mass or renormalizing a non-stochastic proposal would silently return a
    chain with a different (and wrong) stationary distribution.
    """
    pi = np.asarray(pi, dtype=np.float64)
    if pi.shape != (graph.n,):
        raise ValueError(f"pi must have shape ({graph.n},), got {pi.shape}")
    if np.any(pi <= 0):
        raise ValueError("pi must be strictly positive")
    pi = pi / pi.sum()
    if q is None:
        q = simple_rw(graph)
    else:
        q = np.asarray(q, dtype=np.float64)
        if q.shape != (graph.n, graph.n):
            raise ValueError(
                f"proposal q must have shape ({graph.n}, {graph.n}), "
                f"got {q.shape}"
            )
        if not is_row_stochastic(q, atol=1e-8):
            bad = np.abs(q.sum(axis=1) - 1.0).argmax()
            raise ValueError(
                "proposal q is not row-stochastic (row "
                f"{bad} sums to {q.sum(axis=1)[bad]:.6g} or carries "
                "negative mass); refusing to renormalize silently"
            )
        if not supported_on_graph(q, graph, atol=1e-12):
            raise ValueError(
                "proposal q places mass on non-edges; the MH chain of an "
                "off-graph proposal is not implementable by a walk on this "
                "graph"
            )

    a = graph.adj
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = (pi[None, :] * q.T) / (pi[:, None] * q)
    ratio = np.where(q > 0, ratio, 0.0)
    p = q * np.minimum(1.0, ratio)
    p *= a  # support constraint (redundant now that q is validated)
    np.fill_diagonal(p, 0.0)
    np.fill_diagonal(p, 1.0 - p.sum(axis=1))
    # numerical guard: tiny negative diagonals from float error
    diag = np.diag(p).copy()
    if np.any(diag < -1e-12):
        raise AssertionError("MH construction produced negative self-loop mass")
    np.fill_diagonal(p, np.maximum(diag, 0.0))
    p /= p.sum(axis=1, keepdims=True)
    return p


def mh_uniform(graph: Graph) -> np.ndarray:
    """MH targeting the uniform distribution (paper design 2)."""
    return mh(graph, np.full(graph.n, 1.0 / graph.n))


def mh_importance(graph: Graph, lipschitz: np.ndarray) -> np.ndarray:
    """P_IS of paper Eq. (7): MH targeting pi_IS(v) ∝ L_v.

    Eq. (7) is exactly Eq. (6) with Q = simple RW and pi = pi_IS:
      P(i,j) = (1/deg(i)) min{1, deg(i) L_j / (deg(j) L_i)}.
    """
    lipschitz = np.asarray(lipschitz, dtype=np.float64)
    if lipschitz.shape != (graph.n,):
        raise ValueError(
            f"lipschitz must have shape ({graph.n},), got {lipschitz.shape}"
        )
    if np.any(lipschitz <= 0):
        raise ValueError("Lipschitz constants must be strictly positive")
    return mh(graph, lipschitz / lipschitz.sum())


def mhlj(
    graph: Graph,
    lipschitz: np.ndarray,
    params: MHLJParams,
    *,
    chained_levy: bool = True,
) -> np.ndarray:
    """MHLJ effective transition: P = (1 - p_J) P_IS + p_J P_Lévy (paper §V).

    ``chained_levy=True`` uses the exact law of Algorithm 1's jump loop
    (composition of uniform hops); ``False`` uses the paper's adjacency-power
    closed form.  They coincide on regular graphs (ring, torus grid).
    """
    params.validate()
    p_is = mh_importance(graph, lipschitz)
    if params.p_j == 0.0:
        return p_is
    if chained_levy:
        p_levy = levy_mod.levy_matrix_chained(graph, params.p_d, params.r)
    else:
        p_levy = levy_mod.levy_matrix(graph, params.p_d, params.r)
    return (1.0 - params.p_j) * p_is + params.p_j * p_levy


# ---------------------------------------------------------------------------
# Validation + padded representation helpers
# ---------------------------------------------------------------------------


def is_row_stochastic(p: np.ndarray, atol: float = 1e-9) -> bool:
    return bool(
        np.all(p >= -atol) and np.allclose(p.sum(axis=1), 1.0, atol=atol)
    )


def supported_on_graph(p: np.ndarray, graph: Graph, atol: float = 1e-12) -> bool:
    """True iff P(i,j) > 0 only where adj(i,j) = 1 ... for 1-hop kernels.

    Note MHLJ with r > 1 is NOT 1-hop supported (jumps traverse up to r edges
    but each hop uses only local neighbor knowledge) — callers should test the
    r-hop reachability matrix instead.
    """
    off_support = p * (1.0 - np.minimum(graph.adj, 1.0))
    return bool(np.abs(off_support).max() <= atol)


# ---------------------------------------------------------------------------
# Sparse (padded-row) counterparts — O(E), no dense N×N matrix
# ---------------------------------------------------------------------------
#
# These compute the SAME 1-hop kernels as the dense builders above, but
# directly on the padded neighbor tensor of a ``Graph`` or ``CSRGraph``
# (everything is local: deg(v), deg(u), L_v, L_u).  Convention: each true
# neighbor slot (including the single self slot) carries its probability,
# leftover MH mass lands on the self slot, pads carry exactly 0 — so CDF
# inversion and ``walk_markov``'s categorical both realize the exact law.
#
# All builders route through the ``_*_block`` helpers, which operate on an
# arbitrary padded neighbor block ``(rows, width)``.  Because pads carry
# exactly 0 and float sums over trailing exact zeros are unchanged, a row
# computed at bucket width is the column-truncation of the same row at
# ``max_deg`` — the bitwise bridge between the padded and bucketed layouts
# (see docs/layouts.md).


def _block_masks(nbrs: np.ndarray, self_ids: np.ndarray, deg_v: np.ndarray):
    width = nbrs.shape[1]
    is_pad = np.arange(width)[None, :] >= deg_v[:, None]
    is_self = (nbrs == self_ids[:, None].astype(nbrs.dtype)) & ~is_pad
    return is_pad, is_self


def _mh_rows_block(
    nbrs: np.ndarray,  # (rows, width) padded neighbor block
    self_ids: np.ndarray,  # (rows,) owning node id per row
    deg_v: np.ndarray,  # (rows,) true degree per row
    degrees: np.ndarray,  # (n,) full degree vector (neighbor lookups)
    target_weight: np.ndarray,  # (n,) pi ∝ target_weight
) -> np.ndarray:
    """MH rows (Eq. 6, Q = simple RW) on an arbitrary padded block.

    P(v,u) = (1/deg_v) min{1, deg_v w_u / (deg_u w_v)} for true neighbors
    u != v; leftover mass goes to the self slot, pads carry exactly 0.
    """
    is_pad, is_self = _block_masks(nbrs, self_ids, deg_v)
    w = np.asarray(target_weight, dtype=np.float64)
    deg_vf = deg_v[:, None].astype(np.float64)
    deg_u = degrees[nbrs].astype(np.float64)
    move = np.minimum(1.0 / deg_vf, w[nbrs] / (deg_u * w[self_ids][:, None]))
    move = np.where(is_pad | is_self, 0.0, move)
    p_self = 1.0 - move.sum(axis=1, keepdims=True)
    out = np.where(is_self, p_self, move)
    out = np.maximum(out, 0.0)
    return (out / out.sum(axis=1, keepdims=True)).astype(np.float32)


def _simple_rw_block(nbrs: np.ndarray, deg_v: np.ndarray) -> np.ndarray:
    """Simple-RW rows on a padded block: 1/deg_v on true slots, pads 0."""
    width = nbrs.shape[1]
    is_pad = np.arange(width)[None, :] >= deg_v[:, None]
    out = np.where(is_pad, 0.0, 1.0 / deg_v[:, None].astype(np.float64))
    return out.astype(np.float32)


def _graph_locals(graph):
    nbrs = np.asarray(graph.neighbors)
    deg = np.asarray(graph.degrees, dtype=np.int64)
    return nbrs, np.arange(graph.n, dtype=np.int64), deg


def simple_rw_rows(graph) -> np.ndarray:
    """Padded rows of the simple RW: 1/deg(v) on every true neighbor slot."""
    nbrs, _, deg = _graph_locals(graph)
    return _simple_rw_block(nbrs, deg)


def mh_uniform_rows(graph) -> np.ndarray:
    """Padded MH rows targeting uniform pi: P(v,u) = min{1/deg_v, 1/deg_u}."""
    nbrs, ids, deg = _graph_locals(graph)
    return _mh_rows_block(nbrs, ids, deg, deg, np.ones(graph.n))


def _check_lipschitz(graph, lipschitz) -> np.ndarray:
    lipschitz = np.asarray(lipschitz, dtype=np.float64)
    if lipschitz.shape != (graph.n,):
        raise ValueError(
            f"lipschitz must have shape ({graph.n},), got {lipschitz.shape}"
        )
    if np.any(lipschitz <= 0):
        raise ValueError("Lipschitz constants must be strictly positive")
    return lipschitz


def mh_importance_rows(graph, lipschitz: np.ndarray) -> np.ndarray:
    """Padded P_IS rows of Eq. (7) from local info only (numpy twin of
    ``engine.p_is_rows``, with leftover mass on the self slot)."""
    lipschitz = _check_lipschitz(graph, lipschitz)
    nbrs, ids, deg = _graph_locals(graph)
    return _mh_rows_block(nbrs, ids, deg, deg, lipschitz)


# -- degree-bucketed counterparts (tuple of per-bucket (n_b, width_b)) ------
#
# Same three 1-hop kernels for a ``BucketedCSRGraph``: one array per degree
# bucket, aligned with ``bucket.neighbors``.  Each bucket array is the
# column-truncation of the corresponding padded-builder rows (same block
# math, same zero-pad convention), so ``layout="bucketed"`` samples the
# identical CDF per key.


def simple_rw_rows_bucketed(graph) -> tuple:
    """Per-bucket simple-RW rows for a :class:`BucketedCSRGraph`."""
    deg = np.asarray(graph.degrees, dtype=np.int64)
    return tuple(
        _simple_rw_block(b.neighbors, deg[b.node_ids]) for b in graph.buckets
    )


def _mh_rows_bucketed(graph, target_weight: np.ndarray) -> tuple:
    deg = np.asarray(graph.degrees, dtype=np.int64)
    return tuple(
        _mh_rows_block(
            b.neighbors, b.node_ids.astype(np.int64),
            deg[b.node_ids], deg, target_weight,
        )
        for b in graph.buckets
    )


def mh_uniform_rows_bucketed(graph) -> tuple:
    """Per-bucket MH-uniform rows for a :class:`BucketedCSRGraph`."""
    return _mh_rows_bucketed(graph, np.ones(graph.n))


def mh_importance_rows_bucketed(graph, lipschitz: np.ndarray) -> tuple:
    """Per-bucket P_IS rows of Eq. (7) for a :class:`BucketedCSRGraph`."""
    return _mh_rows_bucketed(graph, _check_lipschitz(graph, lipschitz))


# -- ragged (flat per-edge) counterparts ------------------------------------
#
# Same three 1-hop kernels as a flat ``(nnz,)`` probability buffer aligned
# with the graph's CSR ``indices`` — the row source of the engine's
# ``layout="ragged"`` true-degree path.  Rows are produced in bounded-size
# chunks through the SAME block builders at the full ``max_deg`` width and
# then stripped of their (exactly-zero) pads by ``graphs.flat_edge_values``,
# so every flat entry is bit-for-bit the corresponding padded-builder entry
# and the ragged layout samples the identical CDF per key.  No O(n·max_deg)
# array ever exists — transient memory is O(chunk·max_deg).


def _rows_ragged(
    graph,
    block_fn,
    chunk_rows: Optional[int] = None,
    node_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    deg = np.asarray(graph.degrees, dtype=np.int64)
    n, max_deg = deg.size, int(deg.max())
    if node_ids is not None:
        # Restricted build for incremental churn updates: one flat buffer
        # covering exactly these rows in ascending CSR edge order — the
        # ``touched_probs`` input of ``engine.ragged_edge_cdf_update``.
        # Rows go through the SAME block builder at the full ``max_deg``
        # width, so each entry stays bit-for-bit the full-build entry.
        ids = np.asarray(node_ids, dtype=np.int64)
        if ids.size and (
            np.any(np.diff(ids) <= 0) or ids[0] < 0 or ids[-1] >= n
        ):
            raise ValueError(
                "node_ids must be unique ascending node ids in range "
                "(EdgeChurn.touched_rows is)"
            )
        nbrs = _pad_neighbor_lists(
            indptr, indices, deg, node_ids=ids, width=max_deg
        )
        return flat_edge_values(
            indptr, deg, block_fn(nbrs, ids, deg[ids]), node_ids=ids
        )
    out = np.empty(indices.shape[0], dtype=np.float32)
    for ids in _ragged_row_chunks(n, max_deg, chunk_rows):
        nbrs = _pad_neighbor_lists(
            indptr, indices, deg, node_ids=ids, width=max_deg
        )
        out[indptr[ids[0]] : indptr[ids[-1] + 1]] = flat_edge_values(
            indptr, deg, block_fn(nbrs, ids, deg[ids]), node_ids=ids
        )
    return out


def simple_rw_rows_ragged(
    graph,
    chunk_rows: Optional[int] = None,
    node_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Flat (nnz,) simple-RW probabilities for any CSR-core graph.

    ``node_ids`` (unique ascending) restricts the buffer to those rows —
    the churn-update row source (``engine.ragged_edge_cdf_update``).
    """
    return _rows_ragged(
        graph, lambda nbrs, ids, deg_v: _simple_rw_block(nbrs, deg_v),
        chunk_rows, node_ids,
    )


def mh_uniform_rows_ragged(
    graph,
    chunk_rows: Optional[int] = None,
    node_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Flat (nnz,) MH-uniform probabilities for any CSR-core graph."""
    deg = np.asarray(graph.degrees, dtype=np.int64)
    weight = np.ones(deg.size)
    return _rows_ragged(
        graph,
        lambda nbrs, ids, deg_v: _mh_rows_block(nbrs, ids, deg_v, deg, weight),
        chunk_rows, node_ids,
    )


def mh_importance_rows_ragged(
    graph,
    lipschitz: np.ndarray,
    chunk_rows: Optional[int] = None,
    node_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Flat (nnz,) P_IS probabilities of Eq. (7) for any CSR-core graph.

    The row source of the engine's ``layout="ragged"`` path: entry
    ``indptr[v] + k`` is bit-for-bit ``mh_importance_rows(graph)[v, k]``
    (same block math at the same width, pads dropped), so the flat CDF the
    engine builds from it inverts to the identical neighbor per key.
    ``node_ids`` (unique ascending) restricts the buffer to those rows —
    the churn-update row source (``engine.ragged_edge_cdf_update``).
    """
    lipschitz = _check_lipschitz(graph, lipschitz)
    deg = np.asarray(graph.degrees, dtype=np.int64)
    return _rows_ragged(
        graph,
        lambda nbrs, ids, deg_v: _mh_rows_block(
            nbrs, ids, deg_v, deg, lipschitz
        ),
        chunk_rows, node_ids,
    )


# ---------------------------------------------------------------------------
# Heterogeneity-aware law (Dandi et al., arXiv:2204.06477)
# ---------------------------------------------------------------------------
#
# MH targeting the pi optimized by ``repro.core.heterogeneity`` against the
# measured gradient-dissimilarity matrix.  Structurally this is Eq. (6) with
# w = pi, so every variant is one call into the shared block math — the
# four-layout bitwise parity contract is inherited, not re-proven.


def _check_target_pi(graph, pi) -> np.ndarray:
    pi = np.asarray(pi, dtype=np.float64)
    if pi.shape != (graph.n,):
        raise ValueError(f"pi must have shape ({graph.n},), got {pi.shape}")
    if np.any(pi <= 0):
        raise ValueError(
            "heterogeneity target pi must be strictly positive — a zero "
            "entry disconnects the MH chain (use the optimizer's floor)"
        )
    return pi


def heterogeneity_mh(graph: Graph, pi: np.ndarray) -> np.ndarray:
    """Dense MH chain targeting a heterogeneity-optimized pi.

    ``pi`` comes from ``repro.core.heterogeneity.optimize_pi`` (or
    ``heterogeneity_pi``); any strictly positive (n,) target is accepted.
    """
    return mh(graph, _check_target_pi(graph, pi))


def heterogeneity_rows(graph, pi: np.ndarray) -> np.ndarray:
    """Padded MH rows targeting a heterogeneity-optimized pi."""
    pi = _check_target_pi(graph, pi)
    nbrs, ids, deg = _graph_locals(graph)
    return _mh_rows_block(nbrs, ids, deg, deg, pi)


def heterogeneity_rows_bucketed(graph, pi: np.ndarray) -> tuple:
    """Per-bucket heterogeneity-law rows for a :class:`BucketedCSRGraph`."""
    return _mh_rows_bucketed(graph, _check_target_pi(graph, pi))


def heterogeneity_rows_ragged(
    graph,
    pi: np.ndarray,
    chunk_rows: Optional[int] = None,
    node_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Flat (nnz,) heterogeneity-law probabilities for any CSR-core graph."""
    pi = _check_target_pi(graph, pi)
    deg = np.asarray(graph.degrees, dtype=np.int64)
    return _rows_ragged(
        graph,
        lambda nbrs, ids, deg_v: _mh_rows_block(nbrs, ids, deg_v, deg, pi),
        chunk_rows, node_ids,
    )


# ---------------------------------------------------------------------------
# Private weighted walk (Ayache & El Rouayheb, arXiv:2009.01790)
# ---------------------------------------------------------------------------
#
# A weighted random walk whose stationary distribution encodes node
# importance, run on Gamma-perturbed weights ŵ_v = w_v + G_v so no node's
# true weight (its data's worth — e.g. its Lipschitz constant) is revealed
# to neighbors.  The noise exploits Gamma infinite divisibility: with
# G_v ~ Gamma(1/n, theta) i.i.d., the aggregate Σ_v G_v ~ Gamma(1, theta)
# is an Exponential(theta) regardless of n, so total distortion of the
# stationary law stays bounded while each node's share is maximally vague.
# ``gamma`` scales theta = gamma · n · mean(w): gamma = 0 is the exact
# weighted walk, larger gamma trades convergence (stationary TV deviation)
# for privacy — the knob the law sweep benchmark exposes.


def private_weights(
    weights: np.ndarray, gamma: float, *, seed: int = 0
) -> np.ndarray:
    """Gamma-noised node weights ŵ = w + G, G_v ~ Gamma(1/n, gamma·n·w̄).

    Drawn ONCE per chain from a fixed ``seed`` (numpy Generator): the
    perturbed weights are then an ordinary static MH target, so all four
    engine layouts built from the same (weights, gamma, seed) triple sample
    the identical chain bitwise.  ``gamma=0`` returns ``w`` exactly.
    """
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1:
        raise ValueError(f"weights must be (n,), got shape {w.shape}")
    if np.any(w <= 0):
        raise ValueError("node weights must be strictly positive")
    if gamma < 0:
        raise ValueError(f"privacy gamma must be >= 0, got {gamma}")
    if gamma == 0.0:
        return w.copy()
    n = w.size
    rng = np.random.default_rng(seed)
    noise = rng.gamma(shape=1.0 / n, scale=gamma * n * w.mean(), size=n)
    return w + noise


def private_weighted_mh(
    graph: Graph, weights: np.ndarray, gamma: float, *, seed: int = 0
) -> np.ndarray:
    """Dense private weighted walk: MH targeting ŵ = ``private_weights``."""
    w_hat = private_weights(_check_lipschitz(graph, weights), gamma, seed=seed)
    return mh(graph, w_hat / w_hat.sum())


def private_weighted_rows(
    graph, weights: np.ndarray, gamma: float, *, seed: int = 0
) -> np.ndarray:
    """Padded private-weighted-walk rows (MH targeting ŵ)."""
    w_hat = private_weights(_check_lipschitz(graph, weights), gamma, seed=seed)
    nbrs, ids, deg = _graph_locals(graph)
    return _mh_rows_block(nbrs, ids, deg, deg, w_hat)


def private_weighted_rows_bucketed(
    graph, weights: np.ndarray, gamma: float, *, seed: int = 0
) -> tuple:
    """Per-bucket private-weighted-walk rows for a :class:`BucketedCSRGraph`."""
    w_hat = private_weights(_check_lipschitz(graph, weights), gamma, seed=seed)
    return _mh_rows_bucketed(graph, w_hat)


def private_weighted_rows_ragged(
    graph,
    weights: np.ndarray,
    gamma: float,
    *,
    seed: int = 0,
    chunk_rows: Optional[int] = None,
    node_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Flat (nnz,) private-weighted-walk probabilities for any CSR-core graph.

    The noise draw depends only on (weights, gamma, seed) — never on
    ``node_ids`` — so a churn-restricted buffer stays consistent with the
    full build of the same triple.
    """
    w_hat = private_weights(_check_lipschitz(graph, weights), gamma, seed=seed)
    deg = np.asarray(graph.degrees, dtype=np.int64)
    return _rows_ragged(
        graph,
        lambda nbrs, ids, deg_v: _mh_rows_block(nbrs, ids, deg_v, deg, w_hat),
        chunk_rows, node_ids,
    )


def row_probs_padded(p: np.ndarray, graph: Graph) -> np.ndarray:
    """Gather each row of a 1-hop-supported P onto the padded neighbor lists.

    Returns (n, max_deg) float32 probabilities aligned with ``graph.neighbors``;
    padding entries get probability 0.  Only valid for 1-hop kernels
    (simple RW, MH, P_IS) — the MHLJ *simulation* never materializes P but
    follows Algorithm 1's two-phase sampling instead.
    """
    if not supported_on_graph(p, graph):
        raise ValueError("row_probs_padded requires a 1-hop-supported kernel")
    n, max_deg = graph.neighbors.shape
    out = np.zeros((n, max_deg), dtype=np.float32)
    for v in range(n):
        deg = int(graph.degrees[v])
        nbrs = graph.neighbors[v, :deg]
        out[v, :deg] = p[v, nbrs]
        # self-loop mass may appear both as a real neighbor entry and (for
        # padded slots) must not be duplicated: pads stay at 0.
    # renormalize tiny float error
    s = out.sum(axis=1, keepdims=True)
    return (out / s).astype(np.float32)
