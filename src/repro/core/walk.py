"""JAX random-walk simulators (paper §II.C + Algorithm 1).

The MHLJ transition itself lives in :mod:`repro.core.engine` — the single
source of truth for Algorithm 1 — and the simulators here are thin
trajectory-shaped consumers of :class:`~repro.core.engine.WalkEngine`:

* :func:`walk_markov` — a generic 1-hop time-homogeneous chain given padded
  per-row probabilities (covers simple RW, MH-uniform, MH-IS).  Not an MHLJ
  variant, so it does not route through the engine.
* :func:`walk_mhlj` — Algorithm 1 exactly, via ``WalkEngine.run``: per
  iteration flip J~Ber(p_J); J=0 -> one MH-IS hop; J=1 -> d~TruncGeom(p_d, r)
  uniform hops without updates.  Returns the sequence of *update* nodes v_t
  plus the number of physical transitions per iteration (Remark-1
  accounting).
* :func:`walk_mhlj_batched` — W parallel walks in one batched engine run
  (a single vectorized transition per step, not W scans).

``p_j`` may be a scalar or a (T,) schedule array (Fig 6 annealing).

Representation: graphs enter as padded neighbor tensors ``neighbors`` of shape
(n, max_deg) with degree vector ``degrees`` (see ``core.graphs``); 1-hop
transition rows enter as (n, max_deg) probabilities aligned with neighbors.
"""
from __future__ import annotations

import functools
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import WalkEngine

__all__ = [
    "graph_tensors",
    "walk_markov",
    "walk_mhlj",
    "walk_markov_batched",
    "walk_mhlj_batched",
]


def graph_tensors(graph) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Device tensors (neighbors int32 (n,max_deg), degrees int32 (n,)).

    Accepts a dense :class:`~repro.core.graphs.Graph` or an O(E)
    :class:`~repro.core.graphs.CSRGraph` — both carry the same padded
    neighbor tensors, so every simulator here runs on either.  A
    :class:`~repro.core.graphs.BucketedCSRGraph` deliberately has no full
    padded tensor; build the engine from it directly
    (``WalkEngine.from_graph``) instead of materializing one here.
    """
    if not hasattr(graph, "neighbors"):
        raise TypeError(
            "graph has no padded neighbor tensor (bucketed layout?); use "
            "WalkEngine.from_graph(graph, ...) or graph.to_csr() instead"
        )
    return jnp.asarray(graph.neighbors), jnp.asarray(graph.degrees)


def _categorical_padded(key, probs_row: jnp.ndarray) -> jnp.ndarray:
    """Sample an index from a padded probability row (pads have prob 0)."""
    logits = jnp.log(jnp.maximum(probs_row, 1e-38))
    logits = jnp.where(probs_row > 0, logits, -jnp.inf)
    return jax.random.categorical(key, logits)


@functools.partial(jax.jit, static_argnames=("num_steps",))
def walk_markov(
    key: jax.Array,
    row_probs: jnp.ndarray,  # (n, max_deg) float, aligned with neighbors
    neighbors: jnp.ndarray,  # (n, max_deg) int32
    v0: Union[int, jnp.ndarray],
    num_steps: int,
) -> jnp.ndarray:
    """Simulate a 1-hop chain; returns trajectory (num_steps+1,) of node ids."""

    def step(carry, key_t):
        v = carry
        idx = _categorical_padded(key_t, row_probs[v])
        v_next = neighbors[v, idx]
        return v_next, v_next

    keys = jax.random.split(key, num_steps)
    v0 = jnp.asarray(v0, dtype=jnp.int32)
    _, traj = jax.lax.scan(step, v0, keys)
    return jnp.concatenate([v0[None], traj])


@functools.partial(jax.jit, static_argnames=("num_steps", "r", "p_d"))
def walk_mhlj(
    key: jax.Array,
    is_row_probs: jnp.ndarray,  # (n, max_deg) P_IS rows
    neighbors: jnp.ndarray,  # (n, max_deg)
    degrees: jnp.ndarray,  # (n,)
    v0: Union[int, jnp.ndarray],
    num_steps: int,
    p_j: Union[float, jnp.ndarray],
    p_d: float,
    r: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Algorithm 1's node sequence (single walk), via the unified engine.

    Returns:
      update_nodes: (num_steps,) int32 — v_t at which update t is applied
        (element t is the node holding the model when update t runs; the
        first update runs at v0).
      transitions: (num_steps,) int32 — physical hops taken after update t
        (1 for an MH move, d for a jump) — Remark-1 accounting.
    """
    engine = WalkEngine(
        neighbors=neighbors,
        degrees=degrees,
        p_d=p_d,
        r=r,
        row_probs=is_row_probs,
        backend="scan",
    )
    return engine.run(key, jnp.asarray(v0, jnp.int32), num_steps, p_j=p_j)


def walk_markov_batched(key, row_probs, neighbors, v0s, num_steps):
    """vmap over independent walks; v0s: (w,) -> trajectories (w, num_steps+1)."""
    keys = jax.random.split(key, v0s.shape[0])
    return jax.vmap(walk_markov, in_axes=(0, None, None, 0, None))(
        keys, row_probs, neighbors, v0s, num_steps
    )


@functools.partial(
    jax.jit, static_argnames=("num_steps", "r", "p_d", "backend")
)
def walk_mhlj_batched(
    key,
    is_row_probs,
    neighbors,
    degrees,
    v0s,
    num_steps,
    p_j,
    p_d,
    r,
    backend: str = "auto",
):
    """W Algorithm-1 walks in one batched engine run.

    One vectorized transition services all W walks per step (the Pallas
    kernel on TPU, vmapped scan math elsewhere); returns (w, num_steps)
    update nodes + hops.
    """
    engine = WalkEngine(
        neighbors=neighbors,
        degrees=degrees,
        p_d=p_d,
        r=r,
        row_probs=is_row_probs,
        backend=backend,
    )
    return engine.run(key, v0s, num_steps, p_j=p_j)


def empirical_distribution(update_nodes: np.ndarray, n: int, burn_in: int = 0) -> np.ndarray:
    """Empirical visit distribution of the update sequence after burn-in."""
    seq = np.asarray(update_nodes)[..., burn_in:].ravel()
    counts = np.bincount(seq, minlength=n).astype(np.float64)
    return counts / counts.sum()
