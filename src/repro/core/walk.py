"""JAX random-walk simulators (paper §II.C + Algorithm 1).

Two simulators, both ``jax.lax.scan``-based and jit/vmap-friendly:

* :func:`walk_markov` — a generic 1-hop time-homogeneous chain given padded
  per-row probabilities (covers simple RW, MH-uniform, MH-IS).
* :func:`walk_mhlj` — Algorithm 1 exactly: per iteration flip J~Ber(p_J);
  J=0 -> one MH-IS hop; J=1 -> d~TruncGeom(p_d, r) uniform hops without
  updates.  Returns the sequence of *update* nodes v_t plus the number of
  physical transitions per iteration (Remark-1 accounting).

``p_j`` may be a scalar or a (T,) schedule array (Fig 6 annealing).

Representation: graphs enter as padded neighbor tensors ``neighbors`` of shape
(n, max_deg) with degree vector ``degrees`` (see ``core.graphs``); 1-hop
transition rows enter as (n, max_deg) probabilities aligned with neighbors.
"""
from __future__ import annotations

import functools
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import Graph
from repro.core.levy import trunc_geom_pmf

__all__ = [
    "graph_tensors",
    "walk_markov",
    "walk_mhlj",
    "walk_markov_batched",
    "walk_mhlj_batched",
]


def graph_tensors(graph: Graph) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Device tensors (neighbors int32 (n,max_deg), degrees int32 (n,))."""
    return jnp.asarray(graph.neighbors), jnp.asarray(graph.degrees)


def _categorical_padded(key, probs_row: jnp.ndarray) -> jnp.ndarray:
    """Sample an index from a padded probability row (pads have prob 0)."""
    logits = jnp.log(jnp.maximum(probs_row, 1e-38))
    logits = jnp.where(probs_row > 0, logits, -jnp.inf)
    return jax.random.categorical(key, logits)


def _uniform_neighbor(key, neighbors_row: jnp.ndarray, degree: jnp.ndarray) -> jnp.ndarray:
    """Uniform true-neighbor choice from a padded row."""
    idx = jax.random.randint(key, (), 0, degree)
    return neighbors_row[idx]


@functools.partial(jax.jit, static_argnames=("num_steps",))
def walk_markov(
    key: jax.Array,
    row_probs: jnp.ndarray,  # (n, max_deg) float, aligned with neighbors
    neighbors: jnp.ndarray,  # (n, max_deg) int32
    v0: Union[int, jnp.ndarray],
    num_steps: int,
) -> jnp.ndarray:
    """Simulate a 1-hop chain; returns trajectory (num_steps+1,) of node ids."""

    def step(carry, key_t):
        v = carry
        idx = _categorical_padded(key_t, row_probs[v])
        v_next = neighbors[v, idx]
        return v_next, v_next

    keys = jax.random.split(key, num_steps)
    v0 = jnp.asarray(v0, dtype=jnp.int32)
    _, traj = jax.lax.scan(step, v0, keys)
    return jnp.concatenate([v0[None], traj])


@functools.partial(jax.jit, static_argnames=("num_steps", "r", "p_d"))
def walk_mhlj(
    key: jax.Array,
    is_row_probs: jnp.ndarray,  # (n, max_deg) P_IS rows
    neighbors: jnp.ndarray,  # (n, max_deg)
    degrees: jnp.ndarray,  # (n,)
    v0: Union[int, jnp.ndarray],
    num_steps: int,
    p_j: Union[float, jnp.ndarray],
    p_d: float,
    r: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Algorithm 1's node sequence.

    Returns:
      update_nodes: (num_steps,) int32 — v_t at which update t is applied
        (element t is the node holding the model when update t runs; the
        first update runs at v0).
      transitions: (num_steps,) int32 — physical hops taken after update t
        (1 for an MH move, d for a jump) — Remark-1 accounting.
    """
    p_j_sched = jnp.broadcast_to(jnp.asarray(p_j, dtype=jnp.float32), (num_steps,))
    d_pmf = jnp.asarray(trunc_geom_pmf(p_d, r), dtype=jnp.float32)
    d_logits = jnp.log(d_pmf)

    def jump(key_j, v):
        key_d, key_hops = jax.random.split(key_j)
        d = 1 + jax.random.categorical(key_d, d_logits)  # in {1..r}
        hop_keys = jax.random.split(key_hops, r)

        def hop(i, state):
            v_cur = state
            v_new = _uniform_neighbor(hop_keys[i], neighbors[v_cur], degrees[v_cur])
            return jnp.where(i < d, v_new, v_cur)

        v_fin = jax.lax.fori_loop(0, r, hop, v)
        return v_fin, d.astype(jnp.int32)

    def mh_move(key_m, v):
        idx = _categorical_padded(key_m, is_row_probs[v])
        return neighbors[v, idx], jnp.int32(1)

    def step(carry, inputs):
        v = carry
        key_t, p_j_t = inputs
        key_b, key_mv = jax.random.split(key_t)
        do_jump = jax.random.bernoulli(key_b, p_j_t)
        v_jump, d_jump = jump(key_mv, v)
        v_mh, d_mh = mh_move(key_mv, v)
        v_next = jnp.where(do_jump, v_jump, v_mh)
        hops = jnp.where(do_jump, d_jump, d_mh)
        return v_next, (v, hops)

    keys = jax.random.split(key, num_steps)
    v0 = jnp.asarray(v0, dtype=jnp.int32)
    _, (update_nodes, transitions) = jax.lax.scan(step, v0, (keys, p_j_sched))
    return update_nodes, transitions


def walk_markov_batched(key, row_probs, neighbors, v0s, num_steps):
    """vmap over independent walks; v0s: (w,) -> trajectories (w, num_steps+1)."""
    keys = jax.random.split(key, v0s.shape[0])
    return jax.vmap(walk_markov, in_axes=(0, None, None, 0, None))(
        keys, row_probs, neighbors, v0s, num_steps
    )


def walk_mhlj_batched(
    key, is_row_probs, neighbors, degrees, v0s, num_steps, p_j, p_d, r
):
    """vmap Algorithm-1 walks; returns (w, num_steps) update nodes + hops."""
    keys = jax.random.split(key, v0s.shape[0])
    fn = functools.partial(
        walk_mhlj, num_steps=num_steps, p_j=p_j, p_d=p_d, r=r
    )
    return jax.vmap(
        lambda k, v0: fn(k, is_row_probs, neighbors, degrees, v0)
    )(keys, v0s)


def empirical_distribution(update_nodes: np.ndarray, n: int, burn_in: int = 0) -> np.ndarray:
    """Empirical visit distribution of the update sequence after burn-in."""
    seq = np.asarray(update_nodes)[..., burn_in:].ravel()
    counts = np.bincount(seq, minlength=n).astype(np.float64)
    return counts / counts.sum()
