from repro.data.synthetic import (
    RegressionData,
    make_heterogeneous_regression,
    make_homogeneous_regression,
)
from repro.data.lm_data import NodeTokenData, make_node_token_shards
from repro.data.pipeline import NodeDataPipeline

__all__ = [
    "RegressionData",
    "make_heterogeneous_regression",
    "make_homogeneous_regression",
    "NodeTokenData",
    "make_node_token_shards",
    "NodeDataPipeline",
]
