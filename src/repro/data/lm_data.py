"""Per-node synthetic LM token shards with controllable heterogeneity.

Decentralized setting: each graph node is a data silo holding a token shard.
Heterogeneity is produced by giving each silo its own Zipf-like unigram
distribution over a silo-specific vocabulary slice; "hard" silos draw from a
flatter (higher-entropy) distribution over rarer tokens, which empirically
yields larger gradient norms — the LLM analogue of the paper's sigma_H^2
nodes.  Sequences get structure from a deterministic n-gram mixing rule so the
loss is learnable (not pure noise).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["NodeTokenData", "make_node_token_shards"]


@dataclasses.dataclass(frozen=True)
class NodeTokenData:
    """Token shards for all nodes: tokens[v] is a (shard_len,) int32 stream."""

    tokens: np.ndarray  # (n, shard_len) int32
    hard_mask: np.ndarray  # (n,) bool — high-heterogeneity silos
    vocab_size: int

    @property
    def n(self) -> int:
        return int(self.tokens.shape[0])

    def batch(self, node: int, batch_size: int, seq_len: int, seed: int) -> dict:
        """Sample a (batch, seq_len+1) window batch from node's shard."""
        rng = np.random.default_rng(seed)
        shard = self.tokens[node]
        max_start = len(shard) - seq_len - 1
        starts = rng.integers(0, max_start, size=batch_size)
        windows = np.stack([shard[s : s + seq_len + 1] for s in starts])
        return {"tokens": windows[:, :-1].astype(np.int32),
                "labels": windows[:, 1:].astype(np.int32)}


def make_node_token_shards(
    n: int,
    vocab_size: int,
    shard_len: int = 4096,
    p_hard: float = 0.05,
    seed: int = 0,
    force_min_hard: int = 1,
) -> NodeTokenData:
    rng = np.random.default_rng(seed)
    hard = rng.random(n) < p_hard
    if hard.sum() < force_min_hard:
        hard[rng.choice(n, size=force_min_hard - int(hard.sum()), replace=False)] = True

    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    tokens = np.empty((n, shard_len), dtype=np.int32)
    for v in range(n):
        # silo-specific vocab rotation + Zipf exponent (hard silos flatter)
        alpha = 0.6 if hard[v] else 1.3
        probs = ranks ** (-alpha)
        probs /= probs.sum()
        rot = int(rng.integers(0, vocab_size))
        stream = rng.choice(vocab_size, size=shard_len, p=probs)
        stream = (stream + rot) % vocab_size
        # inject learnable bigram structure: every odd position repeats a
        # deterministic function of its predecessor half the time
        mix = rng.random(shard_len) < 0.5
        shifted = (stream * 31 + 7) % vocab_size
        stream = np.where(mix & (np.arange(shard_len) % 2 == 1), shifted, stream)
        tokens[v] = stream.astype(np.int32)
    return NodeTokenData(tokens=tokens, hard_mask=hard, vocab_size=vocab_size)
