"""Node-indexed data pipeline feeding the walk-orchestrated training loop.

The walk (host-side orchestration) decides which node's shard produces the
next global batch; the pipeline materializes that batch (host numpy) and the
pjit'd train_step consumes it sharded over ('pod','data') along batch.

For full-jax small-scale training (regression), nodes' data lives as device
arrays and selection is a gather — see ``walk_sgd.trainer``.
"""
from __future__ import annotations

from typing import Iterator


from repro.data.lm_data import NodeTokenData

__all__ = ["NodeDataPipeline"]


class NodeDataPipeline:
    """Stateful host-side pipeline: next_batch(node) -> {tokens, labels}."""

    def __init__(
        self,
        data: NodeTokenData,
        batch_size: int,
        seq_len: int,
        seed: int = 0,
    ) -> None:
        self.data = data
        self.batch_size = batch_size
        self.seq_len = seq_len
        self._counter = seed

    def next_batch(self, node: int) -> dict:
        self._counter += 1
        return self.data.batch(int(node), self.batch_size, self.seq_len, self._counter)

    def stream(self, nodes: Iterator[int]) -> Iterator[dict]:
        for v in nodes:
            yield self.next_batch(v)
