"""Paper Appendix-D synthetic regression data.

Homogeneous:   A_v ~ N(0, sigma^2 I_d),        y_v = A_v^T x* + eps, eps ~ N(0,1)
Heterogeneous: A_v | sigma_v^2 ~ N(0, sigma_v^2 I_d), where sigma_v^2 = sigma_H^2
               with probability p_high (paper: Fig 3 uses p=0.002, Appendix
               uses p=0.005) and sigma_L^2 otherwise.

One data point per node (paper: "For each node v, we assign one data point").
L_v = 2 ||A_v||^2 for the squared loss f_v(x) = (y_v - x^T A_v)^2.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.importance import linear_regression_lipschitz

__all__ = [
    "RegressionData",
    "make_homogeneous_regression",
    "make_heterogeneous_regression",
]


@dataclasses.dataclass(frozen=True)
class RegressionData:
    """Per-node least-squares data (paper Eq. 17-18)."""

    features: np.ndarray  # (n, d)  A_v
    targets: np.ndarray  # (n,)     y_v
    x_star: np.ndarray  # (d,)      ground-truth regressor
    lipschitz: np.ndarray  # (n,)   L_v = 2 ||A_v||^2
    high_variance_mask: np.ndarray  # (n,) bool — which nodes got sigma_H^2

    @property
    def n(self) -> int:
        return int(self.features.shape[0])

    @property
    def dim(self) -> int:
        return int(self.features.shape[1])

    def mse(self, x: np.ndarray) -> float:
        """Paper Fig-3 metric: sum_v (y_v - A_v x)^2 / |V|."""
        resid = self.targets - self.features @ np.asarray(x)
        return float((resid**2).mean())

    def optimum(self) -> np.ndarray:
        """Least-squares minimizer of the average loss (ridge-free pinv)."""
        return np.linalg.pinv(self.features) @ self.targets


def _finish(features, rng, x_star, mask) -> RegressionData:
    noise = rng.normal(size=features.shape[0])
    targets = features @ x_star + noise
    return RegressionData(
        features=features,
        targets=targets,
        x_star=x_star,
        lipschitz=linear_regression_lipschitz(features),
        high_variance_mask=mask,
    )


def make_homogeneous_regression(
    n: int, dim: int = 10, sigma_sq: float = 1.0, seed: int = 0,
    x_star_scale: float = 10.0,
) -> RegressionData:
    rng = np.random.default_rng(seed)
    x_star = x_star_scale * rng.normal(size=dim)
    features = rng.normal(scale=np.sqrt(sigma_sq), size=(n, dim))
    return _finish(features, rng, x_star, np.zeros(n, dtype=bool))


def make_heterogeneous_regression(
    n: int,
    dim: int = 10,
    sigma_low_sq: float = 1.0,
    sigma_high_sq: float = 100.0,
    p_high: float = 0.002,
    seed: int = 0,
    force_min_high: int = 1,
    high_nodes: np.ndarray | None = None,
    x_star_scale: float = 10.0,
) -> RegressionData:
    """Paper heterogeneous scheme; Fig 3 uses (sigma_H^2=100, p=0.002) on n=1000.

    ``force_min_high`` guarantees at least that many high-variance nodes so
    small-n test instances still exhibit heterogeneity.  ``high_nodes`` pins
    the high-variance node ids (e.g. Fig-2's node 1 on a 5-ring).
    ``x_star_scale`` sets ||x*|| so the initial MSE matches the paper's
    ~1e4 starting point (x0 = 0).
    """
    rng = np.random.default_rng(seed)
    x_star = x_star_scale * rng.normal(size=dim)
    if high_nodes is not None:
        mask = np.zeros(n, dtype=bool)
        mask[np.asarray(high_nodes)] = True
    else:
        mask = rng.random(n) < p_high
        if mask.sum() < force_min_high:
            extra = rng.choice(n, size=force_min_high - int(mask.sum()), replace=False)
            mask[extra] = True
    scale = np.where(mask, np.sqrt(sigma_high_sq), np.sqrt(sigma_low_sq))
    features = rng.normal(size=(n, dim)) * scale[:, None]
    return _finish(features, rng, x_star, mask)
