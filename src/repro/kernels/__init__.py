"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel is a subpackage with kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd model-layout wrapper), ref.py (pure-jnp oracle):

* flash_attention — blockwise online-softmax attention (causal/sliding, GQA)
* ssd             — mamba-2 chunked SSD scan with VMEM-resident state
* rmsnorm         — fused rmsnorm(+scale)
* walk_transition — batched MHLJ next-node sampling (the paper's hot spot
                    at large walk counts): CDF inversion over padded
                    neighbor rows.  The ``"pallas"`` backend of
                    ``core.engine.WalkEngine`` — the single implementation
                    of Algorithm 1 — mirrored by the engine's scan math

CPU validation uses interpret=True; on TPU the compiled kernels run.
"""
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.rmsnorm import ops as rmsnorm_ops
from repro.kernels.walk_transition import ops as walk_ops

__all__ = ["ssd_ops", "flash_ops", "rmsnorm_ops", "walk_ops"]
