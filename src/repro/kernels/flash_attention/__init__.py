from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import mha, mha_ref
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention", "mha", "mha_ref", "attention_ref"]
