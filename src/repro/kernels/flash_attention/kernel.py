"""Flash attention Pallas TPU kernel: blockwise online-softmax attention.

Tiling: grid = (batch, q_head, q_blocks, k_blocks) with the k-block axis
minor-most — TPU grids execute sequentially, so the running max / denominator
/ accumulator live in VMEM scratch carried across k-block iterations.
Q/K/V blocks are (bq, head_dim) / (bk, head_dim) VMEM tiles; head_dim and
block sizes should be multiples of 128 / the MXU lane width for peak MXU
utilization (we assert multiples of 8 and pad upstream).

GQA is handled with zero memory overhead: the kv BlockSpec index_map folds
the query head index onto its kv head (kv = n * K // N) — no repeat of K/V.

Supports causal and sliding-window masks.  Fully-masked k-blocks are skipped
with ``pl.when`` (the skip is exact for causal/window geometry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention"]

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref,  # blocks
    m_scr, l_scr, acc_scr,  # scratch: (bq,1), (bq,1), (bq,h)
    *, scale: float, causal: bool, window: int, bq: int, bk: int, num_kb: int,
    kv_len: int,
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # k block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    row = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    col = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: causal blocks fully above the diagonal, or fully
    # outside the sliding window, contribute nothing.
    run = jnp.asarray(True)
    if causal:
        run = (j * bk) <= (i * bq + bq - 1)
        if window > 0:
            # row r attends cols in (r - window, r]; the oldest row of this
            # q block is i*bq, so the block is dead when its newest col is
            # older than i*bq - window + 1.
            run = run & ((j * bk + bk - 1) >= (i * bq - window + 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, h)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, h)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, h)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        mask = col < kv_len  # valid-length mask (tail padding)
        if causal:
            mask = mask & (col <= row)
            if window > 0:
                mask = mask & (col > row - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=1))[:, None]
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == num_kb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, N, S, h)
    k: jnp.ndarray,  # (B, K, T, h)
    v: jnp.ndarray,  # (B, K, T, h)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, n, s, h = q.shape
    _, kh, t, _ = k.shape
    if n % kh:
        raise ValueError("q heads must be a multiple of kv heads")
    # Arbitrary lengths: pad to block multiples; padded k columns are masked
    # inside the kernel (col < kv_len), padded q rows are sliced off below.
    pad_q = (-s) % block_q
    pad_k = (-t) % block_k
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    s_pad, t_pad = s + pad_q, t + pad_k
    scale = h**-0.5
    num_kb = t_pad // block_k
    grid = (b, n, s_pad // block_q, num_kb)

    def kv_index(bi, ni, qi, ki):
        return (bi, ni * kh // n, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            bq=block_q, bk=block_k, num_kb=num_kb, kv_len=t,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, h), lambda bi, ni, qi, ki: (bi, ni, qi, 0)),
            pl.BlockSpec((1, 1, block_k, h), kv_index),
            pl.BlockSpec((1, 1, block_k, h), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, h), lambda bi, ni, qi, ki: (bi, ni, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, s_pad, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s] if pad_q else out


flash_attention_kernel = _kernel
