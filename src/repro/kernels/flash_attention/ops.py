"""Jit'd public wrapper: model-layout (B, S, N, h) in/out, GQA-aware.

On CPU this dispatches to interpret mode (validation); on TPU the compiled
kernel runs.  ``use_kernel=False`` falls back to the jnp oracle — the switch
the model layers use (DESIGN.md: kernels are enabled on real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def mha(
    q: jnp.ndarray,  # (B, S, N, h) — model layout
    k: jnp.ndarray,  # (B, T, K, h)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention(
        qt, kt, vt,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=not _is_tpu(),
    )
    return out.swapaxes(1, 2)


def mha_ref(q, k, v, *, causal=True, window=0):
    return attention_ref(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2), causal=causal, window=window
    ).swapaxes(1, 2)
