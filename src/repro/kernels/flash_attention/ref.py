"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, N, S, h)
    k: jnp.ndarray,  # (B, K, T, h)
    v: jnp.ndarray,  # (B, K, T, h)
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    b, n, s, h = q.shape
    kh = k.shape[1]
    rep = n // kh
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum(
        "bnsh,bnth->bnst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (h**-0.5)
    if causal:
        row = jnp.arange(s)[:, None]
        col = jnp.arange(k.shape[2])[None, :]
        mask = col <= row
        if window > 0:
            mask = mask & (col > row - window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bnst,bnth->bnsh", probs, v.astype(jnp.float32)).astype(q.dtype)
