from repro.kernels.rmsnorm.kernel import rmsnorm_fused
from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_oracle
from repro.kernels.rmsnorm.ref import rmsnorm_ref

__all__ = ["rmsnorm_fused", "rmsnorm", "rmsnorm_oracle", "rmsnorm_ref"]
