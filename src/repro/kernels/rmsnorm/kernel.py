"""Fused RMSNorm Pallas TPU kernel: one HBM round-trip per row block.

Grid over row blocks; block (block_rows, D) in VMEM; fp32 accumulation for
the mean-square; scale applied in-register before the single store.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_fused"]


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (normed * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fused(
    x: jnp.ndarray,  # (R, D)
    scale: jnp.ndarray,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    r, d = x.shape
    br = min(block_rows, r)
    if r % br:
        br = 1  # degenerate fallback keeps correctness for odd row counts
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale)
