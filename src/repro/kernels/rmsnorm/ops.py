"""Jit'd wrapper: model layout (..., D)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_fused
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = rmsnorm_fused(flat, scale, eps=eps, interpret=not _is_tpu())
    return out.reshape(shape)


def rmsnorm_oracle(x, scale, eps: float = 1e-6):
    return rmsnorm_ref(x, scale, eps)
