"""Pure-jnp oracle for the fused rmsnorm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * (var + eps) ** -0.5 * scale.astype(jnp.float32)).astype(x.dtype)
