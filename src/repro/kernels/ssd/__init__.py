from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ops import ssd, ssd_oracle
from repro.kernels.ssd.ref import ssd_ref

__all__ = ["ssd_scan", "ssd", "ssd_oracle", "ssd_ref"]
