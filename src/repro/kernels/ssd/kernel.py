"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid = (batch, head, num_chunks) with the chunk axis minor-most: TPU grids
run sequentially, so the (N, P) SSM state lives in VMEM scratch and is
carried across chunk iterations — the inter-chunk recurrence costs zero HBM
round-trips (the key TPU adaptation: on GPU this is a separate state-passing
kernel; on TPU the sequential grid + VMEM residency fuses it).

Per (b, h, c) iteration, VMEM blocks:
    x   (Q, P)    head inputs
    da  (Q, 1)    dt * A   (log-decay increments, <= 0)
    dt  (Q, 1)
    b/c (Q, N)    input/output projections (group-expanded upstream)
Compute (all MXU-shaped):
    cum    = cumsum(da)                                   (Q,)
    att    = (C B^T) * exp(cum_i - cum_j) * dt_j, lower-tri
    y      = att @ x + exp(cum) * (C @ state)
    state  = exp(cum_Q) * state + (B * exp(cum_Q - cum) * dt)^T @ x

Q (chunk) and P, N should be multiples of the 128-lane MXU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, da_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    da = da_ref[0, 0].astype(jnp.float32)  # (Q, 1)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q, 1)
    bb = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    cc = c_ref[0, 0].astype(jnp.float32)  # (Q, N)

    cum = jnp.cumsum(da[:, 0])  # (Q,)
    # intra-chunk quadratic part
    scores = jax.lax.dot_general(
        cc, bb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C_i . B_j
    decay = jnp.exp(cum[:, None] - cum[None, :])
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    att = jnp.where(col <= row, scores * decay, 0.0) * dt[:, 0][None, :]
    y = jax.lax.dot_general(
        att, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)
    # inter-chunk contribution from the carried state
    state = state_ref[...]  # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cc, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # state update
    tail = jnp.exp(cum[-1] - cum) * dt[:, 0]  # (Q,)
    state_ref[...] = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        bb * tail[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    xs: jnp.ndarray,  # (B, H, L, P) head-major layout
    da: jnp.ndarray,  # (B, H, L)
    dt: jnp.ndarray,  # (B, H, L)
    bs: jnp.ndarray,  # (B, H, L, N) group-expanded
    cs: jnp.ndarray,  # (B, H, L, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, l, p = xs.shape
    n = bs.shape[-1]
    if l % chunk:
        raise ValueError(f"L={l} must divide chunk={chunk}")
    nc = l // chunk
    grid = (b, h, nc)
    qp_spec = pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0))
    qn_spec = pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0))
    q1_spec = pl.BlockSpec((1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0))
    return pl.pallas_call(
        functools.partial(_kernel, q=chunk),
        grid=grid,
        in_specs=[qp_spec, q1_spec, q1_spec, qn_spec, qn_spec],
        out_specs=qp_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, l, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xs, da[..., None], dt[..., None], bs, cs)
