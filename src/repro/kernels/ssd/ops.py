"""Jit'd wrapper: model layout (B, L, H, P) + per-head A, grouped B/C."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ref import ssd_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(
    xs: jnp.ndarray,  # (B, L, H, P) — model layout
    dt: jnp.ndarray,  # (B, L, H) post-softplus
    a: jnp.ndarray,  # (H,) negative decay rates
    bs: jnp.ndarray,  # (B, L, G, N)
    cs: jnp.ndarray,  # (B, L, G, N)
    chunk: int = 128,
):
    """Returns (y (B,L,H,P) fp32, None) — matches layers.mamba2.ssd_chunked."""
    b, l, h, p = xs.shape
    g = bs.shape[2]
    rep = h // g
    xs_k = xs.transpose(0, 2, 1, 3)  # (B,H,L,P)
    dt_k = dt.transpose(0, 2, 1)  # (B,H,L)
    da_k = dt_k * a[None, :, None]
    bs_k = jnp.repeat(bs, rep, axis=2).transpose(0, 2, 1, 3)  # (B,H,L,N)
    cs_k = jnp.repeat(cs, rep, axis=2).transpose(0, 2, 1, 3)
    pad = (-l) % chunk
    if pad:
        xs_k = jnp.pad(xs_k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        da_k = jnp.pad(da_k, ((0, 0), (0, 0), (0, pad)))
        dt_k = jnp.pad(dt_k, ((0, 0), (0, 0), (0, pad)))
        bs_k = jnp.pad(bs_k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cs_k = jnp.pad(cs_k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    y = ssd_scan(
        xs_k, da_k, dt_k, bs_k, cs_k, chunk=chunk, interpret=not _is_tpu()
    )
    y = y[:, :, :l].transpose(0, 2, 1, 3)  # (B,L,H,P)
    return y, None


def ssd_oracle(xs, dt, a, bs, cs):
    """Model-layout oracle (exact recurrence)."""
    h = xs.shape[2]
    g = bs.shape[2]
    rep = h // g
    xs_k = xs.transpose(0, 2, 1, 3)
    dt_k = dt.transpose(0, 2, 1)
    da_k = dt_k * a[None, :, None]
    bs_k = jnp.repeat(bs, rep, axis=2).transpose(0, 2, 1, 3)
    cs_k = jnp.repeat(cs, rep, axis=2).transpose(0, 2, 1, 3)
    return ssd_ref(xs_k, da_k, dt_k, bs_k, cs_k).transpose(0, 2, 1, 3)
