"""Pure-jnp oracle for the SSD kernel: exact sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xs, da, dt, bs, cs):
    """Head-major layout: xs (B,H,L,P), da/dt (B,H,L), bs/cs (B,H,L,N)."""
    b, h, l, p = xs.shape
    n = bs.shape[-1]

    def step(state, inp):
        x_t, da_t, dt_t, b_t, c_t = inp  # (B,H,P),(B,H),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(da_t)
        state = decay[..., None, None] * state + (
            dt_t[..., None, None] * b_t[..., None] * x_t[..., None, :]
        )
        y = jnp.einsum("bhn,bhnp->bhp", c_t, state)
        return state, y

    inputs = (
        xs.transpose(2, 0, 1, 3).astype(jnp.float32),
        da.transpose(2, 0, 1).astype(jnp.float32),
        dt.transpose(2, 0, 1).astype(jnp.float32),
        bs.transpose(2, 0, 1, 3).astype(jnp.float32),
        cs.transpose(2, 0, 1, 3).astype(jnp.float32),
    )
    state0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, state0, inputs)
    return ys.transpose(1, 2, 0, 3)  # (B,H,L,P)
