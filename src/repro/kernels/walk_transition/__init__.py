from repro.kernels.walk_transition.kernel import (
    walk_transition,
    walk_transition_bucketed,
    walk_transition_ragged,
    walk_transition_sparse,
)
from repro.kernels.walk_transition.ops import (
    mhlj_step_batched,
    mhlj_step_bucketed,
    mhlj_step_dense,
    mhlj_step_oracle,
    mhlj_step_ragged,
    mhlj_step_sparse,
)
from repro.kernels.walk_transition.ref import (
    walk_transition_bucketed_ref,
    walk_transition_ragged_ref,
    walk_transition_ref,
    walk_transition_sparse_ref,
)

__all__ = [
    "walk_transition",
    "walk_transition_sparse",
    "walk_transition_bucketed",
    "walk_transition_ragged",
    "mhlj_step_batched",
    "mhlj_step_bucketed",
    "mhlj_step_dense",
    "mhlj_step_oracle",
    "mhlj_step_ragged",
    "mhlj_step_sparse",
    "walk_transition_ref",
    "walk_transition_sparse_ref",
    "walk_transition_bucketed_ref",
    "walk_transition_ragged_ref",
]
