from repro.kernels.walk_transition.kernel import walk_transition
from repro.kernels.walk_transition.ops import mhlj_step_batched, mhlj_step_oracle
from repro.kernels.walk_transition.ref import walk_transition_ref

__all__ = ["walk_transition", "mhlj_step_batched", "mhlj_step_oracle", "walk_transition_ref"]
