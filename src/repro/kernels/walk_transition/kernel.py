"""Batched MHLJ transition Pallas TPU kernel — the paper's orchestration hot
spot at scale (W parallel walks on a large silo graph, sampled every step).
This is the ``"pallas"`` backend of :class:`repro.core.engine.WalkEngine`;
its per-walk body mirrors ``engine.mhlj_transition_math`` statement for
statement, and the parity tests assert bitwise-equal outputs.

One grid step processes ``block_w`` walks.  Per walk:
  * MH-IS move: CDF inversion over the walk's padded P_IS neighbor row
    (precomputed or live (n, max_deg) table, resident in VMEM — graphs here
    are orchestration-scale, n <= a few thousand silos);
  * Lévy jump: distance d <- TruncGeom(p_d, r) via the shared closed-form
    inverse CDF (``core.levy.trunc_geom_icdf``), then d uniform hops using
    the neighbors/degrees tables.

All per-walk work is scalar loads from VMEM tables (pl.dslice rows +
static-column picks) — no vector gathers, which keeps the kernel TPU-legal.

When W is not a multiple of ``block_w`` the walk axis is padded up to the
next block multiple and the padded lanes sliced off afterwards, so large
non-power-of-two fleets keep the intended grid instead of collapsing into
one giant block.

Inputs:
  nodes      (W,)  int32     current node per walk
  row_probs  (n, max_deg)    P_IS rows aligned with ``neighbors``
  neighbors  (n, max_deg)    int32 padded (pad = self id)
  degrees    (n, 1) int32
  uniforms   (W, 3 + r)      pre-drawn U(0,1) with slot layout
                             [jump_flag, mh, distance, hop_1..hop_r];
                             slot 0 arrives as a {0.0, 1.0} Bernoulli(p_J)
                             flag resolved by the engine (this is what lets
                             p_J be a traced annealing schedule while the
                             kernel keeps only static compile-time params)
Outputs:
  next_nodes (W,) int32
  hops       (W,) int32      Remark-1 physical transitions (1 MH, d jump)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.engine import U_DIST, U_HOP0, U_JUMP, U_MH, num_uniforms
from repro.core.levy import trunc_geom_icdf

__all__ = ["walk_transition"]


def _kernel(
    nodes_ref, probs_ref, neigh_ref, deg_ref, u_ref, out_ref, hops_ref,
    *, p_d: float, r: int, block_w: int, max_deg: int,
):
    def one_walk(w, _):
        v = nodes_ref[w]

        # --- MH-IS move via CDF inversion over the padded neighbor row ----
        prow = pl.load(probs_ref, (pl.dslice(v, 1), slice(None)))[0]  # (max_deg,)
        cdf = jnp.cumsum(prow)
        idx = jnp.sum((cdf < u_ref[w, U_MH] * cdf[-1]).astype(jnp.int32))
        idx = jnp.minimum(idx, max_deg - 1)
        nrow = pl.load(neigh_ref, (pl.dslice(v, 1), slice(None)))[0]
        v_mh = jnp.take(nrow, idx, axis=0)

        # --- Lévy jump: shared TruncGeom inverse CDF, then d uniform hops -
        d = trunc_geom_icdf(u_ref[w, U_DIST], p_d, r)

        def hop(i, v_cur):
            deg = pl.load(deg_ref, (pl.dslice(v_cur, 1), slice(None)))[0, 0]
            hop_idx = jnp.minimum(
                (u_ref[w, U_HOP0 + i] * deg.astype(jnp.float32)).astype(jnp.int32),
                deg - 1,
            )
            row = pl.load(neigh_ref, (pl.dslice(v_cur, 1), slice(None)))[0]
            v_new = jnp.take(row, hop_idx, axis=0)
            return jnp.where(i < d, v_new, v_cur)

        v_jump = jax.lax.fori_loop(0, r, hop, v)

        do_jump = u_ref[w, U_JUMP] > 0.5
        out_ref[w] = jnp.where(do_jump, v_jump, v_mh)
        hops_ref[w] = jnp.where(do_jump, d, jnp.int32(1))
        return _

    jax.lax.fori_loop(0, block_w, one_walk, 0)


@functools.partial(
    jax.jit, static_argnames=("p_d", "r", "block_w", "interpret")
)
def walk_transition(
    nodes: jnp.ndarray,  # (W,) int32
    row_probs: jnp.ndarray,  # (n, max_deg) float32
    neighbors: jnp.ndarray,  # (n, max_deg) int32
    degrees: jnp.ndarray,  # (n,) int32
    uniforms: jnp.ndarray,  # (W, 3 + r) float32, slot 0 = jump flag
    *,
    p_d: float,
    r: int,
    block_w: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    w = nodes.shape[0]
    n, max_deg = neighbors.shape
    n_u = num_uniforms(r)
    bw = min(block_w, w)
    # pad W up to a block multiple (padded lanes run a harmless MH move on
    # node 0 and are sliced off below)
    w_pad = -(-w // bw) * bw
    if w_pad != w:
        nodes = jnp.pad(nodes, (0, w_pad - w))
        uniforms = jnp.pad(uniforms, ((0, w_pad - w), (0, 0)))
    grid = (w_pad // bw,)
    table = lambda i: (0, 0)
    next_nodes, hops = pl.pallas_call(
        functools.partial(
            _kernel, p_d=p_d, r=r, block_w=bw, max_deg=max_deg
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, max_deg), table),
            pl.BlockSpec((n, max_deg), table),
            pl.BlockSpec((n, 1), table),
            pl.BlockSpec((bw, n_u), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w_pad,), jnp.int32),
            jax.ShapeDtypeStruct((w_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(nodes, row_probs, neighbors, degrees[:, None], uniforms)
    return next_nodes[:w], hops[:w]
