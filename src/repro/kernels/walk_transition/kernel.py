"""Batched MHLJ transition Pallas TPU kernels — the paper's orchestration hot
spot at scale (W parallel walks on a large silo graph, sampled every step).
These back the ``"pallas"`` backend of :class:`repro.core.engine.WalkEngine`;
the per-walk bodies mirror ``engine.mhlj_transition_math`` statement for
statement, and the parity tests assert bitwise-equal outputs.

Three entry points:

* :func:`walk_transition` — the ``layout="dense"`` path: the full
  ``(n, max_deg)`` P_IS/neighbor tables live in VMEM and every per-walk row
  is a dynamic-slice load.  Exact but caps n at a few thousand (VMEM).
* :func:`walk_transition_sparse` — the ``layout="sparse"`` MH-move kernel:
  the engine gathers only the W active rows into ``[block_w, max_deg]``
  tiles (an O(W·max_deg) working set, independent of n), the kernel runs a
  fully vectorized CDF inversion per tile, and the Lévy hop chain is left
  to the engine's O(W) XLA gathers.  This is what lets 100k-node graphs run
  with O(E) memory — no full table ever reaches kernel memory.
* :func:`walk_transition_bucketed` — the ``layout="bucketed"`` MH-move
  dispatch: one :func:`walk_transition_sparse` launch per degree bucket at
  that bucket's width (tiles ``[block_w, width_b]`` with width_b = 8, 16,
  …), each walk keeping the result of its own bucket's pass.  Hub rows
  only pay their own bucket's width, so hub-heavy graphs stop paying
  O(max_deg) per low-degree walk; the CDF inversion itself still exists
  exactly once (``_sparse_kernel``).
* :func:`walk_transition_ragged` — the ``layout="ragged"`` fused kernel:
  a ``PrefetchScalarGridSpec`` launch whose scalar-prefetch arguments
  (walk nodes, CSR ``indptr``, ``degrees``) drive per-walk ``pl.dslice``
  loads straight out of the **flat** per-edge CDF/index buffers at each
  row's *true* degree — no padded tile is ever gathered, no bucket ladder
  dispatched.  The whole MHLJ step fuses into the one pass: the MH move
  is a binary search of the walk's CDF segment (mirroring
  ``engine.ragged_mh_invert``), the Lévy branch runs its r CSR-gathered
  hops in-kernel, and the jump/MH combine writes ``(next, hops)``
  directly — none of the O(W) XLA gather round-trips the other sparse
  layouts leave between the tile kernel and ``engine.levy_jump_batched``.

One grid step processes ``block_w`` walks.  Per walk:
  * MH-IS move: CDF inversion over the walk's padded P_IS neighbor row
    (precomputed or live (n, max_deg) table, resident in VMEM — graphs here
    are orchestration-scale, n <= a few thousand silos);
  * Lévy jump: distance d <- TruncGeom(p_d, r) via the shared closed-form
    inverse CDF (``core.levy.trunc_geom_icdf``), then d uniform hops using
    the neighbors/degrees tables.

All per-walk work is scalar loads from VMEM tables (pl.dslice rows +
static-column picks) — no vector gathers, which keeps the kernel TPU-legal.

When W is not a multiple of ``block_w`` the walk axis is padded up to the
next block multiple and the padded lanes sliced off afterwards, so large
non-power-of-two fleets keep the intended grid instead of collapsing into
one giant block.

Inputs:
  nodes      (W,)  int32     current node per walk
  row_probs  (n, max_deg)    P_IS rows aligned with ``neighbors``
  neighbors  (n, max_deg)    int32 padded (pad = self id)
  degrees    (n, 1) int32
  uniforms   (W, 3 + r)      pre-drawn U(0,1) with slot layout
                             [jump_flag, mh, distance, hop_1..hop_r];
                             slot 0 arrives as a {0.0, 1.0} Bernoulli(p_J)
                             flag resolved by the engine (this is what lets
                             p_J be a traced annealing schedule while the
                             kernel keeps only static compile-time params)
Outputs:
  next_nodes (W,) int32
  hops       (W,) int32      Remark-1 physical transitions (1 MH, d jump)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.engine import (
    U_DIST,
    U_HOP0,
    U_JUMP,
    U_MH,
    combine_bucketed,
    num_uniforms,
    scatter_compacted,
)
from repro.core.levy import trunc_geom_icdf

__all__ = [
    "walk_transition",
    "walk_transition_sparse",
    "walk_transition_bucketed",
    "walk_transition_bucketed_compacted",
    "walk_transition_ragged",
]


def _kernel(
    nodes_ref, probs_ref, neigh_ref, deg_ref, u_ref, out_ref, hops_ref,
    *, p_d: float, r: int, block_w: int, max_deg: int,
):
    def one_walk(w, _):
        v = nodes_ref[w]

        # --- MH-IS move via CDF inversion over the padded neighbor row ----
        prow = pl.load(probs_ref, (pl.dslice(v, 1), slice(None)))[0]  # (max_deg,)
        cdf = jnp.cumsum(prow)
        idx = jnp.sum((cdf < u_ref[w, U_MH] * cdf[-1]).astype(jnp.int32))
        idx = jnp.minimum(idx, max_deg - 1)
        nrow = pl.load(neigh_ref, (pl.dslice(v, 1), slice(None)))[0]
        v_mh = jnp.take(nrow, idx, axis=0)

        # --- Lévy jump: shared TruncGeom inverse CDF, then d uniform hops -
        d = trunc_geom_icdf(u_ref[w, U_DIST], p_d, r)

        def hop(i, v_cur):
            deg = pl.load(deg_ref, (pl.dslice(v_cur, 1), slice(None)))[0, 0]
            hop_idx = jnp.minimum(
                (u_ref[w, U_HOP0 + i] * deg.astype(jnp.float32)).astype(jnp.int32),
                deg - 1,
            )
            row = pl.load(neigh_ref, (pl.dslice(v_cur, 1), slice(None)))[0]
            v_new = jnp.take(row, hop_idx, axis=0)
            return jnp.where(i < d, v_new, v_cur)

        v_jump = jax.lax.fori_loop(0, r, hop, v)

        do_jump = u_ref[w, U_JUMP] > 0.5
        out_ref[w] = jnp.where(do_jump, v_jump, v_mh)
        hops_ref[w] = jnp.where(do_jump, d, jnp.int32(1))
        return _

    jax.lax.fori_loop(0, block_w, one_walk, 0)


@functools.partial(
    jax.jit, static_argnames=("p_d", "r", "block_w", "interpret")
)
def walk_transition(
    nodes: jnp.ndarray,  # (W,) int32
    row_probs: jnp.ndarray,  # (n, max_deg) float32
    neighbors: jnp.ndarray,  # (n, max_deg) int32
    degrees: jnp.ndarray,  # (n,) int32
    uniforms: jnp.ndarray,  # (W, 3 + r) float32, slot 0 = jump flag
    *,
    p_d: float,
    r: int,
    block_w: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    w = nodes.shape[0]
    n, max_deg = neighbors.shape
    n_u = num_uniforms(r)
    bw = min(block_w, w)
    # pad W up to a block multiple (padded lanes run a harmless MH move on
    # node 0 and are sliced off below)
    w_pad = -(-w // bw) * bw
    if w_pad != w:
        nodes = jnp.pad(nodes, (0, w_pad - w))
        uniforms = jnp.pad(uniforms, ((0, w_pad - w), (0, 0)))
    grid = (w_pad // bw,)

    def table(i):
        return (0, 0)

    next_nodes, hops = pl.pallas_call(
        functools.partial(
            _kernel, p_d=p_d, r=r, block_w=bw, max_deg=max_deg
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, max_deg), table),
            pl.BlockSpec((n, max_deg), table),
            pl.BlockSpec((n, 1), table),
            pl.BlockSpec((bw, n_u), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w_pad,), jnp.int32),
            jax.ShapeDtypeStruct((w_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(nodes, row_probs, neighbors, degrees[:, None], uniforms)
    return next_nodes[:w], hops[:w]


# ---------------------------------------------------------------------------
# Sparse-layout MH-move kernel (pre-gathered neighbor tiles)
# ---------------------------------------------------------------------------


def _sparse_kernel(probs_ref, neigh_ref, u_ref, out_ref, *, block_w, max_deg):
    """CDF inversion over a [block_w, max_deg] tile, fully vectorized.

    Same arithmetic as the per-walk body of ``mhlj_transition_math``
    (cumsum, ``cdf < u * cdf[-1]`` count, clamp) so outputs stay bitwise
    equal to the scan backend; the neighbor pick is a one-hot reduction
    instead of a gather to stay TPU-legal.
    """
    prow = probs_ref[...]  # (block_w, max_deg) f32
    cdf = jnp.cumsum(prow, axis=1)
    u = u_ref[...]  # (block_w, 1) f32
    idx = jnp.sum((cdf < u * cdf[:, max_deg - 1][:, None]).astype(jnp.int32), axis=1)
    idx = jnp.minimum(idx, max_deg - 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_w, max_deg), 1)
    picked = jnp.where(cols == idx[:, None], neigh_ref[...], 0)
    out_ref[...] = jnp.sum(picked, axis=1)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def walk_transition_sparse(
    rows: jnp.ndarray,  # (W, max_deg) float32 — P_IS rows of the W walks
    neigh_rows: jnp.ndarray,  # (W, max_deg) int32 — their padded neighbor rows
    u_mh: jnp.ndarray,  # (W,) float32 — the U_MH uniform per walk
    *,
    block_w: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """MH-IS move for W walks from gathered [block_w, max_deg] tiles.

    Returns ``v_mh`` (W,) int32.  ``max_deg`` need not be a multiple of any
    block size — each tile spans the full (possibly odd) neighbor axis.
    The Lévy branch is composed outside (``engine.levy_jump_batched``).
    """
    w, max_deg = rows.shape
    bw = min(block_w, w)
    w_pad = -(-w // bw) * bw
    if w_pad != w:
        # padded lanes: all-zero rows -> idx 0 -> neighbor 0, sliced off below
        rows = jnp.pad(rows, ((0, w_pad - w), (0, 0)))
        neigh_rows = jnp.pad(neigh_rows, ((0, w_pad - w), (0, 0)))
        u_mh = jnp.pad(u_mh, (0, w_pad - w))
    v_mh = pl.pallas_call(
        functools.partial(_sparse_kernel, block_w=bw, max_deg=max_deg),
        grid=(w_pad // bw,),
        in_specs=[
            pl.BlockSpec((bw, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((bw, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((bw, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w_pad,), jnp.int32),
        interpret=interpret,
    )(rows, neigh_rows, u_mh[:, None])
    return v_mh[:w]


# ---------------------------------------------------------------------------
# Bucketed-layout MH-move dispatch (per-degree-bucket sparse tiles)
# ---------------------------------------------------------------------------


def walk_transition_bucketed(
    bucket_ids: jnp.ndarray,  # (W,) int32 — degree bucket of each walk's node
    rows_by_bucket,  # tuple of (W, width_b) float32 P_IS tiles
    tiles_by_bucket,  # tuple of (W, width_b) int32 neighbor tiles
    u_mh: jnp.ndarray,  # (W,) float32 — the U_MH uniform per walk
    *,
    block_w: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """MH-IS move via one sparse tile launch per degree bucket.

    Each bucket pass runs :func:`walk_transition_sparse` at the bucket's
    own width; walk w keeps the result of the pass matching
    ``bucket_ids[w]`` (its other passes read the bucket's row 0 — a dummy
    the ``engine.combine_bucketed`` merge discards).  Because every bucket
    row is a column-truncation of the walk's full padded row and pads
    carry exactly 0, the inverted CDF index is unchanged and the result
    is bitwise-equal to the full-width layouts given the same uniforms.
    Returns ``v_mh`` (W,).
    """
    return combine_bucketed(
        bucket_ids,
        [
            walk_transition_sparse(
                rows, tiles, u_mh, block_w=block_w, interpret=interpret
            )
            for rows, tiles in zip(rows_by_bucket, tiles_by_bucket)
        ],
    )


def walk_transition_bucketed_compacted(
    rows_by_bucket,  # tuple of (cap_b, width_b) float32 compacted P_IS tiles
    tiles_by_bucket,  # tuple of (cap_b, width_b) int32 compacted neighbor tiles
    u_by_bucket,  # tuple of (cap_b,) float32 — U_MH uniform per lane
    walk_idx_by_bucket,  # tuple of (cap_b,) int32 — original walk index
    valid_by_bucket,  # tuple of (cap_b,) bool — lane holds a real walk
    num_walks: int,
    *,
    block_w: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """MH-IS move over *compacted* per-bucket tiles (the fast bucketed path).

    The engine's compaction pass (``engine.compact_plan`` +
    ``engine.bucket_capacities``) has already sorted the W walks by bucket
    id and gathered each bucket's walks into a ``[cap_b, width_b]`` tile,
    so — unlike :func:`walk_transition_bucketed` — each
    :func:`walk_transition_sparse` launch pays for the bucket's own walks
    only, not all W.  Results scatter back to original walk order through
    ``engine.scatter_compacted`` (capacity-slop lanes dropped), keeping
    the merge rule in exactly one place.  Per-lane arithmetic is the same
    CDF inversion over the same tile row and uniform, so outputs are
    bitwise-equal to the uncompacted dispatch per key.  Returns ``v_mh``
    ``(num_walks,)`` int32.
    """
    return scatter_compacted(
        num_walks,
        walk_idx_by_bucket,
        valid_by_bucket,
        [
            walk_transition_sparse(
                rows, tiles, u_b, block_w=block_w, interpret=interpret
            )
            for rows, tiles, u_b in zip(
                rows_by_bucket, tiles_by_bucket, u_by_bucket
            )
        ],
    )


# ---------------------------------------------------------------------------
# Ragged-layout fused kernel (true-degree flat-CSR reads, scalar prefetch)
# ---------------------------------------------------------------------------


def _ragged_kernel(
    # scalar-prefetch refs (SMEM): available before the body runs, used to
    # compute every flat-buffer address
    nodes_ref,  # (W_pad,) int32 current node per walk
    indptr_ref,  # (n+1,) int32 CSR row pointers
    deg_ref,  # (n,) int32 true degrees
    # tensor refs
    cdf_ref,  # (nnz,) f32 flat per-edge CDF
    idx_ref,  # (nnz,) int32 flat CSR neighbor ids
    u_ref,  # (block_w, 3 + r) f32 uniforms tile
    out_ref,  # (block_w,) int32
    hops_ref,  # (block_w,) int32
    *,
    p_d: float,
    r: int,
    block_w: int,
    search_iters: int,
):
    i = pl.program_id(0)

    def load1(ref, at):
        return pl.load(ref, (pl.dslice(at, 1),))[0]

    def one_walk(w, _):
        v = nodes_ref[i * block_w + w]
        start = indptr_ref[v]
        deg = deg_ref[v]

        # --- MH-IS move: binary search of the row's true-degree CDF ------
        # segment — mirrors engine.ragged_mh_invert statement for
        # statement, so outputs stay bitwise-equal to every other layout
        total = load1(cdf_ref, start + deg - 1)
        t = u_ref[w, U_MH] * total

        def probe(_, lohi):
            lo, hi = lohi
            active = lo < hi
            mid = (lo + hi) // 2
            c = load1(cdf_ref, start + jnp.minimum(mid, deg - 1))
            pred = active & (c < t)
            lo = jnp.where(pred, mid + 1, lo)
            hi = jnp.where(active & ~pred, mid, hi)
            return lo, hi

        lo, _hi = jax.lax.fori_loop(
            0, search_iters, probe, (jnp.int32(0), deg)
        )
        v_mh = load1(idx_ref, start + jnp.minimum(lo, deg - 1))

        # --- Lévy jump: shared TruncGeom inverse CDF, then d uniform hops
        # gathered straight from the flat CSR (the csr= arithmetic of
        # engine.levy_jump_batched, fused in-kernel)
        d = trunc_geom_icdf(u_ref[w, U_DIST], p_d, r)

        def hop(j, v_cur):
            deg_c = deg_ref[v_cur]
            hop_idx = jnp.minimum(
                (u_ref[w, U_HOP0 + j] * deg_c.astype(jnp.float32)).astype(
                    jnp.int32
                ),
                deg_c - 1,
            )
            v_new = load1(idx_ref, indptr_ref[v_cur] + hop_idx)
            return jnp.where(j < d, v_new, v_cur)

        v_jump = jax.lax.fori_loop(0, r, hop, v)

        do_jump = u_ref[w, U_JUMP] > 0.5
        out_ref[w] = jnp.where(do_jump, v_jump, v_mh)
        hops_ref[w] = jnp.where(do_jump, d, jnp.int32(1))
        return _

    jax.lax.fori_loop(0, block_w, one_walk, 0)


@functools.partial(
    jax.jit,
    static_argnames=("p_d", "r", "max_degree", "block_w", "interpret"),
)
def walk_transition_ragged(
    nodes: jnp.ndarray,  # (W,) int32
    indptr: jnp.ndarray,  # (n+1,) int32 CSR row pointers
    degrees: jnp.ndarray,  # (n,) int32
    indices: jnp.ndarray,  # (nnz,) int32 flat CSR neighbor ids
    edge_cdf: jnp.ndarray,  # (nnz,) float32 flat per-edge CDF
    uniforms: jnp.ndarray,  # (W, 3 + r) float32, slot 0 = jump flag
    *,
    p_d: float,
    r: int,
    max_degree: int,
    block_w: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The fused true-degree MHLJ step — one scalar-prefetch pass per tile.

    ``PrefetchScalarGridSpec`` prefetches the walk nodes and the CSR
    ``indptr``/``degrees`` so every per-walk address into the flat
    ``edge_cdf``/``indices`` buffers is computable up front; each walk
    then (1) binary-searches its own CDF segment at its *true* degree
    (``ceil(log2(max_degree + 1))`` probes — the only per-walk row work,
    vs O(max_deg) on the padded layouts), (2) runs the r-hop Lévy chain
    from the flat CSR, and (3) resolves the jump/MH branch, all inside
    the kernel.  Per-walk arithmetic mirrors ``engine.ragged_mh_invert``
    + ``engine.levy_jump_batched(csr=)`` + ``engine.combine_mh_jump``
    statement for statement, so outputs are bitwise-equal to every other
    layout per key.  Working set is the flat O(E) buffers — no padded or
    per-bucket table exists on this path, which is the point.

    On-hardware caveat (ROADMAP): the flat buffers ride in kernel memory
    whole, like the dense kernel's tables — real-TPU runs at nnz beyond
    VMEM need an HBM + DMA variant; CI exercises interpret mode.

    Returns ``(next_nodes, hops)``, both (W,) int32.
    """
    w = nodes.shape[0]
    n_u = num_uniforms(r)
    bw = min(block_w, w)
    w_pad = -(-w // bw) * bw
    if w_pad != w:
        # padded lanes walk node 0 on zero uniforms and are sliced off below
        nodes = jnp.pad(nodes, (0, w_pad - w))
        uniforms = jnp.pad(uniforms, ((0, w_pad - w), (0, 0)))
    search_iters = max(1, math.ceil(math.log2(max_degree + 1)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # nodes, indptr, degrees
        grid=(w_pad // bw,),
        in_specs=[
            pl.BlockSpec(edge_cdf.shape, lambda i, *_: (0,)),
            pl.BlockSpec(indices.shape, lambda i, *_: (0,)),
            pl.BlockSpec((bw, n_u), lambda i, *_: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bw,), lambda i, *_: (i,)),
            pl.BlockSpec((bw,), lambda i, *_: (i,)),
        ],
    )
    next_nodes, hops = pl.pallas_call(
        functools.partial(
            _ragged_kernel,
            p_d=p_d,
            r=r,
            block_w=bw,
            search_iters=search_iters,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((w_pad,), jnp.int32),
            jax.ShapeDtypeStruct((w_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(
        nodes.astype(jnp.int32),
        indptr.astype(jnp.int32),
        degrees.astype(jnp.int32),
        edge_cdf,
        indices.astype(jnp.int32),
        uniforms,
    )
    return next_nodes[:w], hops[:w]
