"""Batched MHLJ transition Pallas TPU kernel — the paper's orchestration hot
spot at scale (W parallel walks on a large silo graph, sampled every step).

One grid step processes ``block_w`` walks.  Per walk:
  * MH-IS move: CDF inversion over the walk's padded P_IS neighbor row
    (precomputed (n, max_deg) table, resident in VMEM — graphs here are
    orchestration-scale, n <= a few thousand silos);
  * Lévy jump: distance d <- TruncGeom(p_d, r) via closed-form inverse CDF,
    then d uniform hops using the neighbors/degrees tables.

All per-walk work is scalar loads from VMEM tables (pl.dslice rows +
static-column picks) — no vector gathers, which keeps the kernel TPU-legal.

Inputs:
  nodes      (W,)  int32     current node per walk
  row_probs  (n, max_deg)    P_IS rows aligned with ``neighbors``
  neighbors  (n, max_deg)    int32 padded (pad = self id)
  degrees    (n, 1) int32
  uniforms   (W, 2 + r)      pre-drawn U(0,1): [jump?, distance, hop_1..hop_r]
Output:
  next_nodes (W,) int32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["walk_transition"]


def _kernel(
    nodes_ref, probs_ref, neigh_ref, deg_ref, u_ref, out_ref,
    *, p_j: float, p_d: float, r: int, block_w: int, max_deg: int,
):
    def one_walk(w, _):
        v = nodes_ref[w]
        u_jump = u_ref[w, 0]

        # --- MH-IS move via CDF inversion over the padded neighbor row ----
        prow = pl.load(probs_ref, (pl.dslice(v, 1), slice(None)))[0]  # (max_deg,)
        cdf = jnp.cumsum(prow)
        idx = jnp.sum((cdf < u_ref[w, 1] * cdf[-1]).astype(jnp.int32))
        idx = jnp.minimum(idx, max_deg - 1)
        nrow = pl.load(neigh_ref, (pl.dslice(v, 1), slice(None)))[0]
        v_mh = jnp.take(nrow, idx, axis=0)

        # --- Levy jump: closed-form TruncGeom inverse CDF ------------------
        # F(d) = (1-(1-p_d)^d) / (1-(1-p_d)^r);  d = ceil(log1p(-u*Z)/log(1-p_d))
        z = 1.0 - (1.0 - p_d) ** r
        log_q = jnp.log(1.0 - p_d)
        d = jnp.ceil(jnp.log1p(-u_ref[w, 1] * z) / log_q).astype(jnp.int32)
        d = jnp.clip(d, 1, r)

        def hop(i, v_cur):
            deg = pl.load(deg_ref, (pl.dslice(v_cur, 1), slice(None)))[0, 0]
            hop_idx = jnp.minimum(
                (u_ref[w, 2 + i] * deg.astype(jnp.float32)).astype(jnp.int32),
                deg - 1,
            )
            row = pl.load(neigh_ref, (pl.dslice(v_cur, 1), slice(None)))[0]
            v_new = jnp.take(row, hop_idx, axis=0)
            return jnp.where(i < d, v_new, v_cur)

        v_jump = jax.lax.fori_loop(0, r, hop, v)

        out_ref[w] = jnp.where(u_jump < p_j, v_jump, v_mh)
        return _

    jax.lax.fori_loop(0, block_w, one_walk, 0)


@functools.partial(
    jax.jit, static_argnames=("p_j", "p_d", "r", "block_w", "interpret")
)
def walk_transition(
    nodes: jnp.ndarray,  # (W,) int32
    row_probs: jnp.ndarray,  # (n, max_deg) float32
    neighbors: jnp.ndarray,  # (n, max_deg) int32
    degrees: jnp.ndarray,  # (n,) int32
    uniforms: jnp.ndarray,  # (W, 2 + r) float32
    *,
    p_j: float,
    p_d: float,
    r: int,
    block_w: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    w = nodes.shape[0]
    n, max_deg = neighbors.shape
    bw = min(block_w, w)
    if w % bw:
        bw = w
    grid = (w // bw,)
    table = lambda i: (0, 0)
    return pl.pallas_call(
        functools.partial(
            _kernel, p_j=p_j, p_d=p_d, r=r, block_w=bw, max_deg=max_deg
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, max_deg), table),
            pl.BlockSpec((n, max_deg), table),
            pl.BlockSpec((n, 1), table),
            pl.BlockSpec((bw, 2 + r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=interpret,
    )(nodes, row_probs, neighbors, degrees[:, None], uniforms)
