"""Jit'd wrappers for batched MHLJ transitions (multi-walk mode).

Both entry points are thin views over :class:`repro.core.engine.WalkEngine`
— ``mhlj_step_batched`` forces the Pallas backend (interpret mode off-TPU),
``mhlj_step_oracle`` forces the pure-JAX scan backend.  Given the same key
they consume identical uniforms and must agree bitwise (test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine import WalkEngine


@functools.partial(jax.jit, static_argnames=("p_j", "p_d", "r"))
def mhlj_step_batched(
    key: jax.Array,
    nodes: jnp.ndarray,
    row_probs: jnp.ndarray,
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    *,
    p_j: float,
    p_d: float,
    r: int,
) -> jnp.ndarray:
    engine = WalkEngine(
        neighbors=neighbors,
        degrees=degrees,
        p_j=p_j,
        p_d=p_d,
        r=r,
        row_probs=row_probs,
        backend="pallas",
    )
    next_nodes, _ = engine.step(key, nodes)
    return next_nodes


def mhlj_step_oracle(key, nodes, row_probs, neighbors, degrees, *, p_j, p_d, r):
    engine = WalkEngine(
        neighbors=neighbors,
        degrees=degrees,
        p_j=p_j,
        p_d=p_d,
        r=r,
        row_probs=row_probs,
        backend="scan",
    )
    next_nodes, _ = engine.step(key, nodes)
    return next_nodes
