"""Jit'd wrapper for batched MHLJ transitions (multi-walk mode)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.walk_transition.kernel import walk_transition
from repro.kernels.walk_transition.ref import walk_transition_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("p_j", "p_d", "r"))
def mhlj_step_batched(
    key: jax.Array,
    nodes: jnp.ndarray,
    row_probs: jnp.ndarray,
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    *,
    p_j: float,
    p_d: float,
    r: int,
) -> jnp.ndarray:
    u = jax.random.uniform(key, (nodes.shape[0], 2 + r), jnp.float32)
    return walk_transition(
        nodes, row_probs, neighbors, degrees, u,
        p_j=p_j, p_d=p_d, r=r, interpret=not _is_tpu(),
    )


def mhlj_step_oracle(key, nodes, row_probs, neighbors, degrees, *, p_j, p_d, r):
    u = jax.random.uniform(key, (nodes.shape[0], 2 + r), jnp.float32)
    return walk_transition_ref(
        nodes, row_probs, neighbors, degrees, u, p_j=p_j, p_d=p_d, r=r
    )
