"""Jit'd wrappers for batched MHLJ transitions (multi-walk mode).

All entry points are thin views over :class:`repro.core.engine.WalkEngine`
— ``mhlj_step_batched`` forces the Pallas backend in its sparse tile layout
(interpret mode off-TPU), ``mhlj_step_sparse`` is its explicit alias,
``mhlj_step_dense`` forces the full-table dense kernel,
``mhlj_step_bucketed`` forces the per-degree-bucket dispatch from a
prebuilt bucketed engine, and ``mhlj_step_oracle`` forces the pure-JAX
scan backend.  Given the same key they all consume identical uniforms and
must agree bitwise (test_kernels.py / test_sparse_engine.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.engine import WalkEngine


@functools.partial(jax.jit, static_argnames=("p_j", "p_d", "r", "layout"))
def mhlj_step_batched(
    key: jax.Array,
    nodes: jnp.ndarray,
    row_probs: jnp.ndarray,
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    *,
    p_j: float,
    p_d: float,
    r: int,
    layout: str = "sparse",
) -> jnp.ndarray:
    engine = WalkEngine(
        neighbors=neighbors,
        degrees=degrees,
        p_j=p_j,
        p_d=p_d,
        r=r,
        row_probs=row_probs,
        backend="pallas",
        layout=layout,
    )
    next_nodes, _ = engine.step(key, nodes)
    return next_nodes


def mhlj_step_sparse(key, nodes, row_probs, neighbors, degrees, *, p_j, p_d, r):
    """Sparse-tile Pallas path, explicitly (== the default of
    ``mhlj_step_batched``)."""
    return mhlj_step_batched(
        key, nodes, row_probs, neighbors, degrees,
        p_j=p_j, p_d=p_d, r=r, layout="sparse",
    )


def mhlj_step_dense(key, nodes, row_probs, neighbors, degrees, *, p_j, p_d, r):
    """Full-table dense-layout Pallas kernel (parity testing only)."""
    return mhlj_step_batched(
        key, nodes, row_probs, neighbors, degrees,
        p_j=p_j, p_d=p_d, r=r, layout="dense",
    )


@jax.jit
def _engine_step_nodes(engine: WalkEngine, key, nodes):
    # the engine is a pytree argument: its arrays are traced leaves while
    # backend/layout ride as static aux data, so each layout compiles once
    next_nodes, _ = engine.step(key, nodes)
    return next_nodes


def mhlj_step_bucketed(key, nodes, engine: WalkEngine):
    """Per-degree-bucket pallas dispatch from a prebuilt bucketed engine
    (``WalkEngine.from_graph(graph.to_bucketed(), ...)``)."""
    if engine.layout != "bucketed":
        raise ValueError(f"engine layout must be 'bucketed', got {engine.layout!r}")
    return _engine_step_nodes(
        dataclasses.replace(engine, backend="pallas"), key, nodes
    )


def mhlj_step_ragged(key, nodes, engine: WalkEngine):
    """Fused true-degree scalar-prefetch kernel from a prebuilt ragged
    engine (``WalkEngine.from_graph(graph, ..., layout="ragged")``)."""
    if engine.layout != "ragged":
        raise ValueError(f"engine layout must be 'ragged', got {engine.layout!r}")
    return _engine_step_nodes(
        dataclasses.replace(engine, backend="pallas"), key, nodes
    )


def mhlj_step_oracle(key, nodes, row_probs, neighbors, degrees, *, p_j, p_d, r):
    engine = WalkEngine(
        neighbors=neighbors,
        degrees=degrees,
        p_j=p_j,
        p_d=p_d,
        r=r,
        row_probs=row_probs,
        backend="scan",
    )
    next_nodes, _ = engine.step(key, nodes)
    return next_nodes
