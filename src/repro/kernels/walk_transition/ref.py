"""Pure-jnp oracle for the walk-transition kernels (same pre-drawn uniforms).

The oracles *are* the engine's scan-backend math — re-exported here so the
kernel directory keeps the kernel/ops/ref layout of its siblings while
Algorithm 1 stays implemented exactly once (repro.core.engine).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import (
    U_MH,
    combine_bucketed,
    combine_mh_jump,
    levy_jump_batched,
    mh_cdf_invert,
    mhlj_transition_math,
    ragged_mh_invert,
    scatter_compacted,
)


def walk_transition_ref(
    nodes: jnp.ndarray,
    row_probs: jnp.ndarray,
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    uniforms: jnp.ndarray,
    *,
    p_d: float,
    r: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ``kernel.walk_transition`` (slot 0 = jump flag)."""
    return mhlj_transition_math(
        nodes, row_probs[nodes], neighbors, degrees, uniforms, p_d, r
    )


def walk_transition_sparse_ref(
    rows: jnp.ndarray, neigh_rows: jnp.ndarray, u_mh: jnp.ndarray
) -> jnp.ndarray:
    """Same contract as ``kernel.walk_transition_sparse`` — the engine's
    vectorized CDF inversion over gathered tiles."""
    return mh_cdf_invert(rows, neigh_rows, u_mh)


def walk_transition_bucketed_ref(
    bucket_ids: jnp.ndarray,
    rows_by_bucket,
    tiles_by_bucket,
    u_mh: jnp.ndarray,
) -> jnp.ndarray:
    """Same contract as ``kernel.walk_transition_bucketed``: per-bucket CDF
    inversion with each walk keeping its own bucket's result (merge rule:
    ``engine.combine_bucketed``)."""
    return combine_bucketed(
        bucket_ids,
        [
            mh_cdf_invert(rows, tiles, u_mh)
            for rows, tiles in zip(rows_by_bucket, tiles_by_bucket)
        ],
    )


def walk_transition_ragged_ref(
    nodes: jnp.ndarray,
    indptr: jnp.ndarray,
    degrees: jnp.ndarray,
    indices: jnp.ndarray,
    edge_cdf: jnp.ndarray,
    uniforms: jnp.ndarray,
    *,
    p_d: float,
    r: int,
    max_degree: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ``kernel.walk_transition_ragged``: the engine's
    flat-CDF binary-search MH move (``engine.ragged_mh_invert``), the
    CSR-gathered Lévy branch and the jump/MH combine — the fused kernel
    mirrors this composition per walk."""
    v_mh = ragged_mh_invert(
        indptr, degrees, indices, edge_cdf, nodes, uniforms[:, U_MH],
        max_degree=max_degree,
    )
    v_jump, d = levy_jump_batched(
        nodes, uniforms, None, degrees, p_d, r, csr=(indptr, indices)
    )
    return combine_mh_jump(v_mh, v_jump, d, uniforms)


def walk_transition_bucketed_compacted_ref(
    rows_by_bucket,
    tiles_by_bucket,
    u_by_bucket,
    walk_idx_by_bucket,
    valid_by_bucket,
    num_walks: int,
) -> jnp.ndarray:
    """Same contract as ``kernel.walk_transition_bucketed_compacted``: the
    engine's CDF inversion over each compacted ``[cap_b, width_b]`` tile,
    merged back to walk order by ``engine.scatter_compacted``."""
    return scatter_compacted(
        num_walks,
        walk_idx_by_bucket,
        valid_by_bucket,
        [
            mh_cdf_invert(rows, tiles, u_b)
            for rows, tiles, u_b in zip(
                rows_by_bucket, tiles_by_bucket, u_by_bucket
            )
        ],
    )
