"""Pure-jnp oracle for the walk-transition kernel (same pre-drawn uniforms)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def walk_transition_ref(
    nodes: jnp.ndarray,
    row_probs: jnp.ndarray,
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    uniforms: jnp.ndarray,
    *,
    p_j: float,
    p_d: float,
    r: int,
) -> jnp.ndarray:
    def one(v, u):
        prow = row_probs[v]
        cdf = jnp.cumsum(prow)
        idx = jnp.minimum(
            jnp.sum((cdf < u[1] * cdf[-1]).astype(jnp.int32)), prow.shape[0] - 1
        )
        v_mh = neighbors[v, idx]

        z = 1.0 - (1.0 - p_d) ** r
        d = jnp.clip(
            jnp.ceil(jnp.log1p(-u[1] * z) / jnp.log(1.0 - p_d)).astype(jnp.int32), 1, r
        )

        def hop(i, v_cur):
            deg = degrees[v_cur]
            hop_idx = jnp.minimum(
                (u[2 + i] * deg.astype(jnp.float32)).astype(jnp.int32), deg - 1
            )
            v_new = neighbors[v_cur, hop_idx]
            return jnp.where(i < d, v_new, v_cur)

        v_jump = jax.lax.fori_loop(0, r, hop, v)
        return jnp.where(u[0] < p_j, v_jump, v_mh)

    return jax.vmap(one)(nodes, uniforms)
