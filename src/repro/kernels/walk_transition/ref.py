"""Pure-jnp oracle for the walk-transition kernel (same pre-drawn uniforms).

The oracle *is* the engine's scan-backend math — re-exported here so the
kernel directory keeps the kernel/ops/ref layout of its siblings while
Algorithm 1 stays implemented exactly once (repro.core.engine).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import mhlj_transition_math


def walk_transition_ref(
    nodes: jnp.ndarray,
    row_probs: jnp.ndarray,
    neighbors: jnp.ndarray,
    degrees: jnp.ndarray,
    uniforms: jnp.ndarray,
    *,
    p_d: float,
    r: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ``kernel.walk_transition`` (slot 0 = jump flag)."""
    return mhlj_transition_math(
        nodes, row_probs[nodes], neighbors, degrees, uniforms, p_d, r
    )
