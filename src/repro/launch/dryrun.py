"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) on the production meshes and capture
memory / cost / collective analyses for the roofline (deliverable g).

MUST set the fake-device flag before ANY jax import (jax locks the device
count at first init).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import optim  # noqa: E402
from repro.configs import (  # noqa: E402
    ARCHITECTURES,
    INPUT_SHAPES,
    arch_for_shape,
    get_arch,
    get_shape,
)
from repro.core.graphs import ring  # noqa: E402
from repro.core.transition import MHLJParams  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.factory import build_model  # noqa: E402
from repro.sharding import rules as sh  # noqa: E402
from repro.utils.hlo_parse import collective_summary  # noqa: E402
from repro.walk_sgd.llm_trainer import (  # noqa: E402
    WalkContext,
    init_walk_state,
    make_serve_step,
    make_train_step,
)

N_SILOS = 64  # graph nodes (data silos) for the walk-orchestrated train step


def make_optimizer(cfg):
    if cfg.optimizer == "adafactor":
        return optim.adafactor(1e-3)
    return optim.adamw(3e-4)


def _decode_profile(cfg) -> str:
    # pure-TP decode needs params to fit one model-parallel group: use the
    # 2-D profile for very large archs (DESIGN.md §5)
    return "fsdp_decode" if cfg.param_count() * 2 > 120e9 else "tp_decode"


def lower_case(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    extra: dict | None = None,
    unroll: bool = False,
    model_parallel: int = 16,
):
    """Returns (lowered, compiled, info) for one (arch, shape, mesh) case.

    ``unroll=True`` fully unrolls layer scans so cost_analysis /
    collective_summary count EVERY layer (XLA prices a while body once) —
    the roofline capture mode.  Rolled scan remains the deployment default.
    """
    if unroll:
        from repro.models.model_utils import unrolled_layers

        with unrolled_layers():
            return _lower_case_inner(
                arch_name, shape_name, multi_pod, extra, True, model_parallel
            )
    return _lower_case_inner(
        arch_name, shape_name, multi_pod, extra, False, model_parallel
    )


def _lower_case_inner(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    extra: dict | None,
    unrolled: bool,
    model_parallel: int = 16,
):
    shape = get_shape(shape_name)
    cfg = arch_for_shape(get_arch(arch_name), shape)
    if extra:
        import dataclasses

        cfg = dataclasses.replace(cfg, **extra)
    mesh = make_production_mesh(multi_pod=multi_pod, model_parallel=model_parallel)
    model = build_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    if shape.kind == "train":
        profile = "fsdp_tp"
        optimizer = make_optimizer(cfg)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        graph = ring(N_SILOS)
        walk = WalkContext.from_graph(graph, MHLJParams(0.1, 0.5, 3))
        step = make_train_step(model, optimizer, walk)
        walk_shapes = jax.eval_shape(
            lambda: init_walk_state(N_SILOS, np.ones(N_SILOS, np.float32))
        )
        batch_shapes = model.input_specs(shape)

        p_spec = sh.param_specs(params_shapes, profile, mesh)
        o_spec = sh.opt_state_specs(opt_shapes, p_spec, params_shapes, profile, mesh)
        w_spec = jax.tree_util.tree_map(lambda _: jax.sharding.PartitionSpec(), walk_shapes)
        b_spec = sh.batch_specs(batch_shapes, profile, mesh)
        in_sh = tuple(
            sh.named_shardings(s, mesh) for s in (p_spec, o_spec, w_spec, b_spec)
        )
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        out_sh = (in_sh[0], in_sh[1], in_sh[2], rep)
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(params_shapes, opt_shapes, walk_shapes, batch_shapes)
    elif shape.kind == "prefill":
        profile = _decode_profile(cfg)

        def prefill_step(params, batch):
            hidden = model.apply(params, batch)
            logits = jnp.einsum(
                "bd,vd->bv", hidden[:, -1], params["embedding"]["table"],
                preferred_element_type=jnp.float32,
            )
            return logits

        batch_shapes = model.input_specs(shape)
        p_spec = sh.param_specs(params_shapes, profile, mesh)
        b_spec = sh.batch_specs(batch_shapes, profile, mesh)
        in_sh = tuple(sh.named_shardings(s, mesh) for s in (p_spec, b_spec))
        fn = jax.jit(prefill_step, in_shardings=in_sh)
        with mesh:
            lowered = fn.lower(params_shapes, batch_shapes)
    else:  # decode
        profile = _decode_profile(cfg)
        serve = make_serve_step(model)
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        tok_shapes = model.input_specs(shape, for_decode=True)["tokens"]
        p_spec = sh.param_specs(params_shapes, profile, mesh)
        c_spec = sh.cache_specs(cache_shapes, profile, mesh)
        t_spec = sh.batch_specs({"t": tok_shapes}, profile, mesh)["t"]
        rep_spec = jax.sharding.PartitionSpec()
        in_sh = (
            sh.named_shardings(p_spec, mesh),
            sh.named_shardings(c_spec, mesh),
            jax.sharding.NamedSharding(mesh, t_spec),
            jax.sharding.NamedSharding(mesh, rep_spec),
        )
        out_sh = (in_sh[2], in_sh[1])
        fn = jax.jit(serve, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(1,))
        pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            lowered = fn.lower(params_shapes, cache_shapes, tok_shapes, pos_shape)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    coll = collective_summary(compiled.as_text())
    info = {
        "arch": arch_name,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "unrolled": unrolled,
        "model_parallel": model_parallel,
        "kind": shape.kind,
        "profile": profile,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "memory": mem_info,
        "collectives": coll,
        "compile_seconds": compile_s,
    }
    return lowered, compiled, info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument(
        "--unroll", action="store_true",
        help="fully unroll layer scans (roofline capture: per-layer costs counted)",
    )
    args = ap.parse_args()

    archs = list(ARCHITECTURES) if args.arch == "all" else args.arch.split(",")
    shapes = (
        [s.name for s in INPUT_SHAPES] if args.shape == "all" else args.shape.split(",")
    )
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    _, compiled, info = lower_case(arch, shape, mp, unroll=args.unroll)
                    info["status"] = "ok"
                    print(
                        f"[OK]   {tag}: flops={info['flops']:.3e} "
                        f"bytes={info['bytes_accessed']:.3e} "
                        f"coll={info['collectives']['total_bytes']:.3e}B "
                        f"compile={info['compile_seconds']:.1f}s",
                        flush=True,
                    )
                    del compiled
                except Exception as e:
                    info = {
                        "arch": arch, "shape": shape, "multi_pod": mp,
                        "status": "fail", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                results.append(info)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(info) + "\n")
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cases lowered+compiled successfully", flush=True)
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
