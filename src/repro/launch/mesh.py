"""Production mesh construction (deliverable e).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Functions, not module-level constants, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh", "make_walker_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False, model_parallel: int = 16):
    """Production mesh: 256 chips/pod.  ``model_parallel`` splits the pod
    between data and model axes (16x16 default; 32x8 is the §Perf layout
    for archs whose head counts do not divide 16 — same 256 chips)."""
    assert 256 % model_parallel == 0
    data = 256 // model_parallel
    shape = (2, data, model_parallel) if multi_pod else (data, model_parallel)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh for CPU smoke tests (same axis names as production)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_walker_mesh(num_devices: int | None = None):
    """1-D fleet mesh: ``num_devices`` (default: all visible devices) on the
    ``data`` axis — the mesh axis the ``walker`` logical axis of
    ``repro.sharding.rules`` maps to, so a W-walker ``WalkFleet`` shards
    its walker batch across every device and the periodic cross-walker
    model average becomes one all-reduce along ``data``.  On CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes to get a multi-device fleet mesh (the CI sharded leg)."""
    n = len(jax.devices()) if num_devices is None else num_devices
    return jax.make_mesh((n,), ("data",))


class HW:
    """TPU v5e hardware constants for the roofline model (per chip)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # bytes/s
    ICI_BW = 50e9  # bytes/s per link
    HBM_BYTES = 16 * 1024**3
