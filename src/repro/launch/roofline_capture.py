"""Roofline capture (deliverable g): loop-aware cost terms per (arch x shape).

XLA's ``compiled.cost_analysis()`` prices a ``while`` body ONCE, so rolled
layer scans undercount FLOPs / bytes / collective bytes by ~num_layers.
This capture compiles each case normally (rolled scans — fast) and re-prices
the compiled HLO with ``repro.utils.hlo_cost`` (dots priced from contracting
dims, loop bodies multiplied by trip counts recovered from loop conditions,
collectives accumulated inside loops, fusion-internal traffic excluded).

Writes one JSONL record per case; consumed by benchmarks/roofline.py.

Run:  PYTHONPATH=src python -m repro.launch.roofline_capture \
          --out results/roofline.jsonl
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_arch  # noqa: E402
from repro.launch.dryrun import lower_case  # noqa: E402
from repro.utils.hlo_cost import price_module  # noqa: E402

__all__ = ["capture_case", "main"]


# §Perf optimized configuration: Megatron-style kv-head repeat + explicit
# activation/dispatch sharding constraints; a head-divisible 32x8 submesh
# for qwen2.5 (40 heads % 16 != 0).  Applied to train/prefill only — the
# cached decode path showed regressions under both levers (EXPERIMENTS.md
# §Perf, refuted-hypothesis log), so decode keeps the baseline layout.
def _opt_settings(arch_name: str, shape_name: str) -> dict:
    from repro.configs import get_shape

    kind = get_shape(shape_name).kind
    if kind == "decode":
        return {}
    mp = 16
    if arch_name == "qwen2.5-32b":
        mp = 8  # 40 heads % 16 != 0
    elif arch_name == "paligemma-3b":
        mp = 8  # 8 heads fit exactly (beats 2x-padded 16-way by ~2x)
    elif arch_name == "whisper-tiny" and kind == "train":
        mp = 1  # 37M params: pure data parallel; prefill's batch 32 cannot
        # shard over data=256, so prefill keeps the 16x16 layout
    return {"extra": {"gqa_repeat_kv": True}, "model_parallel": mp}


def capture_case(
    arch_name: str, shape_name: str, multi_pod: bool = False, opt: bool = False
) -> dict:
    cfg = get_arch(arch_name)
    kw = _opt_settings(arch_name, shape_name) if opt else {}
    _, compiled, info = lower_case(arch_name, shape_name, multi_pod, **kw)
    cost = price_module(compiled.as_text())
    return {
        "arch": arch_name,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "optimized": opt,
        "kind": info["kind"],
        "profile": info["profile"],
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "flops": cost.flops,
        "bytes_accessed": cost.bytes,
        "collectives": {
            "total_bytes": cost.coll_bytes,
            "total_ring_cost_bytes": cost.coll_ring_bytes,
            "by_kind": cost.coll_counts,
        },
        "xla_cost_analysis": {  # body-once numbers, kept for reference
            "flops": info["flops"],
            "bytes_accessed": info["bytes_accessed"],
        },
        "memory": info["memory"],
        "compile_seconds": info["compile_seconds"],
        "status": "ok",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="capture the §Perf optimized configuration")
    ap.add_argument("--out", default="results/roofline.jsonl")
    args = ap.parse_args()

    archs = list(ARCHITECTURES) if args.arch == "all" else args.arch.split(",")
    shapes = (
        [s.name for s in INPUT_SHAPES] if args.shape == "all" else args.shape.split(",")
    )
    n_ok = n_tot = 0
    for arch in archs:
        for shape in shapes:
            n_tot += 1
            tag = f"{arch} x {shape}"
            t0 = time.time()
            try:
                rec = capture_case(arch, shape, args.multi_pod, opt=args.opt)
                n_ok += 1
                print(
                    f"[OK]   {tag}: flops={rec['flops']:.3e} "
                    f"bytes={rec['bytes_accessed']:.3e} "
                    f"coll={rec['collectives']['total_bytes']:.3e}B "
                    f"({time.time() - t0:.0f}s)",
                    flush=True,
                )
            except Exception as e:
                rec = {
                    "arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                    "status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-1500:],
                }
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"\n{n_ok}/{n_tot} roofline captures complete", flush=True)
    return 0 if n_ok == n_tot else 1


if __name__ == "__main__":
    raise SystemExit(main())
