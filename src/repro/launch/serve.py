"""Walk-routed serving: requests pinned to graph nodes, routed by walker fleets.

Two layers (documented in docs/serving.md):

1. :class:`ServeEngine` — slot-based continuous batching over the model's
   cached decode path, hardened for sustained traffic: a bounded admission
   queue (backpressure — a full queue sheds loudly instead of growing
   without limit), per-request deadlines (an expired request is shed
   exactly once, never silently dropped), loud rejection of prompts that
   could never fit the KV-cache budget, cache *recycling* when the shared
   write position exhausts ``cache_len`` (in-flight requests are preempted
   back to the queue front and replayed — greedy decode is deterministic —
   instead of the engine simply stopping), and per-request latency
   bookkeeping in engine ticks (p50/p95/p99 via :func:`latency_percentiles`).

2. :class:`ServeSimulator` — the heavy-traffic scenario from the ROADMAP:
   each request arrives *at a node* of a ragged-layout graph (traffic skew
   set by a per-node load vector, degree-proportional by default, so
   hub-heavy Barabasi-Albert graphs concentrate demand exactly where the
   entrapment problem lives), and a :class:`~repro.walk_sgd.fleet.WalkFleet`
   of W walkers advances one batched
   :class:`~repro.core.engine.WalkEngine` transition per tick, picking up
   pending requests at the nodes it visits and feeding them to the serve
   engine.  The routing law is selected through the *trainer* METHODS seam
   (:func:`build_route_engine` — simple / uniform / importance / mhlj /
   heterogeneity / private, with the request load vector standing in for
   the per-node Lipschitz constants), so the convergence-vs-entrapment
   trade-off each chain law makes shows up directly as a
   requests-per-second / p99-latency / visit-Herfindahl trade-off.

CPU-scale:  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
              --nodes 2000 --walkers 32 --method mhlj --ticks 200 --drain 100
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_arch, reduced
from repro.core.entrapment import occupancy_concentration
from repro.core.faults import FaultModel
from repro.core.graphs import barabasi_albert
from repro.data.synthetic import RegressionData
from repro.models.factory import build_model
from repro.walk_sgd.fleet import WalkFleet

__all__ = [
    "Request",
    "ServeEngine",
    "ServeSimulator",
    "build_route_engine",
    "latency_percentiles",
    "load_arrival_trace",
    "save_arrival_trace",
    "main",
]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int
    node: int = -1  # graph node the request is pinned to (-1 = direct submit)
    deadline: Optional[int] = None  # last tick at which admission is allowed
    submit_tick: Optional[int] = None
    admit_tick: Optional[int] = None
    done_tick: Optional[int] = None
    generated: Optional[List[int]] = None
    done: bool = False
    shed: bool = False
    shed_reason: Optional[str] = None


def latency_percentiles(requests) -> Dict[str, float]:
    """p50/p95/p99 of ``done_tick - submit_tick`` over finished requests.

    Latency is measured in *engine ticks* (the simulator clock), not wall
    seconds, so the numbers are machine-independent.  Zero completed
    requests — every request shed, or a fault scenario that killed the
    whole serving region — returns defined zeros rather than NaN or an
    exception, so a fully-degraded leg of a sweep still serializes;
    pair the percentiles with ``completed`` to tell "instant" from
    "nothing finished".
    """
    lats = [
        r.done_tick - r.submit_tick
        for r in requests
        if r.done_tick is not None and r.submit_tick is not None
    ]
    if not lats:
        return {"p50_ticks": 0.0, "p95_ticks": 0.0, "p99_ticks": 0.0}
    arr = np.asarray(lats, np.float64)
    return {f"p{p}_ticks": float(np.percentile(arr, p)) for p in (50, 95, 99)}


def save_arrival_trace(path: str, trace) -> str:
    """Write an arrival trace — ``(tick, node, prompt_len)`` int64 rows.

    The trace is the replayable workload of a :class:`ServeSimulator`
    run (``sim.arrival_log`` after ``run()``): feeding it back through
    ``arrival_trace=`` replays the *identical* offered load, which is
    what makes fault sweeps comparable — the rescue-on and rescue-off
    legs of ``benchmarks/fault_sweep.py`` see the same requests at the
    same nodes on the same ticks, so any difference is the policy's.
    """
    arr = np.asarray(trace, dtype=np.int64)
    if arr.size == 0:
        arr = arr.reshape(0, 3)
    if arr.ndim != 2 or arr.shape[1] != 3:
        raise ValueError(
            f"arrival trace must be (k, 3) rows of (tick, node, "
            f"prompt_len); got shape {arr.shape}"
        )
    np.savez(path, tick=arr[:, 0], node=arr[:, 1], prompt_len=arr[:, 2])
    return path


def load_arrival_trace(path: str) -> np.ndarray:
    """Load :func:`save_arrival_trace` → ``(k, 3)`` int64, tick-sorted."""
    with np.load(path, allow_pickle=False) as z:
        arr = np.stack([z["tick"], z["node"], z["prompt_len"]], axis=1)
    return arr[np.argsort(arr[:, 0], kind="stable")].astype(np.int64)


class ServeEngine:
    """Slot-based continuous batching over the model's cached decode step.

    Every slot advances one token per engine step; a slot is either
    prefilling (consuming its prompt) or generating (feeding back its own
    last output).  Finished slots are refilled from the admission queue in
    the same step.  The scheduling contract on top of that core:

    * **Backpressure** — ``max_queue`` bounds the admission queue; a
      ``submit`` against a full queue sheds the request (reason
      ``"queue_full"``), returns ``False`` and counts it.  ``None`` keeps
      the queue unbounded (the standalone-demo default).
    * **Deadlines** — ``Request.deadline`` is the last tick at which the
      request may be *admitted to a slot*; an expired queue head is shed
      (reason ``"deadline"``) when slots are filled.  :meth:`shed` enforces
      the shed-exactly-once contract: a second shed of the same request is
      a ``RuntimeError``, not a double-counted statistic.
    * **Cache budget** — a request whose ``prompt + max_new_tokens``
      exceeds ``cache_len - 1`` could never finish inside one cache epoch
      and is rejected loudly at ``submit`` (``ValueError``), never queued.
    * **Cache recycling** — the decode path uses one shared cache write
      position; when it reaches ``cache_len - 1`` the engine preempts all
      in-flight requests back to the *front* of the queue, re-initializes
      the cache and replays them (greedy decode is deterministic, so the
      replayed tokens are identical).  ``cache_recycles`` counts epochs;
      the preemption penalty is visible in the latency percentiles.
    """

    def __init__(
        self,
        cfg,
        batch_size: int,
        cache_len: int,
        dtype=jnp.float32,
        seed=0,
        max_queue: Optional[int] = None,
    ):
        self.cfg = cfg
        self.model = build_model(cfg, dtype=dtype)
        if self.model.init_cache is None:
            raise ValueError(f"{cfg.name} has no decode path")
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.batch_size = batch_size
        self.cache_len = cache_len
        self.max_queue = max_queue

        def step(params, cache, tokens, pos):
            logits, cache = self.model.decode_step(params, tokens, cache, pos)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32).reshape(-1), cache

        self._step = jax.jit(step, donate_argnums=(1,))
        self.reset()

    def reset(self) -> "ServeEngine":
        """Fresh serving state on the same built model + jitted decode step
        (so a sweep over routing laws pays model build/compile once)."""
        self.cache = self.model.init_cache(self.batch_size, self.cache_len)
        self.slots: List[Optional[Request]] = [None] * self.batch_size
        self.slot_pos = np.zeros(self.batch_size, np.int64)  # tokens consumed
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.shed_requests: List[Request] = []
        self.shed_counts: Dict[str, int] = {}
        self.engine_steps = 0
        self.busy_slot_steps = 0
        self.cache_pos = 0  # shared KV write index, reset at each recycle
        self.cache_recycles = 0
        self.queue_depth_sum = 0.0
        self.queue_depth_max = 0
        # node ids currently down (set by the fault-aware simulator each
        # tick); an expiry observed while the request's node is in this
        # set sheds with reason "node_down" instead of "deadline"
        self.down_nodes: set = set()
        return self

    # -- scheduling ---------------------------------------------------------
    def submit(self, req: Request, tick: int = 0) -> bool:
        """Admit ``req`` to the queue; ``False`` = shed on backpressure."""
        plen = len(req.prompt)
        need = plen + req.max_new_tokens
        if plen == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if need > self.cache_len - 1:
            raise ValueError(
                f"request {req.rid}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) = {need} exceeds the cache budget "
                f"(cache_len - 1 = {self.cache_len - 1}); it could never "
                "finish within one cache epoch — split the request or raise "
                "cache_len"
            )
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.shed(req, "queue_full")
            return False
        req.generated = []
        if req.submit_tick is None:
            req.submit_tick = tick
        self.queue.append(req)
        return True

    def shed(self, req: Request, reason: str) -> None:
        """Drop ``req`` loudly, exactly once (double shed = RuntimeError)."""
        if req.shed:
            raise RuntimeError(
                f"request {req.rid} shed twice: "
                f"{req.shed_reason!r} then {reason!r}"
            )
        req.shed = True
        req.shed_reason = reason
        self.shed_counts[reason] = self.shed_counts.get(reason, 0) + 1
        self.shed_requests.append(req)

    def _fill_slots(self, tick: int = 0) -> None:
        for i in range(self.batch_size):
            if self.slots[i] is not None:
                continue
            while self.queue:
                req = self.queue.pop(0)
                if req.deadline is not None and tick > req.deadline:
                    self.shed(
                        req,
                        "node_down" if req.node in self.down_nodes
                        else "deadline",
                    )
                    continue
                req.admit_tick = tick
                self.slots[i] = req
                self.slot_pos[i] = 0
                break

    def _recycle(self, tick: int) -> None:
        """Cache epoch rollover: preempt in-flight requests to the queue
        front (they replay deterministically), re-init the KV cache."""
        inflight = [r for r in self.slots if r is not None]
        for r in inflight:
            r.generated = []
        self.queue[:0] = inflight
        self.slots = [None] * self.batch_size
        self.slot_pos[:] = 0
        self.cache = self.model.init_cache(self.batch_size, self.cache_len)
        self.cache_pos = 0
        self.cache_recycles += 1

    def _gather_tokens(self) -> np.ndarray:
        toks = np.zeros((self.batch_size, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = self.slot_pos[i]
            if p < len(req.prompt):
                toks[i, 0] = req.prompt[p]
            elif req.generated:
                toks[i, 0] = req.generated[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        return toks

    def step(self, tick: Optional[int] = None) -> None:
        """One engine step: every occupied slot consumes/produces one token.

        ``tick`` is the external clock (the simulator's); standalone use
        defaults it to ``engine_steps`` so latency is measured in decode
        steps either way.  An all-empty step is a no-op — it burns neither
        an engine step nor a cache row.
        """
        if tick is None:
            tick = self.engine_steps
        self._fill_slots(tick)
        if all(s is None for s in self.slots):
            return
        if self.cache_pos >= self.cache_len - 1:
            self._recycle(tick)
            self._fill_slots(tick)
        tokens = jnp.asarray(self._gather_tokens())
        # single shared cache write position; slots that joined mid-epoch
        # waste cache rows but stay correct because attention masks beyond
        # pos — cache exhaustion recycles the epoch (see _recycle) instead
        # of stopping the engine
        pos = jnp.asarray(self.cache_pos, jnp.int32)
        next_tok, self.cache = self._step(self.params, self.cache, tokens, pos)
        next_tok = np.asarray(next_tok)
        self.engine_steps += 1
        self.cache_pos += 1
        self.queue_depth_sum += len(self.queue)
        self.queue_depth_max = max(self.queue_depth_max, len(self.queue))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.busy_slot_steps += 1
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                req.generated.append(int(next_tok[i]))
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    req.done_tick = tick
                    self.completed.append(req)
                    self.slots[i] = None

    def stats(self) -> dict:
        toks = sum(len(r.generated) for r in self.completed)
        return {
            "completed": len(self.completed),
            "generated_tokens": toks,
            "engine_steps": self.engine_steps,
            "slot_utilization": self.busy_slot_steps
            / max(1, self.engine_steps * self.batch_size),
            "queued": len(self.queue),
            "shed_queue_full": self.shed_counts.get("queue_full", 0),
            "shed_deadline": self.shed_counts.get("deadline", 0),
            "shed_node_down": self.shed_counts.get("node_down", 0),
            "cache_recycles": self.cache_recycles,
            "mean_queue_depth": self.queue_depth_sum / max(1, self.engine_steps),
            "max_queue_depth": self.queue_depth_max,
            **latency_percentiles(self.completed),
        }

    def run(self, max_engine_steps: int = 10_000) -> dict:
        """Standalone drain: decode until queue + slots are empty."""
        t0 = time.time()
        while (self.queue or any(s is not None for s in self.slots)) and (
            self.engine_steps < max_engine_steps
        ):
            self.step()
        dt = time.time() - t0
        out = self.stats()
        out["tokens_per_sec"] = out["generated_tokens"] / max(dt, 1e-9)
        return out


def build_route_engine(
    graph,
    method: str,
    load: np.ndarray,
    *,
    mhlj_params=None,
    law_kwargs: Optional[dict] = None,
    engine_kwargs: Optional[dict] = None,
):
    """Routing :class:`~repro.core.engine.WalkEngine` via the trainer seam.

    Any name in ``repro.walk_sgd.trainer.METHODS`` works: the per-node
    request ``load`` stands in for the Lipschitz vector the training laws
    weight by (``RegressionData.lipschitz = load`` exactly, via features
    ``sqrt(load/2)``), so ``importance``/``mhlj`` target pi ∝ load — visit
    hot nodes more — while ``uniform`` ignores the skew and ``simple``
    follows degrees.  Returns ``(engine, p_j)`` with ``p_j`` the law's
    jump probability (0 for the non-jump laws).
    """
    from repro.walk_sgd import trainer as trainer_mod

    load = np.asarray(load, np.float64)
    if load.shape != (graph.n,) or (load <= 0).any():
        raise ValueError(f"load must be a positive ({graph.n},) vector")
    data = RegressionData(
        features=np.sqrt(load / 2.0)[:, None],
        targets=np.zeros(graph.n),
        x_star=np.zeros(1),
        lipschitz=load,
        high_variance_mask=np.zeros(graph.n, bool),
    )
    row_probs, _w, p_j_sched, p_d, r, _uw = trainer_mod._setup_method(
        method, graph, data, mhlj_params, None, 1, law_kwargs
    )
    engine = trainer_mod._build_engine(
        graph, p_d, r, row_probs, engine_kwargs, "auto"
    )
    return engine, float(p_j_sched[0])


def _faulted_advance(fleet, key, p_j, fmodel, fstate):
    """One fault-aware tick transition (jitted as a whole in the sim).

    The fault process advances *first* (same per-tick ordering as the
    training fleet scan), then the fleet takes one liveness-masked step;
    the returned state carries the engine's consecutive-blocked counters
    forward so patience accrues across ticks.
    """
    akey, fkey = jax.random.split(key)
    fstate = fmodel.advance(fkey, fstate)
    new_fleet, _hops, aux = fleet.advance(akey, p_j=p_j, faults=(fmodel, fstate))
    fstate = dataclasses.replace(fstate, blocked=aux["blocked_steps"])
    return new_fleet, fstate, fmodel.live_mask(fstate), aux


class ServeSimulator:
    """Requests as nodes on the graph, walkers as the routing fabric.

    Per tick: (1) Poisson arrivals land at nodes drawn ∝ ``load`` and join
    that node's pending deque; (2) the W-walker fleet takes one batched
    engine transition (one jitted call — the fleet/engine pytree crosses
    the jit boundary like everywhere else in the repo) and its visited
    nodes are logged for the entrapment telemetry; (3) each walker picks up
    to ``pickup`` pending requests at its node and submits them to the
    serve engine (queue-full → shed, deadline-expired → shed, both exactly
    once); (4) the serve engine takes one decode step.  ``metrics()``
    reports requests/s, queue depth, slot occupancy, p50/p95/p99 latency in
    ticks, aggregate walk-steps/s and the per-node visit Herfindahl/top-k
    share (``repro.core.entrapment.occupancy_concentration`` — the same
    telemetry ``benchmarks/law_sweep.py`` attaches to training walks).

    ``method="heterogeneity"`` defaults its target pi to the normalized
    load (routing interpretation: visit mass ∝ demand) so the O(n²)
    dissimilarity measurement is never run on a serving graph; pass
    ``law_kwargs={"pi": ...}`` to override.

    **Degraded operation** (docs/faults.md): with
    ``fault_model=FaultModel(...)`` the node fault process advances once
    per tick on its own key stream, the fleet transition is
    liveness-masked (blocked walkers accrue patience and take Lévy
    rescues onto the live set), walkers parked on dead nodes pick
    nothing up, and pending requests at a node that has been down for
    ``relocate_after`` consecutive ticks are re-queued at a live node
    (arrival order preserved, counted in ``relocated_requests``).  A
    deadline expiry observed while the request's node is down sheds with
    reason ``"node_down"`` instead of ``"deadline"`` — still exactly
    once.  ``fault_model=None`` is bitwise the pre-fault simulator.

    **Trace-driven load**: ``arrival_trace`` (``(k, 3)`` int64 rows of
    ``(tick, node, prompt_len)``, see :func:`save_arrival_trace`)
    replaces the Poisson generator so two legs of a sweep face the
    identical workload; every run also records its own arrivals in
    ``self.arrival_log`` for re-play.
    """

    def __init__(
        self,
        graph,
        serve_engine: ServeEngine,
        *,
        method: str = "mhlj",
        num_walkers: int = 64,
        load: Optional[np.ndarray] = None,
        rate: float = 1.0,
        pickup: int = 4,
        deadline_ticks: Optional[int] = None,
        prompt_len=(4, 16),
        max_new_tokens: int = 8,
        mhlj_params=None,
        law_kwargs: Optional[dict] = None,
        engine_kwargs: Optional[dict] = None,
        seed: int = 0,
        fault_model: Optional[FaultModel] = None,
        relocate_after: int = 3,
        arrival_trace: Optional[np.ndarray] = None,
    ):
        self.graph = graph
        self.n = int(graph.n)
        self.engine = serve_engine
        self.method = method
        if load is None:
            load = np.asarray(graph.degrees, np.float64)
        self.load = np.asarray(load, np.float64)
        if method == "heterogeneity" and not (law_kwargs and "pi" in law_kwargs):
            law_kwargs = {**(law_kwargs or {}), "pi": self.load / self.load.sum()}
        self._pop_cdf = np.cumsum(self.load / self.load.sum())
        self.route_engine, self._p_j = build_route_engine(
            graph, method, self.load,
            mhlj_params=mhlj_params, law_kwargs=law_kwargs,
            engine_kwargs=engine_kwargs,
        )
        self.num_walkers = num_walkers
        self.fleet = WalkFleet.create(self.route_engine, num_walkers, seed=seed)
        self._advance = jax.jit(
            lambda fleet, key, p_j: fleet.advance(key, p_j=p_j)
        )
        self._base_key = jax.random.PRNGKey(seed)
        self._rng = np.random.default_rng(seed + 1)
        # fault machinery — all of it dormant (and RNG-silent) when
        # fault_model is None, so the no-fault path stays bitwise
        self.fault_model = fault_model
        self.relocate_after = int(relocate_after)
        self._fault_state = (
            None if fault_model is None
            else fault_model.init_state(self.n, num_walkers)
        )
        self._advance_faulted = (
            None if fault_model is None else jax.jit(_faulted_advance)
        )
        self._relocate_rng = np.random.default_rng(seed + 2)
        self._down_now: set = set()
        self.down_since: Dict[int, int] = {}
        self.rescues = 0
        self.blocked_steps = 0
        self.down_node_ticks = 0
        self.relocated = 0
        # trace-driven load (replaces the Poisson generator when set)
        if arrival_trace is not None:
            arr = np.asarray(arrival_trace, dtype=np.int64)
            if arr.ndim != 2 or arr.shape[1] != 3:
                raise ValueError(
                    "arrival_trace must be (k, 3) rows of (tick, node, "
                    f"prompt_len); got shape {arr.shape}"
                )
            arrival_trace = arr[np.argsort(arr[:, 0], kind="stable")]
        self._trace = arrival_trace
        self._trace_pos = 0
        self._draining = False
        self.arrival_log: List[tuple] = []
        self.rate = rate
        self.pickup = pickup
        self.deadline_ticks = deadline_ticks
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.pending: Dict[int, deque] = {}
        self.pending_count = 0
        self.visits: List[np.ndarray] = []
        self.offered = 0
        self.picked_up = 0
        self.walk_steps = 0
        self.ticks = 0
        self._next_rid = 0
        self._wall = 0.0

    # -- workload -----------------------------------------------------------
    def offer(self, req: Request) -> None:
        """Pin ``req`` to its node's pending queue (arrival, not admission)."""
        if not (0 <= req.node < self.n):
            raise ValueError(
                f"request {req.rid}: node {req.node} outside [0, {self.n})"
            )
        need = len(req.prompt) + req.max_new_tokens
        if need > self.engine.cache_len - 1:
            # mirror the engine's loud cache-budget reject at the door, so
            # an impossible request never waits for a walker first
            raise ValueError(
                f"request {req.rid}: prompt+max_new ({need}) exceeds the "
                f"cache budget (cache_len - 1 = {self.engine.cache_len - 1})"
            )
        self.pending.setdefault(req.node, deque()).append(req)
        self.pending_count += 1
        self.offered += 1

    def _offer_generated(self, t: int, node: int, plen: int) -> None:
        """One synthetic arrival: prompt tokens from the workload RNG."""
        self.offer(
            Request(
                rid=self._next_rid,
                prompt=self._rng.integers(
                    0, self.engine.cfg.vocab_size, plen
                ).astype(np.int32),
                max_new_tokens=self.max_new_tokens,
                node=node,
                deadline=(
                    None
                    if self.deadline_ticks is None
                    else t + self.deadline_ticks
                ),
                submit_tick=t,
            )
        )
        self.arrival_log.append((t, node, plen))
        self._next_rid += 1

    def _arrivals(self, t: int) -> None:
        if self._trace is not None:
            if self._draining:
                return
            tr, i = self._trace, self._trace_pos
            while i < tr.shape[0] and tr[i, 0] <= t:
                if tr[i, 0] == t:
                    self._offer_generated(t, int(tr[i, 1]), int(tr[i, 2]))
                i += 1
            self._trace_pos = i
            return
        k = int(self._rng.poisson(self.rate))
        if k == 0:
            return
        nodes = np.searchsorted(self._pop_cdf, self._rng.random(k))
        lo, hi = self.prompt_len
        for v in nodes:
            plen = int(self._rng.integers(lo, hi + 1))
            self._offer_generated(t, int(v), plen)

    # -- fault handling -----------------------------------------------------
    def _advance_faults(self, t: int, key) -> None:
        """Advance the fault process + fleet one tick, then degrade:
        update the engine's ``down_nodes`` view, track per-node downtime,
        and relocate pending work off nodes down past the backoff."""
        self.fleet, self._fault_state, live, aux = self._advance_faulted(
            self.fleet, key, self._p_j, self.fault_model, self._fault_state
        )
        live_np = np.asarray(live)
        self.rescues += int(np.asarray(aux["rescued"]).sum())
        self.blocked_steps += int(np.asarray(aux["fault_blocked"]).sum())
        self.down_node_ticks += int((~live_np).sum())
        self._down_now = set(np.nonzero(~live_np)[0].tolist())
        self.engine.down_nodes = self._down_now
        for v in [u for u in self.down_since if u not in self._down_now]:
            del self.down_since[v]
        for v in self._down_now:
            self.down_since.setdefault(v, t)
        self._relocate_pending(t, live_np)

    def _relocate_pending(self, t: int, live_np: np.ndarray) -> None:
        """Re-queue pending requests off nodes down ≥ ``relocate_after``
        ticks onto a uniformly-drawn live node (arrival order kept)."""
        live_ids = np.nonzero(live_np)[0]
        if live_ids.size == 0:
            return  # total failure: nowhere to go, requests wait or expire
        stale = [
            v for v in list(self.pending)
            if v in self._down_now
            and t - self.down_since.get(v, t) >= self.relocate_after
        ]
        for v in stale:
            dq = self.pending.pop(v)
            tgt = int(live_ids[int(self._relocate_rng.integers(live_ids.size))])
            for req in dq:
                req.node = tgt
            self.relocated += len(dq)
            self.pending.setdefault(tgt, deque()).extend(dq)

    # -- the tick loop ------------------------------------------------------
    def tick(self) -> None:
        t = self.ticks
        self._arrivals(t)
        key = jax.random.fold_in(self._base_key, t)
        if self.fault_model is None:
            self.fleet, _hops = self._advance(self.fleet, key, self._p_j)
        else:
            self._advance_faults(t, key)
        where = np.asarray(self.fleet.nodes)
        self.visits.append(where.copy())
        self.walk_steps += self.num_walkers
        for v in where.tolist():
            if v in self._down_now:
                continue  # a walker parked on a dead node serves nothing
            dq = self.pending.get(v)
            if not dq:
                continue
            for _ in range(self.pickup):
                if not dq:
                    break
                req = dq.popleft()
                self.pending_count -= 1
                if req.deadline is not None and t > req.deadline:
                    self.engine.shed(req, "deadline")
                    continue
                if self.engine.submit(req, tick=t):
                    self.picked_up += 1
            if not dq:
                self.pending.pop(v, None)
        self.engine.step(tick=t)
        self.ticks += 1

    def _expire_pending(self) -> None:
        """Shed deadline-expired requests still waiting at their node;
        expiry observed at a currently-down node sheds as ``node_down``."""
        t = self.ticks
        for v in list(self.pending):
            keep: deque = deque()
            dq = self.pending.pop(v)
            while dq:
                req = dq.popleft()
                if req.deadline is not None and t > req.deadline:
                    self.engine.shed(
                        req,
                        "node_down" if req.node in self._down_now
                        else "deadline",
                    )
                    self.pending_count -= 1
                else:
                    keep.append(req)
            if keep:
                self.pending[v] = keep

    def run(self, num_ticks: int, drain_ticks: int = 0) -> dict:
        """``num_ticks`` with arrivals, then ``drain_ticks`` at rate 0."""
        t0 = time.time()
        for _ in range(num_ticks):
            self.tick()
        rate, self.rate = self.rate, 0.0
        self._draining = True
        try:
            for _ in range(drain_ticks):
                self.tick()
        finally:
            self.rate = rate
            self._draining = False
        self._expire_pending()
        self._wall += time.time() - t0
        return self.metrics()

    # -- telemetry ----------------------------------------------------------
    def metrics(self) -> dict:
        eng = self.engine.stats()
        if self.visits:
            traj = np.concatenate(self.visits)
            conc = occupancy_concentration(traj, self.n, topk=min(8, self.n))
        else:
            conc = {"herfindahl": 0.0, "topk_share": 0.0}
        wall = max(self._wall, 1e-9)
        return {
            "ticks": self.ticks,
            "offered": self.offered,
            "picked_up": self.picked_up,
            "pending_left": self.pending_count,
            "completed": eng["completed"],
            "generated_tokens": eng["generated_tokens"],
            "queued_left": eng["queued"],
            "shed_queue_full": eng["shed_queue_full"],
            "shed_deadline": eng["shed_deadline"],
            "shed_node_down": eng["shed_node_down"],
            "cache_recycles": eng["cache_recycles"],
            "slot_occupancy": eng["slot_utilization"],
            "mean_queue_depth": eng["mean_queue_depth"],
            "max_queue_depth": eng["max_queue_depth"],
            "requests_per_sec": eng["completed"] / wall,
            "tokens_per_sec": eng["generated_tokens"] / wall,
            "walk_steps_per_sec": self.walk_steps / wall,
            "p50_ticks": eng["p50_ticks"],
            "p95_ticks": eng["p95_ticks"],
            "p99_ticks": eng["p99_ticks"],
            "herfindahl": conc["herfindahl"],
            "topk_share": conc["topk_share"],
            # degradation telemetry — all zeros when fault_model is None,
            # so the metrics schema is stable across sweep legs
            "walker_rescues": self.rescues,
            "walker_blocked_steps": self.blocked_steps,
            "relocated_requests": self.relocated,
            "node_downtime_frac": (
                self.down_node_ticks / max(1, self.ticks * self.n)
            ),
        }


def main():
    from repro.walk_sgd.trainer import METHODS

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-370m", choices=sorted(ARCHITECTURES))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--nodes", type=int, default=2000,
                    help="graph size (ragged-layout Barabasi-Albert)")
    ap.add_argument("--ba-m", type=int, default=3,
                    help="Barabasi-Albert attachment parameter")
    ap.add_argument("--walkers", type=int, default=32,
                    help="routing fleet size W")
    ap.add_argument("--method", default="mhlj", choices=list(METHODS),
                    help="routing law (the trainer METHODS seam)")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="mean Poisson arrivals per tick")
    ap.add_argument("--ticks", type=int, default=200)
    ap.add_argument("--drain", type=int, default=100,
                    help="extra arrival-free ticks to drain the system")
    ap.add_argument("--batch", type=int, default=4, help="decode slots")
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pickup", type=int, default=4,
                    help="max requests a walker picks up per visit")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission-queue bound (backpressure)")
    ap.add_argument("--deadline", type=int, default=None,
                    help="per-request admission deadline in ticks")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-rate", type=float, default=0.0,
                    help="per-tick node crash probability (0 = no faults)")
    ap.add_argument("--recovery-rate", type=float, default=0.0,
                    help="per-tick dead-node recovery probability")
    ap.add_argument("--patience", type=int, default=3,
                    help="consecutive blocked steps before a Lévy rescue")
    ap.add_argument("--no-rescue", action="store_true",
                    help="disable the Lévy-jump rescue (blocked walkers "
                    "just wait)")
    ap.add_argument("--relocate-after", type=int, default=3,
                    help="ticks a node stays down before its pending "
                    "requests are re-queued at a live node")
    ap.add_argument("--trace", default=None,
                    help="replay arrivals from a recorded trace file "
                    "instead of the Poisson generator")
    ap.add_argument("--record-trace", default=None,
                    help="write this run's arrival trace to a file "
                    "(replayable via --trace)")
    ap.add_argument("--standalone", action="store_true",
                    help="skip graph routing: direct-submit --requests "
                    "requests to the slot engine (the original demo)")
    ap.add_argument("--requests", type=int, default=8,
                    help="standalone mode: number of direct-submitted requests")
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch)) if args.scale == "smoke" else get_arch(args.arch)
    engine = ServeEngine(
        cfg, args.batch, args.cache_len, seed=args.seed, max_queue=args.max_queue
    )

    if args.standalone:
        rng = np.random.default_rng(args.seed)
        for rid in range(args.requests):
            plen = int(rng.integers(4, 24))
            engine.submit(
                Request(
                    rid=rid,
                    prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=args.max_new,
                )
            )
        stats = engine.run()
        for k, v in stats.items():
            print(f"{k}: {v:.4g}" if isinstance(v, float) else f"{k}: {v}")
        return 0 if stats["completed"] == args.requests else 1

    graph = barabasi_albert(args.nodes, args.ba_m, seed=args.seed, layout="ragged")
    fault_model = None
    if args.crash_rate > 0.0:
        fault_model = FaultModel(
            crash_rate=args.crash_rate,
            recovery_rate=args.recovery_rate,
            patience=args.patience,
            rescue=not args.no_rescue,
        )
    sim = ServeSimulator(
        graph,
        engine,
        method=args.method,
        num_walkers=args.walkers,
        rate=args.rate,
        pickup=args.pickup,
        deadline_ticks=args.deadline,
        max_new_tokens=args.max_new,
        seed=args.seed,
        fault_model=fault_model,
        relocate_after=args.relocate_after,
        arrival_trace=(
            load_arrival_trace(args.trace) if args.trace else None
        ),
    )
    metrics = sim.run(args.ticks, drain_ticks=args.drain)
    if args.record_trace:
        save_arrival_trace(args.record_trace, sim.arrival_log)
    for k, v in metrics.items():
        print(f"{k}: {v:.4g}" if isinstance(v, float) else f"{k}: {v}")
    return 0 if metrics["completed"] > 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
