"""Batched serving driver (deliverable b): KV-cache greedy decoding with a
simple continuous-batching front end.

Requests arrive with different prompt lengths; the scheduler packs up to
``--batch`` of them into one decode batch (left-aligned, per-slot position
counters), prefills prompts token-by-token through the cached decode path
(exactly the path the decode dry-run shapes lower), then generates until
every request hits its max_new_tokens.  Finished slots are immediately
refilled from the queue — the slot occupancy statistics are reported.

CPU-scale:  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
              --requests 8 --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_arch, reduced
from repro.models.factory import build_model

__all__ = ["ServeEngine", "Request", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching over the model's cached decode step.

    Every slot advances one token per engine step; a slot is either
    prefilling (consuming its prompt) or generating (feeding back its own
    last output).  Per-slot position counters index the KV cache, so mixed
    prefill/generate batches run in the same jitted call.
    """

    def __init__(self, cfg, batch_size: int, cache_len: int, dtype=jnp.float32, seed=0):
        self.cfg = cfg
        self.model = build_model(cfg, dtype=dtype)
        if self.model.init_cache is None:
            raise ValueError(f"{cfg.name} has no decode path")
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.batch_size = batch_size
        self.cache_len = cache_len
        self.cache = self.model.init_cache(batch_size, cache_len)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.slot_pos = np.zeros(batch_size, np.int64)  # tokens consumed per slot
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.engine_steps = 0
        self.busy_slot_steps = 0

        def step(params, cache, tokens, pos):
            logits, cache = self.model.decode_step(params, tokens, cache, pos)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32).reshape(-1), cache

        self._step = jax.jit(step, donate_argnums=(1,))

    # -- scheduling ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.generated = []
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)
                self.slot_pos[i] = 0

    def _gather_tokens(self) -> np.ndarray:
        toks = np.zeros((self.batch_size, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = self.slot_pos[i]
            if p < len(req.prompt):
                toks[i, 0] = req.prompt[p]
            elif req.generated:
                toks[i, 0] = req.generated[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        return toks

    def step(self) -> None:
        """One engine step: every occupied slot consumes/produces one token."""
        self._fill_slots()
        if all(s is None for s in self.slots):
            return
        tokens = jnp.asarray(self._gather_tokens())
        # single shared position (cache write index); slots that joined late
        # waste cache rows but stay correct because attention masks beyond pos
        pos = jnp.asarray(self.engine_steps, jnp.int32)
        next_tok, self.cache = self._step(self.params, self.cache, tokens, pos)
        next_tok = np.asarray(next_tok)
        self.engine_steps += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.busy_slot_steps += 1
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                req.generated.append(int(next_tok[i]))
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    self.completed.append(req)
                    self.slots[i] = None

    def run(self, max_engine_steps: int = 10_000) -> dict:
        t0 = time.time()
        while (self.queue or any(self.slots)) and self.engine_steps < max_engine_steps:
            if self.engine_steps >= self.cache_len - 1:
                break  # cache exhausted; production would roll the cache
            self.step()
        dt = time.time() - t0
        toks = sum(len(r.generated) for r in self.completed)
        return {
            "completed": len(self.completed),
            "generated_tokens": toks,
            "engine_steps": self.engine_steps,
            "slot_utilization": self.busy_slot_steps
            / max(1, self.engine_steps * self.batch_size),
            "tokens_per_sec": toks / max(dt, 1e-9),
        }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-370m", choices=sorted(ARCHITECTURES))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch)) if args.scale == "smoke" else get_arch(args.arch)
    engine = ServeEngine(cfg, args.batch, args.cache_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 24))
        engine.submit(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                max_new_tokens=args.max_new,
            )
        )
    stats = engine.run()
    for k, v in stats.items():
        print(f"{k}: {v:.4g}" if isinstance(v, float) else f"{k}: {v}")
    return 0 if stats["completed"] == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
