"""End-to-end decentralized training driver (deliverable b's e2e path).

Runs walk-orchestrated LLM training: a graph of data silos, MHLJ (or any
baseline) routing, per-silo token shards, a pjit-able train step, periodic
checkpointing, and metric logging.

CPU-scale invocation (examples/llm_decentralized.py uses this):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --scale smoke \
      --steps 100 --method mhlj

``--scale smoke`` trains the arch's reduced() variant on a 1-device mesh;
``--scale custom`` takes explicit --layers/--d-model/... for the ~100M-class
driver run; on a real TPU pod slice ``--scale full`` uses the production
mesh + fsdp_tp profile (same code path; the dry-run proves it lowers).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import ARCHITECTURES, get_arch, reduced
from repro.core import graphs as g_mod
from repro.core import schedules as pj_schedules
from repro.core.transition import MHLJParams
from repro.data.lm_data import make_node_token_shards
from repro.data.pipeline import NodeDataPipeline
from repro.models.factory import build_model
from repro.utils import checkpoint as ckpt
from repro.walk_sgd.llm_trainer import (
    WalkContext,
    init_walk_state,
    make_train_step,
)

__all__ = ["run_training", "main"]

GRAPHS = {
    "ring": lambda n, seed: g_mod.ring(n),
    "grid": lambda n, seed: g_mod.grid2d(int(np.sqrt(n))),
    "watts_strogatz": lambda n, seed: g_mod.watts_strogatz(n, 4, 0.1, seed),
    "erdos_renyi": lambda n, seed: g_mod.erdos_renyi(n, 0.1, seed),
    "expander": lambda n, seed: g_mod.expander(n, 6, seed),
}


def run_training(
    cfg,
    *,
    graph_kind: str = "ring",
    n_silos: int = 16,
    method: str = "mhlj",
    steps: int = 100,
    batch_size: int = 4,
    seq_len: int = 128,
    lr: float = 3e-4,
    p_j: float = 0.1,
    p_d: float = 0.5,
    r: int = 3,
    anneal_pj: bool = False,
    online_lipschitz: bool = True,
    seed: int = 0,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 0,
    resume: bool = False,
    log_every: int = 10,
    dtype=jnp.float32,
) -> dict:
    """Train; returns {'losses': ..., 'walk': ..., 'steps_per_sec': ...}."""
    graph = GRAPHS[graph_kind](n_silos, seed)
    n_silos = graph.n
    data = make_node_token_shards(
        n_silos, cfg.vocab_size, shard_len=max(2048, (seq_len + 1) * 4), seed=seed
    )
    pipeline = NodeDataPipeline(data, batch_size, seq_len, seed=seed)

    model = build_model(cfg, dtype=dtype)
    params = model.init(jax.random.PRNGKey(seed))
    optimizer = optim.adamw(lr)
    opt_state = optimizer.init(params)

    # method -> walk configuration (p_j=0 degrades MHLJ to plain MH-IS;
    # uniform Lipschitz degrades MH-IS to MH-uniform)
    if method == "mhlj":
        params_w = MHLJParams(p_j, p_d, r)
        lips0 = np.ones(n_silos, np.float32)
    elif method == "importance":
        params_w = MHLJParams(0.0, p_d, r)
        lips0 = np.ones(n_silos, np.float32)
    elif method == "uniform":
        params_w = MHLJParams(0.0, p_d, r)
        lips0 = np.ones(n_silos, np.float32)
        online_lipschitz = False  # keep L_v == 1 -> MH-uniform
    else:
        raise ValueError(f"unknown method {method!r}")

    walk = WalkContext.from_graph(graph, params_w, online_lipschitz=online_lipschitz)
    walk_state = init_walk_state(n_silos, lips0, v0=0, seed=seed, online=online_lipschitz)
    if anneal_pj and method == "mhlj":
        pj_sched = pj_schedules.polynomial_decay(p_j, steps, t0=max(1, steps // 4))
    else:
        pj_sched = np.full(steps, params_w.p_j, np.float32)

    # deterministic resume: restore params/opt/walk AND the pipeline counter
    # so a restarted job continues the SAME walk trajectory and batch stream
    # (Algorithm 1 is sequential — resuming from the wrong node silently
    # changes the sampled distribution)
    start_step = 0
    if resume and checkpoint_dir and ckpt.latest_step(checkpoint_dir) is not None:
        walk_state["p_j"] = jnp.asarray(0.0, jnp.float32)  # fix treedef for load
        out = ckpt.load_checkpoint(checkpoint_dir, params, opt_state, walk_state)
        params, opt_state = out["params"], out["opt_state"]
        walk_state = jax.tree_util.tree_map(jnp.asarray, out["walk_state"])
        start_step = out["step"]
        pipeline._counter = out["extra"].get("pipeline_counter", seed + start_step)

    step_fn = jax.jit(make_train_step(model, optimizer, walk), donate_argnums=(0, 1))

    losses, nodes = [], []
    t0 = time.time()
    for t in range(start_step, steps):
        node = int(walk_state["node"])
        batch = {k: jnp.asarray(v) for k, v in pipeline.next_batch(node).items()}
        walk_state["p_j"] = jnp.asarray(pj_sched[t], jnp.float32)
        params, opt_state, walk_state, metrics = step_fn(
            params, opt_state, walk_state, batch
        )
        losses.append(float(metrics["loss"]))
        nodes.append(node)
        if log_every and (t % log_every == 0 or t == steps - 1):
            print(
                f"step {t:5d}  node {node:3d}  loss {losses[-1]:.4f}  "
                f"w {float(metrics['weight']):.3f}",
                flush=True,
            )
        if checkpoint_dir and checkpoint_every and (t + 1) % checkpoint_every == 0:
            ckpt.save_checkpoint(
                checkpoint_dir, t + 1, params, opt_state, walk_state,
                extra={
                    "arch": cfg.name,
                    "method": method,
                    "pipeline_counter": pipeline._counter,
                },
            )
    dt = time.time() - t0
    hops = int(walk_state["hops"])
    updates = int(walk_state["updates"])
    return {
        "losses": np.asarray(losses),
        "update_nodes": np.asarray(nodes),
        "transitions_per_update": hops / max(updates, 1),
        "steps_per_sec": steps / dt,
        "params": params,
        "opt_state": opt_state,
        "walk_state": walk_state,
        "final_lipschitz": np.asarray(walk_state["lipschitz"]),
    }


def _custom_cfg(args):
    base = get_arch(args.arch)
    return dataclasses.replace(
        reduced(base),
        name=f"{args.arch}-custom",
        num_layers=args.layers,
        d_model=args.d_model,
        num_heads=args.heads,
        num_kv_heads=min(args.heads, base.num_kv_heads) or args.heads,
        head_dim=args.d_model // args.heads,
        d_ff=args.d_ff or 4 * args.d_model,
        vocab_size=args.vocab,
        loss_chunks=1,
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-32b", choices=sorted(ARCHITECTURES))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "custom", "full"])
    ap.add_argument("--graph", default="ring", choices=sorted(GRAPHS))
    ap.add_argument("--silos", type=int, default=16)
    ap.add_argument("--method", default="mhlj", choices=["mhlj", "importance", "uniform"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--p-j", type=float, default=0.1)
    ap.add_argument("--anneal-pj", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in --checkpoint-dir")
    # --scale custom model dims (the ~100M-class driver)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=8192)
    args = ap.parse_args()

    if args.scale == "smoke":
        cfg = reduced(get_arch(args.arch))
    elif args.scale == "custom":
        cfg = _custom_cfg(args)
    else:
        cfg = get_arch(args.arch)

    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"method={args.method} graph={args.graph}({args.silos})", flush=True)
    res = run_training(
        cfg,
        graph_kind=args.graph,
        n_silos=args.silos,
        method=args.method,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        lr=args.lr,
        p_j=args.p_j,
        anneal_pj=args.anneal_pj,
        seed=args.seed,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        resume=args.resume,
    )
    summary = {
        "loss_first10": float(res["losses"][:10].mean()),
        "loss_last10": float(res["losses"][-10:].mean()),
        "transitions_per_update": res["transitions_per_update"],
        "steps_per_sec": res["steps_per_sec"],
    }
    print(json.dumps(summary, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
