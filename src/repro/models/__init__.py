from repro.models import regression
from repro.models.base import Model

__all__ = ["regression", "Model"]
