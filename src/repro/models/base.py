"""Model protocol shared by all architectures in the framework.

A Model is a bundle of pure functions over pytrees — no module state:

  init(rng) -> params                    parameter pytree
  loss(params, batch, rng) -> (scalar, aux)   training objective
  apply(params, batch) -> outputs        forward pass (logits etc.)
  param_specs() -> pytree of PartitionSpec    sharding (same treedef as params)
  input_specs(shape_cfg) -> dict of ShapeDtypeStruct  dry-run stand-ins

Concrete LLM models are produced by factory functions from a config
dataclass (see ``repro.configs``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

__all__ = ["Model"]


@dataclasses.dataclass(frozen=True)
class Model:
    name: str
    init: Callable[..., Any]
    loss: Callable[..., Any]
    apply: Callable[..., Any]
    param_specs: Optional[Callable[[], Any]] = None
    input_specs: Optional[Callable[..., Any]] = None
    # decode-path (serving) hooks; None for encoder-only / non-LM models
    init_cache: Optional[Callable[..., Any]] = None
    decode_step: Optional[Callable[..., Any]] = None
    cache_specs: Optional[Callable[[], Any]] = None
