"""Encoder-decoder audio backbone — Whisper [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB (per the assignment carve-out):
``input_specs`` supplies post-conv frame embeddings (B, encoder_len, d_model).
Everything downstream is fully implemented: sinusoidal-position encoder with
bidirectional attention, decoder with causal self-attn + cross-attn + GELU
MLPs, LayerNorms (whisper convention), learned decoder positions.

Decode path: decoder self-attn KV ring cache + cross-KV precomputed once per
request (stored in the cache pytree).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import Model
from repro.models.layers import attention as attn_mod
from repro.models.layers import embedding as emb_mod
from repro.models.layers import mlp as mlp_mod
from repro.models.layers.norms import layernorm, layernorm_init
from repro.models.model_utils import remat_wrap, scan_layers, stacked_init, layer_scan

__all__ = ["build_encdec_model"]


def _sinusoid(length: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2, jnp.float32) / dim)
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def build_encdec_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    dims = attn_mod.AttnDims(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=True,  # whisper uses biases
        use_rope=False,  # absolute positions, whisper convention
    )

    def enc_layer_init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": layernorm_init(cfg.d_model),
            "attn": attn_mod.attn_init(k1, dims, dtype),
            "ln2": layernorm_init(cfg.d_model),
            "mlp": mlp_mod.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_layer_init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": layernorm_init(cfg.d_model),
            "self_attn": attn_mod.attn_init(k1, dims, dtype),
            "ln_x": layernorm_init(cfg.d_model),
            "cross_attn": attn_mod.cross_attn_init(k2, dims, dtype),
            "ln2": layernorm_init(cfg.d_model),
            "mlp": mlp_mod.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
        }

    def init(key):
        k_emb, k_pos, k_enc, k_dec = jax.random.split(key, 4)
        return {
            "embedding": emb_mod.embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "dec_pos": (jax.random.normal(k_pos, (8192, cfg.d_model), jnp.float32) * 0.01).astype(dtype),
            "encoder": stacked_init(enc_layer_init, k_enc, cfg.num_encoder_layers),
            "ln_enc": layernorm_init(cfg.d_model),
            "decoder": stacked_init(dec_layer_init, k_dec, cfg.num_layers),
            "ln_f": layernorm_init(cfg.d_model),
        }

    def enc_body(lp, x):
        h = attn_mod.attention_full(
            lp["attn"], layernorm(lp["ln1"], x), dims, mode="bidir"
        )
        x = x + h
        return x + mlp_mod.gelu_mlp(lp["mlp"], layernorm(lp["ln2"], x))

    def encode(params, frames):
        x = frames.astype(dtype) + _sinusoid(frames.shape[1], cfg.d_model).astype(dtype)
        x = scan_layers(enc_body, params["encoder"], x, remat=cfg.remat)
        return layernorm(params["ln_enc"], x)

    def dec_body_full(lp, carry):
        x, memory = carry
        h = attn_mod.attention_full(
            lp["self_attn"], layernorm(lp["ln1"], x), dims,
            mode="causal", window=cfg.sliding_window,
        )
        x = x + h
        mem_kv = attn_mod.precompute_cross_kv(lp["cross_attn"], memory, dims)
        x = x + attn_mod.cross_attention(lp["cross_attn"], layernorm(lp["ln_x"], x), mem_kv, dims)
        x = x + mlp_mod.gelu_mlp(lp["mlp"], layernorm(lp["ln2"], x))
        return (x, memory)

    def _trunk(params, batch):
        memory = encode(params, batch["frames"])
        tokens = batch["tokens"]
        x = emb_mod.embed(params["embedding"], tokens)
        # learned positions (whisper convention); table wraps for seq lengths
        # beyond 8192 (whisper's real text ctx is 448 — the 32k/500k shapes
        # are assignment stress-tests, see DESIGN.md §4)
        pos_ids = jnp.arange(tokens.shape[1]) % params["dec_pos"].shape[0]
        x = x + params["dec_pos"][pos_ids][None]
        fn = remat_wrap(dec_body_full, cfg.remat)

        def step(carry, lp):
            return fn(lp, carry), None

        (x, _), _ = layer_scan(step, (x, memory), params["decoder"])
        return layernorm(params["ln_f"], x)

    def apply(params, batch):
        return _trunk(params, batch)

    def loss(params, batch):
        x = _trunk(params, batch)
        ce = emb_mod.chunked_softmax_xent(
            params["embedding"]["table"], x, batch["labels"], cfg.loss_chunks
        )
        return ce, {"xent": ce}

    # ---- decode ----
    def init_cache(batch_size: int, cache_len: int, params=None, frames=None):
        """Cross-KV requires params+frames; dry-run passes ShapeDtypeStructs."""
        window = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        self_cache = attn_mod.init_kv_cache(
            batch_size, window, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
        )
        def stack(t):
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape),
                t,
            )

        if params is not None and frames is not None:
            memory = encode(params, frames)
            cross = jax.vmap(
                lambda lp: attn_mod.precompute_cross_kv(lp["cross_attn"], memory, dims),
                in_axes=(0,),
            )(params["decoder"])
        else:
            enc_len = cfg.encoder_len
            kv = jnp.zeros(
                (cfg.num_layers, batch_size, enc_len, cfg.num_kv_heads, cfg.resolved_head_dim),
                dtype,
            )
            cross = {"k": kv, "v": kv}
        return {"self": stack(self_cache), "cross": cross}

    def decode_body(lp, x, cache, pos):
        self_cache, mem_kv = cache
        h, new_self = attn_mod.attention_decode(
            lp["self_attn"], layernorm(lp["ln1"], x), self_cache, pos, dims
        )
        x = x + h
        x = x + attn_mod.cross_attention(
            lp["cross_attn"], layernorm(lp["ln_x"], x), mem_kv, dims
        )
        x = x + mlp_mod.gelu_mlp(lp["mlp"], layernorm(lp["ln2"], x))
        return x, new_self

    def decode_step(params, tokens, cache, pos):
        x = emb_mod.embed(params["embedding"], tokens)
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos % 8192, 1)[None]

        def step(carry, inputs):
            lp, sc, ck, cv = inputs
            y, new_sc = decode_body(lp, carry, (sc, {"k": ck, "v": cv}), pos)
            return y, new_sc

        x, new_self = layer_scan(
            step, x, (params["decoder"], cache["self"], cache["cross"]["k"], cache["cross"]["v"])
        )
        x = layernorm(params["ln_f"], x)
        logits = emb_mod.unembed_logits(params["embedding"], x)[:, 0]
        return logits, {"self": new_self, "cross": cache["cross"]}

    def input_specs(shape, for_decode: bool = False):
        b, s = shape.global_batch, shape.seq_len
        if for_decode:
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "frames": jax.ShapeDtypeStruct((b, cfg.encoder_len, cfg.d_model), dtype),
        }

    return Model(
        name=cfg.name,
        init=init,
        loss=loss,
        apply=apply,
        input_specs=input_specs,
        init_cache=init_cache,
        decode_step=decode_step,
    )
