"""Build a Model from an ArchConfig, dispatching on family."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import Model
from repro.models.encdec import build_encdec_model
from repro.models.hybrid import build_hybrid_model
from repro.models.mamba_model import build_mamba_model
from repro.models.moe_transformer import build_moe_model
from repro.models.transformer import build_dense_model

__all__ = ["build_model"]


def build_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    if cfg.family in ("dense", "vlm"):
        return build_dense_model(cfg, dtype)
    if cfg.family == "moe":
        return build_moe_model(cfg, dtype)
    if cfg.family == "ssm":
        return build_mamba_model(cfg, dtype)
    if cfg.family == "hybrid":
        return build_hybrid_model(cfg, dtype)
    if cfg.family == "audio":
        return build_encdec_model(cfg, dtype)
    raise ValueError(f"unknown family {cfg.family!r}")
