"""Hybrid Mamba+Attention+MoE LM — Jamba-1.5-Large [arXiv:2403.19887].

Jamba block structure: periods of ``attn_period`` (=8) layers with ONE
attention layer (at ``attn_offset``) and 7 mamba layers; an FFN follows every
mixer, alternating dense / MoE (``moe_every``=2, MoE on odd layers).  No RoPE:
position information comes from the mamba mixers (Jamba convention).

Implementation: lax.scan over the (num_layers / attn_period) periods with
period-stacked params; the 8 sublayers inside a period are Python-unrolled
(static structure), so HLO size stays ~one period.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import Model
from repro.models.layers import attention as attn_mod
from repro.models.layers import embedding as emb_mod
from repro.models.layers import mamba2 as mamba_mod
from repro.models.layers import mlp as mlp_mod
from repro.models.layers import moe as moe_mod
from repro.models.layers.norms import rmsnorm, rmsnorm_init
from repro.models.mamba_model import mamba_dims_from_cfg
from repro.models.model_utils import remat_wrap, stacked_init, layer_scan
from repro.models.moe_transformer import _moe_dims
from repro.models.transformer import _dims

__all__ = ["build_hybrid_model"]


def _period_structure(cfg: ArchConfig):
    """Static per-period layout: list of (mixer, ffn) tags + index within kind."""
    period = cfg.attn_period
    layout = []
    counters = {"mamba": 0, "moe": 0, "mlp": 0}
    for i in range(period):
        mixer = "attn" if i == cfg.attn_offset else "mamba"
        mixer_idx = counters["mamba"] if mixer == "mamba" else 0
        if mixer == "mamba":
            counters["mamba"] += 1
        ffn = "moe" if (cfg.num_experts and i % cfg.moe_every == cfg.moe_every - 1) else "mlp"
        ffn_idx = counters[ffn]
        counters[ffn] += 1
        layout.append((mixer, mixer_idx, ffn, ffn_idx))
    return layout, counters


def build_hybrid_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    if cfg.num_layers % cfg.attn_period != 0:
        raise ValueError("hybrid num_layers must be divisible by attn_period")
    num_periods = cfg.num_layers // cfg.attn_period
    layout, counts = _period_structure(cfg)
    adims = _dims(cfg)
    mdims = mamba_dims_from_cfg(cfg)
    edims = _moe_dims(cfg)

    def period_init(key):
        k_m, k_a, k_e, k_f = jax.random.split(key, 4)
        return {
            "mamba": stacked_init(
                lambda k: {"ln": rmsnorm_init(cfg.d_model), "mixer": mamba_mod.mamba_init(k, mdims, dtype)},
                k_m, counts["mamba"],
            ),
            "attn": {"ln": rmsnorm_init(cfg.d_model), "attn": attn_mod.attn_init(k_a, adims, dtype)},
            "moe": stacked_init(
                lambda k: {"ln": rmsnorm_init(cfg.d_model), "moe": moe_mod.moe_init(k, edims, dtype)},
                k_e, counts["moe"],
            ) if counts["moe"] else {},
            "mlp": stacked_init(
                lambda k: {"ln": rmsnorm_init(cfg.d_model), "mlp": mlp_mod.swiglu_init(k, cfg.d_model, cfg.d_ff, dtype)},
                k_f, counts["mlp"],
            ) if counts["mlp"] else {},
        }

    def init(key):
        k_emb, k_p = jax.random.split(key)
        return {
            "embedding": emb_mod.embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "periods": stacked_init(period_init, k_p, num_periods),
            "ln_f": rmsnorm_init(cfg.d_model),
        }

    def _sub(tree, idx):
        return jax.tree_util.tree_map(lambda a: a[idx], tree)

    def period_body(pp, x):
        aux_total = jnp.zeros((), jnp.float32)
        for mixer, m_idx, ffn, f_idx in layout:
            if mixer == "attn":
                lp = pp["attn"]
                h = attn_mod.attention_full(
                    lp["attn"], rmsnorm(lp["ln"], x, cfg.norm_eps), adims,
                    mode="causal", window=cfg.sliding_window,
                )
            else:
                lp = _sub(pp["mamba"], m_idx)
                h = mamba_mod.mamba_apply(lp["mixer"], rmsnorm(lp["ln"], x, cfg.norm_eps), mdims, use_kernel=cfg.use_kernels)
            x = x + h
            if ffn == "moe":
                lp = _sub(pp["moe"], f_idx)
                h, aux = moe_mod.moe_apply(lp["moe"], rmsnorm(lp["ln"], x, cfg.norm_eps), edims)
                aux_total = aux_total + aux["moe_aux_loss"]
            else:
                lp = _sub(pp["mlp"], f_idx)
                h = mlp_mod.swiglu(lp["mlp"], rmsnorm(lp["ln"], x, cfg.norm_eps))
            x = x + h
        return x, aux_total / max(counts["moe"], 1)

    def _trunk(params, batch):
        x = emb_mod.embed(params["embedding"], batch["tokens"])
        fn = remat_wrap(period_body, cfg.remat)

        def step(carry, pp):
            new_x, aux = fn(pp, carry)
            return new_x, aux

        x, auxs = layer_scan(step, x, params["periods"])
        return rmsnorm(params["ln_f"], x, cfg.norm_eps), jnp.mean(auxs)

    def apply(params, batch):
        return _trunk(params, batch)[0]

    def loss(params, batch):
        x, aux_loss = _trunk(params, batch)
        ce = emb_mod.chunked_softmax_xent(
            params["embedding"]["table"], x, batch["labels"], cfg.loss_chunks
        )
        return ce + 0.01 * aux_loss, {"xent": ce, "moe_aux": aux_loss}

    # ---- decode ----
    def init_cache(batch_size: int, cache_len: int):
        window = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        attn_cache = attn_mod.init_kv_cache(
            batch_size, window, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
        )
        mamba_cache = mamba_mod.init_mamba_cache(batch_size, mdims, dtype)
        per_period = {
            "attn": attn_cache,
            "mamba": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (counts["mamba"],) + x.shape), mamba_cache
            ),
        }
        return {
            "periods": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (num_periods,) + x.shape), per_period
            )
        }

    def period_decode(pp, x, cache, pos):
        new_cache = {"attn": None, "mamba": [None] * counts["mamba"]}
        for mixer, m_idx, ffn, f_idx in layout:
            if mixer == "attn":
                lp = pp["attn"]
                h, nc = attn_mod.attention_decode(
                    lp["attn"], rmsnorm(lp["ln"], x, cfg.norm_eps), cache["attn"], pos, adims
                )
                new_cache["attn"] = nc
            else:
                lp = _sub(pp["mamba"], m_idx)
                h, nc = mamba_mod.mamba_decode(
                    lp["mixer"], rmsnorm(lp["ln"], x, cfg.norm_eps),
                    _sub(cache["mamba"], m_idx), mdims,
                )
                new_cache["mamba"][m_idx] = nc
            x = x + h
            if ffn == "moe":
                lp = _sub(pp["moe"], f_idx)
                h, _ = moe_mod.moe_apply(lp["moe"], rmsnorm(lp["ln"], x, cfg.norm_eps), edims)
            else:
                lp = _sub(pp["mlp"], f_idx)
                h = mlp_mod.swiglu(lp["mlp"], rmsnorm(lp["ln"], x, cfg.norm_eps))
            x = x + h
        stacked_mamba = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_cache["mamba"]
        )
        return x, {"attn": new_cache["attn"], "mamba": stacked_mamba}

    def decode_step(params, tokens, cache, pos):
        x = emb_mod.embed(params["embedding"], tokens)

        def step(carry, inputs):
            pp, pc = inputs
            y, nc = period_decode(pp, carry, pc, pos)
            return y, nc

        x, new_cache = layer_scan(step, x, (params["periods"], cache["periods"]))
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = emb_mod.unembed_logits(params["embedding"], x)[:, 0]
        return logits, {"periods": new_cache}

    def input_specs(shape, for_decode: bool = False):
        b, s = shape.global_batch, shape.seq_len
        if for_decode:
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }

    return Model(
        name=cfg.name,
        init=init,
        loss=loss,
        apply=apply,
        input_specs=input_specs,
        init_cache=init_cache,
        decode_step=decode_step,
    )
