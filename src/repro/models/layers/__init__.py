from repro.models.layers import norms, rotary, embedding, attention, mlp, moe, mamba2

__all__ = ["norms", "rotary", "embedding", "attention", "mlp", "moe", "mamba2"]
