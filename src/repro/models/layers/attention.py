"""Multi-head attention: GQA/MQA, RoPE, causal/prefix/bidirectional/sliding
masks, cross-attention, and a ring-buffer KV cache for decode.

The full-sequence path is plain jnp einsum attention (XLA-fused); the Pallas
flash-attention kernel in ``repro.kernels`` is a drop-in replacement for the
inner softmax(QK^T)V on TPU (enabled via ``use_flash``), validated against
this code path in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers.rotary import apply_rope

__all__ = [
    "AttnDims",
    "attn_init",
    "attention_full",
    "attention_decode",
    "init_kv_cache",
    "cross_attn_init",
    "cross_attention",
    "precompute_cross_kv",
]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    # §Perf: repeat kv heads to num_heads before the score einsum so BOTH
    # operands shard heads over 'model' (Megatron-style GQA).  Avoids XLA's
    # involuntary batch replication when num_kv_heads doesn't divide the
    # model axis; costs g x kv HBM traffic (small vs the S^2 tensors).
    repeat_kv: bool = False


def _maybe_constrain(x: jnp.ndarray, spec: tuple) -> jnp.ndarray:
    """with_sharding_constraint when a mesh with these axes is active (the
    production lowering path); a no-op for un-meshed CPU tests.  Axes are
    kept when the GSPMD padding waste ceil(dim/axis)*axis/dim is <= 2x —
    so 8 heads still shard over 16 devices (2x padding beats full batch
    replication, measured on paligemma prefill), but a batch-1 decode
    tensor is never forced onto a 16-way axis (measured regression)."""
    axis_sizes = {}
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.shape_tuple:
            axis_sizes = dict(mesh.shape_tuple)
    except Exception:
        pass
    if not axis_sizes:  # legacy `with mesh:` context (thread resources)
        try:
            from jax._src import mesh as _mesh_lib

            phys = _mesh_lib.thread_resources.env.physical_mesh
            if not phys.empty:
                axis_sizes = dict(zip(phys.axis_names, phys.devices.shape))
        except Exception:
            pass
    if not axis_sizes:
        return x
    def keep(i, s):
        if s is None or s not in axis_sizes or i >= x.ndim:
            return False
        dim, ax = x.shape[i], axis_sizes[s]
        padded = -(-dim // ax) * ax
        return padded <= 2 * dim

    used = tuple(s if keep(i, s) else None for i, s in enumerate(spec))
    try:
        return jax.lax.with_sharding_constraint(x, jax.sharding.PartitionSpec(*used))
    except Exception:
        return x


def attn_init(key, dims: AttnDims, dtype=jnp.bfloat16) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, n, k, h = dims.d_model, dims.num_heads, dims.num_kv_heads, dims.head_dim
    scale = d**-0.5
    params = {
        "wq": (jax.random.normal(kq, (d, n, h), jnp.float32) * scale).astype(dtype),
        "wk": (jax.random.normal(kk, (d, k, h), jnp.float32) * scale).astype(dtype),
        "wv": (jax.random.normal(kv, (d, k, h), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(ko, (n, h, d), jnp.float32) * (n * h) ** -0.5).astype(dtype),
    }
    if dims.qkv_bias:
        params["bq"] = jnp.zeros((n, h), dtype)
        params["bk"] = jnp.zeros((k, h), dtype)
        params["bv"] = jnp.zeros((k, h), dtype)
    return params


def _project_qkv(params, x, dims: AttnDims, positions):
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, params["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, params["wv"])
    if dims.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if dims.use_rope:
        q = apply_rope(q, positions, dims.rope_theta)
        k = apply_rope(k, positions, dims.rope_theta)
    return q, k, v


def _grouped_scores(q, k, dims: AttnDims):
    """(B,S,N,h) x (B,T,K,h) -> (B,K,G,S,T) with G = N/K query groups."""
    b, s, n, h = q.shape
    kk = dims.num_kv_heads
    g = n // kk
    qg = q.reshape(b, s, kk, g, h)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32)


def _grouped_out(probs, v, dims: AttnDims):
    b, kk, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(b, s, kk * g, -1)


def _repeated_scores(q, k, dims: AttnDims):
    """repeat_kv path: kv repeated to N heads; heads shard over 'model'."""
    g = dims.num_heads // dims.num_kv_heads
    k = jnp.repeat(k, g, axis=2)  # (B,T,N,h)
    q = _maybe_constrain(q, ("data", None, "model", None))
    k = _maybe_constrain(k, ("data", None, "model", None))
    return jnp.einsum("bsnh,btnh->bnst", q, k, preferred_element_type=jnp.float32)


def _repeated_out(probs, v, dims: AttnDims):
    g = dims.num_heads // dims.num_kv_heads
    v = jnp.repeat(v, g, axis=2)
    v = _maybe_constrain(v, ("data", None, "model", None))
    out = jnp.einsum("bnst,btnh->bsnh", probs.astype(v.dtype), v)
    return _maybe_constrain(out, ("data", None, "model", None))


def make_mask(
    seq_len: int,
    mode: str,
    *,
    window: int = 0,
    prefix_len: int = 0,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Additive (S, S) mask.  mode: 'causal' | 'prefix' | 'bidir'.

    ``window > 0`` restricts causal attention to the last ``window`` keys
    (sliding window).  'prefix' is the PaliGemma prefix-LM mask: full
    attention within the first ``prefix_len`` positions, causal after.
    """
    i = jnp.arange(seq_len)[:, None]
    j = jnp.arange(seq_len)[None, :]
    if mode == "bidir":
        allowed = jnp.ones((seq_len, seq_len), bool)
    elif mode == "causal":
        allowed = j <= i
    elif mode == "prefix":
        allowed = (j <= i) | ((i < prefix_len) & (j < prefix_len))
    else:
        raise ValueError(f"unknown mask mode {mode!r}")
    if window > 0 and mode != "bidir":
        allowed = allowed & (j > i - window)
    return jnp.where(allowed, 0.0, NEG_INF).astype(dtype)


def attention_full(
    params: dict,
    x: jnp.ndarray,  # (B, S, D)
    dims: AttnDims,
    *,
    mode: str = "causal",
    window: int = 0,
    prefix_len: int = 0,
    positions: Optional[jnp.ndarray] = None,
    use_flash: bool = False,
) -> jnp.ndarray:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, dims, positions)
    if use_flash and mode in ("causal", "bidir"):
        # Pallas flash-attention kernel (TPU; interpret mode on CPU) —
        # (B,S,N,h) layout, GQA folded in the kernel's kv index_map.
        # 'prefix' masks fall through to the einsum path below.
        from repro.kernels.flash_attention.ops import mha

        out = mha(
            q, k.astype(q.dtype), v.astype(q.dtype),
            causal=mode == "causal", window=window,
        ).astype(x.dtype)
        return jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    mask = make_mask(s, mode, window=window, prefix_len=prefix_len)
    if dims.repeat_kv:
        scores = _repeated_scores(q, k, dims) * (dims.head_dim**-0.5)
        scores = scores + mask[None, None]
        probs = jax.nn.softmax(scores, axis=-1)
        out = _repeated_out(probs, v, dims)
    else:
        scores = _grouped_scores(q, k, dims) * (dims.head_dim**-0.5)
        scores = scores + mask[None, None, None]
        probs = jax.nn.softmax(scores, axis=-1)
        out = _grouped_out(probs, v, dims)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# Decode path: ring-buffer KV cache (window = full seq_len or sliding window)
# ---------------------------------------------------------------------------


def init_kv_cache(
    batch: int, window: int, num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16
) -> dict:
    return {
        "k": jnp.zeros((batch, window, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, window, num_kv_heads, head_dim), dtype),
        "slot_pos": jnp.full((window,), -1, jnp.int32),  # absolute pos per slot
    }


def attention_decode(
    params: dict,
    x: jnp.ndarray,  # (B, 1, D) current token hidden
    cache: dict,
    pos: jnp.ndarray,  # scalar int32 absolute position of this token
    dims: AttnDims,
) -> tuple[jnp.ndarray, dict]:
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None], (b, 1))
    q, k_new, v_new = _project_qkv(params, x, dims, positions)

    window = cache["k"].shape[1]
    slot = pos % window
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(cache["slot_pos"], pos[None], (slot,))

    scores = _grouped_scores(q, k, dims) * (dims.head_dim**-0.5)  # (B,K,G,1,W)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(probs, v, dims)  # (B,1,N,h)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return y, {"k": k, "v": v, "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder -> encoder memory)
# ---------------------------------------------------------------------------


def cross_attn_init(key, dims: AttnDims, dtype=jnp.bfloat16) -> dict:
    return attn_init(key, dims, dtype)


def precompute_cross_kv(params: dict, memory: jnp.ndarray, dims: AttnDims) -> dict:
    """Encoder memory -> (k, v) once per request (no RoPE on cross path)."""
    k = jnp.einsum("btd,dkh->btkh", memory, params["wk"])
    v = jnp.einsum("btd,dkh->btkh", memory, params["wv"])
    if dims.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    return {"k": k, "v": v}


def cross_attention(
    params: dict,
    x: jnp.ndarray,  # (B, S, D) decoder states
    memory_kv: dict,  # precomputed {k, v}: (B, T, K, h)
    dims: AttnDims,
) -> jnp.ndarray:
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    if dims.qkv_bias:
        q = q + params["bq"]
    scores = _grouped_scores(q, memory_kv["k"], dims) * (dims.head_dim**-0.5)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(probs, memory_kv["v"], dims)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
