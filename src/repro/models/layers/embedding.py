"""Token embedding / unembedding + memory-safe chunked cross-entropy.

The chunked cross-entropy never materializes the full (B, S, V) logits tensor:
it scans over sequence chunks, computing per-chunk logits -> logsumexp ->
label gather, which caps peak activation memory at (B, chunk, V_shard) and
lets the backward pass rematerialize per chunk.  This matters at
vocab=257k x seq=4k x batch=256 (paligemma train_4k would otherwise need a
~540 GB transient).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "embedding_init",
    "embed",
    "unembed_logits",
    "chunked_softmax_xent",
]


def embedding_init(key, vocab_size: int, d_model: int, dtype=jnp.bfloat16) -> dict:
    table = jax.random.normal(key, (vocab_size, d_model), jnp.float32) * 0.02
    return {"table": table.astype(dtype)}


def embed(params: dict, tokens: jnp.ndarray, scale_by_sqrt_dim: bool = False) -> jnp.ndarray:
    out = jnp.take(params["table"], tokens, axis=0)
    if scale_by_sqrt_dim:
        out = out * jnp.sqrt(jnp.asarray(out.shape[-1], out.dtype))
    return out


def unembed_logits(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Full logits (B, S, V) — decode path only (S=1)."""
    return jnp.einsum(
        "bsd,vd->bsv", x, params["table"], preferred_element_type=jnp.float32
    )


def chunked_softmax_xent(
    table: jnp.ndarray,  # (V, D) embedding/unembedding weights
    x: jnp.ndarray,  # (B, S, D) final hidden states
    labels: jnp.ndarray,  # (B, S) int32; negative labels are masked out
    num_chunks: int = 8,
    z_loss: float = 0.0,
) -> jnp.ndarray:
    """Mean token cross-entropy over unmasked positions, scanned over S chunks."""
    from repro.models.model_utils import grad_dtype_guard

    x = grad_dtype_guard(x)
    b, s, d = x.shape
    if s % num_chunks != 0:
        num_chunks = 1
    chunk = s // num_chunks
    xc = x.reshape(b, num_chunks, chunk, d).swapaxes(0, 1)  # (C, B, chunk, D)
    lc = labels.reshape(b, num_chunks, chunk).swapaxes(0, 1)

    def one_chunk(carry, inp):
        tot, cnt = carry
        xx, ll = inp  # (B, chunk, D), (B, chunk)
        mask = (ll >= 0).astype(jnp.float32)
        safe = jnp.maximum(ll, 0)
        logits = jnp.einsum(
            "bsd,vd->bsv", xx, table, preferred_element_type=jnp.float32
        )
        lse = jax.nn.logsumexp(logits, axis=-1)  # (B, chunk)
        picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        loss = ((lse - picked) * mask).sum()
        if z_loss > 0.0:
            loss = loss + z_loss * (jnp.square(lse) * mask).sum()
        return (tot + loss, cnt + mask.sum()), None

    (total, count), _ = jax.lax.scan(
        one_chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xc, lc)
    )
    return total / jnp.maximum(count, 1.0)
