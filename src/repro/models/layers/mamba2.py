"""Mamba-2 (SSD, state-space duality) mixer layer [arXiv:2405.21060].

Selective state space with scalar-per-head decay:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T        (state: (H, N, P))
    y_t = C_t h_t + D * x_t

Training uses the chunked SSD formulation (quadratic within chunks of length
Q, linear state passing across chunks) — the same blocking the Pallas
``ssd_scan`` kernel implements on TPU (MXU-aligned Q).  Decode is the O(1)
single-step recurrence.  ``ssd_reference`` (exact sequential scan) is the
oracle used by tests.

Because dt*A <= 0, all decay products are computed in log space directly as
segment sums of da = dt*A (no log() calls needed) — numerically exact.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers.norms import rmsnorm

__all__ = [
    "MambaDims",
    "mamba_init",
    "mamba_apply",
    "mamba_decode",
    "init_mamba_cache",
    "ssd_chunked",
    "ssd_reference",
]


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_state: int  # N
    num_heads: int  # H
    head_dim: int  # P  (d_inner = H * P)
    num_groups: int = 1  # G (B/C shared per group)
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.num_groups * self.d_state


def mamba_init(key, dims: MambaDims, dtype=jnp.bfloat16) -> dict:
    k_in, k_conv, k_dt, k_out = jax.random.split(key, 4)
    d = dims.d_model
    proj_out = dims.d_inner + dims.conv_channels + dims.num_heads  # z, conv-in, dt
    dt = jnp.exp(
        jax.random.uniform(k_dt, (dims.num_heads,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    return {
        "in_proj": (jax.random.normal(k_in, (d, proj_out), jnp.float32) * d**-0.5).astype(dtype),
        "conv_w": (jax.random.normal(k_conv, (dims.conv_kernel, dims.conv_channels), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dims.conv_channels,), dtype),
        "a_log": jnp.log(jnp.arange(1, dims.num_heads + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((dims.num_heads,), jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "norm_scale": jnp.ones((dims.d_inner,), dtype),
        "out_proj": (jax.random.normal(k_out, (dims.d_inner, d), jnp.float32) * dims.d_inner**-0.5).astype(dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _split_proj(params, x, dims: MambaDims):
    proj = jnp.einsum("bld,dp->blp", x, params["in_proj"])
    z, conv_in, dt_raw = jnp.split(
        proj, [dims.d_inner, dims.d_inner + dims.conv_channels], axis=-1
    )
    return z, conv_in, dt_raw


def _split_conv_out(conv_out, dims: MambaDims):
    xs, bs, cs = jnp.split(
        conv_out,
        [dims.d_inner, dims.d_inner + dims.num_groups * dims.d_state],
        axis=-1,
    )
    b, l = conv_out.shape[:2]
    xs = xs.reshape(b, l, dims.num_heads, dims.head_dim)
    bs = bs.reshape(b, l, dims.num_groups, dims.d_state)
    cs = cs.reshape(b, l, dims.num_groups, dims.d_state)
    return xs, bs, cs


def ssd_chunked(
    xs: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)  post-softplus, fp32
    a: jnp.ndarray,  # (H,) negative decay rates, fp32
    bs: jnp.ndarray,  # (B, L, G, N)
    cs: jnp.ndarray,  # (B, L, G, N)
    chunk: int,
    h0: jnp.ndarray | None = None,  # (B, H, N, P) initial state
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD; returns (y (B,L,H,P), final_state (B,H,N,P))."""
    b, l, h, p = xs.shape
    g, n = bs.shape[2], bs.shape[3]
    pad = (-l) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bs = jnp.pad(bs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cs = jnp.pad(cs, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc, q = lp // chunk, chunk
    rep = h // g  # heads per group

    xs = xs.reshape(b, nc, q, h, p).astype(jnp.float32)
    dt = dt.reshape(b, nc, q, h)
    bs = jnp.repeat(bs.reshape(b, nc, q, g, n), rep, axis=3).astype(jnp.float32)  # (B,NC,Q,H,N)
    cs = jnp.repeat(cs.reshape(b, nc, q, g, n), rep, axis=3).astype(jnp.float32)

    da = dt * a[None, None, None, :]  # (B,NC,Q,H) log-decay increments (<=0)
    cum = jnp.cumsum(da, axis=2)  # inclusive within chunk

    # intra-chunk (quadratic): att[i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, j<=i
    scores = jnp.einsum("bcihn,bcjhn->bchij", cs, bs)
    cum_t = cum.transpose(0, 1, 3, 2)  # (B,NC,H,Q)
    decay = jnp.exp(cum_t[..., :, None] - cum_t[..., None, :])  # (B,NC,H,Qi,Qj)
    mask = jnp.tril(jnp.ones((q, q), bool))
    att = jnp.where(mask[None, None, None], scores * decay, 0.0)
    att = att * dt.transpose(0, 1, 3, 2)[:, :, :, None, :]  # multiply dt_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", att, xs)

    # chunk summary states: S_k = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dt  # (B,NC,Q,H)
    s_k = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", tail, bs, xs)

    # inter-chunk recurrence over NC (sequential scan)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,NC,H)
    h_init = (
        jnp.zeros((b, h, n, p), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def scan_fn(h_prev, inp):
        cd, sk = inp  # (B,H), (B,H,N,P)
        h_new = cd[..., None, None] * h_prev + sk
        return h_new, h_prev  # emit state ENTERING this chunk

    h_last, h_enter = jax.lax.scan(
        scan_fn,
        h_init,
        (chunk_decay.swapaxes(0, 1), s_k.swapaxes(0, 1)),
    )
    h_enter = h_enter.swapaxes(0, 1)  # (B,NC,H,N,P)

    # inter-chunk contribution: y_i += C_i exp(cum_i) H_enter
    y_inter = jnp.einsum(
        "bcihn,bcih,bchnp->bcihp", cs, jnp.exp(cum), h_enter
    )
    y = (y_intra + y_inter).reshape(b, lp, h, p)[:, :l]
    return y, h_last


def ssd_reference(xs, dt, a, bs, cs, h0=None):
    """Exact sequential recurrence (oracle).  Same signature minus chunk."""
    b, l, h, p = xs.shape
    g, n = bs.shape[2], bs.shape[3]
    rep = h // g
    bs = jnp.repeat(bs, rep, axis=2).astype(jnp.float32)
    cs = jnp.repeat(cs, rep, axis=2).astype(jnp.float32)
    xs = xs.astype(jnp.float32)
    h_state = jnp.zeros((b, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h_prev, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dt_t * a[None])  # (B,H)
        # contrib[b,h,n,p] = dt[b,h] * B[b,h,n] * x[b,h,p]
        contrib = dt_t[..., None, None] * b_t[..., None] * x_t[:, :, None, :]
        h_new = decay[..., None, None] * h_prev + contrib
        y_t = jnp.einsum("bhn,bhnp->bhp", c_t, h_new)
        return h_new, y_t

    inputs = (
        xs.swapaxes(0, 1),
        dt.swapaxes(0, 1),
        bs.swapaxes(0, 1),
        cs.swapaxes(0, 1),
    )
    h_last, ys = jax.lax.scan(step, h_state, inputs)
    return ys.swapaxes(0, 1), h_last


def mamba_apply(
    params: dict, x: jnp.ndarray, dims: MambaDims, use_kernel: bool = False
) -> jnp.ndarray:
    """Full-sequence mamba2 block: (B, L, D) -> (B, L, D)."""
    from repro.models.layers.attention import _maybe_constrain

    z, conv_in, dt_raw = _split_proj(params, x, dims)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"], params["conv_b"]))
    xs, bs, cs = _split_conv_out(conv_out, dims)
    # pin the SSD layout: batch over 'data', heads over 'model' (the grouped
    # B/C tensors have G=1 group dims that cannot shard, which otherwise
    # makes XLA replicate the whole batch — §Perf iteration E)
    xs = _maybe_constrain(xs, ("data", None, "model", None))
    bs = _maybe_constrain(bs, ("data", None, None, None))
    cs = _maybe_constrain(cs, ("data", None, None, None))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    dt = _maybe_constrain(dt, ("data", None, "model"))
    a = -jnp.exp(params["a_log"])
    if use_kernel:
        from repro.kernels import ssd_ops

        y, _ = ssd_ops.ssd(xs, dt, a, bs, cs, chunk=dims.chunk)
    else:
        y, _ = ssd_chunked(xs, dt, a, bs, cs, dims.chunk)
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(x.shape[0], x.shape[1], dims.d_inner).astype(x.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    return jnp.einsum("bli,id->bld", y, params["out_proj"])


# ---------------------------------------------------------------------------
# Decode path: O(1) recurrent step with (conv, ssm) cache
# ---------------------------------------------------------------------------


def init_mamba_cache(batch: int, dims: MambaDims, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": jnp.zeros((batch, dims.conv_kernel - 1, dims.conv_channels), dtype),
        "ssm": jnp.zeros((batch, dims.num_heads, dims.d_state, dims.head_dim), jnp.float32),
    }


def mamba_decode(
    params: dict, x: jnp.ndarray, cache: dict, dims: MambaDims
) -> tuple[jnp.ndarray, dict]:
    """One-token step. x: (B, 1, D) -> (B, 1, D), updated cache."""
    z, conv_in, dt_raw = _split_proj(params, x, dims)  # (B,1,*)
    window = jnp.concatenate([cache["conv"], conv_in.astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"]
    conv_out = (window * w[None]).sum(axis=1, keepdims=True) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xs, bs, cs = _split_conv_out(conv_out, dims)  # (B,1,H,P), (B,1,G,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"])
    rep = dims.num_heads // dims.num_groups
    b_t = jnp.repeat(bs[:, 0], rep, axis=1).astype(jnp.float32)  # (B,H,N)
    c_t = jnp.repeat(cs[:, 0], rep, axis=1).astype(jnp.float32)
    x_t = xs[:, 0].astype(jnp.float32)  # (B,H,P)

    decay = jnp.exp(dt * a[None])  # (B,H)
    h_new = (
        decay[..., None, None] * cache["ssm"]
        + dt[..., None, None] * b_t[..., None] * x_t[..., None, :]
    )
    y = jnp.einsum("bhn,bhnp->bhp", c_t, h_new)
    y = y + params["d_skip"][None, :, None] * x_t
    y = y.reshape(x.shape[0], 1, dims.d_inner).astype(x.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    out = jnp.einsum("bli,id->bld", y, params["out_proj"])
    return out, {"conv": window[:, 1:], "ssm": h_new}
