"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["swiglu_init", "swiglu", "gelu_mlp_init", "gelu_mlp"]


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d_model**-0.5, d_ff**-0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out).astype(dtype),
    }


def swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    hidden = jax.nn.silu(gate) * up
    return jnp.einsum("bsf,fd->bsd", hidden, params["w_down"])


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": (jax.random.normal(k1, (d_model, d_ff), jnp.float32) * d_model**-0.5).astype(dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d_model), jnp.float32) * d_ff**-0.5).astype(dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"]) + params["b_out"]
