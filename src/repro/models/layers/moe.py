"""Mixture-of-Experts layer: token-choice top-k routing with fixed expert
capacity, scatter/gather dispatch (TPU-friendly; no (S,E,C) one-hot einsum),
optional shared experts (DeepSeekMoE), Switch-style load-balance aux loss.

Expert weights carry a leading E axis sharded over the 'model' mesh axis
(expert parallelism); the scatter/gather dispatch lowers to all-to-all-style
collectives under pjit.

Router math is fp32 (production convention: routing decisions are precision
sensitive).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers.attention import _maybe_constrain

__all__ = ["MoEDims", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    num_experts: int
    experts_per_token: int
    d_expert: int  # per-expert FFN hidden dim
    num_shared_experts: int = 0
    capacity_factor: float = 1.25


def moe_init(key, dims: MoEDims, dtype=jnp.bfloat16) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    d, e, f = dims.d_model, dims.num_experts, dims.d_expert
    s_in, s_out = d**-0.5, f**-0.5
    params = {
        "router": (jax.random.normal(kr, (d, e), jnp.float32) * s_in),  # fp32
        "w_gate": (jax.random.normal(kg, (e, d, f), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (e, d, f), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (e, f, d), jnp.float32) * s_out).astype(dtype),
    }
    if dims.num_shared_experts > 0:
        fs = dims.num_shared_experts * f
        k1, k2, k3 = jax.random.split(ks, 3)
        params["shared"] = {
            "w_gate": (jax.random.normal(k1, (d, fs), jnp.float32) * s_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (d, fs), jnp.float32) * s_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (fs, d), jnp.float32) * fs**-0.5).astype(dtype),
        }
    return params


def _capacity(seq_tokens: int, dims: MoEDims) -> int:
    c = int(dims.capacity_factor * seq_tokens * dims.experts_per_token / dims.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_apply(params: dict, x: jnp.ndarray, dims: MoEDims) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (B, S, D), aux dict with load-balance loss + stats."""
    b, s, d = x.shape
    e, k = dims.num_experts, dims.experts_per_token
    cap = _capacity(s, dims)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (B,S,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renorm top-k

    # position of each (token, choice) within its expert's capacity buffer
    flat_idx = idx.reshape(b, s * k)  # choices in scan order
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # (B, SK, E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot  # exclusive count
    pos = jnp.take_along_axis(pos_in_expert, flat_idx[..., None], axis=-1)[..., 0]
    keep = pos < cap  # (B, SK) overflow dropped

    # scatter tokens into (E, cap, D) expert buffers, per batch row
    x_rep = jnp.repeat(x, k, axis=1)  # (B, SK, D) token repeated per choice

    def dispatch_row(xr, er, pr, kr):
        buf = jnp.zeros((e, cap, d), xr.dtype)
        safe_pos = jnp.where(kr, pr, cap - 1)
        contrib = jnp.where(kr[:, None], xr, 0.0)
        return buf.at[er, safe_pos].add(contrib, mode="drop")

    expert_in = jax.vmap(dispatch_row)(x_rep, flat_idx, pos, keep)  # (B,E,C,D)
    # expert-parallel layout: batch over 'data', experts over 'model' — the
    # dispatch boundary then lowers to all-to-all-style exchanges instead of
    # dense cross-device all-reduces (§Perf iteration B).  Decode-size
    # capacities (cap ~ 8 for s=1) are NOT constrained: forcing the layout
    # on tiny buffers measured as a pure collective regression.
    constrain_ep = cap >= 64
    if constrain_ep:
        expert_in = _maybe_constrain(expert_in, ("data", "model", None, None))

    # expert FFN (SwiGLU) — batched over (B, E)
    h_gate = jnp.einsum("becd,edf->becf", expert_in, params["w_gate"])
    h_up = jnp.einsum("becd,edf->becf", expert_in, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    expert_out = jnp.einsum("becf,efd->becd", h, params["w_down"])
    if constrain_ep:
        expert_out = _maybe_constrain(expert_out, ("data", "model", None, None))

    # gather back: out[token] = sum_k gate_k * expert_out[e_k, pos_k]
    def combine_row(eo, er, pr, kr, gr):
        vals = eo[er, jnp.where(kr, pr, cap - 1)]  # (SK, D)
        vals = jnp.where(kr[:, None], vals, 0.0)
        return (vals.reshape(s, k, d) * gr[..., None].astype(vals.dtype)).sum(axis=1)

    out = jax.vmap(combine_row)(expert_out, flat_idx, pos, keep, gates)  # (B,S,D)

    if dims.num_shared_experts > 0:
        sh = params["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, sh["w_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, sh["w_down"])

    # Switch-style load balance: E * sum_e f_e * p_e
    f_e = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=(1, 2)) / (s * k)  # (B,E)
    p_e = probs.mean(axis=1)  # (B,E)
    aux_loss = e * jnp.mean(jnp.sum(f_e * p_e, axis=-1))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {
        "moe_aux_loss": aux_loss,
        "moe_dropped_frac": dropped,
        "moe_expert_load": f_e.mean(axis=0),
    }
    return out.astype(x.dtype), aux
