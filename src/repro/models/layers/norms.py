"""Normalization layers (pure functions + init)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm"]


def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm in fp32 math, cast back to input dtype (production convention)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * (var + eps) ** -0.5
    out = normed * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * (var + eps) ** -0.5
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)
