"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_frequencies", "apply_rope"]


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies (head_dim/2,) in fp32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(
    x: jnp.ndarray,  # (..., seq, heads, head_dim)
    positions: jnp.ndarray,  # (..., seq) int32
    theta: float = 10000.0,
) -> jnp.ndarray:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]) — llama 'half' convention."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
