"""Mamba-2 attention-free LM (mamba2-370m [arXiv:2405.21060]).

Stack of (rmsnorm -> mamba2 mixer -> residual); no separate FFN (mamba2
follows the mamba convention of mixer-only blocks).  Decode carries
(conv, ssm-state) caches — O(1) per token, so long_500k is native.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import Model
from repro.models.layers import embedding as emb_mod
from repro.models.layers import mamba2 as mamba_mod
from repro.models.layers.norms import rmsnorm, rmsnorm_init
from repro.models.model_utils import scan_layers, scan_layers_cache, stacked_init

__all__ = ["build_mamba_model", "mamba_dims_from_cfg"]


def mamba_dims_from_cfg(cfg: ArchConfig) -> mamba_mod.MambaDims:
    return mamba_mod.MambaDims(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        num_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_head_dim,
        num_groups=cfg.ssm_groups,
        conv_kernel=cfg.conv_kernel,
        chunk=cfg.ssd_chunk,
    )


def build_mamba_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    mdims = mamba_dims_from_cfg(cfg)

    def layer_init(key):
        return {"ln": rmsnorm_init(cfg.d_model), "mixer": mamba_mod.mamba_init(key, mdims, dtype)}

    def init(key):
        k_emb, k_layers = jax.random.split(key)
        return {
            "embedding": emb_mod.embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "layers": stacked_init(layer_init, k_layers, cfg.num_layers),
            "ln_f": rmsnorm_init(cfg.d_model),
        }

    def body(lp, x):
        return x + mamba_mod.mamba_apply(lp["mixer"], rmsnorm(lp["ln"], x, cfg.norm_eps), mdims, use_kernel=cfg.use_kernels)

    def _trunk(params, batch):
        x = emb_mod.embed(params["embedding"], batch["tokens"])
        x = scan_layers(body, params["layers"], x, remat=cfg.remat)
        return rmsnorm(params["ln_f"], x, cfg.norm_eps)

    def apply(params, batch):
        return _trunk(params, batch)

    def loss(params, batch):
        x = _trunk(params, batch)
        ce = emb_mod.chunked_softmax_xent(
            params["embedding"]["table"], x, batch["labels"], cfg.loss_chunks
        )
        return ce, {"xent": ce}

    def init_cache(batch_size: int, cache_len: int):
        del cache_len  # SSM state is O(1) in sequence length
        one = mamba_mod.init_mamba_cache(batch_size, mdims, dtype)
        return {
            "layers": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one
            )
        }

    def decode_body(lp, x, cache, pos):
        del pos
        h, new_cache = mamba_mod.mamba_decode(
            lp["mixer"], rmsnorm(lp["ln"], x, cfg.norm_eps), cache, mdims
        )
        return x + h, new_cache

    def decode_step(params, tokens, cache, pos):
        x = emb_mod.embed(params["embedding"], tokens)
        x, new_cache = scan_layers_cache(
            decode_body, params["layers"], cache["layers"], x, pos
        )
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = emb_mod.unembed_logits(params["embedding"], x)[:, 0]
        return logits, {"layers": new_cache}

    def input_specs(shape, for_decode: bool = False):
        b, s = shape.global_batch, shape.seq_len
        if for_decode:
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }

    return Model(
        name=cfg.name,
        init=init,
        loss=loss,
        apply=apply,
        input_specs=input_specs,
        init_cache=init_cache,
        decode_step=decode_step,
    )
