"""Shared model-assembly machinery: stacked layer init, scan-over-layers with
remat, decode-cache threading, and the Model bundle builder.

``unrolled_layers()`` switches every layer scan to a full unroll.  XLA's
cost_analysis counts a ``while`` body ONCE regardless of trip count, so the
roofline capture (launch/dryrun.py --unroll) lowers with unrolled layers to
get per-step FLOPs / bytes / collective totals that include every layer;
normal training/serving keeps the rolled scan (compile-time, code size).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "stacked_init",
    "scan_layers",
    "scan_layers_aux",
    "scan_layers_cache",
    "remat_wrap",
    "layer_scan",
    "unrolled_layers",
]

_SCAN_UNROLL: int | bool = 1


@contextlib.contextmanager
def unrolled_layers(enable: bool = True):
    """Context: fully unroll all layer scans (roofline capture mode)."""
    global _SCAN_UNROLL
    prev = _SCAN_UNROLL
    _SCAN_UNROLL = True if enable else 1
    try:
        yield
    finally:
        _SCAN_UNROLL = prev


def layer_scan(step: Callable, init, xs):
    """lax.scan over stacked layer params honoring the unroll context."""
    return jax.lax.scan(step, init, xs, unroll=_SCAN_UNROLL)


@functools.lru_cache(maxsize=None)
def _make_dtype_guard(dtype_name: str):
    @jax.custom_vjp
    def guard(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, g):
        return (g.astype(dtype_name),)

    guard.defvjp(fwd, bwd)
    return guard


def grad_dtype_guard(x):
    """Identity whose COTANGENT is cast back to the primal dtype.

    f32-preferring einsums (attention scores, vocab logits) emit f32
    cotangents; without a guard at each layer/loss boundary the f32
    cotangent rides the whole backward residual stream — measured as 48%
    of deepseek-67b train HBM bytes (EXPERIMENTS.md §Perf F).  Casting the
    activation gradient to the activation dtype is the standard
    mixed-precision convention (parameter grads stay untouched)."""
    return _make_dtype_guard(jnp.dtype(x.dtype).name)(x)


def stacked_init(layer_init: Callable, key, n: int):
    """vmap a per-layer init over n split keys -> params with leading (n,) axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(layer_init)(keys)


def remat_wrap(fn: Callable, mode: str) -> Callable:
    if mode == "full":
        return jax.checkpoint(fn)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if mode == "none":
        return fn
    raise ValueError(f"unknown remat mode {mode!r}")


def scan_layers(body: Callable, stacked_params, x, remat: str = "full"):
    """x -> body(layer_params, x) repeated over the stacked leading axis."""
    fn = remat_wrap(body, remat)

    def step(carry, lp):
        return fn(lp, grad_dtype_guard(carry)), None

    out, _ = layer_scan(step, x, stacked_params)
    return out


def scan_layers_aux(body: Callable, stacked_params, x, remat: str = "full"):
    """Like scan_layers but body returns (x, aux_scalar); returns (x, mean_aux)."""
    fn = remat_wrap(body, remat)

    def step(carry, lp):
        new_x, aux = fn(lp, grad_dtype_guard(carry))
        return new_x, aux

    out, auxs = layer_scan(step, x, stacked_params)
    return out, jax.tree_util.tree_map(jnp.mean, auxs)


def scan_layers_cache(body: Callable, stacked_params, stacked_cache, x, pos):
    """Decode: thread (x, per-layer cache) through stacked layers."""

    def step(carry, inputs):
        lp, cache = inputs
        y, new_cache = body(lp, carry, cache, pos)
        return y, new_cache

    out, new_caches = layer_scan(step, x, (stacked_params, stacked_cache))
    return out, new_caches
