"""MoE decoder-only transformer (DeepSeekMoE-16B, OLMoE-1B-7B).

DeepSeekMoE structure [arXiv:2401.06066]: fine-grained experts (64 routed,
top-6) + 2 shared experts, first layer dense (d_ff 10944).  OLMoE
[arXiv:2409.02060]: 64 routed top-8, no shared experts, all layers MoE.

Leading dense layers are unrolled outside the scan (different treedef);
the homogeneous MoE stack is scanned with stacked params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import Model
from repro.models.layers import attention as attn_mod
from repro.models.layers import embedding as emb_mod
from repro.models.layers import mlp as mlp_mod
from repro.models.layers import moe as moe_mod
from repro.models.layers.norms import rmsnorm, rmsnorm_init
from repro.models.model_utils import remat_wrap, scan_layers_cache, stacked_init, layer_scan
from repro.models.transformer import _decode_body, _dims

__all__ = ["build_moe_model"]


def _moe_dims(cfg: ArchConfig) -> moe_mod.MoEDims:
    return moe_mod.MoEDims(
        d_model=cfg.d_model,
        num_experts=cfg.num_experts,
        experts_per_token=cfg.experts_per_token,
        d_expert=cfg.moe_d_ff,
        num_shared_experts=cfg.num_shared_experts,
        capacity_factor=cfg.capacity_factor,
    )


def _moe_layer_init(cfg: ArchConfig, dtype):
    dims = _dims(cfg)
    mdims = _moe_dims(cfg)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": rmsnorm_init(cfg.d_model),
            "attn": attn_mod.attn_init(k1, dims, dtype),
            "ln2": rmsnorm_init(cfg.d_model),
            "moe": moe_mod.moe_init(k2, mdims, dtype),
        }

    return init


def _dense_layer_init(cfg: ArchConfig, dtype):
    dims = _dims(cfg)
    d_ff = cfg.dense_d_ff or cfg.d_ff

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": rmsnorm_init(cfg.d_model),
            "attn": attn_mod.attn_init(k1, dims, dtype),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_mod.swiglu_init(k2, cfg.d_model, d_ff, dtype),
        }

    return init


def build_moe_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    dims = _dims(cfg)
    mdims = _moe_dims(cfg)
    n_dense = cfg.first_dense_layers
    n_moe = cfg.num_layers - n_dense

    def init(key):
        k_emb, k_dense, k_moe = jax.random.split(key, 3)
        params = {
            "embedding": emb_mod.embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "moe_layers": stacked_init(_moe_layer_init(cfg, dtype), k_moe, n_moe),
            "ln_f": rmsnorm_init(cfg.d_model),
        }
        if n_dense:
            params["dense_layers"] = [
                _dense_layer_init(cfg, dtype)(k)
                for k in jax.random.split(k_dense, n_dense)
            ]
        return params

    def _moe_body(lp, x):
        h = attn_mod.attention_full(
            lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), dims,
            mode="causal", window=cfg.sliding_window,
        )
        x = x + h
        h, aux = moe_mod.moe_apply(lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps), mdims)
        return x + h, aux["moe_aux_loss"]

    def _dense_body(lp, x):
        h = attn_mod.attention_full(
            lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), dims,
            mode="causal", window=cfg.sliding_window,
        )
        x = x + h
        h = mlp_mod.swiglu(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + h

    def _trunk(params, batch):
        x = emb_mod.embed(params["embedding"], batch["tokens"])
        dense_fn = remat_wrap(_dense_body, cfg.remat)
        for lp in params.get("dense_layers", []):
            x = dense_fn(lp, x)
        moe_fn = remat_wrap(_moe_body, cfg.remat)

        def step(carry, lp):
            new_x, aux = moe_fn(lp, carry)
            return new_x, aux

        x, auxs = layer_scan(step, x, params["moe_layers"])
        return rmsnorm(params["ln_f"], x, cfg.norm_eps), jnp.mean(auxs)

    def apply(params, batch):
        return _trunk(params, batch)[0]

    def loss(params, batch):
        x, aux_loss = _trunk(params, batch)
        ce = emb_mod.chunked_softmax_xent(
            params["embedding"]["table"], x, batch["labels"], cfg.loss_chunks
        )
        total = ce + 0.01 * aux_loss
        return total, {"xent": ce, "moe_aux": aux_loss}

    # ---- decode ----
    def _moe_decode_body(lp, x, cache, pos):
        h, new_cache = attn_mod.attention_decode(
            lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), cache, pos, dims
        )
        x = x + h
        h, _ = moe_mod.moe_apply(lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps), mdims)
        return x + h, new_cache

    dense_decode = _decode_body(cfg)

    def init_cache(batch_size: int, cache_len: int):
        window = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        def one():
            return attn_mod.init_kv_cache(
                batch_size, window, cfg.num_kv_heads, cfg.resolved_head_dim,
                dtype,
            )

        cache = {
            "moe_layers": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n_moe,) + x.shape), one()
            )
        }
        if n_dense:
            cache["dense_layers"] = [one() for _ in range(n_dense)]
        return cache

    def decode_step(params, tokens, cache, pos):
        x = emb_mod.embed(params["embedding"], tokens)
        new_cache = {}
        if n_dense:
            dl = []
            for lp, c in zip(params["dense_layers"], cache["dense_layers"]):
                x, nc = dense_decode(lp, x, c, pos)
                dl.append(nc)
            new_cache["dense_layers"] = dl
        x, nmc = scan_layers_cache(
            _moe_decode_body, params["moe_layers"], cache["moe_layers"], x, pos
        )
        new_cache["moe_layers"] = nmc
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = emb_mod.unembed_logits(params["embedding"], x)[:, 0]
        return logits, new_cache

    def input_specs(shape, for_decode: bool = False):
        b, s = shape.global_batch, shape.seq_len
        if for_decode:
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }

    return Model(
        name=cfg.name,
        init=init,
        loss=loss,
        apply=apply,
        input_specs=input_specs,
        init_cache=init_cache,
        decode_step=decode_step,
    )
