"""The paper's models: linear least squares (Eq. 17-18) and logistic regression.

Losses follow the paper exactly:
  linear:   f_v(x) = (y_v - x^T A_v)^2        L_v = 2 ||A_v||^2
  logistic: f_v(x) = -[y_v x^T A_v - log(1 + exp(x^T A_v))]   L_v = ||A_v||^2/4
(the paper writes the logistic *log-likelihood*; we minimize its negative)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "linear_loss",
    "linear_grad",
    "logistic_loss",
    "logistic_grad",
    "mse_objective",
]


def linear_loss(x: jnp.ndarray, feature: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    resid = target - feature @ x
    return resid**2


linear_grad = jax.grad(linear_loss)


def logistic_loss(x: jnp.ndarray, feature: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    z = feature @ x
    # -(y z - log(1+e^z)) = log(1+e^z) - y z, numerically stable via softplus
    return jax.nn.softplus(z) - target * z


logistic_grad = jax.grad(logistic_loss)


def mse_objective(x: jnp.ndarray, features: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Paper's reported metric: sum_v (y_v - A_v x)^2 / |V|."""
    resid = targets - features @ x
    return jnp.mean(resid**2)
