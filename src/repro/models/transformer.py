"""Dense decoder-only transformer LM (llama/qwen/gemma families) plus the
PaliGemma prefix-LM variant (vlm): stub patch embeddings occupy the first
``num_prefix_tokens`` positions and the mask is bidirectional over the prefix.

Covers assigned archs: deepseek-7b, deepseek-67b, minitron-8b, qwen2.5-32b
(qkv_bias=True), paligemma-3b (is_prefix_lm).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import Model
from repro.models.layers import attention as attn_mod
from repro.models.layers import embedding as emb_mod
from repro.models.layers import mlp as mlp_mod
from repro.models.layers.norms import rmsnorm, rmsnorm_init
from repro.models.model_utils import scan_layers, scan_layers_cache, stacked_init

__all__ = ["build_dense_model"]


def _dims(cfg: ArchConfig) -> attn_mod.AttnDims:
    return attn_mod.AttnDims(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope,
        repeat_kv=cfg.gqa_repeat_kv,
    )


def _layer_init(cfg: ArchConfig, dtype):
    dims = _dims(cfg)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": rmsnorm_init(cfg.d_model),
            "attn": attn_mod.attn_init(k1, dims, dtype),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_mod.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    return init


def _layer_body(cfg: ArchConfig, mode: str, window: int, prefix_len: int):
    dims = _dims(cfg)

    def body(lp, x):
        h = attn_mod.attention_full(
            lp["attn"],
            rmsnorm(lp["ln1"], x, cfg.norm_eps),
            dims,
            mode=mode,
            window=window,
            prefix_len=prefix_len,
            use_flash=cfg.use_kernels,
        )
        x = x + h
        h = mlp_mod.swiglu(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + h

    return body


def _decode_body(cfg: ArchConfig):
    dims = _dims(cfg)

    def body(lp, x, cache, pos):
        h, new_cache = attn_mod.attention_decode(
            lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), cache, pos, dims
        )
        x = x + h
        h = mlp_mod.swiglu(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + h, new_cache

    return body


def build_dense_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    mask_mode = "prefix" if cfg.is_prefix_lm else "causal"
    prefix_len = cfg.num_prefix_tokens if cfg.is_prefix_lm else 0

    def init(key):
        k_emb, k_layers = jax.random.split(key)
        return {
            "embedding": emb_mod.embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "layers": stacked_init(_layer_init(cfg, dtype), k_layers, cfg.num_layers),
            "ln_f": rmsnorm_init(cfg.d_model),
        }

    def _trunk(params, batch, window: int):
        x = emb_mod.embed(params["embedding"], batch["tokens"])
        if cfg.is_prefix_lm:
            prefix = batch["prefix_embeddings"].astype(x.dtype)
            x = jax.lax.dynamic_update_slice(x, prefix, (0, 0, 0))
        x = scan_layers(
            _layer_body(cfg, mask_mode, window, prefix_len),
            params["layers"],
            x,
            remat=cfg.remat,
        )
        return rmsnorm(params["ln_f"], x, cfg.norm_eps)

    def apply(params, batch):
        return _trunk(params, batch, cfg.sliding_window)

    def loss(params, batch):
        x = _trunk(params, batch, cfg.sliding_window)
        ce = emb_mod.chunked_softmax_xent(
            params["embedding"]["table"], x, batch["labels"], cfg.loss_chunks
        )
        return ce, {"xent": ce}

    def init_cache(batch_size: int, cache_len: int):
        window = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        def one():
            return attn_mod.init_kv_cache(
                batch_size, window, cfg.num_kv_heads, cfg.resolved_head_dim,
                dtype,
            )

        return {
            "layers": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape),
                one(),
            )
        }

    def decode_step(params, tokens, cache, pos):
        x = emb_mod.embed(params["embedding"], tokens)  # (B,1,D)
        x, new_layer_cache = scan_layers_cache(
            _decode_body(cfg), params["layers"], cache["layers"], x, pos
        )
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = emb_mod.unembed_logits(params["embedding"], x)[:, 0]
        return logits, {"layers": new_layer_cache}

    def input_specs(shape, for_decode: bool = False):
        b, s = shape.global_batch, shape.seq_len
        if for_decode:
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        else:
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        if cfg.is_prefix_lm and not for_decode:
            specs["prefix_embeddings"] = jax.ShapeDtypeStruct(
                (b, cfg.num_prefix_tokens, cfg.d_model), dtype
            )
        return specs

    return Model(
        name=cfg.name,
        init=init,
        loss=loss,
        apply=apply,
        input_specs=input_specs,
        init_cache=init_cache,
        decode_step=decode_step,
    )
