from repro.optim.base import GradientTransformation, OptState, chain, identity
from repro.optim.sgd import sgd, momentum
from repro.optim.adam import adam, adamw
from repro.optim.adafactor import adafactor
from repro.optim.transforms import clip_by_global_norm, add_weight_decay, scale, scale_by_schedule
from repro.optim.schedules import constant_lr, cosine_decay, warmup_cosine, inverse_sqrt

__all__ = [
    "GradientTransformation", "OptState", "chain", "identity",
    "sgd", "momentum", "adam", "adamw", "adafactor",
    "clip_by_global_norm", "add_weight_decay", "scale", "scale_by_schedule",
    "constant_lr", "cosine_decay", "warmup_cosine", "inverse_sqrt",
]
