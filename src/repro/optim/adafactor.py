"""Adafactor (factored second moments) — the memory-lean optimizer used for
the largest assigned config (jamba-1.5-large-398b) where full Adam state does
not fit the per-chip HBM budget; see EXPERIMENTS.md §Dry-run."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation
from repro.optim.sgd import ScalarOrSchedule, _lr_at


class AdafactorState(NamedTuple):
    count: jnp.ndarray
    row: object  # factored second moment (rows) or None-like zeros for <2D
    col: object
    full: object  # unfactored second moment for <2D params


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor(
    learning_rate: ScalarOrSchedule,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> GradientTransformation:
    def init(params):
        def row_init(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape) else jnp.zeros((), jnp.float32)

        def col_init(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p.shape) else jnp.zeros((), jnp.float32))

        def full_init(p):
            return jnp.zeros(p.shape, jnp.float32) if not _factored(p.shape) else jnp.zeros((), jnp.float32)

        return AdafactorState(
            count=jnp.zeros((), jnp.int32),
            row=jax.tree_util.tree_map(row_init, params),
            col=jax.tree_util.tree_map(col_init, params),
            full=jax.tree_util.tree_map(full_init, params),
        )

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        beta = 1.0 - count.astype(jnp.float32) ** (-decay)
        lr = _lr_at(learning_rate, state.count)

        def upd(g, r, c, f):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                new_r = beta * r + (1 - beta) * g2.mean(axis=-1)
                new_c = beta * c + (1 - beta) * g2.mean(axis=-2)
                r_factor = new_r / jnp.maximum(new_r.mean(axis=-1, keepdims=True), eps)
                v = r_factor[..., None] * new_c[..., None, :]
                new_f = f
            else:
                new_f = beta * f + (1 - beta) * g2
                v = new_f
                new_r, new_c = r, c
            u = g / jnp.sqrt(jnp.maximum(v, eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * u, new_r, new_c, new_f

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = treedef.flatten_up_to(state.row)
        flat_c = treedef.flatten_up_to(state.col)
        flat_f = treedef.flatten_up_to(state.full)
        outs = [upd(g, r, c, f) for g, r, c, f in zip(flat_g, flat_r, flat_c, flat_f)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_row = treedef.unflatten([o[1] for o in outs])
        new_col = treedef.unflatten([o[2] for o in outs])
        new_full = treedef.unflatten([o[3] for o in outs])
        return updates, AdafactorState(count=count, row=new_row, col=new_col, full=new_full)

    return GradientTransformation(init, update)
