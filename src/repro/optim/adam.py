"""Adam / AdamW."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation
from repro.optim.sgd import ScalarOrSchedule, _lr_at


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: object
    nu: object


def adam(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    mu_dtype: jnp.dtype = jnp.float32,
) -> GradientTransformation:
    def init(params):
        mu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=mu_dtype), params)
        nu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(count=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        lr = _lr_at(learning_rate, state.count)
        mu = jax.tree_util.tree_map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(mu_dtype),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree_util.tree_map(
            lambda m, v: -lr * (m.astype(jnp.float32) / c1) / (jnp.sqrt(v / c2) + eps),
            mu, nu)
        return updates, AdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def adamw(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    mu_dtype: jnp.dtype = jnp.float32,
) -> GradientTransformation:
    inner = adam(learning_rate, b1=b1, b2=b2, eps=eps, mu_dtype=mu_dtype)

    def init(params):
        return inner.init(params)

    def update(grads, state, params):
        updates, new_state = inner.update(grads, state, params)
        lr = _lr_at(learning_rate, state.count)
        updates = jax.tree_util.tree_map(
            lambda u, p: u - lr * weight_decay * p.astype(jnp.float32),
            updates, params)
        return updates, new_state

    return GradientTransformation(init, update)
