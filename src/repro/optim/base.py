"""Minimal gradient-transformation library (optax is not available offline).

A ``GradientTransformation`` is an (init, update) pair:
    init(params)                      -> state
    update(grads, state, params)      -> (updates, state)
Updates are *added* to params: ``params + updates`` (sign convention: the
transformations produce the final negative-lr-scaled step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

OptState = Any
Params = Any
Updates = Any


@dataclasses.dataclass(frozen=True)
class GradientTransformation:
    init: Callable[[Params], OptState]
    update: Callable[[Updates, OptState, Params], tuple]


class ChainState(NamedTuple):
    inner: tuple


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return ChainState(tuple(t.init(params) for t in transforms))

    def update(grads, state, params=None):
        new_states = []
        updates = grads
        for t, s in zip(transforms, state.inner):
            updates, s = t.update(updates, s, params)
            new_states.append(s)
        return updates, ChainState(tuple(new_states))

    return GradientTransformation(init, update)


def identity() -> GradientTransformation:
    return GradientTransformation(
        init=lambda params: (),
        update=lambda g, s, p=None: (g, s),
    )


def apply_updates(params: Params, updates: Updates) -> Params:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if p is not None else None, params, updates
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
