"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0):
    def schedule(count):
        t = jnp.minimum(count.astype(jnp.float32), decay_steps) / decay_steps
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return init_value * ((1 - alpha) * cos + alpha)

    return schedule


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def schedule(count):
        c = count.astype(jnp.float32)
        warm = peak * c / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((c - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(c < warmup_steps, warm, cos)

    return schedule


def inverse_sqrt(peak: float, warmup_steps: int = 1000):
    def schedule(count):
        c = jnp.maximum(count.astype(jnp.float32), 1.0)
        return peak * jnp.minimum(c / warmup_steps, jnp.sqrt(warmup_steps / c))

    return schedule
