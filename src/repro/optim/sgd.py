"""SGD and heavy-ball momentum."""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation

ScalarOrSchedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: ScalarOrSchedule, count):
    return lr(count) if callable(lr) else lr


class SGDState(NamedTuple):
    count: jnp.ndarray


def sgd(learning_rate: ScalarOrSchedule) -> GradientTransformation:
    def init(params):
        del params
        return SGDState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        lr = _lr_at(learning_rate, state.count)
        updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, SGDState(count=state.count + 1)

    return GradientTransformation(init, update)


class MomentumState(NamedTuple):
    count: jnp.ndarray
    trace: object


def momentum(
    learning_rate: ScalarOrSchedule,
    beta: float = 0.9,
    nesterov: bool = False,
    dtype: jnp.dtype = jnp.float32,
) -> GradientTransformation:
    """Heavy-ball momentum; ``dtype`` controls the trace precision (bf16 trace
    halves optimizer memory for the 398B config — see DESIGN.md §5)."""

    def init(params):
        trace = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=dtype), params)
        return MomentumState(count=jnp.zeros((), jnp.int32), trace=trace)

    def update(grads, state, params=None):
        del params
        lr = _lr_at(learning_rate, state.count)
        new_trace = jax.tree_util.tree_map(
            lambda t, g: (beta * t.astype(jnp.float32) + g).astype(dtype),
            state.trace, grads,
        )
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda t, g: -lr * (beta * t.astype(jnp.float32) + g), new_trace, grads
            )
        else:
            upd = jax.tree_util.tree_map(lambda t: -lr * t.astype(jnp.float32), new_trace)
        return upd, MomentumState(count=state.count + 1, trace=new_trace)

    return GradientTransformation(init, update)
