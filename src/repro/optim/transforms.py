"""Composable gradient transforms: clipping, weight decay, scaling."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation, global_norm


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        norm = global_norm(grads)
        scale_factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale_factor, grads), state

    return GradientTransformation(init, update)


def add_weight_decay(weight_decay: float) -> GradientTransformation:
    """Adds wd * params to the *gradients* (L2, pre-preconditioner)."""

    def init(params):
        del params
        return ()

    def update(grads, state, params):
        upd = jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
        )
        return upd, state

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree_util.tree_map(lambda g: factor * g, grads), state

    return GradientTransformation(init, update)


class ScheduleState(NamedTuple):
    count: jnp.ndarray


def scale_by_schedule(schedule: Callable) -> GradientTransformation:
    def init(params):
        del params
        return ScheduleState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        s = schedule(state.count)
        return (
            jax.tree_util.tree_map(lambda g: s * g, grads),
            ScheduleState(count=state.count + 1),
        )

    return GradientTransformation(init, update)
