from repro.sharding.rules import (
    PROFILES,
    spec_for_leaf,
    param_specs,
    batch_specs,
    cache_specs,
    opt_state_specs,
    named_shardings,
)

__all__ = [
    "PROFILES",
    "spec_for_leaf",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "opt_state_specs",
    "named_shardings",
]
