"""Logical-axis sharding rules -> PartitionSpecs for every param / cache /
batch leaf, with automatic divisibility fallback.

Scheme (DESIGN.md §5):

* ``fsdp_tp`` (training): 2-D parameter sharding — the "embed"-like axis over
  the ``data`` mesh axis (FSDP; XLA inserts all-gathers at use sites and
  reduce-scatters in the backward), the "parallel" axis (heads / mlp / vocab /
  expert) over ``model`` (tensor parallelism).  Optimizer state inherits the
  param specs (ZeRO-3-equivalent).  Params are replicated across ``pod``;
  the batch is sharded over (pod, data).
* ``tp_decode`` (serving): weight-stationary tensor parallelism — parallel
  axes over ``model``, embed axes replicated; KV caches shard batch over
  ``data`` and kv-heads over ``model`` (falling back to head_dim when the
  kv-head count does not divide the mesh axis — e.g. GQA kv=8 on model=16).
* ``fsdp_decode``: like tp_decode but embed axes also over ``data`` — used
  when weights alone exceed per-chip HBM under pure TP (jamba-398b).

A mesh axis is assigned to at most one tensor dim (PartitionSpec constraint);
rules list logical axes per trailing dim and the first divisible unclaimed
axis wins, others degrade to replication.  Leading stacked dims (layers /
periods / inner stacks) are auto-padded with None.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "PROFILES",
    "spec_for_leaf",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "opt_state_specs",
    "named_shardings",
    "resolve_walker_axis",
    "walker_batch_specs",
    "fleet_specs",
]

# logical axes for the TRAILING dims of each known leaf name
PARAM_RULES: dict[str, tuple] = {
    "table": ("vocab", "embed"),
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "bq": ("heads", None),
    "bk": ("kv_heads", None),
    "bv": ("kv_heads", None),
    "w_gate": ("embed", "mlp"),  # rank-3 (expert) handled below
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    "w_in": ("embed", "mlp"),
    "w_out": ("mlp", "embed"),
    "b_in": ("mlp",),
    "b_out": (None,),
    "router": ("embed", None),
    "in_proj": ("embed", "mlp"),
    "out_proj": ("mlp", "embed"),
    "conv_w": (None, "mlp"),
    "conv_b": ("mlp",),
    "norm_scale": (None,),
    "a_log": (None,),
    "d_skip": (None,),
    "dt_bias": (None,),
    "scale": (None,),
    "bias": (None,),
    "dec_pos": (None, "embed"),
}

EXPERT_RULES: dict[str, tuple] = {
    "w_gate": ("expert", "embed", "mlp"),
    "w_up": ("expert", "embed", "mlp"),
    "w_down": ("expert", "mlp", "embed"),
}

CACHE_RULES: dict[str, tuple] = {
    "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "slot_pos": (None,),
    "conv": ("batch", None, "mlp"),
    "ssm": ("batch", "heads", None, None),
}

# logical -> mesh axis, per profile.  "batch" resolves to pod+data jointly.
# "walker" is the W-walker fleet axis of repro.walk_sgd.fleet: the leading
# dim of every walker-batch leaf (walk nodes, stacked per-walker model /
# optimizer / walk state) maps to the data mesh axis, so the periodic
# cross-walker model average lowers to an all-reduce along "data".
PROFILES: dict[str, dict] = {
    "fsdp_tp": {
        "embed": "data",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "batch": ("pod", "data"),
        "kv_seq": None,
        "walker": "data",
    },
    "tp_decode": {
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "batch": ("pod", "data"),
        "kv_seq": None,
        "walker": "data",
    },
    "fsdp_decode": {
        "embed": "data",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "batch": ("pod", "data"),
        "kv_seq": None,
        "walker": "data",
    },
    # pure walker-parallel fleet (regression path / engine sweeps): the
    # whole mesh is one walker axis, graph state replicated.
    "fleet": {
        "walker": "data",
    },
}


def _mesh_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve_axis(logical, profile, mesh_sizes, dim_size, used):
    """Map one logical axis to a mesh axis (or None) respecting divisibility
    and single-use; supports tuple mesh axes (e.g. batch over (pod, data))."""
    if logical is None:
        return None
    target = profile.get(logical)
    if target is None:
        return None
    if isinstance(target, tuple):
        axes = tuple(a for a in target if a in mesh_sizes and a not in used)
        total = int(np.prod([mesh_sizes[a] for a in axes])) if axes else 1
        if axes and dim_size % total == 0 and dim_size > 0:
            used.update(axes)
            return axes if len(axes) > 1 else axes[0]
        # retry with progressively fewer axes (drop leading)
        for k in range(1, len(axes)):
            sub = axes[k:]
            total = int(np.prod([mesh_sizes[a] for a in sub]))
            if dim_size % total == 0 and dim_size > 0:
                used.update(sub)
                return sub if len(sub) > 1 else sub[0]
        return None
    if target not in mesh_sizes or target in used:
        return None
    if dim_size % mesh_sizes[target] != 0 or dim_size == 0:
        return None
    used.add(target)
    return target


def spec_for_leaf(
    name: str,
    shape: tuple,
    profile_name: str,
    mesh: Mesh,
    rules: Optional[dict] = None,
    is_expert: bool = False,
) -> P:
    """PartitionSpec for one leaf, padding leading stacked dims with None."""
    rules = rules or PARAM_RULES
    profile = PROFILES[profile_name]
    mesh_sizes = _mesh_sizes(mesh)
    logical = rules.get(name)
    if is_expert and name in EXPERT_RULES and len(shape) >= 3:
        # routed-expert weight: trailing (E, D, F) under optional stacked dims
        logical = EXPERT_RULES[name]
    if logical is None:
        return P()
    n_lead = len(shape) - len(logical)
    if n_lead < 0:  # rule longer than rank (e.g. scalar variants): replicate
        return P()
    used: set = set()
    entries = [None] * n_lead
    for logical_axis, dim in zip(logical, shape[n_lead:]):
        entries.append(_resolve_axis(logical_axis, profile, mesh_sizes, dim, used))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _path_keys(path) -> tuple:
    return tuple(
        str(e.key) if isinstance(e, jax.tree_util.DictKey) else getattr(e, "name", "")
        for e in path
    )


def param_specs(params_shapes, profile_name: str, mesh: Mesh):
    """Spec tree matching a params (or eval_shape) tree."""

    def assign(path, leaf):
        keys = _path_keys(path)
        is_expert = "moe" in keys and "shared" not in keys
        return spec_for_leaf(
            _leaf_name(path), leaf.shape, profile_name, mesh, is_expert=is_expert
        )

    return jax.tree_util.tree_map_with_path(assign, params_shapes)


def cache_specs(cache_shapes, profile_name: str, mesh: Mesh):
    def assign(path, leaf):
        return spec_for_leaf(
            _leaf_name(path), leaf.shape, profile_name, mesh, rules=CACHE_RULES
        )

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)


def batch_specs(batch_shapes, profile_name: str, mesh: Mesh):
    """Batch dict: dim0 = batch over (pod, data); everything else replicated."""
    profile = PROFILES[profile_name]
    mesh_sizes = _mesh_sizes(mesh)

    def assign(path, leaf):
        del path
        used: set = set()
        first = _resolve_axis("batch", profile, mesh_sizes, leaf.shape[0], used)
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(assign, batch_shapes)


def opt_state_specs(opt_state_shapes, p_specs, params_shapes, profile_name: str, mesh: Mesh):
    """Optimizer-state specs: state leaves matching a param shape inherit that
    param's spec; reduced-shape leaves (adafactor rows/cols) get a spec derived
    from the param rule re-applied to their own shape; scalars replicate."""
    flat_params = {
        tuple(str(k) for k in path): (leaf.shape, spec)
        for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(params_shapes)[0],
            jax.tree_util.tree_leaves(p_specs, is_leaf=lambda x: isinstance(x, P)),
        )
    }
    by_shape: dict = {}
    for shape, spec in flat_params.values():
        by_shape.setdefault(shape, spec)

    def assign(path, leaf):
        if leaf.shape == ():
            return P()
        if leaf.shape in by_shape:
            return by_shape[leaf.shape]
        # adafactor factored moments: re-derive from the leaf name fallback
        return spec_for_leaf(_leaf_name(path), leaf.shape, profile_name, mesh)

    return jax.tree_util.tree_map_with_path(assign, opt_state_shapes)


def named_shardings(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Walker-fleet specs (repro.walk_sgd.fleet): the "walker" logical axis.
# ---------------------------------------------------------------------------


def resolve_walker_axis(
    num_walks: int, mesh: Mesh, profile_name: str = "fleet"
) -> Optional[NamedSharding]:
    """NamedSharding for a 1-D ``(W,)`` walker-axis leaf, or ``None`` when
    the profile's walker mesh axis is absent or W does not divide it
    (replication fallback — same degradation rule as every other logical
    axis here)."""
    used: set = set()
    axis = _resolve_axis(
        "walker", PROFILES[profile_name], _mesh_sizes(mesh), num_walks, used
    )
    if axis is None:
        return None
    return NamedSharding(mesh, P(axis))


def walker_batch_specs(
    tree, num_walks: int, mesh: Mesh, profile_name: str = "fleet"
):
    """Spec tree for a walker-stacked pytree: every leaf whose leading dim
    equals ``num_walks`` gets the walker mesh axis on dim 0 (stacked
    per-walker params / optimizer state / walk state / ``x0s``); leaves
    without the walker batch dim — and everything when W does not divide
    the axis — replicate."""
    profile = PROFILES[profile_name]
    mesh_sizes = _mesh_sizes(mesh)

    def assign(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) >= 1 and shape[0] == num_walks:
            used: set = set()
            axis = _resolve_axis("walker", profile, mesh_sizes, shape[0], used)
            if axis is not None:
                return P(axis, *([None] * (len(shape) - 1)))
        return P()

    return jax.tree_util.tree_map(assign, tree)


def fleet_specs(fleet, mesh: Mesh, profile_name: str = "fleet"):
    """Spec tree matching a ``repro.walk_sgd.fleet.WalkFleet``: the walk
    ``nodes`` ride the walker axis, every engine leaf (padded neighbor
    tables, ragged CSR ``indptr``/``indices`` row state, the flat per-edge
    ``edge_cdf``) is **replicated** — walker positions are data-dependent
    gathers into the graph, so keeping graph state whole on every device
    avoids cross-device gathers on the walk's hot path."""
    import dataclasses

    wspec = walker_batch_specs(
        {"nodes": fleet.nodes}, fleet.num_walks, mesh, profile_name
    )["nodes"]
    engine_specs = jax.tree_util.tree_map(lambda _: P(), fleet.engine)
    return dataclasses.replace(fleet, engine=engine_specs, nodes=wspec)
