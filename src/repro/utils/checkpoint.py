"""Checkpointing: pytree <-> npz with key-path flattening.

Production shape: atomic write (tmp + rename), monotonically numbered step
directories, latest-k retention, and a manifest carrying the walk state so a
restarted job resumes the SAME random-walk trajectory (paper Algorithm 1 is a
sequential process — resuming from the wrong node would silently change the
sampled distribution).

Arrays are gathered to host (process 0) before writing; restoring returns
numpy arrays which the caller re-shards via its NamedShardings (device_put).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "flatten_with_paths",
    "unflatten_from_paths",
    "save_pytree",
    "load_pytree",
    "save_checkpoint",
    "load_checkpoint",
    "latest_step",
]

_SEP = "/"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def flatten_with_paths(tree: Any) -> Tuple[dict, Any]:
    """-> ({path: np.ndarray}, treedef)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = _path_str(path)
        if key in flat:
            raise ValueError(f"duplicate checkpoint key {key!r}")
        flat[key] = np.asarray(leaf)
    return flat, treedef


def unflatten_from_paths(treedef, flat: dict) -> Any:
    """Rebuild a pytree from a treedef and the path-keyed arrays."""
    # leaf order of tree_flatten_with_path matches tree_unflatten's order
    dummy = jax.tree_util.tree_unflatten(treedef, list(range(treedef.num_leaves)))
    leaves_paths, _ = jax.tree_util.tree_flatten_with_path(dummy)
    ordered = []
    for path, _ in leaves_paths:
        key = _path_str(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key!r}")
        ordered.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)


def save_pytree(path: str, tree: Any) -> None:
    """Atomic npz write of one pytree."""
    flat, _ = flatten_with_paths(tree)
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, like: Any) -> Any:
    """Load an npz checkpoint into the treedef of ``like``."""
    _, treedef = flatten_with_paths(like)
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return unflatten_from_paths(treedef, flat)


_STEP_RE = re.compile(r"^step_(\d{10})$")


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(root)
        if (m := _STEP_RE.match(d)) and os.path.exists(os.path.join(root, d, "MANIFEST.json"))
    ]
    return max(steps) if steps else None


def save_checkpoint(
    root: str,
    step: int,
    params: Any,
    opt_state: Any = None,
    walk_state: Any = None,
    extra: Optional[dict] = None,
    keep: int = 3,
) -> str:
    """Write one numbered checkpoint; prune to the newest ``keep``."""
    final = _step_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    save_pytree(os.path.join(tmp, "params.npz"), params)
    manifest = {"step": step, "extra": extra or {}}
    if opt_state is not None:
        save_pytree(os.path.join(tmp, "opt_state.npz"), opt_state)
        manifest["has_opt_state"] = True
    if walk_state is not None:
        save_pytree(os.path.join(tmp, "walk_state.npz"), walk_state)
        manifest["has_walk_state"] = True
    # manifest written LAST: its presence marks the checkpoint complete
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    if keep > 0:
        steps = sorted(
            int(m.group(1)) for d in os.listdir(root) if (m := _STEP_RE.match(d))
        )
        for old in steps[:-keep]:
            shutil.rmtree(_step_dir(root, old), ignore_errors=True)
    return final


def load_checkpoint(
    root: str,
    like_params: Any,
    like_opt_state: Any = None,
    like_walk_state: Any = None,
    step: Optional[int] = None,
) -> dict:
    """Restore the given (or latest) step; returns dict with restored trees."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root!r}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    out = {
        "step": step,
        "extra": manifest.get("extra", {}),
        "params": load_pytree(os.path.join(d, "params.npz"), like_params),
    }
    if like_opt_state is not None and manifest.get("has_opt_state"):
        out["opt_state"] = load_pytree(os.path.join(d, "opt_state.npz"), like_opt_state)
    if like_walk_state is not None and manifest.get("has_walk_state"):
        out["walk_state"] = load_pytree(os.path.join(d, "walk_state.npz"), like_walk_state)
    return out
