"""Loop-aware HLO cost model (roofline source, deliverable g).

XLA's ``compiled.cost_analysis()`` prices a ``while`` body ONCE regardless
of trip count, which undercounts scanned-layer models by ~num_layers.  This
module re-prices the compiled HLO text with explicit loop accounting:

  * every computation is priced from its instructions (symbol table of
    result shapes; dot FLOPs from contracting dims, convolution from window
    dims, elementwise/reduce approximations),
  * ``fusion``/``call`` instructions inline the cost of their callee
    (fusion internals contribute FLOPs but not HBM bytes — operands +
    outputs only, matching fusion semantics),
  * ``while`` instructions multiply (body + condition) cost by the trip
    count recovered from the condition computation's compare constant,
  * collectives (all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute) accumulate operand bytes and ring-cost bytes,
    including inside loop bodies.

Approximations (documented for EXPERIMENTS.md):
  * elementwise ops: 1 FLOP per output element; reduces: 1 per input
    element; transcendentals not weighted extra.
  * bytes = operand + output sizes per top-level op, with view/bookkeeping
    ops free (get-tuple-element, tuple, reshape, bitcast, parameter),
    windowed ops priced at 2x their window (slice / dynamic-update-slice /
    gather / scatter), and fusion-internal traffic excluded — an
    HBM-traffic model that assumes in-place buffers and perfect fusion.
    Producer+consumer pairs still double-count relative to a unique-bytes
    model (~2x, uniform across cases).
  * trip count = the largest integer constant in the loop condition —
    exact for lax.scan/fori_loop lowerings (jax emits compare(iv, N)).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_hlo_module", "price_module", "HloCost", "collective_summary_loops"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ZERO_FLOP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "reshape", "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "iota", "reverse", "pad",
    "gather", "scatter", "select", "convert", "rng", "rng-bit-generator",
    "after-all", "partition-id", "replica-id", "copy-start", "copy-done",
    "infeed", "outfeed", "custom-call", "domain", "opt-barrier",
    "get-dimension-size",
}

# bookkeeping ops that move no data (views / tuple plumbing / metadata)
_FREE_BYTE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "iota", "after-all", "partition-id", "replica-id",
    "domain", "opt-barrier", "get-dimension-size", "copy-start",
    "copy-done",
}


def _io_bytes(inst: "Instruction", comp: "Computation") -> float:
    """HBM-traffic estimate for one instruction (see module docstring)."""
    op = inst.op
    if op in _FREE_BYTE_OPS:
        return 0.0
    out_b = _shape_bytes(inst.type_str)
    if op in ("slice", "dynamic-slice", "broadcast"):
        return 2.0 * out_b if op != "broadcast" else out_b
    if op == "dynamic-update-slice":
        # in-place: read + write of the updated window (+ indices, tiny)
        upd = (
            _shape_bytes(comp.symbols.get(inst.operands[1], ""))
            if len(inst.operands) > 1
            else out_b
        )
        return 2.0 * upd
    if op == "gather":
        idx = (
            _shape_bytes(comp.symbols.get(inst.operands[1], ""))
            if len(inst.operands) > 1
            else 0
        )
        return 2.0 * out_b + idx
    if op == "scatter":
        upd = (
            _shape_bytes(comp.symbols.get(inst.operands[2], ""))
            if len(inst.operands) > 2
            else out_b
        )
        return 2.0 * upd + out_b
    opd_b = sum(_shape_bytes(comp.symbols.get(o, "")) for o in inst.operands)
    return out_b + opd_b


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an array or tuple type string."""
    total = 0
    for dtype, dims in re.findall(r"(\w+)\[([\d,]*)\]", type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    elems = 0
    for _, dims in re.findall(r"(\w+)\[([\d,]*)\]", type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
    return elems


def _array_dims(type_str: str) -> List[int]:
    m = re.search(r"\w+\[([\d,]*)\]", type_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str
    is_root: bool


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    symbols: Dict[str, str]  # %name -> type string


_COMP_HEAD = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)(?:\.clone)? \(.*\{$")
_INSTR = re.compile(
    r"^\s*(ROOT )?%?([\w\.\-]+) = ((?:\([^=]*?\)|[\w\[\],{}\s]+?)) ([\w\-]+)\((.*)$"
)


def parse_hlo_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    cur_name = None
    for line in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=k*/ comments
        if cur is None:
            if line.rstrip().endswith("{") and ("(" in line and "->" in line):
                m = re.match(r"^(?:ENTRY )?%?([\w\.\-]+) ", line)
                if m:
                    cur_name = m.group(1)
                    cur = Computation(cur_name, [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        is_root, name, type_str, op, rest = (
            bool(m.group(1)), m.group(2), m.group(3).strip(), m.group(4), m.group(5),
        )
        # operand names: %refs inside the first (...) — cut at the matching
        # close paren by scanning depth
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arg_str, attrs = rest[: i - 1], rest[i:]
        operands = re.findall(r"%([\w\.\-]+)", arg_str)
        cur.symbols[name] = type_str
        cur.instructions.append(
            Instruction(name, type_str, op, operands, attrs, is_root)
        )
    return comps


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_ring_bytes: float = 0.0
    coll_counts: Optional[Dict[str, float]] = None

    def __add__(self, o: "HloCost") -> "HloCost":
        counts = dict(self.coll_counts or {})
        for k, v in (o.coll_counts or {}).items():
            counts[k] = counts.get(k, 0) + v
        return HloCost(
            self.flops + o.flops,
            self.bytes + o.bytes,
            self.coll_bytes + o.coll_bytes,
            self.coll_ring_bytes + o.coll_ring_bytes,
            counts,
        )

    def __mul__(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k, self.bytes * k, self.coll_bytes * k,
            self.coll_ring_bytes * k,
            {kk: v * k for kk, v in (self.coll_counts or {}).items()},
        )


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    out_elems = _shape_elems(inst.type_str)
    lhs_type = comp.symbols.get(inst.operands[0], "") if inst.operands else ""
    lhs_dims = _array_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
    contract = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(inst: Instruction, comp: Computation) -> float:
    out_elems = _shape_elems(inst.type_str)
    window = 1
    m = re.search(r"window=\{[^}]*size=([\dx]+)", inst.attrs)
    if m:
        for d in m.group(1).split("x"):
            window *= int(d)
    rhs_type = comp.symbols.get(inst.operands[1], "") if len(inst.operands) > 1 else ""
    rhs_dims = _array_dims(rhs_type)  # kernel: spatial.. in_ch, out_ch (default)
    in_ch = rhs_dims[-2] if len(rhs_dims) >= 2 else 1
    g = re.search(r"feature_group_count=(\d+)", inst.attrs)
    groups = int(g.group(1)) if g else 1
    return 2.0 * out_elems * window * max(1, in_ch // max(1, groups)) / 1.0


def _ring_cost(kind: str, nbytes: float, group_size: int = 16) -> float:
    k = max(2, group_size)
    if kind == "all-reduce":
        return 2.0 * nbytes * (k - 1) / k
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return nbytes * (k - 1) / k
    return nbytes  # collective-permute


def _replica_group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:  # iota v2 format [groups, group_size]
        return int(m.group(2))
    return 16


_CONST_IN_COND = re.compile(r"s32\[\] constant\((\d+)\)")


def price_module(
    text: str,
    *,
    entry_override: Optional[str] = None,
) -> HloCost:
    comps = parse_hlo_module(text)
    # map computation -> raw text block for trip-count constants
    blocks: Dict[str, str] = {}
    cur_name, buf = None, []
    for line in text.splitlines():
        if cur_name is None:
            m = re.match(r"^(?:ENTRY )?%?([\w\.\-]+) \(.*\{$", line)
            if m:
                cur_name, buf = m.group(1), [line]
            continue
        buf.append(line)
        if line.startswith("}"):
            blocks[cur_name] = "\n".join(buf)
            cur_name = None

    entry = entry_override
    m = re.search(r"^ENTRY %?([\w\.\-]+) ", text, re.M)
    if m and entry is None:
        entry = m.group(1)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].instructions))

    memo: Dict[Tuple[str, bool], HloCost] = {}

    def price(comp_name: str, top_level: bool) -> HloCost:
        key = (comp_name, top_level)
        if key in memo:
            return memo[key]
        comp = comps.get(comp_name)
        if comp is None:
            return HloCost()
        memo[key] = HloCost()  # recursion guard
        total = HloCost(coll_counts={})
        for inst in comp.instructions:
            op = inst.op
            out_bytes = _shape_bytes(inst.type_str)
            opd_bytes = sum(
                _shape_bytes(comp.symbols.get(o, "")) for o in inst.operands
            )
            io_bytes = _io_bytes(inst, comp)

            if op == "while":
                cond_m = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
                body_m = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
                trip = 1
                if cond_m and cond_m.group(1) in blocks:
                    consts = _CONST_IN_COND.findall(blocks[cond_m.group(1)])
                    if consts:
                        trip = max(int(c) for c in consts)
                inner = HloCost()
                if body_m:
                    inner = inner + price(body_m.group(1), True)
                if cond_m:
                    inner = inner + price(cond_m.group(1), True)
                total = total + inner * trip
                continue

            if op in ("fusion", "call"):
                callee = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", inst.attrs)
                if callee:
                    inner = price(callee.group(1), False)
                    # fusion internals: flops + collectives count, bytes do
                    # not (on-chip); the fusion's own operands/outputs do.
                    total.flops += inner.flops
                    total.coll_bytes += inner.coll_bytes
                    total.coll_ring_bytes += inner.coll_ring_bytes
                    for k, v in (inner.coll_counts or {}).items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0) + v
                if top_level:
                    total.bytes += io_bytes
                continue

            kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if kind is not None:
                nbytes = max(out_bytes, opd_bytes)
                gsize = _replica_group_size(inst.attrs)
                total.coll_bytes += nbytes
                total.coll_ring_bytes += _ring_cost(kind, nbytes, gsize)
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                if top_level:
                    total.bytes += io_bytes
                continue

            if op == "dot":
                total.flops += _dot_flops(inst, comp)
            elif op == "convolution":
                total.flops += _conv_flops(inst, comp)
            elif op in ("reduce", "reduce-window"):
                total.flops += sum(
                    _shape_elems(comp.symbols.get(o, "")) for o in inst.operands[:1]
                )
            elif op == "sort":
                n = _shape_elems(inst.type_str)
                total.flops += n * max(1, n.bit_length())
            elif op not in _ZERO_FLOP_OPS:
                # elementwise and everything else: 1 flop / output element
                total.flops += _shape_elems(inst.type_str)
            if top_level:
                total.bytes += io_bytes
        memo[key] = total
        return total

    return price(entry, True)


def collective_summary_loops(text: str) -> dict:
    """Loop-aware replacement for hlo_parse.collective_summary."""
    cost = price_module(text)
    return {
        "total_bytes": cost.coll_bytes,
        "total_ring_cost_bytes": cost.coll_ring_bytes,
        "num_ops": sum((cost.coll_counts or {}).values()),
        "by_kind": dict(cost.coll_counts or {}),
    }
