"""Collective-traffic extraction from compiled (SPMD-partitioned) HLO text.

``compiled.as_text()`` shapes are PER-DEVICE after partitioning, so summed
byte counts are per-chip wire traffic.  For each collective we record the
result-shape bytes and a modeled ring-cost (bytes actually serialized on the
slowest link path):

    all-reduce       2 * bytes * (g-1)/g
    all-gather       bytes * (g-1)/g          (bytes = result, gathered)
    reduce-scatter   bytes_result * (g-1)     (operand = g * result)
    all-to-all       bytes * (g-1)/g
    collective-permute   bytes
"""
from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

__all__ = ["parse_collectives", "collective_summary"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO result type (handles tuples by summing)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",") if d]))
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return max(1, int(m.group(2)))
    m = _GROUPS_RE.search(line)
    if m:
        members = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(members))
    return 1


def parse_collectives(hlo_text: str) -> list[dict]:
    """Extract every collective op: kind, result bytes, group size, ring cost."""
    ops = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)", stripped)
        if not m:
            continue
        kind = m.group(2)
        base = None
        for c in _COLLECTIVES:
            if kind == c or kind.startswith(c + "-start") or kind == c + "-start":
                base = c
                break
        if base is None:
            continue
        result_bytes = _shape_bytes(m.group(1))
        g = _group_size(stripped)
        if base == "all-reduce":
            cost = 2.0 * result_bytes * (g - 1) / max(g, 1)
        elif base == "all-gather":
            cost = result_bytes * (g - 1) / max(g, 1)
        elif base == "reduce-scatter":
            cost = result_bytes * (g - 1)
        elif base == "all-to-all":
            cost = result_bytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            cost = float(result_bytes)
        ops.append(
            {"kind": base, "bytes": result_bytes, "group": g, "ring_cost_bytes": cost}
        )
    return ops


def collective_summary(hlo_text: str) -> dict:
    ops = parse_collectives(hlo_text)
    by_kind = defaultdict(lambda: {"count": 0, "bytes": 0, "ring_cost_bytes": 0.0})
    for op in ops:
        k = by_kind[op["kind"]]
        k["count"] += 1
        k["bytes"] += op["bytes"]
        k["ring_cost_bytes"] += op["ring_cost_bytes"]
    return {
        "total_bytes": int(sum(o["bytes"] for o in ops)),
        "total_ring_cost_bytes": float(sum(o["ring_cost_bytes"] for o in ops)),
        "num_ops": len(ops),
        "by_kind": {k: dict(v) for k, v in by_kind.items()},
    }
