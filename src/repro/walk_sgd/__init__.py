from repro.walk_sgd.trainer import (
    MultiRWSGDResult,
    RWSGDResult,
    run_rw_sgd,
    run_rw_sgd_multi,
)
from repro.walk_sgd.comm_model import CommModel, comm_report

__all__ = [
    "MultiRWSGDResult",
    "RWSGDResult",
    "run_rw_sgd",
    "run_rw_sgd_multi",
    "CommModel",
    "comm_report",
]
