from repro.walk_sgd.trainer import (
    MultiRWSGDResult,
    RWSGDResult,
    run_rw_sgd,
    run_rw_sgd_multi,
)
from repro.walk_sgd.comm_model import (
    CommModel,
    comm_report,
    fleet_averaging_traffic,
)
from repro.walk_sgd.fleet import (
    WalkFleet,
    fleet_average,
    init_fleet_walk_state,
    load_fleet_checkpoint,
    make_fleet_step,
    migrate_walk_nodes,
    run_fleet,
    sample_initial_nodes,
    save_fleet_checkpoint,
    shard_walker_batch,
)
from repro.walk_sgd.graph_learning import (
    DadaResult,
    personalize_models,
    run_dada,
    similarity_edges,
)

__all__ = [
    "MultiRWSGDResult",
    "RWSGDResult",
    "run_rw_sgd",
    "run_rw_sgd_multi",
    "CommModel",
    "comm_report",
    "fleet_averaging_traffic",
    "WalkFleet",
    "fleet_average",
    "init_fleet_walk_state",
    "make_fleet_step",
    "migrate_walk_nodes",
    "run_fleet",
    "sample_initial_nodes",
    "save_fleet_checkpoint",
    "load_fleet_checkpoint",
    "shard_walker_batch",
    "DadaResult",
    "personalize_models",
    "run_dada",
    "similarity_edges",
]
