from repro.walk_sgd.trainer import RWSGDResult, run_rw_sgd
from repro.walk_sgd.comm_model import CommModel, comm_report

__all__ = ["RWSGDResult", "run_rw_sgd", "CommModel", "comm_report"]
