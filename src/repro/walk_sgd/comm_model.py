"""Remark-1 communication accounting.

One *transition* = one model hand-off over a graph edge.  MHLJ trades extra
transitions (jump hops carry the model without updating it) for fewer updates
to a target accuracy.  This module turns (updates, transitions, model bytes)
into the paper's cost statement and a bytes-on-the-wire estimate.

The W-walker fleet (``repro.walk_sgd.fleet``) adds a second traffic class
on top of the per-walk hand-offs: the periodic cross-walker model average,
one all-reduce along the walker mesh axis every ``avg_every`` steps.
:func:`fleet_averaging_traffic` prices it as a function of W, the mesh
size and the model size.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.levy import expected_transitions_per_update, remark1_bound

__all__ = ["CommModel", "comm_report", "fleet_averaging_traffic"]


@dataclasses.dataclass(frozen=True)
class CommModel:
    model_bytes: int  # bytes per hand-off (parameters at wire precision)
    link_bandwidth: float = 1e9  # bytes/s per edge (WAN-ish default)
    per_hop_latency: float = 1e-3  # seconds


def comm_report(
    transitions: np.ndarray,
    p_j: float,
    p_d: float,
    r: int,
    comm: CommModel | None = None,
) -> dict:
    """Measured vs predicted transitions/update + wire-cost estimate."""
    measured = float(np.asarray(transitions, dtype=np.float64).mean())
    exact = expected_transitions_per_update(p_j, p_d, r)
    bound = remark1_bound(p_j, p_d, r)
    out = {
        "transitions_per_update_measured": measured,
        "transitions_per_update_exact": exact,
        "transitions_per_update_bound": bound,
        "within_bound": bool(measured <= bound + 5e-2),
    }
    if comm is not None:
        n_hops = float(np.asarray(transitions, dtype=np.float64).sum())
        out["wire_bytes_total"] = n_hops * comm.model_bytes
        out["wire_seconds_est"] = n_hops * (
            comm.model_bytes / comm.link_bandwidth + comm.per_hop_latency
        )
    return out


def fleet_averaging_traffic(
    num_walks: int,
    num_steps: int,
    avg_every: int,
    model_bytes: int,
    *,
    mesh_devices: int = 1,
    comm: CommModel | None = None,
) -> dict:
    """Wire cost of the fleet's periodic cross-walker averaging collective.

    Every ``avg_every`` steps, ``repro.walk_sgd.fleet.fleet_average``
    all-reduces one model's worth of parameters along the walker mesh
    axis.  With W walkers sharded over D devices, each device first forms
    its *local* partial mean over the walkers it hosts (free — no wire
    traffic), so the collective payload is one model regardless of W;
    only ``D_eff = min(W, D)`` devices hold walkers and participate.
    Under the standard ring all-reduce cost model each participating
    device sends ``2 * (D_eff - 1) / D_eff * model_bytes`` per
    collective, hence total wire bytes per collective are
    ``2 * (D_eff - 1) * model_bytes`` — zero on a single device, where
    the average is a local reduction.

    ``avg_every <= 0`` (never average) prices to zero collectives.  With
    ``comm``, a wall-clock estimate per collective and in total is added
    using the ring's per-device bytes plus one ``per_hop_latency`` per
    collective.  Returns a dict; see ``tests/test_fleet.py`` for the
    invariants (monotone in model size, zero at D=1, W-independence of
    the per-collective payload once W >= D).
    """
    if num_walks < 1 or mesh_devices < 1:
        raise ValueError("num_walks and mesh_devices must be >= 1")
    d_eff = min(num_walks, mesh_devices)
    n_collectives = num_steps // avg_every if avg_every > 0 else 0
    per_device = 2.0 * (d_eff - 1) / d_eff * model_bytes if d_eff > 1 else 0.0
    per_collective = per_device * d_eff  # == 2 * (d_eff - 1) * model_bytes
    out = {
        "num_collectives": n_collectives,
        "participating_devices": d_eff,
        "bytes_per_device_per_collective": per_device,
        "bytes_per_collective": per_collective,
        "total_wire_bytes": per_collective * n_collectives,
        # amortized collective traffic per model update across the fleet
        "bytes_per_update": (
            per_collective * n_collectives / (num_steps * num_walks)
            if num_steps > 0
            else 0.0
        ),
    }
    if comm is not None:
        secs = (
            per_device / comm.link_bandwidth + comm.per_hop_latency
            if d_eff > 1
            else 0.0
        )
        out["wire_seconds_per_collective"] = secs
        out["wire_seconds_total"] = secs * n_collectives
    return out
