"""Remark-1 communication accounting.

One *transition* = one model hand-off over a graph edge.  MHLJ trades extra
transitions (jump hops carry the model without updating it) for fewer updates
to a target accuracy.  This module turns (updates, transitions, model bytes)
into the paper's cost statement and a bytes-on-the-wire estimate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.levy import expected_transitions_per_update, remark1_bound

__all__ = ["CommModel", "comm_report"]


@dataclasses.dataclass(frozen=True)
class CommModel:
    model_bytes: int  # bytes per hand-off (parameters at wire precision)
    link_bandwidth: float = 1e9  # bytes/s per edge (WAN-ish default)
    per_hop_latency: float = 1e-3  # seconds


def comm_report(
    transitions: np.ndarray,
    p_j: float,
    p_d: float,
    r: int,
    comm: CommModel | None = None,
) -> dict:
    """Measured vs predicted transitions/update + wire-cost estimate."""
    measured = float(np.asarray(transitions, dtype=np.float64).mean())
    exact = expected_transitions_per_update(p_j, p_d, r)
    bound = remark1_bound(p_j, p_d, r)
    out = {
        "transitions_per_update_measured": measured,
        "transitions_per_update_exact": exact,
        "transitions_per_update_bound": bound,
        "within_bound": bool(measured <= bound + 5e-2),
    }
    if comm is not None:
        n_hops = float(np.asarray(transitions, dtype=np.float64).sum())
        out["wire_bytes_total"] = n_hops * comm.model_bytes
        out["wire_seconds_est"] = n_hops * (
            comm.model_bytes / comm.link_bandwidth + comm.per_hop_latency
        )
    return out
