"""One fleet loop: the W-walker batch behind every walk-SGD training path.

The repo used to carry three divergent walk-SGD loops — the single-walk
``trainer._run_scan``, the batched ``trainer._run_scan_multi`` and the
LLM orchestrator's ``WalkContext.advance``/``make_train_step`` step — none
of which touched the mesh/sharding stack.  This module collapses them into
one **fleet** abstraction: the W walker batch (walk nodes, per-walker
model/optimizer state, per-walker PRNG streams on the LLM path) is one
pytree whose walker-batch leaves carry a leading ``(W, ...)`` axis, the
``walker`` logical axis of ``repro.sharding.rules``.  Sharded over the
mesh ``data`` axis (``repro.sharding.rules.fleet_specs`` /
``repro.launch.mesh.make_walker_mesh``) the fleet trains W walks across
devices off ONE batched :class:`~repro.core.engine.WalkEngine` transition
per step, with the graph state — padded tables, ragged CSR row state,
the flat per-edge CDF — **replicated** (walk positions are data-dependent
gathers into the graph; replication keeps them local).

Periodic cross-walker model averaging (``avg_every``-style local SGD) is
:func:`fleet_average`: a mean over the leading walker axis, which XLA
lowers to an all-reduce along the mesh axis the walker axis is sharded
over — so the only cross-device traffic of the fleet is one model-sized
collective every ``avg_every`` steps
(``repro.walk_sgd.comm_model.fleet_averaging_traffic`` prices it).

This is the multi-walker regime of the journal extension *Decentralized
Learning via Random Walk with Jumps* (arXiv:2604.12260): W independent
MHLJ walks over the same graph, each carrying its own model, periodically
averaged.  Averaging divides the Markov-sampling variance term of
Theorem 1 by ~W while the O(p_J^2) perturbation bias is unchanged — the
convergence-vs-num-walkers sweep in ``benchmarks/multi_walk.py`` /
``benchmarks/large_graph_walk.py`` measures exactly that.

Consumers (all three former loops route through here):

* ``repro.walk_sgd.trainer.run_rw_sgd`` — the W=1 case of
  :func:`run_fleet` (bitwise-identical per key to the pre-refactor
  single-walk scan; ``tests/test_fleet.py`` pins it against a frozen
  oracle copy).
* ``repro.walk_sgd.trainer.run_rw_sgd_multi`` — constructs a
  :class:`WalkFleet` and calls :func:`run_fleet`, optionally under a
  mesh.
* ``repro.walk_sgd.llm_trainer`` / ``repro.walk_sgd.multi_walk`` — thin
  consumers: ``WalkContext.advance`` advances a one-walker fleet, and
  :func:`make_fleet_step` is THE W-walker LLM step
  (``make_multi_walk_step`` delegates here).
* ``repro.launch.serve.ServeSimulator`` — the fleet as a *service
  fabric*: W walkers route serving requests pinned to graph nodes (one
  batched :meth:`WalkFleet.advance` per tick; more walkers = more pickup
  bandwidth), the non-training consumer of the walker batch — see
  docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import WalkEngine
from repro.models import regression as reg
from repro.sharding.rules import (
    fleet_specs,
    named_shardings,
    resolve_walker_axis,
    walker_batch_specs,
)

__all__ = [
    "WalkFleet",
    "sample_initial_nodes",
    "migrate_walk_nodes",
    "fleet_average",
    "run_fleet",
    "shard_fleet",
    "shard_walker_batch",
    "make_fleet_step",
    "init_fleet_walk_state",
]


def sample_initial_nodes(
    n: int,
    num_walks: int,
    *,
    seed: int = 0,
    v0s: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """THE initial-node seeding + validation for every multi-walk path.

    ``v0s=None`` samples ``num_walks`` start nodes with
    ``np.random.default_rng(seed)`` (without replacement while the fleet
    fits the graph, with replacement beyond) — the exact stream the
    pre-fleet ``run_rw_sgd_multi`` and ``init_multi_walk_state`` each
    duplicated, now in one place so the regression and LLM paths sample
    identical fleets for the same seed.  Explicit ``v0s`` are validated
    (shape ``(num_walks,)``, every node in ``[0, n)``).
    """
    if v0s is None:
        rng = np.random.default_rng(seed)
        v0s = rng.choice(n, size=num_walks, replace=num_walks > n)
    v0s = np.asarray(v0s, np.int32)
    if v0s.shape != (num_walks,):
        raise ValueError(f"v0s must have shape ({num_walks},), got {v0s.shape}")
    if v0s.size and (int(v0s.min()) < 0 or int(v0s.max()) >= n):
        raise ValueError(
            f"v0s must be node ids in [0, {n}), got range "
            f"[{int(v0s.min())}, {int(v0s.max())}]"
        )
    return v0s


def migrate_walk_nodes(
    nodes,
    new_degrees,
    *,
    seed: int = 0,
):
    """THE walk-continuity rule across graph versions — see
    docs/dynamic_graphs.md.

    After an edge churn (``graphs.apply_edge_churn``), a walk standing on
    a node that is still *in* the new graph (degree > 1, i.e. any edge
    beyond the structural self-loop) carries its position unchanged —
    bitwise, no re-draw.  A walk standing on a **departed** node (degree
    exactly 1: self-loop only, unreachable for every other walk) is
    re-seeded through the existing :func:`sample_initial_nodes` stream
    over the surviving nodes: draw index ``w``'s node is
    ``active[sample_initial_nodes(len(active), W, seed=seed)[w]]`` with
    ``active`` the ascending in-graph node ids — documented here because
    the continuity test pins exactly this formula.  RNG continuity for
    surviving walks is free by construction: the fleet loops split one
    key stream over all W walks regardless of position, so carrying a
    position carries its uniform stream.

    Returns ``(new_nodes, displaced)``: the ``(W,)`` int32 positions and
    the boolean mask of re-seeded walks.
    """
    nodes_np = np.atleast_1d(np.asarray(nodes, np.int32))
    deg = np.asarray(new_degrees, np.int64)
    in_graph = deg > 1
    if not in_graph.any():
        raise ValueError(
            "no node of the churned graph has a non-loop edge; every walk "
            "would be displaced with nowhere to land"
        )
    if nodes_np.size and (
        int(nodes_np.min()) < 0 or int(nodes_np.max()) >= deg.size
    ):
        raise ValueError("walk positions out of range for the churned graph")
    displaced = ~in_graph[nodes_np]
    new_nodes = nodes_np.copy()
    if displaced.any():
        active = np.nonzero(in_graph)[0].astype(np.int32)
        draws = sample_initial_nodes(
            int(active.size), int(nodes_np.size), seed=seed
        )
        new_nodes[displaced] = active[draws[displaced]]
    return new_nodes, displaced


def fleet_average(tree, do_avg=None):
    """Cross-walker model average — THE ``avg_every`` collective.

    Every leaf is averaged over its leading walker axis and re-broadcast
    to all W walkers.  When the walker axis is sharded over a mesh axis
    (``repro.sharding.rules.fleet_specs``), XLA lowers the mean to an
    all-reduce along that axis — one model-sized collective, independent
    of W (each device contributes its local partial mean; see
    ``repro.walk_sgd.comm_model.fleet_averaging_traffic``).

    ``do_avg=None`` averages unconditionally; a traced boolean makes the
    average conditional per step (the ``(t + 1) % avg_every == 0`` gate of
    the fleet loops) while keeping shapes static.
    """

    def avg(p):
        m = jnp.broadcast_to(
            jnp.mean(p, axis=0, keepdims=True), p.shape
        ).astype(p.dtype)
        return m if do_avg is None else jnp.where(do_avg, m, p)

    return jax.tree_util.tree_map(avg, tree)


@dataclasses.dataclass(frozen=True, eq=False)
class WalkFleet:
    """W parallel walkers riding one batched engine — THE walker batch.

    ``nodes`` is the ``(W,)`` walk-position vector (a scalar for the
    one-walker LLM adapter, which keeps the engine's squeeze semantics),
    the ``walker`` logical axis of ``repro.sharding.rules``; ``engine``
    holds the replicated graph/row state.  Registered as a pytree
    (``engine``/``nodes`` are children, ``num_walks``/``avg_every`` ride
    as static aux data) so a fleet crosses ``jax.jit`` boundaries as a
    plain argument exactly like the engine itself does.
    """

    engine: WalkEngine
    nodes: jnp.ndarray  # (W,) int32 walk positions (scalar for W=1 adapter)
    num_walks: int = 1  # static
    avg_every: int = 0  # static: 0 = never average

    @classmethod
    def create(
        cls,
        engine: WalkEngine,
        num_walks: int,
        *,
        v0s: Optional[Sequence[int]] = None,
        seed: int = 0,
        avg_every: int = 0,
    ) -> "WalkFleet":
        """Fleet with :func:`sample_initial_nodes` seeding/validation."""
        n = int(engine.degrees.shape[0])
        v0 = sample_initial_nodes(n, num_walks, seed=seed, v0s=v0s)
        return cls(
            engine=engine,
            nodes=jnp.asarray(v0),
            num_walks=num_walks,
            avg_every=avg_every,
        )

    def migrate(self, engine: WalkEngine, *, seed: int = 0):
        """Carry this fleet onto a churned engine (next graph version).

        Applies :func:`migrate_walk_nodes` to the walk positions against
        the new engine's degree vector: surviving walks keep their
        position bitwise, walks on departed nodes re-seed via the
        documented :func:`sample_initial_nodes` path.  Returns
        ``(new_fleet, displaced)``; the scalar-``nodes`` W=1 adapter shape
        is preserved.
        """
        was_scalar = jnp.ndim(self.nodes) == 0
        new_nodes, displaced = migrate_walk_nodes(
            self.nodes, np.asarray(engine.degrees), seed=seed
        )
        nodes = jnp.asarray(
            new_nodes[0] if was_scalar else new_nodes, jnp.int32
        )
        return dataclasses.replace(self, engine=engine, nodes=nodes), displaced

    def advance(
        self,
        key: jax.Array,
        *,
        p_j=None,
        lipschitz: Optional[jnp.ndarray] = None,
    ):
        """ONE batched MHLJ transition for all W walkers.

        Returns ``(advanced_fleet, hops)``; ``hops`` is the Remark-1
        physical transition count per walker.
        """
        nxt, hops = self.engine.step(
            key, self.nodes, p_j=p_j, lipschitz=lipschitz
        )
        return dataclasses.replace(self, nodes=nxt), hops


def _fleet_flatten(f: WalkFleet):
    return (f.engine, f.nodes), (f.num_walks, f.avg_every)


def _fleet_unflatten(aux, children) -> WalkFleet:
    engine, nodes = children
    num_walks, avg_every = aux
    return WalkFleet(
        engine=engine, nodes=nodes, num_walks=num_walks, avg_every=avg_every
    )


jax.tree_util.register_pytree_node(WalkFleet, _fleet_flatten, _fleet_unflatten)


# ---------------------------------------------------------------------------
# THE fleet training scan (regression path): the single implementation that
# replaced trainer._run_scan (its W=1 case) and trainer._run_scan_multi.
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("num_steps", "use_weights", "loss_grad"),
)
def _fleet_scan(
    key,
    x0s,  # (W, dim) per-walker models
    features,
    targets,
    weights,  # (n,) L_bar / L_v (ones when unweighted)
    fleet: WalkFleet,  # pytree arg: arrays traced, W/avg_every/layout static
    num_steps: int,
    gamma: float,
    p_j_sched,  # (num_steps,)
    use_weights: bool,
    loss_grad,  # static callable: grad of per-node loss
):
    engine = fleet.engine
    avg_every = fleet.avg_every
    grad_w = jax.vmap(loss_grad, in_axes=(0, 0, 0))

    def step(carry, inputs):
        xs, vs, t = carry
        key_t, p_j_t = inputs
        gs = grad_w(xs, features[vs], targets[vs])  # (W, dim)
        ws = jnp.where(use_weights, weights[vs], 1.0)[:, None]
        xs_new = xs - gamma * ws * gs
        if avg_every > 0:
            do_avg = (t + 1) % avg_every == 0
            xs_new = fleet_average(xs_new, do_avg)
        vs_next, hops = engine.step(key_t, vs, p_j=p_j_t)  # ONE batched call
        mses = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
            xs_new, features, targets
        )
        avg_mse = reg.mse_objective(xs_new.mean(axis=0), features, targets)
        return (xs_new, vs_next, t + 1), (mses, avg_mse, vs, hops)

    keys = jax.random.split(key, num_steps)
    (xs_fin, _, _), (mses, avg_mses, nodes, hops) = jax.lax.scan(
        step, (x0s, fleet.nodes, jnp.int32(0)), (keys, p_j_sched)
    )
    mse0 = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
        x0s, features, targets
    )
    avg0 = reg.mse_objective(x0s.mean(axis=0), features, targets)
    return (
        xs_fin,
        jnp.concatenate([mse0[None], mses]).T,  # (W, T+1)
        jnp.concatenate([avg0[None], avg_mses]),  # (T+1,)
        nodes.T,  # (W, T) node holding the model at update t
        hops.T,  # (W, T)
    )


def shard_fleet(fleet: WalkFleet, mesh) -> WalkFleet:
    """Place a fleet on ``mesh``: walker-axis leaves sharded, engine
    replicated, and the engine made shard-aware.

    The fleet's ``nodes`` get the ``walker`` logical axis's mesh axis
    (``repro.sharding.rules.fleet_specs``; replication fallback when W
    does not divide the axis), every engine leaf — padded tables, ragged
    CSR state, the flat per-edge CDF — is replicated, and the engine is
    handed the walker ``NamedSharding`` so its ``step``/``run`` keep the
    per-walk uniforms and outputs partitioned over the walker axis
    (:meth:`repro.core.engine.WalkEngine.with_walker_sharding`).
    """
    specs = fleet_specs(fleet, mesh)
    fleet = jax.device_put(fleet, named_shardings(specs, mesh))
    walker_sharding = resolve_walker_axis(fleet.num_walks, mesh)
    if walker_sharding is not None:
        fleet = dataclasses.replace(
            fleet, engine=fleet.engine.with_walker_sharding(walker_sharding)
        )
    return fleet


def shard_walker_batch(tree, num_walks: int, mesh):
    """Place a walker-stacked pytree (leading ``(W, ...)`` leaves — stacked
    params/opt/walk state on the LLM path, ``x0s`` on the regression path)
    per ``repro.sharding.rules.walker_batch_specs``."""
    specs = walker_batch_specs(tree, num_walks, mesh)
    return jax.device_put(tree, named_shardings(specs, mesh))


def run_fleet(
    key: jax.Array,
    x0s: jnp.ndarray,  # (W, dim)
    features: jnp.ndarray,
    targets: jnp.ndarray,
    weights: jnp.ndarray,
    fleet: WalkFleet,
    num_steps: int,
    gamma: float,
    p_j_sched: jnp.ndarray,
    use_weights: bool,
    loss_grad: Callable,
    *,
    mesh=None,
):
    """Run the fleet training scan, optionally mesh-sharded.

    With ``mesh``, the walker batch (``x0s`` and the fleet's nodes) is
    sharded over the ``walker`` logical axis, graph/data state is
    replicated, and the scan's periodic :func:`fleet_average` lowers to an
    all-reduce along the walker mesh axis.  Without a mesh this is exactly
    the pre-fleet single-device scan — bitwise-identical per key
    (``tests/test_fleet.py`` pins both paths against the frozen
    pre-refactor oracle).

    Returns ``(x_final (W, dim), mse (W, T+1), avg_mse (T+1,),
    update_nodes (W, T), hops (W, T))``.
    """
    if mesh is not None:
        fleet = shard_fleet(fleet, mesh)
        x0s = shard_walker_batch(x0s, fleet.num_walks, mesh)
        repl = named_shardings(
            jax.tree_util.tree_map(lambda _: jax.sharding.PartitionSpec(),
                                   (features, targets, weights, p_j_sched)),
            mesh,
        )
        features, targets, weights, p_j_sched = jax.device_put(
            (features, targets, weights, p_j_sched), repl
        )
    return _fleet_scan(
        key,
        x0s,
        features,
        targets,
        weights,
        fleet,
        num_steps,
        gamma,
        p_j_sched,
        use_weights,
        loss_grad,
    )


# ---------------------------------------------------------------------------
# THE fleet step for the LLM path (pjit-sharded models): vmapped per-walker
# update + one batched walk advance + the periodic averaging collective.
# ---------------------------------------------------------------------------


def make_fleet_step(model, optimizer, walk, avg_every: int = 0) -> Callable:
    """Jittable ``(params_w, opt_w, walk_w, batches_w, step_idx)`` fleet
    step for the large-architecture path.

    Each leaf of ``params_w``/``opt_w``/``walk_w``/``batches_w`` carries a
    leading walker axis (shard with :func:`shard_walker_batch`).  The
    single-walker train step (``repro.walk_sgd.llm_trainer``'s update
    body, walk advance disabled) is vmapped over walkers, all W walk
    positions advance through ONE batched engine transition
    (``walk.advance_batched`` → :meth:`WalkFleet.advance`), and
    ``avg_every > 0`` applies :func:`fleet_average` every that many steps.
    ``multi_walk.make_multi_walk_step`` is a thin alias of this.
    """
    from repro.walk_sgd.llm_trainer import make_train_step

    single = make_train_step(model, optimizer, walk, advance_walk=False)
    vstep = jax.vmap(single)

    def fleet_step(params_w, opt_w, walk_w, batches_w, step_idx):
        params_w, opt_w, walk_w, metrics = vstep(
            params_w, opt_w, walk_w, batches_w
        )
        walk_w = walk.advance_batched(walk_w)
        if avg_every > 0:
            do_avg = (step_idx + 1) % avg_every == 0
            params_w = fleet_average(params_w, do_avg)
        return params_w, opt_w, walk_w, metrics

    return fleet_step


def init_fleet_walk_state(
    n_nodes: int,
    num_walks: int,
    lipschitz: Optional[np.ndarray] = None,
    v0s: Optional[Sequence[int]] = None,
    seed: int = 0,
    online: bool = False,
):
    """Stacked LLM walk states for a W-walker fleet.

    Start nodes come from :func:`sample_initial_nodes` (the same
    seeding/validation the regression fleet constructor uses, so both
    paths sample identical fleets per seed); each walker gets its own
    PRNG stream (``seed * 1009 + i``).  Every leaf carries a leading
    walker axis — shard with :func:`shard_walker_batch`.
    """
    from repro.walk_sgd.llm_trainer import init_walk_state

    v0s = sample_initial_nodes(n_nodes, num_walks, seed=seed, v0s=v0s)
    states = [
        init_walk_state(
            n_nodes, lipschitz, v0=int(v), seed=seed * 1009 + i, online=online
        )
        for i, v in enumerate(v0s)
    ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
