"""One fleet loop: the W-walker batch behind every walk-SGD training path.

The repo used to carry three divergent walk-SGD loops — the single-walk
``trainer._run_scan``, the batched ``trainer._run_scan_multi`` and the
LLM orchestrator's ``WalkContext.advance``/``make_train_step`` step — none
of which touched the mesh/sharding stack.  This module collapses them into
one **fleet** abstraction: the W walker batch (walk nodes, per-walker
model/optimizer state, per-walker PRNG streams on the LLM path) is one
pytree whose walker-batch leaves carry a leading ``(W, ...)`` axis, the
``walker`` logical axis of ``repro.sharding.rules``.  Sharded over the
mesh ``data`` axis (``repro.sharding.rules.fleet_specs`` /
``repro.launch.mesh.make_walker_mesh``) the fleet trains W walks across
devices off ONE batched :class:`~repro.core.engine.WalkEngine` transition
per step, with the graph state — padded tables, ragged CSR row state,
the flat per-edge CDF — **replicated** (walk positions are data-dependent
gathers into the graph; replication keeps them local).

Periodic cross-walker model averaging (``avg_every``-style local SGD) is
:func:`fleet_average`: a mean over the leading walker axis, which XLA
lowers to an all-reduce along the mesh axis the walker axis is sharded
over — so the only cross-device traffic of the fleet is one model-sized
collective every ``avg_every`` steps
(``repro.walk_sgd.comm_model.fleet_averaging_traffic`` prices it).

This is the multi-walker regime of the journal extension *Decentralized
Learning via Random Walk with Jumps* (arXiv:2604.12260): W independent
MHLJ walks over the same graph, each carrying its own model, periodically
averaged.  Averaging divides the Markov-sampling variance term of
Theorem 1 by ~W while the O(p_J^2) perturbation bias is unchanged — the
convergence-vs-num-walkers sweep in ``benchmarks/multi_walk.py`` /
``benchmarks/large_graph_walk.py`` measures exactly that.

Consumers (all three former loops route through here):

* ``repro.walk_sgd.trainer.run_rw_sgd`` — the W=1 case of
  :func:`run_fleet` (bitwise-identical per key to the pre-refactor
  single-walk scan; ``tests/test_fleet.py`` pins it against a frozen
  oracle copy).
* ``repro.walk_sgd.trainer.run_rw_sgd_multi`` — constructs a
  :class:`WalkFleet` and calls :func:`run_fleet`, optionally under a
  mesh.
* ``repro.walk_sgd.llm_trainer`` / ``repro.walk_sgd.multi_walk`` — thin
  consumers: ``WalkContext.advance`` advances a one-walker fleet, and
  :func:`make_fleet_step` is THE W-walker LLM step
  (``make_multi_walk_step`` delegates here).
* ``repro.launch.serve.ServeSimulator`` — the fleet as a *service
  fabric*: W walkers route serving requests pinned to graph nodes (one
  batched :meth:`WalkFleet.advance` per tick; more walkers = more pickup
  bandwidth), the non-training consumer of the walker batch — see
  docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import WalkEngine
from repro.models import regression as reg
from repro.sharding.rules import (
    fleet_specs,
    named_shardings,
    resolve_walker_axis,
    walker_batch_specs,
)

__all__ = [
    "WalkFleet",
    "sample_initial_nodes",
    "migrate_walk_nodes",
    "fleet_average",
    "run_fleet",
    "shard_fleet",
    "shard_walker_batch",
    "make_fleet_step",
    "init_fleet_walk_state",
    "save_fleet_checkpoint",
    "load_fleet_checkpoint",
]


def sample_initial_nodes(
    n: int,
    num_walks: int,
    *,
    seed: int = 0,
    v0s: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """THE initial-node seeding + validation for every multi-walk path.

    ``v0s=None`` samples ``num_walks`` start nodes with
    ``np.random.default_rng(seed)`` (without replacement while the fleet
    fits the graph, with replacement beyond) — the exact stream the
    pre-fleet ``run_rw_sgd_multi`` and ``init_multi_walk_state`` each
    duplicated, now in one place so the regression and LLM paths sample
    identical fleets for the same seed.  Explicit ``v0s`` are validated
    (shape ``(num_walks,)``, every node in ``[0, n)``).
    """
    if n <= 0:
        # total node failure or full-departure churn: say WHY seeding is
        # impossible instead of letting rng.choice/indexing fail opaquely
        raise ValueError(
            f"cannot seed {num_walks} walks: the active-node set is empty "
            f"(n={n}) — a graph with no live/in-graph nodes cannot host a "
            "fleet (total failure, or every node departed in a churn)"
        )
    if v0s is None:
        rng = np.random.default_rng(seed)
        v0s = rng.choice(n, size=num_walks, replace=num_walks > n)
    v0s = np.asarray(v0s, np.int32)
    if v0s.shape != (num_walks,):
        raise ValueError(f"v0s must have shape ({num_walks},), got {v0s.shape}")
    if v0s.size and (int(v0s.min()) < 0 or int(v0s.max()) >= n):
        raise ValueError(
            f"v0s must be node ids in [0, {n}), got range "
            f"[{int(v0s.min())}, {int(v0s.max())}]"
        )
    return v0s


def migrate_walk_nodes(
    nodes,
    new_degrees,
    *,
    seed: int = 0,
):
    """THE walk-continuity rule across graph versions — see
    docs/dynamic_graphs.md.

    After an edge churn (``graphs.apply_edge_churn``), a walk standing on
    a node that is still *in* the new graph (degree > 1, i.e. any edge
    beyond the structural self-loop) carries its position unchanged —
    bitwise, no re-draw.  A walk standing on a **departed** node (degree
    exactly 1: self-loop only, unreachable for every other walk) is
    re-seeded through the existing :func:`sample_initial_nodes` stream
    over the surviving nodes: draw index ``w``'s node is
    ``active[sample_initial_nodes(len(active), W, seed=seed)[w]]`` with
    ``active`` the ascending in-graph node ids — documented here because
    the continuity test pins exactly this formula.  RNG continuity for
    surviving walks is free by construction: the fleet loops split one
    key stream over all W walks regardless of position, so carrying a
    position carries its uniform stream.

    Returns ``(new_nodes, displaced)``: the ``(W,)`` int32 positions and
    the boolean mask of re-seeded walks.
    """
    nodes_np = np.atleast_1d(np.asarray(nodes, np.int32))
    deg = np.asarray(new_degrees, np.int64)
    in_graph = deg > 1
    if not in_graph.any():
        raise ValueError(
            "no node of the churned graph has a non-loop edge; every walk "
            "would be displaced with nowhere to land"
        )
    if nodes_np.size and (
        int(nodes_np.min()) < 0 or int(nodes_np.max()) >= deg.size
    ):
        raise ValueError("walk positions out of range for the churned graph")
    displaced = ~in_graph[nodes_np]
    new_nodes = nodes_np.copy()
    if displaced.any():
        active = np.nonzero(in_graph)[0].astype(np.int32)
        draws = sample_initial_nodes(
            int(active.size), int(nodes_np.size), seed=seed
        )
        new_nodes[displaced] = active[draws[displaced]]
    return new_nodes, displaced


def fleet_average(tree, do_avg=None):
    """Cross-walker model average — THE ``avg_every`` collective.

    Every leaf is averaged over its leading walker axis and re-broadcast
    to all W walkers.  When the walker axis is sharded over a mesh axis
    (``repro.sharding.rules.fleet_specs``), XLA lowers the mean to an
    all-reduce along that axis — one model-sized collective, independent
    of W (each device contributes its local partial mean; see
    ``repro.walk_sgd.comm_model.fleet_averaging_traffic``).

    ``do_avg=None`` averages unconditionally; a traced boolean makes the
    average conditional per step (the ``(t + 1) % avg_every == 0`` gate of
    the fleet loops) while keeping shapes static.
    """

    def avg(p):
        m = jnp.broadcast_to(
            jnp.mean(p, axis=0, keepdims=True), p.shape
        ).astype(p.dtype)
        return m if do_avg is None else jnp.where(do_avg, m, p)

    return jax.tree_util.tree_map(avg, tree)


@dataclasses.dataclass(frozen=True, eq=False)
class WalkFleet:
    """W parallel walkers riding one batched engine — THE walker batch.

    ``nodes`` is the ``(W,)`` walk-position vector (a scalar for the
    one-walker LLM adapter, which keeps the engine's squeeze semantics),
    the ``walker`` logical axis of ``repro.sharding.rules``; ``engine``
    holds the replicated graph/row state.  Registered as a pytree
    (``engine``/``nodes`` are children, ``num_walks``/``avg_every`` ride
    as static aux data) so a fleet crosses ``jax.jit`` boundaries as a
    plain argument exactly like the engine itself does.
    """

    engine: WalkEngine
    nodes: jnp.ndarray  # (W,) int32 walk positions (scalar for W=1 adapter)
    num_walks: int = 1  # static
    avg_every: int = 0  # static: 0 = never average

    @classmethod
    def create(
        cls,
        engine: WalkEngine,
        num_walks: int,
        *,
        v0s: Optional[Sequence[int]] = None,
        seed: int = 0,
        avg_every: int = 0,
    ) -> "WalkFleet":
        """Fleet with :func:`sample_initial_nodes` seeding/validation."""
        n = int(engine.degrees.shape[0])
        v0 = sample_initial_nodes(n, num_walks, seed=seed, v0s=v0s)
        return cls(
            engine=engine,
            nodes=jnp.asarray(v0),
            num_walks=num_walks,
            avg_every=avg_every,
        )

    def migrate(self, engine: WalkEngine, *, seed: int = 0):
        """Carry this fleet onto a churned engine (next graph version).

        Applies :func:`migrate_walk_nodes` to the walk positions against
        the new engine's degree vector: surviving walks keep their
        position bitwise, walks on departed nodes re-seed via the
        documented :func:`sample_initial_nodes` path.  Returns
        ``(new_fleet, displaced)``; the scalar-``nodes`` W=1 adapter shape
        is preserved.
        """
        was_scalar = jnp.ndim(self.nodes) == 0
        new_nodes, displaced = migrate_walk_nodes(
            self.nodes, np.asarray(engine.degrees), seed=seed
        )
        nodes = jnp.asarray(
            new_nodes[0] if was_scalar else new_nodes, jnp.int32
        )
        return dataclasses.replace(self, engine=engine, nodes=nodes), displaced

    def advance(
        self,
        key: jax.Array,
        *,
        p_j=None,
        lipschitz: Optional[jnp.ndarray] = None,
        faults=None,
    ):
        """ONE batched MHLJ transition for all W walkers.

        Returns ``(advanced_fleet, hops)``; ``hops`` is the Remark-1
        physical transition count per walker.  With
        ``faults=(FaultModel, FaultState)`` the transition is
        liveness-masked (docs/faults.md) and a third element carries the
        engine's fault aux (``blocked_steps`` — the caller's next
        ``FaultState.blocked`` — plus the ``fault_blocked``/``rescued``
        telemetry masks); ``faults=None`` is bitwise the pre-fault
        advance.
        """
        if faults is None:
            nxt, hops = self.engine.step(
                key, self.nodes, p_j=p_j, lipschitz=lipschitz
            )
            return dataclasses.replace(self, nodes=nxt), hops
        nxt, hops, aux = self.engine.step(
            key, self.nodes, p_j=p_j, lipschitz=lipschitz,
            with_aux=True, faults=faults,
        )
        return dataclasses.replace(self, nodes=nxt), hops, aux


    # -- crash consistency (docs/faults.md: "checkpoint format") ------------
    def checkpoint(self) -> dict:
        """Host-side snapshot: pytree → flat numpy arrays + static aux.

        Every engine data field becomes a plain ``np.ndarray`` (tuples of
        arrays, e.g. the bucketed ladder, stay tuples of arrays), engine
        statics ride in ``engine_meta`` and fleet statics at the top
        level.  ``walker_sharding`` is deliberately dropped — device
        placement is not state; re-place with :func:`shard_fleet` after
        :meth:`restore`.  :meth:`restore` of this dict resumes bitwise
        (``tests/test_faults.py`` pins a mid-run kill-and-restore).
        """
        from repro.core.engine import (
            _ENGINE_DATA_FIELDS,
            _ENGINE_META_FIELDS,
        )

        data = {}
        for f in _ENGINE_DATA_FIELDS:
            v = getattr(self.engine, f)
            if v is None:
                data[f] = None
            elif isinstance(v, tuple):
                data[f] = tuple(np.asarray(x) for x in v)
            else:
                data[f] = np.asarray(v)
        meta = {
            f: getattr(self.engine, f)
            for f in _ENGINE_META_FIELDS
            if f != "walker_sharding"
        }
        meta["walker_sharding"] = None
        # a python-float p_j is a static-style scalar; keep it one across
        # the round trip so the restored pytree has the same leaf set
        if isinstance(self.engine.p_j, float):
            data["p_j"] = float(self.engine.p_j)
        return {
            "version": 1,
            "num_walks": self.num_walks,
            "avg_every": self.avg_every,
            "nodes": np.asarray(self.nodes),
            "engine_data": data,
            "engine_meta": meta,
        }

    @classmethod
    def restore(cls, ckpt: dict) -> "WalkFleet":
        """Rebuild a fleet from :meth:`checkpoint` output — bitwise."""
        from repro.core.engine import WalkEngine as _Engine

        data = {}
        for f, v in ckpt["engine_data"].items():
            if v is None or isinstance(v, float):
                data[f] = v
            elif isinstance(v, tuple):
                data[f] = tuple(jnp.asarray(x) for x in v)
            else:
                data[f] = jnp.asarray(v)
        engine = _Engine(**data, **ckpt["engine_meta"])
        return cls(
            engine=engine,
            nodes=jnp.asarray(ckpt["nodes"]),
            num_walks=ckpt["num_walks"],
            avg_every=ckpt["avg_every"],
        )


def save_fleet_checkpoint(
    path: str,
    fleet: WalkFleet,
    *,
    step: int = 0,
    extras: Optional[dict] = None,
) -> str:
    """Crash-consistent fleet checkpoint on disk (atomic ``os.replace``).

    One ``.npz`` holding the :meth:`WalkFleet.checkpoint` arrays plus any
    ``extras`` arrays (per-walker models, a ``FaultState``'s leaves, the
    DADA round index — whatever the caller's loop carries), and a JSON
    sidecar entry for the static aux.  A crash mid-write never corrupts
    an existing checkpoint: the temp file is renamed into place only
    after a full flush.
    """
    import json
    import os
    import tempfile

    ckpt = fleet.checkpoint()
    arrays: dict = {"nodes": ckpt["nodes"]}
    none_fields, tuple_lens, scalar_fields = [], {}, {}
    for f, v in ckpt["engine_data"].items():
        if v is None:
            none_fields.append(f)
        elif isinstance(v, float):
            scalar_fields[f] = v
        elif isinstance(v, tuple):
            tuple_lens[f] = len(v)
            for i, x in enumerate(v):
                arrays[f"engine_data/{f}/{i}"] = x
        else:
            arrays[f"engine_data/{f}"] = v
    extras = extras or {}
    for name, x in extras.items():
        arrays[f"extras/{name}"] = np.asarray(x)
    meta = {
        "version": ckpt["version"],
        "num_walks": ckpt["num_walks"],
        "avg_every": ckpt["avg_every"],
        "step": int(step),
        "engine_meta": {
            k: (list(v) if isinstance(v, tuple) else v)
            for k, v in ckpt["engine_meta"].items()
        },
        "meta_tuples": [
            k for k, v in ckpt["engine_meta"].items() if isinstance(v, tuple)
        ],
        "none_fields": none_fields,
        "tuple_lens": tuple_lens,
        "scalar_fields": scalar_fields,
        "extras": sorted(extras),
    }
    arrays["meta_json"] = np.asarray(json.dumps(meta))
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez(fh, **arrays)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def load_fleet_checkpoint(path: str):
    """Load :func:`save_fleet_checkpoint` → ``(fleet, step, extras)``."""
    import json

    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta_json"]))
        data: dict = {f: None for f in meta["none_fields"]}
        data.update(meta["scalar_fields"])
        for f, k in meta["tuple_lens"].items():
            data[f] = tuple(z[f"engine_data/{f}/{i}"] for i in range(k))
        for key in z.files:
            if key.startswith("engine_data/") and key.count("/") == 1:
                data[key.split("/", 1)[1]] = z[key]
        engine_meta = {
            k: (tuple(v) if k in meta["meta_tuples"] and v is not None else v)
            for k, v in meta["engine_meta"].items()
        }
        fleet = WalkFleet.restore(
            {
                "version": meta["version"],
                "num_walks": meta["num_walks"],
                "avg_every": meta["avg_every"],
                "nodes": z["nodes"],
                "engine_data": data,
                "engine_meta": engine_meta,
            }
        )
        extras = {name: z[f"extras/{name}"] for name in meta["extras"]}
    return fleet, meta["step"], extras


def _fleet_flatten(f: WalkFleet):
    return (f.engine, f.nodes), (f.num_walks, f.avg_every)


def _fleet_unflatten(aux, children) -> WalkFleet:
    engine, nodes = children
    num_walks, avg_every = aux
    return WalkFleet(
        engine=engine, nodes=nodes, num_walks=num_walks, avg_every=avg_every
    )


jax.tree_util.register_pytree_node(WalkFleet, _fleet_flatten, _fleet_unflatten)


# ---------------------------------------------------------------------------
# THE fleet training scan (regression path): the single implementation that
# replaced trainer._run_scan (its W=1 case) and trainer._run_scan_multi.
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_steps", "use_weights", "loss_grad", "start_step", "total_steps",
    ),
)
def _fleet_scan(
    key,
    x0s,  # (W, dim) per-walker models
    features,
    targets,
    weights,  # (n,) L_bar / L_v (ones when unweighted)
    fleet: WalkFleet,  # pytree arg: arrays traced, W/avg_every/layout static
    num_steps: int,
    gamma: float,
    p_j_sched,  # (num_steps,)
    use_weights: bool,
    loss_grad,  # static callable: grad of per-node loss
    faults=None,  # (FaultModel, FaultState) or None — docs/faults.md
    start_step: int = 0,  # static: absolute index of the first step taken
    total_steps=None,  # static: absolute run length the key stream is cut
    #   from — split(key, total)[start : start + num] so a resumed window
    #   replays the exact keys of the uninterrupted run (bitwise)
):
    engine = fleet.engine
    avg_every = fleet.avg_every
    grad_w = jax.vmap(loss_grad, in_axes=(0, 0, 0))
    fmodel = faults[0] if faults is not None else None

    def step(carry, inputs):
        if faults is None:
            xs, vs, t = carry
            key_t, p_j_t = inputs
            alive_w = None
        else:
            # fault timeline per tick: the fault process advances first
            # (nodes crash/recover), THEN the walkers react — a walker on
            # a dead node computes no update (its compute is down), takes
            # no part in averaging, and its handoff is liveness-rejected.
            xs, vs, t, fstate = carry
            key_t, p_j_t = inputs
            key_t, key_f = jax.random.split(key_t)
            fstate = fmodel.advance(key_f, fstate)
            alive_w = fmodel.live_mask(fstate)[vs]  # (W,) walker liveness
        gs = grad_w(xs, features[vs], targets[vs])  # (W, dim)
        ws = jnp.where(use_weights, weights[vs], 1.0)[:, None]
        xs_new = xs - gamma * ws * gs
        if alive_w is not None:
            xs_new = jnp.where(alive_w[:, None], xs_new, xs)
        if avg_every > 0:
            do_avg = (t + 1) % avg_every == 0
            if alive_w is None:
                xs_new = fleet_average(xs_new, do_avg)
            else:
                # dead walkers are unreachable: they neither contribute to
                # nor receive the average (a parked model stays frozen and
                # drags the fleet only when it REJOINS — the stalled-worker
                # cost benchmarks/fault_sweep.py measures)
                w_live = alive_w.astype(xs_new.dtype)[:, None]
                mean = (xs_new * w_live).sum(axis=0, keepdims=True) / (
                    jnp.maximum(w_live.sum(), 1.0)
                )
                avg = jnp.broadcast_to(mean, xs_new.shape).astype(
                    xs_new.dtype
                )
                xs_new = jnp.where(
                    do_avg & alive_w[:, None], avg, xs_new
                )
        if faults is None:
            vs_next, hops = engine.step(key_t, vs, p_j=p_j_t)  # ONE batched call
        else:
            vs_next, hops, aux = engine.step(
                key_t, vs, p_j=p_j_t, with_aux=True, faults=(fmodel, fstate)
            )
            fstate = dataclasses.replace(
                fstate, blocked=aux["blocked_steps"]
            )
        mses = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
            xs_new, features, targets
        )
        avg_mse = reg.mse_objective(xs_new.mean(axis=0), features, targets)
        if faults is None:
            return (xs_new, vs_next, t + 1), (mses, avg_mse, vs, hops)
        return (
            (xs_new, vs_next, t + 1, fstate),
            (
                mses, avg_mse, vs, hops,
                aux["rescued"].sum(), aux["fault_blocked"].sum(),
            ),
        )

    total = num_steps if total_steps is None else total_steps
    keys = jax.random.split(key, total)[start_step:start_step + num_steps]
    t0 = jnp.int32(start_step)
    if faults is None:
        (xs_fin, vs_fin, _), (mses, avg_mses, nodes, hops) = jax.lax.scan(
            step, (x0s, fleet.nodes, t0), (keys, p_j_sched)
        )
        final = {"nodes": vs_fin, "fault_state": None, "rescued": None,
                 "blocked": None}
    else:
        (xs_fin, vs_fin, _, fstate_fin), (
            mses, avg_mses, nodes, hops, rescued, blocked
        ) = jax.lax.scan(
            step, (x0s, fleet.nodes, t0, faults[1]), (keys, p_j_sched)
        )
        final = {"nodes": vs_fin, "fault_state": fstate_fin,
                 "rescued": rescued, "blocked": blocked}
    mse0 = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
        x0s, features, targets
    )
    avg0 = reg.mse_objective(x0s.mean(axis=0), features, targets)
    return (
        xs_fin,
        jnp.concatenate([mse0[None], mses]).T,  # (W, T+1)
        jnp.concatenate([avg0[None], avg_mses]),  # (T+1,)
        nodes.T,  # (W, T) node holding the model at update t
        hops.T,  # (W, T)
        final,  # final walk positions + fault carry/telemetry (resume seam)
    )


def shard_fleet(fleet: WalkFleet, mesh) -> WalkFleet:
    """Place a fleet on ``mesh``: walker-axis leaves sharded, engine
    replicated, and the engine made shard-aware.

    The fleet's ``nodes`` get the ``walker`` logical axis's mesh axis
    (``repro.sharding.rules.fleet_specs``; replication fallback when W
    does not divide the axis), every engine leaf — padded tables, ragged
    CSR state, the flat per-edge CDF — is replicated, and the engine is
    handed the walker ``NamedSharding`` so its ``step``/``run`` keep the
    per-walk uniforms and outputs partitioned over the walker axis
    (:meth:`repro.core.engine.WalkEngine.with_walker_sharding`).
    """
    specs = fleet_specs(fleet, mesh)
    fleet = jax.device_put(fleet, named_shardings(specs, mesh))
    walker_sharding = resolve_walker_axis(fleet.num_walks, mesh)
    if walker_sharding is not None:
        fleet = dataclasses.replace(
            fleet, engine=fleet.engine.with_walker_sharding(walker_sharding)
        )
    return fleet


def shard_walker_batch(tree, num_walks: int, mesh):
    """Place a walker-stacked pytree (leading ``(W, ...)`` leaves — stacked
    params/opt/walk state on the LLM path, ``x0s`` on the regression path)
    per ``repro.sharding.rules.walker_batch_specs``."""
    specs = walker_batch_specs(tree, num_walks, mesh)
    return jax.device_put(tree, named_shardings(specs, mesh))


def run_fleet(
    key: jax.Array,
    x0s: jnp.ndarray,  # (W, dim)
    features: jnp.ndarray,
    targets: jnp.ndarray,
    weights: jnp.ndarray,
    fleet: WalkFleet,
    num_steps: int,
    gamma: float,
    p_j_sched: jnp.ndarray,
    use_weights: bool,
    loss_grad: Callable,
    *,
    mesh=None,
    faults=None,
    fault_state=None,
    start_step: int = 0,
    total_steps: Optional[int] = None,
):
    """Run the fleet training scan, optionally mesh-sharded.

    With ``mesh``, the walker batch (``x0s`` and the fleet's nodes) is
    sharded over the ``walker`` logical axis, graph/data state is
    replicated, and the scan's periodic :func:`fleet_average` lowers to an
    all-reduce along the walker mesh axis.  Without a mesh this is exactly
    the pre-fleet single-device scan — bitwise-identical per key
    (``tests/test_fleet.py`` pins both paths against the frozen
    pre-refactor oracle).

    ``faults`` takes a :class:`repro.core.faults.FaultModel` for the
    liveness-masked regime (docs/faults.md): nodes crash/recover per
    tick, dead walkers stop updating/averaging, blocked walkers past the
    model's patience take the forced live-restricted jump.
    ``fault_state`` resumes a recorded :class:`FaultState` (defaults to
    the all-live state at tick ``start_step``).

    ``start_step``/``total_steps`` are the crash-recovery seam: the scan
    burns ``split(key, total_steps)[start_step : start_step+num_steps]``,
    so running ``[0, k)`` — checkpointing via
    :func:`save_fleet_checkpoint` — then ``[k, T)`` replays the exact
    per-step keys of the uninterrupted ``[0, T)`` run (bitwise; pinned by
    ``tests/test_faults.py``).  Pass the matching ``p_j_sched`` window
    (``full_sched[start_step:start_step+num_steps]``).

    Returns ``(x_final (W, dim), mse (W, T+1), avg_mse (T+1,),
    update_nodes (W, T), hops (W, T), final)`` where ``final`` carries
    the resume state: ``final["nodes"]`` are the walk positions after the
    last step and, under faults, ``final["fault_state"]`` plus per-step
    ``final["rescued"]``/``final["blocked"]`` (T,) totals.
    """
    if start_step < 0:
        raise ValueError(f"start_step must be >= 0, got {start_step}")
    total = num_steps if total_steps is None else total_steps
    if start_step + num_steps > total:
        raise ValueError(
            f"window [{start_step}, {start_step + num_steps}) exceeds "
            f"total_steps={total}"
        )
    faults_arg = None
    if faults is not None:
        n = int(fleet.engine.degrees.shape[0])
        w = int(jnp.atleast_1d(fleet.nodes).shape[0])
        if fault_state is None:
            fault_state = faults.init_state(n, w)
            if start_step:
                fault_state = dataclasses.replace(
                    fault_state, t=jnp.int32(start_step)
                )
        faults_arg = (faults, fault_state)
    if mesh is not None:
        fleet = shard_fleet(fleet, mesh)
        x0s = shard_walker_batch(x0s, fleet.num_walks, mesh)
        repl = named_shardings(
            jax.tree_util.tree_map(lambda _: jax.sharding.PartitionSpec(),
                                   (features, targets, weights, p_j_sched)),
            mesh,
        )
        features, targets, weights, p_j_sched = jax.device_put(
            (features, targets, weights, p_j_sched), repl
        )
    return _fleet_scan(
        key,
        x0s,
        features,
        targets,
        weights,
        fleet,
        num_steps,
        gamma,
        p_j_sched,
        use_weights,
        loss_grad,
        faults_arg,
        start_step=start_step,
        total_steps=total_steps,
    )


# ---------------------------------------------------------------------------
# THE fleet step for the LLM path (pjit-sharded models): vmapped per-walker
# update + one batched walk advance + the periodic averaging collective.
# ---------------------------------------------------------------------------


def make_fleet_step(model, optimizer, walk, avg_every: int = 0) -> Callable:
    """Jittable ``(params_w, opt_w, walk_w, batches_w, step_idx)`` fleet
    step for the large-architecture path.

    Each leaf of ``params_w``/``opt_w``/``walk_w``/``batches_w`` carries a
    leading walker axis (shard with :func:`shard_walker_batch`).  The
    single-walker train step (``repro.walk_sgd.llm_trainer``'s update
    body, walk advance disabled) is vmapped over walkers, all W walk
    positions advance through ONE batched engine transition
    (``walk.advance_batched`` → :meth:`WalkFleet.advance`), and
    ``avg_every > 0`` applies :func:`fleet_average` every that many steps.
    ``multi_walk.make_multi_walk_step`` is a thin alias of this.
    """
    from repro.walk_sgd.llm_trainer import make_train_step

    single = make_train_step(model, optimizer, walk, advance_walk=False)
    vstep = jax.vmap(single)

    def fleet_step(params_w, opt_w, walk_w, batches_w, step_idx):
        params_w, opt_w, walk_w, metrics = vstep(
            params_w, opt_w, walk_w, batches_w
        )
        walk_w = walk.advance_batched(walk_w)
        if avg_every > 0:
            do_avg = (step_idx + 1) % avg_every == 0
            params_w = fleet_average(params_w, do_avg)
        return params_w, opt_w, walk_w, metrics

    return fleet_step


def init_fleet_walk_state(
    n_nodes: int,
    num_walks: int,
    lipschitz: Optional[np.ndarray] = None,
    v0s: Optional[Sequence[int]] = None,
    seed: int = 0,
    online: bool = False,
):
    """Stacked LLM walk states for a W-walker fleet.

    Start nodes come from :func:`sample_initial_nodes` (the same
    seeding/validation the regression fleet constructor uses, so both
    paths sample identical fleets per seed); each walker gets its own
    PRNG stream (``seed * 1009 + i``).  Every leaf carries a leading
    walker axis — shard with :func:`shard_walker_batch`.
    """
    from repro.walk_sgd.llm_trainer import init_walk_state

    v0s = sample_initial_nodes(n_nodes, num_walks, seed=seed, v0s=v0s)
    states = [
        init_walk_state(
            n_nodes, lipschitz, v0=int(v), seed=seed * 1009 + i, online=online
        )
        for i, v in enumerate(v0s)
    ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
