"""Dada-style learned collaboration graphs over walk-SGD.

The Dada line of work (Zantedeschi et al., AISTATS 2020) alternates two
phases: train models *on* the collaboration graph, then update the
collaboration graph itself from pairwise model similarity — nodes with
similar local models become neighbors, so collaboration concentrates
where it helps.  This module is that scenario end to end on the
dynamic-graph machinery:

1. one walk-SGD epoch through the ordinary trainer/fleet stack
   (:func:`repro.walk_sgd.trainer.run_rw_sgd_multi`, ``engine=`` seam);
2. **personalization**: every node takes a few local gradient steps on
   its own datum from the walk-averaged model
   (:func:`personalize_models`) — the per-node models whose similarity
   defines the new graph;
3. **rewiring**: mutual-k-nearest-neighbor edges in model space
   (:func:`similarity_edges`), applied as a *batched churn*
   (``graphs.apply_edge_churn``) so the engine's flat per-edge CDF is
   patched segment-locally (``WalkEngine.apply_churn``) instead of
   rebuilt, and the walk fleet carries across the graph version under
   the continuity rule (``fleet.migrate_walk_nodes``: surviving walks
   keep their position bitwise, displaced walks re-seed).

The loop never rebuilds row state from scratch after round one — the
whole point of the incremental churn path — and the per-round receipts
(edges churned, walks displaced, ``graph_version``) come back in the
:class:`DadaResult` so the dynamics are measurable, not anecdotal.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import WalkEngine
from repro.core.graphs import RaggedCSRGraph, _edges_to_csr, apply_edge_churn
from repro.core.transition import MHLJParams, mh_importance_rows_ragged
from repro.data.synthetic import RegressionData
from repro.models import regression as reg
from repro.walk_sgd.fleet import (
    WalkFleet,
    load_fleet_checkpoint,
    migrate_walk_nodes,
    save_fleet_checkpoint,
)
from repro.walk_sgd.trainer import run_rw_sgd_multi

__all__ = [
    "DadaResult",
    "personalize_models",
    "similarity_edges",
    "run_dada",
]


@dataclasses.dataclass
class DadaResult:
    """Per-round telemetry of one :func:`run_dada` run."""

    round_mse: np.ndarray  # (rounds,) walk-averaged-model MSE per round
    personalized_mse: np.ndarray  # (rounds,) mean per-node local sq. error
    edges_inserted: np.ndarray  # (rounds,) churn batch sizes (0 = no rewire)
    edges_deleted: np.ndarray  # (rounds,)
    walks_displaced: np.ndarray  # (rounds,) re-seeded walks entering round
    graph_versions: np.ndarray  # (rounds,) engine.graph_version per round
    x_final: np.ndarray  # (W, dim) final per-walk models
    method: str


def personalize_models(
    x_avg,
    features,
    targets,
    *,
    local_steps: int = 5,
    lr: float = 0.01,
) -> np.ndarray:
    """Per-node models: ``local_steps`` local gradient steps from ``x_avg``.

    Every node starts at the shared walk-averaged model and descends its
    own single-datum squared loss (``models.regression.linear_grad``,
    vmapped) — the Dada personalization phase whose resulting ``(n, dim)``
    model matrix feeds :func:`similarity_edges`.
    """
    if local_steps < 0:
        raise ValueError("local_steps must be >= 0")
    feats = jnp.asarray(features, jnp.float32)
    targs = jnp.asarray(targets, jnp.float32)
    x = jnp.broadcast_to(
        jnp.asarray(x_avg, jnp.float32)[None, :],
        (feats.shape[0], feats.shape[1]),
    )
    grad_all = jax.vmap(reg.linear_grad)
    for _ in range(local_steps):
        x = x - lr * grad_all(x, feats, targs)
    return np.asarray(x)


def _component_labels(indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """Connected-component label per node over a CSR structure (O(E) BFS)."""
    n = indptr.shape[0] - 1
    labels = np.full(n, -1, dtype=np.int64)
    c = 0
    for s in range(n):
        if labels[s] >= 0:
            continue
        labels[s] = c
        stack = [s]
        while stack:
            v = stack.pop()
            for u in indices[indptr[v] : indptr[v + 1]]:
                if labels[u] < 0:
                    labels[u] = c
                    stack.append(int(u))
        c += 1
    return labels


def similarity_edges(models: np.ndarray, k: int) -> np.ndarray:
    """Symmetrized k-nearest-neighbor edge set in model space.

    Each node proposes its ``k`` nearest peers by squared model distance
    (ties broken by node id — deterministic), proposals are symmetrized
    into undirected pairs, and — because a kNN graph may fragment — any
    secondary component is bridged to the first by one edge between the
    components' smallest-id members, so the result always yields a
    connected collaboration graph.  Returns a ``(E, 2)`` int64 canonical
    pair array ready for ``graphs.apply_edge_churn`` / ``from_edges``.
    """
    x = np.asarray(models, dtype=np.float64)
    n = x.shape[0]
    if x.ndim != 2 or n < 2:
        raise ValueError("models must be (n >= 2, dim)")
    if not (1 <= k < n):
        raise ValueError(f"similarity_edges needs 1 <= k < n, got k={k}")
    sq = (x * x).sum(axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    np.fill_diagonal(d2, np.inf)
    nn = np.argsort(d2, axis=1, kind="stable")[:, :k]
    src = np.repeat(np.arange(n, dtype=np.int64), k)
    dst = nn.ravel().astype(np.int64)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    codes = np.unique(lo * n + hi)
    pairs = np.stack([codes // n, codes % n], axis=1)
    indptr, indices, _ = _edges_to_csr(n, pairs[:, 0], pairs[:, 1])
    labels = _component_labels(indptr, indices)
    num_comp = int(labels.max()) + 1
    if num_comp > 1:
        reps = np.asarray(
            [int(np.nonzero(labels == c)[0][0]) for c in range(num_comp)],
            dtype=np.int64,
        )
        bridges = np.stack(
            [np.full(num_comp - 1, reps[0]), reps[1:]], axis=1
        )
        bridges = np.stack(
            [bridges.min(axis=1), bridges.max(axis=1)], axis=1
        )
        codes = np.unique(
            np.concatenate([codes, bridges[:, 0] * n + bridges[:, 1]])
        )
        pairs = np.stack([codes // n, codes % n], axis=1)
    return pairs


def _undirected_pairs(core) -> np.ndarray:
    """Canonical non-loop undirected pairs of a CSR-core graph."""
    n = core.n
    src = np.repeat(
        np.arange(n, dtype=np.int64), np.diff(np.asarray(core.indptr))
    )
    dst = np.asarray(core.indices, dtype=np.int64)
    keep = src < dst
    return np.stack([src[keep], dst[keep]], axis=1)


def run_dada(
    graph,
    data: RegressionData,
    *,
    rounds: int = 3,
    num_steps: int = 200,
    num_walks: int = 4,
    gamma: Optional[float] = None,
    k: int = 3,
    method: str = "mhlj",
    mhlj_params: Optional[MHLJParams] = None,
    avg_every: int = 25,
    local_steps: int = 5,
    local_lr: Optional[float] = None,
    seed: int = 0,
    backend: str = "auto",
    checkpoint_path: Optional[str] = None,
) -> DadaResult:
    """Alternate walk-SGD epochs with learned collaboration-graph updates.

    Per round: one ``num_steps``-step walk-SGD epoch of ``num_walks``
    walkers on the current graph (models carry over between rounds),
    personalization (:func:`personalize_models`), then — except after the
    final round — a kNN rewire (:func:`similarity_edges`) applied as a
    batched churn: ``apply_edge_churn`` diffs the edge sets,
    ``WalkEngine.apply_churn`` patches only the touched CDF segments, and
    ``migrate_walk_nodes`` carries the walk positions across the graph
    version (``k >= 1`` keeps every node in the graph, so displacement is
    the exception, not the rule).

    ``method`` must be a P_IS-row law (``"mhlj"`` or ``"importance"``) —
    the engine is carried across rounds with Eq.-7 rows built from
    ``data.lipschitz``, bit-for-bit the rows the plain trainer would
    build, so round one is bitwise-identical to an ordinary
    ``run_rw_sgd_multi`` call on the same seed.

    ``checkpoint_path`` makes the loop crash-consistent at round
    granularity (docs/faults.md): after every round the engine (with its
    churned graph state), the averaged model, the migrated walk
    positions and the per-round telemetry land in one atomic
    :func:`repro.walk_sgd.fleet.save_fleet_checkpoint` file; a rerun
    with the same path resumes at the first unfinished round and
    produces the uninterrupted run's result bitwise (per-round seeds are
    absolute, ``seed + rnd``).
    """
    if rounds < 1:
        raise ValueError("run_dada needs rounds >= 1")
    if method not in ("mhlj", "importance"):
        raise ValueError(
            "run_dada carries Eq.-7 P_IS rows across graph versions; "
            f"method must be 'mhlj' or 'importance', got {method!r}"
        )
    core = graph.to_ragged() if hasattr(graph, "to_ragged") else (
        graph.to_csr().to_ragged()
    )
    lips = np.asarray(data.lipschitz, dtype=np.float64)
    if gamma is None:
        gamma = 0.3 / float(lips.mean())
    if local_lr is None:
        local_lr = 0.5 / float(lips.max())
    if method == "mhlj":
        params = (
            mhlj_params if mhlj_params is not None
            else MHLJParams(p_j=0.1, p_d=0.5, r=3)
        )
        p_d, r = params.p_d, params.r
    else:
        params = mhlj_params
        p_d, r = 0.5, 1  # the trainer's no-jump engine shape

    engine = WalkEngine.from_graph(
        core,
        MHLJParams(p_j=0.0, p_d=p_d, r=r),
        row_probs=mh_importance_rows_ragged(core, lips),
        backend=backend,
        layout="ragged",
    )

    round_mse = np.zeros(rounds)
    personalized_mse = np.zeros(rounds)
    edges_inserted = np.zeros(rounds, dtype=np.int64)
    edges_deleted = np.zeros(rounds, dtype=np.int64)
    walks_displaced = np.zeros(rounds, dtype=np.int64)
    graph_versions = np.zeros(rounds, dtype=np.int64)
    x0 = None
    v0s = None
    res = None

    def _result(x_final: np.ndarray) -> DadaResult:
        return DadaResult(
            round_mse=round_mse,
            personalized_mse=personalized_mse,
            edges_inserted=edges_inserted,
            edges_deleted=edges_deleted,
            walks_displaced=walks_displaced,
            graph_versions=graph_versions,
            x_final=np.asarray(x_final),
            method=method,
        )

    def _ckpt(step: int, nodes, x_final=None) -> None:
        extras = {
            "x0": np.asarray(x0),
            "round_mse": round_mse,
            "personalized_mse": personalized_mse,
            "edges_inserted": edges_inserted,
            "edges_deleted": edges_deleted,
            "walks_displaced": walks_displaced,
            "graph_versions": graph_versions,
            "dada_rounds": np.int64(rounds),
            "dada_seed": np.int64(seed),
            "dada_num_steps": np.int64(num_steps),
        }
        if x_final is not None:
            extras["x_final"] = np.asarray(x_final)
        save_fleet_checkpoint(
            checkpoint_path,
            WalkFleet(
                engine=engine,
                nodes=jnp.asarray(np.asarray(nodes), jnp.int32),
                num_walks=num_walks,
            ),
            step=step,
            extras=extras,
        )

    start_round = 0
    if checkpoint_path is not None and os.path.exists(checkpoint_path):
        fleet, step, extras = load_fleet_checkpoint(checkpoint_path)
        saved = {
            k: int(extras[f"dada_{k}"])
            for k in ("rounds", "seed", "num_steps")
        }
        want = {"rounds": rounds, "seed": seed, "num_steps": num_steps}
        if saved != want or fleet.num_walks != num_walks:
            raise ValueError(
                f"checkpoint at {checkpoint_path!r} was written by a "
                f"different run_dada config (saved {saved} "
                f"num_walks={fleet.num_walks}, requested {want} "
                f"num_walks={num_walks}); refusing to resume"
            )
        engine = fleet.engine
        # the core graph IS the engine's CSR state — rebuild it host-side
        # with the canonical ragged dtypes so the rewire diff is bitwise
        # the one a fresh run would compute
        core = RaggedCSRGraph(
            indptr=np.asarray(engine.indptr, dtype=np.int64),
            indices=np.asarray(engine.indices, dtype=np.int32),
            degrees=np.asarray(engine.degrees, dtype=np.int32),
            name=core.name,
        )
        for name, arr in (
            ("round_mse", round_mse),
            ("personalized_mse", personalized_mse),
            ("edges_inserted", edges_inserted),
            ("edges_deleted", edges_deleted),
            ("walks_displaced", walks_displaced),
            ("graph_versions", graph_versions),
        ):
            arr[:] = extras[name]
        x0 = np.asarray(extras["x0"])
        v0s = np.asarray(fleet.nodes)
        start_round = int(step)
        if start_round >= rounds:
            return _result(extras["x_final"])

    for rnd in range(start_round, rounds):
        res = run_rw_sgd_multi(
            method,
            core,
            data,
            gamma,
            num_steps,
            num_walks,
            mhlj_params=params,
            x0=x0,
            v0s=v0s,
            avg_every=avg_every,
            seed=seed + rnd,
            engine=engine,
        )
        x0 = res.x_avg
        models = personalize_models(
            x0, data.features, data.targets,
            local_steps=local_steps, lr=local_lr,
        )
        preds = (models * np.asarray(data.features)).sum(axis=1)
        round_mse[rnd] = float(res.avg_mse[-1])
        personalized_mse[rnd] = float(
            ((preds - np.asarray(data.targets)) ** 2).mean()
        )
        graph_versions[rnd] = engine.graph_version
        if rnd == rounds - 1:
            if checkpoint_path is not None:
                _ckpt(rounds, res.update_nodes[:, -1], x_final=res.x_final)
            break
        # rewire: diff the current edge set against the kNN proposal and
        # apply the net churn incrementally
        desired = similarity_edges(models, k)
        current = _undirected_pairs(core)
        n = core.n
        des_codes = desired[:, 0] * n + desired[:, 1]
        cur_codes = current[:, 0] * n + current[:, 1]
        ins_codes = np.setdiff1d(des_codes, cur_codes)
        del_codes = np.setdiff1d(cur_codes, des_codes)
        edges_inserted[rnd] = ins_codes.size
        edges_deleted[rnd] = del_codes.size
        last_nodes = res.update_nodes[:, -1]
        if ins_codes.size or del_codes.size:
            ins = np.stack([ins_codes // n, ins_codes % n], axis=1)
            dele = np.stack([del_codes // n, del_codes % n], axis=1)
            core, churn = apply_edge_churn(
                core,
                insert=ins if ins_codes.size else None,
                delete=dele if del_codes.size else None,
            )
            # the escalated full rebuild (max degree outgrew the engine's
            # cdf_width) needs row probabilities for EVERY row, not just
            # the touched closure
            need_full = int(np.asarray(core.degrees).max()) > engine.cdf_width
            engine = engine.apply_churn(
                core,
                churn,
                touched_probs=mh_importance_rows_ragged(
                    core, lips,
                    node_ids=None if need_full else churn.touched_rows,
                ),
            )
        v0s, displaced = migrate_walk_nodes(
            last_nodes, np.asarray(core.degrees), seed=seed + 7919 * (rnd + 1)
        )
        walks_displaced[rnd + 1] = int(displaced.sum())
        if checkpoint_path is not None:
            _ckpt(rnd + 1, v0s)

    return _result(res.x_final)
