"""Walk-orchestrated training for the large architectures (pjit-sharded).

The paper's loop at datacenter scale (DESIGN.md §2): the random-walk state
(current silo, RNG, per-silo Lipschitz estimates) is carried INSIDE the jitted
train_step — the MHLJ transition (Algorithm 1) executes on-device each step,
so the sampled silo sequence is part of the compiled program and the host
pipeline just feeds the batch for the *announced* node (walk_state is
replicated; its node id is fetched asynchronously by the input pipeline).

The MH-IS transition probabilities are computed ON THE FLY from the current
Lipschitz vector (Eq. 7 needs only deg(v), deg(u), L_v, L_u — local
information), which supports both the paper's static L_v and the online EMA
estimator for losses without closed-form smoothness (DESIGN.md §2).

The MHLJ transition itself is NOT implemented here: ``WalkContext`` is a
thin adapter over :class:`repro.core.engine.WalkEngine`, the single source
of truth for Algorithm 1 (live Eq.-7 rows via ``engine.p_is_rows``), and
the walk advance routes through the fleet abstraction
(``repro.walk_sgd.fleet.WalkFleet`` — ``advance`` is the one-walker
fleet, ``advance_batched`` the W-walker fleet; the W-walker *training*
step lives in ``repro.walk_sgd.fleet.make_fleet_step``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import WalkEngine
from repro.core.graphs import Graph
from repro.core.importance import param_fingerprint
from repro.core.transition import MHLJParams
from repro.models.base import Model
from repro.optim.base import GradientTransformation, apply_updates, global_norm
from repro.walk_sgd.fleet import WalkFleet

__all__ = ["WalkContext", "make_train_step", "make_serve_step", "init_walk_state"]


@dataclasses.dataclass(frozen=True)
class WalkContext:
    """Device-resident graph + MHLJ hyper-parameters (all small tensors)."""

    neighbors: jnp.ndarray  # (n, max_deg) int32, padded with self id
    degrees: jnp.ndarray  # (n,) int32
    p_j: float
    p_d: float
    r: int
    online_lipschitz: bool = False
    lipschitz_ema: float = 0.9
    # importance-weight clip range: online L_v estimates are noisy early on
    # and w = L_bar/L_v multiplies the gradient; unclipped extremes (measured
    # 0.1-6x within 200 steps) destabilize adaptive optimizers.  The paper's
    # exact closed-form-L_v setting corresponds to clip = (0, inf).
    weight_clip: tuple = (0.1, 10.0)

    @classmethod
    def from_graph(
        cls, graph: Graph, params: MHLJParams, online_lipschitz: bool = False
    ) -> "WalkContext":
        return cls(
            neighbors=jnp.asarray(graph.neighbors),
            degrees=jnp.asarray(graph.degrees),
            p_j=params.p_j,
            p_d=params.p_d,
            r=params.r,
            online_lipschitz=online_lipschitz,
        )

    # -- transition machinery (all shapes static, jit-safe) -----------------

    def engine(self) -> WalkEngine:
        """The unified Algorithm-1 sampler; rows come live from the current
        Lipschitz vector (Eq. 7), so no table is precomputed here."""
        return WalkEngine(
            neighbors=self.neighbors,
            degrees=self.degrees,
            p_j=self.p_j,
            p_d=self.p_d,
            r=self.r,
            backend="scan",
        )

    def advance(self, state: dict) -> dict:
        """Advance one walk state: the one-walker case of the fleet loop
        (``repro.walk_sgd.fleet.WalkFleet.advance`` over a scalar node —
        the engine's squeeze semantics make it bitwise-identical to the
        historical direct ``engine.step`` call)."""
        key, key_step = jax.random.split(state["rng"])
        fleet = WalkFleet(engine=self.engine(), nodes=state["node"], num_walks=1)
        fleet, hops = fleet.advance(
            key_step,
            p_j=state.get("p_j", self.p_j),
            lipschitz=state["lipschitz"],
        )
        return {
            **state,
            "rng": key,
            "node": fleet.nodes.astype(jnp.int32),
            "hops": state["hops"] + hops,
            "updates": state["updates"] + 1,
        }

    def advance_batched(self, states: dict) -> dict:
        """Advance W stacked walk states (leading walk axis on every leaf) —
        the fleet advance used by ``repro.walk_sgd.fleet.make_fleet_step``."""
        return jax.vmap(self.advance)(states)

    def weight(self, state: dict) -> jnp.ndarray:
        """Importance weight w(v) = L_bar / L_v (Eq. 12), clipped when the
        online estimator is active (exact L_v needs no clip)."""
        lips = state["lipschitz"]
        w = jnp.mean(lips) / lips[state["node"]]
        if self.online_lipschitz and self.weight_clip is not None:
            w = jnp.clip(w, *self.weight_clip)
        return w

    def update_lipschitz(self, state: dict, grad_norm, param_fp) -> dict:
        """Online EMA secant estimate of L_v (DESIGN.md adaptation)."""
        if not self.online_lipschitz:
            return state
        v = state["node"]
        prev_g = state["last_grad_norm"][v]
        prev_f = state["last_param_fp"][v]
        seen = state["visited"][v]
        secant = jnp.abs(grad_norm - prev_g) / jnp.maximum(jnp.abs(param_fp - prev_f), 1e-8)
        secant = jnp.clip(secant, 1e-3, 1e3)
        old = state["lipschitz"][v]
        new = jnp.where(seen, self.lipschitz_ema * old + (1 - self.lipschitz_ema) * secant, old)
        return {
            **state,
            "lipschitz": state["lipschitz"].at[v].set(new),
            "last_grad_norm": state["last_grad_norm"].at[v].set(grad_norm),
            "last_param_fp": state["last_param_fp"].at[v].set(param_fp),
            "visited": state["visited"].at[v].set(True),
        }


def init_walk_state(
    n_nodes: int,
    lipschitz: Optional[np.ndarray] = None,
    v0: int = 0,
    seed: int = 0,
    online: bool = False,
) -> dict:
    state = {
        "node": jnp.asarray(v0, jnp.int32),
        "rng": jax.random.PRNGKey(seed),
        "lipschitz": (
            jnp.asarray(lipschitz, jnp.float32)
            if lipschitz is not None
            else jnp.ones((n_nodes,), jnp.float32)
        ),
        "hops": jnp.zeros((), jnp.int32),
        "updates": jnp.zeros((), jnp.int32),
    }
    if online:
        state.update(
            last_grad_norm=jnp.zeros((n_nodes,), jnp.float32),
            last_param_fp=jnp.zeros((n_nodes,), jnp.float32),
            visited=jnp.zeros((n_nodes,), bool),
        )
    return state


def make_train_step(
    model: Model,
    optimizer: GradientTransformation,
    walk: WalkContext,
    advance_walk: bool = True,
) -> Callable:
    """Jittable (params, opt_state, walk_state, batch) -> updated + metrics.

    ``advance_walk=False`` leaves the walk position untouched so a caller
    managing W stacked walks can advance them all in one batched engine
    transition (``walk.advance_batched`` / ``multi_walk.make_multi_walk_step``).
    """

    def train_step(params, opt_state, walk_state, batch):
        (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        w = walk.weight(walk_state)
        grads = jax.tree_util.tree_map(lambda g: g * w.astype(g.dtype), grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        if walk.online_lipschitz:
            gn = global_norm(grads)
            # random-projection fingerprint, NOT ||params||: equal-norm
            # param states must not collapse the secant denominator
            fp = param_fingerprint(params)
            walk_state = walk.update_lipschitz(walk_state, gn, fp)
        if advance_walk:
            walk_state = walk.advance(walk_state)
        metrics = {"loss": loss, "weight": w, **aux}
        return params, opt_state, walk_state, metrics

    return train_step


def make_serve_step(model: Model) -> Callable:
    """Jittable batched greedy decode step: (params, cache, tokens, pos)."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, tokens, cache, pos)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, cache

    return serve_step
