"""Beyond-paper: W parallel MHLJ walks with periodic parameter averaging.

The paper's algorithm is a SINGLE walk — communication-minimal but
sequential.  At datacenter scale the multi-pod mesh gives us W pods; we run
one independent MHLJ walk per pod and average parameters every
``avg_every`` updates (a token-algorithm analogue of local-SGD/FedAvg).

Averaging W walks divides the Markov-sampling variance term of Theorem 1 by
~W while keeping per-walk communication at the paper's Remark-1 budget; the
only extra cost is one all-reduce of the parameters every ``avg_every``
steps over the 'pod' axis.  The error-gap term is unchanged (each walk runs
the same perturbed chain).  Benchmarked against the faithful single walk in
benchmarks/ (EXPERIMENTS.md §Perf "beyond-paper").

Implementation: parameters/optimizer/walk states are stacked on a leading
walk axis and the single-walk train step is vmapped (with its per-walk
advance disabled); all W walk positions then advance together through ONE
batched transition of the unified Algorithm-1 sampler
(``core.engine.WalkEngine`` via ``WalkContext.advance_batched``).  On the
production mesh the walk axis is sharded over 'pod' so each pod executes
exactly one walk.  ``average_params`` is the periodic all-reduce.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import Model
from repro.optim.base import GradientTransformation
from repro.walk_sgd.llm_trainer import WalkContext, init_walk_state, make_train_step

__all__ = [
    "init_multi_walk_state",
    "stack_params",
    "make_multi_walk_step",
    "average_params",
]


def stack_params(params, num_walks: int):
    """Replicate a param pytree along a new leading walk axis."""
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (num_walks,) + p.shape), params
    )


def init_multi_walk_state(
    n_nodes: int,
    num_walks: int,
    lipschitz: Optional[np.ndarray] = None,
    v0s: Optional[Sequence[int]] = None,
    seed: int = 0,
):
    """Stacked walk states with distinct start nodes and RNG streams."""
    if v0s is None:
        rng = np.random.default_rng(seed)
        v0s = rng.choice(n_nodes, size=num_walks, replace=num_walks > n_nodes)
    states = [
        init_walk_state(n_nodes, lipschitz, v0=int(v), seed=seed * 1009 + i)
        for i, v in enumerate(v0s)
    ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def average_params(params_w):
    """All-walk parameter average, re-broadcast to every walk (the periodic
    'pod'-axis all-reduce; XLA lowers the mean to an all-reduce when the
    walk axis is sharded over 'pod')."""
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(
            jnp.mean(p, axis=0, keepdims=True), p.shape
        ).astype(p.dtype),
        params_w,
    )


def make_multi_walk_step(
    model: Model,
    optimizer: GradientTransformation,
    walk: WalkContext,
    avg_every: int = 0,
) -> Callable:
    """Jittable (params_w, opt_w, walk_w, batches_w, step_idx) -> updated.

    ``batches_w`` carries one batch per walk (leading walk axis).  When
    ``avg_every > 0``, parameters are averaged across walks every
    ``avg_every`` steps (local-SGD style).
    """
    single = make_train_step(model, optimizer, walk, advance_walk=False)
    vstep = jax.vmap(single)

    def step(params_w, opt_w, walk_w, batches_w, step_idx):
        params_w, opt_w, walk_w, metrics = vstep(params_w, opt_w, walk_w, batches_w)
        walk_w = walk.advance_batched(walk_w)
        if avg_every > 0:
            do_avg = (step_idx + 1) % avg_every == 0
            params_w = jax.tree_util.tree_map(
                lambda avg, raw: jnp.where(do_avg, avg, raw),
                average_params(params_w),
                params_w,
            )
        return params_w, opt_w, walk_w, metrics

    return step
