"""W parallel MHLJ walks with periodic parameter averaging — thin consumer
of the fleet abstraction (``repro.walk_sgd.fleet``).

The paper's algorithm is a SINGLE walk — communication-minimal but
sequential.  The journal extension (arXiv:2604.12260) analyzes W
independent walks whose models are averaged every ``avg_every`` updates (a
token-algorithm analogue of local-SGD/FedAvg): averaging divides the
Markov-sampling variance term of Theorem 1 by ~W while keeping per-walk
communication at the paper's Remark-1 budget; the only extra cost is one
all-reduce of the parameters per averaging round along the walker mesh
axis.  The error-gap term is unchanged (each walk runs the same perturbed
chain).  Benchmarked in ``benchmarks/multi_walk.py`` and the fleet sweep
of ``benchmarks/large_graph_walk.py``.

This module is the historical entry point for the large-architecture
path; every function now delegates to the single fleet implementation:
``make_multi_walk_step`` is ``repro.walk_sgd.fleet.make_fleet_step``
(vmapped per-walker update + ONE batched engine transition + the
conditional :func:`~repro.walk_sgd.fleet.fleet_average` collective),
``init_multi_walk_state`` seeds start nodes through
``repro.walk_sgd.fleet.sample_initial_nodes`` — the same
seeding/validation the regression fleet constructor uses — and
``average_params`` is the unconditional fleet average.  Shard the stacked
states over the mesh with ``repro.walk_sgd.fleet.shard_walker_batch``.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import Model
from repro.optim.base import GradientTransformation
from repro.walk_sgd.fleet import (
    fleet_average,
    init_fleet_walk_state,
    make_fleet_step,
)
from repro.walk_sgd.llm_trainer import WalkContext

__all__ = [
    "init_multi_walk_state",
    "stack_params",
    "make_multi_walk_step",
    "average_params",
]


def stack_params(params, num_walks: int):
    """Replicate a param pytree along a new leading walk axis."""
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (num_walks,) + p.shape), params
    )


def init_multi_walk_state(
    n_nodes: int,
    num_walks: int,
    lipschitz: Optional[np.ndarray] = None,
    v0s: Optional[Sequence[int]] = None,
    seed: int = 0,
):
    """Stacked walk states with distinct start nodes and RNG streams
    (``repro.walk_sgd.fleet.init_fleet_walk_state``)."""
    return init_fleet_walk_state(
        n_nodes, num_walks, lipschitz=lipschitz, v0s=v0s, seed=seed
    )


def average_params(params_w):
    """All-walk parameter average, re-broadcast to every walk — the
    unconditional ``repro.walk_sgd.fleet.fleet_average`` (XLA lowers the
    mean to an all-reduce when the walk axis is sharded over a mesh
    axis)."""
    return fleet_average(params_w)


def make_multi_walk_step(
    model: Model,
    optimizer: GradientTransformation,
    walk: WalkContext,
    avg_every: int = 0,
) -> Callable:
    """Jittable (params_w, opt_w, walk_w, batches_w, step_idx) -> updated.

    Alias of ``repro.walk_sgd.fleet.make_fleet_step`` — THE W-walker fleet
    step.  ``batches_w`` carries one batch per walk (leading walk axis).
    When ``avg_every > 0``, parameters are averaged across walks every
    ``avg_every`` steps (local-SGD style).
    """
    return make_fleet_step(model, optimizer, walk, avg_every)
