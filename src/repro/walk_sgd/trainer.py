"""Full-JAX random-walk SGD trainer (paper Algorithm 1 + baselines).

Runs the *entire* T-iteration training as one ``lax.scan``: per iteration the
carried state is (model x, walk position v); the update applies the
importance-weighted stochastic gradient of the visited node's local loss
(Eq. 12), and the walk advances per the chosen method:

  method='uniform'    MH targeting uniform pi, plain gradient (w=1)
  method='importance' MH-IS (Eq. 7), weighted gradient w(v)=L_bar/L_v
  method='mhlj'       Algorithm 1 (MH-IS + Levy jumps), weighted gradient
  method='simple'     simple random walk, plain gradient (degree-biased)

This is the regression-scale engine used for the paper's figures; the
pjit-sharded LLM engine is ``walk_sgd.llm_trainer``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transition as trans_mod
from repro.core.graphs import Graph
from repro.core.levy import trunc_geom_pmf
from repro.core.transition import MHLJParams
from repro.core.walk import graph_tensors
from repro.data.synthetic import RegressionData
from repro.models import regression as reg

__all__ = ["RWSGDResult", "run_rw_sgd"]

METHODS = ("uniform", "importance", "mhlj", "simple")


@dataclasses.dataclass
class RWSGDResult:
    mse: np.ndarray  # (T+1,) objective trace (paper Fig-3 metric)
    update_nodes: np.ndarray  # (T,)
    transitions: np.ndarray  # (T,) physical hops per update (Remark 1)
    x_final: np.ndarray
    method: str

    @property
    def transitions_per_update(self) -> float:
        return float(self.transitions.mean())


@functools.partial(
    jax.jit,
    static_argnames=("num_steps", "r", "p_d", "use_weights", "use_jumps", "loss_grad"),
)
def _run_scan(
    key,
    x0,
    features,
    targets,
    weights,  # (n,) L_bar / L_v (ones when unweighted)
    row_probs,  # (n, max_deg)
    neighbors,
    degrees,
    v0,
    num_steps: int,
    gamma: float,
    p_j_sched,  # (num_steps,)
    p_d: float,
    r: int,
    use_weights: bool,
    use_jumps: bool,
    loss_grad,  # static callable: grad of per-node loss
):
    d_logits = jnp.log(jnp.asarray(trunc_geom_pmf(p_d, r), jnp.float32)) if use_jumps else None

    def mh_move(key_m, v):
        probs = row_probs[v]
        logits = jnp.where(probs > 0, jnp.log(jnp.maximum(probs, 1e-38)), -jnp.inf)
        idx = jax.random.categorical(key_m, logits)
        return neighbors[v, idx], jnp.int32(1)

    def jump(key_j, v):
        key_d, key_hops = jax.random.split(key_j)
        d = 1 + jax.random.categorical(key_d, d_logits)
        hop_keys = jax.random.split(key_hops, r)

        def hop(i, v_cur):
            idx = jax.random.randint(hop_keys[i], (), 0, degrees[v_cur])
            v_new = neighbors[v_cur, idx]
            return jnp.where(i < d, v_new, v_cur)

        return jax.lax.fori_loop(0, r, hop, v), d.astype(jnp.int32)

    def step(carry, inputs):
        x, v = carry
        key_t, p_j_t = inputs
        g = loss_grad(x, features[v], targets[v])
        w = jnp.where(use_weights, weights[v], 1.0)
        x_new = x - gamma * w * g

        key_b, key_mv = jax.random.split(key_t)
        if use_jumps:
            do_jump = jax.random.bernoulli(key_b, p_j_t)
            v_jump, d_jump = jump(key_mv, v)
            v_mh, d_mh = mh_move(key_mv, v)
            v_next = jnp.where(do_jump, v_jump, v_mh)
            hops = jnp.where(do_jump, d_jump, d_mh)
        else:
            v_next, hops = mh_move(key_mv, v)

        mse = reg.mse_objective(x_new, features, targets)
        return (x_new, v_next), (mse, v, hops)

    keys = jax.random.split(key, num_steps)
    (x_fin, _), (mses, nodes, hops) = jax.lax.scan(
        step, (x0, jnp.asarray(v0, jnp.int32)), (keys, p_j_sched)
    )
    mse0 = reg.mse_objective(x0, features, targets)
    return x_fin, jnp.concatenate([mse0[None], mses]), nodes, hops


def run_rw_sgd(
    method: str,
    graph: Graph,
    data: RegressionData,
    gamma: float,
    num_steps: int,
    *,
    mhlj_params: Optional[MHLJParams] = None,
    p_j_schedule: Optional[np.ndarray] = None,
    loss: str = "linear",
    x0: Optional[np.ndarray] = None,
    v0: int = 0,
    seed: int = 0,
) -> RWSGDResult:
    """Run one RW-SGD training; returns the Fig-3 style MSE trace."""
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}")
    lips = data.lipschitz
    if method == "uniform":
        p = trans_mod.mh_uniform(graph)
        use_weights, use_jumps = False, False
    elif method == "simple":
        p = trans_mod.simple_rw(graph)
        use_weights, use_jumps = False, False
    elif method == "importance":
        p = trans_mod.mh_importance(graph, lips)
        use_weights, use_jumps = True, False
    else:  # mhlj
        mhlj_params = mhlj_params or MHLJParams()
        mhlj_params.validate()
        p = trans_mod.mh_importance(graph, lips)  # MH part; jumps sampled live
        use_weights, use_jumps = True, True

    row_probs = jnp.asarray(trans_mod.row_probs_padded(p, graph))
    neighbors, degrees = graph_tensors(graph)
    weights = jnp.asarray(lips.mean() / lips, jnp.float32)

    if use_jumps:
        if p_j_schedule is not None:
            p_j_sched = jnp.asarray(p_j_schedule, jnp.float32)
            if p_j_sched.shape != (num_steps,):
                raise ValueError("p_j_schedule must have shape (num_steps,)")
        else:
            p_j_sched = jnp.full((num_steps,), mhlj_params.p_j, jnp.float32)
        p_d, r = mhlj_params.p_d, mhlj_params.r
    else:
        p_j_sched = jnp.zeros((num_steps,), jnp.float32)
        p_d, r = 0.5, 1  # unused

    grad_fn = {"linear": reg.linear_grad, "logistic": reg.logistic_grad}[loss]
    x0 = jnp.zeros(data.dim, jnp.float32) if x0 is None else jnp.asarray(x0, jnp.float32)

    x_fin, mses, nodes, hops = _run_scan(
        jax.random.PRNGKey(seed),
        x0,
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights,
        row_probs,
        neighbors,
        degrees,
        v0,
        num_steps,
        gamma,
        p_j_sched,
        p_d,
        r,
        use_weights,
        use_jumps,
        grad_fn,
    )
    return RWSGDResult(
        mse=np.asarray(mses),
        update_nodes=np.asarray(nodes),
        transitions=np.asarray(hops),
        x_final=np.asarray(x_fin),
        method=method,
    )
