"""Full-JAX random-walk SGD trainer (paper Algorithm 1 + baselines).

Runs the *entire* T-iteration training as one ``lax.scan``: per iteration the
carried state is (model x, walk position v); the update applies the
importance-weighted stochastic gradient of the visited node's local loss
(Eq. 12), and the walk advances per the chosen method:

  method='uniform'       MH targeting uniform pi, plain gradient (w=1)
  method='importance'    MH-IS (Eq. 7), weighted gradient w(v)=L_bar/L_v
  method='mhlj'          Algorithm 1 (MH-IS + Levy jumps), weighted gradient
  method='simple'        simple random walk, plain gradient (degree-biased)
  method='heterogeneity' MH targeting the gradient-heterogeneity-optimized
                         pi of ``repro.core.heterogeneity`` (Dandi et al.,
                         arXiv:2204.06477), weighted gradient w ∝ 1/pi
  method='private'       private weighted walk on Gamma-noised weights
                         (Ayache & El Rouayheb, arXiv:2009.01790), weighted
                         gradient w ∝ 1/ŵ; ``law_kwargs={"gamma": ...}``
                         sets the privacy knob

The walk advances through :class:`repro.core.engine.WalkEngine` (the single
implementation of the MHLJ transition); non-jump methods are the engine at
p_J = 0.  The engine is built once per training run from the graph —
``Graph``, ``CSRGraph``, ``BucketedCSRGraph`` or ``RaggedCSRGraph`` — and
passed *into* the jitted scan as a pytree argument, so every layout rides
the identical training loop.

There is exactly ONE training scan: ``repro.walk_sgd.fleet.run_fleet``,
the W-walker fleet loop.  :func:`run_rw_sgd` is its W=1 case (bitwise
identical per key to the historical single-walk scan — the engine's
uniform block for one walk is the same whether the node is scalar or a
``(1,)`` batch) and :func:`run_rw_sgd_multi` is the fleet-construction
seam: it builds a :class:`repro.walk_sgd.fleet.WalkFleet` and, given a
``mesh``, shards the walker batch over the ``walker`` logical axis of
``repro.sharding.rules`` so W walks train across devices with the
periodic model average running as one collective.

This is the regression-scale trainer used for the paper's figures; the
pjit-sharded LLM engine is ``walk_sgd.llm_trainer`` (whose W-walker step
is the same fleet abstraction — ``repro.walk_sgd.fleet.make_fleet_step``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heterogeneity as het_mod
from repro.core import transition as trans_mod
from repro.core.engine import WalkEngine
from repro.core.graphs import Graph
from repro.core.transition import MHLJParams
from repro.data.synthetic import RegressionData
from repro.models import regression as reg
from repro.walk_sgd.fleet import WalkFleet, run_fleet

__all__ = ["RWSGDResult", "MultiRWSGDResult", "run_rw_sgd", "run_rw_sgd_multi"]

METHODS = (
    "uniform", "importance", "mhlj", "simple", "heterogeneity", "private"
)


@dataclasses.dataclass
class RWSGDResult:
    mse: np.ndarray  # (T+1,) objective trace (paper Fig-3 metric)
    update_nodes: np.ndarray  # (T,)
    transitions: np.ndarray  # (T,) physical hops per update (Remark 1)
    x_final: np.ndarray
    method: str

    @property
    def transitions_per_update(self) -> float:
        return float(self.transitions.mean())


def _setup_method(
    method: str,
    graph: Graph,
    data: RegressionData,
    mhlj_params: Optional[MHLJParams],
    p_j_schedule: Optional[np.ndarray],
    num_steps: int,
    law_kwargs: Optional[dict] = None,
):
    """Shared method dispatch: padded P rows, weights, p_J schedule, (p_d, r).

    ``graph`` may be a dense :class:`~repro.core.graphs.Graph` (rows come
    from the dense transition builders, exactly as the paper's analysis
    stack computes them), a :class:`~repro.core.graphs.CSRGraph` (rows
    come from the O(E) local builders — same law, no N×N matrix), a
    :class:`~repro.core.graphs.BucketedCSRGraph` (per-degree-bucket rows,
    so hub-heavy 100k+-node topologies train without the O(n·max_deg)
    padded table) or a :class:`~repro.core.graphs.RaggedCSRGraph` (flat
    per-edge rows — the true-degree engine layout, exactly-O(E) row
    state, no padded tensor anywhere in the training loop).

    ``law_kwargs`` parameterizes the chain law itself:

    * ``method="heterogeneity"`` — ``pi`` (precomputed (n,) target; when
      absent it is measured+optimized from ``data`` via
      ``repro.core.heterogeneity.heterogeneity_pi``, whose ``floor`` /
      ``num_probes`` / ``probe_scale`` / ``seed`` / ``steps`` knobs pass
      through).
    * ``method="private"`` — ``gamma`` (privacy knob, default 0.1) and
      ``noise_seed`` (Gamma-noise draw seed, default 0).
    """
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}")
    if law_kwargs and method not in ("heterogeneity", "private"):
        raise ValueError(f"law_kwargs is not consumed by method={method!r}")
    lips = data.lipschitz
    dense = getattr(graph, "adj", None) is not None
    bucketed = hasattr(graph, "buckets")
    ragged = not (dense or bucketed) and not hasattr(graph, "neighbors")

    def pick(dense_p, padded_rows, bucket_rows, ragged_rows):
        if dense:
            return trans_mod.row_probs_padded(dense_p(), graph)
        if bucketed:
            return bucket_rows()
        return ragged_rows() if ragged else padded_rows()

    # the chain's target weight vector, for the laws whose gradient scaling
    # must undo the visit bias (w(v) = mean(target)/target(v), the Eq.-12
    # structure); None = the lipschitz default
    target_weight = None

    if method == "uniform":
        use_weights, use_jumps = False, False
        rows = pick(
            lambda: trans_mod.mh_uniform(graph),
            lambda: trans_mod.mh_uniform_rows(graph),
            lambda: trans_mod.mh_uniform_rows_bucketed(graph),
            lambda: trans_mod.mh_uniform_rows_ragged(graph),
        )
    elif method == "simple":
        use_weights, use_jumps = False, False
        rows = pick(
            lambda: trans_mod.simple_rw(graph),
            lambda: trans_mod.simple_rw_rows(graph),
            lambda: trans_mod.simple_rw_rows_bucketed(graph),
            lambda: trans_mod.simple_rw_rows_ragged(graph),
        )
    elif method == "heterogeneity":
        use_weights, use_jumps = True, False
        kw = dict(law_kwargs or {})
        pi = kw.pop("pi", None)
        if pi is None:
            pi = het_mod.heterogeneity_pi(data, **kw)
        elif kw:
            raise ValueError(
                f"unused heterogeneity law_kwargs besides pi: {sorted(kw)}"
            )
        pi = np.asarray(pi, dtype=np.float64)
        rows = pick(
            lambda: trans_mod.heterogeneity_mh(graph, pi),
            lambda: trans_mod.heterogeneity_rows(graph, pi),
            lambda: trans_mod.heterogeneity_rows_bucketed(graph, pi),
            lambda: trans_mod.heterogeneity_rows_ragged(graph, pi),
        )
        target_weight = pi
    elif method == "private":
        use_weights, use_jumps = True, False
        kw = dict(law_kwargs or {})
        priv_gamma = float(kw.pop("gamma", 0.1))
        noise_seed = int(kw.pop("noise_seed", 0))
        if kw:
            raise ValueError(f"unknown private-walk law_kwargs: {sorted(kw)}")
        rows = pick(
            lambda: trans_mod.private_weighted_mh(
                graph, lips, priv_gamma, seed=noise_seed
            ),
            lambda: trans_mod.private_weighted_rows(
                graph, lips, priv_gamma, seed=noise_seed
            ),
            lambda: trans_mod.private_weighted_rows_bucketed(
                graph, lips, priv_gamma, seed=noise_seed
            ),
            lambda: trans_mod.private_weighted_rows_ragged(
                graph, lips, priv_gamma, seed=noise_seed
            ),
        )
        # the update sees only the noised weights (they ARE the chain's
        # stationary target); true weights stay private to their nodes
        target_weight = trans_mod.private_weights(
            np.asarray(lips, dtype=np.float64), priv_gamma, seed=noise_seed
        )
    else:  # importance / mhlj share the P_IS rows; jumps sampled live
        use_weights = True
        use_jumps = method == "mhlj"
        if use_jumps:
            mhlj_params = mhlj_params or MHLJParams()
            mhlj_params.validate()
        rows = pick(
            lambda: trans_mod.mh_importance(graph, lips),
            lambda: trans_mod.mh_importance_rows(graph, lips),
            lambda: trans_mod.mh_importance_rows_bucketed(graph, lips),
            lambda: trans_mod.mh_importance_rows_ragged(graph, lips),
        )

    row_probs = rows if bucketed else jnp.asarray(rows)
    if target_weight is None:
        target_weight = np.asarray(lips, dtype=np.float64)
    weights = jnp.asarray(target_weight.mean() / target_weight, jnp.float32)

    if use_jumps:
        if p_j_schedule is not None:
            p_j_sched = jnp.asarray(p_j_schedule, jnp.float32)
            if p_j_sched.shape != (num_steps,):
                raise ValueError("p_j_schedule must have shape (num_steps,)")
        else:
            p_j_sched = jnp.full((num_steps,), mhlj_params.p_j, jnp.float32)
        p_d, r = mhlj_params.p_d, mhlj_params.r
    else:
        p_j_sched = jnp.zeros((num_steps,), jnp.float32)
        p_d, r = 0.5, 1  # engine never jumps at p_J = 0

    return row_probs, weights, p_j_sched, p_d, r, use_weights


def _build_engine(graph, p_d, r, row_probs, engine_kwargs, default_backend):
    """Engine for a training run; ``engine_kwargs`` may override backend."""
    kwargs = dict(engine_kwargs or {})
    backend = kwargs.pop("backend", default_backend)
    return WalkEngine.from_graph(
        graph, MHLJParams(p_j=0.0, p_d=p_d, r=r),
        row_probs=row_probs, backend=backend, **kwargs,
    )


def run_rw_sgd(
    method: str,
    graph: Graph,
    data: RegressionData,
    gamma: float,
    num_steps: int,
    *,
    mhlj_params: Optional[MHLJParams] = None,
    p_j_schedule: Optional[np.ndarray] = None,
    loss: str = "linear",
    x0: Optional[np.ndarray] = None,
    v0: int = 0,
    seed: int = 0,
    engine_kwargs: Optional[dict] = None,
    law_kwargs: Optional[dict] = None,
) -> RWSGDResult:
    """Run one RW-SGD training; returns the Fig-3 style MSE trace.

    The W=1 case of the fleet loop (``repro.walk_sgd.fleet.run_fleet``):
    a one-walker :class:`~repro.walk_sgd.fleet.WalkFleet` rides the same
    scan as :func:`run_rw_sgd_multi`, and the result is bitwise-identical
    per key to the historical dedicated single-walk scan
    (``tests/test_fleet.py`` pins this against a frozen oracle).

    ``graph`` may be a dense ``Graph``, an O(E) ``CSRGraph``, a
    degree-bucketed ``BucketedCSRGraph`` or a bare-core
    ``RaggedCSRGraph`` (the true-degree engine layout; its flat per-edge
    rows are built here and the engine turns them into the O(E) CDF
    buffer).  ``engine_kwargs`` forwards extra knobs to
    :meth:`WalkEngine.from_graph` (e.g. ``compact`` /
    ``capacity_factor`` for the bucketed layout's per-step walk
    compaction, or ``block_w``).
    """
    row_probs, weights, p_j_sched, p_d, r, use_weights = _setup_method(
        method, graph, data, mhlj_params, p_j_schedule, num_steps, law_kwargs
    )
    engine = _build_engine(graph, p_d, r, row_probs, engine_kwargs, "scan")
    fleet = WalkFleet.create(engine, 1, v0s=[v0])
    grad_fn = {"linear": reg.linear_grad, "logistic": reg.logistic_grad}[loss]
    x0 = jnp.zeros(data.dim, jnp.float32) if x0 is None else jnp.asarray(x0, jnp.float32)

    xs_fin, mses, _, nodes, hops, _final = run_fleet(
        jax.random.PRNGKey(seed),
        jnp.broadcast_to(x0[None], (1, data.dim)),
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights,
        fleet,
        num_steps,
        gamma,
        p_j_sched,
        use_weights,
        grad_fn,
    )
    return RWSGDResult(
        mse=np.asarray(mses[0]),
        update_nodes=np.asarray(nodes[0]),
        transitions=np.asarray(hops[0]),
        x_final=np.asarray(xs_fin[0]),
        method=method,
    )


# ---------------------------------------------------------------------------
# Batched multi-walk training (arXiv:2604.12260 regime, mesh-shardable)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiRWSGDResult:
    """W parallel walks trained in one scan off one batched engine step."""

    mse: np.ndarray  # (W, T+1) per-walk objective traces
    avg_mse: np.ndarray  # (T+1,) objective of the walk-averaged model
    update_nodes: np.ndarray  # (W, T) node holding each model at update t
    transitions: np.ndarray  # (W, T) physical hops (Remark 1)
    x_final: np.ndarray  # (W, dim) per-walk models
    method: str

    @property
    def x_avg(self) -> np.ndarray:
        return self.x_final.mean(axis=0)

    @property
    def transitions_per_update(self) -> float:
        return float(self.transitions.mean())


def run_rw_sgd_multi(
    method: str,
    graph: Graph,
    data: RegressionData,
    gamma: float,
    num_steps: int,
    num_walks: int,
    *,
    mhlj_params: Optional[MHLJParams] = None,
    p_j_schedule: Optional[np.ndarray] = None,
    loss: str = "linear",
    x0: Optional[np.ndarray] = None,
    v0s: Optional[Sequence[int]] = None,
    avg_every: int = 0,
    seed: int = 0,
    engine_kwargs: Optional[dict] = None,
    law_kwargs: Optional[dict] = None,
    mesh=None,
    engine: Optional[WalkEngine] = None,
) -> MultiRWSGDResult:
    """W parallel RW-SGD trainings sharing one batched engine transition.

    The fleet-construction seam: builds a
    :class:`~repro.walk_sgd.fleet.WalkFleet` (whose constructor owns the
    v0 seeding/validation shared with the LLM path) and runs it through
    the single fleet scan.  Each walk carries its own model;
    ``avg_every > 0`` averages the models across walks every that many
    updates (local-SGD style — the multi-walker regime of
    arXiv:2604.12260).  All W transitions per step are sampled by a
    single ``WalkEngine.step`` call — the Pallas kernel on TPU — instead
    of W independent scans.

    ``mesh`` (e.g. ``repro.launch.mesh.make_walker_mesh``) shards the
    walker batch over the ``walker`` logical axis of
    ``repro.sharding.rules``: per-walk model state and walk positions
    split across devices, graph/row state replicates, and the periodic
    average lowers to an all-reduce along the walker mesh axis.  On one
    device the sharded path is bitwise-identical to ``mesh=None``.

    ``engine_kwargs`` forwards extra knobs to
    :meth:`WalkEngine.from_graph` (bucketed compaction, ``block_w``, a
    ``backend`` override, …).

    ``engine`` injects a pre-built :class:`WalkEngine` instead of
    constructing one from ``graph`` — the dynamic-graph seam: a churned
    engine carried across graph versions by
    :meth:`WalkEngine.apply_churn` (see ``walk_sgd/graph_learning.py``)
    rides the same fleet scan without rebuilding its row state.  The
    caller owns consistency between the injected engine's rows and
    ``method`` (the method's row build is skipped); mutually exclusive
    with ``engine_kwargs``.
    """
    row_probs, weights, p_j_sched, p_d, r, use_weights = _setup_method(
        method, graph, data, mhlj_params, p_j_schedule, num_steps, law_kwargs
    )
    if engine is None:
        engine = _build_engine(graph, p_d, r, row_probs, engine_kwargs, "auto")
    elif engine_kwargs is not None:
        raise ValueError(
            "pass either a pre-built engine or engine_kwargs, not both"
        )
    fleet = WalkFleet.create(
        engine, num_walks, v0s=v0s, seed=seed, avg_every=avg_every
    )

    grad_fn = {"linear": reg.linear_grad, "logistic": reg.logistic_grad}[loss]
    x0 = jnp.zeros(data.dim, jnp.float32) if x0 is None else jnp.asarray(x0, jnp.float32)
    x0s = jnp.broadcast_to(x0[None], (num_walks, data.dim))

    xs_fin, mses, avg_mses, nodes, hops, _final = run_fleet(
        jax.random.PRNGKey(seed),
        x0s,
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights,
        fleet,
        num_steps,
        gamma,
        p_j_sched,
        use_weights,
        grad_fn,
        mesh=mesh,
    )
    return MultiRWSGDResult(
        mse=np.asarray(mses),
        avg_mse=np.asarray(avg_mses),
        update_nodes=np.asarray(nodes),
        transitions=np.asarray(hops),
        x_final=np.asarray(xs_fin),
        method=method,
    )
