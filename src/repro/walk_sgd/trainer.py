"""Full-JAX random-walk SGD trainer (paper Algorithm 1 + baselines).

Runs the *entire* T-iteration training as one ``lax.scan``: per iteration the
carried state is (model x, walk position v); the update applies the
importance-weighted stochastic gradient of the visited node's local loss
(Eq. 12), and the walk advances per the chosen method:

  method='uniform'    MH targeting uniform pi, plain gradient (w=1)
  method='importance' MH-IS (Eq. 7), weighted gradient w(v)=L_bar/L_v
  method='mhlj'       Algorithm 1 (MH-IS + Levy jumps), weighted gradient
  method='simple'     simple random walk, plain gradient (degree-biased)

The walk advances through :class:`repro.core.engine.WalkEngine` (the single
implementation of the MHLJ transition); non-jump methods are the engine at
p_J = 0.  The engine is built once per training run from the graph —
``Graph``, ``CSRGraph`` or ``BucketedCSRGraph`` — and passed *into* the
jitted scan as a pytree argument, so every layout (dense analysis graphs,
padded CSR, degree-bucketed hub-heavy graphs) rides the identical training
loop.  :func:`run_rw_sgd_multi` runs W walks at once off one batched
engine transition per step (the multi-walk benchmark path).

This is the regression-scale trainer used for the paper's figures; the
pjit-sharded LLM engine is ``walk_sgd.llm_trainer``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transition as trans_mod
from repro.core.engine import WalkEngine
from repro.core.graphs import Graph
from repro.core.transition import MHLJParams
from repro.data.synthetic import RegressionData
from repro.models import regression as reg

__all__ = ["RWSGDResult", "MultiRWSGDResult", "run_rw_sgd", "run_rw_sgd_multi"]

METHODS = ("uniform", "importance", "mhlj", "simple")


@dataclasses.dataclass
class RWSGDResult:
    mse: np.ndarray  # (T+1,) objective trace (paper Fig-3 metric)
    update_nodes: np.ndarray  # (T,)
    transitions: np.ndarray  # (T,) physical hops per update (Remark 1)
    x_final: np.ndarray
    method: str

    @property
    def transitions_per_update(self) -> float:
        return float(self.transitions.mean())


@functools.partial(
    jax.jit,
    static_argnames=("num_steps", "use_weights", "loss_grad"),
)
def _run_scan(
    key,
    x0,
    features,
    targets,
    weights,  # (n,) L_bar / L_v (ones when unweighted)
    engine: WalkEngine,  # pytree arg: arrays traced, layout/backend static
    v0,
    num_steps: int,
    gamma: float,
    p_j_sched,  # (num_steps,)
    use_weights: bool,
    loss_grad,  # static callable: grad of per-node loss
):
    def step(carry, inputs):
        x, v = carry
        key_t, p_j_t = inputs
        g = loss_grad(x, features[v], targets[v])
        w = jnp.where(use_weights, weights[v], 1.0)
        x_new = x - gamma * w * g
        v_next, hops = engine.step(key_t, v, p_j=p_j_t)
        mse = reg.mse_objective(x_new, features, targets)
        return (x_new, v_next), (mse, v, hops)

    keys = jax.random.split(key, num_steps)
    (x_fin, _), (mses, nodes, hops) = jax.lax.scan(
        step, (x0, jnp.asarray(v0, jnp.int32)), (keys, p_j_sched)
    )
    mse0 = reg.mse_objective(x0, features, targets)
    return x_fin, jnp.concatenate([mse0[None], mses]), nodes, hops


def _setup_method(
    method: str,
    graph: Graph,
    data: RegressionData,
    mhlj_params: Optional[MHLJParams],
    p_j_schedule: Optional[np.ndarray],
    num_steps: int,
):
    """Shared method dispatch: padded P rows, weights, p_J schedule, (p_d, r).

    ``graph`` may be a dense :class:`~repro.core.graphs.Graph` (rows come
    from the dense transition builders, exactly as the paper's analysis
    stack computes them), a :class:`~repro.core.graphs.CSRGraph` (rows
    come from the O(E) local builders — same law, no N×N matrix), a
    :class:`~repro.core.graphs.BucketedCSRGraph` (per-degree-bucket rows,
    so hub-heavy 100k+-node topologies train without the O(n·max_deg)
    padded table) or a :class:`~repro.core.graphs.RaggedCSRGraph` (flat
    per-edge rows — the true-degree engine layout, exactly-O(E) row
    state, no padded tensor anywhere in the training loop).
    """
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}")
    lips = data.lipschitz
    dense = getattr(graph, "adj", None) is not None
    bucketed = hasattr(graph, "buckets")
    ragged = not (dense or bucketed) and not hasattr(graph, "neighbors")

    def pick(dense_p, padded_rows, bucket_rows, ragged_rows):
        if dense:
            return trans_mod.row_probs_padded(dense_p(), graph)
        if bucketed:
            return bucket_rows()
        return ragged_rows() if ragged else padded_rows()

    if method == "uniform":
        use_weights, use_jumps = False, False
        rows = pick(
            lambda: trans_mod.mh_uniform(graph),
            lambda: trans_mod.mh_uniform_rows(graph),
            lambda: trans_mod.mh_uniform_rows_bucketed(graph),
            lambda: trans_mod.mh_uniform_rows_ragged(graph),
        )
    elif method == "simple":
        use_weights, use_jumps = False, False
        rows = pick(
            lambda: trans_mod.simple_rw(graph),
            lambda: trans_mod.simple_rw_rows(graph),
            lambda: trans_mod.simple_rw_rows_bucketed(graph),
            lambda: trans_mod.simple_rw_rows_ragged(graph),
        )
    else:  # importance / mhlj share the P_IS rows; jumps sampled live
        use_weights = True
        use_jumps = method == "mhlj"
        if use_jumps:
            mhlj_params = mhlj_params or MHLJParams()
            mhlj_params.validate()
        rows = pick(
            lambda: trans_mod.mh_importance(graph, lips),
            lambda: trans_mod.mh_importance_rows(graph, lips),
            lambda: trans_mod.mh_importance_rows_bucketed(graph, lips),
            lambda: trans_mod.mh_importance_rows_ragged(graph, lips),
        )

    row_probs = rows if bucketed else jnp.asarray(rows)
    weights = jnp.asarray(lips.mean() / lips, jnp.float32)

    if use_jumps:
        if p_j_schedule is not None:
            p_j_sched = jnp.asarray(p_j_schedule, jnp.float32)
            if p_j_sched.shape != (num_steps,):
                raise ValueError("p_j_schedule must have shape (num_steps,)")
        else:
            p_j_sched = jnp.full((num_steps,), mhlj_params.p_j, jnp.float32)
        p_d, r = mhlj_params.p_d, mhlj_params.r
    else:
        p_j_sched = jnp.zeros((num_steps,), jnp.float32)
        p_d, r = 0.5, 1  # engine never jumps at p_J = 0

    return row_probs, weights, p_j_sched, p_d, r, use_weights


def run_rw_sgd(
    method: str,
    graph: Graph,
    data: RegressionData,
    gamma: float,
    num_steps: int,
    *,
    mhlj_params: Optional[MHLJParams] = None,
    p_j_schedule: Optional[np.ndarray] = None,
    loss: str = "linear",
    x0: Optional[np.ndarray] = None,
    v0: int = 0,
    seed: int = 0,
    engine_kwargs: Optional[dict] = None,
) -> RWSGDResult:
    """Run one RW-SGD training; returns the Fig-3 style MSE trace.

    ``graph`` may be a dense ``Graph``, an O(E) ``CSRGraph``, a
    degree-bucketed ``BucketedCSRGraph`` or a bare-core
    ``RaggedCSRGraph`` (the true-degree engine layout; its flat per-edge
    rows are built here and the engine turns them into the O(E) CDF
    buffer).  ``engine_kwargs`` forwards extra knobs to
    :meth:`WalkEngine.from_graph` (e.g. ``compact`` /
    ``capacity_factor`` for the bucketed layout's per-step walk
    compaction, or ``block_w``).
    """
    row_probs, weights, p_j_sched, p_d, r, use_weights = _setup_method(
        method, graph, data, mhlj_params, p_j_schedule, num_steps
    )
    engine = WalkEngine.from_graph(
        graph, MHLJParams(p_j=0.0, p_d=p_d, r=r),
        row_probs=row_probs, backend="scan", **(engine_kwargs or {}),
    )
    grad_fn = {"linear": reg.linear_grad, "logistic": reg.logistic_grad}[loss]
    x0 = jnp.zeros(data.dim, jnp.float32) if x0 is None else jnp.asarray(x0, jnp.float32)

    x_fin, mses, nodes, hops = _run_scan(
        jax.random.PRNGKey(seed),
        x0,
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights,
        engine,
        v0,
        num_steps,
        gamma,
        p_j_sched,
        use_weights,
        grad_fn,
    )
    return RWSGDResult(
        mse=np.asarray(mses),
        update_nodes=np.asarray(nodes),
        transitions=np.asarray(hops),
        x_final=np.asarray(x_fin),
        method=method,
    )


# ---------------------------------------------------------------------------
# Batched multi-walk training (beyond-paper, benchmarks/multi_walk.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiRWSGDResult:
    """W parallel walks trained in one scan off one batched engine step."""

    mse: np.ndarray  # (W, T+1) per-walk objective traces
    avg_mse: np.ndarray  # (T+1,) objective of the walk-averaged model
    transitions: np.ndarray  # (W, T) physical hops (Remark 1)
    x_final: np.ndarray  # (W, dim) per-walk models
    method: str

    @property
    def x_avg(self) -> np.ndarray:
        return self.x_final.mean(axis=0)

    @property
    def transitions_per_update(self) -> float:
        return float(self.transitions.mean())


@functools.partial(
    jax.jit,
    static_argnames=("num_steps", "use_weights", "loss_grad", "avg_every"),
)
def _run_scan_multi(
    key,
    x0s,  # (W, dim)
    features,
    targets,
    weights,
    engine: WalkEngine,  # pytree arg: arrays traced, layout/backend static
    v0s,  # (W,)
    num_steps: int,
    gamma: float,
    p_j_sched,
    use_weights: bool,
    loss_grad,
    avg_every: int,
):
    grad_w = jax.vmap(loss_grad, in_axes=(0, 0, 0))

    def step(carry, inputs):
        xs, vs, t = carry
        key_t, p_j_t = inputs
        gs = grad_w(xs, features[vs], targets[vs])  # (W, dim)
        ws = jnp.where(use_weights, weights[vs], 1.0)[:, None]
        xs_new = xs - gamma * ws * gs
        if avg_every > 0:
            do_avg = (t + 1) % avg_every == 0
            xs_new = jnp.where(do_avg, xs_new.mean(axis=0)[None], xs_new)
        vs_next, hops = engine.step(key_t, vs, p_j=p_j_t)  # ONE batched call
        mses = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
            xs_new, features, targets
        )
        avg_mse = reg.mse_objective(xs_new.mean(axis=0), features, targets)
        return (xs_new, vs_next, t + 1), (mses, avg_mse, hops)

    keys = jax.random.split(key, num_steps)
    (xs_fin, _, _), (mses, avg_mses, hops) = jax.lax.scan(
        step, (x0s, v0s, jnp.int32(0)), (keys, p_j_sched)
    )
    mse0 = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
        x0s, features, targets
    )
    avg0 = reg.mse_objective(x0s.mean(axis=0), features, targets)
    return (
        xs_fin,
        jnp.concatenate([mse0[None], mses]).T,  # (W, T+1)
        jnp.concatenate([avg0[None], avg_mses]),
        hops.T,  # (W, T)
    )


def run_rw_sgd_multi(
    method: str,
    graph: Graph,
    data: RegressionData,
    gamma: float,
    num_steps: int,
    num_walks: int,
    *,
    mhlj_params: Optional[MHLJParams] = None,
    p_j_schedule: Optional[np.ndarray] = None,
    loss: str = "linear",
    x0: Optional[np.ndarray] = None,
    v0s: Optional[Sequence[int]] = None,
    avg_every: int = 0,
    seed: int = 0,
    engine_kwargs: Optional[dict] = None,
) -> MultiRWSGDResult:
    """W parallel RW-SGD trainings sharing one batched engine transition.

    Each walk carries its own model; ``avg_every > 0`` averages the models
    across walks every that many updates (local-SGD style).  All W
    transitions per step are sampled by a single ``WalkEngine.step`` call —
    the Pallas kernel on TPU — instead of W independent scans.
    ``engine_kwargs`` forwards extra knobs to
    :meth:`WalkEngine.from_graph` (bucketed compaction, ``block_w``, a
    backend override, …).
    """
    row_probs, weights, p_j_sched, p_d, r, use_weights = _setup_method(
        method, graph, data, mhlj_params, p_j_schedule, num_steps
    )
    engine = WalkEngine.from_graph(
        graph, MHLJParams(p_j=0.0, p_d=p_d, r=r),
        row_probs=row_probs, backend="auto", **(engine_kwargs or {}),
    )

    if v0s is None:
        rng = np.random.default_rng(seed)
        v0s = rng.choice(graph.n, size=num_walks, replace=num_walks > graph.n)
    v0s = jnp.asarray(np.asarray(v0s, np.int32))
    if v0s.shape != (num_walks,):
        raise ValueError(f"v0s must have shape ({num_walks},), got {v0s.shape}")

    grad_fn = {"linear": reg.linear_grad, "logistic": reg.logistic_grad}[loss]
    x0 = jnp.zeros(data.dim, jnp.float32) if x0 is None else jnp.asarray(x0, jnp.float32)
    x0s = jnp.broadcast_to(x0[None], (num_walks, data.dim))

    xs_fin, mses, avg_mses, hops = _run_scan_multi(
        jax.random.PRNGKey(seed),
        x0s,
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights,
        engine,
        v0s,
        num_steps,
        gamma,
        p_j_sched,
        use_weights,
        grad_fn,
        avg_every,
    )
    return MultiRWSGDResult(
        mse=np.asarray(mses),
        avg_mse=np.asarray(avg_mses),
        transitions=np.asarray(hops),
        x_final=np.asarray(xs_fin),
        method=method,
    )
