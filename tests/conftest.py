"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests see 1 device;
only launch/dryrun.py forces 512 placeholder devices (system requirement)."""
import numpy as np
import pytest

from repro.core import MHLJParams, ring
from repro.data import make_heterogeneous_regression


@pytest.fixture(scope="session")
def small_ring():
    return ring(16)


@pytest.fixture(scope="session")
def hetero_lipschitz():
    lips = np.ones(16)
    lips[3] = 50.0
    return lips


@pytest.fixture(scope="session")
def mhlj_params():
    return MHLJParams(p_j=0.1, p_d=0.5, r=3)


@pytest.fixture(scope="session")
def small_hetero_data():
    return make_heterogeneous_regression(
        32, dim=6, sigma_high_sq=100.0, p_high=0.05, seed=0
    )
