"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated in its REDUCED variant (<=2
layers, d_model<=256, <=4 experts) and runs one forward + one full
walk-orchestrated train step on CPU, asserting output shapes and no NaNs.
Decode-capable archs additionally run one cached decode step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHITECTURES, ShapeConfig, reduced
from repro.core.graphs import ring
from repro.core.transition import MHLJParams
from repro.models.factory import build_model
from repro.walk_sgd.llm_trainer import (
    WalkContext,
    init_walk_state,
    make_serve_step,
    make_train_step,
)

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
ARCH_IDS = sorted(ARCHITECTURES)


def materialize(specs, seed=0):
    """Random concrete arrays for a pytree of ShapeDtypeStructs."""
    rng = np.random.default_rng(seed)

    def one(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, 64, s.shape), s.dtype)
        return jnp.asarray(rng.normal(size=s.shape), s.dtype)

    return jax.tree_util.tree_map(one, specs)


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = reduced(ARCHITECTURES[request.param])
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_forward_shapes_and_finite(arch_setup):
    cfg, model, params = arch_setup
    batch = materialize(model.input_specs(SMOKE_SHAPE))
    hidden = model.apply(params, batch)
    assert hidden.shape == (SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all())


def test_one_walk_train_step(arch_setup):
    cfg, model, params = arch_setup
    graph = ring(8)
    walk = WalkContext.from_graph(graph, MHLJParams(0.1, 0.5, 3))
    optimizer = optim.adamw(1e-3)
    opt_state = optimizer.init(params)
    walk_state = init_walk_state(8, np.ones(8, np.float32))
    step = jax.jit(make_train_step(model, optimizer, walk))
    batch = materialize(model.input_specs(SMOKE_SHAPE))
    params2, opt_state2, walk_state2, metrics = step(
        params, opt_state, walk_state, batch
    )
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0.0
    leaves = jax.tree_util.tree_leaves(params2)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0
    assert int(walk_state2["updates"]) == 1
    assert int(walk_state2["hops"]) >= 1


def test_one_decode_step(arch_setup):
    cfg, model, params = arch_setup
    if model.init_cache is None or model.decode_step is None:
        pytest.skip("no decode path")
    b, cache_len = 2, 64
    cache = model.init_cache(b, cache_len)
    serve = jax.jit(make_serve_step(model))
    tokens = jnp.zeros((b, 1), jnp.int32)
    next_tokens, cache = serve(params, cache, tokens, jnp.asarray(0, jnp.int32))
    assert next_tokens.shape == (b, 1)
    assert next_tokens.dtype == jnp.int32
    assert bool((next_tokens >= 0).all())
    assert bool((next_tokens < cfg.vocab_size).all())
    # second step consumes the first step's output
    next2, cache = serve(params, cache, next_tokens, jnp.asarray(1, jnp.int32))
    assert next2.shape == (b, 1)


def test_loss_grads_finite(arch_setup):
    cfg, model, params = arch_setup
    batch = materialize(model.input_specs(SMOKE_SHAPE))
    (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert bool(jnp.isfinite(loss))
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in gleaves)
    # at least one nonzero gradient leaf
    assert any(float(jnp.abs(g).max()) > 0 for g in gleaves)
