"""Anti-rot gate for the benchmark harness.

Runs ``python -m benchmarks.run --smoke`` as a subprocess: every benchmark
module must satisfy the harness contract (NAME / PAPER_CLAIM / run) and the
modules with a smoke tier (fig5_sparse_graphs, large_graph_walk) must
actually execute at toy sizes.  The large-graph tier must take real walk
steps through EVERY registered engine layout (``repro.core.engine.LAYOUTS``)
so a rotted layout — not just the default one — fails tier 1 here instead
of rotting until someone runs the full suite.
"""
import os
import subprocess
import sys

from repro.core.engine import LAYOUTS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_benchmarks_smoke_tier_passes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke"],
        cwd=REPO,
        env=env,
        capture_output=True,
        text=True,
        timeout=540,
    )
    assert proc.returncode == 0, (
        f"--smoke failed (rc={proc.returncode})\n"
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    out = proc.stdout
    # the executed smoke tiers must have reported derived metrics
    assert "large_graph_walk[smoke]" in out
    assert "fig5_sparse_graphs[smoke]" in out
    assert "FAILED" not in out
    # every registered engine layout must have taken real walk steps
    for layout in LAYOUTS:
        assert f"_{layout}_steps_per_sec" in out, (
            f"layout {layout!r} was not exercised by the smoke tier"
        )
