"""Anti-rot gate for the benchmark harness.

Runs ``python -m benchmarks.run --smoke`` as a subprocess: every benchmark
module must satisfy the harness contract (NAME / PAPER_CLAIM / run) and the
modules with a smoke tier (fig5_sparse_graphs, large_graph_walk, law_sweep,
serve_throughput, fault_sweep) must actually execute at toy sizes.  The large-graph tier must take real walk
steps through EVERY registered engine layout (``repro.core.engine.LAYOUTS``)
plus the compacted bucketed dispatch, so a rotted path — not just the
default one — fails tier 1 here instead of rotting until someone runs the
full suite.  The same smoke run's steps/sec then feed
``benchmarks/check_regression.py`` against the committed baseline in
``results/BENCH_large_graph.json`` — so an order-of-magnitude step-time
regression fails tier 1 too, not just a correctness break.
"""
import json
import os
import subprocess
import sys

from repro.core.engine import LAYOUTS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    return env


def test_benchmarks_smoke_tier_passes(tmp_path):
    json_path = str(tmp_path / "smoke.json")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "--json", json_path],
        cwd=REPO,
        env=_env(),
        capture_output=True,
        text=True,
        timeout=540,
    )
    assert proc.returncode == 0, (
        f"--smoke failed (rc={proc.returncode})\n"
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    out = proc.stdout
    # the executed smoke tiers must have reported derived metrics
    assert "large_graph_walk[smoke]" in out
    assert "fig5_sparse_graphs[smoke]" in out
    assert "law_sweep[smoke]" in out
    assert "serve_throughput[smoke]" in out
    assert "fault_sweep[smoke]" in out
    assert "FAILED" not in out
    # every registered engine layout + the compacted bucketed dispatch must
    # have taken real walk steps
    for layout in tuple(LAYOUTS) + ("bucketed_compact",):
        assert f"_{layout}_steps_per_sec" in out, (
            f"layout {layout!r} was not exercised by the smoke tier"
        )
    # the --json dump (the regression gate's input) must carry the numbers
    with open(json_path) as f:
        derived = json.load(f)
    assert any(
        k.endswith("_steps_per_sec")
        for k in derived.get("large_graph_walk", {})
    )
    # every chain law must have swept every trap family — the law sweep's
    # presence-gated telemetry keys feed check_regression's missing-key
    # path (labels spelled out here on purpose: shrinking LAWS must break
    # this test, not silently shrink it)
    law_keys = set(derived.get("law_sweep", {}))
    for family in ("ba", "dumbbell", "lollipop"):
        for label in (
            "simple", "uniform", "importance", "mhlj", "heterogeneity",
            "private_g0.1", "private_g1.0",
        ):
            assert f"{family}_{label}_herfindahl" in law_keys, (
                f"law {label!r} vanished from the {family} sweep"
            )
    # every routing law must have served the walk-routed workload — the
    # serving sweep's presence-gated keys (Herfindahl entrapment telemetry,
    # p99 latency, requests/s) feed the same missing-key path
    serve_keys = set(derived.get("serve_throughput", {}))
    for label in (
        "simple", "uniform", "importance", "mhlj", "heterogeneity",
        "private_g0.5",
    ):
        for suffix in ("herfindahl", "p99_ticks", "requests_per_sec"):
            assert f"ba_{label}_{suffix}" in serve_keys, (
                f"routing law {label!r} vanished from the serving sweep "
                f"({suffix})"
            )
    # every fault-sweep leg must have run: the rescue-on AND rescue-off
    # training legs per family plus the trace-replayed serving legs feed
    # check_regression's presence gate ("_rescue"/"_fault_free" suffixes)
    fault_keys = set(derived.get("fault_sweep", {}))
    for fam in ("dumbbell", "ba"):
        assert f"{fam}_excess_fault_free" in fault_keys
        for tag in ("with_rescue", "no_rescue"):
            assert f"{fam}_excess_f5_{tag}" in fault_keys, (
                f"fault leg {tag!r} vanished from the {fam} sweep"
            )
    for suffix in ("p99", "shed_rate"):
        assert f"serve_{suffix}_fault_free" in fault_keys
        assert f"serve_{suffix}_f5_with_rescue" in fault_keys
        assert f"serve_{suffix}_f5_no_rescue" in fault_keys

    # step-time regression gate: fresh smoke numbers vs the committed
    # baseline (generous 2.5x tolerance — catches rot, not noise)
    check = subprocess.run(
        [
            sys.executable,
            os.path.join("benchmarks", "check_regression.py"),
            "--fresh", json_path,
        ],
        cwd=REPO,
        env=_env(),
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert check.returncode == 0, (
        f"check_regression failed (rc={check.returncode})\n"
        f"stdout:\n{check.stdout}\nstderr:\n{check.stderr}"
    )
    assert "no step-time regressions" in check.stdout
