"""Tier-1 doc-link check: the theory map cannot silently rot.

Every backticked repo path (``src/repro/...`` etc.) and every backticked
dotted name (``repro.module.attr``) in ``docs/*.md`` and ``README.md``
must actually exist — paths on disk, dotted names via import + getattr.
A rename that orphans a reference in the documentation fails here, in
tier 1, instead of leaving the theory-to-code map pointing at nothing.

CLI flags are checked too: every ``--flag`` in a documented ``python -m
repro.x`` / ``python path/to/script.py`` command line (inside a code
fence) must appear in an ``add_argument`` call of the module it targets —
so a renamed or deleted flag cannot leave the docs quoting commands that
crash on arrival.
"""
import glob
import importlib
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))) + [
    os.path.join(REPO, "README.md")
]

_BACKTICK = re.compile(r"`([^`\n]+)`")
_DOTTED = re.compile(r"repro(?:\.[A-Za-z_]\w*)+$")
_PATHLIKE = re.compile(r"[\w\-.]+(?:/[\w\-.]+)+\.(?:py|md|json|txt)$")
_TOPLEVEL = re.compile(r"[\w\-]+\.md$")


def _tokens(path):
    with open(path) as f:
        return _BACKTICK.findall(f.read())


def _resolve_dotted(dotted: str):
    """Import the longest module prefix, then getattr the rest."""
    parts = dotted.split(".")
    last_err = None
    for split in range(len(parts), 0, -1):
        modname = ".".join(parts[:split])
        try:
            obj = importlib.import_module(modname)
        except ImportError as e:
            last_err = e
            continue
        for attr in parts[split:]:
            obj = getattr(obj, attr)  # AttributeError propagates = failure
        return obj
    raise last_err


@pytest.mark.parametrize(
    "doc", DOC_FILES, ids=[os.path.relpath(p, REPO) for p in DOC_FILES]
)
def test_doc_references_exist(doc):
    assert os.path.exists(doc), f"documented file missing: {doc}"
    missing = []
    for tok in _tokens(doc):
        if _DOTTED.fullmatch(tok):
            try:
                _resolve_dotted(tok)
            except (ImportError, AttributeError) as e:
                missing.append(f"{tok!r}: {e}")
        elif _PATHLIKE.fullmatch(tok) or _TOPLEVEL.fullmatch(tok):
            if not os.path.exists(os.path.join(REPO, tok)):
                missing.append(f"{tok!r}: no such file")
    assert not missing, (
        f"{os.path.relpath(doc, REPO)} references nonexistent code/paths:\n  "
        + "\n  ".join(missing)
    )


def test_doc_tree_is_present():
    """The documented doc set itself: a rename here must be deliberate."""
    for name in (
        "theory_map.md",
        "layouts.md",
        "benchmarks.md",
        "fleet.md",
        "dynamic_graphs.md",
        "serving.md",
        "faults.md",
    ):
        assert os.path.exists(os.path.join(REPO, "docs", name)), name


# -- CLI flags quoted in docs must match the argparse definitions ----------

_FENCE = re.compile(r"```[^\n]*\n(.*?)```", re.S)
_CMD = re.compile(
    r"python(?:3)?\s+(?:-m\s+(?P<mod>[A-Za-z_][\w.]*)|(?P<script>[\w\-./]+\.py))"
)
_FLAG = re.compile(r"(?<!\S)(--[A-Za-z][\w-]*)")
_ADD_ARGUMENT = re.compile(r"add_argument\(\s*[\"'](--[\w-]+)[\"']")


def _module_path(mod: str):
    """Repo file for a ``python -m`` target; None = not a repo module
    (``pytest`` etc. are skipped, not failed)."""
    rel = mod.replace(".", os.sep) + ".py"
    for cand in (rel, os.path.join("src", rel)):
        path = os.path.join(REPO, cand)
        if os.path.exists(path):
            return path
    return None


def _doc_commands(doc):
    """(command line, target path, flags) for every repo-targeting
    ``python`` invocation inside the doc's code fences."""
    with open(doc) as f:
        text = f.read()
    for fence in _FENCE.findall(text):
        # fold backslash continuations so multi-line commands are one line
        for line in fence.replace("\\\n", " ").splitlines():
            m = _CMD.search(line)
            if not m:
                continue
            path = (
                _module_path(m["mod"])
                if m["mod"]
                else _module_path(m["script"][: -len(".py")].replace("/", "."))
            )
            if path is None:
                continue
            # only flags AFTER the module reference (env-var assignments
            # like XLA_FLAGS=--xla_... before `python` are not CLI flags)
            flags = set(_FLAG.findall(line[m.end():]))
            if flags:
                yield line.strip(), path, flags


@pytest.mark.parametrize(
    "doc", DOC_FILES, ids=[os.path.relpath(p, REPO) for p in DOC_FILES]
)
def test_doc_cli_flags_exist(doc):
    problems = []
    for line, path, flags in _doc_commands(doc):
        with open(path) as f:
            defined = set(_ADD_ARGUMENT.findall(f.read()))
        for flag in sorted(flags - defined):
            problems.append(
                f"{flag!r} (from {line!r}) is not an argparse flag of "
                f"{os.path.relpath(path, REPO)}"
            )
    assert not problems, (
        f"{os.path.relpath(doc, REPO)} quotes CLI flags that do not "
        "resolve:\n  " + "\n  ".join(problems)
    )
