"""Tier-1 doc-link check: the theory map cannot silently rot.

Every backticked repo path (``src/repro/...`` etc.) and every backticked
dotted name (``repro.module.attr``) in ``docs/*.md`` and ``README.md``
must actually exist — paths on disk, dotted names via import + getattr.
A rename that orphans a reference in the documentation fails here, in
tier 1, instead of leaving the theory-to-code map pointing at nothing.
"""
import glob
import importlib
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))) + [
    os.path.join(REPO, "README.md")
]

_BACKTICK = re.compile(r"`([^`\n]+)`")
_DOTTED = re.compile(r"repro(?:\.[A-Za-z_]\w*)+$")
_PATHLIKE = re.compile(r"[\w\-.]+(?:/[\w\-.]+)+\.(?:py|md|json|txt)$")
_TOPLEVEL = re.compile(r"[\w\-]+\.md$")


def _tokens(path):
    with open(path) as f:
        return _BACKTICK.findall(f.read())


def _resolve_dotted(dotted: str):
    """Import the longest module prefix, then getattr the rest."""
    parts = dotted.split(".")
    last_err = None
    for split in range(len(parts), 0, -1):
        modname = ".".join(parts[:split])
        try:
            obj = importlib.import_module(modname)
        except ImportError as e:
            last_err = e
            continue
        for attr in parts[split:]:
            obj = getattr(obj, attr)  # AttributeError propagates = failure
        return obj
    raise last_err


@pytest.mark.parametrize(
    "doc", DOC_FILES, ids=[os.path.relpath(p, REPO) for p in DOC_FILES]
)
def test_doc_references_exist(doc):
    assert os.path.exists(doc), f"documented file missing: {doc}"
    missing = []
    for tok in _tokens(doc):
        if _DOTTED.fullmatch(tok):
            try:
                _resolve_dotted(tok)
            except (ImportError, AttributeError) as e:
                missing.append(f"{tok!r}: {e}")
        elif _PATHLIKE.fullmatch(tok) or _TOPLEVEL.fullmatch(tok):
            if not os.path.exists(os.path.join(REPO, tok)):
                missing.append(f"{tok!r}: no such file")
    assert not missing, (
        f"{os.path.relpath(doc, REPO)} references nonexistent code/paths:\n  "
        + "\n  ".join(missing)
    )


def test_doc_tree_is_present():
    """The documented doc set itself: a rename here must be deliberate."""
    for name in (
        "theory_map.md",
        "layouts.md",
        "benchmarks.md",
        "fleet.md",
        "dynamic_graphs.md",
    ):
        assert os.path.exists(os.path.join(REPO, "docs", name)), name
