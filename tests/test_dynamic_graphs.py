"""Dynamic graphs under differential test — the acceptance contract of the
incremental edge-churn path (``graphs.apply_edge_churn`` /
``engine.ragged_edge_cdf_update`` / ``WalkEngine.apply_churn``) and the
walk-continuity rule (``fleet.migrate_walk_nodes``).

The correctness story is *differential*: every incremental update must be
**bitwise identical** to a from-scratch rebuild —

1. the churned CSR core (indptr/indices/degrees, and the padded
   ``neighbors`` tensor on :class:`CSRGraph`) equals ``from_edges`` over
   the churned edge list, batch after batch, for random churn sequences
   (hypothesis-driven when installed, pinned draws always);
2. the incrementally patched flat per-edge CDF equals a from-scratch
   ``ragged_edge_cdf`` build on the rebuilt graph **at the engine's
   recorded ``cdf_width``**, through BOTH row sources (``lipschitz``
   and ``touched_probs``) — and equals the plain ``WalkEngine.from_graph``
   rebuild whenever the churn left the max degree at that width.  The
   width pin is not pedantry: XLA's CPU reductions lane-split by padded
   row width, so the same row probabilities materialized at a different
   max degree differ in the last ulp — bits are a function of
   (values, width).  ``WalkEngine.apply_churn`` therefore patches at the
   sticky ``cdf_width`` and escalates to a full rebuild only when an
   insert outgrows it (tested explicitly below);
3. the churned ragged engine *steps* bitwise-identically to fresh
   engines of all four layouts on the rebuilt graph, at a W that is not
   a block multiple;
4. the batch contract is strict — every malformed batch raises before
   anything is modified;
5. walk continuity across a graph version pins the documented rule:
   surviving walks carry bitwise, displaced walks re-seed via
   ``active[sample_initial_nodes(len(active), W, seed)[w]]``;
6. (slow) the post-churn chain still realizes the dense ``mhlj()`` law —
   chi-square at ~4-sigma — and its update occupancy still matches the
   rebuilt chain's stationary ``pi``;
7. the learned-collaboration-graph loop (``walk_sgd.run_dada``) runs end
   to end through the trainer/fleet stack, and its first round is
   bitwise-identical to a plain ``run_rw_sgd_multi`` call.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dependency (requirements-dev.txt)
    given = settings = st = None

from repro.core import (
    MHLJParams,
    WalkEngine,
    apply_edge_churn,
    barabasi_albert,
    from_edges,
    lollipop,
    mh_importance,
    mh_importance_rows_ragged,
    mhlj,
    mixing,
    row_probs_padded,
)
from repro.core import graphs as graphs_mod
from repro.core.engine import ragged_edge_cdf, ragged_edge_cdf_update
from repro.core.walk import empirical_distribution
from repro.data import make_heterogeneous_regression
from repro.walk_sgd import (
    WalkFleet,
    migrate_walk_nodes,
    run_dada,
    run_rw_sgd_multi,
    sample_initial_nodes,
)

PARAMS = MHLJParams(p_j=0.25, p_d=0.5, r=3)


# ---------------------------------------------------------------------------
# Churn-batch generation (shared by the differential and hypothesis tests)
# ---------------------------------------------------------------------------


def _undirected_pairs(core):
    """Canonical non-loop (lo, hi) pairs of a CSR-core graph."""
    n = core.n
    indptr = np.asarray(core.indptr, np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    dst = np.asarray(core.indices, np.int64)
    keep = src < dst
    return np.stack([src[keep], dst[keep]], axis=1)


def _random_churn(core, rng, k_del, k_ins):
    """A random legal churn batch: deletes keep both endpoints at degree
    >= 3 post-batch and the graph connected (halve-and-retry), inserts are
    uniform non-edges.  Either side may come back ``None`` (empty)."""
    n = core.n
    deg = np.asarray(core.degrees, np.int64)
    pairs = _undirected_pairs(core)
    codes = set((pairs[:, 0] * n + pairs[:, 1]).tolist())
    ok = (deg[pairs[:, 0]] >= 4) & (deg[pairs[:, 1]] >= 4)
    cand = pairs[ok]
    dele = None
    k_del = min(k_del, cand.shape[0])
    while k_del:
        sel = rng.choice(cand.shape[0], size=k_del, replace=False)
        try:
            apply_edge_churn(core, delete=cand[sel], check_connectivity=True)
        except ValueError:
            k_del //= 2
            continue
        dele = cand[sel]
        break
    ins = []
    attempts = 0
    while len(ins) < k_ins and attempts < 50 * (k_ins + 1):
        attempts += 1
        a, b = (int(x) for x in rng.integers(0, n, size=2))
        if a == b:
            continue
        lo, hi = min(a, b), max(a, b)
        if lo * n + hi in codes:
            continue
        codes.add(lo * n + hi)
        ins.append((lo, hi))
    return (np.asarray(ins, np.int64) if ins else None), dele


# ---------------------------------------------------------------------------
# 1+2: differential churn sequences — incremental == rebuild, bitwise
# ---------------------------------------------------------------------------


def _check_churn_sequence(seed, k_del, k_ins, batches=3):
    """Random churn sequence on a hub-heavy BA graph; after every batch the
    incremental core, the padded CSR twin AND the incrementally patched
    engine CDFs (both row sources) are bitwise-equal to from-scratch
    rebuilds."""
    g = barabasi_albert(60, 3, seed=seed, layout="csr")
    core = g.to_ragged()
    padded = g
    lips = np.ones(g.n)
    lips[5] = 35.0  # trap node
    lips_j = jnp.asarray(lips, jnp.float32)
    rng = np.random.default_rng(seed + 100)
    eng_lip = WalkEngine.from_graph(
        core, PARAMS, lipschitz=lips_j, backend="scan", layout="ragged"
    )
    eng_flat = WalkEngine.from_graph(
        core, PARAMS, row_probs=mh_importance_rows_ragged(core, lips),
        backend="scan", layout="ragged",
    )
    for batch in range(batches):
        ins, dele = _random_churn(core, rng, k_del, k_ins)
        core, churn = apply_edge_churn(core, insert=ins, delete=dele)
        padded, churn_p = apply_edge_churn(padded, insert=ins, delete=dele)
        assert churn.num_edges_after == int(np.asarray(core.degrees).sum())
        core.validate()  # the from-scratch audit passes on the increment
        eng_lip = eng_lip.apply_churn(core, churn, lipschitz=lips_j)
        # production calling pattern (mirrors walk_sgd.run_dada): a
        # touched-rows-restricted buffer unless the batch outgrew the
        # engine's sticky cdf_width, which escalates to a full rebuild
        # and needs every row
        need_full = (
            int(np.asarray(core.degrees).max()) > eng_flat.cdf_width
        )
        eng_flat = eng_flat.apply_churn(
            core, churn,
            touched_probs=mh_importance_rows_ragged(
                core, lips,
                node_ids=None if need_full else churn.touched_rows,
            ),
        )
        assert eng_lip.graph_version == batch + 1
        assert eng_flat.graph_version == batch + 1
        assert eng_lip.cdf_width == eng_flat.cdf_width
        assert eng_lip.cdf_width >= int(np.asarray(core.degrees).max())

        # from-scratch oracle over the churned edge list
        pairs = _undirected_pairs(core)
        rebuilt = from_edges(
            core.n, pairs[:, 0], pairs[:, 1], layout="ragged"
        )
        for got, ref in (
            (core.indptr, rebuilt.indptr),
            (core.indices, rebuilt.indices),
            (core.degrees, rebuilt.degrees),
        ):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        rebuilt_csr = from_edges(
            core.n, pairs[:, 0], pairs[:, 1], layout="csr"
        )
        np.testing.assert_array_equal(padded.neighbors, rebuilt_csr.neighbors)
        np.testing.assert_array_equal(padded.degrees, rebuilt_csr.degrees)

        # from-scratch CDF oracle at the engine's sticky build width —
        # the bits every patched buffer must reproduce exactly
        ref_lip_cdf = ragged_edge_cdf(
            rebuilt.indptr, rebuilt.indices, rebuilt.degrees,
            lipschitz=lips_j, width=eng_lip.cdf_width,
        )
        ref_flat_cdf = ragged_edge_cdf(
            rebuilt.indptr, rebuilt.indices, rebuilt.degrees,
            row_probs=mh_importance_rows_ragged(rebuilt, lips),
            width=eng_flat.cdf_width,
        )
        np.testing.assert_array_equal(
            np.asarray(eng_lip.edge_cdf).view(np.int32),
            np.asarray(ref_lip_cdf).view(np.int32),
        )
        np.testing.assert_array_equal(
            np.asarray(eng_flat.edge_cdf).view(np.int32),
            np.asarray(ref_flat_cdf).view(np.int32),
        )
        # whenever the churn left the max degree at the build width, the
        # plain from_graph rebuild (natural width) is the same oracle —
        # the incremental engine equals a user's from-scratch engine
        if eng_lip.cdf_width == int(np.asarray(rebuilt.degrees).max()):
            ref_lip = WalkEngine.from_graph(
                rebuilt, PARAMS, lipschitz=lips_j, backend="scan",
                layout="ragged",
            )
            np.testing.assert_array_equal(
                np.asarray(eng_lip.edge_cdf).view(np.int32),
                np.asarray(ref_lip.edge_cdf).view(np.int32),
            )


@pytest.mark.parametrize(
    "seed,k_del,k_ins",
    [(1, 5, 5), (2, 8, 0), (3, 0, 8), (4, 1, 1), (5, 0, 0)],
)
def test_churn_differential_pinned(seed, k_del, k_ins):
    """Pinned churn-sequence draws — run with or without hypothesis;
    covers delete-only, insert-only and empty batches."""
    _check_churn_sequence(seed, k_del, k_ins)


if st is not None:

    @given(
        seed=st.integers(0, 7),
        k_del=st.integers(0, 8),
        k_ins=st.integers(0, 8),
    )
    @settings(max_examples=10, deadline=None)
    def test_churn_differential_hypothesis(seed, k_del, k_ins):
        _check_churn_sequence(seed, k_del, k_ins, batches=2)

else:

    @pytest.mark.skip(
        reason="hypothesis not installed (requirements-dev.txt): the "
        "randomized churn-sequence differential test is skipped; pinned "
        "draws still ran"
    )
    def test_churn_differential_hypothesis():
        """Visible placeholder so a missing hypothesis install shows up as
        a skip instead of the test silently vanishing from collection."""


def test_churn_width_change_on_padded_layout():
    """CSRGraph churn where the padded width must grow (inserts exceed the
    old max degree) and then shrink back (deleting the hub edges) stays
    bitwise-equal to the ``from_edges`` rebuild — the width-changed branch
    of the padded patch."""
    g = graphs_mod.ring(12, layout="csr")
    old_width = g.neighbors.shape[1]
    v = 0
    ins = np.asarray(
        [[v, u] for u in (3, 5, 6, 7, 9)], np.int64
    )  # degree 0: 3 -> 8 > old width
    g2, churn = apply_edge_churn(g, insert=ins)
    assert g2.neighbors.shape[1] > old_width
    g2.validate()
    pairs = _undirected_pairs(g2)
    rebuilt = from_edges(g2.n, pairs[:, 0], pairs[:, 1], layout="csr")
    np.testing.assert_array_equal(g2.neighbors, rebuilt.neighbors)
    g3, _ = apply_edge_churn(g2, delete=ins)
    assert g3.neighbors.shape[1] == old_width
    pairs = _undirected_pairs(g3)
    rebuilt3 = from_edges(g3.n, pairs[:, 0], pairs[:, 1], layout="csr")
    np.testing.assert_array_equal(g3.neighbors, rebuilt3.neighbors)
    np.testing.assert_array_equal(g3.indices, g.indices)


def test_churn_width_escalation_rebuilds_at_new_width():
    """Inserting onto the hub pushes the max degree past the engine's
    recorded ``cdf_width``: ``apply_churn`` escalates to a full
    from-scratch rebuild at the new width — bitwise-equal to a plain
    ``from_graph`` rebuild, whose natural width now agrees — and a
    touched-rows-restricted probability buffer is loudly rejected,
    because untouched rows need rebuilding too."""
    g = barabasi_albert(40, 3, seed=4, layout="csr")
    core = g.to_ragged()
    n = core.n
    lips = np.ones(n)
    lips[5] = 35.0
    lips_j = jnp.asarray(lips, jnp.float32)
    eng = WalkEngine.from_graph(
        core, PARAMS, lipschitz=lips_j, backend="scan", layout="ragged"
    )
    old_width = eng.cdf_width
    assert old_width == int(np.asarray(core.degrees).max())
    indptr = np.asarray(core.indptr, np.int64)
    hub = int(np.asarray(core.degrees, np.int64).argmax())
    nbrs = set(
        np.asarray(core.indices)[indptr[hub] : indptr[hub + 1]].tolist()
    )
    targets = [v for v in range(n) if v != hub and v not in nbrs][:2]
    ins = np.asarray(
        [[min(hub, v), max(hub, v)] for v in targets], np.int64
    )
    core2, churn = apply_edge_churn(core, insert=ins)
    new_max = int(np.asarray(core2.degrees).max())
    assert new_max > old_width

    # a buffer restricted to the touched closure cannot rebuild the
    # untouched rows the width change invalidates
    with pytest.raises(ValueError, match="full-length"):
        eng.apply_churn(
            core2, churn,
            touched_probs=mh_importance_rows_ragged(
                core2, lips, node_ids=churn.touched_rows
            ),
        )

    eng_lip = eng.apply_churn(core2, churn, lipschitz=lips_j)
    assert eng_lip.cdf_width == new_max == eng_lip.max_degree
    assert eng_lip.graph_version == 1
    ref_lip = WalkEngine.from_graph(
        core2, PARAMS, lipschitz=lips_j, backend="scan", layout="ragged"
    )
    np.testing.assert_array_equal(
        np.asarray(eng_lip.edge_cdf).view(np.int32),
        np.asarray(ref_lip.edge_cdf).view(np.int32),
    )

    eng_full = eng.apply_churn(
        core2, churn, touched_probs=mh_importance_rows_ragged(core2, lips)
    )
    ref_flat = WalkEngine.from_graph(
        core2, PARAMS,
        row_probs=mh_importance_rows_ragged(core2, lips),
        backend="scan", layout="ragged",
    )
    np.testing.assert_array_equal(
        np.asarray(eng_full.edge_cdf).view(np.int32),
        np.asarray(ref_flat.edge_cdf).view(np.int32),
    )


def test_churn_sticky_width_when_max_degree_drops():
    """Deleting hub edges lowers the graph's max degree; the engine keeps
    its recorded ``cdf_width`` (sticky — never shrinks) and the patched
    CDF matches the from-scratch oracle built at that same width, NOT a
    natural-width rebuild: XLA reduction bits depend on the
    materialization width, so the two oracles legitimately differ."""
    g = barabasi_albert(40, 3, seed=6, layout="csr")
    core = g.to_ragged()
    n = core.n
    lips_j = jnp.asarray(np.ones(n), jnp.float32)
    eng = WalkEngine.from_graph(
        core, PARAMS, lipschitz=lips_j, backend="scan", layout="ragged"
    )
    w0 = eng.cdf_width
    deg = np.asarray(core.degrees, np.int64)
    hub = int(deg.argmax())
    indptr = np.asarray(core.indptr, np.int64)
    hub_nbrs = np.asarray(core.indices, np.int64)[
        indptr[hub] : indptr[hub + 1]
    ]
    victims = [
        int(v) for v in hub_nbrs if v != hub and deg[v] >= 4
    ][: int(deg[hub]) - 1]
    dele = np.asarray(
        [[min(hub, v), max(hub, v)] for v in victims], np.int64
    )
    core2, churn = apply_edge_churn(core, delete=dele)
    new_max = int(np.asarray(core2.degrees).max())
    assert new_max < w0  # the hub WAS the max and lost enough edges
    eng2 = eng.apply_churn(core2, churn, lipschitz=lips_j)
    assert eng2.cdf_width == w0 and eng2.max_degree == new_max
    oracle = ragged_edge_cdf(
        core2.indptr, core2.indices, core2.degrees,
        lipschitz=lips_j, width=w0,
    )
    np.testing.assert_array_equal(
        np.asarray(eng2.edge_cdf).view(np.int32),
        np.asarray(oracle).view(np.int32),
    )


# ---------------------------------------------------------------------------
# 3: four-layout stepping parity on the churned graph
# ---------------------------------------------------------------------------


def test_four_layout_parity_post_churn():
    """The incrementally churned ragged engine steps bitwise-identically
    to fresh dense/sparse/bucketed/ragged engines built from the rebuilt
    graph — same key, W=37 (not a block multiple).

    The churn here is constrained to preserve the max degree (no pair
    touches a current hub): fresh engines materialize rows at the
    rebuilt graph's natural width, and cross-layout *bitwise* stepping
    parity holds exactly when that width equals the churned engine's
    sticky ``cdf_width`` (XLA reduction bits are width-dependent)."""
    g = barabasi_albert(48, 3, seed=1, layout="csr")
    core = g.to_ragged()
    lips = np.ones(g.n)
    lips[5] = 35.0
    rng = np.random.default_rng(3)
    eng = WalkEngine.from_graph(
        core, PARAMS, row_probs=mh_importance_rows_ragged(core, lips),
        backend="auto", layout="ragged",
    )
    max_deg = int(np.asarray(core.degrees).max())
    for batch in range(2):
        deg = np.asarray(core.degrees, np.int64)
        hub = deg >= max_deg
        ins, dele = _random_churn(core, rng, 4, 4)
        if dele is not None:
            dele = dele[~(hub[dele[:, 0]] | hub[dele[:, 1]])]
            dele = dele if dele.size else None
        if ins is not None:
            ins = ins[~(hub[ins[:, 0]] | hub[ins[:, 1]])]
            ins = ins if ins.size else None
        core, churn = apply_edge_churn(core, insert=ins, delete=dele)
        assert int(np.asarray(core.degrees).max()) == max_deg
        eng = eng.apply_churn(
            core, churn,
            touched_probs=mh_importance_rows_ragged(
                core, lips, node_ids=churn.touched_rows
            ),
        )
    assert eng.cdf_width == max_deg
    pairs = _undirected_pairs(core)
    dense = from_edges(core.n, pairs[:, 0], pairs[:, 1], layout="dense")
    csr = dense.to_csr()
    rp = jnp.asarray(row_probs_padded(mh_importance(dense, lips), dense))
    key = jax.random.PRNGKey(9)
    nodes = jnp.arange(37, dtype=jnp.int32) % core.n
    ref_n, ref_h = eng.step(key, nodes)
    for layout in ("dense", "sparse", "bucketed", "ragged"):
        fresh = WalkEngine.from_graph(
            csr, PARAMS, row_probs=rp, backend="auto", layout=layout
        )
        n2, h2 = fresh.step(key, nodes)
        np.testing.assert_array_equal(np.asarray(ref_n), np.asarray(n2))
        np.testing.assert_array_equal(np.asarray(ref_h), np.asarray(h2))


# ---------------------------------------------------------------------------
# 4: strict batch contract — every malformed batch raises, untouched graph
# ---------------------------------------------------------------------------


def test_churn_contract_errors():
    g = barabasi_albert(30, 3, seed=2, layout="csr")
    core = g.to_ragged()
    pairs = _undirected_pairs(core)
    present = pairs[:1]
    absent = None
    n = core.n
    codes = set((pairs[:, 0] * n + pairs[:, 1]).tolist())
    for a in range(n):
        for b in range(a + 1, n):
            if a * n + b not in codes:
                absent = np.asarray([[a, b]], np.int64)
                break
        if absent is not None:
            break

    with pytest.raises(ValueError, match="already present"):
        apply_edge_churn(core, insert=present)
    with pytest.raises(ValueError, match="not present"):
        apply_edge_churn(core, delete=absent)
    with pytest.raises(ValueError, match="overlap"):
        apply_edge_churn(core, insert=present, delete=present)
    with pytest.raises(ValueError, match="self-loops are structural"):
        apply_edge_churn(core, insert=np.asarray([[3, 3]], np.int64))
    with pytest.raises(ValueError, match="duplicate"):
        apply_edge_churn(
            core, insert=np.concatenate([absent, absent[:, ::-1]])
        )
    with pytest.raises(ValueError):
        apply_edge_churn(core, insert=np.asarray([[0, n]], np.int64))
    with pytest.raises(TypeError, match="to_csr"):
        apply_edge_churn(core.to_dense(), insert=absent)

    # engine-side contract
    lips_j = jnp.asarray(np.ones(n), jnp.float32)
    core2, churn = apply_edge_churn(core, insert=absent)
    eng_sparse = WalkEngine.from_graph(
        g, PARAMS, lipschitz=lips_j, backend="scan", layout="sparse"
    )
    with pytest.raises(ValueError, match="ragged"):
        eng_sparse.apply_churn(core2, churn, lipschitz=lips_j)
    eng = WalkEngine.from_graph(
        core, PARAMS, lipschitz=lips_j, backend="scan", layout="ragged"
    )
    with pytest.raises(ValueError, match="exactly one"):
        eng.apply_churn(core2, churn)
    with pytest.raises(ValueError, match="exactly one"):
        eng.apply_churn(
            core2, churn, lipschitz=lips_j,
            touched_probs=mh_importance_rows_ragged(
                core2, np.ones(n), node_ids=churn.touched_rows
            ),
        )
    # a touched set that misses a degree-changed row is rejected
    with pytest.raises(ValueError, match="touched"):
        ragged_edge_cdf_update(
            np.asarray(core.indptr, np.int64),
            np.asarray(core.degrees),
            eng.edge_cdf,
            core2.indptr,
            core2.indices,
            core2.degrees,
            np.asarray([], np.int64),
            lipschitz=lips_j,
        )


def test_churn_connectivity_gate():
    """Deleting a path tip's only non-loop edge departs the node; with
    ``check_connectivity=True`` the same batch fails loudly."""
    g = lollipop(6, 3, layout="csr")
    core = g.to_ragged()
    tip = core.n - 1
    nbrs = _undirected_pairs(core)
    tip_edges = nbrs[(nbrs[:, 0] == tip) | (nbrs[:, 1] == tip)]
    assert tip_edges.shape[0] == 1
    with pytest.raises(ValueError, match="disconnects"):
        apply_edge_churn(core, delete=tip_edges, check_connectivity=True)
    core2, churn = apply_edge_churn(core, delete=tip_edges)
    assert int(np.asarray(core2.degrees)[tip]) == 1  # departed: loop only
    assert tip in churn.endpoints and tip in churn.degree_changed


# ---------------------------------------------------------------------------
# 5: walk continuity — the documented re-seed rule, pinned exactly
# ---------------------------------------------------------------------------


def test_walk_continuity_pins_reseed_formula():
    g = lollipop(6, 3, layout="csr")
    core = g.to_ragged()
    tip = core.n - 1
    nbrs = _undirected_pairs(core)
    tip_edges = nbrs[(nbrs[:, 0] == tip) | (nbrs[:, 1] == tip)]
    core2, churn = apply_edge_churn(core, delete=tip_edges)
    deg2 = np.asarray(core2.degrees)

    nodes = np.asarray([0, tip, 3, tip], np.int32)
    new_nodes, displaced = migrate_walk_nodes(nodes, deg2, seed=11)
    np.testing.assert_array_equal(displaced, [False, True, False, True])
    # surviving walks carry their position bitwise
    assert new_nodes[0] == 0 and new_nodes[2] == 3
    # displaced walk w lands on active[sample_initial_nodes(len(active),
    # W, seed)[w]] — THE documented path, nothing else
    active = np.nonzero(deg2 > 1)[0].astype(np.int32)
    draws = sample_initial_nodes(int(active.size), 4, seed=11)
    assert new_nodes[1] == active[draws[1]]
    assert new_nodes[3] == active[draws[3]]
    assert (deg2[new_nodes] > 1).all()

    # fleet-level wiring: engine swap + migration in one call
    lips_j = jnp.asarray(np.ones(core.n), jnp.float32)
    eng = WalkEngine.from_graph(
        core, PARAMS, lipschitz=lips_j, backend="scan", layout="ragged"
    )
    fleet = WalkFleet(
        engine=eng, nodes=jnp.asarray([0, tip], jnp.int32), num_walks=2
    )
    eng2 = eng.apply_churn(core2, churn, lipschitz=lips_j)
    fleet2, disp = fleet.migrate(eng2, seed=11)
    assert fleet2.engine.graph_version == 1
    np.testing.assert_array_equal(disp, [False, True])
    assert int(np.asarray(fleet2.nodes)[0]) == 0
    assert int(np.asarray(fleet2.nodes)[1]) == active[
        sample_initial_nodes(int(active.size), 2, seed=11)[1]
    ]

    with pytest.raises(ValueError, match="out of range"):
        migrate_walk_nodes(np.asarray([core.n + 3]), deg2)
    with pytest.raises(ValueError, match="non-loop"):
        migrate_walk_nodes(nodes, np.ones(core.n, np.int64))


# ---------------------------------------------------------------------------
# 6 (slow): the churned chain still realizes the rebuilt dense law
# ---------------------------------------------------------------------------


def _chi_square_stat(counts, probs, min_expected=10.0):
    total = counts.sum()
    expected = probs * total
    big = expected >= min_expected
    obs = np.concatenate([counts[big], [counts[~big].sum()]])
    exp = np.concatenate([expected[big], [expected[~big].sum()]])
    keep = exp > 0
    obs, exp = obs[keep], exp[keep]
    stat = float(((obs - exp) ** 2 / exp).sum())
    return stat, len(obs) - 1


def _churned_engine_and_dense(seed=1):
    """One churn batch on the BA fixture graph; returns the incremental
    ragged engine and the rebuilt dense twin + lipschitz."""
    g = barabasi_albert(48, 3, seed=seed, layout="csr")
    core = g.to_ragged()
    lips = np.ones(g.n)
    lips[5] = 35.0
    rng = np.random.default_rng(17)
    eng = WalkEngine.from_graph(
        core, PARAMS, lipschitz=jnp.asarray(lips, jnp.float32),
        backend="auto", layout="ragged",
    )
    ins, dele = _random_churn(core, rng, 6, 6)
    core, churn = apply_edge_churn(core, insert=ins, delete=dele)
    eng = eng.apply_churn(core, churn, lipschitz=jnp.asarray(lips, jnp.float32))
    pairs = _undirected_pairs(core)
    dense = from_edges(core.n, pairs[:, 0], pairs[:, 1], layout="dense")
    return eng, dense, lips


@pytest.mark.slow
def test_post_churn_one_step_law_chi_square():
    """The churned engine's one-step empirical law from the trap node
    matches the dense ``mhlj()`` row of the REBUILT graph at ~4-sigma."""
    eng, dense, lips = _churned_engine_and_dense()
    start = 5
    w = 30_000
    nodes = jnp.full((w,), start, jnp.int32)
    expected_row = mhlj(dense, lips, PARAMS)[start]
    nxt, _ = eng.step(jax.random.PRNGKey(23), nodes)
    counts = np.bincount(np.asarray(nxt), minlength=dense.n).astype(np.float64)
    stat, dof = _chi_square_stat(counts, expected_row)
    crit = dof + 4.0 * np.sqrt(2.0 * dof)
    assert stat < crit, f"post-churn chi2={stat:.1f} >= {crit:.1f} (dof={dof})"


@pytest.mark.slow
def test_post_churn_update_occupancy_matches_chain_pi():
    """Long-run update occupancy of the churned engine matches the
    stationary ``pi`` of the rebuilt dense MHLJ chain (TV < 0.08)."""
    eng, dense, lips = _churned_engine_and_dense()
    pi = mixing.stationary_distribution(mhlj(dense, lips, PARAMS))
    num_walks, num_steps = 256, 800
    rng = np.random.default_rng(29)
    nodes = jnp.asarray(
        rng.choice(pi.size, size=num_walks, p=pi), jnp.int32
    )
    occupancy = []
    key = jax.random.PRNGKey(31)
    for _ in range(num_steps):
        key, sub = jax.random.split(key)
        nodes, _ = eng.step(sub, nodes)
        occupancy.append(np.asarray(nodes))
    emp = empirical_distribution(np.stack(occupancy), dense.n)
    tv = mixing.tv_distance(emp, pi)
    assert tv < 0.08, f"post-churn TV(emp, mhlj-pi)={tv:.3f}"


# ---------------------------------------------------------------------------
# 7: the learned-collaboration-graph loop, end to end
# ---------------------------------------------------------------------------


def test_run_dada_end_to_end():
    g = barabasi_albert(40, 3, seed=2, layout="csr")
    data = make_heterogeneous_regression(40, dim=5, seed=3)
    res = run_dada(
        g, data, rounds=3, num_steps=40, num_walks=4, k=3,
        method="mhlj", avg_every=10, seed=5, backend="scan",
    )
    assert res.round_mse.shape == (3,) and np.isfinite(res.round_mse).all()
    assert np.isfinite(res.personalized_mse).all()
    np.testing.assert_array_equal(res.graph_versions, [0, 1, 2])
    assert res.edges_inserted[:-1].sum() > 0  # the graph actually rewires
    assert res.edges_inserted[-1] == 0  # no rewire after the final round
    assert res.x_final.shape == (4, 5)
    # training made progress on the learned graph
    assert res.round_mse[-1] < res.round_mse[0]

    with pytest.raises(ValueError, match="mhlj"):
        run_dada(g, data, method="uniform")


def test_run_dada_round_one_is_plain_trainer():
    """Round 1 of the Dada loop is bitwise-identical to an ordinary
    ``run_rw_sgd_multi`` call on the same seed — the engine seam adds
    nothing to the single-graph path."""
    g = barabasi_albert(40, 3, seed=2, layout="csr")
    data = make_heterogeneous_regression(40, dim=5, seed=3)
    lips = np.asarray(data.lipschitz, np.float64)
    gamma = 0.3 / float(lips.mean())
    params = MHLJParams(p_j=0.1, p_d=0.5, r=3)
    ref = run_rw_sgd_multi(
        "mhlj", g.to_ragged(), data, gamma, 40, 4,
        mhlj_params=params, avg_every=10, seed=5,
    )
    res = run_dada(
        g, data, rounds=1, num_steps=40, num_walks=4, k=3,
        method="mhlj", avg_every=10, seed=5,
    )
    np.testing.assert_array_equal(np.asarray(ref.x_final), res.x_final)
    assert float(ref.avg_mse[-1]) == res.round_mse[0]
