"""Unified walk-engine correctness: backend parity + Remark-1 accounting.

The acceptance contract of the engine refactor: the scan backend, the
Pallas (interpret) backend, and the dense ``mhlj()`` matrix chain all
realize the SAME transition law, and the engine's hop counts reproduce the
Remark-1 communication budget.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MHLJParams,
    WalkEngine,
    expected_transitions_per_update,
    mh_importance,
    mhlj,
    p_is_rows,
    remark1_bound,
    row_probs_padded,
    watts_strogatz,
)
from repro.core.walk import graph_tensors


@pytest.fixture(scope="module")
def setup():
    # irregular graph: degree spread + an extreme-Lipschitz trap node
    g = watts_strogatz(50, 4, 0.2, seed=2)
    lips = np.ones(50)
    lips[7] = 40.0
    params = MHLJParams(0.25, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    return g, lips, params, rp


def _engine(g, params, rp, backend):
    return WalkEngine.from_graph(g, params, row_probs=rp, backend=backend)


def _chi_square_stat(counts, probs, min_expected=10.0):
    """Pearson chi-square with small-expectation bins lumped together.

    Returns (stat, dof).  No scipy in the image, so callers compare against
    the normal approximation dof + z * sqrt(2 dof).
    """
    total = counts.sum()
    expected = probs * total
    big = expected >= min_expected
    obs = np.concatenate([counts[big], [counts[~big].sum()]])
    exp = np.concatenate([expected[big], [expected[~big].sum()]])
    keep = exp > 0
    obs, exp = obs[keep], exp[keep]
    stat = float(((obs - exp) ** 2 / exp).sum())
    return stat, len(obs) - 1


def test_backends_bitwise_equal_including_padded_grid(setup):
    """Scan and Pallas backends consume identical uniforms -> identical
    outputs, also when W is not a block multiple (the padded-grid path)."""
    g, lips, params, rp = setup
    key = jax.random.PRNGKey(0)
    for w, block_w in ((128, 64), (300, 128), (37, 256)):
        nodes = jnp.arange(w, dtype=jnp.int32) % g.n
        eng_s = _engine(g, params, rp, "scan")
        eng_p = WalkEngine.from_graph(
            g, params, row_probs=rp, backend="pallas", block_w=block_w
        )
        n_s, h_s = eng_s.step(key, nodes)
        n_p, h_p = eng_p.step(key, nodes)
        np.testing.assert_array_equal(np.asarray(n_s), np.asarray(n_p))
        np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_p))


@pytest.mark.slow
def test_backends_match_dense_chain_chi_square(setup):
    """Empirical one-step update-node law of both backends vs the dense
    MHLJ matrix chain, chi-square at ~4-sigma."""
    g, lips, params, rp = setup
    start = 7
    w = 30_000
    nodes = jnp.full((w,), start, jnp.int32)
    expected_row = mhlj(g, lips, params)[start]  # chained-Levy exact law

    for backend in ("scan", "pallas"):
        nxt, _ = _engine(g, params, rp, backend).step(
            jax.random.PRNGKey(11), nodes
        )
        counts = np.bincount(np.asarray(nxt), minlength=g.n).astype(np.float64)
        stat, dof = _chi_square_stat(counts, expected_row)
        crit = dof + 4.0 * np.sqrt(2.0 * dof)
        assert stat < crit, f"{backend}: chi2={stat:.1f} >= {crit:.1f} (dof={dof})"


@pytest.mark.slow
def test_scan_pallas_empirical_distributions_agree(setup):
    """Two-sample chi-square between the backends' own empirical update-node
    distributions (independent keys, so not just bitwise identity)."""
    g, lips, params, rp = setup
    w = 30_000
    nodes = jnp.arange(w, dtype=jnp.int32) % g.n
    n_s, _ = _engine(g, params, rp, "scan").step(jax.random.PRNGKey(3), nodes)
    n_p, _ = _engine(g, params, rp, "pallas").step(jax.random.PRNGKey(4), nodes)
    c_s = np.bincount(np.asarray(n_s), minlength=g.n).astype(np.float64)
    c_p = np.bincount(np.asarray(n_p), minlength=g.n).astype(np.float64)
    pooled = (c_s + c_p) / (2.0 * w)
    stat_s, dof = _chi_square_stat(c_s, pooled)
    stat_p, _ = _chi_square_stat(c_p, pooled)
    crit = dof + 4.0 * np.sqrt(2.0 * dof)
    assert stat_s < crit and stat_p < crit


def test_remark1_hop_accounting(setup):
    """Engine hop counts match expected_transitions_per_update and stay
    within the paper's Remark-1 bound."""
    g, lips, params, rp = setup
    eng = _engine(g, params, rp, "scan")
    v0s = jnp.arange(32, dtype=jnp.int32) % g.n
    _, hops = eng.run(jax.random.PRNGKey(5), v0s, 3_000)
    measured = float(np.asarray(hops, np.float64).mean())
    exact = expected_transitions_per_update(params.p_j, params.p_d, params.r)
    bound = remark1_bound(params.p_j, params.p_d, params.r)
    assert abs(measured - exact) < 0.02
    assert measured <= bound + 0.02


def test_pj_zero_never_jumps(setup):
    g, lips, params, rp = setup
    eng = _engine(g, params, rp, "scan")
    _, hops = eng.run(
        jax.random.PRNGKey(6), jnp.arange(16, dtype=jnp.int32), 500, p_j=0.0
    )
    assert int(np.asarray(hops).max()) == 1


def test_scheduled_pj_anneals_hops(setup):
    """A (T,) p_J schedule flows through the engine (traced, not static)."""
    g, lips, params, rp = setup
    eng = _engine(g, params, rp, "scan")
    sched = jnp.concatenate(
        [jnp.full((500,), 0.5), jnp.zeros((500,))]
    ).astype(jnp.float32)
    _, hops = eng.run(
        jax.random.PRNGKey(7), jnp.arange(8, dtype=jnp.int32), 1_000, p_j=sched
    )
    hops = np.asarray(hops, np.float64)
    assert hops[:, :500].mean() > 1.1
    assert hops[:, 500:].mean() == 1.0


def test_live_rows_match_dense_p_is(setup):
    """Eq.-7 rows computed from a live Lipschitz vector scatter back to the
    dense mh_importance matrix exactly (self mass may spread over pads)."""
    g, lips, params, rp = setup
    dense = mh_importance(g, lips)
    nbrs, degs = graph_tensors(g)
    live = np.asarray(p_is_rows(nbrs, degs, jnp.asarray(lips, jnp.float32)))
    scattered = np.zeros((g.n, g.n))
    nbrs_np = np.asarray(g.neighbors)
    for v in range(g.n):
        np.add.at(scattered[v], nbrs_np[v], live[v])
    np.testing.assert_allclose(scattered, dense, atol=2e-6)


def test_backend_env_var_overrides_auto(setup, monkeypatch):
    """REPRO_BACKEND pins backend="auto" resolution (the CI matrix knob);
    explicit backends ignore it, bogus values fall through to the default."""
    from repro.core.engine import BACKEND_ENV_VAR

    g, lips, params, rp = setup
    eng = WalkEngine.from_graph(g, params, row_probs=rp, backend="auto")
    monkeypatch.setenv(BACKEND_ENV_VAR, "pallas")
    assert eng.resolved_backend == "pallas"
    monkeypatch.setenv(BACKEND_ENV_VAR, "scan")
    assert eng.resolved_backend == "scan"
    monkeypatch.setenv(BACKEND_ENV_VAR, "nonsense")
    assert eng.resolved_backend in ("scan", "pallas")  # platform default
    # explicit backend wins regardless of the env var
    monkeypatch.setenv(BACKEND_ENV_VAR, "pallas")
    explicit = WalkEngine.from_graph(g, params, row_probs=rp, backend="scan")
    assert explicit.resolved_backend == "scan"
    # and the env-pinned engine still samples the same law, bitwise
    nodes = jnp.arange(16, dtype=jnp.int32) % g.n
    key = jax.random.PRNGKey(0)
    n_env, h_env = eng.step(key, nodes)
    monkeypatch.delenv(BACKEND_ENV_VAR)
    n_ref, h_ref = WalkEngine.from_graph(
        g, params, row_probs=rp, backend="pallas"
    ).step(key, nodes)
    np.testing.assert_array_equal(np.asarray(n_env), np.asarray(n_ref))
    np.testing.assert_array_equal(np.asarray(h_env), np.asarray(h_ref))
