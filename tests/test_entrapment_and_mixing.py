"""The paper's core phenomenon: entrapment under MH-IS on sparse graphs, and
its resolution by MHLJ (paper §IV-§V, Theorem 1 ingredients)."""
import numpy as np
import pytest

from repro.core import (
    MHLJParams,
    complete,
    grid2d,
    mh_importance,
    mh_uniform,
    mhlj,
    ring,
)
from repro.core import entrapment, mixing, theory


def _trap_instance(n=16, trap=3, strength=50.0):
    lips = np.ones(n)
    lips[trap] = strength
    return lips, trap


def test_entrapment_dwell_time_on_ring():
    """Detailed balance forces huge dwell at the important node (Eq. 8)."""
    g = ring(16)
    lips, trap = _trap_instance()
    p_is = mh_importance(g, lips)
    dwell = entrapment.expected_dwell_time(p_is)
    assert dwell[trap] > 20  # ~ deg/2 * L_trap / L_neighbor scale
    assert dwell[trap] > 10 * np.median(dwell)


def test_mhlj_cuts_dwell_time(mhlj_params):
    g = ring(16)
    lips, trap = _trap_instance()
    dwell_is = entrapment.expected_dwell_time(mh_importance(g, lips))[trap]
    dwell_mhlj = entrapment.expected_dwell_time(mhlj(g, lips, mhlj_params))[trap]
    assert dwell_mhlj < 0.3 * dwell_is


@pytest.mark.parametrize("graph_fn", [lambda: ring(16), lambda: grid2d(4, 4)])
def test_jumps_shrink_mixing_time_on_sparse_graphs(graph_fn, mhlj_params):
    """Paper §VI: tau_mix(MHLJ) < tau_mix(MH-IS) on sparse trap graphs."""
    g = graph_fn()
    lips, _ = _trap_instance(g.n)
    t_is = mixing.mixing_time_tv(mh_importance(g, lips))
    t_mhlj = mixing.mixing_time_tv(mhlj(g, lips, mhlj_params))
    assert t_mhlj < t_is


def test_no_entrapment_on_well_connected_graph(mhlj_params):
    """Entrapment is a sparse-graph phenomenon (paper §IV): on a complete
    graph the IS walk mixes fast even with extreme heterogeneity."""
    g = complete(16)
    lips, _ = _trap_instance(16)
    assert mixing.mixing_time_tv(mh_importance(g, lips)) < 64


def test_spectral_gap_ordering(mhlj_params):
    g = ring(20)
    lips, _ = _trap_instance(20)
    gap_is = mixing.spectral_gap(mh_importance(g, lips))
    gap_mhlj = mixing.spectral_gap(mhlj(g, lips, mhlj_params))
    assert gap_mhlj > gap_is


def test_mixing_time_bounds_bracket_empirical(small_ring, hetero_lipschitz):
    p = mh_uniform(small_ring)
    t_emp = mixing.mixing_time_tv(p, eps=0.25)
    bounds = mixing.mixing_time_bounds(p, eps=0.25)
    assert bounds["lower"] <= t_emp <= bounds["upper"] + 1


def test_conductance_explains_trap():
    g = ring(16)
    lips, _ = _trap_instance()
    phi_is = mixing.conductance(mh_importance(g, lips))
    phi_uni = mixing.conductance(mh_uniform(g))
    assert phi_is < phi_uni  # the IS chain has the tighter bottleneck


def test_error_gap_scales_quadratically_in_pj(small_ring, hetero_lipschitz):
    """Theorem 1's second term is O(p_J^2 ||P_IS - P_Levy||_1^2)."""
    gaps = []
    for p_j in (0.05, 0.1, 0.2):
        t = theory.theorem1_terms(
            small_ring, hetero_lipschitz, MHLJParams(p_j, 0.5, 3), num_iters=1000
        )
        gaps.append(t.gap_term)
    # doubling p_j quadruples the gap term
    np.testing.assert_allclose(gaps[1] / gaps[0], 4.0, rtol=1e-6)
    np.testing.assert_allclose(gaps[2] / gaps[1], 4.0, rtol=1e-6)


def test_perturbation_l1_bounded_by_n_squared(small_ring, hetero_lipschitz, mhlj_params):
    pert = theory.perturbation_l1(small_ring, hetero_lipschitz, mhlj_params)
    assert 0 < pert <= small_ring.n**2  # paper: "upper bounded by n^2"


def test_needell_speedup_prediction(hetero_lipschitz):
    rates = theory.needell_rates(hetero_lipschitz, num_iters=1000)
    # heterogeneous: L_max >> L_bar ~ L_min => IS rate better than uniform
    assert rates["importance"] < rates["uniform"]
    assert rates["speedup_predicted"] > 1.0


# ---------------------------------------------------------------------------
# Non-mixing sentinel + trajectory validation — satellite regressions
# ---------------------------------------------------------------------------


def test_mixing_time_raises_on_non_mixing_chain():
    """Pre-fix, mixing_time_tv returned max_t for a chain that NEVER mixes
    (reducible identity chain) — indistinguishable from 'mixed exactly at
    max_t', so sweeps recorded garbage mixing times."""
    p = np.eye(4)  # reducible: TV to pi never decays
    with pytest.raises(mixing.NotMixedError) as exc:
        mixing.mixing_time_tv(p, max_t=64)
    assert exc.value.max_t == 64
    assert exc.value.worst_tv > 0.25  # genuinely far from mixed
    assert "not mixed" in str(exc.value)


def test_mixing_time_raises_on_periodic_chain():
    """A 2-cycle is periodic: TV oscillates and never stays below eps."""
    p = np.array([[0.0, 1.0], [1.0, 0.0]])
    with pytest.raises(mixing.NotMixedError):
        mixing.mixing_time_tv(p, max_t=128)


def test_mixing_time_still_returns_for_mixing_chain():
    """Validation must not break the happy path: a lazy ring chain mixes
    and reports a finite time well under max_t."""
    t = mixing.mixing_time_tv(mh_uniform(ring(8)))
    assert 1 <= t < 4096


def test_visit_fractions_rejects_out_of_range_ids():
    """Pre-fix, ids >= n were silently dropped by bincount truncation —
    occupancy summed to < 1 and entrapment metrics were quietly wrong
    whenever a trajectory was paired with the wrong graph size."""
    with pytest.raises(ValueError, match="trajectory and graph size"):
        entrapment.visit_fractions(np.array([0, 1, 7]), 4)
    with pytest.raises(ValueError, match="trajectory and graph size"):
        entrapment.visit_fractions(np.array([-1, 0, 1]), 4)
    with pytest.raises(ValueError, match="empty"):
        entrapment.visit_fractions(np.array([], dtype=int), 4)
    # happy path unchanged: fractions over n bins summing to 1
    f = entrapment.visit_fractions(np.array([0, 0, 3]), 4)
    np.testing.assert_allclose(f, [2 / 3, 0.0, 0.0, 1 / 3])
