"""Fault-injection tests: liveness rejection, jump rescue, scripted
scenarios, crash-consistent fleet/dada checkpoints, and degradation-aware
serving (docs/faults.md).

Two frozen-oracle pins guard the no-fault seam: ``faults=None`` through
``WalkEngine.step`` and ``run_fleet`` must stay bitwise-identical to the
pre-fault stack (goldens captured from the last pre-fault commit), so the
fault layer can NEVER perturb a healthy run — not by one key split.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import WalkEngine
from repro.core.faults import (
    NEVER,
    FaultModel,
    FaultState,
    apply_liveness,
    dumbbell_bridge_mask,
    edge_slot_lookup,
    kill_top_hubs,
    live_uniform_choice,
    partition_groups,
)
from repro.core.graphs import barabasi_albert, dumbbell
from repro.core.transition import MHLJParams
from repro.models import regression as reg
from repro.walk_sgd.fleet import (
    WalkFleet,
    load_fleet_checkpoint,
    run_fleet,
    sample_initial_nodes,
    save_fleet_checkpoint,
)

# ---------------------------------------------------------------------------
# model validation + state lifecycle
# ---------------------------------------------------------------------------


def test_fault_model_validation():
    with pytest.raises(ValueError, match="together"):
        FaultModel(down_at=jnp.zeros(4, jnp.int32))
    with pytest.raises(ValueError, match="together"):
        FaultModel(edge_up_at=jnp.zeros(4, jnp.int32))
    with pytest.raises(ValueError, match="patience"):
        FaultModel(patience=0)


def test_init_state_all_live():
    fm = FaultModel(crash_rate=0.1, recovery_rate=0.1)
    st = fm.init_state(7, 3)
    assert bool(st.live.all()) and st.live.shape == (7,)
    assert st.blocked.shape == (3,) and not st.blocked.any()
    assert int(st.t) == 0
    assert bool(fm.live_mask(st).all())


def test_markov_advance_reaches_steady_state():
    fm = FaultModel(crash_rate=0.2, recovery_rate=0.2)
    st = fm.init_state(400, 1)
    for i in range(60):
        st = fm.advance(jax.random.PRNGKey(i), st)
    frac_down = 1.0 - float(fm.live_mask(st).mean())
    # steady state crash/(crash+recovery) = 0.5 (tolerance: 400 nodes)
    assert 0.35 < frac_down < 0.65
    assert int(st.t) == 60


def test_scripted_window_is_pure():
    """Scripted-only models never mutate the Markov live vector."""
    n = 6
    down = np.full(n, NEVER, np.int32)
    up = np.full(n, NEVER, np.int32)
    down[2], up[2] = 3, 5
    fm = FaultModel(down_at=jnp.asarray(down), up_at=jnp.asarray(up))
    st = fm.init_state(n, 1)
    seen = []
    for i in range(7):
        seen.append(bool(fm.live_mask(st)[2]))
        st = fm.advance(jax.random.PRNGKey(i), st)
        assert bool(st.live.all())  # Markov component untouched
    assert seen == [True, True, True, False, False, True, True]


# ---------------------------------------------------------------------------
# the rejection + rescue arithmetic
# ---------------------------------------------------------------------------


def _liveness_case(live_np, nodes, nxt, blocked, **kw):
    W = len(nodes)
    return apply_liveness(
        jax.random.PRNGKey(0),
        jnp.asarray(nodes, jnp.int32),
        jnp.asarray(nxt, jnp.int32),
        jnp.ones(W, jnp.int32),
        jnp.asarray(blocked, jnp.int32),
        jnp.asarray(live_np, bool),
        **kw,
    )


def test_rejection_rule_endpoints():
    # nodes 0..3; node 2 dead. walk0 moves 0->1 (ok), walk1 moves 1->2
    # (dst dead), walk2 sits on 2 (self dead), walk3 stays at 3 (ok)
    live = [True, True, False, True]
    out, hops, blocked, was_blocked, rescued = _liveness_case(
        live, [0, 1, 2, 3], [1, 2, 2, 3], [0, 0, 0, 0],
        patience=3, rescue=True,
    )
    assert np.asarray(out).tolist() == [1, 1, 2, 3]
    assert np.asarray(was_blocked).tolist() == [False, True, True, False]
    # blocked counters: reset on success, increment on rejection
    assert np.asarray(blocked).tolist() == [0, 1, 1, 0]
    assert not np.asarray(rescued).any()


def test_patience_triggers_rescue_and_resets():
    live = [False, True, True, True]
    out, hops, blocked, was_blocked, rescued = _liveness_case(
        live, [0, 0, 0, 0], [0, 0, 0, 0], [0, 1, 2, 5],
        patience=3, rescue=True, rescue_hops=4,
    )
    r = np.asarray(rescued).tolist()
    assert r == [False, False, True, True]
    out = np.asarray(out)
    assert (out[2:] != 0).all() and np.asarray(live)[out[2:]].all()
    assert np.asarray(hops).tolist()[2:] == [4, 4]
    assert np.asarray(blocked).tolist() == [1, 2, 0, 0]


def test_rescue_off_parks_walkers_indefinitely():
    live = [False, True, True]
    out, hops, blocked, _, rescued = _liveness_case(
        live, [0, 0, 0], [1, 1, 1], [0, 7, 99], patience=3, rescue=False,
    )
    assert np.asarray(out).tolist() == [0, 0, 0]
    assert np.asarray(blocked).tolist() == [1, 8, 100]
    assert not np.asarray(rescued).any()


def test_total_failure_parks_even_with_rescue():
    live = [False, False, False]
    out, _, blocked, was_blocked, rescued = _liveness_case(
        live, [0, 1, 2], [1, 2, 0], [5, 5, 5], patience=1, rescue=True,
    )
    assert np.asarray(out).tolist() == [0, 1, 2]
    assert np.asarray(was_blocked).all()
    assert not np.asarray(rescued).any()  # no live target: stay parked


def test_live_uniform_choice_lands_on_live_set():
    live = jnp.asarray([False, True, False, True, True, False])
    u = jax.random.uniform(jax.random.PRNGKey(3), (512,))
    picks = np.asarray(live_uniform_choice(u, live))
    assert set(picks.tolist()) == {1, 3, 4}
    counts = np.bincount(picks, minlength=6)[[1, 3, 4]]
    assert counts.min() > 512 / 3 * 0.6  # roughly uniform


def test_edge_slot_lookup_found_and_missing():
    g = dumbbell(4, layout="ragged")
    indptr = jnp.asarray(np.asarray(g.indptr))
    indices = jnp.asarray(np.asarray(g.indices))
    indices_np = np.asarray(g.indices)
    indptr_np = np.asarray(g.indptr)
    src = jnp.asarray([0, 0], jnp.int32)
    # 0->1 exists in the clique; 0->(n-1) crosses to the far clique: absent
    dst = jnp.asarray([1, g.n - 1], jnp.int32)
    slot, found = edge_slot_lookup(
        indptr, indices, src, dst, int(np.asarray(g.degrees).max())
    )
    assert np.asarray(found).tolist() == [True, False]
    s = int(np.asarray(slot)[0])
    assert indptr_np[0] <= s < indptr_np[1] and indices_np[s] == 1


# ---------------------------------------------------------------------------
# engine integration: the faults= step path
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense_engine():
    g = dumbbell(6, layout="dense")
    return g, WalkEngine.from_graph(
        g, MHLJParams(p_j=0.2, p_d=0.5, r=2),
        lipschitz=np.ones(g.n), backend="scan",
    )


def test_faults_none_path_matches_prefault_golden(dense_engine):
    """FROZEN ORACLE: faults=None consumes the key exactly like the
    pre-fault engine (golden captured from the last pre-fault commit)."""
    _, eng = dense_engine
    nxt, hops = eng.step(jax.random.PRNGKey(0), jnp.arange(4, dtype=jnp.int32))
    assert np.asarray(nxt).tolist() == [0, 3, 1, 2]
    assert np.asarray(hops).tolist() == [1, 1, 1, 1]


def test_faults_require_with_aux(dense_engine):
    g, eng = dense_engine
    fm = FaultModel(crash_rate=0.1, recovery_rate=0.1)
    st = fm.init_state(g.n, 4)
    with pytest.raises(ValueError, match="with_aux"):
        eng.step(jax.random.PRNGKey(0), jnp.arange(4, dtype=jnp.int32),
                 faults=(fm, st))


def test_engine_step_all_dead_stays_put(dense_engine):
    g, eng = dense_engine
    fm = FaultModel(patience=1)
    st = dataclasses.replace(
        fm.init_state(g.n, 4), live=jnp.zeros(g.n, bool)
    )
    nodes = jnp.arange(4, dtype=jnp.int32)
    nxt, hops, aux = eng.step(
        jax.random.PRNGKey(0), nodes, with_aux=True, faults=(fm, st)
    )
    assert np.array_equal(np.asarray(nxt), np.asarray(nodes))
    assert np.asarray(aux["fault_blocked"]).all()
    assert np.asarray(aux["blocked_steps"]).tolist() == [1, 1, 1, 1]
    assert not np.asarray(aux["rescued"]).any()


def test_engine_step_scans_with_fault_carry(dense_engine):
    g, eng = dense_engine
    fm = FaultModel(crash_rate=0.3, recovery_rate=0.2, patience=2)

    def body(carry, k):
        v, st = carry
        st = fm.advance(k, st)
        nn, _h, aux = eng.step(k, v, with_aux=True, faults=(fm, st))
        st = dataclasses.replace(st, blocked=aux["blocked_steps"])
        return (nn, st), (aux["rescued"].sum(), aux["fault_blocked"].sum())

    keys = jax.random.split(jax.random.PRNGKey(1), 50)
    (_vf, stf), (resc, blk) = jax.lax.scan(
        body, (jnp.arange(4, dtype=jnp.int32), fm.init_state(g.n, 4)), keys
    )
    assert int(np.asarray(blk).sum()) > 0
    assert int(np.asarray(resc).sum()) > 0
    assert int(stf.t) == 50


def test_scripted_partition_blocks_bridge_crossings():
    g = dumbbell(6, layout="ragged")
    eng = WalkEngine.from_graph(
        g, MHLJParams(p_j=0.0, p_d=0.5, r=1),
        lipschitz=np.ones(g.n), backend="scan",
    )
    side = dumbbell_bridge_mask(g.n, 6, 1)
    fm = partition_groups(g.indptr, g.indices, side, at=0, patience=10,
                          rescue=False)
    st = fm.init_state(g.n, 16)
    # all walkers on the bridge node: any accepted move crossing the cut
    # must have been rejected, so sides never mix
    nodes = jnp.full(16, 6, jnp.int32)  # bridge node of dumbbell(6, 1)
    for i in range(20):
        nodes, _h, aux = eng.step(
            jax.random.PRNGKey(i), nodes, with_aux=True, faults=(fm, st)
        )
        st = dataclasses.replace(st, blocked=aux["blocked_steps"])
    # the bridge node sits on the A side of the cut: nobody crossed
    assert not side[np.asarray(nodes)].any()


def test_kill_top_hubs_scripts_the_right_nodes():
    g = barabasi_albert(64, 2, seed=0, layout="ragged")
    deg = np.asarray(g.degrees)
    fm = kill_top_hubs(deg, 3, at=5, duration=10)
    top = np.argsort(-deg, kind="stable")[:3]
    st = fm.init_state(g.n, 1)
    assert bool(fm.live_mask(st).all())  # before the window
    st = dataclasses.replace(st, t=jnp.int32(5))
    mask = np.asarray(fm.live_mask(st))
    assert not mask[top].any() and mask.sum() == g.n - 3
    st = dataclasses.replace(st, t=jnp.int32(15))
    assert bool(fm.live_mask(st).all())  # recovered
    with pytest.raises(ValueError, match="k must be"):
        kill_top_hubs(deg, 0, at=0)


def test_partition_validation():
    g = dumbbell(4, layout="ragged")
    with pytest.raises(ValueError, match="bool mask"):
        partition_groups(g.indptr, g.indices, np.zeros(3, bool), at=0)
    with pytest.raises(ValueError, match="cuts no edge"):
        partition_groups(g.indptr, g.indices, np.zeros(g.n, bool), at=0)
    with pytest.raises(ValueError, match="not a dumbbell"):
        dumbbell_bridge_mask(10, 6, 1)


# ---------------------------------------------------------------------------
# fleet: frozen no-fault golden, checkpoint resume, faulted runs
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fleet_case():
    g = dumbbell(8, layout="dense")
    eng = WalkEngine.from_graph(
        g, MHLJParams(p_j=0.2, p_d=0.5, r=2),
        lipschitz=np.ones(g.n), backend="scan",
    )
    fleet = WalkFleet.create(eng, 4, seed=3, avg_every=5)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.n, 3))
    targs = rng.normal(size=(g.n,))
    return g, fleet, feats, targs


def _run(fleet_case, fleet, steps, sched, **kw):
    _g, _fleet, feats, targs = fleet_case
    return run_fleet(
        jax.random.PRNGKey(42), np.zeros((4, 3)), feats, targs,
        np.ones(feats.shape[0]), fleet, steps, 0.05, sched, False,
        reg.linear_grad, **kw,
    )


def test_run_fleet_no_faults_matches_prefault_golden(fleet_case):
    """FROZEN ORACLE: the full no-fault training scan is bitwise-identical
    to the pre-fault ``run_fleet`` (goldens captured pre-change)."""
    _g, fleet, _f, _t = fleet_case
    sched = np.full(30, 0.2, np.float32)
    xs, mses, avg, nodes, hops, final = _run(fleet_case, fleet, 30, sched)
    gold = np.array(
        [[-0.05618035048246384, 0.5244519710540771, -0.018438000231981277]]
        * 4
    )
    assert np.array_equal(np.asarray(xs, np.float64), gold)
    assert float(np.asarray(avg)[-1]) == 0.6565974950790405
    assert int(np.asarray(nodes).sum()) == 752
    assert int(np.asarray(hops).sum()) == 129
    assert np.asarray(final["nodes"]).shape == (4,)


def test_fleet_checkpoint_resume_is_bitwise(fleet_case, tmp_path):
    """Kill at step 18 of 30, checkpoint through disk, resume: the stitched
    run equals the uninterrupted one bitwise."""
    _g, fleet, _f, _t = fleet_case
    sched = np.full(30, 0.2, np.float32)
    ref = _run(fleet_case, fleet, 30, sched)

    a = _run(fleet_case, fleet, 18, sched[:18], total_steps=30)
    fleet_mid = dataclasses.replace(fleet, nodes=a[5]["nodes"])
    path = save_fleet_checkpoint(
        str(tmp_path / "fleet.npz"), fleet_mid, step=18,
        extras={"xs": np.asarray(a[0])},
    )
    fleet_r, step_r, extras_r = load_fleet_checkpoint(path)
    assert step_r == 18
    b = run_fleet(
        jax.random.PRNGKey(42), jnp.asarray(extras_r["xs"]),
        fleet_case[2], fleet_case[3], np.ones(fleet_case[2].shape[0]),
        fleet_r, 12, 0.05, sched[18:], False, reg.linear_grad,
        start_step=18, total_steps=30,
    )
    assert np.array_equal(np.asarray(b[0]), np.asarray(ref[0]))
    nodes_full = np.concatenate(
        [np.asarray(a[3]), np.asarray(b[3])], axis=1
    )
    assert np.array_equal(nodes_full, np.asarray(ref[3]))
    mse_full = np.concatenate(
        [np.asarray(a[1]), np.asarray(b[1])[:, 1:]], axis=1
    )
    assert np.array_equal(mse_full, np.asarray(ref[1]))


def test_faulted_fleet_run_and_checkpoint_resume(fleet_case, tmp_path):
    """The faulted scan produces rescue telemetry, and a mid-run
    checkpoint carrying the FaultState resumes bitwise."""
    _g, fleet, _f, _t = fleet_case
    fm = FaultModel(crash_rate=0.05, recovery_rate=0.1, patience=2)
    sched = np.full(120, 0.2, np.float32)
    ref = _run(fleet_case, fleet, 120, sched, faults=fm)
    assert ref[5]["fault_state"] is not None
    assert int(np.asarray(ref[5]["blocked"]).sum()) > 0
    assert int(np.asarray(ref[5]["rescued"]).sum()) > 0

    a = _run(fleet_case, fleet, 70, sched[:70], faults=fm, total_steps=120)
    st_mid = a[5]["fault_state"]
    fleet_mid = dataclasses.replace(fleet, nodes=a[5]["nodes"])
    path = save_fleet_checkpoint(
        str(tmp_path / "faulted.npz"), fleet_mid, step=70,
        extras={
            "xs": np.asarray(a[0]),
            "fault_live": np.asarray(st_mid.live),
            "fault_blocked": np.asarray(st_mid.blocked),
            "fault_t": np.asarray(st_mid.t),
        },
    )
    fl, step_r, ex = load_fleet_checkpoint(path)
    st_restored = FaultState(
        live=jnp.asarray(ex["fault_live"]),
        blocked=jnp.asarray(ex["fault_blocked"]),
        t=jnp.asarray(ex["fault_t"]),
    )
    b = run_fleet(
        jax.random.PRNGKey(42), jnp.asarray(ex["xs"]), fleet_case[2],
        fleet_case[3], np.ones(fleet_case[2].shape[0]), fl, 50, 0.05,
        sched[70:], False, reg.linear_grad, faults=fm,
        fault_state=st_restored, start_step=70, total_steps=120,
    )
    assert np.array_equal(np.asarray(b[0]), np.asarray(ref[0]))


def test_rescue_off_fleet_accumulates_blocked_without_rescues(fleet_case):
    _g, fleet, _f, _t = fleet_case
    fm = FaultModel(crash_rate=0.1, recovery_rate=0.05, patience=2,
                    rescue=False)
    out = _run(fleet_case, fleet, 80, np.full(80, 0.2, np.float32),
               faults=fm)
    assert int(np.asarray(out[5]["blocked"]).sum()) > 0
    assert int(np.asarray(out[5]["rescued"]).sum()) == 0


def test_empty_active_node_set_raises():
    with pytest.raises(ValueError, match="active-node set is empty"):
        sample_initial_nodes(0, 4)


def test_run_fleet_window_validation(fleet_case):
    _g, fleet, _f, _t = fleet_case
    with pytest.raises(ValueError, match="start_step"):
        _run(fleet_case, fleet, 10, np.full(10, 0.2, np.float32),
             start_step=-1)
    with pytest.raises(ValueError, match="exceeds"):
        _run(fleet_case, fleet, 10, np.full(10, 0.2, np.float32),
             start_step=5, total_steps=10)


# ---------------------------------------------------------------------------
# dada: crash-consistent round checkpoints
# ---------------------------------------------------------------------------


def test_run_dada_kill_and_restore_is_bitwise(tmp_path, monkeypatch):
    from repro.core.graphs import watts_strogatz
    from repro.data.synthetic import make_homogeneous_regression
    from repro.walk_sgd import graph_learning as gl

    g = watts_strogatz(24, 4, 0.2, seed=3)
    data = make_homogeneous_regression(g.n, dim=4, seed=5)
    kw = dict(rounds=3, num_steps=60, num_walks=4, k=3, avg_every=20,
              seed=11, backend="scan")
    ref = gl.run_dada(g, data, **kw)

    path = str(tmp_path / "dada.npz")
    orig = gl.run_rw_sgd_multi
    calls = {"n": 0}

    def dying(*a, **k):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("simulated crash in round 2")
        return orig(*a, **k)

    monkeypatch.setattr(gl, "run_rw_sgd_multi", dying)
    with pytest.raises(RuntimeError, match="simulated crash"):
        gl.run_dada(g, data, checkpoint_path=path, **kw)
    monkeypatch.setattr(gl, "run_rw_sgd_multi", orig)
    import os
    assert os.path.exists(path), "round-1 checkpoint missing after crash"

    res = gl.run_dada(g, data, checkpoint_path=path, **kw)
    for f in ("round_mse", "personalized_mse", "edges_inserted",
              "edges_deleted", "walks_displaced", "graph_versions",
              "x_final"):
        assert np.array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(res, f))
        ), f

    # completed checkpoint: the fast path returns without recompute
    res2 = gl.run_dada(g, data, checkpoint_path=path, **kw)
    assert np.array_equal(res2.x_final, ref.x_final)

    # config mismatch refuses to resume rather than corrupt
    with pytest.raises(ValueError, match="refusing to resume"):
        gl.run_dada(g, data, checkpoint_path=path,
                    **{**kw, "seed": 12})


# ---------------------------------------------------------------------------
# serving: degradation telemetry, shed-exactly-once, trace replay
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_graph():
    return barabasi_albert(96, 2, seed=0, layout="ragged")


def _serve_sim(graph, *, fault_model=None, trace=None, seed=0):
    from repro.configs import get_arch, reduced
    from repro.launch.serve import ServeEngine, ServeSimulator

    cfg = reduced(get_arch("mamba2-370m"))
    eng = ServeEngine(cfg, 2, 64, seed=0, max_queue=8)
    sim = ServeSimulator(
        graph, eng, method="mhlj", num_walkers=6, rate=1.2, pickup=2,
        deadline_ticks=40, prompt_len=(3, 6), max_new_tokens=4, seed=seed,
        fault_model=fault_model, relocate_after=2, arrival_trace=trace,
    )
    return sim


def test_faulted_serving_degrades_gracefully(serve_graph):
    """Faults produce degradation telemetry while every offered request is
    accounted for exactly once (completed/shed/pending/queued/in-slot) —
    the shed-exactly-once invariant under recycle + deadline + node_down."""
    fm = FaultModel(crash_rate=0.04, recovery_rate=0.1, patience=2)
    sim = _serve_sim(serve_graph, fault_model=fm)
    m = sim.run(80, drain_ticks=40)
    assert m["completed"] > 0  # the cluster keeps serving through faults
    assert m["walker_blocked_steps"] > 0
    assert m["walker_rescues"] > 0
    assert m["node_downtime_frac"] > 0
    tot_shed = (
        m["shed_queue_full"] + m["shed_deadline"] + m["shed_node_down"]
    )
    eng = sim.engine
    assert tot_shed == len(eng.shed_requests)
    rids = [r.rid for r in eng.shed_requests] + [
        r.rid for r in eng.completed
    ]
    assert len(rids) == len(set(rids))  # nothing shed/completed twice
    assert m["offered"] == (
        m["completed"] + tot_shed + m["pending_left"] + m["queued_left"]
        + sum(s is not None for s in eng.slots)
    )


def test_no_fault_serving_keeps_fault_telemetry_zero(serve_graph):
    sim = _serve_sim(serve_graph)
    m = sim.run(30, drain_ticks=10)
    assert m["walker_rescues"] == 0
    assert m["walker_blocked_steps"] == 0
    assert m["shed_node_down"] == 0
    assert m["node_downtime_frac"] == 0.0
    assert m["relocated_requests"] == 0


def test_arrival_trace_roundtrip_and_replay_identity(serve_graph, tmp_path):
    """Record a fault-free trace, replay it under two rescue policies: all
    legs see the identical workload (offered == trace rows) and identical
    seeds give identical completions."""
    from repro.launch.serve import load_arrival_trace, save_arrival_trace

    src = _serve_sim(serve_graph)
    src.run(30, drain_ticks=10)
    trace = np.asarray(src.arrival_log, np.int64)
    assert trace.shape[1] == 3
    path = str(tmp_path / "trace.npz")
    save_arrival_trace(path, trace)
    loaded = load_arrival_trace(path)
    assert np.array_equal(loaded, trace)

    fm_on = FaultModel(crash_rate=0.04, recovery_rate=0.1, patience=2)
    fm_off = dataclasses.replace(fm_on, rescue=False)
    a = _serve_sim(serve_graph, fault_model=fm_on, trace=loaded)
    a.run(30, drain_ticks=10)
    b = _serve_sim(serve_graph, fault_model=fm_on, trace=loaded)
    b.run(30, drain_ticks=10)
    assert a.arrival_log == b.arrival_log
    pa = [(r.rid, r.prompt.tolist()) for r in a.engine.completed]
    pb = [(r.rid, r.prompt.tolist()) for r in b.engine.completed]
    assert pa == pb  # same trace + seed -> bitwise same outcome
    c = _serve_sim(serve_graph, fault_model=fm_off, trace=loaded)
    c.run(30, drain_ticks=10)
    assert a.arrival_log == c.arrival_log  # identical load across legs
    assert a.offered == c.offered == len(loaded)
    assert c.rescues == 0 and a.rescues >= 0


def test_save_arrival_trace_validates(tmp_path):
    from repro.launch.serve import load_arrival_trace, save_arrival_trace

    path = str(tmp_path / "empty.npz")
    save_arrival_trace(path, [])
    assert load_arrival_trace(path).shape == (0, 3)
    with pytest.raises(ValueError):
        save_arrival_trace(str(tmp_path / "bad.npz"), [[1, 2]])
