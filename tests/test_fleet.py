"""Fleet-loop tests: the unified W-walker scan vs the pre-refactor oracle,
sharded-vs-unsharded parity, shared v0 seeding, and the averaging-traffic
model.

The oracle functions below are FROZEN copies of the two training scans the
fleet refactor replaced (``trainer._run_scan`` and ``trainer._run_scan_multi``
as of the last pre-fleet commit).  They are the ground truth for "the
refactor changed no numerics": every path through
``repro.walk_sgd.fleet.run_fleet`` — including the W=1 case behind
``run_rw_sgd`` and the mesh-sharded path on a 1-device mesh — must be
bitwise-identical to them per key.  The multi-device leg only pins the
walk stream bitwise (the cross-device all-reduce may re-associate the
float mean) and bounds the trace drift.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import WalkEngine
from repro.core.graphs import barabasi_albert, ring
from repro.core.transition import MHLJParams
from repro.data.synthetic import make_heterogeneous_regression
from repro.launch.mesh import make_walker_mesh
from repro.models import regression as reg
from repro.sharding.rules import resolve_walker_axis, walker_batch_specs
from repro.walk_sgd import run_rw_sgd, run_rw_sgd_multi
from repro.walk_sgd.comm_model import CommModel, fleet_averaging_traffic
from repro.walk_sgd.fleet import (
    init_fleet_walk_state,
    sample_initial_nodes,
)
from repro.walk_sgd.multi_walk import init_multi_walk_state
from repro.walk_sgd.trainer import _build_engine, _setup_method

# ---------------------------------------------------------------------------
# Frozen pre-refactor oracles (verbatim from the pre-fleet trainer, except
# _oracle_scan_multi additionally scans out ``vs`` — a pure observation of
# the carry that perturbs no computed value).
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("num_steps", "use_weights", "loss_grad")
)
def _oracle_scan(
    key, x0, features, targets, weights, engine, v0,
    num_steps, gamma, p_j_sched, use_weights, loss_grad,
):
    def step(carry, inputs):
        x, v = carry
        key_t, p_j_t = inputs
        g = loss_grad(x, features[v], targets[v])
        w = jnp.where(use_weights, weights[v], 1.0)
        x_new = x - gamma * w * g
        v_next, hops = engine.step(key_t, v, p_j=p_j_t)
        mse = reg.mse_objective(x_new, features, targets)
        return (x_new, v_next), (mse, v, hops)

    keys = jax.random.split(key, num_steps)
    (x_fin, _), (mses, nodes, hops) = jax.lax.scan(
        step, (x0, jnp.asarray(v0, jnp.int32)), (keys, p_j_sched)
    )
    mse0 = reg.mse_objective(x0, features, targets)
    return x_fin, jnp.concatenate([mse0[None], mses]), nodes, hops


@functools.partial(
    jax.jit,
    static_argnames=("num_steps", "use_weights", "loss_grad", "avg_every"),
)
def _oracle_scan_multi(
    key, x0s, features, targets, weights, engine, v0s,
    num_steps, gamma, p_j_sched, use_weights, loss_grad, avg_every,
):
    grad_w = jax.vmap(loss_grad, in_axes=(0, 0, 0))

    def step(carry, inputs):
        xs, vs, t = carry
        key_t, p_j_t = inputs
        gs = grad_w(xs, features[vs], targets[vs])
        ws = jnp.where(use_weights, weights[vs], 1.0)[:, None]
        xs_new = xs - gamma * ws * gs
        if avg_every > 0:
            do_avg = (t + 1) % avg_every == 0
            xs_new = jnp.where(do_avg, xs_new.mean(axis=0)[None], xs_new)
        vs_next, hops = engine.step(key_t, vs, p_j=p_j_t)
        mses = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
            xs_new, features, targets
        )
        avg_mse = reg.mse_objective(xs_new.mean(axis=0), features, targets)
        return (xs_new, vs_next, t + 1), (mses, avg_mse, vs, hops)

    keys = jax.random.split(key, num_steps)
    (xs_fin, _, _), (mses, avg_mses, nodes, hops) = jax.lax.scan(
        step, (x0s, v0s, jnp.int32(0)), (keys, p_j_sched)
    )
    mse0 = jax.vmap(reg.mse_objective, in_axes=(0, None, None))(
        x0s, features, targets
    )
    avg0 = reg.mse_objective(x0s.mean(axis=0), features, targets)
    return (
        xs_fin,
        jnp.concatenate([mse0[None], mses]).T,
        jnp.concatenate([avg0[None], avg_mses]),
        nodes.T,
        hops.T,
    )


def _oracle_single(method, graph, data, gamma, num_steps, *, v0, seed, mhlj):
    row_probs, weights, p_j_sched, p_d, r, use_w = _setup_method(
        method, graph, data, mhlj, None, num_steps
    )
    engine = _build_engine(graph, p_d, r, row_probs, None, "scan")
    x0 = jnp.zeros(data.dim, jnp.float32)
    return _oracle_scan(
        jax.random.PRNGKey(seed), x0,
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights, engine, v0, num_steps, gamma, p_j_sched, use_w,
        reg.linear_grad,
    )


def _oracle_multi(
    method, graph, data, gamma, num_steps, num_walks,
    *, v0s, seed, avg_every, mhlj,
):
    row_probs, weights, p_j_sched, p_d, r, use_w = _setup_method(
        method, graph, data, mhlj, None, num_steps
    )
    engine = _build_engine(graph, p_d, r, row_probs, None, "auto")
    x0s = jnp.zeros((num_walks, data.dim), jnp.float32)
    return _oracle_scan_multi(
        jax.random.PRNGKey(seed), x0s,
        jnp.asarray(data.features, jnp.float32),
        jnp.asarray(data.targets, jnp.float32),
        weights, engine, jnp.asarray(v0s, jnp.int32),
        num_steps, gamma, p_j_sched, use_w, reg.linear_grad, avg_every,
    )


MHLJ = MHLJParams(0.2, 0.5, 3)


@pytest.fixture(scope="module")
def ring_case():
    g = ring(32)
    return g, make_heterogeneous_regression(g.n, seed=0)


# ---------------------------------------------------------------------------
# Bitwise parity with the pre-refactor loops
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["uniform", "mhlj"])
def test_single_walk_matches_prerefactor_oracle(ring_case, method):
    """run_rw_sgd is now the W=1 fleet — results must not move a bit."""
    g, data = ring_case
    mhlj = MHLJ if method == "mhlj" else None
    res = run_rw_sgd(
        method, g, data, 1e-3, 250, mhlj_params=mhlj, v0=3, seed=7
    )
    x_fin, mses, nodes, hops = _oracle_single(
        method, g, data, 1e-3, 250, v0=3, seed=7, mhlj=mhlj
    )
    np.testing.assert_array_equal(res.mse, np.asarray(mses))
    np.testing.assert_array_equal(res.update_nodes, np.asarray(nodes))
    np.testing.assert_array_equal(res.transitions, np.asarray(hops))
    np.testing.assert_array_equal(res.x_final, np.asarray(x_fin))


@pytest.mark.parametrize("avg_every", [0, 3])
def test_multi_walk_matches_prerefactor_oracle(ring_case, avg_every):
    g, data = ring_case
    v0s = sample_initial_nodes(g.n, 5, seed=11)
    res = run_rw_sgd_multi(
        "mhlj", g, data, 1e-3, 250, 5,
        mhlj_params=MHLJ, seed=11, avg_every=avg_every,
    )
    xs, mses, avg, nodes, hops = _oracle_multi(
        "mhlj", g, data, 1e-3, 250, 5,
        v0s=v0s, seed=11, avg_every=avg_every, mhlj=MHLJ,
    )
    np.testing.assert_array_equal(res.mse, np.asarray(mses))
    np.testing.assert_array_equal(res.avg_mse, np.asarray(avg))
    np.testing.assert_array_equal(res.update_nodes, np.asarray(nodes))
    np.testing.assert_array_equal(res.transitions, np.asarray(hops))
    np.testing.assert_array_equal(res.x_final, np.asarray(xs))


def test_sharded_one_device_matches_oracle_bitwise(ring_case):
    """The fleet loop under jax.sharding on a 1-device mesh: every field of
    MultiRWSGDResult bitwise-identical to the pre-refactor oracle."""
    g, data = ring_case
    mesh = make_walker_mesh(1)
    v0s = sample_initial_nodes(g.n, 4, seed=5)
    res = run_rw_sgd_multi(
        "mhlj", g, data, 1e-3, 250, 4,
        mhlj_params=MHLJ, seed=5, avg_every=4, mesh=mesh,
    )
    xs, mses, avg, nodes, hops = _oracle_multi(
        "mhlj", g, data, 1e-3, 250, 4,
        v0s=v0s, seed=5, avg_every=4, mhlj=MHLJ,
    )
    np.testing.assert_array_equal(res.mse, np.asarray(mses))
    np.testing.assert_array_equal(res.avg_mse, np.asarray(avg))
    np.testing.assert_array_equal(res.update_nodes, np.asarray(nodes))
    np.testing.assert_array_equal(res.transitions, np.asarray(hops))
    np.testing.assert_array_equal(res.x_final, np.asarray(xs))


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device (CI leg sets "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
def test_sharded_multi_device_fleet(ring_case):
    """W walkers sharded across the real device fleet: the walk stream
    (nodes, hops — pure PRNG functions) stays bitwise-identical to the
    unsharded run; float traces may differ only by all-reduce
    re-association of the periodic average."""
    g, data = ring_case
    n_dev = len(jax.devices())
    w = 2 * n_dev
    mesh = make_walker_mesh()
    kw = dict(mhlj_params=MHLJ, seed=5, avg_every=4)
    plain = run_rw_sgd_multi("mhlj", g, data, 1e-3, 200, w, **kw)
    shard = run_rw_sgd_multi("mhlj", g, data, 1e-3, 200, w, mesh=mesh, **kw)
    np.testing.assert_array_equal(plain.update_nodes, shard.update_nodes)
    np.testing.assert_array_equal(plain.transitions, shard.transitions)
    np.testing.assert_allclose(plain.mse, shard.mse, rtol=1e-5)
    np.testing.assert_allclose(plain.avg_mse, shard.avg_mse, rtol=1e-5)
    np.testing.assert_allclose(
        plain.x_final, shard.x_final, rtol=1e-4, atol=1e-6
    )
    # non-divisible fleets degrade to replication, not an error
    odd = run_rw_sgd_multi(
        "mhlj", g, data, 1e-3, 50, n_dev + 1, mesh=mesh,
        mhlj_params=MHLJ, seed=5,
    )
    assert np.isfinite(odd.avg_mse).all()


def test_shard_aware_engine_step_is_value_preserving(ring_case):
    g, _ = ring_case
    mesh = make_walker_mesh(1)
    engine = WalkEngine.from_graph(
        g, MHLJ, lipschitz=np.ones(g.n, np.float32), backend="scan"
    )
    sharded = engine.with_walker_sharding(resolve_walker_axis(8, mesh))
    key = jax.random.PRNGKey(0)
    nodes = jnp.arange(8, dtype=jnp.int32) % g.n
    a = engine.step(key, nodes, p_j=0.2)
    b = sharded.step(key, nodes, p_j=0.2)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# ---------------------------------------------------------------------------
# Shared v0 seeding (the former duplication between run_rw_sgd_multi and
# init_multi_walk_state)
# ---------------------------------------------------------------------------


def test_initial_node_seeding_is_shared():
    n, w, seed = 40, 6, 13
    expect = np.random.default_rng(seed).choice(n, size=w, replace=False)
    got = sample_initial_nodes(n, w, seed=seed)
    np.testing.assert_array_equal(got, expect.astype(np.int32))
    # the LLM path samples the identical fleet for the same seed
    walk_w = init_fleet_walk_state(n, w, seed=seed)
    np.testing.assert_array_equal(np.asarray(walk_w["node"]), got)
    legacy = init_multi_walk_state(n, w, seed=seed)
    np.testing.assert_array_equal(np.asarray(legacy["node"]), got)
    # and the regression fleet starts its walks there too
    g = ring(n)
    data = make_heterogeneous_regression(n, seed=0)
    res = run_rw_sgd_multi(
        "mhlj", g, data, 1e-3, 1, w, mhlj_params=MHLJ, seed=seed
    )
    np.testing.assert_array_equal(res.update_nodes[:, 0], got)
    # oversubscribed fleets sample with replacement instead of crashing
    assert sample_initial_nodes(4, 9, seed=0).shape == (9,)


def test_initial_node_validation():
    with pytest.raises(ValueError, match="shape"):
        sample_initial_nodes(10, 3, v0s=[1, 2])
    with pytest.raises(ValueError, match="node ids"):
        sample_initial_nodes(10, 2, v0s=[0, 10])
    with pytest.raises(ValueError, match="node ids"):
        init_fleet_walk_state(10, 2, v0s=[-1, 3])


# ---------------------------------------------------------------------------
# Walker-axis spec resolution
# ---------------------------------------------------------------------------


def test_walker_axis_resolution_and_fallback():
    mesh = make_walker_mesh(1)
    s = resolve_walker_axis(8, mesh)
    assert s is not None and s.spec == jax.sharding.PartitionSpec("data")
    specs = walker_batch_specs(
        {"x": jnp.zeros((8, 3)), "graph": jnp.zeros((5,))}, 8, mesh
    )
    assert specs["x"] == jax.sharding.PartitionSpec("data", None)
    assert specs["graph"] == jax.sharding.PartitionSpec()  # not walker-stacked


# ---------------------------------------------------------------------------
# Averaging-traffic model (satellite: comm_model fleet extension)
# ---------------------------------------------------------------------------


def test_fleet_averaging_traffic():
    mb = 4_000_000
    # single device: the average is local, zero wire bytes
    t1 = fleet_averaging_traffic(8, 1000, 10, mb, mesh_devices=1)
    assert t1["num_collectives"] == 100
    assert t1["total_wire_bytes"] == 0.0
    # ring all-reduce over D devices: 2*(D-1)*model_bytes per collective
    t8 = fleet_averaging_traffic(8, 1000, 10, mb, mesh_devices=8)
    assert t8["participating_devices"] == 8
    assert t8["bytes_per_collective"] == pytest.approx(2 * 7 * mb)
    assert t8["total_wire_bytes"] == pytest.approx(100 * 2 * 7 * mb)
    # payload is W-independent once W >= D (local partial means are free)
    t64 = fleet_averaging_traffic(64, 1000, 10, mb, mesh_devices=8)
    assert t64["bytes_per_collective"] == t8["bytes_per_collective"]
    # ... but W < D shrinks the participant set to the walker count
    t2 = fleet_averaging_traffic(2, 1000, 10, mb, mesh_devices=8)
    assert t2["participating_devices"] == 2
    assert t2["bytes_per_collective"] == pytest.approx(2 * 1 * mb)
    # linear in model size; avg_every<=0 means no collectives at all
    assert (
        fleet_averaging_traffic(8, 1000, 10, 2 * mb, mesh_devices=8)[
            "total_wire_bytes"
        ]
        == 2 * t8["total_wire_bytes"]
    )
    assert (
        fleet_averaging_traffic(8, 1000, 0, mb, mesh_devices=8)[
            "num_collectives"
        ]
        == 0
    )
    # wall-clock estimate appears with a CommModel attached
    priced = fleet_averaging_traffic(
        8, 1000, 10, mb, mesh_devices=8, comm=CommModel(model_bytes=mb)
    )
    assert priced["wire_seconds_total"] > 0
    with pytest.raises(ValueError):
        fleet_averaging_traffic(0, 100, 10, mb)


def test_multi_result_exposes_update_nodes(ring_case):
    """The fleet scan surfaces per-step nodes for W>1 (new in the fleet
    refactor; the single-walk path always had them)."""
    g, data = ring_case
    res = run_rw_sgd_multi(
        "mhlj", g, data, 1e-3, 50, 3, mhlj_params=MHLJ, seed=2
    )
    assert res.update_nodes.shape == (3, 50)
    assert res.update_nodes.dtype == np.int32
    assert (res.update_nodes >= 0).all() and (res.update_nodes < g.n).all()


@pytest.mark.parametrize("ba_graph", [True, False])
def test_fleet_rides_every_layout(ring_case, ba_graph):
    """Fleet + ragged layout parity: same seeds, same trajectories across
    engine layouts (the property test_rw_sgd pins for W=1, here for W>1)."""
    if ba_graph:
        g = barabasi_albert(48, 3, seed=2)
    else:
        g, _ = ring_case
    data = make_heterogeneous_regression(g.n, seed=0)
    base = run_rw_sgd_multi(
        "mhlj", g, data, 1e-3, 120, 4, mhlj_params=MHLJ, seed=3, avg_every=5
    )
    ragged = run_rw_sgd_multi(
        "mhlj", g.to_csr().to_ragged(), data, 1e-3, 120, 4,
        mhlj_params=MHLJ, seed=3, avg_every=5,
    )
    np.testing.assert_array_equal(base.update_nodes, ragged.update_nodes)
    np.testing.assert_array_equal(base.mse, ragged.mse)
