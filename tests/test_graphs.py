"""Graph substrate tests incl. hypothesis property checks.

Deterministic tests always run; the property-based ones skip individually
when hypothesis (a dev-only dependency, requirements-dev.txt) is absent —
not the whole module, so the CSR round-trip and loud-validation coverage
stays in tier 1 regardless.  Each hypothesis test also keeps one pinned
parameter draw that runs without hypothesis.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dependency (requirements-dev.txt)
    given = settings = st = None

from repro.core import graphs


@pytest.mark.parametrize(
    "builder,args",
    [
        (graphs.ring, (11,)),
        (graphs.grid2d, (4, 5)),
        (graphs.watts_strogatz, (24, 4, 0.1)),
        (graphs.erdos_renyi, (20, 0.3)),
        (graphs.star, (9,)),
        (graphs.complete, (7,)),
        (graphs.expander, (16, 4)),
        (graphs.barabasi_albert, (30, 3)),
        (graphs.sbm, ([10, 12, 8], 0.5, 0.05)),
        (graphs.dumbbell, (6, 3)),
        (graphs.lollipop, (6, 4)),
    ],
)
def test_builders_valid(builder, args):
    g = builder(*args)
    g.validate()  # symmetric, self-loops, connected, degrees consistent


def test_ring_structure():
    g = graphs.ring(8)
    assert g.degrees.tolist() == [3] * 8  # two neighbors + self-loop
    assert g.adj[0, 1] == 1 and g.adj[0, 7] == 1 and g.adj[0, 2] == 0


def test_neighbor_padding_is_self():
    g = graphs.star(6)
    # leaves have degree 2 (hub + self); padding must repeat the node id
    for v in range(1, 6):
        row = g.neighbors[v]
        deg = g.degrees[v]
        assert set(row[:deg].tolist()) == {0, v}
        assert all(x == v for x in row[deg:])


# ---------------------------------------------------------------------------
# Property checks (plain callables) — exercised by hypothesis when it is
# installed, and by one pinned draw each when it is not.
# ---------------------------------------------------------------------------


def _assert_csr_round_trip(dense_graph, csr_graph):
    """family(csr) == family(dense).to_csr() == family(csr).to_dense() cycle."""
    via_dense = dense_graph.to_csr()
    np.testing.assert_array_equal(csr_graph.indptr, via_dense.indptr)
    np.testing.assert_array_equal(csr_graph.indices, via_dense.indices)
    np.testing.assert_array_equal(csr_graph.degrees, via_dense.degrees)
    np.testing.assert_array_equal(csr_graph.neighbors, via_dense.neighbors)
    np.testing.assert_array_equal(csr_graph.to_dense().adj, dense_graph.adj)


def _check_er(n, seed):
    g = graphs.erdos_renyi(n, 0.4, seed=seed)
    g.validate()
    assert g.n == n
    assert g.max_degree <= n
    c = g.to_csr()
    c.validate()
    np.testing.assert_array_equal(c.to_dense().adj, g.adj)


def _check_grid(rows, cols):
    g = graphs.grid2d(rows, cols)
    assert g.n == rows * cols
    assert int(g.degrees.max()) <= 5  # 4 grid neighbors + self
    _assert_csr_round_trip(g, graphs.grid2d(rows, cols, layout="csr"))


def _check_ba(n, m, seed):
    m = min(m, n - 1)
    g = graphs.barabasi_albert(n, m, seed=seed)
    g.validate()  # connected, symmetric, self-loops
    assert g.n == n
    # every node beyond the seed core attaches to >= 1 target (+ self-loop)
    assert int(g.degrees.min()) >= 2
    assert g.max_degree <= n
    c = graphs.barabasi_albert(n, m, seed=seed, layout="csr")
    c.validate()
    _assert_csr_round_trip(g, c)


def _check_sbm(sizes, seed):
    g = graphs.sbm(sizes, 0.7, 0.15, seed=seed)
    g.validate()
    assert g.n == sum(sizes)
    assert g.max_degree <= g.n
    c = graphs.sbm(sizes, 0.7, 0.15, seed=seed, layout="csr")
    c.validate()
    _assert_csr_round_trip(g, c)


def _check_dumbbell(k, p):
    g = graphs.dumbbell(k, p)
    g.validate()
    assert g.n == 2 * k + p
    # bridge clique nodes: (k-1) clique edges + self + 1 bridge edge
    assert g.max_degree == k + 1
    _assert_csr_round_trip(g, graphs.dumbbell(k, p, layout="csr"))


def _check_lollipop(k, p):
    g = graphs.lollipop(k, p)
    g.validate()
    assert g.n == k + p
    assert g.max_degree == k + 1
    # the path tip has degree 2 (one path edge + self)
    assert int(g.degrees[-1]) == 2
    _assert_csr_round_trip(g, graphs.lollipop(k, p, layout="csr"))


@pytest.mark.parametrize(
    "check,args",
    [
        (_check_er, (20, 3)),
        (_check_grid, (4, 6)),
        (_check_ba, (24, 3, 2)),
        (_check_sbm, ([8, 10, 6], 4)),
        (_check_dumbbell, (6, 0)),
        (_check_dumbbell, (7, 3)),
        (_check_lollipop, (8, 5)),
    ],
)
def test_family_properties_pinned(check, args):
    """One pinned draw per family — runs with or without hypothesis."""
    check(*args)


if st is not None:

    @given(n=st.integers(4, 40), seed=st.integers(0, 5))
    @settings(max_examples=20, deadline=None)
    def test_er_graph_properties(n, seed):
        _check_er(n, seed)

    @given(rows=st.integers(2, 6), cols=st.integers(2, 6))
    @settings(max_examples=15, deadline=None)
    def test_grid_node_count_and_degree_bounds(rows, cols):
        _check_grid(rows, cols)

    @given(n=st.integers(5, 40), m=st.integers(1, 4), seed=st.integers(0, 5))
    @settings(max_examples=15, deadline=None)
    def test_ba_properties_and_round_trip(n, m, seed):
        _check_ba(n, m, seed)

    @given(
        sizes=st.lists(st.integers(4, 12), min_size=2, max_size=4),
        seed=st.integers(0, 5),
    )
    @settings(max_examples=10, deadline=None)
    def test_sbm_properties_and_round_trip(sizes, seed):
        _check_sbm(sizes, seed)

    @given(k=st.integers(3, 9), p=st.integers(0, 8))
    @settings(max_examples=15, deadline=None)
    def test_dumbbell_properties_and_round_trip(k, p):
        _check_dumbbell(k, p)

    @given(k=st.integers(3, 9), p=st.integers(1, 8))
    @settings(max_examples=15, deadline=None)
    def test_lollipop_properties_and_round_trip(k, p):
        _check_lollipop(k, p)

else:

    @pytest.mark.skip(
        reason="hypothesis not installed (requirements-dev.txt): the 6 "
        "property-based family tests are skipped; pinned draws still ran"
    )
    def test_hypothesis_property_suite():
        """Visible placeholder so a missing hypothesis install shows up as a
        skip in CI output instead of tests silently vanishing from
        collection."""


# ---------------------------------------------------------------------------
# Degree-bucketed layout: round-trip + truncation contract
# ---------------------------------------------------------------------------


def _assert_bucketed_round_trip(csr):
    """to_bucketed() partitions correctly, truncates bitwise, and to_csr()
    is an exact inverse."""
    bg = csr.to_bucketed()
    bg.validate()
    back = bg.to_csr()
    np.testing.assert_array_equal(back.indptr, csr.indptr)
    np.testing.assert_array_equal(back.indices, csr.indices)
    np.testing.assert_array_equal(back.degrees, csr.degrees)
    np.testing.assert_array_equal(back.neighbors, csr.neighbors)
    deg = csr.degrees.astype(np.int64)
    for b_id, b in enumerate(bg.buckets):
        # every bucket row is the column-truncation of the padded row
        np.testing.assert_array_equal(
            b.neighbors, csr.neighbors[b.node_ids][:, : b.width]
        )
        assert (deg[b.node_ids] <= b.width).all()
        if b_id > 0:  # minimality: nothing fits a smaller bucket
            assert (deg[b.node_ids] > bg.buckets[b_id - 1].width).all()
    return bg


@pytest.mark.parametrize(
    "build",
    [
        lambda: graphs.barabasi_albert(120, 3, seed=2, layout="csr"),
        lambda: graphs.lollipop(24, 9, layout="csr"),
        lambda: graphs.ring(32, layout="csr"),
        lambda: graphs.sbm([20, 25, 15], 0.4, 0.05, seed=5, layout="csr"),
        lambda: graphs.dumbbell(9, 4, layout="csr"),
    ],
)
def test_bucketed_round_trip_families(build):
    _assert_bucketed_round_trip(build())


def test_bucketed_bucket_boundary_degrees():
    """Hub degrees exactly at / one off a power-of-two bucket boundary land
    in the right bucket, and the top width clamps to max_degree."""
    for leaves in (7, 8, 9, 15, 16, 17):
        idx = np.arange(1, leaves + 1, dtype=np.int64)
        csr = graphs.from_edges(
            leaves + 1, np.zeros(leaves, np.int64), idx,
            name=f"star({leaves + 1})", layout="csr",
        )
        bg = _assert_bucketed_round_trip(csr)
        hub_deg = leaves + 1  # incl. self-loop
        hub_bucket = bg.buckets[int(bg.node_bucket[0])]
        assert hub_deg <= hub_bucket.width <= max(8, hub_deg)
        assert bg.bucket_widths[-1] <= csr.max_degree  # clamped, no waste


def test_from_edges_bucketed_layout():
    bg = graphs.barabasi_albert(60, 2, seed=1, layout="bucketed")
    ref = graphs.barabasi_albert(60, 2, seed=1, layout="csr").to_bucketed()
    assert isinstance(bg, graphs.BucketedCSRGraph)
    np.testing.assert_array_equal(bg.node_bucket, ref.node_bucket)
    np.testing.assert_array_equal(bg.node_slot, ref.node_slot)
    assert bg.bucket_widths == ref.bucket_widths
    assert bg.to_bucketed() is bg  # identity normalization


@pytest.mark.parametrize(
    "build",
    [
        lambda: graphs.barabasi_albert(120, 3, seed=2, layout="csr"),
        lambda: graphs.lollipop(24, 9, layout="csr"),
        lambda: graphs.sbm([20, 25, 15], 0.4, 0.05, seed=5, layout="csr"),
    ],
)
def test_ragged_round_trip_families(build):
    """to_ragged() keeps the identical CSR core and to_csr() reconstructs
    the padded tensor exactly; every sparse class normalizes to the same
    core via to_ragged()."""
    csr = build()
    rg = csr.to_ragged()
    rg.validate()
    assert isinstance(rg, graphs.RaggedCSRGraph)
    assert not hasattr(rg, "neighbors")  # the point: no padded tensor
    np.testing.assert_array_equal(rg.indptr, csr.indptr)
    np.testing.assert_array_equal(rg.indices, csr.indices)
    np.testing.assert_array_equal(rg.degrees, csr.degrees)
    assert rg.to_ragged() is rg
    back = rg.to_csr()
    np.testing.assert_array_equal(back.neighbors, csr.neighbors)
    np.testing.assert_array_equal(
        csr.to_bucketed().to_ragged().indices, rg.indices
    )
    # bucketing straight off the core matches bucketing the padded class
    assert rg.to_bucketed().bucket_widths == csr.to_bucketed().bucket_widths


def test_from_edges_ragged_layout():
    """from_edges(layout='ragged') returns the bare validated core — same
    arrays as the csr layout, no padded tensor ever built — and
    flat_edge_values flattens padded tables into exact CSR edge order."""
    rg = graphs.barabasi_albert(60, 2, seed=1, layout="ragged")
    ref = graphs.barabasi_albert(60, 2, seed=1, layout="csr")
    assert isinstance(rg, graphs.RaggedCSRGraph)
    np.testing.assert_array_equal(rg.indptr, ref.indptr)
    np.testing.assert_array_equal(rg.indices, ref.indices)
    flat = graphs.flat_edge_values(
        ref.indptr, ref.degrees, ref.neighbors
    )
    np.testing.assert_array_equal(flat, ref.indices)  # pads dropped exactly
    with pytest.raises(ValueError, match="table shape"):
        graphs.flat_edge_values(
            ref.indptr, ref.degrees, ref.neighbors[:, :-1]
        )


@pytest.mark.parametrize("bucket_factor", [2, 4])
def test_bucket_factor_ladder(bucket_factor):
    """The width ladder is geometric in bucket_factor (clamped to
    max_degree), every member degree fits its bucket minimally, and the
    bounded-memory from_edges path agrees exactly with to_bucketed()."""
    csr = graphs.barabasi_albert(150, 3, seed=4, layout="csr")
    bg = csr.to_bucketed(bucket_factor=bucket_factor)
    bg.validate()
    assert bg.bucket_factor == bucket_factor
    widths = bg.bucket_widths
    # geometric ladder: every rung but the (clamped) top is min_width·f^k
    for w in widths[:-1]:
        k = 0
        while 8 * bucket_factor**k < w:
            k += 1
        assert w == 8 * bucket_factor**k
    assert widths[-1] <= csr.max_degree
    deg = csr.degrees.astype(np.int64)
    for b_id, b in enumerate(bg.buckets):
        assert (deg[b.node_ids] <= b.width).all()
        if b_id > 0:
            assert (deg[b.node_ids] > bg.buckets[b_id - 1].width).all()
        np.testing.assert_array_equal(
            b.neighbors, csr.neighbors[b.node_ids][:, : b.width]
        )
    # bounded-memory construction (never builds the padded table) matches
    direct = graphs.barabasi_albert(
        150, 3, seed=4, layout="bucketed", bucket_factor=bucket_factor
    )
    assert direct.bucket_widths == widths
    np.testing.assert_array_equal(direct.node_bucket, bg.node_bucket)
    np.testing.assert_array_equal(direct.node_slot, bg.node_slot)
    for a, b in zip(direct.buckets, bg.buckets):
        np.testing.assert_array_equal(a.neighbors, b.neighbors)
    # round-trip back to CSR is exact regardless of the ladder
    np.testing.assert_array_equal(bg.to_csr().neighbors, csr.neighbors)


def test_to_bucketed_rebuckets_on_factor_mismatch():
    """to_bucketed() is the identity at the stored ladder and a bounded-
    memory re-bucket when a different ladder is requested."""
    bg = graphs.barabasi_albert(100, 3, seed=0, layout="bucketed")
    assert bg.to_bucketed() is bg
    coarse = bg.to_bucketed(bucket_factor=4)
    assert coarse is not bg
    coarse.validate()
    assert coarse.bucket_factor == 4
    assert len(coarse.buckets) <= len(bg.buckets)
    np.testing.assert_array_equal(coarse.indices, bg.indices)


def test_bucketed_validate_catches_corruption():
    bg = graphs.barabasi_albert(40, 3, seed=0, layout="bucketed")
    import dataclasses as dc

    # corrupt one bucket's neighbor row: must fail the truncation contract
    bad_buckets = list(bg.buckets)
    nbrs = bad_buckets[0].neighbors.copy()
    nbrs[0, 0] = (nbrs[0, 0] + 1) % bg.n
    bad_buckets[0] = dc.replace(bad_buckets[0], neighbors=nbrs)
    bad = dc.replace(bg, buckets=tuple(bad_buckets))
    with pytest.raises(ValueError, match="bucket neighbor rows"):
        bad.validate()
    # a node assigned to a too-large bucket must fail minimality
    if len(bg.buckets) > 1:
        nb = bg.node_bucket.copy()
        small = bg.buckets[0].node_ids[0]
        nb[small] = 1
        bad2 = dc.replace(bg, node_bucket=nb)
        with pytest.raises(ValueError):
            bad2.validate()


# ---------------------------------------------------------------------------
# Loud validation on construction
# ---------------------------------------------------------------------------


def test_from_edges_disconnected_fails_loudly():
    for layout in ("dense", "csr"):
        with pytest.raises(ValueError, match="connected"):
            graphs.from_edges(6, [0, 2], [1, 3], layout=layout)


def test_from_edges_out_of_range_fails_loudly():
    with pytest.raises(ValueError, match="out of range"):
        graphs.from_edges(4, [0, 1], [1, 7])


def test_csr_validate_catches_asymmetry():
    c = graphs.ring(8, layout="csr")
    # drop one direction of edge (0, 1): asymmetric edge set must be loud
    keep = ~((np.repeat(np.arange(8), np.diff(c.indptr)) == 0) & (c.indices == 1))
    indices = c.indices[keep]
    degrees = np.diff(c.indptr).copy()
    degrees[0] -= 1
    indptr = np.zeros(9, np.int64)
    np.cumsum(degrees, out=indptr[1:])
    bad = graphs.CSRGraph(
        indptr=indptr,
        indices=indices,
        degrees=degrees.astype(np.int32),
        neighbors=graphs._pad_neighbor_lists(
            indptr, indices, degrees.astype(np.int32)
        ),
        name="bad",
    )
    with pytest.raises(ValueError, match="symmetric"):
        bad.validate()


def test_random_generators_validate_on_construction(monkeypatch):
    """Regression for the 'generators never validate' gap: if validation is
    broken (simulated via a failing Graph.validate), every random generator
    must fail loudly rather than return a silently-invalid graph."""

    def boom(self):
        raise ValueError("validate() was reached")

    monkeypatch.setattr(graphs.Graph, "validate", boom)
    for build in (
        lambda: graphs.erdos_renyi(12, 0.5),
        lambda: graphs.watts_strogatz(12, 2, 0.2),
        lambda: graphs.expander(12, 4),
        lambda: graphs.barabasi_albert(12, 2),
    ):
        with pytest.raises(ValueError, match="validate"):
            build()


def test_watts_strogatz_retries_disconnected_rewirings(monkeypatch):
    """The WS retry loop must run BEFORE the validating constructor, so an
    unlucky rewiring resamples instead of raising."""
    real = graphs._is_connected
    calls = {"n": 0}

    def flaky(adj):
        calls["n"] += 1
        if calls["n"] == 1:  # pretend the first draw came out disconnected
            return False
        return real(adj)

    monkeypatch.setattr(graphs, "_is_connected", flaky)
    g = graphs.watts_strogatz(20, 4, 0.3, seed=0)
    g.validate()
    assert calls["n"] >= 2  # retried with seed+1 instead of raising


# ---------------------------------------------------------------------------
# jax.random sampler ports (core.jax_sampling) — pinned seeds + family parity
# ---------------------------------------------------------------------------


def _jax_sampling():
    import jax

    from repro.core import jax_sampling as js

    return jax, js


def test_ba_jax_pinned_seed_regression():
    """Exact pinned draw at PRNGKey(0): the BA port is deterministic per
    key, and jit compiles to the bitwise-identical sample (asserting
    jit-compatibility, not just closeness)."""
    jax, js = _jax_sampling()
    key = jax.random.PRNGKey(0)
    src, dst = js.barabasi_albert_edges(200, 3, key)
    assert src[:8].tolist() == [3, 3, 3, 4, 4, 4, 5, 5]
    assert dst[:8].tolist() == [0, 1, 2, 3, 3, 2, 3, 2]
    jitted = jax.jit(js.barabasi_albert_edges, static_argnums=(0, 1))
    src_j, dst_j = jitted(200, 3, key)
    np.testing.assert_array_equal(np.asarray(src), np.asarray(src_j))
    np.testing.assert_array_equal(np.asarray(dst), np.asarray(dst_j))
    g = js.barabasi_albert_jax(200, 3, key)
    g.validate()
    assert int(np.asarray(g.degrees).sum()) == 1334


def test_sbm_jax_pinned_seed_regression():
    """Exact pinned draw at PRNGKey(0) for the SBM port: mask count, edge
    count and a degree entry, plus bitwise jit==eager on the mask core."""
    jax, js = _jax_sampling()
    key = jax.random.PRNGKey(0)
    sizes = (30, 30, 30)
    mask = js.sbm_pair_mask(sizes, 0.3, 0.02, key)
    assert int(np.asarray(mask).sum()) == 422
    jitted = jax.jit(js.sbm_pair_mask, static_argnums=(0,))
    np.testing.assert_array_equal(
        np.asarray(mask), np.asarray(jitted(sizes, 0.3, 0.02, key))
    )
    g = js.sbm_jax(list(sizes), 0.3, 0.02, key)
    g.validate()
    assert g.n == 90
    assert int(np.asarray(g.degrees).sum()) == 934
    assert int(np.asarray(g.degrees)[0]) == 9


def _check_ba_jax_family(n, m, key_seed):
    """Family-property parity with graphs.barabasi_albert: attachment
    count, min degree, hub growth — stream-level equality is NOT the
    contract (different RNGs by design)."""
    jax, js = _jax_sampling()
    m = min(m, n - 1)
    src, dst = js.barabasi_albert_edges(n, m, jax.random.PRNGKey(key_seed))
    assert src.shape == (m * (n - m),)
    assert bool((np.asarray(dst) < np.asarray(src)).all())
    g = js.barabasi_albert_jax(n, m, jax.random.PRNGKey(key_seed))
    g.validate()  # connected, symmetric, self-loops — like the numpy family
    assert g.n == n
    assert int(np.asarray(g.degrees).min()) >= 2
    ref = graphs.barabasi_albert(n, m, seed=key_seed, layout="csr")
    # same family envelope as the numpy sampler: dedupe can only shrink
    # the m(n-m) attachments, never past the spanning minimum
    for got in (g, ref):
        und = (int(np.asarray(got.degrees).sum()) - got.n) // 2
        assert n - m <= und <= m * (n - m)


def _check_sbm_jax_family(sizes, key_seed):
    jax, js = _jax_sampling()
    g = js.sbm_jax(sizes, 0.7, 0.15, jax.random.PRNGKey(key_seed))
    g.validate()
    assert g.n == sum(sizes)
    # block structure: in-block degree dominates cross-block on average
    blocks = np.repeat(np.arange(len(sizes)), sizes)
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    src = np.repeat(np.arange(g.n), np.diff(indptr))
    nonloop = src != indices
    same = blocks[src[nonloop]] == blocks[indices[nonloop]]
    in_pairs = sum(s * (s - 1) // 2 for s in sizes)
    out_pairs = g.n * (g.n - 1) // 2 - in_pairs
    in_density = same.sum() / 2 / in_pairs
    out_density = (~same).sum() / 2 / out_pairs
    assert in_density > 2 * out_density


@pytest.mark.parametrize(
    "check,args",
    [
        (_check_ba_jax_family, (24, 3, 2)),
        (_check_ba_jax_family, (60, 1, 0)),
        (_check_sbm_jax_family, ([8, 10, 6], 4)),
    ],
)
def test_jax_sampler_family_pinned(check, args):
    """One pinned draw per ported family — runs with or without
    hypothesis."""
    check(*args)


if st is not None:

    @given(n=st.integers(5, 40), m=st.integers(1, 4), seed=st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_ba_jax_family_properties(n, m, seed):
        _check_ba_jax_family(n, m, seed)

    @given(
        sizes=st.lists(st.integers(5, 12), min_size=2, max_size=3),
        seed=st.integers(0, 5),
    )
    @settings(max_examples=6, deadline=None)
    def test_sbm_jax_family_properties(sizes, seed):
        _check_sbm_jax_family(sizes, seed)
