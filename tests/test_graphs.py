"""Graph substrate tests incl. hypothesis property checks."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import graphs


@pytest.mark.parametrize(
    "builder,args",
    [
        (graphs.ring, (11,)),
        (graphs.grid2d, (4, 5)),
        (graphs.watts_strogatz, (24, 4, 0.1)),
        (graphs.erdos_renyi, (20, 0.3)),
        (graphs.star, (9,)),
        (graphs.complete, (7,)),
        (graphs.expander, (16, 4)),
    ],
)
def test_builders_valid(builder, args):
    g = builder(*args)
    g.validate()  # symmetric, self-loops, connected, degrees consistent


def test_ring_structure():
    g = graphs.ring(8)
    assert g.degrees.tolist() == [3] * 8  # two neighbors + self-loop
    assert g.adj[0, 1] == 1 and g.adj[0, 7] == 1 and g.adj[0, 2] == 0


def test_neighbor_padding_is_self():
    g = graphs.star(6)
    # leaves have degree 2 (hub + self); padding must repeat the node id
    for v in range(1, 6):
        row = g.neighbors[v]
        deg = g.degrees[v]
        assert set(row[:deg].tolist()) == {0, v}
        assert all(x == v for x in row[deg:])


@given(n=st.integers(4, 40), seed=st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_er_graph_properties(n, seed):
    g = graphs.erdos_renyi(n, 0.4, seed=seed)
    g.validate()
    assert g.n == n
    assert g.max_degree <= n


@given(rows=st.integers(2, 6), cols=st.integers(2, 6))
@settings(max_examples=15, deadline=None)
def test_grid_node_count_and_degree_bounds(rows, cols):
    g = graphs.grid2d(rows, cols)
    assert g.n == rows * cols
    assert int(g.degrees.max()) <= 5  # 4 grid neighbors + self
