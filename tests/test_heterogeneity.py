"""The heterogeneity-aware law's measurement + optimization stack
(arXiv:2204.06477 adaptation), the private walk's weight perturbation
(arXiv:2009.01790), and the online-estimator fingerprint regression.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heterogeneity as het
from repro.core import private_weights
from repro.core.importance import (
    online_lipschitz_init,
    online_lipschitz_update,
    param_fingerprint,
)
from repro.data import make_heterogeneous_regression


# ---------------------------------------------------------------------------
# Dissimilarity measurement
# ---------------------------------------------------------------------------


def test_pairwise_dissimilarity_matches_bruteforce():
    rng = np.random.default_rng(0)
    grads = rng.normal(size=(3, 12, 5))
    h = het.pairwise_gradient_dissimilarity(grads)
    brute = np.zeros((12, 12))
    for g in grads:
        for u in range(12):
            for v in range(12):
                brute[u, v] += ((g[u] - g[v]) ** 2).sum()
    brute /= 3
    np.testing.assert_allclose(h, brute, atol=1e-10)
    assert np.allclose(h, h.T) and np.all(np.diag(h) == 0) and np.all(h >= 0)


def test_measure_dissimilarity_flags_heterogeneous_nodes():
    """High-variance nodes (the paper's sigma_H^2 outliers) must carry the
    largest mean dissimilarity — that is the signal the law re-weights on."""
    data = make_heterogeneous_regression(
        64, dim=8, sigma_high_sq=100.0, p_high=0.05, seed=0, force_min_high=3
    )
    h = het.measure_dissimilarity(data, num_probes=6, seed=1)
    hbar = het.mean_dissimilarity(h)
    hot = hbar[data.high_variance_mask].min()
    cold = hbar[~data.high_variance_mask].max()
    assert hot > cold


def test_measure_dissimilarity_deterministic_in_seed():
    data = make_heterogeneous_regression(16, dim=4, seed=3)
    a = het.measure_dissimilarity(data, seed=7)
    b = het.measure_dissimilarity(data, seed=7)
    c = het.measure_dissimilarity(data, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


# ---------------------------------------------------------------------------
# Simplex projection + pi optimizer
# ---------------------------------------------------------------------------


def test_project_to_simplex_properties():
    rng = np.random.default_rng(1)
    for floor in (0.0, 0.2, 0.5):
        v = rng.normal(size=20)
        p = het.project_to_simplex(v, floor=floor)
        assert abs(p.sum() - 1.0) < 1e-12
        assert p.min() >= floor / 20 - 1e-12
    # already-feasible points are fixed points
    u = np.full(10, 0.1)
    np.testing.assert_allclose(het.project_to_simplex(u, 0.3), u, atol=1e-12)


def test_optimizer_matches_closed_form_without_floor():
    """KKT oracle: argmin_pi sum h_bar/pi on the simplex is pi ∝ sqrt(h_bar).
    The projected-descent path must land on it when the floor is off."""
    rng = np.random.default_rng(2)
    h = het.pairwise_gradient_dissimilarity(rng.normal(size=(4, 24, 6)))
    oracle = het.optimal_pi_closed_form(h)
    # from the cold (uniform) start, not the oracle warm start
    pi = het.optimize_pi(h, floor=0.0, steps=600, init=np.full(24, 1 / 24))
    hbar = het.mean_dissimilarity(h)
    hbar = hbar / hbar.max()
    obj = float(np.sum(hbar / pi))
    obj_star = float(np.sum(hbar / oracle))
    assert obj <= obj_star * 1.001  # optimizer reached the optimum
    np.testing.assert_allclose(pi, oracle, atol=5e-3)


def test_optimizer_respects_floor_and_stays_stochastic():
    rng = np.random.default_rng(3)
    h = het.pairwise_gradient_dissimilarity(rng.normal(size=(2, 30, 4)))
    pi = het.optimize_pi(h, floor=0.4)
    assert abs(pi.sum() - 1.0) < 1e-9
    assert pi.min() >= 0.4 / 30 - 1e-12
    # the floor binds somewhere on a genuinely heterogeneous instance, and
    # the objective at the floored solution beats the floored oracle
    hbar = het.mean_dissimilarity(h)
    hbar = hbar / hbar.max()
    floored_oracle = het.project_to_simplex(het.optimal_pi_closed_form(h), 0.4)
    assert np.sum(hbar / pi) <= np.sum(hbar / floored_oracle) + 1e-9


def test_homogeneous_data_gives_uniform_pi():
    """H = 0 (identical nodes) must degenerate to MH-uniform's target."""
    h = np.zeros((12, 12))
    np.testing.assert_allclose(het.optimize_pi(h), np.full(12, 1 / 12))
    np.testing.assert_allclose(
        het.optimal_pi_closed_form(h), np.full(12, 1 / 12)
    )


def test_heterogeneity_pi_pipeline_upweights_outliers():
    data = make_heterogeneous_regression(
        48, dim=6, sigma_high_sq=100.0, p_high=0.04, seed=5, force_min_high=2
    )
    pi = het.heterogeneity_pi(data, floor=0.25, seed=0)
    assert abs(pi.sum() - 1.0) < 1e-9 and pi.min() > 0
    hot = pi[data.high_variance_mask].min()
    cold = pi[~data.high_variance_mask].max()
    assert hot > cold  # outlier nodes get more visit mass


# ---------------------------------------------------------------------------
# Private weight perturbation (arXiv:2009.01790)
# ---------------------------------------------------------------------------


def test_private_weights_gamma_zero_is_exact():
    w = np.array([1.0, 2.0, 3.0])
    np.testing.assert_array_equal(private_weights(w, 0.0), w)


def test_private_weights_seeded_and_additive():
    rng = np.random.default_rng(4)
    w = np.exp(rng.normal(size=32))
    a = private_weights(w, 0.7, seed=9)
    b = private_weights(w, 0.7, seed=9)
    c = private_weights(w, 0.7, seed=10)
    np.testing.assert_array_equal(a, b)  # one chain, one draw
    assert not np.array_equal(a, c)
    assert np.all(a >= w)  # Gamma noise is nonnegative — weights stay valid


def test_private_weights_aggregate_noise_bounded():
    """Infinite divisibility: sum_v G_v ~ Gamma(1, gamma n w_bar), so the
    MEAN total distortion is gamma * n * w_bar independent of how it is
    split across nodes — check the empirical mean over many draws."""
    w = np.ones(64)
    gamma = 0.5
    totals = [
        (private_weights(w, gamma, seed=s) - w).sum() for s in range(300)
    ]
    expected = gamma * 64 * 1.0
    assert abs(np.mean(totals) - expected) < 0.2 * expected


def test_private_weights_validation():
    with pytest.raises(ValueError, match="gamma"):
        private_weights(np.ones(4), -0.1)
    with pytest.raises(ValueError, match="positive"):
        private_weights(np.array([1.0, 0.0]), 0.1)


# ---------------------------------------------------------------------------
# Online-estimator fingerprint regression (satellite bugfix)
# ---------------------------------------------------------------------------


def test_fingerprint_distinguishes_equal_norm_params():
    """THE collision regression: x and -x share ||x||; the old norm
    fingerprint made dx = 0 so the secant clipped to clip_max (1e3),
    wrecking the IS weights.  The random-projection fingerprint keeps the
    secant calibrated."""
    x1 = jnp.asarray(np.array([1.0, 2.0, 3.0], np.float32))
    x2 = -x1  # same norm, maximally different params
    f1, f2 = param_fingerprint(x1), param_fingerprint(x2)
    assert float(jnp.abs(f1 - f2)) > 1e-3  # fingerprints separate

    state = online_lipschitz_init(4)
    state = online_lipschitz_update(state, 0, jnp.float32(1.0), f1)
    state = online_lipschitz_update(state, 0, jnp.float32(2.0), f2)
    est = float(state.lipschitz[0])
    # pre-fix the secant was clip_max=1e3, EMA-blended to ~100.9; post-fix
    # it is |2-1| / |f1-f2| ~ O(1)
    assert est < 50.0, f"secant blew up to {est} — fingerprint collided"


def test_fingerprint_tracks_parameter_distance():
    """E[(r.(x-x'))^2] = ||x-x'||^2 / D: across many leaf shapes the
    fingerprint gap stays on the scale of the parameter gap."""
    rng = np.random.default_rng(6)
    tree1 = {
        "a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32),
    }
    tree2 = {
        "a": tree1["a"] + 1.0,
        "b": tree1["b"] - 1.0,
    }
    gap = float(jnp.abs(param_fingerprint(tree1) - param_fingerprint(tree2)))
    assert 0.0 < gap < 10.0  # nonzero, and calibrated (not clip-scale)
    # determinism: same tree, same seed, same fingerprint
    assert float(param_fingerprint(tree1)) == float(param_fingerprint(tree1))


def test_fingerprint_seed_registered_in_state():
    state = online_lipschitz_init(3, proj_seed=11)
    assert state.proj_seed == 11
    state2 = online_lipschitz_update(
        state, 1, jnp.float32(1.0), param_fingerprint(jnp.ones(4), seed=11)
    )
    assert state2.proj_seed == 11  # survives updates (static aux data)
