"""Loop-aware HLO cost model validation (the roofline source of truth)."""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo_cost import price_module


def _price(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return price_module(txt)


def test_matmul_flops_exact():
    c = _price(lambda a, b: a @ b, jnp.zeros((128, 256)), jnp.zeros((256, 512)))
    assert c.flops == pytest.approx(2 * 128 * 256 * 512, rel=0.01)
    # bytes: at least the three arrays once
    min_bytes = 4 * (128 * 256 + 256 * 512 + 128 * 512)
    assert c.bytes >= min_bytes


def test_scan_trip_count_multiplies():
    def g(x, ws):
        def step(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(step, x, ws)
        return y

    c1 = _price(g, jnp.zeros((64, 128)), jnp.zeros((5, 128, 128)))
    c2 = _price(g, jnp.zeros((64, 128)), jnp.zeros((40, 128, 128)))
    # 8x the iterations -> ~8x the flops (elementwise noise is tiny)
    assert c2.flops / c1.flops == pytest.approx(8.0, rel=0.05)


def test_nested_scan():
    def g(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    c = _price(g, jnp.zeros((32, 64)), jnp.zeros((4, 64, 64)))
    expect = 4 * 3 * 2 * 32 * 64 * 64
    assert c.flops == pytest.approx(expect, rel=0.1)


def test_batched_dot_contracting_dims():
    c = _price(
        lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
        jnp.zeros((8, 32, 64)), jnp.zeros((8, 64, 16)),
    )
    assert c.flops == pytest.approx(2 * 8 * 32 * 64 * 16, rel=0.05)


def test_grad_adds_backward_flops():
    w = jnp.zeros((64, 64))
    x = jnp.zeros((32, 64))

    def loss(w):
        return jnp.sum((x @ w) ** 2)

    fwd = _price(loss, w)
    both = _price(jax.value_and_grad(loss), w)
    assert both.flops > 1.9 * fwd.flops  # bwd of a matmul = 2 matmuls


@pytest.mark.skipif(jax.device_count() != 1, reason="spmd text differs")
def test_collectives_counted_inside_loops():
    """Manual psum inside a scan on a 1-device mesh lowers to an all-reduce
    (or is optimized away on 1 device) — exercise the parser path with a
    shard_map when >1 device is unavailable: fall back to checking the
    collective accumulators stay zero for loop-free local code."""
    c = _price(lambda a: a * 2 + 1, jnp.zeros((16, 16)))
    assert c.coll_bytes == 0 and not c.coll_counts
