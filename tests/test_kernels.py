"""Per-kernel validation (deliverable c): sweep shapes/dtypes and
assert_allclose against the pure-jnp ref.py oracle.  Pallas kernels run in
interpret mode on CPU (the ops.py wrappers select it automatically).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import mha, mha_ref
from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_oracle
from repro.kernels.ssd.ops import ssd, ssd_oracle
from repro.kernels.walk_transition.kernel import walk_transition_bucketed
from repro.kernels.walk_transition.ops import (
    mhlj_step_batched,
    mhlj_step_bucketed,
    mhlj_step_oracle,
)
from repro.kernels.walk_transition.ref import (
    walk_transition_bucketed_ref,
    walk_transition_sparse_ref,
)
from repro.core.engine import WalkEngine
from repro.core.graphs import ring, watts_strogatz
from repro.core import transition as trans_mod


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,nq,nkv,h,causal,window",
    [
        (1, 128, 4, 4, 64, True, 0),      # MHA
        (2, 256, 8, 2, 64, True, 0),      # GQA 4:1
        (1, 256, 4, 1, 128, True, 0),     # MQA (paligemma kv=1)
        (2, 128, 4, 4, 64, False, 0),     # bidirectional (whisper encoder)
        (1, 384, 4, 2, 64, True, 128),    # sliding window (long_500k variant)
        (1, 160, 4, 4, 64, True, 0),      # non-multiple of block
        (2, 150, 4, 4, 64, False, 0),     # non-multiple, bidirectional (pad mask)
    ],
)
def test_flash_attention_matches_ref(b, s, nq, nkv, h, causal, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(k1, (b, s, nq, h), dtype)
    k = rand(k2, (b, s, nkv, h), dtype)
    v = rand(k3, (b, s, nkv, h), dtype)
    out = mha(q, k, v, causal=causal, window=window, block_q=128, block_k=128)
    ref = mha_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (2, 17, 256), (1, 8, 512), (3, 384)])
def test_rmsnorm_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = rand(k1, shape, dtype)
    scale = rand(k2, shape[-1:], jnp.float32)
    out = rmsnorm(x, scale)
    ref = rmsnorm_oracle(x, scale)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ----------------------------------------------------------------------- ssd
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,heads,groups,p,n,chunk",
    [
        (1, 128, 4, 1, 32, 16, 32),
        (2, 96, 4, 2, 64, 32, 32),    # grouped B/C, L not multiple of chunk
        (1, 256, 8, 1, 32, 64, 64),
    ],
)
def test_ssd_matches_ref(b, l, heads, groups, p, n, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(2), 5)
    xs = rand(keys[0], (b, l, heads, p), dtype)
    dt = jax.nn.softplus(rand(keys[1], (b, l, heads), jnp.float32))
    a = -jnp.exp(jax.random.normal(keys[2], (heads,)) * 0.3)
    bs = rand(keys[3], (b, l, groups, n), dtype)
    cs = rand(keys[4], (b, l, groups, n), dtype)
    y, _ = ssd(xs, dt, a, bs, cs, chunk=chunk)
    ref = ssd_oracle(xs, dt, a, bs, cs)
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ----------------------------------------------------------- walk transition
@pytest.mark.parametrize("n,walkers", [(16, 8), (64, 32), (100, 128)])
def test_walk_transition_matches_ref(n, walkers):
    g = ring(n) if n != 100 else watts_strogatz(100, 4, 0.1, seed=0)
    lips = np.ones(n)
    lips[n // 2] = 40.0
    p = trans_mod.mh_importance(g, lips)
    row_probs = jnp.asarray(trans_mod.row_probs_padded(p, g), jnp.float32)
    neighbors = jnp.asarray(g.neighbors)
    degrees = jnp.asarray(g.degrees)
    nodes = jnp.arange(walkers, dtype=jnp.int32) % n
    key = jax.random.PRNGKey(3)
    out = mhlj_step_batched(
        key, nodes, row_probs, neighbors, degrees, p_j=0.2, p_d=0.5, r=3
    )
    ref = mhlj_step_oracle(
        key, nodes, row_probs, neighbors, degrees, p_j=0.2, p_d=0.5, r=3
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # next nodes are valid node ids
    assert bool((out >= 0).all()) and bool((out < n).all())


def test_walk_transition_bucketed_matches_ops_and_refs():
    """The bucketed ops entry point, the per-bucket kernel dispatch, and the
    pure-jnp ref oracles all agree bitwise with the sparse paths."""
    n, walkers = 100, 96
    g = watts_strogatz(n, 4, 0.1, seed=0)
    lips = np.ones(n)
    lips[n // 2] = 40.0
    p = trans_mod.mh_importance(g, lips)
    row_probs = jnp.asarray(trans_mod.row_probs_padded(p, g), jnp.float32)
    neighbors = jnp.asarray(g.neighbors)
    degrees = jnp.asarray(g.degrees)
    nodes = jnp.arange(walkers, dtype=jnp.int32) % n
    key = jax.random.PRNGKey(7)
    params = trans_mod.MHLJParams(0.2, 0.5, 3)

    ref = mhlj_step_oracle(
        key, nodes, row_probs, neighbors, degrees, p_j=0.2, p_d=0.5, r=3
    )
    eng = WalkEngine.from_graph(
        g.to_csr().to_bucketed(), params, row_probs=row_probs, backend="scan"
    )
    out = mhlj_step_bucketed(key, nodes, eng)  # forces pallas inside
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    # the MH-move dispatch alone: kernel vs ref oracle, bitwise, and the
    # sparse ref oracle on full-width tiles agrees with both
    bid, rows_b, tiles_b = eng._bucket_tiles(nodes)
    u_mh = jax.random.uniform(jax.random.PRNGKey(8), (walkers,))
    v_kernel = walk_transition_bucketed(
        bid, rows_b, tiles_b, u_mh, interpret=True
    )
    v_ref = walk_transition_bucketed_ref(bid, rows_b, tiles_b, u_mh)
    v_sparse_ref = walk_transition_sparse_ref(
        row_probs[nodes], neighbors[nodes], u_mh
    )
    np.testing.assert_array_equal(np.asarray(v_kernel), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(v_kernel), np.asarray(v_sparse_ref))
    # misuse: a non-bucketed engine is rejected loudly
    flat = WalkEngine.from_graph(g, params, row_probs=row_probs)
    with pytest.raises(ValueError, match="bucketed"):
        mhlj_step_bucketed(key, nodes, flat)


def test_walk_transition_statistics():
    """Batched kernel transition frequencies match the dense MHLJ matrix row."""
    n = 12
    g = ring(n)
    lips = np.ones(n); lips[0] = 25.0
    p_is = trans_mod.mh_importance(g, lips)
    p_mhlj = trans_mod.mhlj(g, lips, trans_mod.MHLJParams(0.3, 0.5, 3))
    row_probs = jnp.asarray(trans_mod.row_probs_padded(p_is, g), jnp.float32)
    neighbors = jnp.asarray(g.neighbors)
    degrees = jnp.asarray(g.degrees)
    walkers = 40_000
    start = 5
    nodes = jnp.full((walkers,), start, jnp.int32)
    out = mhlj_step_batched(
        jax.random.PRNGKey(4), nodes, row_probs, neighbors, degrees,
        p_j=0.3, p_d=0.5, r=3,
    )
    freq = np.bincount(np.asarray(out), minlength=n) / walkers
    np.testing.assert_allclose(freq, p_mhlj[start], atol=0.012)
