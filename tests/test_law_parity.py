"""Four-layout contract for the two new chain laws.

``heterogeneity_rows*`` (MH targeting a dissimilarity-optimized pi,
arXiv:2204.06477) and ``private_weighted_rows*`` (MH targeting
Gamma-noised weights, arXiv:2009.01790) must honour the same acceptance
contract as every existing law:

1. The padded builder reproduces the dense-matrix truncation
   (``row_probs_padded`` of the dense MH chain) entry for entry, and the
   bucketed/ragged builders flatten it exactly.
2. All four engine layouts × both backends sample the law BITWISE
   identically per PRNG key — inherited from ``_mh_rows_block``, but
   asserted here so a law-specific regression cannot hide.
3. The one-step engine law matches the dense effective chain
   ``(1-p_j) P_mh + p_j P_levy`` by chi-square at ~4-sigma.
4. Long-run occupancy of the pure MH walk matches the law's target:
   pi itself for the heterogeneity law, ŵ/Σŵ for the private law —
   and gamma=0 degenerates the private law to the exact weighted walk.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MHLJParams,
    WalkEngine,
    barabasi_albert,
    flat_edge_values,
    heterogeneity,
    heterogeneity_mh,
    heterogeneity_rows,
    heterogeneity_rows_bucketed,
    heterogeneity_rows_ragged,
    mh_importance,
    mixing,
    private_weighted_mh,
    private_weighted_rows,
    private_weighted_rows_bucketed,
    private_weighted_rows_ragged,
    private_weights,
    row_probs_padded,
    star,
)
from repro.core import levy as levy_mod
from repro.core.walk import (
    empirical_distribution,
    graph_tensors,
    walk_markov_batched,
)
from tests.test_sparse_engine import _chi_square_stat, _engine

GAMMA = 0.6
NOISE_SEED = 4


@pytest.fixture(scope="module")
def setup():
    """Hub-heavy BA graph + one pi per law, shared across the module."""
    g = barabasi_albert(48, 3, seed=1, layout="dense")
    csr = g.to_csr()
    rng = np.random.default_rng(0)
    # a genuinely non-uniform optimized target: optimize_pi on a random
    # dissimilarity instance (floor keeps it strictly positive)
    h = heterogeneity.pairwise_gradient_dissimilarity(
        rng.normal(size=(3, g.n, 5))
    )
    pi = heterogeneity.optimize_pi(h, floor=0.25)
    weights = np.exp(rng.normal(0.0, 0.8, g.n))
    params = MHLJParams(0.25, 0.5, 3)
    return g, csr, pi, weights, params


def _law_cases(g, csr, pi, weights):
    """(tag, dense chain, padded rows, bucketed rows, ragged rows)."""
    bg = csr.to_bucketed()
    rg = csr.to_ragged()
    kw = dict(gamma=GAMMA, seed=NOISE_SEED)
    return [
        (
            "heterogeneity",
            heterogeneity_mh(g, pi),
            heterogeneity_rows(csr, pi),
            heterogeneity_rows_bucketed(bg, pi),
            heterogeneity_rows_ragged(rg, pi),
        ),
        (
            "private",
            private_weighted_mh(g, weights, **kw),
            private_weighted_rows(csr, weights, **kw),
            private_weighted_rows_bucketed(bg, weights, **kw),
            private_weighted_rows_ragged(rg, weights, **kw),
        ),
    ]


def test_builders_reproduce_dense_truncation(setup):
    """Claim 1: padded builder == row_probs_padded(dense chain); bucketed
    and ragged builders are its exact per-bucket / flat views."""
    g, csr, pi, weights, _ = setup
    bg = csr.to_bucketed()
    for tag, dense, rows, rows_b, flat in _law_cases(g, csr, pi, weights):
        # dense chain (float64 matrix math) vs float32 block builder: the
        # repo's contract here is allclose (cf. test_transitions); bitwise
        # holds only BETWEEN the layout builders, asserted below
        np.testing.assert_allclose(
            rows,
            row_probs_padded(dense, g),
            atol=1e-6,
            err_msg=f"{tag}: padded builder drifted from the dense chain",
        )
        np.testing.assert_array_equal(
            flat.view(np.int32),
            flat_edge_values(csr.indptr, csr.degrees, rows).view(np.int32),
            err_msg=f"{tag}: ragged builder is not the exact flatten",
        )
        for b, bucket in enumerate(bg.buckets):
            np.testing.assert_array_equal(
                rows_b[b].view(np.int32),
                rows[bucket.node_ids, : bucket.width].view(np.int32),
                err_msg=f"{tag}: bucket {b} rows drifted",
            )


def test_all_layouts_bitwise_equal_per_key(setup):
    """Claim 2: sparse/dense/bucketed/ragged × scan/pallas sample each new
    law bitwise-identically, from the shared table AND from the
    layout-native builders, at W values that are not block multiples."""
    g, csr, pi, weights, params = setup
    bg = csr.to_bucketed()
    rg = csr.to_ragged()
    for tag, dense, rows, rows_b, flat in _law_cases(g, csr, pi, weights):
        rp = jnp.asarray(rows)
        for w, block_w, key_seed in ((37, 16, 0), (300, 128, 1), (129, 64, 2)):
            key = jax.random.PRNGKey(key_seed)
            nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
            ref_n, ref_h = _engine(csr, params, rp, "scan").step(key, nodes)
            candidates = [
                _engine(csr, params, rp, "pallas", layout="sparse",
                        block_w=block_w),
                _engine(csr, params, rp, "pallas", layout="dense",
                        block_w=block_w),
                _engine(csr, params, rp, "pallas", layout="bucketed",
                        block_w=block_w),
                _engine(csr, params, rp, "scan", layout="bucketed"),
                _engine(csr, params, rp, "pallas", layout="ragged",
                        block_w=block_w),
                _engine(csr, params, rp, "scan", layout="ragged"),
                WalkEngine.from_graph(
                    bg, params, row_probs=rows_b, backend="pallas",
                    block_w=block_w,
                ),
                WalkEngine.from_graph(
                    rg, params, row_probs=flat, backend="scan",
                ),
            ]
            for eng in candidates:
                n2, h2 = eng.step(key, nodes)
                np.testing.assert_array_equal(
                    np.asarray(ref_n), np.asarray(n2),
                    err_msg=f"{tag}: {eng.backend}/{eng.layout} diverged",
                )
                np.testing.assert_array_equal(
                    np.asarray(ref_h), np.asarray(h2),
                    err_msg=f"{tag}: {eng.backend}/{eng.layout} hops diverged",
                )


@pytest.mark.slow
def test_one_step_law_matches_dense_chain_chi_square(setup):
    """Claim 3: the engine's one-step law under each new chain equals the
    dense effective chain (1-p_j) P_mh + p_j P_levy — chi-square at
    ~4-sigma from the trap node, on sparse scan + bucketed/ragged pallas."""
    g, csr, pi, weights, params = setup
    p_levy = levy_mod.levy_matrix_chained(g, params.p_d, params.r)
    start, w = 5, 30_000
    nodes = jnp.full((w,), start, jnp.int32)
    for tag, dense, rows, _, _ in _law_cases(g, csr, pi, weights):
        expected_row = (
            (1.0 - params.p_j) * dense + params.p_j * p_levy
        )[start]
        rp = jnp.asarray(rows)
        for backend, layout, key in (
            ("scan", "sparse", 21),
            ("pallas", "bucketed", 22),
            ("pallas", "ragged", 23),
        ):
            nxt, _ = _engine(csr, params, rp, backend, layout=layout).step(
                jax.random.PRNGKey(key), nodes
            )
            counts = np.bincount(
                np.asarray(nxt), minlength=csr.n
            ).astype(np.float64)
            stat, dof = _chi_square_stat(counts, expected_row)
            crit = dof + 4.0 * np.sqrt(2.0 * dof)
            assert stat < crit, (
                f"{tag}/{backend}/{layout}: chi2={stat:.1f} >= {crit:.1f}"
            )


@pytest.mark.slow
def test_stationary_occupancy_matches_law_target(setup):
    """Claim 4: long-run occupancy of the pure MH walk hits each law's
    target — pi for heterogeneity, ŵ/Σŵ for private."""
    g, csr, pi, weights, _ = setup
    w_hat = private_weights(weights, GAMMA, seed=NOISE_SEED)
    targets = {
        "heterogeneity": pi,
        "private": w_hat / w_hat.sum(),
    }
    nbrs, _ = graph_tensors(g)
    rng = np.random.default_rng(31)
    for tag, dense, rows, _, _ in _law_cases(g, csr, pi, weights):
        target = targets[tag]
        # dense-chain stationarity is exact (the MH construction target)
        pi_dense = mixing.stationary_distribution(dense)
        assert mixing.tv_distance(target, pi_dense) < 1e-8, tag
        v0s = jnp.asarray(
            rng.choice(g.n, size=256, p=target), jnp.int32
        )
        traj = walk_markov_batched(
            jax.random.PRNGKey(32), jnp.asarray(rows), nbrs, v0s, 800
        )
        emp = empirical_distribution(np.asarray(traj), g.n)
        tv = mixing.tv_distance(emp, target)
        assert tv < 0.08, f"{tag}: TV(occupancy, target)={tv:.3f}"


def test_private_gamma_zero_is_exact_weighted_walk(setup):
    """gamma=0 must recover the un-noised weighted walk exactly — the
    privacy knob's zero point is the paper's plain MH weighted chain."""
    from repro.core import mh_importance_rows

    g, csr, _, weights, _ = setup
    np.testing.assert_allclose(
        private_weighted_mh(g, weights, 0.0),
        mh_importance(g, weights),
        atol=1e-12,
    )
    # the builders share _mh_rows_block with the identical target, so the
    # zero point is BITWISE the P_IS builder — not merely close
    np.testing.assert_array_equal(
        private_weighted_rows(csr, weights, 0.0).view(np.int32),
        mh_importance_rows(csr, weights).view(np.int32),
    )


def test_private_gamma_trades_stationary_fidelity(setup):
    """More privacy (larger gamma) pulls the stationary law further from
    the true weighted target, monotonically in expectation — the
    privacy/convergence trade-off the law exists to expose."""
    g, _, _, weights, _ = setup
    target = weights / weights.sum()
    tvs = []
    for gamma in (0.0, 0.5, 4.0):
        # average over noise seeds so the comparison is about gamma
        tv = np.mean(
            [
                mixing.tv_distance(
                    mixing.stationary_distribution(
                        private_weighted_mh(g, weights, gamma, seed=s)
                    ),
                    target,
                )
                for s in range(5)
            ]
        )
        tvs.append(tv)
    assert tvs[0] < 1e-10  # gamma=0: exact
    assert tvs[0] < tvs[1] < tvs[2]


def test_heterogeneity_law_beats_uniform_on_hot_nodes():
    """End-to-end sanity on a star: the optimized law visits the
    high-dissimilarity hub more than MH-uniform would."""
    g = star(12)
    h = np.zeros((g.n, g.n))
    h[0, 1:] = h[1:, 0] = 9.0  # hub disagrees with everyone
    pi = heterogeneity.optimize_pi(h, floor=0.25)
    assert pi[0] > 1.0 / g.n  # upweighted vs uniform
    pi_dense = mixing.stationary_distribution(heterogeneity_mh(g, pi))
    assert mixing.tv_distance(pi, pi_dense) < 1e-8
