"""Correctness pins for the §Perf optimization paths (EXPERIMENTS.md):
repeat_kv attention == grouped GQA; MoE dispatch constraints don't change
values; weight clipping engages only for the online estimator."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graphs import ring
from repro.core.transition import MHLJParams
from repro.models.layers import attention as A
from repro.models.layers import moe as M
from repro.walk_sgd.llm_trainer import WalkContext, init_walk_state


@pytest.mark.parametrize("heads,kv", [(8, 2), (8, 8), (4, 1)])
def test_repeat_kv_matches_grouped(heads, kv):
    dims = A.AttnDims(d_model=128, num_heads=heads, num_kv_heads=kv, head_dim=32)
    params = A.attn_init(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 128))
    for mode, window in (("causal", 0), ("causal", 16), ("bidir", 0)):
        y1 = A.attention_full(params, x, dims, mode=mode, window=window)
        y2 = A.attention_full(
            params, x, dataclasses.replace(dims, repeat_kv=True),
            mode=mode, window=window,
        )
        np.testing.assert_allclose(
            np.asarray(y1), np.asarray(y2), atol=3e-5, rtol=3e-5
        )


def test_maybe_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = A._maybe_constrain(x, ("data", "model"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _activate_mesh(mesh):
    """Version-appropriate mesh activation: ``jax.set_mesh`` /
    ``jax.sharding.set_mesh`` on new JAX, the legacy ``with mesh:`` context
    (thread resources) on older releases."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is None:
        set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh  # Mesh is itself a context manager on older JAX


def test_maybe_constrain_skips_indivisible_dims():
    """Under a real mesh, dims that don't divide the axis are dropped (the
    batch-1 decode regression guard) — values unchanged either way."""
    mesh = jax.make_mesh((1,), ("model",))

    @jax.jit
    def f(x):
        return A._maybe_constrain(x, ("model", None)) * 2.0

    with _activate_mesh(mesh):
        out = f(jnp.ones((3, 4)))  # 3 % 1 == 0 -> constrained fine
    np.testing.assert_allclose(np.asarray(out), 2 * np.ones((3, 4)))


def test_moe_values_unchanged_by_constraint_gate():
    """cap >= 64 (constraint on) and cap < 64 (off) paths produce identical
    math on one device — the gate is perf-only."""
    dims = M.MoEDims(
        d_model=32, num_experts=4, experts_per_token=2, d_expert=16,
        capacity_factor=8.0,  # large cf -> cap >= 64 for s=32
    )
    params = M.moe_init(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    out1, aux1 = M.moe_apply(params, x, dims)
    dims2 = dataclasses.replace(dims, capacity_factor=1.25)  # cap < 64
    out2, aux2 = M.moe_apply(params, x, dims2)
    # different capacity -> possibly dropped tokens; compare only where no
    # drop occurred in either
    assert bool(jnp.isfinite(out1).all()) and bool(jnp.isfinite(out2).all())
    if float(aux1["moe_dropped_frac"]) == 0.0 == float(aux2["moe_dropped_frac"]):
        np.testing.assert_allclose(
            np.asarray(out1), np.asarray(out2), atol=1e-5, rtol=1e-5
        )


def test_weight_clip_online_only():
    graph = ring(16)
    lips = np.ones(16, np.float32)
    lips[0] = 1000.0  # w(0) = mean/1000 ~ 1/16 = 0.0634 -> clipped to 0.1
    exact = WalkContext.from_graph(graph, MHLJParams(0.1, 0.5, 3))
    online = dataclasses.replace(exact, online_lipschitz=True)
    state = init_walk_state(16, lips, v0=0)
    w_exact = float(exact.weight(state))
    w_online = float(online.weight(state))
    assert w_exact == pytest.approx(np.mean(lips) / 1000.0, rel=1e-4)
    assert w_exact < 0.1
    assert w_online == pytest.approx(0.1)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "mamba2-370m"])
def test_use_kernels_model_path_matches(arch):
    """cfg.use_kernels=True routes attention/SSD through the Pallas kernels
    (interpret mode on CPU) and matches the einsum/jnp path."""
    from repro.configs import get_arch, reduced
    from repro.models.factory import build_model

    cfg = reduced(get_arch(arch))
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    m1 = build_model(cfg, dtype=jnp.float32)
    m2 = build_model(cfg_k, dtype=jnp.float32)
    params = m1.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 128)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 128)), jnp.int32),
    }
    h1 = m1.apply(params, batch)
    h2 = m2.apply(params, batch)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4, rtol=2e-4)
    (l1, _), (l2, _) = m1.loss(params, batch), m2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
