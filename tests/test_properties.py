"""Property-based tests (hypothesis) on the system's invariants.

The paper's algebraic guarantees are checked on randomly drawn graphs and
Lipschitz vectors:

  P1  every transition design is row-stochastic and graph-supported
  P2  MH-IS has stationary distribution pi_IS(v) = L_v / sum(L)   (Eq. 5/7)
  P3  MH-IS satisfies detailed balance  pi_i P_ij = pi_j P_ji     (Eq. 8)
  P4  P_Levy is row-stochastic; MHLJ mixture P is a valid chain
  P5  MHLJ breaks detailed balance when the graph is non-regular/hetero
  P6  stationary perturbation is O(p_J): ||pi_MHLJ - pi_IS||_TV -> 0 as p_J -> 0
  P7  TruncGeom pmf sums to 1 and respects the support {1..r}
  P8  Remark 1: E[transitions/update] = 1 + p_J (E[d] - 1) <= 1 + p_J(1/p_d - 1)
  P9  importance weights w(v) = L_bar/L_v give an unbiased reweighted gradient
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import graphs as g_mod
from repro.core import levy as levy_mod
from repro.core import mixing as mix_mod
from repro.core import transition as trans_mod
from repro.core.importance import importance_distribution

MAX_EXAMPLES = 40


@st.composite
def graph_and_lipschitz(draw):
    kind = draw(st.sampled_from(["ring", "grid", "ws", "er", "star", "complete"]))
    seed = draw(st.integers(0, 10_000))
    if kind == "ring":
        n = draw(st.integers(4, 40))
        g = g_mod.ring(n)
    elif kind == "grid":
        r = draw(st.integers(2, 6))
        g = g_mod.grid2d(r, r)
    elif kind == "ws":
        n = draw(st.integers(8, 40))
        g = g_mod.watts_strogatz(n, 4, 0.2, seed=seed)
    elif kind == "er":
        n = draw(st.integers(5, 30))
        g = g_mod.erdos_renyi(n, 0.4, seed=seed)
    elif kind == "star":
        n = draw(st.integers(4, 20))
        g = g_mod.star(n)
    else:
        n = draw(st.integers(3, 15))
        g = g_mod.complete(n)
    rng = np.random.default_rng(seed)
    lips = rng.uniform(0.5, 2.0, g.n)
    if draw(st.booleans()):  # heterogeneous spike
        lips[rng.integers(0, g.n)] *= draw(st.floats(5.0, 200.0))
    return g, lips


@st.composite
def mhlj_params(draw):
    return trans_mod.MHLJParams(
        p_j=draw(st.floats(0.01, 0.5)),
        p_d=draw(st.floats(0.1, 0.9)),
        r=draw(st.integers(1, 5)),
    )


@given(graph_and_lipschitz())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p1_row_stochastic_and_supported(gl):
    g, lips = gl
    for p in (
        trans_mod.simple_rw(g),
        trans_mod.mh_uniform(g),
        trans_mod.mh_importance(g, lips),
    ):
        assert trans_mod.is_row_stochastic(p)
        assert trans_mod.supported_on_graph(p, g)
        assert (p >= -1e-12).all()


@given(graph_and_lipschitz())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p2_mh_is_stationary_is_importance(gl):
    g, lips = gl
    p = trans_mod.mh_importance(g, lips)
    pi = mix_mod.stationary_distribution(p)
    np.testing.assert_allclose(pi, importance_distribution(lips), atol=1e-6)


@given(graph_and_lipschitz())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p3_mh_is_detailed_balance(gl):
    g, lips = gl
    p = trans_mod.mh_importance(g, lips)
    pi = importance_distribution(lips)
    flow = pi[:, None] * p
    np.testing.assert_allclose(flow, flow.T, atol=1e-9)


@given(graph_and_lipschitz(), mhlj_params())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p4_mhlj_valid_chain(gl, params):
    g, lips = gl
    for chained in (True, False):
        p_levy = (
            levy_mod.levy_matrix_chained(g, params.p_d, params.r)
            if chained
            else levy_mod.levy_matrix(g, params.p_d, params.r)
        )
        assert trans_mod.is_row_stochastic(p_levy)
        p = trans_mod.mhlj(g, lips, params, chained_levy=chained)
        assert trans_mod.is_row_stochastic(p)
        # ergodic: stationary distribution exists and is strictly positive
        pi = mix_mod.stationary_distribution(p)
        assert (pi > 0).all()


@given(st.integers(0, 500))
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p5_mhlj_breaks_detailed_balance_on_hetero_ring(seed):
    g = g_mod.ring(12)
    rng = np.random.default_rng(seed)
    lips = rng.uniform(0.5, 2.0, 12)
    lips[rng.integers(0, 12)] *= 80.0
    params = trans_mod.MHLJParams(0.3, 0.5, 3)
    p = trans_mod.mhlj(g, lips, params)
    assert not mix_mod.is_reversible(p)


@given(st.integers(0, 10_000), st.integers(8, 30))
@settings(max_examples=20, deadline=None)
def test_p6_stationary_perturbation_vanishes_with_pj(seed, n):
    """O(p_J) perturbation (Theorem 1's second term).  The linear regime
    requires p_J below the trap-exit scale L_min/L_max, so bounded
    heterogeneity (4x) is drawn here; the deep-trap case is covered
    qualitatively by P5 and the entrapment tests."""
    g = g_mod.watts_strogatz(n, 4, 0.2, seed=seed)
    rng = np.random.default_rng(seed)
    lips = rng.uniform(0.5, 2.0, g.n)
    pi_is = importance_distribution(lips)
    tvs = []
    for p_j in (0.4, 0.2, 0.1, 0.05):
        p = trans_mod.mhlj(g, lips, trans_mod.MHLJParams(p_j, 0.5, 3))
        pi = mix_mod.stationary_distribution(p)
        tvs.append(mix_mod.tv_distance(pi, pi_is))
    # monotone (weakly) decreasing and -> 0; the O(p_J) theory gives ~8x
    # shrink for p_J 0.4 -> 0.05 but the map is sub-linear at large p_J,
    # so require a conservative 2.5x
    assert all(a >= b - 1e-9 for a, b in zip(tvs, tvs[1:]))
    assert tvs[-1] <= 0.4 * tvs[0] + 1e-9 or tvs[0] < 1e-9


@given(st.floats(0.05, 0.95), st.integers(1, 8))
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p7_truncgeom_pmf(p_d, r):
    pmf = levy_mod.trunc_geom_pmf(p_d, r)
    assert pmf.shape == (r,)
    assert (pmf > 0).all()
    np.testing.assert_allclose(pmf.sum(), 1.0, atol=1e-9)
    # matches the paper's formula elementwise
    d = np.arange(1, r + 1)
    expected = p_d * (1 - p_d) ** (d - 1) / (1 - (1 - p_d) ** r)
    np.testing.assert_allclose(pmf, expected, atol=1e-12)


@given(st.floats(0.01, 0.9), st.floats(0.1, 0.9), st.integers(1, 6))
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p8_remark1_bound(p_j, p_d, r):
    exact = levy_mod.expected_transitions_per_update(p_j, p_d, r)
    bound = levy_mod.remark1_bound(p_j, p_d, r)
    assert 1.0 <= exact <= bound + 1e-12


@given(st.integers(0, 1000), st.integers(5, 50))
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_p9_weighted_gradient_unbiased_under_pi_is(seed, n):
    """E_{v~pi_IS}[w(v) g_v] == mean_v g_v  (the IS construction, Eq. 12)."""
    rng = np.random.default_rng(seed)
    lips = rng.uniform(0.2, 5.0, n)
    grads = rng.normal(size=(n, 4))
    pi = importance_distribution(lips)
    w = lips.mean() / lips
    reweighted = (pi[:, None] * w[:, None] * grads).sum(0)
    np.testing.assert_allclose(reweighted, grads.mean(0), atol=1e-10)
