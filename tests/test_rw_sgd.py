"""End-to-end RW-SGD trainer behaviour (paper's experimental claims, small n)."""
import numpy as np
import pytest

from repro.core import MHLJParams, barabasi_albert, complete, ring
from repro.data import make_heterogeneous_regression, make_homogeneous_regression
from repro.walk_sgd import comm_report, run_rw_sgd


def test_uniform_converges_homogeneous():
    g = ring(32)
    data = make_homogeneous_regression(32, dim=6, seed=0, x_star_scale=3.0)
    res = run_rw_sgd("uniform", g, data, 2e-3, 30_000, seed=0)
    assert res.mse[-1] < 0.15 * res.mse[0]


def test_importance_beats_uniform_on_well_connected_hetero():
    """Paper Fig 4b: on ER/complete graphs with heterogeneous data, IS wins."""
    g = complete(32)
    data = make_heterogeneous_regression(
        32, dim=6, sigma_high_sq=100.0, p_high=0.05, seed=1, x_star_scale=3.0
    )
    gamma_u = 0.5 / data.lipschitz.max()
    gamma_is = 0.5 / data.lipschitz.mean()
    T = 15_000
    mse_u = run_rw_sgd("uniform", g, data, gamma_u, T, seed=0).mse
    mse_is = run_rw_sgd("importance", g, data, gamma_is, T, seed=0).mse
    # compare early-phase area under curve (log scale robust): IS faster
    assert np.log(mse_is[200:2000]).mean() < np.log(mse_u[200:2000]).mean()


def test_entrapment_slows_importance_on_ring():
    """Paper Fig 2+3: ring with one extreme-L node at the walk's start.

    MH-IS exit probability from the trap is ~L_nb/L_high (detailed balance,
    Eq. 8), so the walk freezes there; MHLJ's jumps break detailed balance
    and escape.  Assertions verified robust over seeds 0-4 (ratio <= 0.2).
    """
    g = ring(64)
    data = make_heterogeneous_regression(
        64, dim=6, sigma_high_sq=1e3, high_nodes=np.array([0]), seed=3,
        x_star_scale=3.0,
    )
    T = 20_000
    gamma = 0.3 / data.lipschitz.mean()
    res_is = run_rw_sgd("importance", g, data, gamma, T, seed=1, v0=0)
    res_mhlj = run_rw_sgd(
        "mhlj", g, data, gamma, T, mhlj_params=MHLJParams(0.1, 0.5, 3),
        seed=1, v0=0,
    )
    # 1) entrapment: IS spends nearly all updates at the trap node; MHLJ escapes
    assert (res_is.update_nodes == 0).mean() > 0.9
    assert (res_mhlj.update_nodes == 0).mean() < 0.3
    # 2) convergence: MHLJ's mid-phase objective far below entrapped IS
    #    (median is robust to the high-L node's residual amplification)
    med_is = np.median(res_is.mse[2000:10000])
    med_mhlj = np.median(res_mhlj.mse[2000:10000])
    assert med_mhlj < 0.5 * med_is


def test_mhlj_comm_overhead_within_remark1():
    g = ring(32)
    data = make_heterogeneous_regression(32, dim=4, seed=0)
    res = run_rw_sgd(
        "mhlj", g, data, 1e-3, 20_000, mhlj_params=MHLJParams(0.1, 0.5, 3), seed=0
    )
    rep = comm_report(res.transitions, 0.1, 0.5, 3)
    assert rep["within_bound"]
    assert rep["transitions_per_update_measured"] == pytest.approx(
        rep["transitions_per_update_exact"], abs=0.05
    )


def test_pj_annealing_removes_error_gap():
    """Paper Fig 6 / Theorem 1 gap term, checked in closed form: the
    asymptotic bias ||x~(p_J) - x_LS||^2 vanishes superlinearly as
    p_J -> 0 (slope -> 2 on log-log), so annealing p_J removes the gap.
    The closed form avoids the SGD endpoint noise that made the simulated
    version seed-fragile (see examples/annealing_error_gap.py part 2 for
    the seed-averaged simulation)."""
    from repro.core.theory import error_gap_exact

    n = 64
    g = ring(n)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(n, 6)) * np.where(rng.random(n) < 0.1, 2.0, 1.0)[:, None]
    targs = feats @ (3 * rng.normal(size=6)) + rng.normal(size=n)
    lips = 2 * (feats**2).sum(1)
    pjs = [0.2, 0.1, 0.05, 0.025, 0.0125]
    gaps = [
        error_gap_exact(g, feats, targs, lips, MHLJParams(pj, 0.5, 3)) for pj in pjs
    ]
    # strictly decreasing and eventually faster than linear in p_J
    assert all(a > b for a, b in zip(gaps, gaps[1:]))
    slopes = [
        np.log(gaps[i] / gaps[i - 1]) / np.log(pjs[i] / pjs[i - 1])
        for i in range(1, len(gaps))
    ]
    assert slopes[-1] > 1.5  # approaching the O(p_J^2) law
    assert gaps[-1] < 0.05 * gaps[0]
    # p_J = 0 has exactly zero gap (IS weights cancel the sampling bias)
    assert error_gap_exact(g, feats, targs, lips, MHLJParams(0.0, 0.5, 3)) < 1e-18


def test_ragged_graph_trains_identically_to_csr():
    """A RaggedCSRGraph rides the same jitted training loop as every other
    graph class and — because the ragged engine is bitwise-identical per
    key — produces the exact same walk and MSE trace as the padded CSR
    graph for every method."""
    csr = barabasi_albert(40, 3, seed=2, layout="csr")
    rg = csr.to_ragged()
    data = make_heterogeneous_regression(
        40, dim=5, sigma_high_sq=50.0, p_high=0.1, seed=3, x_star_scale=2.0
    )
    for method in ("uniform", "importance", "mhlj"):
        ref = run_rw_sgd(
            method, csr, data, 1e-3, 400, seed=5,
            mhlj_params=MHLJParams(0.2, 0.5, 3),
        )
        got = run_rw_sgd(
            method, rg, data, 1e-3, 400, seed=5,
            mhlj_params=MHLJParams(0.2, 0.5, 3),
        )
        np.testing.assert_array_equal(ref.update_nodes, got.update_nodes)
        np.testing.assert_array_equal(ref.mse, got.mse)


def test_simple_rw_baseline_runs():
    g = ring(16)
    data = make_homogeneous_regression(16, dim=4, seed=0)
    res = run_rw_sgd("simple", g, data, 1e-3, 2_000, seed=0)
    assert np.isfinite(res.mse).all()
    assert res.transitions_per_update == 1.0


# ---------------------------------------------------------------------------
# New chain laws through the trainer
# ---------------------------------------------------------------------------


def test_heterogeneity_method_trains_and_is_layout_invariant():
    """method='heterogeneity' converges, and — like every law — the walk
    and MSE trace are bitwise-identical across graph classes."""
    csr = barabasi_albert(40, 3, seed=2, layout="csr")
    rg = csr.to_ragged()
    data = make_heterogeneous_regression(
        40, dim=5, sigma_high_sq=50.0, p_high=0.1, seed=3, x_star_scale=2.0
    )
    ref = run_rw_sgd("heterogeneity", csr, data, 1e-3, 3_000, seed=5)
    assert np.isfinite(ref.mse).all()
    assert ref.mse[-1] < 0.2 * ref.mse[0]
    got = run_rw_sgd("heterogeneity", rg, data, 1e-3, 3_000, seed=5)
    np.testing.assert_array_equal(ref.update_nodes, got.update_nodes)
    np.testing.assert_array_equal(ref.mse, got.mse)


def test_heterogeneity_method_accepts_precomputed_pi():
    """law_kwargs={'pi': ...} skips the measurement pipeline; the walk then
    targets exactly the supplied distribution."""
    g = ring(24)
    data = make_homogeneous_regression(24, dim=4, seed=0, x_star_scale=2.0)
    rng = np.random.default_rng(0)
    pi = rng.uniform(0.5, 2.0, 24)
    pi /= pi.sum()
    res = run_rw_sgd(
        "heterogeneity", g, data, 1e-3, 20_000, seed=2, law_kwargs={"pi": pi}
    )
    emp = np.bincount(res.update_nodes, minlength=24) / res.update_nodes.size
    assert 0.5 * np.abs(emp - pi).sum() < 0.1  # occupancy hits the target


def test_private_method_trains_and_gamma_zero_matches_importance():
    """method='private' converges; with gamma=0 the noised weights equal
    the true ones, so the walk (and the whole trace) is bitwise the
    importance run."""
    csr = barabasi_albert(40, 3, seed=2, layout="csr")
    data = make_heterogeneous_regression(
        40, dim=5, sigma_high_sq=50.0, p_high=0.1, seed=3, x_star_scale=2.0
    )
    res = run_rw_sgd(
        "private", csr, data, 1e-3, 3_000, seed=5, law_kwargs={"gamma": 0.5}
    )
    assert np.isfinite(res.mse).all()
    assert res.mse[-1] < 0.2 * res.mse[0]
    res0 = run_rw_sgd(
        "private", csr, data, 1e-3, 3_000, seed=5, law_kwargs={"gamma": 0.0}
    )
    ref = run_rw_sgd("importance", csr, data, 1e-3, 3_000, seed=5)
    np.testing.assert_array_equal(res0.update_nodes, ref.update_nodes)
    np.testing.assert_array_equal(res0.mse, ref.mse)


def test_private_noise_seed_changes_walk_not_validity():
    csr = barabasi_albert(32, 3, seed=4, layout="csr")
    data = make_heterogeneous_regression(32, dim=4, seed=1, x_star_scale=2.0)
    kw = dict(gamma=2.0)
    a = run_rw_sgd(
        "private", csr, data, 1e-3, 1_500, seed=7,
        law_kwargs={**kw, "noise_seed": 0},
    )
    b = run_rw_sgd(
        "private", csr, data, 1e-3, 1_500, seed=7,
        law_kwargs={**kw, "noise_seed": 1},
    )
    assert np.isfinite(a.mse).all() and np.isfinite(b.mse).all()
    assert not np.array_equal(a.update_nodes, b.update_nodes)


def test_law_kwargs_rejected_for_other_methods():
    g = ring(16)
    data = make_homogeneous_regression(16, dim=4, seed=0)
    with pytest.raises(ValueError, match="law_kwargs"):
        run_rw_sgd("mhlj", g, data, 1e-3, 100, law_kwargs={"gamma": 0.1})
    with pytest.raises(ValueError, match="unknown"):
        run_rw_sgd(
            "private", g, data, 1e-3, 100, law_kwargs={"gammma": 0.1}
        )
