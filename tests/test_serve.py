"""Walk-routed serving tests: the ServeEngine scheduling contract and the
ServeSimulator routing loop (docs/serving.md documents both).

The engine edge cases named by the contract are each pinned here:
finished-slot immediate refill, queue-empty idle slots as no-ops, prompts
that cannot fit the cache budget rejected loudly, and deadline-expired
requests shed exactly once (a double shed is a RuntimeError, not a
double-counted statistic).
"""
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.graphs import barabasi_albert
from repro.launch.serve import (
    Request,
    ServeEngine,
    ServeSimulator,
    build_route_engine,
    latency_percentiles,
)

CFG = reduced(get_arch("mamba2-370m"))


@pytest.fixture(scope="module")
def engine():
    # one model build + decode compile for the whole module; each test
    # takes a fresh serving state via reset() (the same reuse seam the
    # serve_throughput benchmark leans on)
    return ServeEngine(CFG, 2, 64, seed=0, max_queue=4)


def _req(rid, plen=4, max_new=3, **kw):
    rng = np.random.default_rng(100 + rid)
    prompt = rng.integers(0, CFG.vocab_size, plen).astype(np.int32)
    return Request(rid=rid, prompt=prompt, max_new_tokens=max_new, **kw)


# -- ServeEngine scheduling contract ---------------------------------------


def test_finished_slot_immediately_refilled(engine):
    """A slot freed by a finishing request admits the next queued request
    in the same engine step's fill — no idle step in between."""
    eng = engine.reset()
    for rid in range(4):  # 2 slots, 4 equal-length requests
        assert eng.submit(_req(rid, plen=4, max_new=3))
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
    stats = eng.stats()
    assert stats["completed"] == 4
    # equal-sized requests + immediate refill = both slots busy every step:
    # each request takes plen + max_new - 1 = 6 busy steps (the last prefill
    # step already yields the first generated token), 4 x 6 over 2 slots =
    # exactly 12 engine steps
    assert stats["engine_steps"] == 12
    assert stats["slot_utilization"] == 1.0


def test_queue_empty_idle_slots_are_noops(engine):
    """With nothing queued, step() burns neither an engine step nor a
    cache row; a half-empty batch still decodes correctly."""
    eng = engine.reset()
    eng.step()  # fully idle
    assert eng.engine_steps == 0 and eng.cache_pos == 0
    assert eng.submit(_req(0, plen=4, max_new=3))  # 1 request, 2 slots
    eng.run()
    stats = eng.stats()
    assert stats["completed"] == 1
    assert len(eng.completed[0].generated) == 3
    # exactly one of two slots was ever busy
    assert stats["slot_utilization"] == pytest.approx(0.5)


def test_oversized_prompt_rejected_loudly(engine):
    """prompt + max_new_tokens beyond the cache budget is a ValueError at
    submit — never queued, never silently truncated."""
    eng = engine.reset()
    with pytest.raises(ValueError, match="cache budget"):
        eng.submit(_req(0, plen=eng.cache_len, max_new=1))
    with pytest.raises(ValueError, match="cache budget"):
        eng.submit(_req(1, plen=4, max_new=eng.cache_len))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(_req(2, plen=0))
    assert not eng.queue and not eng.shed_requests


def test_deadline_expired_shed_exactly_once(engine):
    """An expired queue head is shed with reason "deadline" exactly once;
    re-shedding the same request raises instead of double counting."""
    eng = engine.reset()
    expired = _req(0, deadline=5)
    live = _req(1, deadline=50)
    assert eng.submit(expired, tick=0)
    assert eng.submit(live, tick=0)
    eng.step(tick=10)  # past expired's deadline, inside live's
    assert expired.shed and expired.shed_reason == "deadline"
    assert eng.stats()["shed_deadline"] == 1
    assert eng.slots[0] is live  # the live request was admitted instead
    # shed-exactly-once is an invariant, not a convention
    with pytest.raises(RuntimeError, match="shed twice"):
        eng.shed(expired, "queue_full")
    assert eng.stats()["shed_deadline"] == 1
    assert eng.stats()["shed_queue_full"] == 0


def test_bounded_queue_backpressure(engine):
    """submit() against a full admission queue sheds loudly and returns
    False instead of growing the queue without bound."""
    eng = engine.reset()  # max_queue=4
    assert all(eng.submit(_req(rid)) for rid in range(4))
    overflow = _req(99)
    assert eng.submit(overflow) is False
    assert overflow.shed and overflow.shed_reason == "queue_full"
    assert eng.stats()["shed_queue_full"] == 1
    assert len(eng.queue) == 4


def test_cache_recycle_preempts_and_completes(engine):
    """When the shared cache position exhausts cache_len the engine
    recycles (preempt to queue front + replay) instead of stopping."""
    eng = engine.reset()
    # 6 requests x 12 tokens over 2 slots = 36 busy steps > 63-step epoch?
    # no — force recycling with long generations instead: 4 x (8+30) over
    # 2 slots = 76 busy steps, beyond the 63-row cache epoch
    for rid in range(4):
        assert eng.submit(_req(rid, plen=8, max_new=30))
    stats = eng.run()
    assert stats["completed"] == 4
    assert stats["cache_recycles"] >= 1
    for req in eng.completed:
        assert len(req.generated) == 30


def test_latency_percentiles_bookkeeping(engine):
    eng = engine.reset()
    for rid in range(3):
        assert eng.submit(_req(rid, plen=4, max_new=3), tick=0)
    eng.run()
    lat = latency_percentiles(eng.completed)
    assert lat["p50_ticks"] > 0
    assert lat["p50_ticks"] <= lat["p95_ticks"] <= lat["p99_ticks"]
    assert latency_percentiles([]) == {
        "p50_ticks": 0.0, "p95_ticks": 0.0, "p99_ticks": 0.0
    }


# -- walk-routed simulator --------------------------------------------------


@pytest.fixture(scope="module")
def graph():
    return barabasi_albert(96, 3, seed=0, layout="ragged")


def test_build_route_engine_methods_seam(graph):
    load = np.asarray(graph.degrees, np.float64)
    eng_mhlj, p_j = build_route_engine(graph, "mhlj", load)
    assert p_j > 0.0  # the jump law routes with jumps
    _, p_j0 = build_route_engine(graph, "uniform", load)
    assert p_j0 == 0.0
    with pytest.raises(ValueError, match="method"):
        build_route_engine(graph, "no-such-law", load)
    with pytest.raises(ValueError, match="positive"):
        build_route_engine(graph, "uniform", np.zeros(graph.n))


def test_simulator_serves_requests_end_to_end(engine, graph):
    sim = ServeSimulator(
        graph, engine.reset(), method="mhlj", num_walkers=16,
        rate=1.0, pickup=4, deadline_ticks=60,
        prompt_len=(4, 8), max_new_tokens=4, seed=0,
    )
    metrics = sim.run(60, drain_ticks=30)
    assert metrics["offered"] > 0
    assert metrics["completed"] > 0
    assert metrics["requests_per_sec"] > 0
    assert 0.0 < metrics["herfindahl"] <= 1.0
    assert metrics["p99_ticks"] >= metrics["p50_ticks"] > 0
    # conservation: every offered request is accounted for exactly once
    accounted = (
        metrics["completed"]
        + metrics["shed_queue_full"]
        + metrics["shed_deadline"]
        + metrics["pending_left"]
        + metrics["queued_left"]
        + sum(1 for s in sim.engine.slots if s is not None)
    )
    assert accounted == metrics["offered"]


def test_simulator_heterogeneity_defaults_pi_to_load(engine, graph):
    # must not fall into the O(n^2) dissimilarity measurement: the load
    # vector (here degree-proportional) is the routing target by default
    sim = ServeSimulator(
        graph, engine.reset(), method="heterogeneity", num_walkers=8,
        rate=0.5, prompt_len=(4, 6), max_new_tokens=3, seed=1,
    )
    metrics = sim.run(30, drain_ticks=10)
    assert metrics["ticks"] == 40
    assert metrics["offered"] > 0


def test_simulator_rejects_bad_requests(engine, graph):
    sim = ServeSimulator(
        graph, engine.reset(), num_walkers=4, seed=0,
        prompt_len=(4, 6), max_new_tokens=3,
    )
    with pytest.raises(ValueError, match="outside"):
        sim.offer(_req(0, node=graph.n))
    with pytest.raises(ValueError, match="cache budget"):
        sim.offer(_req(1, node=0, plen=engine.cache_len, max_new=1))
